//! Churn resilience demo (paper Fig. 8 in miniature): a 120-node FedLay
//! overlay suffers 30 simultaneous crash-failures, then 30 simultaneous
//! joins, while we plot topology correctness over time.
//!
//! ```bash
//! cargo run --release --example churn_demo
//! ```

use fedlay::bench_util::Table;
use fedlay::config::{NetConfig, OverlayConfig};
use fedlay::ndmp::messages::MS;
use fedlay::sim::{churn, Simulator};

fn main() {
    let overlay = OverlayConfig {
        spaces: 3,
        heartbeat_ms: 500,
        failure_multiple: 3,
        repair_probe_ms: 2_000,
    };
    let net = NetConfig {
        latency_ms: 350.0,
        jitter: 0.2,
        seed: 5,
    };

    println!("== phase A: 30 concurrent failures out of 120 nodes ==");
    let mut sim = Simulator::new(overlay.clone(), net.clone());
    churn::mass_fail(&mut sim, 120, 30, 10 * MS, 1);
    churn::sample_correctness(&mut sim, 60_000 * MS, 2_000 * MS);
    sim.run_until(60_000 * MS);
    let mut t = Table::new(&["t (s)", "correctness", "live"]);
    for s in &sim.samples {
        t.row(&[
            format!("{:.0}", s.at as f64 / 1e6),
            format!("{:.4}", s.correctness),
            s.live_nodes.to_string(),
        ]);
    }
    print!("{}", t.render());
    let final_c = sim.correctness();
    println!("final correctness: {final_c:.4}\n");
    assert!(final_c > 0.999, "failure recovery incomplete");

    println!("== phase B: 30 concurrent joins into 90 survivors ==");
    let mut sim2 = Simulator::new(overlay, net);
    churn::mass_join(&mut sim2, 90, 30, 10 * MS, 2);
    churn::sample_correctness(&mut sim2, 60_000 * MS, 2_000 * MS);
    sim2.run_until(60_000 * MS);
    let mut t2 = Table::new(&["t (s)", "correctness", "live"]);
    for s in &sim2.samples {
        t2.row(&[
            format!("{:.0}", s.at as f64 / 1e6),
            format!("{:.4}", s.correctness),
            s.live_nodes.to_string(),
        ]);
    }
    print!("{}", t2.render());
    let final_c2 = sim2.correctness();
    println!("final correctness: {final_c2:.4}");
    assert!(final_c2 > 0.999, "join convergence incomplete");
    println!("churn_demo OK");
}
