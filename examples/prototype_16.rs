//! The paper's §IV-A1 "real experiment": 16 FedLay clients exchanging
//! real TCP packets on localhost (ids map to ports), each owning a private
//! PJRT engine, non-iid shards, and heterogeneous capacities. One node
//! bootstraps; the other 15 join through NDMP greedy routing; everyone
//! trains and runs MEP offer/request/payload exchanges; finally each node
//! reports accuracy and message counters.
//!
//! Scaled down for CI wallclock (2 s exchange period, ~20 s run); the
//! protocol path is identical to a WAN deployment.
//!
//! ```bash
//! make artifacts && cargo run --release --example prototype_16
//! ```

use fedlay::bench_util::Table;
use fedlay::config::OverlayConfig;
use fedlay::net::{spawn, ClientNodeConfig};
use fedlay::runtime::find_artifacts_dir;

fn main() -> anyhow::Result<()> {
    let n: u64 = std::env::var("FEDLAY_PROTO_NODES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(16);
    let run_ms: u64 = std::env::var("FEDLAY_PROTO_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(20_000);
    let base_port = 7450u16;
    let dir = find_artifacts_dir(None)?;
    let overlay = OverlayConfig {
        spaces: 3,
        heartbeat_ms: 500,
        failure_multiple: 3,
        repair_probe_ms: 1_500,
    };
    let shards = fedlay::data::shard_labels(n as usize, 10, 8, 42);

    println!("spawning {n} real TCP clients on 127.0.0.1:{base_port}+id ...");
    let mut handles = Vec::new();
    for id in 0..n {
        let cfg = ClientNodeConfig {
            id,
            base_port,
            bootstrap: if id == 0 { None } else { Some((id * 7) % id) },
            book: None,
            overlay: overlay.clone(),
            artifacts_dir: dir.clone(),
            task: "mlp".into(),
            task_id: 0,
            label_weights: shards[id as usize].clone(),
            lr: 0.5,
            local_steps: 2,
            // heterogeneity: high/low/medium tiers like the paper
            period_ms: match id % 5 {
                0 => 1_400, // high capacity
                1 => 4_000, // low capacity
                _ => 2_000, // medium
            },
            seed: 42,
        };
        handles.push(spawn(cfg)?);
        // slight stagger so joiners find a live bootstrap
        std::thread::sleep(std::time::Duration::from_millis(if id == 0 { 300 } else { 120 }));
    }
    println!("running for {run_ms} ms of wall-clock protocol time ...");
    std::thread::sleep(std::time::Duration::from_millis(run_ms));

    let mut t = Table::new(&[
        "node", "acc", "loss", "neighbors", "joined", "ctrl msgs", "model MB", "dedup",
    ]);
    let mut accs = Vec::new();
    let mut joined_count = 0;
    for h in handles {
        let r = h.stop_and_join()?;
        accs.push(r.accuracy);
        joined_count += r.joined as usize;
        t.row(&[
            r.id.to_string(),
            format!("{:.3}", r.accuracy),
            format!("{:.3}", r.loss),
            r.neighbor_count.to_string(),
            r.joined.to_string(),
            r.control_sent.to_string(),
            format!("{:.2}", r.model_bytes_sent as f64 / 1e6),
            r.dedup_skips.to_string(),
        ]);
    }
    print!("{}", t.render());
    let mean = accs.iter().sum::<f64>() / accs.len() as f64;
    println!("\nmean accuracy: {mean:.3}  nodes joined: {joined_count}/{n}");
    anyhow::ensure!(joined_count == n as usize, "some nodes failed to join");
    anyhow::ensure!(mean > 0.2, "prototype learned nothing (mean acc {mean:.3})");
    println!("prototype_16 OK");
    Ok(())
}
