//! End-to-end system driver (the EXPERIMENTS.md validation run).
//!
//! Exercises every layer of the stack on one workload:
//!   1. a FedLay overlay is built **decentralized** by NDMP joins in the
//!      discrete-event simulator (350 ms WAN latency, heartbeats, probes);
//!   2. the resulting *live* overlay graph (not the idealized one) is
//!      handed to the DFL trainer;
//!   3. 16 heterogeneous non-iid clients train the MLP task through the
//!      AOT artifacts (PJRT; L1 Pallas kernels inside) with MEP
//!      confidence-weighted asynchronous exchange;
//!   4. mid-run, 4 clients crash and 4 new ones join (accuracy-under-churn);
//!   5. the loss/accuracy curve, per-client CDF, and communication costs
//!      are printed.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end_dfl
//! ```

use fedlay::bench_util::Table;
use fedlay::config::{Config, NetConfig, OverlayConfig};
use fedlay::dfl::{MethodSpec, Trainer};
use fedlay::graph::Graph;
use fedlay::ndmp::messages::MS;
use fedlay::runtime::{find_artifacts_dir, Engine};
use fedlay::sim::{grow_network, Simulator};
use fedlay::util::cdf_points;

/// Extract the live overlay graph (indices 0..n over live node ids).
fn live_graph(sim: &Simulator) -> Graph {
    let ids: Vec<u64> = sim.nodes.keys().copied().collect();
    let index: std::collections::BTreeMap<u64, usize> =
        ids.iter().enumerate().map(|(i, &id)| (id, i)).collect();
    let mut g = Graph::new(ids.len());
    for (&id, st) in &sim.nodes {
        for n in st.neighbor_ids() {
            if let (Some(&u), Some(&v)) = (index.get(&id), index.get(&n)) {
                g.add_edge(u, v);
            }
        }
    }
    g
}

fn main() -> anyhow::Result<()> {
    let n = 16;
    println!("=== end-to-end FedLay DFL: {n} clients, mlp task ===\n");

    // --- Phase 1: decentralized overlay construction (NDMP) ---
    let overlay = OverlayConfig {
        spaces: 3,
        heartbeat_ms: 500,
        failure_multiple: 3,
        repair_probe_ms: 2_000,
    };
    let net = NetConfig {
        latency_ms: 350.0, // paper's WAN latency
        jitter: 0.2,
        seed: 11,
    };
    let sim = grow_network(overlay, net, n, 1_500 * MS);
    let correctness = sim.correctness();
    println!("phase 1 — NDMP construction:");
    println!("  topology correctness: {correctness:.4}");
    println!(
        "  control messages/node: {:.1}",
        sim.control_messages_per_node()
    );
    let g = live_graph(&sim);
    let tm = fedlay::metrics::evaluate(&g, 3);
    println!(
        "  live overlay: lambda={:.3} diameter={} aspl={:.2} avg degree={:.1}\n",
        tm.lambda, tm.diameter, tm.avg_shortest_path, tm.avg_degree
    );
    assert!(correctness > 0.99, "NDMP failed to build a correct overlay");

    // --- Phase 2+3: DFL training over the live overlay ---
    let cfg = Config::default();
    let mut dfl = cfg.dfl.clone();
    dfl.clients = n;
    dfl.local_steps = 4;
    dfl.shards_per_client = 8;
    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &["mlp"])?;
    let weights = fedlay::data::shard_labels(n, 10, dfl.shards_per_client, dfl.seed);
    let spec = MethodSpec::fedlay_with_graph(g);
    let mut trainer = Trainer::new(&engine, spec, dfl, weights)?;
    println!("phase 2/3 — asynchronous MEP training (5-min base period):");
    let horizon = 240 * 60 * 1_000_000u64; // 4 simulated hours
    let sample = 30 * 60 * 1_000_000u64;
    trainer.run(horizon, sample)?;
    let mut t = Table::new(&["t (min)", "mean acc", "mean loss"]);
    for s in &trainer.samples {
        t.row(&[
            format!("{:.0}", s.at as f64 / 60e6),
            format!("{:.4}", s.mean_accuracy),
            format!("{:.4}", s.mean_loss),
        ]);
    }
    print!("{}", t.render());
    let last = trainer.samples.last().unwrap().clone();

    // --- per-client accuracy CDF (paper Fig. 9d-f analogue) ---
    println!("\nper-client accuracy CDF at convergence:");
    for (acc, frac) in cdf_points(&last.per_client) {
        println!("  acc<={acc:.3}: {frac:.2}");
    }
    let spread = last
        .per_client
        .iter()
        .cloned()
        .fold(f64::NEG_INFINITY, f64::max)
        - last
            .per_client
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min);
    println!("  spread (max-min): {spread:.3}  — no stragglers expected");

    // --- comm cost ---
    println!("\ncommunication:");
    println!(
        "  model payload: {:.2} MB/client, dedup skips: {}",
        trainer.model_mb_per_client(),
        trainer.clients.iter().map(|c| c.dedup_skips).sum::<u64>()
    );
    println!(
        "  train steps/client: {:.1}",
        trainer.train_steps_per_client()
    );

    // --- sanity gates for EXPERIMENTS.md ---
    let base = trainer.samples[0].mean_accuracy;
    anyhow::ensure!(
        last.mean_accuracy > base + 0.25,
        "training did not improve enough: {base:.3} -> {:.3}",
        last.mean_accuracy
    );
    anyhow::ensure!(
        last.mean_loss < trainer.samples[0].mean_loss,
        "loss did not decrease"
    );
    println!("\nend_to_end_dfl OK (acc {:.3} -> {:.3})", base, last.mean_accuracy);
    Ok(())
}
