//! End-to-end system driver (the EXPERIMENTS.md validation run).
//!
//! Exercises every layer of the stack on one workload:
//!   1. a FedLay overlay is built **decentralized** by NDMP joins in the
//!      discrete-event simulator (350 ms WAN latency, heartbeats, probes);
//!   2. the trainer runs on the *live* NDMP overlay (`fedlay_dynamic`):
//!      neighborhoods are read from the protocol state each wake;
//!   3. 16 heterogeneous non-iid clients train the MLP task through the
//!      runtime engine with MEP confidence-weighted asynchronous exchange;
//!   4. mid-run, 4 clients crash and 4 new ones join through the NDMP
//!      join protocol (accuracy-under-churn on one continuous timeline);
//!   5. the loss/accuracy curve, per-client CDF, and communication costs
//!      are printed.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end_dfl
//! ```

use fedlay::bench_util::Table;
use fedlay::config::{Config, NetConfig, OverlayConfig};
use fedlay::dfl::{MethodSpec, Trainer};
use fedlay::ndmp::messages::MS;
use fedlay::runtime::{find_artifacts_dir, Engine};
use fedlay::sim::grow_network;
use fedlay::util::cdf_points;

fn main() -> anyhow::Result<()> {
    let n = 16;
    println!("=== end-to-end FedLay DFL: {n} clients, mlp task ===\n");

    // --- Phase 1: decentralized overlay construction (NDMP) ---
    let overlay = OverlayConfig {
        spaces: 3,
        heartbeat_ms: 500,
        failure_multiple: 3,
        repair_probe_ms: 2_000,
    };
    let net = NetConfig {
        latency_ms: 350.0, // paper's WAN latency
        jitter: 0.2,
        seed: 11,
    };
    let sim = grow_network(overlay.clone(), net.clone(), n, 1_500 * MS);
    let correctness = sim.correctness();
    println!("phase 1 — NDMP construction:");
    println!("  topology correctness: {correctness:.4}");
    println!(
        "  control messages/node: {:.1}",
        sim.control_messages_per_node()
    );
    let (g, _ids) = sim.live_graph();
    let tm = fedlay::metrics::evaluate(&g, 3);
    println!(
        "  live overlay: lambda={:.3} diameter={} aspl={:.2} avg degree={:.1}\n",
        tm.lambda, tm.diameter, tm.avg_shortest_path, tm.avg_degree
    );
    assert!(correctness > 0.99, "NDMP failed to build a correct overlay");

    // --- Phase 2+3: DFL training over the live overlay ---
    let cfg = Config::default();
    let mut dfl = cfg.dfl.clone();
    dfl.clients = n;
    dfl.local_steps = 4;
    dfl.shards_per_client = 8;
    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &["mlp"])?;
    let joiners = 4usize;
    let weights = fedlay::data::shard_labels(n + joiners, 10, dfl.shards_per_client, dfl.seed);
    let spec = MethodSpec::fedlay_dynamic(overlay, net);
    let mut trainer = Trainer::new(&engine, spec, dfl, weights[..n].to_vec())?;
    // hand the *decentralized-grown* network from phase 1 to the trainer:
    // training runs on that exact protocol state, not a fresh bootstrap
    trainer.adopt_overlay(sim)?;
    println!("phase 2/3 — asynchronous MEP training on the live overlay:");
    let minute = 60 * 1_000_000u64;
    let horizon = 240 * minute; // 4 simulated hours
    let sample = 30 * minute;
    // phase 4: 4 crash-failures at t=80min, 4 NDMP joins at t=120min
    for &f in &[2usize, 5, 9, 13] {
        trainer.schedule_fail(80 * minute, f);
    }
    for (j, &boot) in [0usize, 3, 6, 10].iter().enumerate() {
        trainer.schedule_join(120 * minute, weights[n + j].clone(), boot)?;
    }
    trainer.run(horizon, sample)?;
    let mut t = Table::new(&["t (min)", "mean acc", "mean loss"]);
    for s in trainer.samples() {
        t.row(&[
            format!("{:.0}", s.at as f64 / 60e6),
            format!("{:.4}", s.mean_accuracy),
            format!("{:.4}", s.mean_loss),
        ]);
    }
    print!("{}", t.render());
    let last = trainer.samples().last().unwrap().clone();

    // --- per-client accuracy CDF (paper Fig. 9d-f analogue) ---
    println!("\nper-client accuracy CDF at convergence:");
    for (acc, frac) in cdf_points(&last.per_client) {
        println!("  acc<={acc:.3}: {frac:.2}");
    }
    // spread over *live* clients (failed clients keep their frozen model)
    let live_accs: Vec<f64> = trainer
        .clients
        .iter()
        .zip(&last.per_client)
        .filter(|(c, _)| c.alive)
        .map(|(_, &a)| a)
        .collect();
    let spread = live_accs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
        - live_accs.iter().cloned().fold(f64::INFINITY, f64::min);
    println!("  spread (max-min, live): {spread:.3}  — no stragglers expected");

    // --- comm cost ---
    println!("\ncommunication:");
    println!(
        "  model payload: {:.2} MB/client, dedup skips: {}",
        trainer.model_mb_per_client(),
        trainer.clients().iter().map(|c| c.dedup_skips).sum::<u64>()
    );
    println!(
        "  train steps/client: {:.1}",
        trainer.train_steps_per_client()
    );

    // --- sanity gates for EXPERIMENTS.md ---
    let churn_correct = trainer
        .overlay
        .as_ref()
        .map(|s| s.correctness())
        .unwrap_or(0.0);
    println!(
        "\nphase 4 — churn: overlay correctness {churn_correct:.3} with {} live nodes",
        trainer.clients().iter().filter(|c| c.alive).count()
    );
    anyhow::ensure!(
        churn_correct > 0.999,
        "NDMP did not repair/extend the overlay under churn"
    );
    let base = trainer.samples()[0].mean_accuracy;
    anyhow::ensure!(
        last.mean_accuracy > base + 0.25,
        "training did not improve enough: {base:.3} -> {:.3}",
        last.mean_accuracy
    );
    anyhow::ensure!(
        last.mean_loss < trainer.samples()[0].mean_loss,
        "loss did not decrease"
    );
    println!("\nend_to_end_dfl OK (acc {:.3} -> {:.3})", base, last.mean_accuracy);
    Ok(())
}
