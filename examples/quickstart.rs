//! Quickstart: build a FedLay overlay two ways (centralized reference +
//! decentralized NDMP joins), compare them, then run a short DFL training
//! round over the AOT artifacts.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use fedlay::bench_util::Table;
use fedlay::config::{Config, NetConfig, OverlayConfig};
use fedlay::dfl::{MethodSpec, Trainer};
use fedlay::metrics;
use fedlay::ndmp::messages::MS;
use fedlay::runtime::{find_artifacts_dir, Engine};
use fedlay::sim::{grow_network, Simulator};
use fedlay::topology::fedlay_graph;

fn main() -> anyhow::Result<()> {
    let cfg = Config::default();

    // 1. The FedLay topology, centralized reference construction.
    println!("== FedLay topology (centralized reference, N=100, L=3) ==");
    let g = fedlay_graph(100, 3);
    let m = metrics::evaluate(&g, 1);
    println!(
        "lambda={:.4}  convergence factor={:.1}  diameter={}  aspl={:.2}  avg degree={:.1}\n",
        m.lambda, m.convergence_factor, m.diameter, m.avg_shortest_path, m.avg_degree
    );

    // 2. The same network built **decentralized**: every node joins via
    //    NDMP greedy routing through a random existing node.
    println!("== Decentralized construction via NDMP (40 sequential joins) ==");
    let overlay = OverlayConfig {
        spaces: 3,
        heartbeat_ms: 500,
        failure_multiple: 3,
        repair_probe_ms: 2_000,
    };
    let net = NetConfig {
        latency_ms: 50.0,
        jitter: 0.2,
        seed: 7,
    };
    let sim: Simulator = grow_network(overlay, net, 40, 1_000 * MS);
    println!(
        "correctness after growth: {:.4} (1.0 = Definition-1 correct)",
        sim.correctness()
    );
    println!(
        "control messages per node: {:.1}\n",
        sim.control_messages_per_node()
    );

    // 3. A short DFL training run through the PJRT runtime (L3->L2->L1).
    println!("== DFL training: FedLay MEP over the AOT artifacts ==");
    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &["mlp"])?;
    let mut dfl_cfg = cfg.dfl.clone();
    dfl_cfg.clients = 10;
    dfl_cfg.local_steps = 4;
    let weights = fedlay::data::shard_labels(dfl_cfg.clients, 10, 8, dfl_cfg.seed);
    let spec = MethodSpec::fedlay(dfl_cfg.clients, 3);
    let mut trainer = Trainer::new(&engine, spec, dfl_cfg, weights)?;
    trainer.run(120 * 60 * 1_000_000, 30 * 60 * 1_000_000)?;
    let mut t = Table::new(&["t (min)", "mean accuracy", "mean loss"]);
    for s in trainer.samples() {
        t.row(&[
            format!("{:.0}", s.at as f64 / 60e6),
            format!("{:.4}", s.mean_accuracy),
            format!("{:.4}", s.mean_loss),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\nmodel payload: {:.2} MB/client  (fingerprint de-dup active)",
        trainer.model_mb_per_client()
    );
    println!("quickstart OK");
    Ok(())
}
