//! Topology explorer: evaluate every overlay in the repo on the paper's
//! three metrics (§II-B) at a chosen size — an interactive version of
//! Fig. 3.
//!
//! ```bash
//! cargo run --release --example topology_explorer -- 200
//! ```

use fedlay::baselines;
use fedlay::bench_util::Table;
use fedlay::metrics;
use fedlay::topology::fedlay_graph;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(200);
    let seed = 1;
    let mut t = Table::new(&[
        "topology", "avg deg", "lambda", "conv.factor", "diameter", "aspl", "connected",
    ]);
    let names = [
        "ring", "chain", "grid", "torus", "hypercube", "complete", "chord", "viceroy",
        "waxman", "delaunay", "social",
    ];
    for name in names {
        let g = baselines::by_name(name, n, seed)?;
        let m = metrics::evaluate(&g, seed);
        t.row(&[
            name.to_string(),
            format!("{:.1}", m.avg_degree),
            format!("{:.4}", m.lambda),
            if m.convergence_factor.is_finite() {
                format!("{:.1}", m.convergence_factor)
            } else {
                "inf".into()
            },
            m.diameter.to_string(),
            format!("{:.2}", m.avg_shortest_path),
            m.connected.to_string(),
        ]);
    }
    for l in [2usize, 3, 5, 7] {
        let g = fedlay_graph(n, l);
        let m = metrics::evaluate(&g, seed);
        t.row(&[
            format!("fedlay-L{l}"),
            format!("{:.1}", m.avg_degree),
            format!("{:.4}", m.lambda),
            format!("{:.1}", m.convergence_factor),
            m.diameter.to_string(),
            format!("{:.2}", m.avg_shortest_path),
            m.connected.to_string(),
        ]);
    }
    // the "Best of 100 random regular graphs" reference row (paper §II-C)
    let trials = if n <= 200 { 20 } else { 5 };
    let best = baselines::best_of_regular(n, 6, trials, seed);
    t.row(&[
        format!("best-of-{trials} RRG d=6"),
        "6.0".into(),
        format!("{:.4}", best.best_lambda),
        format!("{:.1}", best.best_convergence_factor),
        best.best_diameter.to_string(),
        format!("{:.2}", best.best_aspl),
        "true".into(),
    ]);
    print!("{}", t.render());
    Ok(())
}
