//! E13 — Paper Figs. 16/17: MEP confidence parameters (α_d = α_c = 0.5)
//! vs simple averaging on the MNIST-like task.
//!
//! Expected shape: confidence weighting slightly improves accuracy /
//! convergence over the plain average (the paper reports a modest gain).

use fedlay::bench_util::scaled;
use fedlay::config::DflConfig;
use fedlay::dfl::harness::{curves_table, final_acc, run_method};
use fedlay::dfl::MethodSpec;
use fedlay::runtime::{find_artifacts_dir, Engine};

fn main() -> anyhow::Result<()> {
    let clients = 16;
    let minutes = scaled(240u64, 1_500);
    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &["mlp"])?;
    // strong non-iid so per-client data quality actually differs
    let cfg = DflConfig {
        task: "mlp".into(),
        clients,
        shards_per_client: 4,
        local_steps: 3,
        ..DflConfig::default()
    };
    let with = run_method(&engine, MethodSpec::fedlay(clients, 3), &cfg, minutes, minutes / 6)?;
    let without = run_method(
        &engine,
        MethodSpec::fedlay_simple_avg(clients, 3),
        &cfg,
        minutes,
        minutes / 6,
    )?;
    println!("=== Figs. 16/17: confidence weighting vs simple average ===");
    print!(
        "{}",
        curves_table(&[
            ("confidence (a_d=a_c=0.5)", with.samples()),
            ("simple average", without.samples()),
        ])
        .render()
    );
    let (a, b) = (final_acc(&with), final_acc(&without));
    println!("\nfinal: confidence={a:.4} simple={b:.4} delta={:+.4}", a - b);
    assert!(
        a >= b - 0.03,
        "confidence weighting should not hurt ({a:.3} vs {b:.3})"
    );
    println!("fig16/17 OK");
    Ok(())
}
