//! E10 — Paper Fig. 12: synchronous vs asynchronous MEP communication.
//!
//! Expected shape: with heterogeneous clients (60/20/20 medium/high/low,
//! low = 2x medium period), asynchronous exchange converges faster because
//! high-capacity clients never wait for stragglers; synchronous rounds run
//! at the slowest client's period.
//!
//! Churn variant (mlp only): the same asynchronous method on the *live*
//! NDMP overlay (`Neighborhood::Dynamic`) with mid-run failures and
//! protocol-level joins — accuracy must stay in the same band, i.e. the
//! unified engine's churn path does not derail convergence.

use fedlay::bench_util::{scaled, Table};
use fedlay::config::{DflConfig, NetConfig, OverlayConfig};
use fedlay::data::shard_labels;
use fedlay::dfl::harness::{curves_table, final_acc, minutes_to_accuracy, run_method};
use fedlay::dfl::{MethodSpec, Trainer};
use fedlay::runtime::{find_artifacts_dir, Engine};

fn main() -> anyhow::Result<()> {
    let tasks: Vec<&str> = scaled(vec!["mlp"], vec!["mlp", "cnn", "lstm"]);
    let clients = 16;
    let minutes = scaled(240u64, 1_500);
    let dir = find_artifacts_dir(None)?;
    let mut summary = Table::new(&["task", "async acc", "sync acc", "async t->0.5", "sync t->0.5"]);
    for task in tasks {
        let engine = Engine::load(&dir, &[task])?;
        let cfg = DflConfig {
            task: task.into(),
            clients,
            local_steps: 3,
            ..DflConfig::default()
        };
        let a = run_method(&engine, MethodSpec::fedlay(clients, 3), &cfg, minutes, minutes / 6)?;
        let spec = MethodSpec::fedlay_sync(clients, 3);
        let s = run_method(&engine, spec, &cfg, minutes, minutes / 6)?;
        println!("=== Fig. 12 ({task}) ===");
        print!(
            "{}",
            curves_table(&[("async", a.samples()), ("sync", s.samples())]).render()
        );
        let fmt_t = |o: Option<f64>| o.map(|m| format!("{m:.0}m")).unwrap_or("-".into());
        summary.row(&[
            task.to_string(),
            format!("{:.3}", final_acc(&a)),
            format!("{:.3}", final_acc(&s)),
            fmt_t(minutes_to_accuracy(a.samples(), 0.5)),
            fmt_t(minutes_to_accuracy(s.samples(), 0.5)),
        ]);
        // Deviation note (EXPERIMENTS.md): on the synthetic substrate the
        // two modes end close; async's paper advantage is wall-clock
        // time-to-accuracy for high-capacity clients under stragglers,
        // which our round model only partially captures. We require the
        // two to be in the same band rather than asserting a direction.
        assert!(
            (final_acc(&a) - final_acc(&s)).abs() < 0.25,
            "{task}: async vs sync diverged unexpectedly"
        );

        if task == "mlp" {
            let classes = engine.manifest.task(task)?.classes;
            let overlay = OverlayConfig {
                heartbeat_ms: 2_000,
                repair_probe_ms: 8_000,
                ..OverlayConfig::default()
            };
            let joins = 2usize;
            let weights =
                shard_labels(clients + joins, classes, cfg.shards_per_client, cfg.seed);
            let mut c = Trainer::new(
                &engine,
                MethodSpec::fedlay_dynamic(overlay, NetConfig::default()),
                cfg.clone(),
                weights[..clients].to_vec(),
            )?;
            // two failures at t/3, two protocol joins at t/2
            c.schedule_fail(minutes * 60_000_000 / 3, 2);
            c.schedule_fail(minutes * 60_000_000 / 3, 9);
            for j in 0..joins {
                c.schedule_join(minutes * 60_000_000 / 2, weights[clients + j].clone(), 4 + j)?;
            }
            c.run(minutes * 60_000_000, minutes * 60_000_000 / 6)?;
            println!("=== Fig. 12 churn variant (mlp, live NDMP overlay) ===");
            print!(
                "{}",
                curves_table(&[("async", a.samples()), ("async+churn", c.samples())]).render()
            );
            let correctness = c.overlay.as_ref().map(|s| s.correctness()).unwrap_or(0.0);
            println!("overlay correctness after churn: {correctness:.3}");
            assert!(
                (final_acc(&a) - final_acc(&c)).abs() < 0.25,
                "churn should not derail async convergence ({:.3} vs {:.3})",
                final_acc(&a),
                final_acc(&c)
            );
            assert!(correctness > 0.999, "overlay not repaired: {correctness:.3}");
        }
    }
    println!("\n=== Fig. 12 summary ===");
    print!("{}", summary.render());
    println!("fig12 OK");
    Ok(())
}
