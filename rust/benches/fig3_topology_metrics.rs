//! E2 — Paper Fig. 3: convergence factor, diameter, and average shortest
//! path length at N=300 for "Best of 100 random d-regular graphs" and
//! FedLay with degree 4..14, plus single dots for Chord, Viceroy, DT,
//! Waxman, and the social graph.
//!
//! Expected shape (paper): FedLay ≈ Best on all three metrics; every other
//! topology is strictly worse on at least one.

use fedlay::baselines::{self, best_of_regular};
use fedlay::bench_util::{scaled, Table};
use fedlay::metrics;
use fedlay::topology::fedlay_graph;

fn main() -> anyhow::Result<()> {
    let n = 300;
    let trials = scaled(10, 100);
    let seed = 1;

    println!("=== Fig. 3: FedLay vs Best over node degree (N={n}, {trials} RRG trials) ===");
    let mut t = Table::new(&[
        "degree", "best c_G", "fedlay c_G", "best diam", "fedlay diam", "best aspl",
        "fedlay aspl",
    ]);
    for d in [4usize, 6, 8, 10, 12, 14] {
        let best = best_of_regular(n, d, trials, seed);
        // FedLay: degree d corresponds to L = d/2 ring spaces
        let g = fedlay_graph(n, d / 2);
        let m = metrics::evaluate(&g, seed);
        t.row(&[
            d.to_string(),
            format!("{:.1}", best.best_convergence_factor),
            format!("{:.1}", m.convergence_factor),
            best.best_diameter.to_string(),
            m.diameter.to_string(),
            format!("{:.2}", best.best_aspl),
            format!("{:.2}", m.avg_shortest_path),
        ]);
    }
    print!("{}", t.render());

    println!("\n=== Fig. 3: comparator topologies (single dots) ===");
    let mut t2 = Table::new(&["topology", "avg degree", "c_G", "diameter", "aspl"]);
    for name in ["chord", "viceroy", "delaunay", "waxman", "social"] {
        let g = baselines::by_name(name, n, seed)?;
        let m = metrics::evaluate(&g, seed);
        t2.row(&[
            name.to_string(),
            format!("{:.1}", m.avg_degree),
            if m.convergence_factor.is_finite() {
                format!("{:.1}", m.convergence_factor)
            } else {
                "inf".into()
            },
            m.diameter.to_string(),
            format!("{:.2}", m.avg_shortest_path),
        ]);
    }
    print!("{}", t2.render());

    // Shape assertions from the paper's findings
    let fl = metrics::evaluate(&fedlay_graph(n, 5), seed);
    let best10 = best_of_regular(n, 10, trials, seed);
    assert!(
        fl.convergence_factor < best10.best_convergence_factor * 1.35,
        "FedLay c_G should be within ~1.35x of Best (got {:.1} vs {:.1})",
        fl.convergence_factor,
        best10.best_convergence_factor
    );
    let wax = metrics::evaluate(&baselines::by_name("waxman", n, seed)?, seed);
    assert!(
        !wax.connected || wax.avg_shortest_path > fl.avg_shortest_path,
        "geometric Waxman should have longer paths than FedLay"
    );
    println!("\nfig3 shape checks OK");
    Ok(())
}
