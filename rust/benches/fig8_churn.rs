//! E4/E5/E6 — Paper Fig. 8: (a) topology correctness when 25% of the
//! network joins at the same instant; (b) correctness when 25% fails at
//! the same instant; (c) NDMP messages per client to construct networks of
//! increasing size.
//!
//! Paper scale: 400-node network ± 100 nodes, 350 ms latency; correctness
//! recovers to 1.0 within ~8 s. Default scale is 120 ± 30 (1-CPU sandbox);
//! FEDLAY_BENCH_SCALE=paper reproduces 400 ± 100.

use fedlay::bench_util::{scaled, Table};
use fedlay::config::{NetConfig, OverlayConfig};
use fedlay::ndmp::messages::{Time, MS};
use fedlay::sim::{churn, grow_network, Simulator};

fn overlay(spaces: usize) -> OverlayConfig {
    OverlayConfig {
        spaces,
        heartbeat_ms: 500,
        failure_multiple: 3,
        repair_probe_ms: 2_000,
    }
}

fn net() -> NetConfig {
    NetConfig {
        latency_ms: 350.0,
        jitter: 0.2,
        seed: 8,
    }
}

fn timeline(sim: &Simulator) -> Table {
    let mut t = Table::new(&["t (s)", "correctness", "live nodes"]);
    for s in &sim.samples {
        t.row(&[
            format!("{:.1}", s.at as f64 / 1e6),
            format!("{:.4}", s.correctness),
            s.live_nodes.to_string(),
        ]);
    }
    t
}

fn main() {
    let initial = scaled(120usize, 400);
    let churn_n = scaled(30usize, 100);
    let horizon: Time = 90_000 * MS;

    // Fig. 8a: mass joins, for several degrees (L = d/2)
    for l in [3usize, 4, 5, 6] {
        println!(
            "=== Fig. 8a: {churn_n} joins into {initial}-node FedLay (d={}) ===",
            2 * l
        );
        let mut sim = Simulator::new(overlay(l), net());
        churn::mass_join(&mut sim, initial, churn_n, 10 * MS, l as u64);
        churn::sample_correctness(&mut sim, horizon, 3_000 * MS);
        sim.run_until(horizon);
        print!("{}", timeline(&sim).render());
        let fin = sim.correctness();
        println!("final correctness: {fin:.4}\n");
        assert!(fin > 0.995, "join recovery incomplete at d={}", 2 * l);
    }

    // Fig. 8b: mass failures
    println!("=== Fig. 8b: {churn_n} failures out of {initial}-node FedLay (d=6) ===");
    let mut sim = Simulator::new(overlay(3), net());
    churn::mass_fail(&mut sim, initial, churn_n, 10 * MS, 4);
    churn::sample_correctness(&mut sim, horizon, 3_000 * MS);
    sim.run_until(horizon);
    print!("{}", timeline(&sim).render());
    let dip = sim
        .samples
        .iter()
        .map(|s| s.correctness)
        .fold(1.0f64, f64::min);
    let fin = sim.correctness();
    println!("dip: {dip:.3}  final: {fin:.4}\n");
    assert!(dip < 0.95, "failures should dent correctness");
    assert!(fin > 0.995, "failure recovery incomplete");

    // Fig. 8c: construction messages per client vs network size
    println!("=== Fig. 8c: NDMP messages/client to construct an N-node network ===");
    let sizes: Vec<usize> = scaled(vec![50, 100, 150, 250], vec![100, 200, 300, 400, 500]);
    let mut t = Table::new(&["N", "join msgs/client", "correctness"]);
    let mut per_client = Vec::new();
    for &n in &sizes {
        let sim = grow_network(overlay(3), net(), n, 800 * MS);
        let mpc = sim.control_messages_per_node();
        per_client.push(mpc);
        t.row(&[
            n.to_string(),
            format!("{mpc:.1}"),
            format!("{:.4}", sim.correctness()),
        ]);
    }
    print!("{}", t.render());
    // paper: ~30 msgs/client at 500 nodes, growing slowly with N
    let growth = per_client.last().unwrap() / per_client.first().unwrap();
    let size_growth = *sizes.last().unwrap() as f64 / sizes[0] as f64;
    assert!(
        growth < size_growth,
        "construction cost should grow sublinearly ({growth:.2}x msgs for {size_growth:.2}x nodes)"
    );
    println!("\nfig8 shape checks OK");
}
