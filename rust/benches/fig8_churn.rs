//! E4/E5/E6 — Paper Fig. 8: (a) topology correctness when 25% of the
//! network joins at the same instant; (b) correctness when 25% fails at
//! the same instant; (c) NDMP messages per client to construct networks of
//! increasing size.
//!
//! Figs. 8a/8b run through the declarative scenario engine
//! (`sim::scenario`): each panel is a `ScenarioSpec` compiled to a
//! deterministic churn schedule — the same specs the golden-trajectory
//! tests pin and the CLI (`fedlay scenario run`) executes.
//!
//! Paper scale: 400-node network ± 100 nodes, 350 ms latency; correctness
//! recovers to 1.0 within ~8 s. Default scale is 120 ± 30 (1-CPU sandbox);
//! FEDLAY_BENCH_SCALE=paper reproduces 400 ± 100.
//!
//! FEDLAY_TRANSPORT=tcp replays Figs. 8a/8b over real localhost sockets
//! (`net::SchedTransport`) at a reduced node count — the same schedules,
//! scheduler, protocol engines, *and virtual link latency*, with real
//! frames on the wire. Each panel then also runs the in-memory backend
//! on the identical spec and asserts the **round-time series matches
//! sample for sample** — the paper's Fig. 8 timing, not just its
//! converged topology, is reproduced over TCP (docs/transports.md).

use fedlay::bench_util::{scaled, Table};
use fedlay::config::{NetConfig, OverlayConfig};
use fedlay::ndmp::messages::{Time, MS};
use fedlay::net::SchedTransport;
use fedlay::sim::{grow_network, ScenarioReport, ScenarioSpec};

fn tcp_transport() -> bool {
    std::env::var("FEDLAY_TRANSPORT").as_deref() == Ok("tcp")
}

fn overlay(spaces: usize) -> OverlayConfig {
    OverlayConfig {
        spaces,
        heartbeat_ms: 500,
        failure_multiple: 3,
        repair_probe_ms: 2_000,
    }
}

fn net() -> NetConfig {
    NetConfig {
        latency_ms: 350.0,
        jitter: 0.2,
        seed: 8,
        ..NetConfig::default()
    }
}

/// Run one Fig. 8 panel. In tcp mode the panel runs on real sockets AND
/// on the in-memory backend with the same spec, asserting the identical
/// correctness-over-time series (the Fig. 8 "round time" axis).
fn run_panel(spec: &ScenarioSpec) -> ScenarioReport {
    if !tcp_transport() {
        let (_, report) = spec.run_sim(None).expect("scenario run");
        return report;
    }
    let (_, sim_report) = spec.run_sim(None).expect("sim replay");
    let (_, tcp_report) = spec
        .run_sim(Some(Box::new(SchedTransport::new(&spec.net))))
        .expect("tcp run");
    assert_eq!(
        sim_report.correctness.len(),
        tcp_report.correctness.len(),
        "sample counts diverged between backends"
    );
    for (s, t) in sim_report.correctness.iter().zip(&tcp_report.correctness) {
        assert_eq!(s.at, t.at, "sample instants diverged");
        assert_eq!(
            (s.correctness, s.live_nodes),
            (t.correctness, t.live_nodes),
            "round-time series diverged at t={} µs",
            s.at
        );
    }
    assert_eq!(sim_report.delivered, tcp_report.delivered);
    println!(
        "tcp replay: round-time series matches sim over {} samples",
        tcp_report.correctness.len()
    );
    tcp_report
}

fn main() {
    // sockets are real OS resources: cap the fleet in tcp mode
    let initial = if tcp_transport() {
        24
    } else {
        scaled(120usize, 400)
    };
    let churn_n = if tcp_transport() {
        6
    } else {
        scaled(30usize, 100)
    };
    let horizon: Time = 90_000 * MS;
    let degrees: &[usize] = if tcp_transport() { &[3] } else { &[3, 4, 5, 6] };
    let sample_every: Time = 3_000 * MS;

    // Fig. 8a: mass joins, for several degrees (L = d/2)
    for &l in degrees {
        println!(
            "=== Fig. 8a: {churn_n} joins into {initial}-node FedLay (d={}) ===",
            2 * l
        );
        let mut spec = ScenarioSpec::fig8a_join_wave(initial, churn_n, l as u64);
        spec.overlay = overlay(l);
        spec.net = net();
        spec.horizon = horizon;
        spec.sample_every = sample_every;
        let report = run_panel(&spec);
        print!("{}", report.correctness_table().render());
        let fin = report.final_correctness;
        println!("final correctness: {fin:.4}\n");
        assert!(fin > 0.995, "join recovery incomplete at d={}", 2 * l);
    }

    // Fig. 8b: mass failures
    println!("=== Fig. 8b: {churn_n} failures out of {initial}-node FedLay (d=6) ===");
    let mut spec = ScenarioSpec::fig8b_mass_fail(initial, churn_n, 4);
    spec.overlay = overlay(3);
    spec.net = net();
    spec.horizon = horizon;
    spec.sample_every = sample_every;
    let report = run_panel(&spec);
    print!("{}", report.correctness_table().render());
    let dip = report
        .correctness
        .iter()
        .map(|s| s.correctness)
        .fold(1.0f64, f64::min);
    let fin = report.final_correctness;
    println!("dip: {dip:.3}  final: {fin:.4}\n");
    assert!(dip < 0.95, "failures should dent correctness");
    assert!(fin > 0.995, "failure recovery incomplete");

    // Fig. 8c: construction messages per client vs network size
    println!("=== Fig. 8c: NDMP messages/client to construct an N-node network ===");
    let sizes: Vec<usize> = scaled(vec![50, 100, 150, 250], vec![100, 200, 300, 400, 500]);
    let mut t = Table::new(&["N", "join msgs/client", "correctness"]);
    let mut per_client = Vec::new();
    for &n in &sizes {
        let sim = grow_network(overlay(3), net(), n, 800 * MS);
        let mpc = sim.control_messages_per_node();
        per_client.push(mpc);
        t.row(&[
            n.to_string(),
            format!("{mpc:.1}"),
            format!("{:.4}", sim.correctness()),
        ]);
    }
    print!("{}", t.render());
    // paper: ~30 msgs/client at 500 nodes, growing slowly with N
    let growth = per_client.last().unwrap() / per_client.first().unwrap();
    let size_growth = *sizes.last().unwrap() as f64 / sizes[0] as f64;
    assert!(
        growth < size_growth,
        "construction cost should grow sublinearly ({growth:.2}x msgs for {size_growth:.2}x nodes)"
    );
    println!("\nfig8 shape checks OK");
}
