//! E9 — Paper Fig. 11: accuracy under different non-iid levels (4 / 8 / 12
//! shards per client) on the CIFAR-like task, plus the per-client accuracy
//! distribution at the end (Fig. 11c).
//!
//! Expected shape: fewer shards (stronger non-iid) slows convergence for
//! every DFL method; FedLay still approaches FedAvg, and the 4-shard
//! per-client distribution is visibly more uneven.

use fedlay::bench_util::{scaled, Table};
use fedlay::config::DflConfig;
use fedlay::dfl::harness::{final_acc, run_method};
use fedlay::dfl::MethodSpec;
use fedlay::runtime::{find_artifacts_dir, Engine};
use fedlay::util::cdf_points;

fn main() -> anyhow::Result<()> {
    let clients = scaled(16usize, 100);
    let minutes = scaled(200u64, 2_000);
    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &["cnn"])?;

    let mut summary = Table::new(&["shards/client", "fedlay", "fedavg", "gaia"]);
    let mut spreads = Vec::new();
    for shards in [4usize, 8, 12] {
        let cfg = DflConfig {
            task: "cnn".into(),
            clients,
            shards_per_client: shards,
            local_steps: 3,
            comm_period_ms: 10 * 60 * 1_000,
            lr: 0.3,
            ..DflConfig::default()
        };
        let fed = run_method(&engine, MethodSpec::fedlay(clients, 5), &cfg, minutes, minutes / 4)?;
        let fedavg = run_method(&engine, MethodSpec::fedavg(), &cfg, minutes, minutes / 4)?;
        let gaia = run_method(&engine, MethodSpec::gaia(clients, 4), &cfg, minutes, minutes / 4)?;
        summary.row(&[
            shards.to_string(),
            format!("{:.3}", final_acc(&fed)),
            format!("{:.3}", final_acc(&fedavg)),
            format!("{:.3}", final_acc(&gaia)),
        ]);
        // Fig. 11c: per-client distribution
        let last = fed.samples().last().unwrap();
        let spread = last.per_client.iter().cloned().fold(f64::MIN, f64::max)
            - last.per_client.iter().cloned().fold(f64::MAX, f64::min);
        spreads.push((shards, spread));
        println!("fedlay per-client CDF at end ({shards} shards):");
        for (acc, frac) in cdf_points(&last.per_client) {
            println!("  {acc:.3} -> {frac:.2}");
        }
        println!();
    }
    println!("=== Fig. 11: accuracy at convergence vs non-iid level ===");
    print!("{}", summary.render());
    println!("\nper-client accuracy spread by shards: {spreads:?}");
    println!("fig11 done");
    Ok(())
}
