//! E7 — Paper Fig. 9: 16-client accuracy-vs-time curves (a–c) and the
//! per-client accuracy CDF at convergence (d–f), FedLay (d=4) vs Gaia vs
//! DFL-DDS, per task.
//!
//! This bench runs the *emulated* version (same protocol + runtime code;
//! discrete time instead of wall clock). The real-TCP counterpart is
//! `cargo run --release --example prototype_16`.
//! Default scale: mlp + cnn, 180 sim-minutes. paper adds lstm and longer
//! horizons.

use fedlay::bench_util::scaled;
use fedlay::config::DflConfig;
use fedlay::dfl::harness::{curves_table, final_acc, run_method};
use fedlay::dfl::MethodSpec;
use fedlay::runtime::{find_artifacts_dir, Engine};
use fedlay::util::cdf_points;

fn main() -> anyhow::Result<()> {
    let tasks: Vec<&str> = scaled(vec!["mlp", "cnn"], vec!["mlp", "cnn", "lstm"]);
    let minutes = scaled(180u64, 1_500);
    let sample = minutes / 6;
    let dir = find_artifacts_dir(None)?;
    for task in tasks {
        let engine = Engine::load(&dir, &[task])?;
        let mut cfg = DflConfig {
            task: task.into(),
            clients: 16,
            local_steps: 3,
            ..DflConfig::default()
        };
        // paper: Shakespeare period is 40 min, CIFAR 10 min, MNIST 5 min
        cfg.comm_period_ms = match task {
            "lstm" => 40 * 60 * 1_000,
            "cnn" => 10 * 60 * 1_000,
            _ => 5 * 60 * 1_000,
        };
        // per-task step sizes: the conv net prefers a gentler lr; the
        // lstm needs a hotter one on the synthetic stream
        match task {
            "lstm" => cfg.lr = 1.0,
            "cnn" => cfg.lr = 0.3,
            _ => {}
        }
        println!("=== Fig. 9 ({task}): accuracy vs time, 16 clients ===");
        let fed = run_method(&engine, MethodSpec::fedlay(16, 2), &cfg, minutes, sample)?;
        let gaia = run_method(&engine, MethodSpec::gaia(16, 4), &cfg, minutes, sample)?;
        let dds = run_method(&engine, MethodSpec::dfl_dds(3), &cfg, minutes, sample)?;
        let t = curves_table(&[
            ("fedlay d=4", fed.samples()),
            ("gaia", gaia.samples()),
            ("dfl-dds", dds.samples()),
        ]);
        print!("{}", t.render());
        println!(
            "final: fedlay={:.3} gaia={:.3} dfl-dds={:.3}",
            final_acc(&fed),
            final_acc(&gaia),
            final_acc(&dds)
        );
        // Fig. 9d-f: per-client CDF at convergence for FedLay
        let last = fed.samples().last().unwrap();
        println!("fedlay per-client accuracy CDF at convergence:");
        for (acc, frac) in cdf_points(&last.per_client) {
            println!("  {acc:.3} -> {frac:.2}");
        }
        let spread = last.per_client.iter().cloned().fold(f64::MIN, f64::max)
            - last.per_client.iter().cloned().fold(f64::MAX, f64::min);
        println!("  spread: {spread:.3} (paper: similar accuracy, no stragglers)\n");
        // shape: fedlay should beat or match both comparators on the
        // non-iid tasks (gaia averages regions only; dds has geo-local mixing)
        if task != "lstm" {
            assert!(
                final_acc(&fed) >= final_acc(&dds) - 0.03,
                "{task}: fedlay should not lose to dfl-dds"
            );
        }
    }
    println!("fig9 OK");
    Ok(())
}
