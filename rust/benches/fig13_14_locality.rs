//! E11 — Paper Figs. 13/14: data with biased distribution and locality.
//! Clients split into 10 groups; group g holds 6 consecutive labels
//! starting at g (adjacent groups differ by one label). FedLay vs Chord at
//! several degrees, with the fully-connected graph as the upper bound.
//!
//! Expected shape (paper): FedLay beats Chord by a wide margin (~37% avg
//! over degrees) and sits within ~2% of the complete graph.

use fedlay::bench_util::{scaled, Table};
use fedlay::config::DflConfig;
use fedlay::data::locality_groups;
use fedlay::dfl::harness::{curves_table, final_acc, run_method_with_weights};
use fedlay::dfl::MethodSpec;
use fedlay::runtime::{find_artifacts_dir, Engine};

fn main() -> anyhow::Result<()> {
    let clients = scaled(20usize, 100);
    let minutes = scaled(240u64, 2_000);
    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &["cnn"])?;
    let cfg = DflConfig {
        task: "cnn".into(),
        clients,
        local_steps: 3,
        comm_period_ms: 10 * 60 * 1_000,
        lr: 0.3,
        ..DflConfig::default()
    };
    let weights = locality_groups(clients, 10, 10, 6);

    // Fig. 13: accuracy at convergence vs degree
    println!("=== Fig. 13: FedLay vs Chord under biased locality ===");
    let mut t = Table::new(&["method", "degree", "final accuracy"]);
    let mut fed_acc = Vec::new();
    for l in [2usize, 3, 5] {
        let tr = run_method_with_weights(
            &engine,
            MethodSpec::fedlay(clients, l),
            &cfg,
            weights.clone(),
            minutes,
            minutes / 4,
        )?;
        fed_acc.push(final_acc(&tr));
        t.row(&[
            "fedlay".into(),
            (2 * l).to_string(),
            format!("{:.3}", final_acc(&tr)),
        ]);
    }
    let chord = run_method_with_weights(
        &engine,
        MethodSpec::chord(clients),
        &cfg,
        weights.clone(),
        minutes,
        minutes / 4,
    )?;
    t.row(&[
        "chord".into(),
        format!("{:.0}", 2.0 * (clients as f64).log2()),
        format!("{:.3}", final_acc(&chord)),
    ]);
    let complete = run_method_with_weights(
        &engine,
        MethodSpec::complete(clients),
        &cfg,
        weights.clone(),
        minutes,
        minutes / 4,
    )?;
    t.row(&[
        "complete (bound)".into(),
        (clients - 1).to_string(),
        format!("{:.3}", final_acc(&complete)),
    ]);
    print!("{}", t.render());

    // Fig. 14: accuracy vs time, FedLay (best degree) vs Chord
    println!("\n=== Fig. 14: accuracy vs time ===");
    let fed = run_method_with_weights(
        &engine,
        MethodSpec::fedlay(clients, 5),
        &cfg,
        weights.clone(),
        minutes,
        minutes / 6,
    )?;
    print!(
        "{}",
        curves_table(&[("fedlay d=10", fed.samples()), ("chord", chord.samples())]).render()
    );

    // shape checks
    let best_fed = fed_acc.iter().cloned().fold(f64::MIN, f64::max);
    assert!(
        best_fed >= final_acc(&chord) - 0.02,
        "fedlay should beat chord under locality ({best_fed:.3} vs {:.3})",
        final_acc(&chord)
    );
    assert!(
        final_acc(&complete) >= best_fed - 0.03,
        "complete graph should upper-bound fedlay"
    );
    println!("\nfig13/14 shape checks OK");
    Ok(())
}
