//! E8 — Paper Fig. 10 + Table III: medium-scale accuracy comparison —
//! FedLay (d=10) vs FedAvg (centralized upper bound) vs Gaia vs DFL-DDS vs
//! Chord.
//!
//! Paper (100 clients, MNIST): FedAvg 92.1 > FedLay 90.2 > Gaia 89.2 >
//! Chord 88.9 > DFL-DDS 87.4 — FedLay within ~2% of the centralized upper
//! bound and above every decentralized comparator. We assert that ordering
//! shape (FedAvg >= FedLay >= others - eps) at reduced scale.

use fedlay::bench_util::{scaled, Table};
use fedlay::config::DflConfig;
use fedlay::dfl::harness::{curves_table, final_acc, run_method};
use fedlay::dfl::MethodSpec;
use fedlay::runtime::{find_artifacts_dir, Engine};

fn main() -> anyhow::Result<()> {
    let clients = scaled(20usize, 100);
    let minutes = scaled(240u64, 2_000);
    let sample = minutes / 6;
    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &["mlp"])?;
    let cfg = DflConfig {
        task: "mlp".into(),
        clients,
        local_steps: 3,
        shards_per_client: 8,
        ..DflConfig::default()
    };

    println!("=== Fig. 10 / Table III: {clients} clients, mlp task ===");
    let fed = run_method(&engine, MethodSpec::fedlay(clients, 5), &cfg, minutes, sample)?;
    let fedavg = run_method(&engine, MethodSpec::fedavg(), &cfg, minutes, sample)?;
    let gaia = run_method(&engine, MethodSpec::gaia(clients, 5), &cfg, minutes, sample)?;
    let chord = run_method(&engine, MethodSpec::chord(clients), &cfg, minutes, sample)?;
    let dds = run_method(&engine, MethodSpec::dfl_dds(7), &cfg, minutes, sample)?;

    let t = curves_table(&[
        ("fedlay d=10", fed.samples()),
        ("fedavg", fedavg.samples()),
        ("gaia", gaia.samples()),
        ("chord", chord.samples()),
        ("dfl-dds", dds.samples()),
    ]);
    print!("{}", t.render());

    println!("\n=== Table III: accuracy at convergence ===");
    let mut t3 = Table::new(&["method", "accuracy", "gap to fedavg"]);
    let fa = final_acc(&fedavg);
    for (name, tr) in [
        ("fedlay", &fed),
        ("fedavg", &fedavg),
        ("gaia", &gaia),
        ("chord", &chord),
        ("dfl-dds", &dds),
    ] {
        let a = final_acc(tr);
        t3.row(&[
            name.to_string(),
            format!("{:.1}%", a * 100.0),
            format!("{:+.1}%", (a - fa) * 100.0),
        ]);
    }
    print!("{}", t3.render());

    // Paper-shape assertions: FedAvg is the upper bound; FedLay is within
    // a few points of it and not behind the decentralized comparators.
    let f = final_acc(&fed);
    assert!(fa >= f - 0.02, "fedavg should upper-bound fedlay");
    assert!(
        fa - f < 0.15,
        "fedlay should be within striking distance of fedavg ({fa:.3} vs {f:.3})"
    );
    // Gaia is excluded from the ordering assertion at reduced scale: with
    // 20 clients its 5 regions + global sync are effectively FedAvg (the
    // paper's 100-client regime separates them; see EXPERIMENTS.md E8).
    for (name, tr) in [("chord", &chord), ("dfl-dds", &dds)] {
        assert!(
            f >= final_acc(tr) - 0.05,
            "fedlay should not lose to {name} ({f:.3} vs {:.3})",
            final_acc(tr)
        );
    }
    println!("\nfig10/table3 shape checks OK");
    Ok(())
}
