//! E3 — Paper §IV-B metric-scaling figure: convergence factor, diameter,
//! and ASPL as the network grows, for FedLay (d = 6/8/10) vs Chord,
//! Viceroy, and Waxman.
//!
//! Expected shape: Viceroy/Waxman diameters and ASPL grow clearly with N;
//! Chord's convergence factor grows large; FedLay stays near-flat and best.

use fedlay::baselines;
use fedlay::bench_util::{scaled, Table};
use fedlay::metrics;
use fedlay::topology::fedlay_graph;

fn main() -> anyhow::Result<()> {
    let sizes: Vec<usize> = scaled(vec![100, 200, 300, 500], vec![100, 200, 400, 600, 800, 1000]);
    let seed = 2;
    let mut t = Table::new(&["topology", "N", "c_G", "diameter", "aspl"]);
    for &n in &sizes {
        for l in [3usize, 4, 5] {
            let m = metrics::evaluate(&fedlay_graph(n, l), seed);
            t.row(&[
                format!("fedlay-d{}", 2 * l),
                n.to_string(),
                format!("{:.1}", m.convergence_factor),
                m.diameter.to_string(),
                format!("{:.2}", m.avg_shortest_path),
            ]);
        }
        for name in ["chord", "viceroy", "waxman"] {
            let m = metrics::evaluate(&baselines::by_name(name, n, seed)?, seed);
            t.row(&[
                name.to_string(),
                n.to_string(),
                if m.convergence_factor.is_finite() {
                    format!("{:.1}", m.convergence_factor)
                } else {
                    "inf".into()
                },
                m.diameter.to_string(),
                format!("{:.2}", m.avg_shortest_path),
            ]);
        }
    }
    print!("{}", t.render());

    // shape checks
    let small = metrics::evaluate(&fedlay_graph(sizes[0], 4), seed);
    let large = metrics::evaluate(&fedlay_graph(*sizes.last().unwrap(), 4), seed);
    assert!(
        large.avg_shortest_path < small.avg_shortest_path * 2.0,
        "FedLay ASPL should grow sublinearly"
    );
    let wax_small = metrics::evaluate(&baselines::by_name("waxman", sizes[0], seed)?, seed);
    let wax_large =
        metrics::evaluate(&baselines::by_name("waxman", *sizes.last().unwrap(), seed)?, seed);
    assert!(
        wax_large.avg_shortest_path > wax_small.avg_shortest_path,
        "Waxman paths should grow with N"
    );
    println!("\nmetric scaling shape checks OK");
    Ok(())
}
