//! P1 — §Perf microbenchmarks: the hot paths of all three layers as seen
//! from L3. Feeds EXPERIMENTS.md §Perf (before/after iteration log).
//!
//! The bench bodies live in `fedlay::bench_util::suite` so `fedlay bench`
//! (the CI smoke entry point) and this harness measure the same code.
//! Results are printed as a table and persisted to `BENCH_micro.json`
//! in the working directory (schema in docs/perf.md). Pass `--quick`
//! for the scaled-down smoke variant.

use fedlay::bench_util::{engine_suite, micro_suite, render_results, write_bench_json};
use fedlay::runtime::{find_artifacts_dir, Engine};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut results = micro_suite(quick);

    // --- runtime: artifact execution (L2+L1 via PJRT) ---
    match find_artifacts_dir(None).and_then(|dir| Engine::load(&dir, &["mlp", "cnn"])) {
        Ok(engine) => results.extend(engine_suite(&engine, quick)?),
        Err(e) => eprintln!("skipping runtime benches (no artifacts): {e}"),
    }

    print!("{}", render_results(&results));
    let path = write_bench_json(Path::new("."), "micro", &results)?;
    println!("wrote {}", path.display());
    Ok(())
}
