//! P1 — §Perf microbenchmarks: the hot paths of all three layers as seen
//! from L3. Feeds EXPERIMENTS.md §Perf (before/after iteration log).
//!
//!  * greedy routing next-hop decision (per hop cost of NDMP)
//!  * virtual-coordinate hashing
//!  * event-queue throughput (DES backbone)
//!  * model fingerprinting (MEP de-dup)
//!  * CPU aggregation vs the AOT Pallas-kernel aggregation artifact
//!  * train-step and eval-step artifact execution latency

use fedlay::bench_util::{bench, render_results};
use fedlay::mep::{aggregate_cpu, fingerprint, pack_for_artifact};
use fedlay::ndmp::messages::Dir;
use fedlay::ndmp::routing::{coord_of, directional_next_hop, greedy_next_hop};
use fedlay::runtime::{find_artifacts_dir, Engine, XInput};
use fedlay::sim::{EventKind, EventQueue};
use fedlay::topology::fedlay::Membership;
use fedlay::util::Rng;

fn main() -> anyhow::Result<()> {
    let mut results = Vec::new();

    // --- L3: routing hot path ---
    let m = Membership::dense(500, 3);
    let nbrs: Vec<Vec<u64>> = m
        .nodes
        .keys()
        .map(|&id| m.correct_neighbors(id).into_iter().collect())
        .collect();
    let ids: Vec<u64> = m.nodes.keys().copied().collect();
    let mut rng = Rng::new(1);
    results.push(bench("ndmp/greedy_next_hop (500 nodes, L=3)", 100, 20_000, || {
        let i = rng.index(ids.len());
        let target = rng.next_f64();
        greedy_next_hop(ids[i], target, 1, nbrs[i].iter().copied())
    }));
    results.push(bench("ndmp/directional_next_hop", 100, 20_000, || {
        let i = rng.index(ids.len());
        let target = rng.next_f64();
        directional_next_hop(ids[i], target, 1, Dir::Ccw, nbrs[i].iter().copied())
    }));
    results.push(bench("topology/coord_of (sha256)", 100, 20_000, || {
        coord_of(rng.next_u64(), 2)
    }));

    // --- L3: event queue ---
    results.push(bench("sim/event_queue push+pop x1000", 10, 500, || {
        let mut q = EventQueue::new();
        for i in 0..1000u64 {
            q.push(i * 7 % 997, EventKind::Snapshot { tag: i });
        }
        while q.pop().is_some() {}
    }));

    // --- MEP: fingerprint + CPU aggregation ---
    let model: Vec<f32> = (0..101_770).map(|i| i as f32 * 0.001).collect();
    results.push(bench("mep/fingerprint (101k params)", 3, 200, || {
        fingerprint(&model)
    }));
    let stack_models: Vec<Vec<f32>> = (0..7).map(|k| {
        model.iter().map(|v| v * (k as f32 + 1.0)).collect()
    }).collect();
    let refs: Vec<&[f32]> = stack_models.iter().map(|m| m.as_slice()).collect();
    let weights = vec![1.0; 7];
    results.push(bench("mep/aggregate_cpu (7 x 101k)", 3, 100, || {
        aggregate_cpu(&refs, &weights)
    }));

    // --- runtime: artifact execution (L2+L1 via PJRT) ---
    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &["mlp", "cnn"])?;
    let info = engine.manifest.task("mlp")?.clone();
    let k_max = engine.manifest.k_max;
    let params = engine.init("mlp", [1, 2])?;
    let (stack, w) = pack_for_artifact(&refs, &weights, k_max);
    results.push(bench("runtime/agg artifact (Pallas weighted_agg)", 3, 50, || {
        engine.aggregate("mlp", &stack, &w).unwrap()
    }));
    let task = fedlay::data::GaussianTask::mnist_like(3);
    let b = task.test_batch(info.batch, 9);
    results.push(bench("runtime/train_step mlp (B=32)", 3, 50, || {
        engine
            .train_step("mlp", &params, &XInput::F32(&b.x), &b.y, 0.1)
            .unwrap()
    }));
    results.push(bench("runtime/eval_step mlp (B=32)", 3, 50, || {
        engine
            .eval_step("mlp", &params, &XInput::F32(&b.x), &b.y)
            .unwrap()
    }));
    let cnn_params = engine.init("cnn", [1, 2])?;
    let cnn_info = engine.manifest.task("cnn")?.clone();
    let cnn_task = fedlay::data::GaussianTask::cifar_like(3);
    let cb = cnn_task.test_batch(cnn_info.batch, 9);
    results.push(bench("runtime/train_step cnn (B=32)", 3, 50, || {
        engine
            .train_step("cnn", &cnn_params, &XInput::F32(&cb.x), &cb.y, 0.1)
            .unwrap()
    }));

    print!("{}", render_results(&results));
    Ok(())
}
