//! E15 — Paper Fig. 20: scalability. (b) accuracy stays stable as the
//! network grows (large-scale simulation reusing trained models, exactly
//! like the paper's type-3 evaluation); (d) communication cost per client
//! (MB to convergence) for FedLay vs FedAvg vs Gaia vs DFL-DDS.
//!
//! Expected shape: FedLay's accuracy is flat in N; Gaia's per-client
//! communication blows up with N (poor scalability) while FedLay stays
//! near-constant (degree-bounded neighbor exchange).

use fedlay::bench_util::{scaled, Table};
use fedlay::config::DflConfig;
use fedlay::data::shard_labels;
use fedlay::dfl::harness::final_acc;
use fedlay::dfl::{MethodSpec, Trainer};
use fedlay::runtime::{find_artifacts_dir, Engine};

/// Train a small pool once, then instantiate a large fleet with pool
/// models (the paper's "re-use the models trained from the above two types
/// of experiments" methodology).
fn pool_models(engine: &Engine, cfg: &DflConfig, pool: usize) -> anyhow::Result<Vec<Vec<f32>>> {
    let mut pool_cfg = cfg.clone();
    pool_cfg.clients = pool;
    let w = shard_labels(pool, 10, pool_cfg.shards_per_client, pool_cfg.seed);
    let mut tr = Trainer::new(engine, MethodSpec::fedlay(pool, 3), pool_cfg, w)?;
    tr.run(scaled(120u64, 600) * 60_000_000, 60 * 60_000_000)?;
    Ok(tr.into_clients().into_iter().map(|c| c.params).collect())
}

fn main() -> anyhow::Result<()> {
    let sizes: Vec<usize> = scaled(vec![50, 100, 200], vec![200, 400, 600, 800, 1000]);
    let dir = find_artifacts_dir(None)?;
    // cnn task: small params keep the 1000-node fleet affordable
    let engine = Engine::load(&dir, &["cnn"])?;
    let base_cfg = DflConfig {
        task: "cnn".into(),
        clients: 0, // set per run
        local_steps: 2,
        comm_period_ms: 10 * 60 * 1_000,
        lr: 0.3,
        ..DflConfig::default()
    };
    println!("training the reusable model pool ...");
    let pool = pool_models(&engine, &base_cfg, 12)?;

    let horizon = scaled(120u64, 600) * 60_000_000;
    let mut acc_table = Table::new(&["N", "fedlay accuracy (frozen-model sim)"]);
    let mut comm_table = Table::new(&["N", "fedlay MB/client", "fedavg", "gaia", "dfl-dds"]);
    for &n in &sizes {
        let mut cfg = base_cfg.clone();
        cfg.clients = n;
        let w = shard_labels(n, 10, cfg.shards_per_client, cfg.seed);
        // Fig. 20b: accuracy stability with reused models
        let mut tr = Trainer::new(&engine, MethodSpec::fedlay(n, 3), cfg.clone(), w.clone())?;
        for (i, c) in tr.clients_mut().iter_mut().enumerate() {
            c.params = pool[i % pool.len()].clone();
        }
        tr.freeze_training = true;
        tr.run(horizon, horizon)?;
        acc_table.row(&[n.to_string(), format!("{:.3}", final_acc(&tr))]);

        // Fig. 20d: communication MB/client over the horizon, per method
        let mut comm = Vec::new();
        for spec in [
            MethodSpec::fedlay(n, 3),
            MethodSpec::fedavg(),
            MethodSpec::gaia(n, 10),
            MethodSpec::dfl_dds(3),
        ] {
            let mut t = Trainer::new(&engine, spec, cfg.clone(), w.clone())?;
            for (i, c) in t.clients_mut().iter_mut().enumerate() {
                c.params = pool[i % pool.len()].clone();
            }
            t.freeze_training = true;
            t.run(horizon, horizon)?;
            comm.push(t.model_mb_per_client());
        }
        comm_table.row(&[
            n.to_string(),
            format!("{:.2}", comm[0]),
            format!("{:.2}", comm[1]),
            format!("{:.2}", comm[2]),
            format!("{:.2}", comm[3]),
        ]);
    }
    println!("\n=== Fig. 20b: accuracy stability vs N ===");
    print!("{}", acc_table.render());
    println!("\n=== Fig. 20d: communication cost per client (MB) ===");
    print!("{}", comm_table.render());

    // shape checks
    let accs: Vec<f64> = acc_table
        .rows
        .iter()
        .map(|r| r[1].parse().unwrap())
        .collect();
    let spread = accs.iter().cloned().fold(f64::MIN, f64::max)
        - accs.iter().cloned().fold(f64::MAX, f64::min);
    assert!(spread < 0.1, "fedlay accuracy should be stable in N (spread {spread:.3})");
    let fed_first: f64 = comm_table.rows[0][1].parse().unwrap();
    let fed_last: f64 = comm_table.rows.last().unwrap()[1].parse().unwrap();
    assert!(
        fed_last < fed_first * 2.0,
        "fedlay comm/client should stay near-constant in N"
    );
    println!("\nfig20 shape checks OK");
    Ok(())
}
