//! E1 — Paper Table I: qualitative properties of DFL overlay topologies,
//! regenerated from *measured* values on this implementation: node degree,
//! decentralized constructibility (which of our generators have a
//! decentralized protocol), and convergence class from the measured λ.

use fedlay::baselines;
use fedlay::bench_util::Table;
use fedlay::metrics;
use fedlay::topology::fedlay_graph;

fn conv_class(lambda: f64) -> &'static str {
    if lambda < 0.9 {
        "Fast"
    } else if lambda < 0.99 {
        "Slow"
    } else {
        "Very slow"
    }
}

fn main() -> anyhow::Result<()> {
    let n = 128; // power of two so the hypercube row is exact
    let mut t = Table::new(&[
        "overlay", "decentralized construction", "node degree", "model convergence",
        "resilience to churn",
    ]);
    let rows: &[(&str, &str, &str)] = &[
        ("ring", "no protocol known", "no"),
        ("grid", "no protocol known", "no"),
        ("complete", "trivial but O(N) degree", "no"),
        ("chain", "no protocol known", "no"),
        ("hypercube", "no protocol known", "no"),
        ("torus", "no protocol known", "no"),
        ("chord", "yes (DHT join/stabilize)", "partial"),
        ("viceroy", "yes (butterfly emulation)", "partial"),
        ("delaunay", "yes (distributed DT)", "partial"),
        ("waxman", "no protocol known", "no"),
        ("social", "external channel", "no"),
    ];
    for (name, constr, churn) in rows {
        let g = baselines::by_name(name, n, 1)?;
        let m = metrics::evaluate(&g, 1);
        t.row(&[
            name.to_string(),
            constr.to_string(),
            format!("{:.1}", m.avg_degree),
            conv_class(m.lambda).to_string(),
            churn.to_string(),
        ]);
    }
    let g = fedlay_graph(n, 3);
    let m = metrics::evaluate(&g, 1);
    t.row(&[
        "fedlay (this work)".into(),
        "yes (NDMP, this repo)".into(),
        format!("{:.1} (<= 2L)", m.avg_degree),
        conv_class(m.lambda).into(),
        "yes (measured, fig8 bench)".into(),
    ]);
    println!("=== Table I (measured at N={n}) ===");
    print!("{}", t.render());
    Ok(())
}
