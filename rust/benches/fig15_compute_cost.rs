//! E12 — Paper Fig. 15: relative computation cost to reach a target
//! accuracy, normalized to FedAvg = 1. The paper (100 clients, MNIST,
//! target 88%) reports FedLay 1.33 < Gaia 1.53 < Chord 2.47 < DFL-DDS
//! 2.76.
//!
//! Cost metric: total local train steps executed across clients until the
//! method's mean accuracy first reaches the target.

use fedlay::bench_util::{scaled, Table};
use fedlay::config::DflConfig;
use fedlay::dfl::harness::run_method;
use fedlay::dfl::{MethodSpec, Trainer};
use fedlay::runtime::{find_artifacts_dir, Engine};

fn steps_to_target(tr: &Trainer, target: f64) -> Option<f64> {
    // samples record accuracy over time; train steps accrue linearly with
    // wakes, so interpolate cost at the first sample reaching the target.
    let hit = tr.samples().iter().position(|s| s.mean_accuracy >= target)?;
    let frac = tr.samples()[hit].at as f64 / tr.samples().last().unwrap().at.max(1) as f64;
    Some(tr.train_steps_per_client() * frac)
}

fn main() -> anyhow::Result<()> {
    let clients = scaled(16usize, 100);
    let minutes = scaled(200u64, 2_500);
    let target = scaled(0.5, 0.8);
    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &["mlp"])?;
    let cfg = DflConfig {
        task: "mlp".into(),
        clients,
        local_steps: 3,
        ..DflConfig::default()
    };
    let sample = minutes / 10;

    let fedavg = run_method(&engine, MethodSpec::fedavg(), &cfg, minutes, sample)?;
    let fed = run_method(&engine, MethodSpec::fedlay(clients, 5), &cfg, minutes, sample)?;
    let gaia = run_method(&engine, MethodSpec::gaia(clients, 4), &cfg, minutes, sample)?;
    let chord = run_method(&engine, MethodSpec::chord(clients), &cfg, minutes, sample)?;
    let dds = run_method(&engine, MethodSpec::dfl_dds(5), &cfg, minutes, sample)?;

    let base = steps_to_target(&fedavg, target);
    println!("=== Fig. 15: relative computation cost to reach {:.0}% accuracy ===", target * 100.0);
    let mut t = Table::new(&["method", "steps/client to target", "relative (fedavg=1)"]);
    let mut rel = std::collections::BTreeMap::new();
    for (name, tr) in [
        ("fedavg", &fedavg),
        ("fedlay", &fed),
        ("gaia", &gaia),
        ("chord", &chord),
        ("dfl-dds", &dds),
    ] {
        let steps = steps_to_target(tr, target);
        let r = match (steps, base) {
            (Some(s), Some(b)) if b > 0.0 => Some(s / b),
            _ => None,
        };
        if let Some(r) = r {
            rel.insert(name, r);
        }
        t.row(&[
            name.to_string(),
            steps.map(|s| format!("{s:.1}")).unwrap_or("never".into()),
            r.map(|r| format!("{r:.2}")).unwrap_or("-".into()),
        ]);
    }
    print!("{}", t.render());
    // shape: fedlay overhead over fedavg should be the smallest among the
    // decentralized methods that reached the target
    if let (Some(&f), Some(&c)) = (rel.get("fedlay"), rel.get("chord")) {
        assert!(f <= c + 0.25, "fedlay should be cheaper than chord ({f:.2} vs {c:.2})");
    }
    println!("\nfig15 done");
    Ok(())
}
