//! E14 — Paper Figs. 18/19: model accuracy under extreme churn — N new
//! clients join an N-client FedLay network mid-training. The paper tracks
//! the original nodes' and the newly joined nodes' accuracy separately:
//! new nodes catch up quickly thanks to high-confidence models from the
//! existing nodes.
//!
//! One *continuous* run on the unified engine: the trainer embeds the
//! NDMP overlay simulator (`Neighborhood::Dynamic`) and the join wave is
//! a declarative `ScenarioSpec` (`MassJoin` at t = 150 min) compiled to
//! protocol-level `EventKind::Join`s — the joiners enter through
//! Neighbor Discovery, the live views rewire the learning topology, and
//! training never stops. (The seed's version faked this with two
//! separate Trainers and a parameter copy.)

use fedlay::bench_util::{scaled, Table};
use fedlay::config::{DflConfig, NetConfig, OverlayConfig};
use fedlay::data::shard_labels;
use fedlay::dfl::harness::cohort_acc;
use fedlay::dfl::{MethodSpec, Trainer};
use fedlay::runtime::{find_artifacts_dir, Engine};
use fedlay::sim::{Phase, PhaseKind, ScenarioSpec};
use fedlay::util::cdf_points;

fn main() -> anyhow::Result<()> {
    let half = scaled(8usize, 50); // paper: 50 join 50
    let minutes_pre = scaled(150u64, 1_000);
    let minutes_post = scaled(150u64, 1_000);
    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &["mlp"])?;

    let cfg = DflConfig {
        task: "mlp".into(),
        clients: half,
        local_steps: 3,
        ..DflConfig::default()
    };
    // lighter maintenance traffic: a 2 s heartbeat is plenty at 300 min
    let overlay = OverlayConfig {
        heartbeat_ms: 2_000,
        repair_probe_ms: 8_000,
        ..OverlayConfig::default()
    };
    let seed = cfg.seed;
    let weights = shard_labels(2 * half, 10, 8, seed);
    let mut t = Trainer::new(
        &engine,
        MethodSpec::fedlay_dynamic(overlay.clone(), NetConfig::default()),
        cfg,
        weights[..half].to_vec(),
    )?;

    // The join wave as a declarative scenario: N protocol-level joins at
    // t = 150 min, compiled and scheduled by the scenario engine.
    let join_at = minutes_pre * 60_000_000;
    let total = (minutes_pre + minutes_post) * 60_000_000;
    let scenario = ScenarioSpec {
        name: "fig18-19-join-wave".into(),
        initial: half,
        seed,
        horizon: total,
        sample_every: total / 10,
        settle: 0,
        min_live: (half / 2).max(2),
        shards: 1,
        overlay,
        net: NetConfig::default(),
        phases: vec![Phase {
            at: join_at,
            kind: PhaseKind::MassJoin { count: half },
        }],
    };
    let report = scenario.run_trainer(&mut t, |id| weights[id].clone())?;
    println!(
        "scenario {}: {} joins, neighbor cache {} hits / {} misses",
        report.scenario, report.counts.joins, report.cache_hits, report.cache_misses
    );

    let pre_acc = t
        .samples()
        .iter()
        .filter(|s| s.at < join_at)
        .last()
        .map(|s| cohort_acc(s, 0..half))
        .unwrap_or(0.0);
    println!("phase 1: {half} original clients, accuracy {pre_acc:.3} at join time");
    let correctness = t.overlay.as_ref().map(|s| s.correctness()).unwrap_or(0.0);
    println!(
        "overlay after churn: {} live nodes, correctness {correctness:.3}",
        t.overlay.as_ref().map(|s| s.live_count()).unwrap_or(0)
    );

    println!("\n=== Fig. 18: accuracy of original vs newly joined nodes ===");
    let mut table = Table::new(&["t (min)", "original", "new joiners"]);
    for s in t.samples() {
        let old_acc = cohort_acc(s, 0..half);
        let new_acc = cohort_acc(s, half..2 * half);
        table.row(&[
            format!("{:.0}", s.at as f64 / 60e6),
            format!("{:.3}", old_acc),
            format!("{:.3}", new_acc),
        ]);
    }
    print!("{}", table.render());

    // Fig. 19: the per-client CDF at join time vs at the end
    let first = t
        .samples()
        .iter()
        .find(|s| s.at >= join_at)
        .expect("no post-join sample");
    let last = t.samples().last().unwrap();
    println!("\n=== Fig. 19: per-client accuracy CDF ===");
    println!("at join time:");
    for (a, f) in cdf_points(&first.per_client) {
        println!("  {a:.3} -> {f:.2}");
    }
    println!("at end:");
    for (a, f) in cdf_points(&last.per_client) {
        println!("  {a:.3} -> {f:.2}");
    }

    // shape checks: joiners start near chance, converge toward originals,
    // and the protocol join wave actually rebuilt a correct overlay
    let new_start = cohort_acc(first, half..2 * half);
    let new_end = cohort_acc(last, half..2 * half);
    let old_end = cohort_acc(last, 0..half);
    assert!(new_start < 0.3, "joiners should start low (got {new_start:.3})");
    assert!(
        new_end > new_start + 0.2,
        "joiners should catch up ({new_start:.3} -> {new_end:.3})"
    );
    assert!(
        (old_end - new_end).abs() < 0.15,
        "cohorts should converge together ({old_end:.3} vs {new_end:.3})"
    );
    assert!(
        correctness > 0.999,
        "NDMP should rebuild a correct overlay (got {correctness:.3})"
    );
    println!("\nfig18/19 shape checks OK");
    Ok(())
}
