//! E14 — Paper Figs. 18/19: model accuracy under extreme churn — N new
//! clients join an N-client FedLay network mid-training. The paper tracks
//! the original nodes' and the newly joined nodes' accuracy separately:
//! new nodes catch up quickly thanks to high-confidence models from the
//! existing nodes.

use fedlay::bench_util::{scaled, Table};
use fedlay::config::DflConfig;
use fedlay::data::shard_labels;
use fedlay::dfl::{MethodSpec, Trainer};
use fedlay::runtime::{find_artifacts_dir, Engine};
use fedlay::util::cdf_points;

fn main() -> anyhow::Result<()> {
    let half = scaled(8usize, 50); // paper: 50 join 50
    let minutes_pre = scaled(150u64, 1_000);
    let minutes_post = scaled(150u64, 1_000);
    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &["mlp"])?;

    // Phase 1: train the original cohort alone.
    let cfg1 = DflConfig {
        task: "mlp".into(),
        clients: half,
        local_steps: 3,
        ..DflConfig::default()
    };
    let w1 = shard_labels(half, 10, 8, cfg1.seed);
    let mut t1 = Trainer::new(&engine, MethodSpec::fedlay(half, 3), cfg1.clone(), w1.clone())?;
    t1.run(minutes_pre * 60_000_000, minutes_pre * 60_000_000 / 4)?;
    let pre_acc = t1.samples.last().unwrap().mean_accuracy;
    println!("phase 1: {half} original clients, accuracy {pre_acc:.3} at join time");

    // Phase 2: double the network; originals keep their trained models,
    // joiners start fresh.
    let cfg2 = DflConfig {
        clients: 2 * half,
        ..cfg1.clone()
    };
    let w2 = shard_labels(2 * half, 10, 8, cfg2.seed ^ 1);
    let mut t2 = Trainer::new(&engine, MethodSpec::fedlay(2 * half, 3), cfg2, w2)?;
    for i in 0..half {
        t2.clients[i].params = t1.clients[i].params.clone();
    }
    t2.run(minutes_post * 60_000_000, minutes_post * 60_000_000 / 5)?;

    println!("\n=== Fig. 18: accuracy of original vs newly joined nodes ===");
    let mut table = Table::new(&["t (min)", "original", "new joiners"]);
    for s in &t2.samples {
        let old_acc: f64 = s.per_client[..half].iter().sum::<f64>() / half as f64;
        let new_acc: f64 = s.per_client[half..].iter().sum::<f64>() / half as f64;
        table.row(&[
            format!("{:.0}", s.at as f64 / 60e6),
            format!("{:.3}", old_acc),
            format!("{:.3}", new_acc),
        ]);
    }
    print!("{}", table.render());

    // Fig. 19: the per-client CDF at join time vs at the end
    let first = &t2.samples[0];
    let last = t2.samples.last().unwrap();
    println!("\n=== Fig. 19: per-client accuracy CDF ===");
    println!("at join time:");
    for (a, f) in cdf_points(&first.per_client) {
        println!("  {a:.3} -> {f:.2}");
    }
    println!("at end:");
    for (a, f) in cdf_points(&last.per_client) {
        println!("  {a:.3} -> {f:.2}");
    }

    // shape checks: joiners start near chance, converge toward originals
    let new_start: f64 = first.per_client[half..].iter().sum::<f64>() / half as f64;
    let new_end: f64 = last.per_client[half..].iter().sum::<f64>() / half as f64;
    let old_end: f64 = last.per_client[..half].iter().sum::<f64>() / half as f64;
    assert!(new_start < 0.3, "joiners should start low (got {new_start:.3})");
    assert!(
        new_end > new_start + 0.2,
        "joiners should catch up ({new_start:.3} -> {new_end:.3})"
    );
    assert!(
        (old_end - new_end).abs() < 0.15,
        "cohorts should converge together ({old_end:.3} vs {new_end:.3})"
    );
    println!("\nfig18/19 shape checks OK");
    Ok(())
}
