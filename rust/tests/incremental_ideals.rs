//! Property suite for the incrementally-maintained Definition-1 ideal
//! topology (`topology::IdealRings`, docs/perf.md) — seeded sweeps in
//! the style of `tests/scenario_properties.rs` (proptest is not in the
//! vendored set). Two layers:
//!
//!   * tracker vs oracle: after EVERY event of a random add/remove
//!     schedule — including the n < 2 rings and injected
//!     duplicate-coordinate ties — `ideal_snapshot()` must equal the
//!     batch `ideal_neighbor_sets` over the same membership, and the
//!     running `required`/`present` tallies must match the batch sums,
//!   * engine end to end: during live churn runs, the O(1)
//!     `Simulator::correctness()` must stay *bitwise* equal to the
//!     O(L·n log n) `correctness_batch()` rebuild at every sample
//!     point, on the serial engine and across shard counts — and the
//!     K-shard sample series must be bitwise identical to K=1.

use fedlay::config::{NetConfig, OverlayConfig};
use fedlay::ndmp::messages::{MS, SEC};
use fedlay::sim::Simulator;
use fedlay::topology::{ideal_neighbor_sets, IdealRings, NodeId, VirtualCoords};
use fedlay::util::Rng;
use std::collections::BTreeSet;

// ----------------------------------------------------------------------
// Layer 0: direct n < 4 edge arithmetic — literal expectations, no
// oracle, so a bug shared by tracker and batch builder still fails
// ----------------------------------------------------------------------

fn set(ids: &[NodeId]) -> BTreeSet<NodeId> {
    ids.iter().copied().collect()
}

#[test]
fn inserting_into_a_two_ring_never_unlinks_the_pair() {
    for spaces in 1..=3 {
        let mut t = IdealRings::new(spaces);
        t.add(0);
        assert!(
            t.ideal_snapshot()[&0].is_empty(),
            "L={spaces}: a singleton has no ideal links"
        );
        t.add(1);
        assert_eq!(t.ideal_snapshot()[&0], set(&[1]));
        assert_eq!(t.ideal_snapshot()[&1], set(&[0]));
        assert_eq!(t.required(), 2, "L={spaces}: 2-ring union is one link");
        // growing 2 -> 3: splicing the newcomer between the pair must not
        // drop the existing link (a 3-ring is all-pairs in every space)
        t.add(2);
        let snap = t.ideal_snapshot();
        assert_eq!(snap[&0], set(&[1, 2]), "L={spaces}: 0 lost a link at 2 -> 3");
        assert_eq!(snap[&1], set(&[0, 2]), "L={spaces}: 1 lost a link at 2 -> 3");
        assert_eq!(snap[&2], set(&[0, 1]));
        assert_eq!(t.required(), 6);
    }
}

#[test]
fn removing_from_a_three_ring_never_rewelds_extras() {
    for spaces in 1..=3 {
        for victim in 0..3u64 {
            let mut t = IdealRings::new(spaces);
            for id in 0..3 {
                t.add(id);
            }
            let touched = t.remove(victim);
            let survivors: Vec<NodeId> = (0..3).filter(|&x| x != victim).collect();
            for s in &survivors {
                assert!(
                    touched.contains(s),
                    "L={spaces}: survivor {s} not reported touched by remove({victim})"
                );
            }
            let snap = t.ideal_snapshot();
            assert_eq!(snap.len(), 2, "L={spaces}: victim {victim} still present");
            // exactly the pair link: no duplicate entries, no self-link,
            // and no stale edge back to the removed node
            assert_eq!(snap[&survivors[0]], set(&[survivors[1]]));
            assert_eq!(snap[&survivors[1]], set(&[survivors[0]]));
            assert_eq!(t.required(), 2);
            // shrink to a singleton: the self-weld must not appear
            t.remove(survivors[0]);
            assert!(
                t.ideal_snapshot()[&survivors[1]].is_empty(),
                "L={spaces}: singleton acquired a link after shrink to 1"
            );
            assert_eq!(t.required(), 0);
        }
    }
}

#[test]
fn duplicate_coordinate_ties_resolve_deterministically_and_stay_exact() {
    for spaces in 1..=3 {
        let mut t = IdealRings::new(spaces);
        t.add(7);
        // 3 and 11 collide with 7's coordinates in every space: ring
        // order among the tie group falls back to the id tie-break
        t.add_with_coords(3, VirtualCoords::from_id(7, spaces));
        t.add_with_coords(11, VirtualCoords::from_id(7, spaces));
        let snap = t.ideal_snapshot();
        assert_eq!(snap[&3], set(&[7, 11]), "L={spaces}: tie trio not all-pairs");
        assert_eq!(snap[&7], set(&[3, 11]));
        assert_eq!(snap[&11], set(&[3, 7]));
        // removing the coordinate owner leaves the two imposters as a
        // clean pair (their edges spliced, nothing re-welded to 7)
        let touched = t.remove(7);
        for s in [3u64, 11] {
            assert!(touched.contains(&s), "L={spaces}: {s} not touched");
        }
        let snap = t.ideal_snapshot();
        assert_eq!(snap[&3], set(&[11]));
        assert_eq!(snap[&11], set(&[3]));
        // the survivors' tallies still reach exactly 1.0 on exact sets
        t.refresh(3, &set(&[11]));
        t.refresh(11, &set(&[3]));
        assert_eq!(t.present(), t.required(), "L={spaces}: tally drift");
        assert_eq!(t.correctness(), 1.0);
    }
}

// ----------------------------------------------------------------------
// Layer 1: the tracker against the batch oracle, event by event
// ----------------------------------------------------------------------

/// Assert tracker ≡ oracle on the current membership, then hand every
/// touched node its exact ideal set so the presence invariant ("every
/// live node's flags match a converged overlay") carries to the next
/// event. Returns a readable violation description on mismatch.
fn check_event(t: &mut IdealRings, touched: &[NodeId], what: &str) -> Result<(), String> {
    let batch = ideal_neighbor_sets(&t.membership());
    if t.ideal_snapshot() != batch {
        return Err(format!("{what}: ideal_snapshot diverged from batch oracle"));
    }
    let sum: usize = batch.values().map(|s| s.len()).sum();
    if t.required() != sum {
        return Err(format!(
            "{what}: required tally {} != Σ|want| {sum}",
            t.required()
        ));
    }
    for &id in touched {
        if t.contains(id) {
            let want = t.want(id);
            t.refresh(id, &want);
        }
    }
    // untouched nodes kept their (unchanged) exact sets, touched ones
    // were just restored — the converged tallies must read exactly 1.0
    if t.correctness() != 1.0 {
        return Err(format!(
            "{what}: converged tallies read {} ({} / {})",
            t.correctness(),
            t.present(),
            t.required()
        ));
    }
    Ok(())
}

fn check_tracker_schedule(seed: u64) -> Result<(), String> {
    let mut rng = Rng::new(seed ^ 0x1DEA);
    let spaces = 1 + rng.index(3);
    let mut t = IdealRings::new(spaces);
    let mut live: Vec<NodeId> = Vec::new();
    let mut next_id: NodeId = 0;
    let mut generations = 0u64;
    for step in 0..120 {
        if !live.is_empty() && rng.index(3) == 0 {
            let id = live.swap_remove(rng.index(live.len()));
            let touched = t.remove(id);
            generations += 1;
            check_event(&mut t, &touched, &format!("step {step}: remove {id}"))?;
        } else {
            let id = next_id;
            next_id += 1;
            // one add in four collides its coordinates with a live node:
            // the (coord, id) tie-break must agree with the batch sort
            let touched = if !live.is_empty() && rng.index(4) == 0 {
                let other = live[rng.index(live.len())];
                t.add_with_coords(id, VirtualCoords::from_id(other, spaces))
            } else {
                t.add(id)
            };
            live.push(id);
            generations += 1;
            check_event(&mut t, &touched, &format!("step {step}: add {id}"))?;
        }
        if t.generation() != generations {
            return Err(format!(
                "step {step}: generation {} != {generations} membership events",
                t.generation()
            ));
        }
    }
    // drain to empty in random order: every shrink through the bespoke
    // n < 4 ring arithmetic is exercised on the way down
    while !live.is_empty() {
        let id = live.swap_remove(rng.index(live.len()));
        let touched = t.remove(id);
        check_event(&mut t, &touched, &format!("drain: remove {id}"))?;
    }
    if !t.is_empty() || t.required() != 0 || t.present() != 0 {
        return Err("tracker not empty after full drain".into());
    }
    Ok(())
}

#[test]
fn property_tracker_matches_batch_ideal_after_every_event() {
    for seed in 0..8u64 {
        if let Err(msg) = check_tracker_schedule(seed) {
            panic!("seed {seed}: incremental/batch divergence: {msg}");
        }
    }
}

// ----------------------------------------------------------------------
// Layer 2: the engine end to end — live churn, serial and sharded
// ----------------------------------------------------------------------

/// Drive a seeded join/fail/leave schedule through a `shards`-way
/// engine, asserting incremental ≡ batch (bitwise) at every sample
/// point; returns the sample series for cross-K comparison. The
/// schedule is derived from the seed and a local membership mirror, so
/// identical seeds produce identical schedules at any shard count.
fn churn_run(shards: usize, seed: u64) -> Vec<f64> {
    let overlay = OverlayConfig {
        spaces: 2,
        heartbeat_ms: 500,
        failure_multiple: 3,
        repair_probe_ms: 2_000,
    };
    let net = NetConfig {
        latency_ms: 60.0,
        jitter: 0.2,
        seed,
        ..NetConfig::default()
    };
    let mut sim = Simulator::new(overlay, net);
    if shards > 1 {
        sim.set_shards(shards);
    }
    let n: NodeId = 24;
    let ids: Vec<NodeId> = (0..n).collect();
    sim.bootstrap_correct(&ids);
    let mut alive: BTreeSet<NodeId> = ids.iter().copied().collect();
    let mut next_id: NodeId = n;
    let mut rng = Rng::new(seed ^ 0xC0FFEE);
    let mut samples = Vec::new();
    let pick = |alive: &BTreeSet<NodeId>, k: usize| *alive.iter().nth(k).unwrap();
    for step in 0..12 {
        // one membership op per step, executed before the next is drawn,
        // so the mirror always agrees with the engine's live set
        match rng.index(3) {
            0 => {
                let boot = pick(&alive, rng.index(alive.len()));
                sim.schedule_join(sim.now + 50 * MS, next_id, boot);
                alive.insert(next_id);
                next_id += 1;
            }
            1 if alive.len() > 4 => {
                let node = pick(&alive, rng.index(alive.len()));
                sim.schedule_fail(sim.now + 50 * MS, node);
                alive.remove(&node);
            }
            _ if alive.len() > 4 => {
                let node = pick(&alive, rng.index(alive.len()));
                sim.schedule_leave(sim.now + 50 * MS, node);
                alive.remove(&node);
            }
            _ => {}
        }
        // advance mid-repair: the equality must hold on degraded rings,
        // not just at quiescence
        sim.run_until(sim.now + 2 * SEC);
        let inc = sim.correctness();
        let batch = sim.correctness_batch();
        assert_eq!(
            inc.to_bits(),
            batch.to_bits(),
            "seed {seed} K={shards} step {step}: incremental {inc} != batch {batch}"
        );
        assert_eq!(
            sim.ideal().len(),
            sim.live_count(),
            "seed {seed} K={shards} step {step}: tracker membership drifted"
        );
        samples.push(inc);
    }
    let live: BTreeSet<NodeId> = sim.node_ids().into_iter().collect();
    assert_eq!(live, alive, "seed {seed} K={shards}: membership mirror diverged");
    samples
}

#[test]
fn property_engine_correctness_incremental_equals_batch_under_churn() {
    for seed in 0..4u64 {
        let serial = churn_run(1, seed);
        assert!(
            serial.iter().all(|c| (0.0..=1.0).contains(c)),
            "seed {seed}: correctness out of range: {serial:?}"
        );
    }
}

#[test]
fn property_sharded_sampling_is_bitwise_identical_to_serial() {
    for seed in 0..3u64 {
        let serial = churn_run(1, seed);
        for k in [4usize, 16] {
            let sharded = churn_run(k, seed);
            let a: Vec<u64> = serial.iter().map(|c| c.to_bits()).collect();
            let b: Vec<u64> = sharded.iter().map(|c| c.to_bits()).collect();
            assert_eq!(a, b, "seed {seed}: K={k} sample series != K=1");
        }
    }
}
