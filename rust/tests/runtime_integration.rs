//! Integration: the Rust PJRT runtime executing the AOT artifacts
//! (L3 -> L2 -> L1 composition). Requires `make artifacts` to have run;
//! tests self-skip when the artifacts are absent.

use fedlay::data::GaussianTask;
use fedlay::mep::{aggregate_cpu, pack_for_artifact};
use fedlay::runtime::{find_artifacts_dir, Engine, XInput};
use fedlay::util::Rng;

fn engine(tasks: &[&str]) -> Option<Engine> {
    let dir = find_artifacts_dir(None).ok()?;
    Some(Engine::load(&dir, tasks).expect("engine load"))
}

#[test]
fn init_is_deterministic_and_shaped() {
    let Some(eng) = engine(&["mlp"]) else { return };
    let p1 = eng.init("mlp", [1, 2]).unwrap();
    let p2 = eng.init("mlp", [1, 2]).unwrap();
    let p3 = eng.init("mlp", [3, 4]).unwrap();
    assert_eq!(p1.len(), eng.manifest.task("mlp").unwrap().param_count);
    assert_eq!(p1, p2);
    assert_ne!(p1, p3);
    assert!(p1.iter().all(|v| v.is_finite()));
}

#[test]
fn train_step_learns_a_fixed_batch() {
    let Some(eng) = engine(&["mlp"]) else { return };
    let info = eng.manifest.task("mlp").unwrap().clone();
    let task = GaussianTask::mnist_like(7);
    let batch = task.test_batch(info.batch, 42);
    let mut params = eng.init("mlp", [0, 7]).unwrap();
    let (_, loss0) = eng
        .eval_step("mlp", &params, &XInput::F32(&batch.x), &batch.y)
        .unwrap();
    let mut last_loss = f32::INFINITY;
    for _ in 0..15 {
        let (new, loss) = eng
            .train_step("mlp", &params, &XInput::F32(&batch.x), &batch.y, 0.1)
            .unwrap();
        params = new;
        last_loss = loss;
    }
    let (correct, loss1) = eng
        .eval_step("mlp", &params, &XInput::F32(&batch.x), &batch.y)
        .unwrap();
    assert!(loss1 < loss0, "loss did not fall: {loss0} -> {loss1}");
    assert!(last_loss.is_finite());
    assert!(correct >= 0.0 && correct <= info.batch as f32);
}

#[test]
fn artifact_aggregation_matches_cpu_reference() {
    let Some(eng) = engine(&["cnn"]) else { return };
    let info = eng.manifest.task("cnn").unwrap().clone();
    let k_max = eng.manifest.k_max;
    let mut rng = Rng::new(5);
    let models: Vec<Vec<f32>> = (0..4)
        .map(|_| (0..info.param_count).map(|_| rng.gaussian() as f32).collect())
        .collect();
    let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
    let weights = [0.9, 0.4, 0.1, 0.6];
    let want = aggregate_cpu(&refs, &weights);
    let (stack, w) = pack_for_artifact(&refs, &weights, k_max);
    let got = eng.aggregate("cnn", &stack, &w).unwrap();
    assert_eq!(got.len(), want.len());
    for (i, (g, wv)) in got.iter().zip(&want).enumerate() {
        assert!(
            (g - wv).abs() < 1e-4 * (1.0 + wv.abs()),
            "mismatch at {i}: {g} vs {wv}"
        );
    }
}

#[test]
fn lstm_task_roundtrip() {
    let Some(eng) = engine(&["lstm"]) else { return };
    let info = eng.manifest.task("lstm").unwrap().clone();
    assert_eq!(info.x_dtype, "i32");
    let mut stream = fedlay::data::CharStream::new(&[1], 3);
    let (x, y) = stream.batch(info.batch, info.x_len);
    let params = eng.init("lstm", [9, 9]).unwrap();
    let (new, loss) = eng
        .train_step("lstm", &params, &XInput::I32(&x), &y, 0.5)
        .unwrap();
    assert_eq!(new.len(), info.param_count);
    assert!(loss.is_finite() && loss > 0.0);
    let (correct, eloss) = eng
        .eval_step("lstm", &new, &XInput::I32(&x), &y)
        .unwrap();
    assert!(correct >= 0.0 && correct <= info.batch as f32);
    assert!(eloss.is_finite());
}

#[test]
fn shape_mismatches_are_rejected() {
    let Some(eng) = engine(&["cnn"]) else { return };
    let info = eng.manifest.task("cnn").unwrap().clone();
    let params = vec![0.0f32; info.param_count];
    let bad_x = vec![0.0f32; 3];
    let y = vec![0i32; info.batch];
    assert!(eng
        .train_step("cnn", &params, &XInput::F32(&bad_x), &y, 0.1)
        .is_err());
    let short_params = vec![0.0f32; 10];
    let x = vec![0.0f32; info.batch * info.x_len];
    assert!(eng
        .train_step("cnn", &short_params, &XInput::F32(&x), &y, 0.1)
        .is_err());
    assert!(eng.task("mlp").is_err(), "mlp not loaded in this engine");
}
