//! Integration tests for the unified discrete-event engine: training and
//! NDMP overlay maintenance on one scheduler. A mid-training join wave
//! must (a) rebuild a Definition-1-correct overlay through the actual
//! protocol and (b) let joiners' accuracy converge to the originals'.

use fedlay::config::{DflConfig, NetConfig, OverlayConfig};
use fedlay::data::shard_labels;
use fedlay::dfl::harness::cohort_acc;
use fedlay::dfl::{MethodSpec, Neighborhood, Trainer};
use fedlay::runtime::{find_artifacts_dir, Engine};

const MIN: u64 = 60_000_000; // µs per simulated minute

fn overlay() -> OverlayConfig {
    OverlayConfig {
        spaces: 3,
        heartbeat_ms: 2_000,
        failure_multiple: 3,
        repair_probe_ms: 8_000,
    }
}

fn net() -> NetConfig {
    NetConfig {
        latency_ms: 80.0,
        jitter: 0.2,
        seed: 11,
        ..NetConfig::default()
    }
}

#[test]
fn mid_training_join_wave_rewires_and_converges() -> anyhow::Result<()> {
    let originals = 8usize;
    let joiners = 5usize;
    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &["mlp"])?;
    let cfg = DflConfig {
        task: "mlp".into(),
        clients: originals,
        local_steps: 2,
        ..DflConfig::default()
    };
    let weights = shard_labels(originals + joiners, 10, 8, cfg.seed);
    let mut t = Trainer::new(
        &engine,
        MethodSpec::fedlay_dynamic(overlay(), net()),
        cfg,
        weights[..originals].to_vec(),
    )?;
    // join wave at t = 60 min, run until t = 180 min
    let join_at = 60 * MIN;
    for j in 0..joiners {
        let id = t.schedule_join(join_at, weights[originals + j].clone(), j % originals)?;
        assert_eq!(id, originals + j);
        assert!(!t.clients()[id].alive, "joiners start as dead placeholders");
    }
    t.run(180 * MIN, 30 * MIN)?;

    // (a) the protocol join wave rebuilt a correct overlay over all nodes
    let sim = t.overlay.as_ref().expect("dynamic overlay state");
    assert_eq!(sim.live_count(), originals + joiners, "overlay lost joiners");
    let c = sim.correctness();
    assert!(c > 0.999, "topology correctness after join wave: {c}");
    // every joiner is wired into the live learning topology
    for j in originals..originals + joiners {
        assert!(t.clients()[j].alive);
        let nbrs = sim.node(j as u64).unwrap().ring_neighbor_ids();
        assert!(!nbrs.is_empty(), "joiner {j} has no overlay neighbors");
        assert!(
            nbrs.len() <= 2 * overlay().spaces,
            "learning degree must stay <= 2L, got {}",
            nbrs.len()
        );
        assert!(t.clients()[j].exchanges > 0, "joiner {j} never aggregated");
    }

    // (b) joiners converged to within 0.15 of the originals
    let last = t.samples().last().unwrap();
    let old_end = cohort_acc(last, 0..originals);
    let new_end = cohort_acc(last, originals..originals + joiners);
    let first_post = t.samples().iter().find(|s| s.at >= join_at).unwrap();
    let new_start = cohort_acc(first_post, originals..originals + joiners);
    assert!(old_end > 0.4, "originals failed to learn: {old_end}");
    assert!(
        (old_end - new_end).abs() < 0.15,
        "cohorts did not converge: originals {old_end:.3} vs joiners {new_end:.3} \
         (joiners started at {new_start:.3})"
    );
    Ok(())
}

#[test]
fn failures_rewire_the_learning_topology() -> anyhow::Result<()> {
    let n = 10usize;
    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &["mlp"])?;
    let cfg = DflConfig {
        task: "mlp".into(),
        clients: n,
        local_steps: 1,
        ..DflConfig::default()
    };
    let weights = shard_labels(n, 10, 8, cfg.seed);
    let mut t = Trainer::new(
        &engine,
        MethodSpec::fedlay_dynamic(overlay(), net()),
        cfg,
        weights,
    )?;
    t.schedule_fail(20 * MIN, 3);
    t.schedule_fail(20 * MIN, 7);
    t.run(90 * MIN, 45 * MIN)?;
    let sim = t.overlay.as_ref().unwrap();
    assert_eq!(sim.live_count(), n - 2);
    assert!(!t.clients()[3].alive && !t.clients()[7].alive);
    let c = sim.correctness();
    assert!(c > 0.999, "overlay not repaired after failures: {c}");
    // dead clients froze at failure time; live ones kept training
    let dead_steps = t.clients()[3].train_steps;
    let live_steps = t.clients()[0].train_steps;
    assert!(live_steps > dead_steps, "{live_steps} vs {dead_steps}");
    // the accuracy mean covers live clients only
    assert_eq!(t.samples().last().unwrap().per_client.len(), n);
    Ok(())
}

#[test]
fn adopting_a_grown_overlay_preserves_protocol_state() -> anyhow::Result<()> {
    use fedlay::ndmp::messages::MS;
    use fedlay::sim::grow_network;
    let n = 8usize;
    let sim = grow_network(overlay(), net(), n, 1_200 * MS);
    assert!(sim.correctness() > 0.999, "grown network not correct");
    let delivered0 = sim.delivered;
    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &["mlp"])?;
    let cfg = DflConfig {
        task: "mlp".into(),
        clients: n,
        local_steps: 1,
        ..DflConfig::default()
    };
    let weights = shard_labels(n, 10, 8, cfg.seed);
    let mut t = Trainer::new(
        &engine,
        MethodSpec::fedlay_dynamic(overlay(), net()),
        cfg,
        weights,
    )?;
    t.adopt_overlay(sim)?;
    t.run(30 * MIN, 15 * MIN)?;
    let sim = t.overlay.as_ref().unwrap();
    assert!(sim.correctness() > 0.999, "adopted overlay degraded");
    assert!(
        sim.delivered > delivered0,
        "adopted overlay protocol should keep running under the trainer"
    );
    Ok(())
}

#[test]
fn static_and_dynamic_agree_without_churn() -> anyhow::Result<()> {
    // With no churn, a converged NDMP overlay *is* the FedLay graph, so
    // the two neighborhood sources must produce comparable accuracy.
    let n = 8usize;
    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &["mlp"])?;
    let cfg = DflConfig {
        task: "mlp".into(),
        clients: n,
        local_steps: 2,
        ..DflConfig::default()
    };
    let weights = shard_labels(n, 10, 8, cfg.seed);
    let mut stat = Trainer::new(&engine, MethodSpec::fedlay(n, 3), cfg.clone(), weights.clone())?;
    stat.run(60 * MIN, 30 * MIN)?;
    let mut dyn_t = Trainer::new(
        &engine,
        MethodSpec::fedlay_dynamic(overlay(), net()),
        cfg,
        weights,
    )?;
    assert!(matches!(dyn_t.spec.neighborhood, Neighborhood::Dynamic { .. }));
    dyn_t.run(60 * MIN, 30 * MIN)?;
    let a = stat.samples().last().unwrap().mean_accuracy;
    let b = dyn_t.samples().last().unwrap().mean_accuracy;
    assert!((a - b).abs() < 0.2, "static {a:.3} vs dynamic {b:.3}");
    // joins on a static graph are rejected
    assert!(stat.schedule_join(1, vec![1.0; 10], 0).is_err());
    Ok(())
}
