//! Golden-trajectory tests: one pinned seed per canonical scenario
//! (Fig. 8a join wave, Fig. 8b mass fail, mixed Poisson churn) snapshots
//! the correctness time series produced by the scenario engine on the
//! deterministic in-memory transport. Any behavioral drift in the
//! scheduler, the latency model, the NDMP engines, or scenario
//! compilation shows up as a readable line-by-line diff.
//!
//! Snapshot workflow (insta-style, no external crates):
//!   * goldens live in `tests/golden/<name>.txt`;
//!   * a missing golden is blessed from the current run (first run on a
//!     fresh scenario) — commit the generated file;
//!   * an intentional change is re-blessed with `FEDLAY_BLESS=1`;
//!   * with `FEDLAY_REQUIRE_GOLDEN=1` (set in CI) a missing golden is a
//!     hard failure instead of a self-bless, so the suite actually
//!     *gates*: a deleted or never-committed golden cannot silently
//!     bless itself green on a fresh checkout.

use fedlay::config::{DflConfig, MultiTaskSpec, NetConfig, OverlayConfig};
use fedlay::dfl::{multitask, MethodSpec};
use fedlay::ndmp::messages::SEC;
use fedlay::runtime::{find_artifacts_dir, Engine};
use fedlay::sim::ScenarioSpec;
use std::fs;
use std::path::PathBuf;

fn overlay() -> OverlayConfig {
    OverlayConfig {
        spaces: 3,
        heartbeat_ms: 500,
        failure_multiple: 3,
        repair_probe_ms: 2_000,
    }
}

fn net(seed: u64) -> NetConfig {
    NetConfig {
        latency_ms: 350.0,
        jitter: 0.2,
        seed,
        ..NetConfig::default()
    }
}

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
}

fn diff_report(name: &str, want: &str, got: &str) -> String {
    let mut out = format!(
        "golden trajectory {name:?} diverged from tests/golden/{name}.txt.\n\
         If the change is intentional, regenerate with `FEDLAY_BLESS=1 cargo test \
         --test scenario_golden` and commit the new golden.\n"
    );
    let w: Vec<&str> = want.lines().collect();
    let g: Vec<&str> = got.lines().collect();
    let mut shown = 0;
    for i in 0..w.len().max(g.len()) {
        let a = w.get(i).copied().unwrap_or("<missing>");
        let b = g.get(i).copied().unwrap_or("<missing>");
        if a != b {
            out.push_str(&format!(
                "  line {:>3}: expected `{a}`\n            got      `{b}`\n",
                i + 1
            ));
            shown += 1;
            if shown >= 8 {
                out.push_str("  ... (further differences elided)\n");
                break;
            }
        }
    }
    out
}

fn run_golden(name: &str, spec: &ScenarioSpec) {
    let (_, report) = spec.run_sim(None).expect("scenario run");
    compare_golden(name, &report.golden_lines());
}

/// Compare `got` against `tests/golden/<name>.txt`, blessing a missing
/// golden from the current run (`FEDLAY_BLESS=1` re-blesses;
/// `FEDLAY_REQUIRE_GOLDEN=1` turns a missing golden into a failure).
fn compare_golden(name: &str, got: &str) {
    let path = golden_dir().join(format!("{name}.txt"));
    let bless = std::env::var("FEDLAY_BLESS").is_ok();
    if !bless && !path.exists() && std::env::var("FEDLAY_REQUIRE_GOLDEN").is_ok() {
        panic!(
            "golden {} is missing and FEDLAY_REQUIRE_GOLDEN is set.\n\
             Generate it locally with `FEDLAY_BLESS=1 cargo test --test \
             scenario_golden` and commit tests/golden/{name}.txt.",
            path.display()
        );
    }
    if bless || !path.exists() {
        fs::create_dir_all(golden_dir()).expect("create golden dir");
        fs::write(&path, got).expect("write golden");
        if !bless {
            eprintln!(
                "golden {} was missing; blessed the current trajectory — commit it",
                path.display()
            );
        }
        return;
    }
    let want = fs::read_to_string(&path).expect("read golden");
    if want != got {
        panic!("{}", diff_report(name, &want, got));
    }
}

#[test]
fn golden_fig8a_join_wave() {
    let mut spec = ScenarioSpec::fig8a_join_wave(60, 15, 8);
    spec.overlay = overlay();
    spec.net = net(8);
    spec.horizon = 60 * SEC;
    spec.sample_every = 3 * SEC;
    run_golden("fig8a_join_wave", &spec);
}

#[test]
fn golden_fig8b_mass_fail() {
    let mut spec = ScenarioSpec::fig8b_mass_fail(60, 15, 8);
    spec.overlay = overlay();
    spec.net = net(8);
    spec.horizon = 60 * SEC;
    spec.sample_every = 3 * SEC;
    run_golden("fig8b_mass_fail", &spec);
}

#[test]
fn golden_mixed_poisson() {
    let mut spec = ScenarioSpec::poisson_mix(50, 10.0, 40 * SEC, 8);
    spec.overlay = overlay();
    spec.net = net(8);
    spec.sample_every = 5 * SEC;
    run_golden("mixed_poisson", &spec);
}

/// Canonical two-task trainer run: the `two_task_mix` churn scenario
/// drives BOTH tasks of `configs/tasks/two_task_mix.toml` over one
/// overlay, and the snapshot pins the shared correctness series plus
/// each task's accuracy series (the `task=<name> ...` lines). Any drift
/// in the multi-task engine — lane scheduling, task-keyed dedup,
/// per-lane eval streams, churn fan-out across lanes — shows up as a
/// line diff.
#[test]
fn golden_two_task_mix() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..");
    let spec =
        ScenarioSpec::load(&root.join("configs/scenarios/two_task_mix.toml")).expect("scenario");
    let tasks =
        MultiTaskSpec::load(&root.join("configs/tasks/two_task_mix.toml")).expect("tasks");
    let dir = find_artifacts_dir(None).expect("artifacts");
    let engine = Engine::load(&dir, &tasks.model_tasks()).expect("engine");
    let base = DflConfig {
        clients: spec.initial,
        seed: spec.seed,
        ..DflConfig::default()
    };
    let method =
        MethodSpec::fedlay_multi(spec.overlay.clone(), spec.net.clone(), tasks.tasks.len());
    let report =
        multitask::run_scenario(&engine, &spec, &tasks, method, base, false, None).expect("run");
    // acceptance on top of the snapshot: the shared overlay settles to
    // the ideal rings (per-task correctness exactly 1.0) and both tasks
    // produced their own accuracy series
    assert!(report.settled_at.is_some(), "two-task scenario never settled");
    assert!((report.final_correctness - 1.0).abs() < 1e-12);
    assert_eq!(report.task_accuracy.len(), 2);
    for (name, series) in &report.task_accuracy {
        assert!(!series.is_empty(), "task {name} recorded no samples");
    }
    compare_golden("two_task_mix", &report.golden_lines());
}
