//! Property-based task-isolation suite for the multi-task engine
//! (polestar-style seeded sweeps, like `scenario_properties.rs`): each
//! draw builds a random bundle of 2–3 model tasks — mixed models (and
//! therefore mixed parameter dimensionalities), random MEP periods and
//! shard levels — trains them over ONE shared NDMP overlay under churn
//! (a protocol join and a crash failure mid-run), and asserts the
//! isolation invariants:
//!
//!   * **fingerprint provenance** — no parameter vector ever crosses
//!     tasks: the fingerprint sets of the lanes are pairwise disjoint at
//!     every checkpoint (one task's model can never be aggregated into,
//!     or dedup-suppress, another task's);
//!   * **per-task membership arithmetic** — every lane's live count
//!     equals initial + joins − fails, and all lanes agree on every
//!     client's aliveness;
//!   * **per-task overlay correctness** — the shared overlay quiesces to
//!     Definition-1 correctness exactly 1.0, which is every task's
//!     learning topology at once;
//!   * **bit-for-bit isolation** — disabling all lanes but one
//!     reproduces that task's single-task trajectory *bit for bit*:
//!     identical accuracy series (every f64), identical final
//!     parameters (every f32), identical exchange/dedup/byte telemetry.
//!     A lane's trajectory is a pure function of its own `TaskSpec` plus
//!     the shared churn schedule — other lanes contribute nothing.

use fedlay::config::{DflConfig, NetConfig, OverlayConfig, TaskSpec};
use fedlay::dfl::multitask::{lane_weights, WeightTables};
use fedlay::dfl::{MethodSpec, Trainer};
use fedlay::mep::fingerprint;
use fedlay::ndmp::messages::SEC;
use fedlay::runtime::{find_artifacts_dir, Engine};
use fedlay::sim::quiesce;
use fedlay::util::Rng;
use std::collections::HashSet;

const MIN: u64 = 60_000_000; // µs per simulated minute

fn overlay() -> OverlayConfig {
    OverlayConfig {
        spaces: 2,
        heartbeat_ms: 2_000,
        failure_multiple: 3,
        repair_probe_ms: 8_000,
    }
}

fn net(seed: u64) -> NetConfig {
    NetConfig {
        latency_ms: 80.0,
        jitter: 0.2,
        seed,
        ..NetConfig::default()
    }
}

/// Draw one random task: mixed models (mlp: 7k-dim params, lstm: small
/// char model — different dims by construction), random shard level and
/// MEP period, and a seed derived from the lane index so no two lanes
/// are accidental clones.
fn random_task(rng: &mut Rng, idx: usize) -> TaskSpec {
    let model = ["mlp", "lstm"][rng.index(2)];
    TaskSpec {
        name: format!("t{idx}-{model}"),
        task: model.into(),
        shards_per_client: 4 + rng.index(5),
        local_steps: 1,
        lr: 0.5,
        comm_period_ms: (3 + rng.index(4)) as u64 * 60_000, // 3–6 sim min
        seed: 0x5EED ^ ((idx as u64 + 1) << 16) ^ rng.next_u64(),
    }
}

const HORIZON: u64 = 24 * MIN;
const CHECKPOINT: u64 = 12 * MIN;
const SAMPLE: u64 = 6 * MIN;

/// The seeded random churn every run replays: one protocol join (random
/// instant, random bootstrap) and one crash failure (random victim,
/// random later instant). Both the multi-task run and each single-task
/// baseline schedule the identical draw.
#[derive(Clone, Copy)]
struct ChurnDraw {
    join_at: u64,
    bootstrap: usize,
    fail_at: u64,
    victim: usize,
}

impl ChurnDraw {
    fn random(rng: &mut Rng, n: usize) -> Self {
        Self {
            join_at: (5 + rng.index(4) as u64) * MIN + rng.index(777_777) as u64,
            bootstrap: rng.index(n),
            fail_at: (12 + rng.index(6) as u64) * MIN + rng.index(777_777) as u64,
            victim: rng.index(n),
        }
    }
}

/// Build a trainer over `tasks` (with per-lane weight tables covering
/// `n + 1` clients) and schedule the churn draw — the caller runs it in
/// checkpointed chunks.
fn build_and_schedule<'e>(
    engine: &'e Engine,
    tasks: &[TaskSpec],
    n: usize,
    seed: u64,
    churn: ChurnDraw,
) -> anyhow::Result<(Trainer<'e>, WeightTables)> {
    let method = MethodSpec::fedlay_multi(overlay(), net(seed), tasks.len());
    let mut lanes = Vec::new();
    let mut tables = Vec::new();
    for t in tasks {
        let table = lane_weights(engine, t, n + 1)?;
        lanes.push((t.clone(), table[..n].to_vec()));
        tables.push(table);
    }
    let cfg = DflConfig {
        clients: n,
        seed,
        ..DflConfig::default()
    };
    let mut trainer = Trainer::new_multi(engine, method, cfg, lanes)?;
    let joiner_w: Vec<Vec<f64>> = tables.iter().map(|t| t[n].clone()).collect();
    let id = trainer.schedule_join_tasks(churn.join_at, joiner_w, churn.bootstrap)?;
    assert_eq!(id, n);
    trainer.schedule_fail(churn.fail_at, churn.victim);
    Ok((trainer, tables))
}

/// All parameter fingerprints of one lane's clients.
fn lane_fps(trainer: &Trainer, lane: usize) -> HashSet<u64> {
    trainer.lanes[lane]
        .clients
        .iter()
        .map(|c| fingerprint(&c.params))
        .collect()
}

/// Fingerprint provenance: the lanes' fingerprint sets must be pairwise
/// disjoint — a shared fingerprint would mean a parameter vector crossed
/// tasks.
fn assert_disjoint(sets: &[HashSet<u64>], when: &str) {
    for a in 0..sets.len() {
        for b in a + 1..sets.len() {
            let crossed: Vec<&u64> = sets[a].intersection(&sets[b]).collect();
            assert!(
                crossed.is_empty(),
                "{when}: parameter vectors crossed between lanes {a} and {b}: {crossed:?}"
            );
        }
    }
}

#[test]
fn property_random_task_bundles_stay_isolated_under_churn() -> anyhow::Result<()> {
    let n = 8usize;
    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &["mlp", "lstm"])?;
    for seed in 0..3u64 {
        let mut rng = Rng::new(seed ^ 0x3A5C);
        let k = 2 + rng.index(2); // 2–3 tasks
        let tasks: Vec<TaskSpec> = (0..k).map(|i| random_task(&mut rng, i)).collect();
        let churn = ChurnDraw::random(&mut rng, n);

        // ---- the multi-task run, stepped in two chunks so provenance
        // and membership are checked mid-flight, not just at the end
        let (mut multi, tables) = build_and_schedule(&engine, &tasks, n, seed, churn)?;
        multi.run(CHECKPOINT, SAMPLE)?;
        let mut fp_sets: Vec<HashSet<u64>> = (0..k).map(|l| lane_fps(&multi, l)).collect();
        assert_disjoint(&fp_sets, "checkpoint");
        multi.run(HORIZON, SAMPLE)?;
        for (l, set) in fp_sets.iter_mut().enumerate() {
            set.extend(lane_fps(&multi, l));
        }
        assert_disjoint(&fp_sets, "horizon");

        // ---- per-task membership arithmetic: every lane sees
        // initial + 1 join - 1 fail live clients, and the lanes agree
        // on each client's aliveness
        for (l, lane) in multi.lanes.iter().enumerate() {
            assert_eq!(
                lane.clients.len(),
                n + 1,
                "seed {seed}: lane {l} lost the joiner placeholder"
            );
            let live = lane.clients.iter().filter(|c| c.alive).count();
            assert_eq!(live, n + 1 - 1, "seed {seed}: lane {l} membership drifted");
            assert!(lane.clients[n].alive, "seed {seed}: lane {l} joiner dead");
            assert!(
                !lane.clients[churn.victim].alive,
                "seed {seed}: lane {l} zombie victim {}",
                churn.victim
            );
            let flags: Vec<bool> = lane.clients.iter().map(|c| c.alive).collect();
            let flags0: Vec<bool> = multi.lanes[0].clients.iter().map(|c| c.alive).collect();
            assert_eq!(flags, flags0, "seed {seed}: lanes disagree on aliveness");
            // every lane actually trained and exchanged
            assert!(
                lane.clients.iter().any(|c| c.exchanges > 0),
                "seed {seed}: lane {l} never aggregated"
            );
        }

        // ---- the shared overlay (every task's topology) quiesces to
        // Definition-1 correctness exactly 1.0
        {
            let sim = multi.overlay.as_mut().expect("dynamic overlay");
            let deadline = sim.now + 240 * SEC;
            assert!(
                quiesce(sim, deadline, SEC).is_some(),
                "seed {seed}: overlay never quiesced (c={})",
                sim.correctness()
            );
            assert!((sim.correctness() - 1.0).abs() < 1e-12);
        }

        // ---- bit-for-bit isolation: re-run every lane alone (same
        // spec, same weights, same churn schedule, same chunking) and
        // compare the whole trajectory exactly
        for (l, task) in tasks.iter().enumerate() {
            let mut single = {
                let cfg = DflConfig {
                    clients: n,
                    seed,
                    ..DflConfig::default()
                };
                let lanes = vec![(task.clone(), tables[l][..n].to_vec())];
                Trainer::new_multi(
                    &engine,
                    MethodSpec::fedlay_dynamic(overlay(), net(seed)),
                    cfg,
                    lanes,
                )?
            };
            single.schedule_join(churn.join_at, tables[l][n].clone(), churn.bootstrap)?;
            single.schedule_fail(churn.fail_at, churn.victim);
            single.run(CHECKPOINT, SAMPLE)?;
            single.run(HORIZON, SAMPLE)?;

            let a = &multi.lanes[l];
            let b = &single.lanes[0];
            assert_eq!(
                a.samples.len(),
                b.samples.len(),
                "seed {seed} lane {l}: sample counts diverged"
            );
            for (sa, sb) in a.samples.iter().zip(&b.samples) {
                assert_eq!(sa.at, sb.at, "seed {seed} lane {l}: sample times diverged");
                assert!(
                    sa.mean_accuracy == sb.mean_accuracy
                        && sa.mean_loss == sb.mean_loss
                        && sa.per_client == sb.per_client,
                    "seed {seed} lane {l}: trajectory diverged at t={} \
                     ({} vs {})",
                    sa.at,
                    sa.mean_accuracy,
                    sb.mean_accuracy
                );
            }
            for (ca, cb) in a.clients.iter().zip(&b.clients) {
                assert!(
                    ca.params == cb.params,
                    "seed {seed} lane {l}: final params diverged for client {}",
                    ca.id
                );
                assert_eq!(ca.exchanges, cb.exchanges, "seed {seed} lane {l}");
                assert_eq!(ca.dedup_skips, cb.dedup_skips, "seed {seed} lane {l}");
                assert_eq!(ca.model_bytes_sent, cb.model_bytes_sent, "seed {seed} lane {l}");
                assert_eq!(ca.train_steps, cb.train_steps, "seed {seed} lane {l}");
            }
            // the acceptance bound (≤ 0.02 of baseline) is the loose form
            // of the exact equality above
            let ma = a.samples.last().unwrap().mean_accuracy;
            let sa = b.samples.last().unwrap().mean_accuracy;
            assert!((ma - sa).abs() <= 0.02);
        }
    }
    Ok(())
}

/// Sanity for the legacy constructor: `Trainer::new` is the one-lane
/// special case of the multi-task engine — same lane count, same spec
/// derivation, same clients.
#[test]
fn single_task_constructor_is_the_one_lane_special_case() -> anyhow::Result<()> {
    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &["mlp"])?;
    let cfg = DflConfig {
        clients: 6,
        ..DflConfig::default()
    };
    let w = fedlay::data::shard_labels(6, 10, cfg.shards_per_client, cfg.seed);
    let t = Trainer::new(&engine, MethodSpec::fedlay(6, 2), cfg.clone(), w)?;
    assert_eq!(t.lanes.len(), 1);
    assert_eq!(t.lanes[0].spec, TaskSpec::from_dfl(&cfg));
    assert_eq!(t.clients().len(), 6);
    assert_eq!(t.task_name(), "mlp");
    Ok(())
}

/// Multi-task guardrails: synchronous/centralized methods cannot carry
/// more than one lane, duplicate lane names are rejected, and
/// single-task joins are refused on multi-task trainers.
#[test]
fn multi_task_constructor_guardrails() -> anyhow::Result<()> {
    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &["mlp"])?;
    let cfg = DflConfig {
        clients: 4,
        ..DflConfig::default()
    };
    let mk_task = |name: &str, seed: u64| TaskSpec {
        name: name.into(),
        task: "mlp".into(),
        shards_per_client: 8,
        local_steps: 1,
        lr: 0.5,
        comm_period_ms: 60_000,
        seed,
    };
    let w = fedlay::data::shard_labels(4, 10, 8, 1);
    let two =
        |a: &str, b: &str| vec![(mk_task(a, 1), w.clone()), (mk_task(b, 2), w.clone())];
    // centralized rounds cannot host two lanes
    let central = Trainer::new_multi(&engine, MethodSpec::fedavg(), cfg.clone(), two("a", "b"));
    assert!(central.is_err());
    // duplicate names are ambiguous in every report
    let dup = Trainer::new_multi(
        &engine,
        MethodSpec::fedlay_multi(overlay(), net(1), 2),
        cfg.clone(),
        two("a", "a"),
    );
    assert!(dup.is_err());
    // a valid two-lane trainer refuses the single-task join API
    let mut t = Trainer::new_multi(
        &engine,
        MethodSpec::fedlay_multi(overlay(), net(1), 2),
        cfg,
        two("a", "b"),
    )?;
    assert!(t.schedule_join(1, vec![1.0; 10], 0).is_err());
    let join = t.schedule_join_tasks(1, vec![vec![1.0; 10], vec![1.0; 10]], 0);
    assert!(join.is_ok());
    Ok(())
}
