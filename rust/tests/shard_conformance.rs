//! Shard-determinism conformance: a K-shard run of the discrete-event
//! engine must be *bitwise-identical* to the serial K=1 run. Sharding is
//! a wall-clock knob, never a semantics knob — every observable (the
//! golden trajectory lines, the final ring snapshot, the delivery and
//! control counters) has to match exactly, because the merge barrier
//! replays global effects in producer-seq order (docs/perf.md).
//!
//! Alongside the determinism battery sits the live-state footprint
//! regression: under long churn the engine's memory must stay bounded by
//! the *peak live set* (arena slot recycling) plus small scheduler
//! bookkeeping, never by churn history (retired nodes fold into scalar
//! tallies).

use fedlay::config::{NetConfig, OverlayConfig};
use fedlay::ndmp::messages::{MS, SEC};
use fedlay::sim::{ChurnCounts, Phase, PhaseKind, ScenarioSpec};
use fedlay::topology::NeighborSnapshot;
use fedlay::util::Rng;
use std::path::PathBuf;

/// Run `spec` with `k` shards; return every observable the battery pins.
fn observables(spec: &ScenarioSpec, k: usize) -> (String, NeighborSnapshot, u64, f64) {
    let mut s = spec.clone();
    s.shards = k;
    let (sim, report) = s.run_sim(None).expect("scenario run");
    let per_node = sim.control_messages_per_node();
    (report.golden_lines(), sim.snapshot(), sim.delivered, per_node)
}

fn assert_identical(spec: &ScenarioSpec, ks: &[usize]) {
    let baseline = observables(spec, 1);
    for &k in ks {
        let got = observables(spec, k);
        assert_eq!(got.0, baseline.0, "{}: golden lines diverged at K={k}", spec.name);
        assert_eq!(got.1, baseline.1, "{}: ring snapshot diverged at K={k}", spec.name);
        assert_eq!(got.2, baseline.2, "{}: delivery count diverged at K={k}", spec.name);
        assert_eq!(got.3, baseline.3, "{}: control tally diverged at K={k}", spec.name);
    }
}

/// The pinned CI scenario (non-zero latency, join wave + crash burst)
/// at K = 4 and K = 16 — including K > live nodes in some arcs.
#[test]
fn latency_mix_is_bitwise_identical_across_shard_counts() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..");
    let spec =
        ScenarioSpec::load(&root.join("configs/scenarios/latency_mix.toml")).expect("scenario");
    assert_identical(&spec, &[4, 16]);
}

/// Random small scenario for the property sweep: mixed churn phases at
/// CI-friendly sizes (mirrors scenario_properties::random_spec).
fn random_spec(seed: u64) -> ScenarioSpec {
    let mut rng = Rng::new(seed ^ 0x51A2D);
    let initial = 12 + rng.index(10);
    let n_phases = 1 + rng.index(3);
    let mut phases = Vec::new();
    for p in 0..n_phases as u64 {
        let at = (2 + 5 * p) * SEC + rng.index(1500) as u64 * MS;
        let kind = match rng.index(5) {
            0 => PhaseKind::MassJoin {
                count: 2 + rng.index(4),
            },
            1 => PhaseKind::MassFail {
                count: 2 + rng.index(3),
            },
            2 => PhaseKind::MassLeave {
                count: 2 + rng.index(3),
            },
            3 => PhaseKind::FlashCrowd {
                count: 2 + rng.index(3),
                dwell: (4 + rng.index(6) as u64) * SEC,
            },
            _ => PhaseKind::PoissonChurn {
                join_per_min: 2.0 + rng.next_f64() * 5.0,
                fail_per_min: 1.0 + rng.next_f64() * 3.0,
                leave_per_min: rng.next_f64(),
                window: (8 + rng.index(8) as u64) * SEC,
            },
        };
        phases.push(Phase { at, kind });
    }
    ScenarioSpec {
        name: format!("shard-prop-{seed}"),
        initial,
        seed,
        horizon: 25 * SEC,
        sample_every: 5 * SEC,
        settle: 0,
        min_live: 4,
        shards: 1,
        overlay: OverlayConfig {
            spaces: 2 + rng.index(2),
            heartbeat_ms: 500,
            failure_multiple: 3,
            repair_probe_ms: 2_000,
        },
        net: NetConfig {
            latency_ms: 40.0 + rng.next_f64() * 100.0,
            jitter: 0.2,
            seed,
            ..NetConfig::default()
        },
        phases,
    }
}

/// Property sweep: random specs × random shard counts, every observable
/// identical to the serial run.
#[test]
fn property_random_specs_identical_for_random_shard_counts() {
    for seed in 0..6u64 {
        let spec = random_spec(seed);
        let mut rng = Rng::new(seed ^ 0xC0DE);
        let ks = [2 + rng.index(7), 2 + rng.index(15)];
        assert_identical(&spec, &ks);
    }
}

/// Deterministic slot-recycling bound: six alternating join/fail waves
/// churn 3x the initial population through the overlay, but live
/// membership never exceeds `initial + wave`, so the arena must never
/// allocate past that peak (plus nothing — slots are recycled exactly).
#[test]
fn arena_slots_are_bounded_by_peak_live_set_under_wave_churn() {
    let initial = 24;
    let wave = 20;
    let mut phases = Vec::new();
    for w in 0..3u64 {
        phases.push(Phase {
            at: (5 + 40 * w) * SEC,
            kind: PhaseKind::MassJoin { count: wave },
        });
        phases.push(Phase {
            at: (25 + 40 * w) * SEC,
            kind: PhaseKind::MassFail { count: wave },
        });
    }
    let spec = ScenarioSpec {
        name: "wave-footprint".into(),
        initial,
        seed: 7,
        horizon: 125 * SEC,
        sample_every: 0,
        settle: 0,
        min_live: 4,
        shards: 4,
        overlay: OverlayConfig {
            spaces: 2,
            heartbeat_ms: 500,
            failure_multiple: 3,
            repair_probe_ms: 2_000,
        },
        net: NetConfig {
            latency_ms: 50.0,
            jitter: 0.2,
            seed: 7,
            ..NetConfig::default()
        },
        phases,
    };
    let (sim, report) = spec.run_sim(None).expect("scenario run");
    assert_eq!(report.counts.joins, 3 * wave);
    assert_eq!(report.counts.fails, 3 * wave);
    let fp = sim.footprint();
    assert_eq!(fp.retired_nodes, (3 * wave) as u64, "every failed node retires");
    assert!(
        fp.arena_slots <= initial + wave,
        "arena grew past the peak live set: {} slots for peak {} \
         (slot recycling regressed to O(churn history))",
        fp.arena_slots,
        initial + wave
    );
    // retired counters fold into scalars, so the per-node tally still
    // accounts for all 60 departed nodes without holding their state
    assert!(sim.control_messages_per_node() > 0.0);
}

/// Long balanced Poisson churn: ~100 joins and ~100 fails stream through
/// a 24-node overlay. Live membership is a bounded random walk, so the
/// arena stays far below the churn volume, and scheduler bookkeeping
/// (the windowed tombstone bitmaps) stays in the kilobytes.
#[test]
fn footprint_stays_bounded_under_long_poisson_churn() {
    let spec = ScenarioSpec {
        name: "poisson-footprint".into(),
        initial: 24,
        seed: 11,
        horizon: 150 * SEC,
        sample_every: 0,
        settle: 0,
        min_live: 4,
        shards: 1,
        overlay: OverlayConfig {
            spaces: 2,
            heartbeat_ms: 500,
            failure_multiple: 3,
            repair_probe_ms: 2_000,
        },
        net: NetConfig {
            latency_ms: 50.0,
            jitter: 0.2,
            seed: 11,
            ..NetConfig::default()
        },
        phases: vec![Phase {
            at: 2 * SEC,
            kind: PhaseKind::PoissonChurn {
                join_per_min: 40.0,
                fail_per_min: 40.0,
                leave_per_min: 0.0,
                window: 145 * SEC,
            },
        }],
    };
    let events = spec.compile();
    let counts = ChurnCounts::of(&events);
    assert!(counts.joins >= 60, "draw too small to exercise recycling");
    let (sim, _report) = spec.run_sim(None).expect("scenario run");
    let fp = sim.footprint();
    assert_eq!(fp.retired_nodes, (counts.fails + counts.leaves) as u64);
    // the walk-peak bound: churn volume is ~4x the initial population,
    // but the live set only drifts by its random-walk excursion
    assert!(
        fp.arena_slots < spec.initial + (3 * counts.joins) / 4,
        "arena slots {} approach churn volume {} (live-set bound lost)",
        fp.arena_slots,
        spec.initial + counts.joins
    );
    assert!(
        fp.queue_bookkeeping_bytes < 256 * 1024,
        "scheduler bookkeeping ballooned to {} bytes",
        fp.queue_bookkeeping_bytes
    );
}
