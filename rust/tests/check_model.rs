//! Exhaustive model-checking battery (tier: exhaustive).
//!
//! Clean sweeps: the unmodified NDMP protocol, explored over its full
//! interleaving space for small universes, has zero safety violations,
//! zero deadlocks, and converges from every reachable state once churn
//! stops. Mutation battery: each known-critical repair line, broken via
//! the test-only `Mutation` hook, is caught by the explorer with a
//! minimal replayable counterexample of the expected property class —
//! the proof that the checker can actually find bugs.

use fedlay::check::{explore, mutations, ExploreLimits, ModelConfig, ViolationKind};
use fedlay::check::{format_schedule, parse_schedule};
use fedlay::ndmp::Mutation;

fn clean(n: usize, spaces: usize, joins: usize, fails: usize, leaves: usize) -> ModelConfig {
    ModelConfig {
        n,
        spaces,
        joins,
        fails,
        leaves,
        mutation: Mutation::None,
    }
}

#[test]
fn clean_protocol_n3_single_space_full_churn() {
    let cfg = clean(3, 1, 1, 1, 1);
    let report = explore(&cfg, &ExploreLimits::default()).unwrap();
    assert!(!report.truncated, "n=3 L=1 must be exhaustible");
    assert!(report.liveness_checked);
    assert!(
        report.ok(),
        "violations on the clean protocol: {:#?}",
        report.counterexamples
    );
    assert!(report.converged_states >= 1);
    assert!(report.dedup_hits > 0, "commuting interleavings must dedup");
}

// the L=2 full-churn space is orders of magnitude larger than L=1 —
// swept in release by the CI model-check step, not the debug tier
#[test]
#[ignore = "release-budget sweep; run by the CI model-check step"]
fn clean_protocol_n3_two_spaces_full_churn() {
    let cfg = clean(3, 2, 1, 1, 1);
    let report = explore(&cfg, &ExploreLimits::default()).unwrap();
    assert!(!report.truncated, "n=3 L=2 must be exhaustible");
    assert!(
        report.ok(),
        "violations on the clean protocol: {:#?}",
        report.counterexamples
    );
}

#[test]
fn clean_protocol_on_every_detection_config() {
    // every mutation's guaranteed-detection scenario must be silent when
    // the mutation is NOT installed — otherwise detection proves nothing
    for m in mutations::ALL {
        let cfg = ModelConfig {
            mutation: Mutation::None,
            ..mutations::detection_config(m)
        };
        let report = explore(&cfg, &ExploreLimits::default()).unwrap();
        assert!(!report.truncated);
        assert!(
            report.ok(),
            "clean sweep of {}'s detection config found: {:#?}",
            mutations::name(m),
            report.counterexamples
        );
    }
}

#[test]
fn every_mutation_is_caught_with_the_expected_kind() {
    for m in mutations::ALL {
        let cfg = mutations::detection_config(m);
        let report = explore(&cfg, &ExploreLimits::default()).unwrap();
        assert!(!report.truncated, "{}: sweep truncated", mutations::name(m));
        assert!(
            !report.ok(),
            "mutation {} was not detected",
            mutations::name(m)
        );
        let first = &report.counterexamples[0];
        assert_eq!(
            first.kind,
            mutations::expected_kind(m),
            "mutation {} caught with the wrong property class",
            mutations::name(m)
        );
        // the counterexample is minimal *and* replayable: it parses back
        // from its own text rendering
        let text = format_schedule(&first.schedule);
        assert_eq!(parse_schedule(&text).unwrap(), first.schedule);
        assert!(
            first.depth as usize == first.schedule.len(),
            "depth must equal schedule length"
        );
    }
}

#[test]
fn safety_mutation_reports_the_violated_invariant() {
    let report = explore(
        &mutations::detection_config(Mutation::AdoptUntracked),
        &ExploreLimits::default(),
    )
    .unwrap();
    let safety = report
        .counterexamples
        .iter()
        .find(|c| c.kind == ViolationKind::Safety)
        .expect("adopt-untracked must yield a safety counterexample");
    assert!(
        safety
            .violations
            .iter()
            .any(|v| v.invariant == "view-not-tracked"),
        "expected view-not-tracked, got {:?}",
        safety.violations
    );
}

#[test]
fn liveness_mutations_strand_but_never_corrupt() {
    // the three liveness mutations leave the network unable to heal, but
    // every *reachable* state stays safe — the checker distinguishes the
    // two property classes instead of lumping everything together
    for m in [
        Mutation::NoRepairProbes,
        Mutation::AdoptFarther,
        Mutation::RepairSidesFlipped,
    ] {
        let report = explore(&mutations::detection_config(m), &ExploreLimits::default()).unwrap();
        assert_eq!(
            report.safety_violation_count,
            0,
            "{}: unexpected safety violation",
            mutations::name(m)
        );
        assert!(
            report.liveness_violation_count > 0,
            "{}: no liveness violation found",
            mutations::name(m)
        );
    }
}

#[test]
fn state_cap_reports_truncation_not_violations() {
    let cfg = clean(4, 2, 1, 1, 1);
    let report = explore(
        &cfg,
        &ExploreLimits {
            max_depth: 0,
            max_states: 500,
        },
    )
    .unwrap();
    assert!(report.truncated);
    assert!(!report.liveness_checked);
    assert!(report.states <= 500);
    assert!(report.ok(), "a capped sweep must not invent violations");
}
