//! Refinement between the abstract model and the concrete engine
//! (tier: exhaustive).
//!
//! Two directions:
//!
//! * schedules the explorer sampled on the *clean* protocol replay
//!   through the real `sim::Simulator` and land on a converged overlay
//!   satisfying the shared invariant battery — the abstract convergence
//!   verdict holds concretely;
//! * the pinned mutation counterexample (`fixtures/mutation_noprobes.schedule`)
//!   reproduces the same defect concretely: under the mutation the
//!   simulator never quiesces and the final state violates the shared
//!   invariants; without it the identical churn converges cleanly.

use fedlay::check::{
    explore, mutations, parse_schedule, replay_abstract, replay_concrete, ExploreLimits,
    ModelConfig, ViolationKind,
};
use fedlay::check::explore::churn_free_converges;
use fedlay::ndmp::Mutation;

#[test]
fn clean_sampled_schedules_replay_concretely() {
    let cfg = ModelConfig {
        n: 3,
        spaces: 2,
        joins: 1,
        fails: 1,
        leaves: 0,
        mutation: Mutation::None,
    };
    let report = explore(&cfg, &ExploreLimits::default()).unwrap();
    assert!(report.ok() && !report.truncated);
    assert!(!report.schedules.is_empty());
    for schedule in &report.schedules {
        // abstractly: the sampled state (or any state, after the churn
        // in the schedule) still converges without further churn
        let m = replay_abstract(&cfg, schedule);
        assert!(
            churn_free_converges(&m, 200_000),
            "abstract state after {schedule:?} cannot converge"
        );
        // concretely: the same churn through the real simulator reaches
        // a correct overlay satisfying the shared invariant battery
        let concrete = replay_concrete(&cfg, schedule);
        assert!(
            concrete.converged,
            "concrete replay of {schedule:?} did not quiesce"
        );
        assert!(
            concrete.violations.is_empty(),
            "concrete replay of {schedule:?} violated: {:?}",
            concrete.violations
        );
        assert!(
            (concrete.correctness - 1.0).abs() < 1e-12,
            "correctness {} != 1.0",
            concrete.correctness
        );
    }
}

#[test]
fn pinned_noprobes_counterexample_is_current_and_replays() {
    let text = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/mutation_noprobes.schedule"
    ))
    .unwrap();
    let pinned = parse_schedule(&text).unwrap();

    // the fixture is exactly what the explorer reports today: first
    // liveness counterexample under the guaranteed-detection config
    let cfg = mutations::detection_config(Mutation::NoRepairProbes);
    let report = explore(&cfg, &ExploreLimits::default()).unwrap();
    let first = report
        .counterexamples
        .iter()
        .find(|c| c.kind == ViolationKind::Liveness)
        .expect("no-probes must yield a liveness counterexample");
    assert_eq!(
        first.schedule, pinned,
        "explorer's minimal counterexample drifted from the pinned fixture \
         — regenerate tests/fixtures/mutation_noprobes.schedule"
    );

    // abstract replay: the post-schedule state can never converge
    let stranded = replay_abstract(&cfg, &pinned);
    assert!(
        !churn_free_converges(&stranded, 200_000),
        "pinned schedule no longer strands the abstract model"
    );

    // concrete replay under the mutation: same defect in the real engine
    let broken = replay_concrete(&cfg, &pinned);
    assert!(
        !broken.converged,
        "mutated simulator quiesced despite the missing repair probes"
    );
    assert!(
        !broken.violations.is_empty(),
        "mutated simulator final state unexpectedly satisfies all invariants"
    );

    // control: identical churn without the mutation heals completely
    let clean_cfg = ModelConfig {
        mutation: Mutation::None,
        ..cfg
    };
    let healed = replay_concrete(&clean_cfg, &pinned);
    assert!(healed.converged, "clean replay failed to quiesce");
    assert!(
        healed.violations.is_empty(),
        "clean replay violated: {:?}",
        healed.violations
    );
}
