//! Model-based property tests over the scenario engine (polestar-style:
//! proptest is not in the vendored set, so these are seeded sweeps with
//! explicit shrinking). Each draw generates a random `ScenarioSpec` —
//! arbitrary mixes of mass joins/failures/leaves, flash crowds, Poisson
//! churn, and partition bursts — runs it on the overlay simulator, and
//! asserts the NDMP invariants after quiescence:
//!
//!   * the live membership equals the compiled schedule's arithmetic
//!     (initial + joins − fails − leaves; no lost joiners, no zombies),
//!   * Definition-1 ring correctness is exactly 1.0 and the ring views
//!     match the ideal overlay of the survivors,
//!   * neighbor sets are symmetric and degree-bounded (≤ 2L),
//!   * no node retains a ghost entry for a failed or departed node.
//!
//! On failure the spec is shrunk by deleting phases while the failure
//! reproduces, and the minimal spec is reported as runnable TOML.

use fedlay::config::{NetConfig, OverlayConfig};
use fedlay::ndmp::messages::{MS, SEC};
use fedlay::sim::invariants;
use fedlay::sim::{quiesce, ChurnCounts, ChurnOp, Phase, PhaseKind, ScenarioSpec};
use fedlay::topology::NodeId;
use fedlay::util::Rng;
use std::collections::BTreeSet;

/// Draw a random scenario: 14–25 initial nodes, 2–3 spaces, 1–3 phases
/// over the full kind vocabulary, at sizes small enough for CI.
fn random_spec(seed: u64) -> ScenarioSpec {
    let mut rng = Rng::new(seed ^ 0x5EED);
    let initial = 14 + rng.index(12);
    let spaces = 2 + rng.index(2);
    let n_phases = 1 + rng.index(3);
    let mut phases = Vec::new();
    for p in 0..n_phases as u64 {
        let at = (2 + 6 * p) * SEC + rng.index(2000) as u64 * MS;
        let kind = match rng.index(6) {
            0 => PhaseKind::MassJoin {
                count: 2 + rng.index(5),
            },
            1 => PhaseKind::MassFail {
                count: 2 + rng.index(4),
            },
            2 => PhaseKind::MassLeave {
                count: 2 + rng.index(4),
            },
            3 => PhaseKind::FlashCrowd {
                count: 2 + rng.index(4),
                dwell: (4 + rng.index(8) as u64) * SEC,
            },
            4 => PhaseKind::PoissonChurn {
                join_per_min: 2.0 + rng.next_f64() * 6.0,
                fail_per_min: 1.0 + rng.next_f64() * 3.0,
                leave_per_min: rng.next_f64() * 2.0,
                window: (10 + rng.index(10) as u64) * SEC,
            },
            _ => PhaseKind::Partition {
                fraction: 0.1 + rng.next_f64() * 0.15,
            },
        };
        phases.push(Phase { at, kind });
    }
    ScenarioSpec {
        name: format!("prop-{seed}"),
        initial,
        seed,
        horizon: 30 * SEC,
        sample_every: 0,
        settle: 0,
        min_live: (initial / 2).max(4),
        shards: 1,
        overlay: OverlayConfig {
            spaces,
            heartbeat_ms: 500,
            failure_multiple: 3,
            repair_probe_ms: 2_000,
        },
        net: NetConfig {
            latency_ms: 60.0,
            jitter: 0.2,
            seed,
            ..NetConfig::default()
        },
        phases,
    }
}

/// Run one spec and verify every invariant; `Err` carries a readable
/// description of the first violation.
fn check(spec: &ScenarioSpec) -> Result<(), String> {
    // the engine itself must run past the whole compiled schedule (even
    // Poisson tails spilling past the horizon) — no manual extension here
    let events = spec.compile();
    let counts = ChurnCounts::of(&events);
    let (mut sim, report) = spec.run_sim(None).map_err(|e| e.to_string())?;
    if report.counts != counts {
        return Err("report/schedule churn counts disagree".into());
    }

    // quiesce: rings must converge to the ideal overlay of the survivors
    let deadline = sim.now + 420 * SEC;
    if quiesce(&mut sim, deadline, 2 * SEC).is_none() {
        return Err(format!(
            "no quiescence by t={}s: correctness {:.4}, {} live",
            sim.now / SEC,
            sim.correctness(),
            sim.live_count()
        ));
    }

    // membership arithmetic: exactly the scheduled joins entered, exactly
    // the scheduled fails/leaves left (shared `sim::invariants` battery —
    // the exhaustive model checker asserts the same predicates)
    let mut expected: BTreeSet<NodeId> = (0..spec.initial as NodeId).collect();
    let mut removed: BTreeSet<NodeId> = BTreeSet::new();
    for e in &events {
        match e.op {
            ChurnOp::Join { node, .. } => {
                expected.insert(node);
            }
            ChurnOp::Fail { node } | ChurnOp::Leave { node } => {
                expected.remove(&node);
                removed.insert(node);
            }
        }
    }
    let live: BTreeSet<NodeId> = sim.node_ids().into_iter().collect();
    if let Some(v) = invariants::membership_violations(&live, &expected).first() {
        return Err(format!(
            "{v} (initial {} + {} joins - {} fails - {} leaves)",
            spec.initial, counts.joins, counts.fails, counts.leaves
        ));
    }

    // correctness exactly 1.0, then the full converged-ring battery:
    // degree ≤ 2L, no ghosts, symmetric links, ring ≡ ideal
    let correctness = sim.correctness();
    if (correctness - 1.0).abs() > 1e-12 {
        return Err(format!("ring correctness {correctness:.6} != 1.0"));
    }
    if let Some(v) =
        invariants::converged_ring_violations(&sim.ring_snapshot(), spec.overlay.spaces).first()
    {
        return Err(v.to_string());
    }

    // ghost entries for departed nodes must also drain from the peer
    // tables (failure detection purges them after 3 silent heartbeats)
    sim.run_until(sim.now + 10_000 * MS);
    for (id, nbrs) in sim.snapshot() {
        if let Some(g) = nbrs.iter().find(|n| removed.contains(n)) {
            return Err(format!("node {id} still references departed node {g}"));
        }
    }
    if let Some(v) =
        invariants::converged_ring_violations(&sim.ring_snapshot(), spec.overlay.spaces).first()
    {
        return Err(format!("after settle window: {v}"));
    }
    Ok(())
}

/// Delete phases one at a time while the failure still reproduces.
fn shrink(spec: &ScenarioSpec) -> ScenarioSpec {
    let mut cur = spec.clone();
    loop {
        let mut reduced = None;
        if cur.phases.len() > 1 {
            for i in 0..cur.phases.len() {
                let mut cand = cur.clone();
                cand.phases.remove(i);
                if check(&cand).is_err() {
                    reduced = Some(cand);
                    break;
                }
            }
        }
        match reduced {
            Some(c) => cur = c,
            None => return cur,
        }
    }
}

#[test]
fn property_random_scenarios_restore_ndmp_invariants() {
    for seed in 0..5u64 {
        let spec = random_spec(seed);
        if let Err(msg) = check(&spec) {
            let minimal = shrink(&spec);
            let err = check(&minimal).err().unwrap_or(msg);
            panic!(
                "seed {seed}: NDMP invariant violated: {err}\n\
                 minimal failing spec (save and replay with \
                 `fedlay scenario run`):\n{}",
                minimal.to_toml()
            );
        }
    }
}

#[test]
fn property_compile_is_deterministic_and_round_trips() {
    for seed in 0..20u64 {
        let spec = random_spec(seed);
        assert_eq!(spec.compile(), spec.compile(), "seed {seed}: nondeterministic");
        let back = ScenarioSpec::from_toml_str(&spec.to_toml())
            .unwrap_or_else(|e| panic!("seed {seed}: round trip parse failed: {e}"));
        assert_eq!(spec, back, "seed {seed}: spec changed across TOML round trip");
        assert_eq!(
            spec.compile(),
            back.compile(),
            "seed {seed}: schedule changed across TOML round trip"
        );
    }
}
