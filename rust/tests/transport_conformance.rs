//! Deterministic-vs-socket conformance: an identical seeded churn
//! schedule must replay identically on the in-memory simulated
//! transport and on the real TCP transport (localhost sockets). This is
//! the paper's practicality claim in executable form — NDMP constructs
//! and maintains the same near-random regular topology whether messages
//! are heap events or real frames (§IV-A1 types 1–3).
//!
//! Since virtual latency flows through the socket path (frames carry
//! their virtual send time + sampled per-link delay, released into the
//! scheduler at exactly that instant — see `docs/transports.md`), the
//! comparison is *timing-exact*: both backends must produce the
//! identical per-message arrival timestamps and delivery counts, the
//! identical ring-adjacency snapshots, and — through a training run —
//! the bitwise-identical accuracy series, with non-zero link latency.

use fedlay::config::{DflConfig, MultiTaskSpec, NetConfig, OverlayConfig};
use fedlay::data::shard_labels;
use fedlay::dfl::{multitask, MethodSpec, Trainer};
use fedlay::net::SchedTransport;
use fedlay::ndmp::messages::{Time, SEC};
use fedlay::runtime::{find_artifacts_dir, Engine};
use fedlay::sim::{
    ChurnCounts, ChurnOp, Phase, PhaseKind, ScenarioReport, ScenarioSpec, Simulator, Transport,
};
use fedlay::topology::{Membership, NeighborSnapshot, NodeId};
use std::path::PathBuf;

const SPACES: usize = 2;

fn overlay() -> OverlayConfig {
    OverlayConfig {
        spaces: SPACES,
        heartbeat_ms: 600,
        failure_multiple: 3,
        repair_probe_ms: 2_400,
    }
}

fn net() -> NetConfig {
    NetConfig {
        latency_ms: 30.0,
        jitter: 0.2,
        seed: 13,
        ..NetConfig::default()
    }
}

/// Ideal Definition-1 neighbor sets of a membership — the ground truth
/// both backends must converge to.
fn ideal_snapshot(ids: &[NodeId], spaces: usize) -> NeighborSnapshot {
    let mut m = Membership::new(spaces);
    for &id in ids {
        m.add(id);
    }
    ids.iter().map(|&id| (id, m.correct_neighbors(id))).collect()
}

/// Advance `sim` until its ring views equal the ideal overlay of its
/// live membership (stronger than correctness 1.0: no stale pointers at
/// all). Panics if `deadline` passes first.
fn settle_exact(sim: &mut Simulator, deadline: Time) {
    loop {
        sim.run_until(sim.now + 2 * SEC);
        let live: Vec<NodeId> = sim.node_ids();
        if sim.ring_snapshot() == ideal_snapshot(&live, sim.cfg.spaces) {
            return;
        }
        assert!(
            sim.now < deadline,
            "backend {:?} did not converge to the ideal overlay by t={}s: correctness={}",
            sim.backend(),
            sim.now / SEC,
            sim.correctness()
        );
    }
}

/// The seeded churn schedule both backends replay: concurrent joins, a
/// crash failure, a late join, and a graceful leave.
fn run_schedule(mut sim: Simulator) -> Simulator {
    sim.record_deliveries(true);
    sim.bootstrap_correct(&(0..10).collect::<Vec<NodeId>>());
    sim.schedule_join(2 * SEC, 20, 3);
    sim.schedule_join(2 * SEC, 21, 7);
    sim.schedule_fail(6 * SEC, 4);
    sim.schedule_join(9 * SEC, 22, 1);
    sim.schedule_leave(12 * SEC, 2);
    // run past the last churn event, then settle to the exact overlay
    sim.run_until(13 * SEC);
    settle_exact(&mut sim, 420 * SEC);
    sim
}

#[test]
fn sim_and_tcp_backends_agree_on_churn_schedule() {
    let sim = run_schedule(Simulator::new(overlay(), net()));
    let tcp = run_schedule(Simulator::with_transport(
        overlay(),
        Box::new(SchedTransport::new(&net())),
    ));
    assert_eq!(sim.backend(), "sim");
    assert_eq!(tcp.backend(), "tcp");

    // identical final membership ...
    let sim_ids: Vec<NodeId> = sim.node_ids();
    let tcp_ids: Vec<NodeId> = tcp.node_ids();
    assert_eq!(sim_ids, tcp_ids, "backends disagree on live membership");
    assert_eq!(sim_ids.len(), 11); // 10 - fail - leave + 3 joins

    // ... perfect correctness on both ...
    assert!((sim.correctness() - 1.0).abs() < 1e-12, "sim not correct");
    assert!((tcp.correctness() - 1.0).abs() < 1e-12, "tcp not correct");

    // ... the exact same neighbor multisets, ring by ring ...
    assert_eq!(
        sim.ring_snapshot(),
        tcp.ring_snapshot(),
        "backends converged to different overlays"
    );

    // ... and — the virtual-latency pin, with the schedule's non-zero
    // 30 ms + jitter links — the identical arrival timestamp for every
    // single message, in the identical order.
    assert_eq!(sim.delivered, tcp.delivered, "delivery counts diverged");
    assert_eq!(
        sim.delivery_log, tcp.delivery_log,
        "per-message arrival timestamps diverged between backends"
    );
    assert!(!sim.delivery_log.is_empty(), "trace should cover the run");
}

/// The full-link-model pin: the same churn schedule over *lossy,
/// bandwidth-constrained* links. Both backends sample the identical
/// seeded streams (`sim::LinkModel`), so they must drop the identical
/// frames — same `lost_frames` count — and deliver the survivors at the
/// identical virtual instants, converging to the identical overlay. On
/// the socket side a loss-lottery hit is a deliberate non-send, so a
/// lossy run is still a *clean* run: zero transport-level send errors
/// and zero pacing anomalies expected.
#[test]
fn lossy_links_drop_identical_frames_on_both_backends() {
    let lossy = NetConfig {
        bandwidth_mbps: 8.0,
        loss: 0.05,
        node_up_mbps: 16.0,
        node_down_mbps: 16.0,
        ..net()
    };
    let sim = run_schedule(Simulator::new(overlay(), lossy.clone()));
    let tcp = run_schedule(Simulator::with_transport(
        overlay(),
        Box::new(SchedTransport::new(&lossy)),
    ));
    assert_eq!(sim.backend(), "sim");
    assert_eq!(tcp.backend(), "tcp");

    // the loss lottery actually fired, and on the identical frames
    assert!(sim.lost_frames() > 0, "5% loss should drop some frames");
    assert_eq!(
        sim.lost_frames(),
        tcp.lost_frames(),
        "backends disagree on which frames the loss lottery dropped"
    );
    // loss is modelled, not an error: the socket path never even wrote
    // the lost frames
    assert_eq!(sim.dropped_sends(), 0);
    assert_eq!(tcp.dropped_sends(), 0, "lossy run must not drop writes");

    // the surviving traffic is pinned exactly: same arrival timestamps,
    // same counts, same converged rings, same membership
    let sim_ids: Vec<NodeId> = sim.node_ids();
    let tcp_ids: Vec<NodeId> = tcp.node_ids();
    assert_eq!(sim_ids, tcp_ids, "backends disagree on live membership");
    assert!((sim.correctness() - 1.0).abs() < 1e-12, "sim not correct");
    assert!((tcp.correctness() - 1.0).abs() < 1e-12, "tcp not correct");
    assert_eq!(sim.ring_snapshot(), tcp.ring_snapshot());
    assert_eq!(sim.delivered, tcp.delivered, "delivery counts diverged");
    assert_eq!(
        sim.delivery_log, tcp.delivery_log,
        "arrival timestamps diverged under loss + bandwidth"
    );
    assert!(!sim.delivery_log.is_empty(), "trace should cover the run");
}

/// Scenario-engine conformance with *graceful leaves* on the wire: a
/// flash crowd (joins followed by scheduled departures) plus a mass
/// leave, compiled once by `ScenarioSpec` and replayed on both backends.
/// The TCP path must carry the Leave handshake (not just crash-fail
/// teardown) to land on the same overlay as the in-memory network.
#[test]
fn scenario_with_leaves_agrees_on_both_backends() {
    let spec = ScenarioSpec {
        name: "leave-conformance".into(),
        initial: 10,
        seed: 21,
        horizon: 14 * SEC,
        sample_every: 0,
        settle: 0,
        min_live: 4,
        shards: 1,
        overlay: overlay(),
        net: net(),
        phases: vec![
            // mass leave first (victim drawn from the originals only, so
            // the flash-crowd departures below stay scheduled)
            Phase {
                at: SEC,
                kind: PhaseKind::MassLeave { count: 1 },
            },
            Phase {
                at: 2 * SEC,
                kind: PhaseKind::FlashCrowd {
                    count: 2,
                    dwell: 8 * SEC,
                },
            },
        ],
    };
    let counts = ChurnCounts::of(&spec.compile());
    assert_eq!(counts.joins, 2);
    assert_eq!(counts.leaves, 3, "schedule must exercise graceful leaves");

    let (mut sim, sim_report) = spec.run_sim(None).expect("sim run");
    let (mut tcp, tcp_report) = spec
        .run_sim(Some(Box::new(SchedTransport::new(&spec.net))))
        .expect("tcp run");
    assert_eq!(sim_report.backend, "sim");
    assert_eq!(tcp_report.backend, "tcp");
    // non-zero latency: the whole trajectory is pinned, not just the
    // converged endpoint
    assert_eq!(sim_report.delivered, tcp_report.delivered);
    assert_eq!(sim_report.golden_lines(), tcp_report.golden_lines());

    settle_exact(&mut sim, 420 * SEC);
    settle_exact(&mut tcp, 420 * SEC);
    let sim_ids: Vec<NodeId> = sim.node_ids();
    let tcp_ids: Vec<NodeId> = tcp.node_ids();
    assert_eq!(sim_ids, tcp_ids, "backends disagree on live membership");
    assert_eq!(sim_ids.len(), 10 + 2 - 3);
    assert!((sim.correctness() - 1.0).abs() < 1e-12, "sim not correct");
    assert!((tcp.correctness() - 1.0).abs() < 1e-12, "tcp not correct");
    assert_eq!(
        sim.ring_snapshot(),
        tcp.ring_snapshot(),
        "backends converged to different overlays after leaves"
    );
}

/// Two-task conformance: the canonical `two_task_mix` churn scenario —
/// two model tasks (mlp + lstm) training over ONE shared overlay while
/// three clients join through the protocol and two crash-fail — must be
/// **pinned identical** on the in-memory and the TCP backend: same
/// per-task membership, same ring snapshots after settle, and the same
/// per-task accuracy series to the last bit. Both backends sample the
/// same seeded per-link delays and deliver at the same virtual
/// instants (the TCP path via wire-stamped send times), so ring views
/// agree at every wake and sample time — which is what makes bitwise
/// accuracy conformance possible at all.
#[test]
fn two_task_scenario_is_pinned_identical_on_sim_and_tcp() -> anyhow::Result<()> {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..");
    let scenario = ScenarioSpec::load(&root.join("configs/scenarios/two_task_mix.toml"))?;
    let tasks = MultiTaskSpec::load(&root.join("configs/tasks/two_task_mix.toml"))?;
    assert_eq!(tasks.tasks.len(), 2, "the canonical spec carries two tasks");
    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &tasks.model_tasks())?;
    let joins = scenario
        .compile()
        .iter()
        .filter(|e| matches!(e.op, ChurnOp::Join { .. }))
        .count();
    let population = scenario.initial + joins;

    fn run_once(
        engine: &Engine,
        scenario: &ScenarioSpec,
        tasks: &MultiTaskSpec,
        population: usize,
        transport: Option<Box<dyn Transport>>,
    ) -> anyhow::Result<(ScenarioReport, NeighborSnapshot, Vec<Vec<bool>>)> {
        let base = DflConfig {
            clients: scenario.initial,
            seed: scenario.seed,
            ..DflConfig::default()
        };
        let method = MethodSpec::fedlay_multi(
            scenario.overlay.clone(),
            scenario.net.clone(),
            tasks.tasks.len(),
        );
        let (mut trainer, tables) =
            multitask::build_trainer(engine, method, base, tasks, population)?;
        if let Some(t) = transport {
            trainer.set_transport(t)?;
        }
        let report =
            scenario.run_trainer_tasks(&mut trainer, |lane, node| tables[lane][node].clone())?;
        let snap = trainer.overlay.as_ref().expect("overlay").ring_snapshot();
        let alive = trainer
            .lanes
            .iter()
            .map(|l| l.clients.iter().map(|c| c.alive).collect())
            .collect();
        Ok((report, snap, alive))
    }

    let (sim_report, sim_snap, sim_alive) =
        run_once(&engine, &scenario, &tasks, population, None)?;
    let (tcp_report, tcp_snap, tcp_alive) = run_once(
        &engine,
        &scenario,
        &tasks,
        population,
        Some(Box::new(SchedTransport::new(&scenario.net))),
    )?;
    assert_eq!(sim_report.backend, "sim");
    assert_eq!(tcp_report.backend, "tcp");

    // identical per-task membership on both backends, and the expected
    // arithmetic: 10 initial + 3 joins - 2 fails
    assert_eq!(sim_alive, tcp_alive, "backends disagree on lane membership");
    assert_eq!(sim_report.counts, tcp_report.counts);
    assert_eq!(sim_report.live_nodes, tcp_report.live_nodes);
    assert_eq!(sim_report.live_nodes, 10 + 3 - 2);

    // per-task overlay correctness reaches exactly 1.0 after settle
    assert!(sim_report.settled_at.is_some(), "sim never settled");
    assert!(tcp_report.settled_at.is_some(), "tcp never settled");
    assert!((sim_report.final_correctness - 1.0).abs() < 1e-12);
    assert!((tcp_report.final_correctness - 1.0).abs() < 1e-12);

    // identical ring snapshots (the settled views, not just correctness)
    assert_eq!(sim_snap, tcp_snap, "backends converged to different overlays");

    // the per-task accuracy series are pinned identical, every f64
    assert_eq!(
        sim_report.task_accuracy, tcp_report.task_accuracy,
        "per-task accuracy series diverged between backends"
    );
    // ... and so is the whole golden trajectory (correctness series too)
    assert_eq!(sim_report.golden_lines(), tcp_report.golden_lines());

    // each task's final accuracy matches its single-task baseline (the
    // acceptance bound is 0.02; task isolation actually makes it exact —
    // see tests/multitask_properties.rs)
    for (l, task) in tasks.tasks.iter().enumerate() {
        let solo_spec = MultiTaskSpec {
            tasks: vec![task.clone()],
        };
        let (solo_report, _, _) = run_once(&engine, &scenario, &solo_spec, population, None)?;
        let solo_acc = solo_report.task_accuracy[0].1.last().unwrap().1;
        let multi_acc = sim_report.task_accuracy[l].1.last().unwrap().1;
        assert!(
            (multi_acc - solo_acc).abs() <= 0.02,
            "task {:?} drifted from its single-task baseline: {multi_acc} vs {solo_acc}",
            task.name
        );
    }
    Ok(())
}

/// The tentpole pin: a seeded churn+**training** schedule with
/// *non-zero* link latency (30 ms + exponential jitter) replayed on
/// both backends must produce the identical per-message arrival
/// timestamps, the identical ring snapshots, and the bitwise-identical
/// accuracy series — Fig. 8 timing fidelity over real sockets, not just
/// the converged topology.
#[test]
fn nonzero_latency_training_pins_arrivals_rings_and_accuracy() -> anyhow::Result<()> {
    const MIN: Time = 60_000_000; // µs per simulated minute
    type Trace = (
        Vec<(Time, NodeId, NodeId)>,
        NeighborSnapshot,
        u64,
        Vec<(Time, f64)>,
    );
    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &["mlp"])?;
    let n = 6usize;
    let overlay = OverlayConfig {
        spaces: SPACES,
        heartbeat_ms: 5_000,
        failure_multiple: 3,
        repair_probe_ms: 20_000,
    };
    let run = |transport: Option<Box<dyn Transport>>| -> anyhow::Result<Trace> {
        let cfg = DflConfig {
            task: "mlp".into(),
            clients: n,
            local_steps: 1,
            ..DflConfig::default()
        };
        let weights = shard_labels(n + 1, 10, 8, cfg.seed);
        let mut trainer = Trainer::new(
            &engine,
            MethodSpec::fedlay_dynamic(overlay.clone(), net()),
            cfg,
            weights[..n].to_vec(),
        )?;
        if let Some(t) = transport {
            trainer.set_transport(t)?;
        }
        let joiner = trainer.schedule_join(2 * MIN, weights[n].clone(), 0)?;
        assert_eq!(joiner, n);
        trainer.schedule_fail(5 * MIN, 1);
        // materialize the overlay now so the arrival trace covers the
        // whole run (it is otherwise built lazily inside `run`)
        trainer.schedule_overlay_snapshots(12 * MIN, 6 * MIN)?;
        trainer
            .overlay
            .as_mut()
            .expect("overlay just built")
            .record_deliveries(true);
        let last = trainer.run(12 * MIN, 6 * MIN)?;
        assert!(last.mean_accuracy.is_finite());
        let sim = trainer.overlay.as_ref().expect("dynamic overlay state");
        assert!(sim.contains_node(n as NodeId), "joiner missing");
        assert!(!sim.contains_node(1), "failed node still live");
        assert!(trainer.clients()[joiner].alive);
        assert!(!trainer.clients()[1].alive);
        let acc: Vec<(Time, f64)> = trainer
            .samples()
            .iter()
            .map(|s| (s.at, s.mean_accuracy))
            .collect();
        assert!(!acc.is_empty());
        Ok((
            sim.delivery_log.clone(),
            sim.ring_snapshot(),
            sim.delivered,
            acc,
        ))
    };

    let (sim_log, sim_rings, sim_delivered, sim_acc) = run(None)?;
    let (tcp_log, tcp_rings, tcp_delivered, tcp_acc) =
        run(Some(Box::new(SchedTransport::new(&net()))))?;

    assert_eq!(sim_delivered, tcp_delivered, "delivery counts diverged");
    assert_eq!(
        sim_log, tcp_log,
        "arrival timestamps diverged under non-zero latency"
    );
    assert!(!sim_log.is_empty(), "trace should cover the run");
    assert_eq!(sim_rings, tcp_rings, "ring snapshots diverged");
    assert_eq!(sim_acc, tcp_acc, "accuracy series diverged (bitwise)");
    Ok(())
}

/// The accuracy-vs-bytes claim, in executable form: the same seeded
/// FedLay run with quantized (q8) model exchange must move at least 3×
/// fewer model bytes per client than dense f32 exchange, at no more
/// than 0.02 final-accuracy cost (the bandwidth_mix scenario matrix in
/// docs/scenarios.md is the CLI face of this bound).
#[test]
fn quantized_exchange_cuts_bytes_3x_within_accuracy_bound() -> anyhow::Result<()> {
    use fedlay::dfl::Compression;
    const MIN: Time = 60_000_000; // µs per simulated minute
    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &["mlp"])?;
    let n = 6usize;
    let run = |compression: Compression| -> anyhow::Result<(f64, f64)> {
        let cfg = DflConfig {
            task: "mlp".into(),
            clients: n,
            local_steps: 1,
            ..DflConfig::default()
        };
        let weights = shard_labels(n, 10, 8, cfg.seed);
        let spec = MethodSpec::fedlay(n, SPACES).with_compression(compression);
        let mut trainer = Trainer::new(&engine, spec, cfg, weights)?;
        let last = trainer.run(12 * MIN, 6 * MIN)?;
        Ok((trainer.model_mb_per_client(), last.mean_accuracy))
    };
    let (dense_mb, dense_acc) = run(Compression::None)?;
    let (q8_mb, q8_acc) = run(Compression::Q8)?;
    assert!(dense_mb > 0.0, "dense run should move model bytes");
    assert!(
        q8_mb * 3.0 <= dense_mb,
        "q8 must cut bytes at least 3x: {q8_mb:.3} MB vs {dense_mb:.3} MB"
    );
    assert!(
        (dense_acc - q8_acc).abs() <= 0.02,
        "q8 accuracy drifted beyond the 0.02 bound: {q8_acc:.4} vs {dense_acc:.4}"
    );
    Ok(())
}

/// `train --transport tcp` end-to-end: a small fedlay-dyn run whose
/// embedded overlay lives on real localhost sockets, with a mid-run
/// protocol join and a crash failure — the unified engine drives NDMP
/// over TCP while MEP/training advance in virtual time.
#[test]
fn trainer_completes_fedlay_dyn_over_tcp() -> anyhow::Result<()> {
    const MIN: Time = 60_000_000; // µs per simulated minute
    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &["mlp"])?;
    let n = 6usize;
    let cfg = DflConfig {
        task: "mlp".into(),
        clients: n,
        local_steps: 1,
        ..DflConfig::default()
    };
    // slow protocol timers: the virtual clock covers minutes, and every
    // heartbeat round pays a real loopback round-trip per message
    let overlay = OverlayConfig {
        spaces: SPACES,
        heartbeat_ms: 5_000,
        failure_multiple: 3,
        repair_probe_ms: 20_000,
    };
    let weights = shard_labels(n + 1, 10, 8, cfg.seed);
    let mut trainer = Trainer::new(
        &engine,
        MethodSpec::fedlay_dynamic(overlay, net()),
        cfg,
        weights[..n].to_vec(),
    )?;
    trainer.set_transport(Box::new(SchedTransport::new(&net())))?;
    let joiner = trainer.schedule_join(2 * MIN, weights[n].clone(), 0)?;
    assert_eq!(joiner, n);
    trainer.schedule_fail(5 * MIN, 1);
    let last = trainer.run(12 * MIN, 6 * MIN)?;

    assert!(last.mean_accuracy.is_finite());
    assert!(!trainer.samples().is_empty());
    let sim = trainer.overlay.as_ref().expect("dynamic overlay state");
    assert_eq!(sim.backend(), "tcp");
    assert!(sim.contains_node(n as NodeId), "joiner missing");
    assert!(!sim.contains_node(1), "failed node still live");
    assert!(
        (sim.correctness() - 1.0).abs() < 1e-12,
        "overlay not repaired over TCP: correctness={}",
        sim.correctness()
    );
    assert!(trainer.clients()[joiner].alive);
    assert!(!trainer.clients()[1].alive);
    Ok(())
}
