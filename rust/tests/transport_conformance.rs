//! Deterministic-vs-socket conformance: an identical seeded churn
//! schedule must produce the identical final overlay on the in-memory
//! simulated transport and on the real TCP transport (localhost
//! sockets). This is the paper's practicality claim in executable form —
//! NDMP constructs and maintains the same near-random regular topology
//! whether messages are heap events or real frames (§IV-A1 types 1–3).
//!
//! The comparison view is the ring-adjacency snapshot (Definition-1
//! neighbor sets): message interleavings differ over real sockets, but a
//! converged FedLay's rings are fully determined by the live membership
//! (coordinates are hash-derived from node ids), so both backends must
//! land on the exact same neighbor multisets with correctness 1.0.

use fedlay::config::{DflConfig, NetConfig, OverlayConfig};
use fedlay::data::shard_labels;
use fedlay::dfl::{MethodSpec, Trainer};
use fedlay::net::SchedTransport;
use fedlay::ndmp::messages::{Time, SEC};
use fedlay::runtime::{find_artifacts_dir, Engine};
use fedlay::sim::{ChurnCounts, Phase, PhaseKind, ScenarioSpec, Simulator};
use fedlay::topology::{Membership, NeighborSnapshot, NodeId};

const SPACES: usize = 2;

fn overlay() -> OverlayConfig {
    OverlayConfig {
        spaces: SPACES,
        heartbeat_ms: 600,
        failure_multiple: 3,
        repair_probe_ms: 2_400,
    }
}

fn net() -> NetConfig {
    NetConfig {
        latency_ms: 30.0,
        jitter: 0.2,
        seed: 13,
    }
}

/// Ideal Definition-1 neighbor sets of a membership — the ground truth
/// both backends must converge to.
fn ideal_snapshot(ids: &[NodeId], spaces: usize) -> NeighborSnapshot {
    let mut m = Membership::new(spaces);
    for &id in ids {
        m.add(id);
    }
    ids.iter().map(|&id| (id, m.correct_neighbors(id))).collect()
}

/// Advance `sim` until its ring views equal the ideal overlay of its
/// live membership (stronger than correctness 1.0: no stale pointers at
/// all). Panics if `deadline` passes first.
fn settle_exact(sim: &mut Simulator, deadline: Time) {
    loop {
        sim.run_until(sim.now + 2 * SEC);
        let live: Vec<NodeId> = sim.nodes.keys().copied().collect();
        if sim.ring_snapshot() == ideal_snapshot(&live, sim.cfg.spaces) {
            return;
        }
        assert!(
            sim.now < deadline,
            "backend {:?} did not converge to the ideal overlay by t={}s: correctness={}",
            sim.backend(),
            sim.now / SEC,
            sim.correctness()
        );
    }
}

/// The seeded churn schedule both backends replay: concurrent joins, a
/// crash failure, a late join, and a graceful leave.
fn run_schedule(mut sim: Simulator) -> Simulator {
    sim.bootstrap_correct(&(0..10).collect::<Vec<NodeId>>());
    sim.schedule_join(2 * SEC, 20, 3);
    sim.schedule_join(2 * SEC, 21, 7);
    sim.schedule_fail(6 * SEC, 4);
    sim.schedule_join(9 * SEC, 22, 1);
    sim.schedule_leave(12 * SEC, 2);
    // run past the last churn event, then settle to the exact overlay
    sim.run_until(13 * SEC);
    settle_exact(&mut sim, 420 * SEC);
    sim
}

#[test]
fn sim_and_tcp_backends_agree_on_churn_schedule() {
    let sim = run_schedule(Simulator::new(overlay(), net()));
    let tcp = run_schedule(Simulator::with_transport(
        overlay(),
        Box::new(SchedTransport::new()),
    ));
    assert_eq!(sim.backend(), "sim");
    assert_eq!(tcp.backend(), "tcp");

    // identical final membership ...
    let sim_ids: Vec<NodeId> = sim.nodes.keys().copied().collect();
    let tcp_ids: Vec<NodeId> = tcp.nodes.keys().copied().collect();
    assert_eq!(sim_ids, tcp_ids, "backends disagree on live membership");
    assert_eq!(sim_ids.len(), 11); // 10 - fail - leave + 3 joins

    // ... perfect correctness on both ...
    assert!((sim.correctness() - 1.0).abs() < 1e-12, "sim not correct");
    assert!((tcp.correctness() - 1.0).abs() < 1e-12, "tcp not correct");

    // ... and the exact same neighbor multisets, ring by ring.
    assert_eq!(
        sim.ring_snapshot(),
        tcp.ring_snapshot(),
        "backends converged to different overlays"
    );
}

/// Scenario-engine conformance with *graceful leaves* on the wire: a
/// flash crowd (joins followed by scheduled departures) plus a mass
/// leave, compiled once by `ScenarioSpec` and replayed on both backends.
/// The TCP path must carry the Leave handshake (not just crash-fail
/// teardown) to land on the same overlay as the in-memory network.
#[test]
fn scenario_with_leaves_agrees_on_both_backends() {
    let spec = ScenarioSpec {
        name: "leave-conformance".into(),
        initial: 10,
        seed: 21,
        horizon: 14 * SEC,
        sample_every: 0,
        settle: 0,
        min_live: 4,
        overlay: overlay(),
        net: net(),
        phases: vec![
            // mass leave first (victim drawn from the originals only, so
            // the flash-crowd departures below stay scheduled)
            Phase {
                at: SEC,
                kind: PhaseKind::MassLeave { count: 1 },
            },
            Phase {
                at: 2 * SEC,
                kind: PhaseKind::FlashCrowd {
                    count: 2,
                    dwell: 8 * SEC,
                },
            },
        ],
    };
    let counts = ChurnCounts::of(&spec.compile());
    assert_eq!(counts.joins, 2);
    assert_eq!(counts.leaves, 3, "schedule must exercise graceful leaves");

    let (mut sim, sim_report) = spec.run_sim(None).expect("sim run");
    let (mut tcp, tcp_report) = spec
        .run_sim(Some(Box::new(SchedTransport::new())))
        .expect("tcp run");
    assert_eq!(sim_report.backend, "sim");
    assert_eq!(tcp_report.backend, "tcp");

    settle_exact(&mut sim, 420 * SEC);
    settle_exact(&mut tcp, 420 * SEC);
    let sim_ids: Vec<NodeId> = sim.nodes.keys().copied().collect();
    let tcp_ids: Vec<NodeId> = tcp.nodes.keys().copied().collect();
    assert_eq!(sim_ids, tcp_ids, "backends disagree on live membership");
    assert_eq!(sim_ids.len(), 10 + 2 - 3);
    assert!((sim.correctness() - 1.0).abs() < 1e-12, "sim not correct");
    assert!((tcp.correctness() - 1.0).abs() < 1e-12, "tcp not correct");
    assert_eq!(
        sim.ring_snapshot(),
        tcp.ring_snapshot(),
        "backends converged to different overlays after leaves"
    );
}

/// `train --transport tcp` end-to-end: a small fedlay-dyn run whose
/// embedded overlay lives on real localhost sockets, with a mid-run
/// protocol join and a crash failure — the unified engine drives NDMP
/// over TCP while MEP/training advance in virtual time.
#[test]
fn trainer_completes_fedlay_dyn_over_tcp() -> anyhow::Result<()> {
    const MIN: Time = 60_000_000; // µs per simulated minute
    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &["mlp"])?;
    let n = 6usize;
    let cfg = DflConfig {
        task: "mlp".into(),
        clients: n,
        local_steps: 1,
        ..DflConfig::default()
    };
    // slow protocol timers: the virtual clock covers minutes, and every
    // heartbeat round costs a real settle window over the loopback
    let overlay = OverlayConfig {
        spaces: SPACES,
        heartbeat_ms: 5_000,
        failure_multiple: 3,
        repair_probe_ms: 20_000,
    };
    let weights = shard_labels(n + 1, 10, 8, cfg.seed);
    let mut trainer = Trainer::new(
        &engine,
        MethodSpec::fedlay_dynamic(overlay, net()),
        cfg,
        weights[..n].to_vec(),
    )?;
    trainer.set_transport(Box::new(SchedTransport::new()))?;
    let joiner = trainer.schedule_join(2 * MIN, weights[n].clone(), 0)?;
    assert_eq!(joiner, n);
    trainer.schedule_fail(5 * MIN, 1);
    let last = trainer.run(12 * MIN, 6 * MIN)?;

    assert!(last.mean_accuracy.is_finite());
    assert!(!trainer.samples.is_empty());
    let sim = trainer.overlay.as_ref().expect("dynamic overlay state");
    assert_eq!(sim.backend(), "tcp");
    assert!(sim.nodes.contains_key(&(n as NodeId)), "joiner missing");
    assert!(!sim.nodes.contains_key(&1), "failed node still live");
    assert!(
        (sim.correctness() - 1.0).abs() < 1e-12,
        "overlay not repaired over TCP: correctness={}",
        sim.correctness()
    );
    assert!(trainer.clients[joiner].alive);
    assert!(!trainer.clients[1].alive);
    Ok(())
}
