//! Cross-module integration + property tests for the NDMP coordinator.
//!
//! proptest is not in the vendored dependency set, so these are seeded
//! property sweeps: each test iterates over many random seeds/scenarios
//! and asserts the protocol invariants (Definition 1 correctness, routing
//! termination, no phantom neighbors) hold on every draw.

use fedlay::config::{NetConfig, OverlayConfig};
use fedlay::ndmp::messages::MS;
use fedlay::ndmp::routing::{coord_of, greedy_next_hop};
use fedlay::sim::{churn, grow_network, Simulator};
use fedlay::topology::correctness::report;
use fedlay::topology::fedlay::Membership;
use fedlay::topology::circular_distance;
use fedlay::util::Rng;

fn overlay(spaces: usize) -> OverlayConfig {
    OverlayConfig {
        spaces,
        heartbeat_ms: 500,
        failure_multiple: 3,
        repair_probe_ms: 2_000,
    }
}

fn net(seed: u64) -> NetConfig {
    NetConfig {
        latency_ms: 80.0,
        jitter: 0.3,
        seed,
        ..NetConfig::default()
    }
}

/// Property: decentralized growth reaches a Definition-1-correct overlay
/// for arbitrary seeds, sizes and space counts.
#[test]
fn property_grown_networks_are_correct() {
    for seed in 0..6u64 {
        let mut rng = Rng::new(seed);
        let n = 12 + rng.index(25);
        let spaces = 2 + rng.index(3);
        let sim = grow_network(overlay(spaces), net(seed), n, 1_200 * MS);
        let c = sim.correctness();
        assert!(
            c > 0.999,
            "seed {seed}: n={n} L={spaces} correctness {c}"
        );
        // and no node holds a phantom peer that left/never existed
        let r = report(&sim.snapshot(), spaces);
        assert!(r.missing.is_empty(), "seed {seed}: missing {:?}", r.missing);
    }
}

/// Property: greedy routing terminates at the globally closest node from
/// any start, on any correct membership (Theorem 1), and hop counts are
/// bounded well below n.
#[test]
fn property_greedy_routing_terminates_at_closest() {
    for seed in 0..5u64 {
        let mut rng = Rng::new(seed ^ 0x60D);
        let n = 30 + rng.index(80);
        let spaces = 2;
        let m = Membership::dense(n, spaces);
        for _ in 0..20 {
            let target_id = 10_000 + rng.next_u64() % 10_000;
            let space = rng.index(spaces) as u32;
            let target = coord_of(target_id, space);
            let mut cur = *m.nodes.keys().nth(rng.index(n)).unwrap();
            let mut hops = 0;
            while let Some(w) =
                greedy_next_hop(cur, target, space, m.correct_neighbors(cur).into_iter())
            {
                cur = w;
                hops += 1;
                assert!(hops <= n, "routing loop at seed {seed}");
            }
            let best = m
                .nodes
                .keys()
                .copied()
                .min_by(|&a, &b| {
                    circular_distance(coord_of(a, space), target)
                        .partial_cmp(&circular_distance(coord_of(b, space), target))
                        .unwrap()
                        .then(a.cmp(&b))
                })
                .unwrap();
            assert_eq!(cur, best);
            assert!(hops < n / 2 + 8, "hops {hops} too high for n={n}");
        }
    }
}

/// Property: mixed random churn (joins + failures interleaved) always
/// converges back to a correct network once the churn window closes.
#[test]
fn property_mixed_churn_recovers() {
    for seed in 0..3u64 {
        let mut sim = Simulator::new(overlay(2), net(seed ^ 0xC4));
        churn::mixed_churn(&mut sim, 24, 10, 20_000 * MS, seed);
        let t = sim.run_until_correct(1.0, 420_000 * MS, 5_000 * MS);
        assert!(
            t.is_some(),
            "seed {seed}: stuck at correctness {}",
            sim.correctness()
        );
    }
}

/// Leave protocol: a wave of graceful leaves keeps the network correct
/// without waiting for failure detection.
#[test]
fn graceful_leave_wave_stays_correct() {
    let mut sim = Simulator::new(overlay(3), net(9));
    let ids: Vec<u64> = (0..40).collect();
    sim.bootstrap_correct(&ids);
    for (k, id) in [3u64, 7, 11, 19, 23].iter().enumerate() {
        sim.schedule_leave((1_000 + k as u64 * 2_000) * MS, *id);
    }
    // run past the last leave before checking convergence
    sim.run_until(12_000 * MS);
    let t = sim.run_until_correct(1.0, 120_000 * MS, 1_000 * MS);
    assert!(t.is_some(), "leaves broke the network: {}", sim.correctness());
    assert_eq!(sim.live_count(), 35);
}

/// Failure detection time scales with the heartbeat budget: with
/// failure_multiple=3 and T=500ms, a failure must be repaired within a
/// few seconds (paper reports ~8 s at 400-node scale).
#[test]
fn failure_detection_latency_bounded() {
    let mut sim = Simulator::new(overlay(2), net(4));
    let ids: Vec<u64> = (0..30).collect();
    sim.bootstrap_correct(&ids);
    sim.schedule_fail(1_000 * MS, 13);
    // run past the failure instant before watching for recovery
    sim.run_until(1_100 * MS);
    assert!(sim.correctness() < 1.0, "failure should dent correctness");
    let t = sim
        .run_until_correct(1.0, 60_000 * MS, 250 * MS)
        .expect("no recovery");
    let recovery_s = (t - 1_000 * MS) as f64 / 1e6;
    assert!(
        recovery_s < 15.0,
        "recovery took {recovery_s:.1}s (budget: detection 1.5s + routing)"
    );
}

/// The simulator itself is deterministic: identical seeds → identical
/// message counts, correctness trajectories and node sets.
#[test]
fn simulation_is_reproducible() {
    let run = |seed: u64| {
        let mut sim = Simulator::new(overlay(3), net(seed));
        churn::mass_join(&mut sim, 20, 8, 10 * MS, seed);
        churn::sample_correctness(&mut sim, 60_000 * MS, 2_000 * MS);
        sim.run_until(60_000 * MS);
        let series: Vec<(u64, f64)> = sim.samples.iter().map(|s| (s.at, s.correctness)).collect();
        (series, sim.delivered, sim.live_count())
    };
    assert_eq!(run(5), run(5));
    let (a, ..) = run(5);
    let (b, ..) = run(6);
    assert_ne!(a, b, "different seeds should differ somewhere");
}
