//! Real-TCP integration: a small FedLay fleet on localhost exercising the
//! full stack — NDMP join over sockets, MEP offer/request/payload, local
//! training and aggregation through per-node runtime engines.
//! (The 16-node version is examples/prototype_16.rs.)
//!
//! Nodes bind OS-assigned ports through a shared `AddrBook` (no port
//! collisions between parallel test runs), and every wait is a bounded
//! poll on published protocol state (`NodeStatus`), not a fixed sleep.

use fedlay::config::OverlayConfig;
use fedlay::net::{spawn, AddrBook, ClientHandle, ClientNodeConfig};
use fedlay::runtime::find_artifacts_dir;
use fedlay::topology::{Membership, NodeId};
use std::collections::BTreeSet;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Poll `cond` every 100 ms until it holds or `deadline` passes.
fn wait_for(deadline: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let start = Instant::now();
    loop {
        if cond() {
            return true;
        }
        if start.elapsed() > deadline {
            return false;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
}

fn spawn_fleet(
    n: u64,
    overlay: &OverlayConfig,
    period_ms: u64,
    dir: &std::path::Path,
) -> (Arc<AddrBook>, Vec<ClientHandle>) {
    let book = Arc::new(AddrBook::new());
    let shards = fedlay::data::shard_labels(n as usize, 10, 8, 7);
    let mut handles = Vec::new();
    for id in 0..n {
        let cfg = ClientNodeConfig {
            id,
            base_port: 0,
            bootstrap: if id == 0 { None } else { Some(0) },
            book: Some(book.clone()),
            overlay: overlay.clone(),
            artifacts_dir: dir.to_path_buf(),
            task: "mlp".into(),
            task_id: 0,
            label_weights: shards[id as usize].clone(),
            lr: 0.5,
            local_steps: 1,
            period_ms,
            compression: fedlay::dfl::Compression::None,
            aggregation: fedlay::dfl::Aggregation::Mean,
            seed: 7,
        };
        // spawn blocks until the listener is bound and registered, so
        // joiners always find a live bootstrap — no stagger sleeps
        handles.push(spawn(cfg).expect("spawn"));
    }
    (book, handles)
}

#[test]
fn five_node_tcp_fleet_joins_and_learns() {
    let Ok(dir) = find_artifacts_dir(None) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let n = 5u64;
    let overlay = OverlayConfig {
        spaces: 2,
        heartbeat_ms: 400,
        failure_multiple: 3,
        repair_probe_ms: 1_200,
    };
    let (_book, handles) = spawn_fleet(n, &overlay, 1_200, &dir);
    // bounded poll: everyone joined, found neighbors, and ran at least
    // two MEP rounds with real data traffic
    let converged = wait_for(Duration::from_secs(60), || {
        handles.iter().all(|h| {
            h.status.joined() && !h.status.neighbors().is_empty() && h.status.exchanges() >= 2
        }) && handles.iter().any(|h| h.status.data_sent() > 0)
    });
    assert!(converged, "fleet did not join + exchange within the deadline");
    let mut joined = 0;
    let mut total_ctrl = 0;
    let mut total_data = 0;
    for h in handles {
        let r = h.stop_and_join().expect("report");
        joined += r.joined as usize;
        total_ctrl += r.control_sent;
        total_data += r.data_sent;
        assert!(r.neighbor_count >= 1, "node {} has no neighbors", r.id);
        assert!(r.accuracy.is_finite());
    }
    assert_eq!(joined, n as usize, "not all nodes joined");
    assert!(total_ctrl > 0, "no NDMP traffic happened");
    assert!(total_data > 0, "no MEP traffic happened");
}

#[test]
fn failure_rewiring_over_tcp() {
    let Ok(dir) = find_artifacts_dir(None) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let n = 4u64;
    // fast liveness timers so failure detection fits a test budget
    let overlay = OverlayConfig {
        spaces: 2,
        heartbeat_ms: 300,
        failure_multiple: 3,
        repair_probe_ms: 900,
    };
    let (_book, mut handles) = spawn_fleet(n, &overlay, 1_000, &dir);
    let joined = wait_for(Duration::from_secs(60), || {
        handles
            .iter()
            .all(|h| h.status.joined() && !h.status.ring_neighbors().is_empty())
    });
    assert!(joined, "fleet did not form an overlay");

    // crash-fail node 3: stop emits no Leave — from the survivors'
    // perspective it silently disappears and heartbeats go dark
    let dead: NodeId = 3;
    let victim = handles.remove(dead as usize);
    let report = victim.stop_and_join().expect("victim report");
    assert!(report.joined);

    // survivors must detect the silence (3 × 300 ms) and rewire their
    // rings to the ideal 3-node overlay, all via real repair traffic
    let mut ideal = Membership::new(overlay.spaces);
    for id in 0..n - 1 {
        ideal.add(id);
    }
    let rewired = wait_for(Duration::from_secs(60), || {
        handles.iter().all(|h| {
            let ring = h.status.ring_neighbors();
            !ring.contains(&dead) && ring == ideal.correct_neighbors(h.id)
        })
    });
    if !rewired {
        let rings: Vec<(NodeId, BTreeSet<NodeId>)> = handles
            .iter()
            .map(|h| (h.id, h.status.ring_neighbors()))
            .collect();
        panic!("survivors did not rewire around node {dead}: rings {rings:?}");
    }
    for h in handles {
        let r = h.stop_and_join().expect("report");
        assert!(r.joined);
        assert!(r.neighbor_count >= 1, "survivor {} isolated", r.id);
    }
}
