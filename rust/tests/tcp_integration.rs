//! Real-TCP integration: a small FedLay fleet on localhost exercising the
//! full stack — NDMP join over sockets, MEP offer/request/payload, local
//! training and aggregation through per-node PJRT engines.
//! (The 16-node version is examples/prototype_16.rs.)

use fedlay::config::OverlayConfig;
use fedlay::net::{spawn, ClientNodeConfig};
use fedlay::runtime::find_artifacts_dir;

#[test]
fn five_node_tcp_fleet_joins_and_learns() {
    let Ok(dir) = find_artifacts_dir(None) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let n = 5u64;
    let base_port = 7800u16;
    let overlay = OverlayConfig {
        spaces: 2,
        heartbeat_ms: 400,
        failure_multiple: 3,
        repair_probe_ms: 1_200,
    };
    let shards = fedlay::data::shard_labels(n as usize, 10, 8, 7);
    let mut handles = Vec::new();
    for id in 0..n {
        let cfg = ClientNodeConfig {
            id,
            base_port,
            bootstrap: if id == 0 { None } else { Some(0) },
            overlay: overlay.clone(),
            artifacts_dir: dir.clone(),
            task: "mlp".into(),
            label_weights: shards[id as usize].clone(),
            lr: 0.5,
            local_steps: 1,
            period_ms: 1_200,
            seed: 7,
        };
        handles.push(spawn(cfg).expect("spawn"));
        std::thread::sleep(std::time::Duration::from_millis(if id == 0 { 250 } else { 120 }));
    }
    // run the fleet for ~10 s of real protocol time
    std::thread::sleep(std::time::Duration::from_secs(10));
    let mut joined = 0;
    let mut total_ctrl = 0;
    let mut total_data = 0;
    for h in handles {
        let r = h.stop_and_join().expect("report");
        joined += r.joined as usize;
        total_ctrl += r.control_sent;
        total_data += r.data_sent;
        assert!(
            r.neighbor_count >= 1,
            "node {} has no neighbors",
            r.id
        );
        assert!(r.accuracy.is_finite());
    }
    assert_eq!(joined, n as usize, "not all nodes joined");
    assert!(total_ctrl > 0, "no NDMP traffic happened");
    assert!(total_data > 0, "no MEP traffic happened");
}
