//! Byzantine-resilient aggregation: the property suite over the robust
//! rules and the end-to-end acceptance pin for the adversarial scenario
//! family.
//!
//! Part A (pure CPU, always runs) checks the `mep::Aggregation` rules
//! against a k-honest + f-Byzantine cluster for every poison mode:
//! NaN rows are rejected by the guard under *every* rule (bitwise equal
//! to the honest-only aggregate), finite attacks (scale / sign-flip)
//! corrupt the mean but leave the robust rules near the honest cluster,
//! and `Mean` with clean inputs is bitwise-identical to the historical
//! `aggregate_cpu` (clean goldens unchanged).
//!
//! Part B drives full trainer runs through a PoissonChurn + Poison{nan}
//! scenario: under `Mean` the honest-vs-Byzantine accuracy gap opens
//! while the robust rules stay within 0.05 of the clean run's final
//! accuracy — and no honest client ever stores a non-finite parameter,
//! under any rule (the zero-NaN acceptance invariant).

use fedlay::config::DflConfig;
use fedlay::data::shard_labels;
use fedlay::dfl::{MethodSpec, Trainer};
use fedlay::mep::{aggregate_cpu, Aggregation};
use fedlay::runtime::{find_artifacts_dir, Engine};
use fedlay::sim::{ChurnOp, ScenarioReport, ScenarioSpec};
use fedlay::util::Rng;

// ---------------------------------------------------------------------
// Part A: property suite over the aggregation rules (no engine needed)
// ---------------------------------------------------------------------

const DIM: usize = 32;
const HONEST: usize = 8;
const BYZ: usize = 2;

/// `k` models clustered around one random center (σ = 0.05 per coord).
fn honest_cluster(seed: u64) -> (Vec<f32>, Vec<Vec<f32>>) {
    let mut rng = Rng::new(seed);
    let center: Vec<f32> = (0..DIM).map(|_| rng.gaussian() as f32).collect();
    let models = (0..HONEST)
        .map(|_| center.iter().map(|&c| c + 0.05 * rng.gaussian() as f32).collect())
        .collect();
    (center, models)
}

fn poisoned(mode: &str, victim: &[f32]) -> Vec<f32> {
    match mode {
        "nan" => vec![f32::NAN; victim.len()],
        "scale" => victim.iter().map(|v| v * -10.0).collect(),
        "signflip" => victim.iter().map(|v| -v).collect(),
        other => panic!("unknown mode {other}"),
    }
}

fn refs(models: &[Vec<f32>]) -> Vec<&[f32]> {
    models.iter().map(|m| m.as_slice()).collect()
}

fn max_abs_dev(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

const ROBUST: [Aggregation; 3] = [
    Aggregation::TrimmedMean { beta: 0.25 },
    Aggregation::Median,
    Aggregation::Krum { f: BYZ },
];

/// NaN poisoning is neutralized by the non-finite guard under EVERY
/// rule: the mixed aggregate is bitwise equal to the honest-only one
/// and exactly the Byzantine rows are counted as rejected.
#[test]
fn nan_rows_are_rejected_under_every_rule() {
    let (_, honest) = honest_cluster(42);
    let mut mixed = honest.clone();
    for _ in 0..BYZ {
        mixed.push(poisoned("nan", &honest[0]));
    }
    let w_honest = vec![1.0f64; HONEST];
    let w_mixed = vec![1.0f64; HONEST + BYZ];
    for rule in [Aggregation::Mean].iter().chain(ROBUST.iter()) {
        let (clean, rej0) = rule.apply_guarded(&refs(&honest), &w_honest);
        let (guarded, rej) = rule.apply_guarded(&refs(&mixed), &w_mixed);
        assert_eq!(rej0, 0, "{rule:?} rejected honest rows");
        assert_eq!(rej, BYZ, "{rule:?} miscounted rejected rows");
        assert_eq!(clean, guarded, "{rule:?} not bitwise honest-only under nan poison");
        assert!(guarded.iter().all(|v| v.is_finite()), "{rule:?} emitted non-finite");
    }
}

/// Finite poison (scale ×−10, sign-flip): nothing for the guard to
/// reject, so only the robust rules resist — the mean is dragged far
/// from the honest cluster while trimmed/median/krum stay close.
#[test]
fn robust_rules_resist_finite_poison_where_mean_corrupts() {
    for mode in ["scale", "signflip"] {
        let (_, honest) = honest_cluster(7);
        let honest_mean = aggregate_cpu(&refs(&honest), &[1.0f64; HONEST]);
        let mut mixed = honest.clone();
        for b in 0..BYZ {
            mixed.push(poisoned(mode, &honest[b]));
        }
        let w = vec![1.0f64; HONEST + BYZ];
        let (mean_out, rej) = Aggregation::Mean.apply_guarded(&refs(&mixed), &w);
        assert_eq!(rej, 0, "finite {mode} rows must not be guard-rejected");
        let mean_dev = max_abs_dev(&mean_out, &honest_mean);
        assert!(mean_dev > 0.25, "{mode}: mean barely moved ({mean_dev})");
        for rule in ROBUST {
            let (out, rej) = rule.apply_guarded(&refs(&mixed), &w);
            assert_eq!(rej, 0);
            assert!(out.iter().all(|v| v.is_finite()));
            let dev = max_abs_dev(&out, &honest_mean);
            assert!(
                dev < 0.2,
                "{rule:?} under {mode}: deviation {dev} from honest mean (mean rule: {mean_dev})"
            );
        }
    }
}

/// Every robust rule over honest-only inputs lands near the honest
/// mean (they are all location estimators of the same cluster).
#[test]
fn robust_rules_agree_with_mean_on_clean_inputs() {
    let (_, honest) = honest_cluster(99);
    let w = vec![1.0f64; HONEST];
    let mean = aggregate_cpu(&refs(&honest), &w);
    for rule in ROBUST {
        let (out, rej) = rule.apply_guarded(&refs(&honest), &w);
        assert_eq!(rej, 0);
        let dev = max_abs_dev(&out, &mean);
        assert!(dev < 0.2, "{rule:?} clean deviation {dev}");
    }
}

/// `Aggregation::Mean` is the historical confidence-weighted average,
/// bitwise: random models, random positive weights.
#[test]
fn mean_rule_is_bitwise_aggregate_cpu() {
    let mut rng = Rng::new(3);
    for k in 1..=6 {
        let models: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..DIM).map(|_| rng.gaussian() as f32).collect())
            .collect();
        let weights: Vec<f64> = (0..k).map(|_| rng.next_f64() + 0.1).collect();
        let direct = aggregate_cpu(&refs(&models), &weights);
        let via_rule = Aggregation::Mean.apply(&refs(&models), &weights);
        assert_eq!(direct, via_rule, "Mean diverged from aggregate_cpu at k={k}");
    }
}

// ---------------------------------------------------------------------
// Part B: end-to-end acceptance — PoissonChurn + Poison{nan} trainer runs
// ---------------------------------------------------------------------

/// Clean baseline: background Poisson churn only.
const CLEAN_SPEC: &str = r#"
[scenario]
name = "adversarial-accept-clean"
initial = 12
seed = 9
horizon_ms = 300000
sample_every_ms = 60000
min_live = 8

[overlay]
spaces = 2
heartbeat_ms = 500
failure_multiple = 3
repair_probe_ms = 2000

[net]
latency_ms = 0.0
jitter = 0.0
seed = 9

[phase.1]
kind = "poisson_churn"
at_ms = 5000
join_per_min = 2.0
fail_per_min = 1.0
leave_per_min = 0.0
window_ms = 60000
"#;

/// Same seed + churn, plus a NaN poisoning wave after the churn window
/// (so the churn schedule is identical to the clean spec's — pinned by
/// `attack_phase_leaves_earlier_churn_schedule_untouched` in the unit
/// suite).
const ATTACKED_SPEC: &str = r#"
[scenario]
name = "adversarial-accept-nan"
initial = 12
seed = 9
horizon_ms = 300000
sample_every_ms = 60000
min_live = 8

[overlay]
spaces = 2
heartbeat_ms = 500
failure_multiple = 3
repair_probe_ms = 2000

[net]
latency_ms = 0.0
jitter = 0.0
seed = 9

[phase.1]
kind = "poisson_churn"
at_ms = 5000
join_per_min = 2.0
fail_per_min = 1.0
leave_per_min = 0.0
window_ms = 60000

[phase.2]
kind = "poison"
at_ms = 70000
mode = "nan"
frac = 0.25
"#;

/// One full scenario trainer run. Returns the report, whether every
/// honest (non-Byzantine) client's parameters are finite, and the total
/// guard-rejected model count.
fn run_spec(engine: &Engine, spec: &ScenarioSpec, agg: Aggregation) -> (ScenarioReport, bool, u64) {
    let classes = engine.manifest.task("mlp").expect("mlp task").classes;
    let joins = spec
        .compile()
        .iter()
        .filter(|e| matches!(e.op, ChurnOp::Join { .. }))
        .count();
    let cfg = DflConfig {
        clients: spec.initial,
        seed: spec.seed,
        // wake every 20 sim-seconds so the 5-minute horizon holds ~15
        // exchange rounds per client
        comm_period_ms: 20_000,
        ..DflConfig::default()
    };
    let weights = shard_labels(spec.initial + joins, classes, cfg.shards_per_client, cfg.seed);
    let method = MethodSpec::fedlay_dynamic(spec.overlay.clone(), spec.net.clone())
        .with_aggregation(agg);
    let mut trainer =
        Trainer::new(engine, method, cfg, weights[..spec.initial].to_vec()).expect("trainer");
    let report = spec
        .run_trainer(&mut trainer, |id| weights[id].clone())
        .expect("scenario trainer run");
    let honest_finite = trainer
        .clients()
        .iter()
        .filter(|c| !c.byzantine)
        .all(|c| c.params.iter().all(|v| v.is_finite()));
    let rejected = trainer.rejected_models_total();
    (report, honest_finite, rejected)
}

/// The ISSUE acceptance pin: PoissonChurn + Poison{nan}. The guard
/// keeps every rule's honest clients NaN-free; the honest-vs-Byzantine
/// accuracy gap opens under Mean; TrimmedMean / Median / Krum on the
/// same seed end within 0.05 of the clean run's final accuracy.
#[test]
fn nan_poison_acceptance_gap_opens_and_robust_rules_track_clean() {
    let dir = find_artifacts_dir(None).expect("artifacts");
    let engine = Engine::load(&dir, &["mlp"]).expect("engine");
    let clean_spec = ScenarioSpec::from_toml_str(CLEAN_SPEC).expect("clean spec");
    let attacked_spec = ScenarioSpec::from_toml_str(ATTACKED_SPEC).expect("attacked spec");

    // clean baseline: no attacks compiled, no gap series, nothing rejected
    let (clean, clean_finite, clean_rejected) = run_spec(&engine, &clean_spec, Aggregation::Mean);
    assert!(clean_finite);
    assert_eq!(clean_rejected, 0, "clean run rejected models");
    assert_eq!(clean.attacks.total(), 0);
    assert!(clean.accuracy_gap.is_empty(), "clean run grew a gap series");
    let clean_final = clean.accuracy.last().expect("clean accuracy").1;
    assert!(clean_final > 0.2, "clean run failed to learn: {clean_final}");

    // Mean under NaN poison: attackers serve NaN forever, the guard
    // rejects every pull, honest params stay finite, and the gap series
    // shows honest clients pulling away from the chance-level attackers
    let (mean_r, mean_finite, mean_rejected) =
        run_spec(&engine, &attacked_spec, Aggregation::Mean);
    assert!(mean_finite, "NaN leaked into an honest model under Mean");
    assert!(mean_rejected > 0, "guard never fired under Mean");
    assert!(mean_r.attacks.poisoned > 0, "no attackers compiled");
    assert!(!mean_r.accuracy_gap.is_empty(), "no gap series under attack");
    let first_gap = mean_r.accuracy_gap.first().unwrap().1;
    let last_gap = mean_r.accuracy_gap.last().unwrap().1;
    assert!(last_gap >= 0.05, "accuracy gap never opened: {last_gap}");
    assert!(
        last_gap >= first_gap - 0.05,
        "gap collapsed: first {first_gap}, last {last_gap}"
    );

    // robust rules, same seed: final accuracy within 0.05 of the clean run
    for agg in ROBUST {
        let (r, finite, rejected) = run_spec(&engine, &attacked_spec, agg);
        assert!(finite, "NaN leaked into an honest model under {agg:?}");
        assert!(rejected > 0, "guard never fired under {agg:?}");
        assert!(!r.accuracy_gap.is_empty());
        let final_acc = r.accuracy.last().expect("accuracy").1;
        assert!(
            (final_acc - clean_final).abs() <= 0.05,
            "{agg:?} drifted from clean: attacked {final_acc}, clean {clean_final}"
        );
    }
}
