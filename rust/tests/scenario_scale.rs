//! 10,000-, 100,000- and 500,000-client scale: `PoissonChurn` scenarios
//! driving the *full* unified trainer (frozen training, real NDMP
//! overlay, real MEP aggregation paths) on the in-memory transport —
//! and, at 500k, the bare overlay simulation alone. Exercises the
//! neighbor-set cache (`Trainer::neighbor_cache_stats`) that makes
//! `Neighborhood::Dynamic` tractable at this scale, the incremental
//! Definition-1 ideal tallies (`Simulator::correctness` is O(1) per
//! sample; docs/perf.md), the O(L·n log n) bootstrap, and — at 100k and
//! above — the sharded event engine (`Simulator::set_shards`) plus the
//! O(live-set) footprint guarantees.
//!
//! Ignored under plain `cargo test` (they are release-mode budget
//! tests); CI runs them explicitly under `timeout`:
//!   cargo test --release --test scenario_scale -- --ignored

use fedlay::config::{DflConfig, NetConfig, OverlayConfig};
use fedlay::data::shard_labels;
use fedlay::dfl::{MethodSpec, Trainer};
use fedlay::ndmp::messages::{Time, SEC};
use fedlay::runtime::{find_artifacts_dir, Engine};
use fedlay::sim::{quiesce, ChurnOp, Phase, PhaseKind, ScenarioSpec};

const MIN: Time = 60 * SEC;

#[test]
#[ignore = "10k-client release-mode scale run; CI invokes it explicitly"]
fn poisson_churn_scenario_scales_to_10k_clients() -> anyhow::Result<()> {
    let n = 10_000usize;
    // slow maintenance timers: at 10k nodes a 30 s heartbeat keeps the
    // protocol load proportionate to the 30-minute training horizon
    let overlay = OverlayConfig {
        spaces: 2,
        heartbeat_ms: 30_000,
        failure_multiple: 3,
        repair_probe_ms: 60_000,
    };
    let net = NetConfig {
        latency_ms: 100.0,
        jitter: 0.1,
        seed: 71,
        ..NetConfig::default()
    };
    let spec = ScenarioSpec {
        name: "poisson-10k".into(),
        initial: n,
        seed: 71,
        horizon: 30 * MIN,
        sample_every: 30 * MIN, // endpoints only: eval cost, not protocol
        settle: 0,
        min_live: n / 2,
        shards: 1,
        overlay: overlay.clone(),
        net: net.clone(),
        phases: vec![Phase {
            at: MIN,
            kind: PhaseKind::PoissonChurn {
                join_per_min: 8.0,
                fail_per_min: 5.0,
                leave_per_min: 3.0,
                window: 10 * MIN,
            },
        }],
    };
    let events = spec.compile();
    let joins = events
        .iter()
        .filter(|e| matches!(e.op, ChurnOp::Join { .. }))
        .count();
    assert!(joins > 0, "scenario scheduled no joins");

    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &["mlp"])?;
    let cfg = DflConfig {
        task: "mlp".into(),
        clients: n,
        local_steps: 1,
        seed: 71,
        ..DflConfig::default()
    };
    let weights = shard_labels(n + joins, 10, cfg.shards_per_client, cfg.seed);
    let mut trainer = Trainer::new(
        &engine,
        MethodSpec::fedlay_dynamic(overlay, net),
        cfg,
        weights[..n].to_vec(),
    )?;
    // scalability mode (Fig. 20 methodology): protocol, exchange, and
    // aggregation all run for real; only the SGD inner loop is skipped
    trainer.freeze_training = true;

    let report = spec.run_trainer(&mut trainer, |id| weights[id].clone())?;

    // the neighbor cache must carry the steady-state load
    assert!(
        report.cache_hits > report.cache_misses,
        "cache not effective: {} hits / {} misses",
        report.cache_hits,
        report.cache_misses
    );
    assert!(
        report.cache_hits + report.cache_misses >= n as u64,
        "every client should consult its neighborhood at least once"
    );

    // membership arithmetic holds at scale
    assert_eq!(
        report.live_nodes,
        n + report.counts.joins - report.counts.fails - report.counts.leaves,
        "lost or zombie overlay members"
    );
    assert!(report.accuracy.iter().all(|(_, a)| a.is_finite()));

    // the overlay must repair to the exact ideal rings after the churn
    // window (~19 quiet minutes already elapsed; allow 20 more)
    let sim = trainer.overlay.as_mut().expect("dynamic overlay state");
    let deadline = sim.now + 20 * MIN;
    let settled = quiesce(sim, deadline, 2 * MIN);
    assert!(
        settled.is_some(),
        "10k overlay did not quiesce: correctness {:.4}",
        sim.correctness()
    );
    Ok(())
}

/// The ROADMAP north star: 100k clients through the full trainer over
/// the 16-shard event engine. Maintenance timers slow by another 2x
/// against the 10k pin (the protocol load per virtual minute is 10x),
/// sampling is endpoints-only, and training is frozen — protocol,
/// exchange, fingerprinting, and aggregation all run for real.
#[test]
#[ignore = "100k-client release-mode scale run; CI invokes it explicitly"]
fn poisson_churn_scenario_scales_to_100k_clients_sharded() -> anyhow::Result<()> {
    let n = 100_000usize;
    let overlay = OverlayConfig {
        spaces: 2,
        heartbeat_ms: 60_000,
        failure_multiple: 3,
        repair_probe_ms: 120_000,
    };
    let net = NetConfig {
        latency_ms: 100.0,
        jitter: 0.1,
        seed: 73,
        ..NetConfig::default()
    };
    let spec = ScenarioSpec {
        name: "poisson-100k".into(),
        initial: n,
        seed: 73,
        horizon: 15 * MIN,
        sample_every: 15 * MIN, // endpoints only: eval cost, not protocol
        settle: 0,
        min_live: n / 2,
        shards: 16,
        overlay: overlay.clone(),
        net: net.clone(),
        phases: vec![Phase {
            at: MIN,
            kind: PhaseKind::PoissonChurn {
                join_per_min: 8.0,
                fail_per_min: 5.0,
                leave_per_min: 3.0,
                window: 5 * MIN,
            },
        }],
    };
    let events = spec.compile();
    let joins = events
        .iter()
        .filter(|e| matches!(e.op, ChurnOp::Join { .. }))
        .count();
    assert!(joins > 0, "scenario scheduled no joins");

    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &["mlp"])?;
    let cfg = DflConfig {
        task: "mlp".into(),
        clients: n,
        local_steps: 1,
        seed: 73,
        ..DflConfig::default()
    };
    let weights = shard_labels(n + joins, 10, cfg.shards_per_client, cfg.seed);
    let mut trainer = Trainer::new(
        &engine,
        MethodSpec::fedlay_dynamic(overlay, net),
        cfg,
        weights[..n].to_vec(),
    )?;
    trainer.freeze_training = true;

    let report = spec.run_trainer(&mut trainer, |id| weights[id].clone())?;

    assert_eq!(
        report.live_nodes,
        n + report.counts.joins - report.counts.fails - report.counts.leaves,
        "lost or zombie overlay members"
    );
    assert!(report.accuracy.iter().all(|(_, a)| a.is_finite()));
    assert!(
        report.cache_hits > report.cache_misses,
        "cache not effective: {} hits / {} misses",
        report.cache_hits,
        report.cache_misses
    );

    // O(live-set) guarantees at scale: departed nodes fold into scalar
    // tallies and recycled arena slots never exceed the peak live set
    let sim = trainer.overlay.as_mut().expect("dynamic overlay state");
    let fp = sim.footprint();
    assert_eq!(fp.retired_nodes, (report.counts.fails + report.counts.leaves) as u64);
    assert!(
        fp.arena_slots <= n + report.counts.joins,
        "arena slots {} exceed peak possible live set",
        fp.arena_slots
    );

    // repair budget: failure detection is 3 silent 60 s heartbeats, so
    // allow a generous post-horizon window to reach the exact ideal rings
    let deadline = sim.now + 40 * MIN;
    let settled = quiesce(sim, deadline, 2 * MIN);
    assert!(
        settled.is_some(),
        "100k overlay did not quiesce: correctness {:.4}",
        sim.correctness()
    );
    Ok(())
}

/// Half a million clients through the bare overlay simulation (no
/// trainer, no artifacts): the road-to-1M pin. Feasible only because
/// correctness sampling reads the maintained incremental tallies —
/// the batch rebuild alone would dominate the run at this size.
/// Maintenance timers slow another 2x against the 100k pin to keep the
/// protocol event volume per virtual minute bounded.
#[test]
#[ignore = "500k-client release-mode scale run; CI invokes it explicitly"]
fn poisson_churn_scenario_scales_to_500k_clients_sim_only() -> anyhow::Result<()> {
    let n = 500_000usize;
    let overlay = OverlayConfig {
        spaces: 2,
        heartbeat_ms: 120_000,
        failure_multiple: 3,
        repair_probe_ms: 240_000,
    };
    let net = NetConfig {
        latency_ms: 100.0,
        jitter: 0.1,
        seed: 79,
        ..NetConfig::default()
    };
    let spec = ScenarioSpec {
        name: "poisson-500k".into(),
        initial: n,
        seed: 79,
        horizon: 10 * MIN,
        sample_every: 10 * MIN, // endpoints only: eval cost, not protocol
        settle: 0,
        min_live: n / 2,
        shards: 16,
        overlay,
        net,
        phases: vec![Phase {
            at: MIN,
            kind: PhaseKind::PoissonChurn {
                join_per_min: 8.0,
                fail_per_min: 5.0,
                leave_per_min: 3.0,
                window: 5 * MIN,
            },
        }],
    };
    let events = spec.compile();
    let joins = events
        .iter()
        .filter(|e| matches!(e.op, ChurnOp::Join { .. }))
        .count();
    assert!(joins > 0, "scenario scheduled no joins");

    let (sim, report) = spec.run_sim(None)?;

    assert!(sim.shard_count() >= 16, "500k pin must run sharded");
    assert_eq!(
        report.live_nodes,
        n + report.counts.joins - report.counts.fails - report.counts.leaves,
        "lost or zombie overlay members"
    );
    assert!(
        report.final_correctness > 0.99,
        "500k overlay badly degraded: correctness {:.4}",
        report.final_correctness
    );

    // O(live-set) guarantees at scale: departed nodes fold into scalar
    // tallies and recycled arena slots never exceed the peak live set
    let fp = sim.footprint();
    assert_eq!(fp.retired_nodes, (report.counts.fails + report.counts.leaves) as u64);
    assert!(
        fp.arena_slots <= n + report.counts.joins,
        "arena slots {} exceed peak possible live set",
        fp.arena_slots
    );

    // the incremental tallies must agree exactly with the batch oracle
    // on the final membership — one O(n log n) rebuild, paid once
    let inc = sim.correctness();
    let batch = sim.correctness_batch();
    assert_eq!(
        inc.to_bits(),
        batch.to_bits(),
        "incremental {inc} != batch {batch} at 500k"
    );
    Ok(())
}
