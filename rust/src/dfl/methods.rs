//! DFL method specifications (paper §IV-A4): FedLay and the comparators
//! (FedAvg, Gaia, DFL-DDS, Chord-DFL), expressed as (neighborhood
//! structure, aggregation weighting, synchrony) triples consumed by the
//! trainer.

use crate::baselines;
use crate::graph::Graph;
use crate::topology::fedlay_graph;
use crate::util::Rng;

/// Who aggregates with whom at each exchange.
#[derive(Debug, Clone)]
pub enum Neighborhood {
    /// Fixed overlay graph (FedLay, Chord, complete, ...).
    Static(Graph),
    /// Central server: every client averages with everyone (FedAvg).
    Star,
    /// Gaia's geo-regions: complete graph inside a region, region servers
    /// synchronize as a complete graph. `assignment[i]` = region of i.
    Regions { assignment: Vec<usize>, regions: usize },
    /// DFL-DDS mobility: nodes move (random waypoint on the unit square)
    /// and connect to their `k` nearest at each exchange.
    Mobility { k: usize, speed: f64, seed: u64 },
    /// Live NDMP overlay: the trainer embeds a `sim::Simulator` advanced
    /// in lockstep with training time, and a client's aggregation
    /// neighbors at time `t` are read from its protocol `NodeState` views.
    /// Mid-training joins/failures rewire the learning graph through the
    /// actual join/repair protocols (paper Figs. 18/19).
    Dynamic {
        overlay: crate::config::OverlayConfig,
        net: crate::config::NetConfig,
    },
}

#[derive(Debug, Clone)]
pub struct MethodSpec {
    pub name: String,
    pub neighborhood: Neighborhood,
    /// MEP confidence weighting (false = simple average, the comparators).
    pub confidence: bool,
    /// Asynchronous per-client periods (false = global synchronous rounds).
    pub asynchronous: bool,
}

impl MethodSpec {
    pub fn fedlay(n: usize, spaces: usize) -> Self {
        Self {
            name: format!("fedlay-L{spaces}"),
            neighborhood: Neighborhood::Static(fedlay_graph(n, spaces)),
            confidence: true,
            asynchronous: true,
        }
    }

    /// FedLay over the *live* NDMP overlay: neighborhoods are read from an
    /// embedded protocol simulation, so churn scheduled on the trainer
    /// rewires the topology mid-training.
    pub fn fedlay_dynamic(
        overlay: crate::config::OverlayConfig,
        net: crate::config::NetConfig,
    ) -> Self {
        Self {
            name: format!("fedlay-dyn-L{}", overlay.spaces),
            neighborhood: Neighborhood::Dynamic { overlay, net },
            confidence: true,
            asynchronous: true,
        }
    }

    /// Multi-task FedLay: N independent model tasks over one live NDMP
    /// overlay — the trainer grows one `TaskLane` per task and every
    /// lane reads the same protocol neighborhoods (`Trainer::new_multi`,
    /// `dfl::multitask`).
    pub fn fedlay_multi(
        overlay: crate::config::OverlayConfig,
        net: crate::config::NetConfig,
        tasks: usize,
    ) -> Self {
        Self {
            name: format!("fedlay-multi{tasks}-L{}", overlay.spaces),
            neighborhood: Neighborhood::Dynamic { overlay, net },
            confidence: true,
            asynchronous: true,
        }
    }

    /// FedLay over an explicit (e.g. NDMP-built) overlay graph.
    pub fn fedlay_with_graph(g: Graph) -> Self {
        Self {
            name: "fedlay".into(),
            neighborhood: Neighborhood::Static(g),
            confidence: true,
            asynchronous: true,
        }
    }

    /// Ablation: FedLay topology with plain averaging (Figs. 16/17).
    pub fn fedlay_simple_avg(n: usize, spaces: usize) -> Self {
        Self {
            name: format!("fedlay-avg-L{spaces}"),
            neighborhood: Neighborhood::Static(fedlay_graph(n, spaces)),
            confidence: false,
            asynchronous: true,
        }
    }

    /// Ablation: synchronous FedLay (Fig. 12).
    pub fn fedlay_sync(n: usize, spaces: usize) -> Self {
        Self {
            name: format!("fedlay-sync-L{spaces}"),
            neighborhood: Neighborhood::Static(fedlay_graph(n, spaces)),
            confidence: true,
            asynchronous: false,
        }
    }

    pub fn chord(n: usize) -> Self {
        Self {
            name: "chord".into(),
            neighborhood: Neighborhood::Static(baselines::chord(n)),
            confidence: false,
            asynchronous: true,
        }
    }

    /// The fully-connected "theoretical upper bound" (paper Fig. 13).
    /// Synchronous rounds: with asynchronous gossip a complete graph
    /// over-dilutes each client's fresh update by 1/N per wake, which is
    /// *not* the bound the paper means.
    pub fn complete(n: usize) -> Self {
        Self {
            name: "complete".into(),
            neighborhood: Neighborhood::Static(baselines::complete(n)),
            confidence: false,
            asynchronous: false,
        }
    }

    pub fn fedavg() -> Self {
        Self {
            name: "fedavg".into(),
            neighborhood: Neighborhood::Star,
            confidence: false,
            asynchronous: false, // central rounds are synchronous
        }
    }

    pub fn gaia(n: usize, regions: usize) -> Self {
        // contiguous geographic regions
        let assignment = (0..n).map(|i| i * regions / n).collect();
        Self {
            name: format!("gaia-{regions}r"),
            neighborhood: Neighborhood::Regions { assignment, regions },
            confidence: false,
            asynchronous: false,
        }
    }

    pub fn dfl_dds(seed: u64) -> Self {
        Self {
            name: "dfl-dds".into(),
            neighborhood: Neighborhood::Mobility {
                k: 4,
                speed: 0.05,
                seed,
            },
            confidence: false,
            asynchronous: true,
        }
    }
}

/// Random-waypoint mobility state for DFL-DDS.
#[derive(Debug, Clone)]
pub struct Mobility {
    pos: Vec<(f64, f64)>,
    dst: Vec<(f64, f64)>,
    speed: f64,
    k: usize,
    rng: Rng,
}

impl Mobility {
    pub fn new(n: usize, k: usize, speed: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xDD5);
        let pos: Vec<(f64, f64)> = (0..n).map(|_| (rng.next_f64(), rng.next_f64())).collect();
        let dst: Vec<(f64, f64)> = (0..n).map(|_| (rng.next_f64(), rng.next_f64())).collect();
        Self {
            pos,
            dst,
            speed,
            k,
            rng,
        }
    }

    /// Advance one epoch of movement and return the k-NN contact graph.
    pub fn step(&mut self) -> Graph {
        let n = self.pos.len();
        for i in 0..n {
            let (px, py) = self.pos[i];
            let (dx, dy) = self.dst[i];
            let dist = ((dx - px).powi(2) + (dy - py).powi(2)).sqrt();
            if dist < self.speed {
                self.pos[i] = self.dst[i];
                self.dst[i] = (self.rng.next_f64(), self.rng.next_f64());
            } else {
                let t = self.speed / dist;
                self.pos[i] = (px + (dx - px) * t, py + (dy - py) * t);
            }
        }
        let mut g = Graph::new(n);
        for i in 0..n {
            let mut others: Vec<usize> = (0..n).filter(|&j| j != i).collect();
            others.sort_by(|&a, &b| {
                let da = (self.pos[a].0 - self.pos[i].0).powi(2)
                    + (self.pos[a].1 - self.pos[i].1).powi(2);
                let db = (self.pos[b].0 - self.pos[i].0).powi(2)
                    + (self.pos[b].1 - self.pos[i].1).powi(2);
                da.partial_cmp(&db).unwrap()
            });
            for &j in others.iter().take(self.k) {
                g.add_edge(i, j);
            }
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_have_expected_shapes() {
        let f = MethodSpec::fedlay(40, 3);
        assert!(f.confidence && f.asynchronous);
        match &f.neighborhood {
            Neighborhood::Static(g) => assert_eq!(g.n(), 40),
            _ => panic!(),
        }
        let fa = MethodSpec::fedavg();
        assert!(!fa.asynchronous);
        let g = MethodSpec::gaia(100, 10);
        match &g.neighborhood {
            Neighborhood::Regions { assignment, regions } => {
                assert_eq!(*regions, 10);
                assert_eq!(assignment.len(), 100);
                assert_eq!(assignment[0], 0);
                assert_eq!(assignment[99], 9);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn mobility_moves_and_connects() {
        let mut m = Mobility::new(30, 4, 0.05, 1);
        let before = m.pos.clone();
        let g1 = m.step();
        assert!(g1.n() == 30 && g1.m() > 0);
        assert!((0..30).all(|u| g1.degree(u) >= 4));
        let moved = m
            .pos
            .iter()
            .zip(&before)
            .any(|(a, b)| (a.0 - b.0).abs() + (a.1 - b.1).abs() > 1e-9);
        assert!(moved);
        // graph changes over time
        for _ in 0..20 {
            m.step();
        }
        let g2 = m.step();
        assert_ne!(g1.edges(), g2.edges());
    }
}
