//! DFL method specifications (paper §IV-A4): FedLay and the comparators
//! (FedAvg, Gaia, DFL-DDS, Chord-DFL), expressed as (neighborhood
//! structure, aggregation weighting, synchrony) triples consumed by the
//! trainer.

use crate::baselines;
use crate::graph::Graph;
use crate::mep::{densify_topk, dequantize_q8, quantize_q8, sparsify_topk, Aggregation};
use crate::topology::fedlay_graph;
use crate::util::Rng;

/// How MEP model payloads travel between clients (paper §V comm-cost
/// study): dense f32, per-tensor i8 quantization, or top-k magnitude
/// sparsification. The trainer round-trips every pulled neighbor model
/// through the scheme (so learning sees exactly the wire-surviving
/// parameters) and charges the compressed byte count.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Compression {
    /// Dense f32 parameters — 4 bytes each, bit-exact (the default; all
    /// pre-existing behavior).
    None,
    /// Symmetric per-tensor i8 quantization (`mep::quantize_q8`):
    /// ~1 byte per parameter, ~4× fewer bytes than dense.
    Q8,
    /// Keep only the `keep` fraction of largest-magnitude parameters
    /// (`mep::sparsify_topk`): ~8 bytes per kept entry.
    TopK {
        /// Fraction of entries kept, in (0, 1].
        keep: f64,
    },
}

impl Compression {
    /// Parse a CLI/scenario flag: `none`, `q8`, or `topk:<keep>` (e.g.
    /// `topk:0.1` keeps the top 10% of entries).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "none" => Ok(Compression::None),
            "q8" => Ok(Compression::Q8),
            _ => {
                if let Some(frac) = s.strip_prefix("topk:") {
                    let keep: f64 = frac
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad top-k fraction {frac:?}"))?;
                    anyhow::ensure!(
                        keep > 0.0 && keep <= 1.0,
                        "top-k keep fraction must be in (0, 1], got {keep}"
                    );
                    Ok(Compression::TopK { keep })
                } else {
                    anyhow::bail!("unknown compression {s:?} (none | q8 | topk:<keep>)")
                }
            }
        }
    }

    /// How many entries a top-k scheme keeps of a `dim`-vector (at least
    /// one, so a nonzero model never compresses to nothing).
    pub fn kept(&self, dim: usize) -> usize {
        match self {
            Compression::TopK { keep } => {
                (((dim as f64) * keep).ceil() as usize).clamp(1, dim.max(1))
            }
            _ => dim,
        }
    }

    /// Model-parameter payload bytes for a `dim`-vector under this
    /// scheme. `None` charges exactly the dense `4 * dim` the trainer
    /// always charged, so existing byte accounting is unchanged.
    pub fn payload_bytes(&self, dim: usize) -> u64 {
        match self {
            Compression::None => 4 * dim as u64,
            // levels + the f32 scale
            Compression::Q8 => dim as u64 + 4,
            // u32 index + f32 value per kept entry, + the u32 dense dim
            Compression::TopK { .. } => 8 * self.kept(dim) as u64 + 4,
        }
    }

    /// Round-trip a parameter vector through the wire scheme: what the
    /// receiver reconstructs from the compressed payload. Identity for
    /// `None` (no copy-drift: callers get the same values back).
    pub fn roundtrip(&self, params: &[f32]) -> Vec<f32> {
        match self {
            Compression::None => params.to_vec(),
            Compression::Q8 => {
                let (scale, levels) = quantize_q8(params);
                dequantize_q8(scale, &levels)
            }
            Compression::TopK { .. } => {
                let (indices, values) = sparsify_topk(params, self.kept(params.len()));
                densify_topk(params.len(), &indices, &values)
            }
        }
    }

    /// Short label for reports (`none`, `q8`, `topk10`).
    pub fn label(&self) -> String {
        match self {
            Compression::None => "none".into(),
            Compression::Q8 => "q8".into(),
            Compression::TopK { keep } => format!("topk{}", (keep * 100.0).round() as u64),
        }
    }
}

/// Who aggregates with whom at each exchange.
#[derive(Debug, Clone)]
pub enum Neighborhood {
    /// Fixed overlay graph (FedLay, Chord, complete, ...).
    Static(Graph),
    /// Central server: every client averages with everyone (FedAvg).
    Star,
    /// Gaia's geo-regions: complete graph inside a region, region servers
    /// synchronize as a complete graph. `assignment[i]` = region of i.
    Regions { assignment: Vec<usize>, regions: usize },
    /// DFL-DDS mobility: nodes move (random waypoint on the unit square)
    /// and connect to their `k` nearest at each exchange.
    Mobility { k: usize, speed: f64, seed: u64 },
    /// Live NDMP overlay: the trainer embeds a `sim::Simulator` advanced
    /// in lockstep with training time, and a client's aggregation
    /// neighbors at time `t` are read from its protocol `NodeState` views.
    /// Mid-training joins/failures rewire the learning graph through the
    /// actual join/repair protocols (paper Figs. 18/19).
    Dynamic {
        overlay: crate::config::OverlayConfig,
        net: crate::config::NetConfig,
    },
}

#[derive(Debug, Clone)]
pub struct MethodSpec {
    pub name: String,
    pub neighborhood: Neighborhood,
    /// MEP confidence weighting (false = simple average, the comparators).
    pub confidence: bool,
    /// Asynchronous per-client periods (false = global synchronous rounds).
    pub asynchronous: bool,
    /// Model-payload wire scheme (`Compression::None` = dense f32, the
    /// historical behavior of every constructor).
    pub compression: Compression,
    /// How pulled neighbor models are combined (`Aggregation::Mean` =
    /// the paper's confidence-weighted mean, bitwise-identical to the
    /// historical behavior; the robust rules tolerate Byzantine peers).
    pub aggregation: Aggregation,
}

impl MethodSpec {
    /// Same method, exchanging compressed model payloads: pulled models
    /// are round-tripped through `compression` and byte accounting
    /// charges the compressed size.
    pub fn with_compression(mut self, compression: Compression) -> Self {
        self.compression = compression;
        if compression != Compression::None {
            self.name = format!("{}+{}", self.name, compression.label());
        }
        self
    }

    /// Same method under a Byzantine-robust aggregation rule
    /// (`mep::Aggregation`). `Mean` leaves the method name — and every
    /// clean-run trajectory — untouched.
    pub fn with_aggregation(mut self, aggregation: Aggregation) -> Self {
        self.aggregation = aggregation;
        if aggregation != Aggregation::Mean {
            self.name = format!("{}+{}", self.name, aggregation.label());
        }
        self
    }

    pub fn fedlay(n: usize, spaces: usize) -> Self {
        Self {
            name: format!("fedlay-L{spaces}"),
            neighborhood: Neighborhood::Static(fedlay_graph(n, spaces)),
            confidence: true,
            asynchronous: true,
            compression: Compression::None,
            aggregation: Aggregation::Mean,
        }
    }

    /// FedLay over the *live* NDMP overlay: neighborhoods are read from an
    /// embedded protocol simulation, so churn scheduled on the trainer
    /// rewires the topology mid-training.
    pub fn fedlay_dynamic(
        overlay: crate::config::OverlayConfig,
        net: crate::config::NetConfig,
    ) -> Self {
        Self {
            name: format!("fedlay-dyn-L{}", overlay.spaces),
            neighborhood: Neighborhood::Dynamic { overlay, net },
            confidence: true,
            asynchronous: true,
            compression: Compression::None,
            aggregation: Aggregation::Mean,
        }
    }

    /// Multi-task FedLay: N independent model tasks over one live NDMP
    /// overlay — the trainer grows one `TaskLane` per task and every
    /// lane reads the same protocol neighborhoods (`Trainer::new_multi`,
    /// `dfl::multitask`).
    pub fn fedlay_multi(
        overlay: crate::config::OverlayConfig,
        net: crate::config::NetConfig,
        tasks: usize,
    ) -> Self {
        Self {
            name: format!("fedlay-multi{tasks}-L{}", overlay.spaces),
            neighborhood: Neighborhood::Dynamic { overlay, net },
            confidence: true,
            asynchronous: true,
            compression: Compression::None,
            aggregation: Aggregation::Mean,
        }
    }

    /// FedLay over an explicit (e.g. NDMP-built) overlay graph.
    pub fn fedlay_with_graph(g: Graph) -> Self {
        Self {
            name: "fedlay".into(),
            neighborhood: Neighborhood::Static(g),
            confidence: true,
            asynchronous: true,
            compression: Compression::None,
            aggregation: Aggregation::Mean,
        }
    }

    /// Ablation: FedLay topology with plain averaging (Figs. 16/17).
    pub fn fedlay_simple_avg(n: usize, spaces: usize) -> Self {
        Self {
            name: format!("fedlay-avg-L{spaces}"),
            neighborhood: Neighborhood::Static(fedlay_graph(n, spaces)),
            confidence: false,
            asynchronous: true,
            compression: Compression::None,
            aggregation: Aggregation::Mean,
        }
    }

    /// Ablation: synchronous FedLay (Fig. 12).
    pub fn fedlay_sync(n: usize, spaces: usize) -> Self {
        Self {
            name: format!("fedlay-sync-L{spaces}"),
            neighborhood: Neighborhood::Static(fedlay_graph(n, spaces)),
            confidence: true,
            asynchronous: false,
            compression: Compression::None,
            aggregation: Aggregation::Mean,
        }
    }

    pub fn chord(n: usize) -> Self {
        Self {
            name: "chord".into(),
            neighborhood: Neighborhood::Static(baselines::chord(n)),
            confidence: false,
            asynchronous: true,
            compression: Compression::None,
            aggregation: Aggregation::Mean,
        }
    }

    /// The fully-connected "theoretical upper bound" (paper Fig. 13).
    /// Synchronous rounds: with asynchronous gossip a complete graph
    /// over-dilutes each client's fresh update by 1/N per wake, which is
    /// *not* the bound the paper means.
    pub fn complete(n: usize) -> Self {
        Self {
            name: "complete".into(),
            neighborhood: Neighborhood::Static(baselines::complete(n)),
            confidence: false,
            asynchronous: false,
            compression: Compression::None,
            aggregation: Aggregation::Mean,
        }
    }

    pub fn fedavg() -> Self {
        Self {
            name: "fedavg".into(),
            neighborhood: Neighborhood::Star,
            confidence: false,
            asynchronous: false, // central rounds are synchronous
            compression: Compression::None,
            aggregation: Aggregation::Mean,
        }
    }

    pub fn gaia(n: usize, regions: usize) -> Self {
        // contiguous geographic regions
        let assignment = (0..n).map(|i| i * regions / n).collect();
        Self {
            name: format!("gaia-{regions}r"),
            neighborhood: Neighborhood::Regions { assignment, regions },
            confidence: false,
            asynchronous: false,
            compression: Compression::None,
            aggregation: Aggregation::Mean,
        }
    }

    pub fn dfl_dds(seed: u64) -> Self {
        Self {
            name: "dfl-dds".into(),
            neighborhood: Neighborhood::Mobility {
                k: 4,
                speed: 0.05,
                seed,
            },
            confidence: false,
            asynchronous: true,
            compression: Compression::None,
            aggregation: Aggregation::Mean,
        }
    }
}

/// Random-waypoint mobility state for DFL-DDS.
#[derive(Debug, Clone)]
pub struct Mobility {
    pos: Vec<(f64, f64)>,
    dst: Vec<(f64, f64)>,
    speed: f64,
    k: usize,
    rng: Rng,
}

impl Mobility {
    pub fn new(n: usize, k: usize, speed: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xDD5);
        let pos: Vec<(f64, f64)> = (0..n).map(|_| (rng.next_f64(), rng.next_f64())).collect();
        let dst: Vec<(f64, f64)> = (0..n).map(|_| (rng.next_f64(), rng.next_f64())).collect();
        Self {
            pos,
            dst,
            speed,
            k,
            rng,
        }
    }

    /// Advance one epoch of movement and return the k-NN contact graph.
    pub fn step(&mut self) -> Graph {
        let n = self.pos.len();
        for i in 0..n {
            let (px, py) = self.pos[i];
            let (dx, dy) = self.dst[i];
            let dist = ((dx - px).powi(2) + (dy - py).powi(2)).sqrt();
            if dist < self.speed {
                self.pos[i] = self.dst[i];
                self.dst[i] = (self.rng.next_f64(), self.rng.next_f64());
            } else {
                let t = self.speed / dist;
                self.pos[i] = (px + (dx - px) * t, py + (dy - py) * t);
            }
        }
        let mut g = Graph::new(n);
        for i in 0..n {
            let mut others: Vec<usize> = (0..n).filter(|&j| j != i).collect();
            others.sort_by(|&a, &b| {
                let da = (self.pos[a].0 - self.pos[i].0).powi(2)
                    + (self.pos[a].1 - self.pos[i].1).powi(2);
                let db = (self.pos[b].0 - self.pos[i].0).powi(2)
                    + (self.pos[b].1 - self.pos[i].1).powi(2);
                da.partial_cmp(&db).unwrap()
            });
            for &j in others.iter().take(self.k) {
                g.add_edge(i, j);
            }
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_have_expected_shapes() {
        let f = MethodSpec::fedlay(40, 3);
        assert!(f.confidence && f.asynchronous);
        match &f.neighborhood {
            Neighborhood::Static(g) => assert_eq!(g.n(), 40),
            _ => panic!(),
        }
        let fa = MethodSpec::fedavg();
        assert!(!fa.asynchronous);
        let g = MethodSpec::gaia(100, 10);
        match &g.neighborhood {
            Neighborhood::Regions { assignment, regions } => {
                assert_eq!(*regions, 10);
                assert_eq!(assignment.len(), 100);
                assert_eq!(assignment[0], 0);
                assert_eq!(assignment[99], 9);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn compression_parses_sizes_and_labels() {
        assert_eq!(Compression::parse("none").unwrap(), Compression::None);
        assert_eq!(Compression::parse("q8").unwrap(), Compression::Q8);
        assert_eq!(
            Compression::parse("topk:0.1").unwrap(),
            Compression::TopK { keep: 0.1 }
        );
        assert!(Compression::parse("topk:0").is_err());
        assert!(Compression::parse("topk:1.5").is_err());
        assert!(Compression::parse("zstd").is_err());
        // byte accounting: None charges exactly the historical 4*dim
        assert_eq!(Compression::None.payload_bytes(100), 400);
        // q8 cuts bytes ~4x, topk:0.1 ~5x
        assert!(Compression::Q8.payload_bytes(1000) * 3 < 4_000);
        assert!(
            Compression::TopK { keep: 0.1 }.payload_bytes(1000) * 4 < 4_000
        );
        // a tiny model still ships at least one entry
        assert_eq!(Compression::TopK { keep: 0.01 }.kept(5), 1);
        assert_eq!(Compression::Q8.label(), "q8");
        assert_eq!(Compression::TopK { keep: 0.1 }.label(), "topk10");
    }

    #[test]
    fn compression_roundtrip_shapes() {
        let params = vec![1.0f32, -0.5, 0.25, 0.0, 2.0];
        // None is the identity
        assert_eq!(Compression::None.roundtrip(&params), params);
        // Q8 keeps the shape, values within half a quantization step
        let q = Compression::Q8.roundtrip(&params);
        assert_eq!(q.len(), params.len());
        let scale = 2.0 / 127.0;
        for (p, b) in params.iter().zip(q.iter()) {
            assert!((p - b).abs() <= scale * 0.5 + f32::EPSILON);
        }
        // TopK keeps the largest magnitudes exactly and zeroes the rest
        let t = Compression::TopK { keep: 0.4 }.roundtrip(&params);
        assert_eq!(t, vec![1.0, 0.0, 0.0, 0.0, 2.0]);
        // spec naming records the scheme
        let spec = MethodSpec::fedlay(10, 2).with_compression(Compression::Q8);
        assert_eq!(spec.compression, Compression::Q8);
        assert!(spec.name.ends_with("+q8"));
        let plain = MethodSpec::fedlay(10, 2).with_compression(Compression::None);
        assert!(!plain.name.contains('+'));
    }

    #[test]
    fn mobility_moves_and_connects() {
        let mut m = Mobility::new(30, 4, 0.05, 1);
        let before = m.pos.clone();
        let g1 = m.step();
        assert!(g1.n() == 30 && g1.m() > 0);
        assert!((0..30).all(|u| g1.degree(u) >= 4));
        let moved = m
            .pos
            .iter()
            .zip(&before)
            .any(|(a, b)| (a.0 - b.0).abs() + (a.1 - b.1).abs() > 1e-9);
        assert!(moved);
        // graph changes over time
        for _ in 0..20 {
            m.step();
        }
        let g2 = m.step();
        assert_ne!(g1.edges(), g2.edges());
    }
}
