//! Spec-level harness for the multi-task engine: build a [`Trainer`]
//! whose lanes come from a [`MultiTaskSpec`] (TOML, `configs/tasks/`),
//! generate each lane's non-iid shards for the *whole* eventual
//! population (originals plus scheduled joiners — shard draws depend on
//! the population size, so they are computed once up front exactly like
//! the single-task scenario harness), and drive churn scenarios through
//! the per-lane weight tables.
//!
//! The format and scheduling semantics are documented in
//! `docs/multitask.md`.

use super::methods::MethodSpec;
use super::trainer::Trainer;
use crate::config::{DflConfig, MultiTaskSpec, TaskSpec};
use crate::data::shard_labels;
use crate::runtime::Engine;
use crate::sim::{ChurnOp, ScenarioReport, ScenarioSpec, Transport};
use anyhow::Result;

/// Per-lane weight tables, indexed `[lane][client] -> label weights`.
pub type WeightTables = Vec<Vec<Vec<f64>>>;

/// Per-client label weights of one task for a population of `population`
/// clients — a pure function of the task's spec, so every backend and
/// every re-run derives the same shards.
pub fn lane_weights(
    engine: &Engine,
    task: &TaskSpec,
    population: usize,
) -> Result<Vec<Vec<f64>>> {
    let classes = engine.manifest.task(&task.task)?.classes;
    Ok(shard_labels(
        population,
        classes,
        task.shards_per_client,
        task.seed,
    ))
}

/// Build a multi-task trainer: `base.clients` initial clients, one lane
/// per task in `spec`, each with weight tables covering `population`
/// clients (>= `base.clients`; the surplus feeds scheduled joiners).
/// Returns the trainer plus the per-lane tables, indexed `[lane][client]`.
pub fn build_trainer<'e>(
    engine: &'e Engine,
    method: MethodSpec,
    base: DflConfig,
    spec: &MultiTaskSpec,
    population: usize,
) -> Result<(Trainer<'e>, WeightTables)> {
    spec.validate()?;
    anyhow::ensure!(
        population >= base.clients,
        "population {population} smaller than the initial {} clients",
        base.clients
    );
    let mut tables = Vec::with_capacity(spec.tasks.len());
    let mut tasks = Vec::with_capacity(spec.tasks.len());
    for t in &spec.tasks {
        let table = lane_weights(engine, t, population)?;
        tasks.push((t.clone(), table[..base.clients].to_vec()));
        tables.push(table);
    }
    let trainer = Trainer::new_multi(engine, method, base, tasks)?;
    Ok((trainer, tables))
}

/// Run a churn scenario as a multi-task training run: the scenario is
/// compiled once, the population (initial + scheduled joins) sizes every
/// lane's weight table, and joiners enter the shared overlay with
/// per-lane weights — the multi-task analogue of the CLI's single-task
/// `scenario run --trainer` path. `freeze` skips real training
/// (scalability mode); `transport` routes the shared overlay's protocol
/// traffic over an alternative backend (`None` = in-memory network).
pub fn run_scenario(
    engine: &Engine,
    scenario: &ScenarioSpec,
    tasks: &MultiTaskSpec,
    method: MethodSpec,
    base: DflConfig,
    freeze: bool,
    transport: Option<Box<dyn Transport>>,
) -> Result<ScenarioReport> {
    scenario.validate()?;
    anyhow::ensure!(
        base.clients == scenario.initial,
        "base config has {} clients, scenario starts from {}",
        base.clients,
        scenario.initial
    );
    let joins = scenario
        .compile()
        .iter()
        .filter(|e| matches!(e.op, ChurnOp::Join { .. }))
        .count();
    let population = scenario.initial + joins;
    let (mut trainer, tables) = build_trainer(engine, method, base, tasks, population)?;
    if let Some(t) = transport {
        trainer.set_transport(t)?;
    }
    trainer.freeze_training = freeze;
    scenario.run_trainer_tasks(&mut trainer, |lane, node| tables[lane][node].clone())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_weight_tables_are_deterministic_per_task() {
        // shard draws must be a pure function of (task spec, population):
        // replaying a schedule on another backend re-derives them
        let a = TaskSpec {
            name: "a".into(),
            task: "mlp".into(),
            shards_per_client: 8,
            local_steps: 1,
            lr: 0.5,
            comm_period_ms: 60_000,
            seed: 5,
        };
        let mut b = a.clone();
        b.seed = 6;
        let wa = shard_labels(12, 10, a.shards_per_client, a.seed);
        let wa2 = shard_labels(12, 10, a.shards_per_client, a.seed);
        let wb = shard_labels(12, 10, b.shards_per_client, b.seed);
        assert_eq!(wa, wa2);
        assert_ne!(wa, wb, "different task seeds must shard differently");
    }
}
