//! Shared experiment harness helpers for the per-figure bench binaries.

use super::methods::MethodSpec;
use super::trainer::{AccuracySample, Trainer};
use crate::bench_util::Table;
use crate::config::DflConfig;
use crate::data::shard_labels;
use crate::runtime::Engine;
use anyhow::Result;

/// Run one method for `minutes` of simulated time, sampling every
/// `sample_minutes`. Returns the trainer (samples + telemetry inside).
pub fn run_method<'e>(
    engine: &'e Engine,
    spec: MethodSpec,
    cfg: &DflConfig,
    minutes: u64,
    sample_minutes: u64,
) -> Result<Trainer<'e>> {
    let classes = engine.manifest.task(&cfg.task)?.classes;
    let weights = shard_labels(cfg.clients, classes, cfg.shards_per_client, cfg.seed);
    run_method_with_weights(engine, spec, cfg, weights, minutes, sample_minutes)
}

/// Same, with explicit per-client label weights (locality experiments).
pub fn run_method_with_weights<'e>(
    engine: &'e Engine,
    spec: MethodSpec,
    cfg: &DflConfig,
    weights: Vec<Vec<f64>>,
    minutes: u64,
    sample_minutes: u64,
) -> Result<Trainer<'e>> {
    let mut trainer = Trainer::new(engine, spec, cfg.clone(), weights)?;
    trainer.run(minutes * 60_000_000, sample_minutes * 60_000_000)?;
    Ok(trainer)
}

/// Render several methods' accuracy curves side by side.
pub fn curves_table(named: &[(&str, &[AccuracySample])]) -> Table {
    let mut headers: Vec<String> = vec!["t (min)".into()];
    headers.extend(named.iter().map(|(n, _)| n.to_string()));
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr_refs);
    let rows = named.iter().map(|(_, s)| s.len()).max().unwrap_or(0);
    for r in 0..rows {
        let mut cells = Vec::with_capacity(named.len() + 1);
        let at = named
            .iter()
            .filter_map(|(_, s)| s.get(r))
            .map(|s| s.at)
            .next()
            .unwrap_or(0);
        cells.push(format!("{:.0}", at as f64 / 60e6));
        for (_, s) in named {
            cells.push(
                s.get(r)
                    .map(|x| format!("{:.4}", x.mean_accuracy))
                    .unwrap_or_else(|| "-".into()),
            );
        }
        t.row(&cells);
    }
    t
}

/// Final mean accuracy of a run (primary lane).
pub fn final_acc(t: &Trainer) -> f64 {
    t.samples().last().map(|s| s.mean_accuracy).unwrap_or(0.0)
}

/// Mean accuracy of a client-index cohort in one sample — churn figures
/// track originals (`0..n`) and joiners (`n..`) separately; the unified
/// engine keeps `per_client` index-aligned across churn, so cohorts are
/// plain index ranges.
pub fn cohort_acc(sample: &AccuracySample, range: std::ops::Range<usize>) -> f64 {
    let xs = &sample.per_client[range];
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Simulated minutes needed to first reach `target` accuracy, if ever.
pub fn minutes_to_accuracy(samples: &[AccuracySample], target: f64) -> Option<f64> {
    samples
        .iter()
        .find(|s| s.mean_accuracy >= target)
        .map(|s| s.at as f64 / 60e6)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfl::trainer::AccuracySample;

    fn s(at_min: u64, acc: f64) -> AccuracySample {
        AccuracySample {
            at: at_min * 60_000_000,
            mean_accuracy: acc,
            mean_loss: 1.0,
            byz_mean_accuracy: None,
            per_client: vec![acc],
        }
    }

    #[test]
    fn cohort_acc_averages_ranges() {
        let s = AccuracySample {
            at: 0,
            mean_accuracy: 0.5,
            mean_loss: 1.0,
            byz_mean_accuracy: None,
            per_client: vec![0.2, 0.4, 0.6, 0.8],
        };
        assert!((cohort_acc(&s, 0..2) - 0.3).abs() < 1e-12);
        assert!((cohort_acc(&s, 2..4) - 0.7).abs() < 1e-12);
        assert_eq!(cohort_acc(&s, 1..1), 0.0);
    }

    #[test]
    fn minutes_to_accuracy_finds_first() {
        let xs = [s(0, 0.1), s(10, 0.4), s(20, 0.6), s(30, 0.7)];
        assert_eq!(minutes_to_accuracy(&xs, 0.5), Some(20.0));
        assert_eq!(minutes_to_accuracy(&xs, 0.9), None);
    }

    #[test]
    fn curves_table_aligns_methods() {
        let a = [s(0, 0.1), s(10, 0.5)];
        let b = [s(0, 0.2)];
        let t = curves_table(&[("a", &a), ("b", &b)]);
        let text = t.render();
        assert!(text.contains("0.5000"));
        assert!(text.lines().count() == 4);
    }
}
