//! The DFL training driver: runs any `MethodSpec` (FedLay or a comparator)
//! over the AOT runtime, with the paper's client heterogeneity, non-iid
//! shards, MEP confidence weighting, fingerprint de-dup accounting, and
//! accuracy sampling. Powers every accuracy figure (Figs. 9–19) and the
//! scalability/communication study (Fig. 20).

use super::client::ClientState;
use super::methods::{MethodSpec, Mobility, Neighborhood};
use crate::config::DflConfig;
use crate::data::{CharStream, GaussianTask};
use crate::mep::{
    aggregate_cpu, fingerprint, pack_for_artifact, Capacity, ConfidenceParams,
};
use crate::ndmp::messages::Time;
use crate::runtime::{Engine, XInput};

use anyhow::Result;

/// Client-local dataset generator.
pub enum TaskData {
    Gaussian(GaussianTask),
    /// One Markov stream per client (built from its shard labels as roles).
    Char(Vec<CharStream>),
}

/// One recorded accuracy sample.
#[derive(Debug, Clone)]
pub struct AccuracySample {
    pub at: Time,
    pub mean_accuracy: f64,
    pub mean_loss: f64,
    pub per_client: Vec<f64>,
}

pub struct Trainer<'e> {
    pub engine: &'e Engine,
    pub task_name: String,
    pub spec: MethodSpec,
    pub cfg: DflConfig,
    pub clients: Vec<ClientState>,
    pub samples: Vec<AccuracySample>,
    data: TaskData,
    mobility: Option<Mobility>,
    conf: ConfidenceParams,
    pub now: Time,
    /// Evaluation batches (cached: same test set for every sample).
    eval_x: Vec<Vec<f32>>,
    eval_xi: Vec<Vec<i32>>,
    eval_y: Vec<Vec<i32>>,
    /// Skip real training (scalability mode: reuse pre-trained params).
    pub freeze_training: bool,
}

impl<'e> Trainer<'e> {
    pub fn new(
        engine: &'e Engine,
        spec: MethodSpec,
        cfg: DflConfig,
        label_weights: Vec<Vec<f64>>,
    ) -> Result<Self> {
        let info = engine.manifest.task(&cfg.task)?.clone();
        let n = cfg.clients;
        anyhow::ensure!(label_weights.len() == n, "weights per client mismatch");
        let base_period = cfg.comm_period_ms * 1_000;
        let mut clients = Vec::with_capacity(n);
        // All clients share one initialization (standard DFL practice:
        // averaging independently-initialized nets cancels their features
        // due to permutation symmetry).
        let init_params = engine.init(&cfg.task, [cfg.seed as u32, 0])?;
        for (i, w) in label_weights.iter().enumerate() {
            let cap = Capacity::assign(i, n);
            let params = init_params.clone();
            clients.push(ClientState::new(
                i,
                cap,
                base_period,
                w.clone(),
                params,
                cfg.seed ^ 0xC11E,
            ));
        }
        // synchronous mode: everyone runs at the slowest tier's period
        if !spec.asynchronous {
            let max_period = clients.iter().map(|c| c.schedule.period).max().unwrap();
            for c in &mut clients {
                c.schedule.period = max_period;
                c.schedule.synchronous = true;
                c.next_wake = 0;
            }
        }
        let data = match cfg.task.as_str() {
            "lstm" => {
                let streams = label_weights
                    .iter()
                    .enumerate()
                    .map(|(i, w)| {
                        // each nonzero label acts as a Shakespeare "role"
                        let roles: Vec<u64> = w
                            .iter()
                            .enumerate()
                            .filter(|(_, &x)| x > 0.0)
                            .map(|(l, _)| cfg.seed ^ (l as u64 + 1))
                            .collect();
                        let roles = if roles.is_empty() { vec![cfg.seed] } else { roles };
                        CharStream::new(&roles, cfg.seed ^ (i as u64) << 8)
                    })
                    .collect();
                TaskData::Char(streams)
            }
            "cnn" => TaskData::Gaussian(GaussianTask::cifar_like(cfg.seed)),
            _ => TaskData::Gaussian(GaussianTask::mnist_like(cfg.seed)),
        };
        let mobility = match &spec.neighborhood {
            Neighborhood::Mobility { k, speed, seed } => {
                Some(Mobility::new(n, *k, *speed, *seed))
            }
            _ => None,
        };
        // fixed iid eval set: 2 batches
        let mut eval_x = Vec::new();
        let mut eval_xi = Vec::new();
        let mut eval_y = Vec::new();
        for e in 0..2u64 {
            match &data {
                TaskData::Gaussian(t) => {
                    let b = t.test_batch(info.batch, cfg.seed ^ (0xE0 + e));
                    eval_x.push(b.x);
                    eval_y.push(b.y);
                }
                TaskData::Char(_) => {
                    let roles: Vec<u64> = (0..10).map(|l| cfg.seed ^ (l + 1)).collect();
                    let mut s = CharStream::new(&roles, cfg.seed ^ (0xE0 + e));
                    let (x, y) = s.batch(info.batch, info.x_len);
                    eval_xi.push(x);
                    eval_y.push(y);
                }
            }
        }
        Ok(Self {
            engine,
            task_name: cfg.task.clone(),
            spec,
            cfg,
            clients,
            samples: Vec::new(),
            data,
            mobility,
            conf: ConfidenceParams::default(),
            now: 0,
            eval_x,
            eval_xi,
            eval_y,
            freeze_training: false,
        })
    }

    fn info_batch(&self) -> (usize, usize) {
        let info = self.engine.manifest.task(&self.task_name).unwrap();
        (info.batch, info.x_len)
    }

    /// Draw a local training batch for client `i`.
    fn draw_batch(&mut self, i: usize) -> (Vec<f32>, Vec<i32>, Vec<i32>) {
        let (batch, x_len) = self.info_batch();
        match &mut self.data {
            TaskData::Gaussian(t) => {
                let w = self.clients[i].label_weights.clone();
                let b = t.batch(batch, &w, &mut self.clients[i].rng);
                (b.x, Vec::new(), b.y)
            }
            TaskData::Char(streams) => {
                let (x, y) = streams[i].batch(batch, x_len);
                (Vec::new(), x, y)
            }
        }
    }

    fn local_train(&mut self, i: usize) -> Result<()> {
        if self.freeze_training {
            return Ok(());
        }
        for _ in 0..self.cfg.local_steps {
            let (xf, xi, y) = self.draw_batch(i);
            let x = if xf.is_empty() {
                XInput::I32(&xi)
            } else {
                XInput::F32(&xf)
            };
            let (new, _loss) =
                self.engine
                    .train_step(&self.task_name, &self.clients[i].params, &x, &y, self.cfg.lr)?;
            self.clients[i].params = new;
            self.clients[i].train_steps += 1;
        }
        self.clients[i].version += 1;
        Ok(())
    }

    /// Neighbor ids of client `i` at the current time.
    fn neighbors_of(&mut self, i: usize) -> Vec<usize> {
        match &self.spec.neighborhood {
            Neighborhood::Static(g) => g.neighbors(i).collect(),
            Neighborhood::Star => (0..self.clients.len()).filter(|&j| j != i).collect(),
            Neighborhood::Regions { assignment, .. } => {
                let r = assignment[i];
                (0..self.clients.len())
                    .filter(|&j| j != i && assignment[j] == r)
                    .collect()
            }
            Neighborhood::Mobility { .. } => {
                let g = self.mobility.as_mut().expect("mobility state").step();
                g.neighbors(i).collect()
            }
        }
    }

    /// MEP aggregation for client `i` over `nbrs` (paper §III-C2), with
    /// fingerprint de-dup accounting (§III-C3).
    fn aggregate(&mut self, i: usize, nbrs: &[usize]) -> Result<()> {
        if nbrs.is_empty() {
            return Ok(());
        }
        // fingerprint / transfer accounting: i "pulls" each neighbor's
        // latest model unless the fingerprint matches the last pull
        let p_bytes = (self.clients[i].params.len() * 4) as u64;
        for &j in nbrs {
            let fp = fingerprint(&self.clients[j].params);
            if self.clients[i].fingerprints.is_duplicate(j as u64, fp) {
                self.clients[i].dedup_skips += 1;
            } else {
                self.clients[i].fingerprints.record(j as u64, fp);
                // sender j pays the payload bytes
                self.clients[j].model_bytes_sent += p_bytes;
            }
        }
        // confidence weights normalized over the neighborhood ∪ {i}
        let hood: Vec<(f64, f64)> = std::iter::once(self.clients[i].raw_confidence())
            .chain(nbrs.iter().map(|&j| self.clients[j].raw_confidence()))
            .collect();
        let weights: Vec<f64> = if self.spec.confidence {
            hood.iter().map(|&own| self.conf.combine(own, &hood)).collect()
        } else {
            vec![1.0; hood.len()]
        };
        let k_max = self.engine.manifest.k_max;
        let new = if hood.len() <= k_max {
            // hot path: the L1 Pallas kernel inside the agg artifact
            let models: Vec<&[f32]> = std::iter::once(self.clients[i].params.as_slice())
                .chain(nbrs.iter().map(|&j| self.clients[j].params.as_slice()))
                .collect();
            let (stack, w) = pack_for_artifact(&models, &weights, k_max);
            self.engine.aggregate(&self.task_name, &stack, &w)?
        } else {
            // oversized neighborhood (complete graph / star): CPU fallback
            let models: Vec<&[f32]> = std::iter::once(self.clients[i].params.as_slice())
                .chain(nbrs.iter().map(|&j| self.clients[j].params.as_slice()))
                .collect();
            aggregate_cpu(&models, &weights)
        };
        self.clients[i].params = new;
        self.clients[i].version += 1;
        self.clients[i].exchanges += 1;
        Ok(())
    }

    /// Centralized FedAvg round: global average, broadcast to everyone.
    fn fedavg_round(&mut self) -> Result<()> {
        let models: Vec<&[f32]> = self.clients.iter().map(|c| c.params.as_slice()).collect();
        let weights = vec![1.0; models.len()];
        let global = aggregate_cpu(&models, &weights);
        let p_bytes = (global.len() * 4) as u64;
        for c in &mut self.clients {
            c.params = global.clone();
            c.version += 1;
            c.exchanges += 1;
            // upload + download through the server
            c.model_bytes_sent += 2 * p_bytes;
        }
        Ok(())
    }

    /// Gaia round: average within each region, then across region servers.
    fn gaia_round(&mut self, assignment: &[usize], regions: usize) -> Result<()> {
        let p = self.clients[0].params.len();
        let mut region_models = vec![vec![0.0f32; p]; regions];
        for r in 0..regions {
            let members: Vec<&[f32]> = self
                .clients
                .iter()
                .filter(|c| assignment[c.id] == r)
                .map(|c| c.params.as_slice())
                .collect();
            if members.is_empty() {
                continue;
            }
            region_models[r] = aggregate_cpu(&members, &vec![1.0; members.len()]);
        }
        // inter-region complete-graph averaging (region sizes equal)
        let refs: Vec<&[f32]> = region_models.iter().map(|m| m.as_slice()).collect();
        let global = aggregate_cpu(&refs, &vec![1.0; refs.len()]);
        let p_bytes = (p * 4) as u64;
        let members_per_region = (self.clients.len() / regions.max(1)).max(1) as u64;
        for c in &mut self.clients {
            c.params = global.clone();
            c.version += 1;
            c.exchanges += 1;
            // client <-> region server, plus the servers' complete-graph
            // exchange amortized over members
            c.model_bytes_sent += 2 * p_bytes + (regions as u64 - 1) * p_bytes / members_per_region;
        }
        Ok(())
    }

    /// Evaluate all clients on the fixed iid test set.
    pub fn evaluate(&mut self) -> Result<AccuracySample> {
        let (batch, _) = self.info_batch();
        let mut per_client = Vec::with_capacity(self.clients.len());
        let mut losses = 0.0;
        for c in &self.clients {
            let mut correct = 0.0f64;
            let mut loss = 0.0f64;
            let nb = self.eval_y.len();
            for e in 0..nb {
                let x = if !self.eval_x.is_empty() {
                    XInput::F32(&self.eval_x[e])
                } else {
                    XInput::I32(&self.eval_xi[e])
                };
                let (cr, lo) = self
                    .engine
                    .eval_step(&self.task_name, &c.params, &x, &self.eval_y[e])?;
                correct += cr as f64;
                loss += lo as f64;
            }
            per_client.push(correct / (nb * batch) as f64);
            losses += loss / nb as f64;
        }
        let sample = AccuracySample {
            at: self.now,
            mean_accuracy: per_client.iter().sum::<f64>() / per_client.len() as f64,
            mean_loss: losses / self.clients.len() as f64,
            per_client,
        };
        Ok(sample)
    }

    pub fn record_sample(&mut self) -> Result<()> {
        let s = self.evaluate()?;
        self.samples.push(s);
        Ok(())
    }

    /// Run until `until` (µs of simulated time), sampling accuracy every
    /// `sample_every`. Returns the final sample.
    pub fn run(&mut self, until: Time, sample_every: Time) -> Result<AccuracySample> {
        self.record_sample()?; // t = 0 baseline
        let mut next_sample = sample_every;
        match (&self.spec.neighborhood, self.spec.asynchronous) {
            // synchronous / centralized methods advance in global rounds
            (Neighborhood::Star, _) | (Neighborhood::Regions { .. }, _) | (_, false) => {
                let period = self.clients[0].schedule.period;
                let mut t = period;
                while t <= until {
                    self.now = t;
                    for i in 0..self.clients.len() {
                        self.local_train(i)?;
                    }
                    match self.spec.neighborhood.clone() {
                        Neighborhood::Star => self.fedavg_round()?,
                        Neighborhood::Regions { assignment, regions } => {
                            self.gaia_round(&assignment, regions)?
                        }
                        _ => {
                            // synchronous decentralized: everyone
                            // aggregates against pre-round snapshots
                            let snapshot: Vec<Vec<f32>> =
                                self.clients.iter().map(|c| c.params.clone()).collect();
                            for i in 0..self.clients.len() {
                                let nbrs = self.neighbors_of(i);
                                self.aggregate_snapshot(i, &nbrs, &snapshot)?;
                            }
                        }
                    }
                    while next_sample <= t {
                        self.record_sample()?;
                        next_sample += sample_every;
                    }
                    t += period;
                }
            }
            // asynchronous gossip: clients wake on their own periods
            _ => {
                loop {
                    let (idx, wake) = self
                        .clients
                        .iter()
                        .map(|c| c.next_wake)
                        .enumerate()
                        .min_by_key(|&(_, w)| w)
                        .unwrap();
                    if wake > until {
                        break;
                    }
                    while next_sample <= wake {
                        self.now = next_sample;
                        self.record_sample()?;
                        next_sample += sample_every;
                    }
                    self.now = wake;
                    self.local_train(idx)?;
                    let nbrs = self.neighbors_of(idx);
                    self.aggregate(idx, &nbrs)?;
                    let period = self.clients[idx].schedule.period;
                    self.clients[idx].next_wake = wake + period;
                }
            }
        }
        self.now = until;
        self.record_sample()?;
        Ok(self.samples.last().unwrap().clone())
    }

    /// Synchronous-round aggregation against a pre-round snapshot.
    fn aggregate_snapshot(
        &mut self,
        i: usize,
        nbrs: &[usize],
        snapshot: &[Vec<f32>],
    ) -> Result<()> {
        if nbrs.is_empty() {
            return Ok(());
        }
        let p_bytes = (snapshot[i].len() * 4) as u64;
        for &j in nbrs {
            let fp = fingerprint(&snapshot[j]);
            if self.clients[i].fingerprints.is_duplicate(j as u64, fp) {
                self.clients[i].dedup_skips += 1;
            } else {
                self.clients[i].fingerprints.record(j as u64, fp);
                self.clients[j].model_bytes_sent += p_bytes;
            }
        }
        let hood: Vec<(f64, f64)> = std::iter::once(self.clients[i].raw_confidence())
            .chain(nbrs.iter().map(|&j| self.clients[j].raw_confidence()))
            .collect();
        let weights: Vec<f64> = if self.spec.confidence {
            hood.iter().map(|&own| self.conf.combine(own, &hood)).collect()
        } else {
            vec![1.0; hood.len()]
        };
        let models: Vec<&[f32]> = std::iter::once(snapshot[i].as_slice())
            .chain(nbrs.iter().map(|&j| snapshot[j].as_slice()))
            .collect();
        let k_max = self.engine.manifest.k_max;
        let new = if models.len() <= k_max {
            let (stack, w) = pack_for_artifact(&models, &weights, k_max);
            self.engine.aggregate(&self.task_name, &stack, &w)?
        } else {
            aggregate_cpu(&models, &weights)
        };
        self.clients[i].params = new;
        self.clients[i].version += 1;
        self.clients[i].exchanges += 1;
        Ok(())
    }

    /// Total model payload bytes sent, per client (Fig. 20d metric).
    pub fn model_mb_per_client(&self) -> f64 {
        let total: u64 = self.clients.iter().map(|c| c.model_bytes_sent).sum();
        total as f64 / (1024.0 * 1024.0) / self.clients.len() as f64
    }

    /// Total training compute (train steps) per client — Fig. 15's
    /// relative-computation-cost metric numerator.
    pub fn train_steps_per_client(&self) -> f64 {
        let total: u64 = self.clients.iter().map(|c| c.train_steps).sum();
        total as f64 / self.clients.len() as f64
    }
}
