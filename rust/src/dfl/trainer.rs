//! The DFL training driver, rebuilt on the unified discrete-event engine:
//! client wake-ups, synchronous rounds, accuracy-sample hooks and churn
//! injections are all heap events on one deterministic scheduler
//! (`sim::Scheduler<TrainEvent>`), popped in O(log n).
//!
//! **Multi-task engine.** One trainer drives N independent model tasks —
//! each a [`TaskLane`] with its own dataset shards, model dimensions, MEP
//! period, seeds, eval stream and telemetry — over a *single* shared
//! overlay and a single scheduler (the paper's "machine learning tasks on
//! distributed devices", plural, on one near-random regular overlay).
//! Wake and sample events are task-tagged, fingerprint de-dup is keyed by
//! `(neighbor, task)`, and churn events flip aliveness in every lane at
//! once, so per-task membership always agrees. Task isolation is a hard
//! invariant: a lane's trajectory is a pure function of its own
//! `TaskSpec` plus the shared churn schedule — adding or removing *other*
//! lanes reproduces it bit for bit (`tests/multitask_properties.rs`).
//! The single-task constructor is the one-lane special case.
//!
//! Under `Neighborhood::Dynamic` the trainer embeds an NDMP overlay
//! simulator (`sim::Simulator`) and advances it in lockstep with training
//! time: a client's aggregation neighbors at time `t` are its live
//! protocol `NodeState` views, so mid-training joins and failures rewire
//! the learning topology through the actual join/repair protocols —
//! the paper's central claim that construction/maintenance (NDMP) and
//! training/exchange (MEP) run *together* (Figs. 18/19). Those views are
//! read through a per-client cache invalidated by the overlay's
//! view-change notifications (`Simulator::take_view_changes`), which is
//! what lets Dynamic runs reach the 10k-client scale
//! (`tests/scenario_scale.rs`) instead of rebuilding neighbor sets on
//! every wake. The neighbor cache is task-agnostic (ring views do not
//! depend on which model rides them) and therefore shared by all lanes.
//!
//! Runs any `MethodSpec` (FedLay or a comparator) over the runtime
//! engine, with the paper's client heterogeneity, non-iid shards, MEP
//! confidence weighting, and fingerprint de-dup accounting. Powers every
//! accuracy figure (Figs. 9–19) and the scalability study (Fig. 20).

use super::client::ClientState;
use super::methods::{Compression, MethodSpec, Mobility, Neighborhood};
use crate::config::{DflConfig, TaskSpec};
use crate::data::{CharStream, GaussianTask};
use crate::mep::{
    aggregate_cpu, fingerprint, pack_for_artifact, Aggregation, Capacity, ConfidenceParams,
};
use crate::ndmp::messages::Time;
use crate::runtime::{Engine, XInput};
use crate::sim::{AttackOp, PoisonMode, Scheduler, Simulator, Transport};
use crate::topology::NodeId;

use anyhow::Result;
use rayon::prelude::*;
use std::collections::{HashMap, HashSet};

/// Client-local dataset generator.
pub enum TaskData {
    Gaussian(GaussianTask),
    /// One Markov stream per client (built from its shard labels as roles).
    Char(Vec<CharStream>),
}

/// One recorded accuracy sample. `per_client[i]` is client `i`'s accuracy
/// (placeholders/failed clients are evaluated too, so cohort slices stay
/// index-aligned across churn); the means cover live *honest* clients
/// only — compromised clients report through `byz_mean_accuracy` instead,
/// which stays `None` while no live client is byzantine (clean runs are
/// bitwise-unchanged).
#[derive(Debug, Clone)]
pub struct AccuracySample {
    pub at: Time,
    pub mean_accuracy: f64,
    pub mean_loss: f64,
    /// Mean accuracy over live byzantine clients, when any exist.
    pub byz_mean_accuracy: Option<f64>,
    pub per_client: Vec<f64>,
}

/// Events driving the unified training engine. Everything that used to be
/// a bespoke loop branch — per-client wake-ups, global synchronous
/// rounds, accuracy samples — plus protocol-level churn, on one heap.
/// Wake and sample events carry the lane they belong to; churn events are
/// task-less because membership is shared by every lane.
#[derive(Debug, Clone, PartialEq)]
pub enum TrainEvent {
    /// Asynchronous client wake for one task: local training + MEP
    /// exchange on that task's model.
    Wake { task: usize, client: usize },
    /// Global synchronous round (sync decentralized / FedAvg / Gaia;
    /// single-lane methods only).
    Round,
    /// Accuracy-sample hook for one task's eval stream.
    Sample { task: usize },
    /// `client` joins the live network through `bootstrap`'s NDMP join
    /// protocol (forwarded to the embedded overlay as `EventKind::Join`).
    Join { client: usize, bootstrap: usize },
    /// Crash-fail (silent disappearance; NDMP repair takes over).
    Fail { client: usize },
    /// Graceful NDMP leave.
    Leave { client: usize },
    /// Adversarial compromise of one client (scenario `poison` /
    /// `stale_replay` / `eclipse` phases). Task-less: an attacker is
    /// compromised in every lane at once, like churn flips aliveness.
    Attack { client: usize, kind: AttackKind },
}

/// What an [`TrainEvent::Attack`] does when it fires. `StaleMark`
/// snapshots the victim's current models and schedules `StaleApply`
/// `lag` later, which replays the stale snapshot as the client's
/// permanent payload (the freshness attack); the other kinds compromise
/// immediately.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AttackKind {
    Poison(PoisonMode),
    StaleMark { lag: Time },
    StaleApply,
    Eclipse,
}

/// A fully resolved MEP aggregation for one client: the participants
/// (self first, then neighbors) and their confidence weights. Built once
/// per exchange by `plan_aggregation` — the *single* aggregation path for
/// both the live and the snapshot model source, task-tagged via the lane
/// it resolves against.
struct AggregationPlan {
    members: Vec<usize>,
    weights: Vec<f64>,
}

/// One same-instant wake admitted to the current batch: the client, its
/// neighborhood resolved at the event's serial position, and the local
/// training batches pre-drawn at that same position (so the shared rng
/// streams advance exactly as the serial loop would advance them).
struct WakeJob {
    task: usize,
    client: usize,
    nbrs: Vec<usize>,
    drawn: Vec<(Vec<f32>, Vec<i32>, Vec<i32>)>,
}

/// The pure compute half of one wake, produced against a frozen view of
/// client state and applied serially in batch (= arrival) order so
/// telemetry, fingerprints and re-wake pushes land exactly as the serial
/// event loop would emit them.
struct WakeOutcome {
    task: usize,
    client: usize,
    /// Final parameters (`None` when the wake changed nothing: frozen
    /// training and an empty neighborhood).
    params: Option<Vec<f32>>,
    /// Local training ran (version bump, `steps` train steps).
    trained: bool,
    steps: u64,
    /// An MEP aggregation ran (version + exchange bump).
    aggregated: bool,
    /// `(neighbor, fingerprint, is_duplicate)` per pulled neighbor, in
    /// neighborhood order.
    pulls: Vec<(usize, u64, bool)>,
    payload_bytes: u64,
    /// Neighbor models dropped by the non-finite guard before aggregation.
    rejected: u64,
}

/// Everything one model task owns: per-client per-task state, dataset
/// generators, the fixed eval stream, the accuracy series, and the
/// `TaskSpec` it was built from. The trainer holds one lane per task;
/// single-task runs are the one-lane special case.
pub struct TaskLane {
    pub spec: TaskSpec,
    pub clients: Vec<ClientState>,
    pub samples: Vec<AccuracySample>,
    data: TaskData,
    /// Shared initialization (also handed to mid-run joiners, mirroring
    /// the paper's "new nodes start from the common init").
    init_params: Vec<f32>,
    /// Evaluation batches (cached: same test set for every sample).
    eval_x: Vec<Vec<f32>>,
    eval_xi: Vec<Vec<i32>>,
    eval_y: Vec<Vec<i32>>,
    /// Per-model eval memo keyed by parameter fingerprint: after any
    /// broadcast round every client shares one model, which then costs a
    /// single evaluation instead of `n`. Per-lane, so one task's memo can
    /// never serve another task's (same-dimensioned) model.
    eval_cache: HashMap<u64, (f64, f64)>,
}

impl TaskLane {
    fn new(
        engine: &Engine,
        spec: TaskSpec,
        n: usize,
        synchronous: bool,
        label_weights: Vec<Vec<f64>>,
    ) -> Result<Self> {
        let info = engine.manifest.task(&spec.task)?.clone();
        let base_period = spec.comm_period_ms * 1_000;
        // All clients share one initialization (standard DFL practice:
        // averaging independently-initialized nets cancels their features
        // due to permutation symmetry).
        let init_params = engine.init(&spec.task, [spec.seed as u32, 0])?;
        let mut clients = Vec::with_capacity(n);
        for (i, w) in label_weights.iter().enumerate() {
            let cap = Capacity::assign(i, n);
            clients.push(ClientState::new(
                i,
                cap,
                base_period,
                w.clone(),
                init_params.clone(),
                spec.seed ^ 0xC11E,
            ));
        }
        // synchronous mode: everyone runs at the slowest tier's period
        if synchronous {
            let max_period = clients.iter().map(|c| c.schedule.period).max().unwrap();
            for c in &mut clients {
                c.schedule.period = max_period;
                c.schedule.synchronous = true;
                c.next_wake = 0;
            }
        }
        let data = match spec.task.as_str() {
            "lstm" => {
                let streams = label_weights
                    .iter()
                    .enumerate()
                    .map(|(i, w)| char_stream_for(spec.seed, i, w))
                    .collect();
                TaskData::Char(streams)
            }
            "cnn" => TaskData::Gaussian(GaussianTask::cifar_like(spec.seed)),
            _ => TaskData::Gaussian(GaussianTask::mnist_like(spec.seed)),
        };
        // fixed iid eval set: 2 batches
        let mut eval_x = Vec::new();
        let mut eval_xi = Vec::new();
        let mut eval_y = Vec::new();
        for e in 0..2u64 {
            match &data {
                TaskData::Gaussian(t) => {
                    let b = t.test_batch(info.batch, spec.seed ^ (0xE0 + e));
                    eval_x.push(b.x);
                    eval_y.push(b.y);
                }
                TaskData::Char(_) => {
                    let roles: Vec<u64> = (0..10).map(|l| spec.seed ^ (l + 1)).collect();
                    let mut s = CharStream::new(&roles, spec.seed ^ (0xE0 + e));
                    let (x, y) = s.batch(info.batch, info.x_len);
                    eval_xi.push(x);
                    eval_y.push(y);
                }
            }
        }
        Ok(Self {
            spec,
            clients,
            samples: Vec::new(),
            data,
            init_params,
            eval_x,
            eval_xi,
            eval_y,
            eval_cache: HashMap::new(),
        })
    }
}

pub struct Trainer<'e> {
    pub engine: &'e Engine,
    pub spec: MethodSpec,
    /// Base run configuration (population size, capacity split, seeds);
    /// per-task knobs live in each lane's `TaskSpec`.
    pub cfg: DflConfig,
    /// One lane per model task. Lane 0 is the primary task — the
    /// single-task accessors (`clients`, `samples`, `evaluate`) read it.
    pub lanes: Vec<TaskLane>,
    /// Embedded NDMP overlay (Neighborhood::Dynamic), advanced in
    /// lockstep with training time and shared by every lane.
    pub overlay: Option<Simulator>,
    /// Transport override for the embedded overlay: `ensure_overlay`
    /// builds the Simulator on this backend (e.g. `net::SchedTransport`
    /// for real localhost sockets) instead of the in-memory default.
    transport: Option<Box<dyn Transport>>,
    mobility: Option<Mobility>,
    conf: ConfidenceParams,
    pub now: Time,
    /// The unified event heap: wakes, rounds, samples, churn — for every
    /// lane.
    queue: Scheduler<TrainEvent>,
    /// Per-client neighbor-set cache for `Neighborhood::Dynamic`: the
    /// filtered aggregation neighborhood of client `i`, valid until the
    /// overlay emits a view change for node `i` (`take_view_changes`,
    /// drained in `sync_overlay`) or a churn event flips the aliveness
    /// of a client it references (targeted invalidation,
    /// `invalidate_neighbor_caches_for`). Task-agnostic (ring views carry
    /// every task), hence shared by all lanes. Without it every wake
    /// re-reads `ring_neighbor_ids()` from the protocol state, which caps
    /// Dynamic runs well below 10k clients.
    nbr_cache: Vec<Option<Vec<usize>>>,
    nbr_cache_hits: u64,
    nbr_cache_misses: u64,
    /// Shard count applied to the embedded overlay when `ensure_overlay`
    /// builds it (`Simulator::set_shards`); 1 = serial engine. Adopted
    /// overlays and custom transports keep their own configuration.
    overlay_shards: usize,
    /// Per-victim model snapshots captured by `AttackKind::StaleMark`,
    /// consumed by the matching `StaleApply` (one entry per lane).
    stale_snapshots: HashMap<usize, Vec<Vec<f32>>>,
    /// Skip real training (scalability mode: reuse pre-trained params).
    pub freeze_training: bool,
}

impl<'e> Trainer<'e> {
    /// The classic single-task trainer: one lane derived from `cfg`.
    pub fn new(
        engine: &'e Engine,
        spec: MethodSpec,
        cfg: DflConfig,
        label_weights: Vec<Vec<f64>>,
    ) -> Result<Self> {
        let task = TaskSpec::from_dfl(&cfg);
        Self::new_multi(engine, spec, cfg, vec![(task, label_weights)])
    }

    /// The multi-task engine: N independent model tasks over one shared
    /// overlay and one scheduler. Each entry pairs a `TaskSpec` with that
    /// task's per-client label weights (`cfg.clients` vectors). Lanes
    /// must have unique names; with more than one lane the method must be
    /// asynchronous and its neighborhood Static or Dynamic (central
    /// rounds and the mobility comparator are single-task constructs).
    pub fn new_multi(
        engine: &'e Engine,
        spec: MethodSpec,
        cfg: DflConfig,
        tasks: Vec<(TaskSpec, Vec<Vec<f64>>)>,
    ) -> Result<Self> {
        anyhow::ensure!(!tasks.is_empty(), "at least one task is required");
        let n = cfg.clients;
        if tasks.len() > 1 {
            anyhow::ensure!(
                spec.asynchronous,
                "multi-task runs are asynchronous (per-task MEP periods)"
            );
            anyhow::ensure!(
                matches!(
                    spec.neighborhood,
                    Neighborhood::Dynamic { .. } | Neighborhood::Static(_)
                ),
                "multi-task runs need a shared overlay neighborhood (Static or Dynamic)"
            );
        }
        let mut names = HashSet::new();
        for (t, _) in &tasks {
            anyhow::ensure!(names.insert(t.name.clone()), "duplicate task name {:?}", t.name);
        }
        let synchronous = !spec.asynchronous;
        let mut lanes = Vec::with_capacity(tasks.len());
        for (tspec, w) in tasks {
            anyhow::ensure!(
                w.len() == n,
                "weights per client mismatch for task {:?}",
                tspec.name
            );
            tspec.validate()?;
            lanes.push(TaskLane::new(engine, tspec, n, synchronous, w)?);
        }
        let mobility = match &spec.neighborhood {
            Neighborhood::Mobility { k, speed, seed } => {
                Some(Mobility::new(n, *k, *speed, *seed))
            }
            _ => None,
        };
        // Dynamic's embedded NDMP fleet is built lazily at the first
        // `run` (see `ensure_overlay`) so `adopt_overlay` callers don't
        // pay for a bootstrap that is immediately replaced.
        Ok(Self {
            engine,
            spec,
            cfg,
            lanes,
            overlay: None,
            transport: None,
            mobility,
            conf: ConfidenceParams::default(),
            now: 0,
            queue: Scheduler::new(),
            nbr_cache: vec![None; n],
            nbr_cache_hits: 0,
            nbr_cache_misses: 0,
            overlay_shards: 1,
            stale_snapshots: HashMap::new(),
            freeze_training: false,
        })
    }

    // ------------------------------------------------------------------
    // Lane accessors (lane 0 = the primary task)
    // ------------------------------------------------------------------

    /// Primary-lane client states (single-task callers' view).
    pub fn clients(&self) -> &[ClientState] {
        &self.lanes[0].clients
    }

    pub fn clients_mut(&mut self) -> &mut [ClientState] {
        &mut self.lanes[0].clients
    }

    /// Consume the trainer, yielding the primary lane's client states
    /// (model-pool workflows, Fig. 20).
    pub fn into_clients(mut self) -> Vec<ClientState> {
        self.lanes.swap_remove(0).clients
    }

    /// Primary-lane accuracy series.
    pub fn samples(&self) -> &[AccuracySample] {
        &self.lanes[0].samples
    }

    /// Primary-lane runtime model task name.
    pub fn task_name(&self) -> &str {
        &self.lanes[0].spec.task
    }

    fn info_batch(&self, task: usize) -> (usize, usize) {
        let info = self.engine.manifest.task(&self.lanes[task].spec.task).unwrap();
        (info.batch, info.x_len)
    }

    /// Centralized topologies (Star/Regions) and `asynchronous = false`
    /// methods advance in global rounds; everything else gossips on
    /// per-client wake events.
    fn synchronous(&self) -> bool {
        !self.spec.asynchronous
            || matches!(
                self.spec.neighborhood,
                Neighborhood::Star | Neighborhood::Regions { .. }
            )
    }

    // ------------------------------------------------------------------
    // Churn scheduling (heap events, executed mid-run)
    // ------------------------------------------------------------------

    /// Register a client that joins the live network at `at` through
    /// `bootstrap`'s NDMP join protocol (single-task trainers; multi-task
    /// trainers supply one weight vector per lane via
    /// `schedule_join_tasks`). The client exists immediately as a dead
    /// placeholder (so cohort indices are stable) and comes alive — in
    /// both the training loop and the overlay — when the event fires.
    /// Returns the new client's id.
    pub fn schedule_join(
        &mut self,
        at: Time,
        label_weights: Vec<f64>,
        bootstrap: usize,
    ) -> Result<usize> {
        anyhow::ensure!(
            self.lanes.len() == 1,
            "multi-task trainers need schedule_join_tasks (one weight vector per task)"
        );
        self.schedule_join_tasks(at, vec![label_weights], bootstrap)
    }

    /// Multi-task join: the client enters the shared overlay once, and
    /// every lane gains its per-task state (weights, data stream, model
    /// initialized from that lane's common init).
    pub fn schedule_join_tasks(
        &mut self,
        at: Time,
        per_task_weights: Vec<Vec<f64>>,
        bootstrap: usize,
    ) -> Result<usize> {
        anyhow::ensure!(
            matches!(self.spec.neighborhood, Neighborhood::Dynamic { .. }),
            "mid-run joins need Neighborhood::Dynamic (NDMP-backed); static graphs cannot grow"
        );
        anyhow::ensure!(
            bootstrap < self.lanes[0].clients.len(),
            "bootstrap {bootstrap} unknown"
        );
        anyhow::ensure!(
            per_task_weights.len() == self.lanes.len(),
            "got {} weight vectors for {} tasks",
            per_task_weights.len(),
            self.lanes.len()
        );
        let i = self.lanes[0].clients.len();
        // `MethodSpec` fields are public, so a hand-built synchronous
        // Dynamic spec is possible; keep joiners on the shared round
        // period in that case.
        let sync = !self.spec.asynchronous;
        for (lane, w) in self.lanes.iter_mut().zip(per_task_weights) {
            let base_period = lane.spec.comm_period_ms * 1_000;
            let mut c = ClientState::new(
                i,
                Capacity::assign(i, i + 1),
                base_period,
                w.clone(),
                lane.init_params.clone(),
                lane.spec.seed ^ 0xC11E,
            );
            c.alive = false;
            if sync {
                c.schedule.period = lane.clients[0].schedule.period;
                c.schedule.synchronous = true;
            }
            lane.clients.push(c);
            if let TaskData::Char(streams) = &mut lane.data {
                streams.push(char_stream_for(lane.spec.seed, i, &w));
            }
        }
        self.nbr_cache.push(None);
        self.queue.push(at, TrainEvent::Join { client: i, bootstrap });
        Ok(i)
    }

    /// Crash-fail `client` at `at`: it silently stops waking (in every
    /// lane); under Dynamic the overlay node disappears and NDMP repair
    /// rewires around it.
    pub fn schedule_fail(&mut self, at: Time, client: usize) {
        self.queue.push(at, TrainEvent::Fail { client });
    }

    /// Graceful departure at `at` (NDMP leave under Dynamic).
    pub fn schedule_leave(&mut self, at: Time, client: usize) {
        self.queue.push(at, TrainEvent::Leave { client });
    }

    /// Schedule one compiled adversarial op (scenario `poison` /
    /// `stale_replay` / `eclipse` phases). The victim is compromised in
    /// every lane when the event fires: it stays alive — neighbors keep
    /// pulling its model, which *is* the attack — but stops training and
    /// aggregating, so honest averages never wash its payload out.
    pub fn schedule_attack(&mut self, at: Time, op: AttackOp) -> Result<()> {
        let (client, kind) = match op {
            AttackOp::Poison { node, mode } => (node as usize, AttackKind::Poison(mode)),
            AttackOp::StaleReplay { node, lag } => (node as usize, AttackKind::StaleMark { lag }),
            AttackOp::Eclipse { node } => (node as usize, AttackKind::Eclipse),
        };
        anyhow::ensure!(
            client < self.lanes[0].clients.len(),
            "attack target {client} unknown"
        );
        self.queue.push(at, TrainEvent::Attack { client, kind });
        Ok(())
    }

    /// Replace the embedded overlay with an existing simulation — e.g. a
    /// network grown *decentralized* via `sim::grow_network` — so training
    /// continues on that exact protocol state instead of a fresh
    /// centralized bootstrap. Requires `Neighborhood::Dynamic`, must be
    /// called before `run`, and every client needs a live node. The
    /// adopted overlay's clock may be ahead of the training clock;
    /// maintenance resumes once training time passes it.
    pub fn adopt_overlay(&mut self, sim: Simulator) -> Result<()> {
        anyhow::ensure!(
            matches!(self.spec.neighborhood, Neighborhood::Dynamic { .. }),
            "adopt_overlay needs Neighborhood::Dynamic"
        );
        anyhow::ensure!(
            self.now == 0 && self.lanes.iter().all(|l| l.samples.is_empty()),
            "adopt_overlay must be called before run()"
        );
        for id in 0..self.lanes[0].clients.len() as NodeId {
            anyhow::ensure!(
                sim.contains_node(id),
                "adopted overlay is missing node {id}"
            );
        }
        self.overlay = Some(sim);
        Ok(())
    }

    /// Route the embedded overlay's protocol traffic over an alternative
    /// backend — e.g. `net::SchedTransport` for real localhost TCP
    /// sockets (the CLI's `train --transport tcp`). Must be called before
    /// `run` on a `Neighborhood::Dynamic` spec; the default is the
    /// deterministic in-memory network.
    pub fn set_transport(&mut self, transport: Box<dyn Transport>) -> Result<()> {
        anyhow::ensure!(
            matches!(self.spec.neighborhood, Neighborhood::Dynamic { .. }),
            "set_transport needs Neighborhood::Dynamic (the embedded NDMP overlay)"
        );
        anyhow::ensure!(
            self.overlay.is_none() && self.now == 0,
            "set_transport must be called before run()"
        );
        self.transport = Some(transport);
        Ok(())
    }

    /// Partition the embedded overlay's event engine into `k` coordinate
    /// arcs (see [`Simulator::set_shards`]). Takes effect when
    /// `ensure_overlay` builds the overlay — so it must be set before the
    /// first `run` — and only for the in-memory transport (custom
    /// transports deliver out-of-band and stay on the serial engine).
    /// `k > 1` is bitwise-identical to the serial engine; this is purely
    /// a wall-clock knob for large `Neighborhood::Dynamic` runs.
    pub fn set_overlay_shards(&mut self, k: usize) {
        self.overlay_shards = k.max(1);
    }

    /// Build the embedded overlay on first use (Dynamic only): the
    /// original `cfg.clients` start as an instantly-correct network —
    /// the decentralized path for later arrivals is `schedule_join`, and
    /// `adopt_overlay` substitutes a grown network wholesale.
    fn ensure_overlay(&mut self) {
        if self.overlay.is_some() {
            return;
        }
        if let Neighborhood::Dynamic { overlay, net } = &self.spec.neighborhood {
            let mut sim = match self.transport.take() {
                Some(t) => Simulator::with_transport(overlay.clone(), t),
                None => {
                    let mut s = Simulator::new(overlay.clone(), net.clone());
                    if self.overlay_shards > 1 {
                        s.set_shards(self.overlay_shards);
                    }
                    s
                }
            };
            let ids: Vec<NodeId> = (0..self.cfg.clients as NodeId).collect();
            sim.bootstrap_correct(&ids);
            self.overlay = Some(sim);
        }
    }

    /// Advance the embedded overlay protocol to the trainer clock, then
    /// invalidate the neighbor cache of exactly the nodes whose ring
    /// views the protocol changed meanwhile.
    fn sync_overlay(&mut self) {
        let now = self.now;
        if let Some(sim) = self.overlay.as_mut() {
            sim.run_until(now);
            for id in sim.take_view_changes() {
                let i = id as usize;
                if i < self.nbr_cache.len() {
                    self.nbr_cache[i] = None;
                }
            }
        }
    }

    /// `client`'s aliveness flipped: drop its own cached list plus every
    /// cached list that references it (the alive-filter baked into those
    /// lists is stale). Targeted — clearing all `n` entries per churn
    /// event would defeat the cache exactly when 10k-client Poisson
    /// scenarios need it.
    fn invalidate_neighbor_caches_for(&mut self, client: usize) {
        for (i, e) in self.nbr_cache.iter_mut().enumerate() {
            if i == client || e.as_ref().is_some_and(|l| l.contains(&client)) {
                *e = None;
            }
        }
    }

    /// `client` left the run (crash or graceful leave): flip its
    /// aliveness in every lane and expire its dedup entries *per task*
    /// (`forget_task`) — one task's peer expiry must never evict another
    /// task's fingerprint state.
    fn retire_client(&mut self, client: usize) {
        for (t, lane) in self.lanes.iter_mut().enumerate() {
            lane.clients[client].alive = false;
            for c in lane.clients.iter_mut() {
                c.fingerprints.forget_task(client as u64, t as u32);
            }
        }
        self.invalidate_neighbor_caches_for(client);
    }

    /// `(hits, misses)` of the `Neighborhood::Dynamic` neighbor-set
    /// cache — surfaced by `ScenarioReport` so large-scale runs can
    /// verify the cache actually carries the load.
    pub fn neighbor_cache_stats(&self) -> (u64, u64) {
        (self.nbr_cache_hits, self.nbr_cache_misses)
    }

    /// Total neighbor models rejected by the non-finite guard, summed
    /// over every lane and client — `ScenarioReport`'s rejected-model
    /// telemetry.
    pub fn rejected_models_total(&self) -> u64 {
        self.lanes
            .iter()
            .flat_map(|l| l.clients.iter())
            .map(|c| c.rejected_models)
            .sum()
    }

    /// Schedule correctness snapshots on the embedded overlay every
    /// `every` from the current clock through `until` (endpoints only
    /// when `every` is 0), so scenario runs record the correctness
    /// series alongside the accuracy series.
    pub fn schedule_overlay_snapshots(&mut self, until: Time, every: Time) -> Result<()> {
        anyhow::ensure!(
            matches!(self.spec.neighborhood, Neighborhood::Dynamic { .. }),
            "overlay snapshots need Neighborhood::Dynamic (the embedded NDMP overlay)"
        );
        self.ensure_overlay();
        let now = self.now;
        let sim = self.overlay.as_mut().expect("dynamic overlay state");
        if every == 0 {
            // endpoints only
            sim.schedule_snapshot(now);
            sim.schedule_snapshot(until);
        } else {
            let mut t = now;
            while t <= until {
                sim.schedule_snapshot(t);
                t += every;
            }
        }
        Ok(())
    }

    /// Draw a local training batch for client `i` of lane `task`.
    fn draw_batch(&mut self, task: usize, i: usize) -> (Vec<f32>, Vec<i32>, Vec<i32>) {
        let (batch, x_len) = self.info_batch(task);
        let lane = &mut self.lanes[task];
        match &mut lane.data {
            TaskData::Gaussian(t) => {
                let w = lane.clients[i].label_weights.clone();
                let b = t.batch(batch, &w, &mut lane.clients[i].rng);
                (b.x, Vec::new(), b.y)
            }
            TaskData::Char(streams) => {
                let (x, y) = streams[i].batch(batch, x_len);
                (Vec::new(), x, y)
            }
        }
    }

    fn local_train(&mut self, task: usize, i: usize) -> Result<()> {
        if self.freeze_training {
            return Ok(());
        }
        let (steps, lr) = (self.lanes[task].spec.local_steps, self.lanes[task].spec.lr);
        for _ in 0..steps {
            let (xf, xi, y) = self.draw_batch(task, i);
            let x = if xf.is_empty() {
                XInput::I32(&xi)
            } else {
                XInput::F32(&xf)
            };
            let (new, _loss) = self.engine.train_step(
                &self.lanes[task].spec.task,
                &self.lanes[task].clients[i].params,
                &x,
                &y,
                lr,
            )?;
            let lane = &mut self.lanes[task];
            lane.clients[i].params = new;
            lane.clients[i].train_steps += 1;
        }
        self.lanes[task].clients[i].version += 1;
        Ok(())
    }

    /// Live-neighbor ids of client `i` at the current time. Task-agnostic:
    /// every lane aggregates over the same overlay neighborhood.
    fn neighbors_of(&mut self, i: usize) -> Vec<usize> {
        let n = self.lanes[0].clients.len();
        match &self.spec.neighborhood {
            Neighborhood::Static(g) => g
                .neighbors(i)
                .filter(|&j| self.lanes[0].clients[j].alive)
                .collect(),
            Neighborhood::Star => (0..n)
                .filter(|&j| j != i && self.lanes[0].clients[j].alive)
                .collect(),
            Neighborhood::Regions { assignment, .. } => {
                let r = assignment[i];
                (0..n)
                    .filter(|&j| j != i && assignment[j] == r && self.lanes[0].clients[j].alive)
                    .collect()
            }
            Neighborhood::Mobility { .. } => {
                let g = self.mobility.as_mut().expect("mobility state").step();
                g.neighbors(i)
                    .filter(|&j| self.lanes[0].clients[j].alive)
                    .collect()
            }
            Neighborhood::Dynamic { .. } => {
                // Serve from the per-client cache when node i's ring
                // views are unchanged since the last read; recompute on
                // a view-change notification or after any churn.
                if let Some(cached) = &self.nbr_cache[i] {
                    self.nbr_cache_hits += 1;
                    return cached.clone();
                }
                let sim = self.overlay.as_ref().expect("dynamic overlay state");
                let list: Vec<usize> = match sim.node(i as NodeId) {
                    Some(st) => st
                        .ring_neighbor_ids()
                        .into_iter()
                        .filter_map(|id| {
                            let j = id as usize;
                            (j != i && j < n && self.lanes[0].clients[j].alive).then_some(j)
                        })
                        .collect(),
                    None => Vec::new(), // not joined yet / failed
                };
                self.nbr_cache_misses += 1;
                self.nbr_cache[i] = Some(list.clone());
                list
            }
        }
    }

    // ------------------------------------------------------------------
    // MEP aggregation — the synchronous (pre-round snapshot) path; the
    // asynchronous path is `compute_wake`/`apply_wake`
    // ------------------------------------------------------------------

    /// Resolve one MEP aggregation (paper §III-C2): fingerprint de-dup and
    /// transfer accounting (§III-C3) against the pre-round snapshot —
    /// keyed by `(neighbor, task)` so coexisting tasks never suppress
    /// each other's transfers — then the confidence weights normalized
    /// over the neighborhood ∪ {i}.
    fn plan_aggregation(
        &mut self,
        task: usize,
        i: usize,
        nbrs: &[usize],
        snapshot: &[Vec<f32>],
    ) -> AggregationPlan {
        let task_key = task as u32;
        let compression = self.spec.compression;
        let lane = &mut self.lanes[task];
        // i "pulls" each neighbor's latest model unless the fingerprint
        // matches the last pull; the sender pays the (possibly
        // compressed) payload bytes.
        let p_bytes = compression.payload_bytes(snapshot[i].len());
        for &j in nbrs {
            let fp = fingerprint(&snapshot[j]);
            if lane.clients[i].fingerprints.is_duplicate(j as u64, task_key, fp) {
                lane.clients[i].dedup_skips += 1;
            } else {
                lane.clients[i].fingerprints.record(j as u64, task_key, fp);
                lane.clients[j].model_bytes_sent += p_bytes;
            }
        }
        let hood: Vec<(f64, f64)> = std::iter::once(lane.clients[i].raw_confidence())
            .chain(nbrs.iter().map(|&j| lane.clients[j].raw_confidence()))
            .collect();
        let weights: Vec<f64> = if self.spec.confidence {
            hood.iter().map(|&own| self.conf.combine(own, &hood)).collect()
        } else {
            vec![1.0; hood.len()]
        };
        let members = std::iter::once(i).chain(nbrs.iter().copied()).collect();
        AggregationPlan { members, weights }
    }

    /// Execute one MEP aggregation for client `i` of lane `task` against
    /// the pre-round snapshot (synchronous decentralized rounds).
    fn aggregate(
        &mut self,
        task: usize,
        i: usize,
        nbrs: &[usize],
        snapshot: &[Vec<f32>],
    ) -> Result<()> {
        if nbrs.is_empty() {
            return Ok(());
        }
        let plan = self.plan_aggregation(task, i, nbrs, snapshot);
        let engine = self.engine;
        let k_max = engine.manifest.k_max;
        let compression = self.spec.compression;
        let lane = &self.lanes[task];
        // neighbor models (members[1..]) arrive through the wire scheme;
        // a client's own model (members[0]) never travels
        let wire_models: Option<Vec<Vec<f32>>> = (compression != Compression::None).then(|| {
            plan.members[1..]
                .iter()
                .map(|&j| compression.roundtrip(&snapshot[j]))
                .collect()
        });
        let models: Vec<&[f32]> = match &wire_models {
            Some(ws) => std::iter::once(snapshot[i].as_slice())
                .chain(ws.iter().map(|v| v.as_slice()))
                .collect(),
            None => plan
                .members
                .iter()
                .map(|&j| snapshot[j].as_slice())
                .collect(),
        };
        // Byzantine guard: drop non-finite rows *before* anything reaches
        // the AOT kernel (which would propagate NaN into every survivor).
        // Clean runs keep every row, so the Mean path below is
        // bitwise-identical to the historical behavior.
        let mut kept: Vec<&[f32]> = Vec::with_capacity(models.len());
        let mut kept_w: Vec<f64> = Vec::with_capacity(plan.weights.len());
        let mut rejected = 0u64;
        for (&m, &w) in models.iter().zip(&plan.weights) {
            if w.is_finite() && m.iter().all(|v| v.is_finite()) {
                kept.push(m);
                kept_w.push(w);
            } else {
                rejected += 1;
            }
        }
        let task_name = lane.spec.task.clone();
        let aggregation = self.spec.aggregation;
        let lane = &mut self.lanes[task];
        lane.clients[i].rejected_models += rejected;
        if kept.is_empty() {
            // every participant (including self) was non-finite: keep the
            // current model rather than overwrite it with a zero vector
            return Ok(());
        }
        let new = match aggregation {
            Aggregation::Mean if kept.len() <= k_max => {
                // hot path: the L1 Pallas kernel inside the agg artifact
                let (stack, w) = pack_for_artifact(&kept, &kept_w, k_max);
                engine.aggregate(&task_name, &stack, &w)?
            }
            // oversized neighborhood (complete graph / star) or a robust
            // rule: CPU path
            agg => agg.apply(&kept, &kept_w),
        };
        let lane = &mut self.lanes[task];
        lane.clients[i].params = new;
        lane.clients[i].version += 1;
        lane.clients[i].exchanges += 1;
        Ok(())
    }

    /// Centralized FedAvg round: global average, broadcast to everyone
    /// (single-lane methods only).
    fn fedavg_round(&mut self) -> Result<()> {
        let compression = self.spec.compression;
        let lane = &mut self.lanes[0];
        let models: Vec<&[f32]> = lane
            .clients
            .iter()
            .filter(|c| c.alive)
            .map(|c| c.params.as_slice())
            .collect();
        if models.is_empty() {
            return Ok(());
        }
        let weights = vec![1.0; models.len()];
        // the broadcast global model travels through the wire scheme too
        let global = compression.roundtrip(&aggregate_cpu(&models, &weights));
        let p_bytes = compression.payload_bytes(global.len());
        // byzantine clients keep their adversarial payload rather than
        // accept the broadcast (the attack would self-heal otherwise)
        for c in lane.clients.iter_mut().filter(|c| c.alive && !c.byzantine) {
            c.params = global.clone();
            c.version += 1;
            c.exchanges += 1;
            // upload + download through the server
            c.model_bytes_sent += 2 * p_bytes;
        }
        Ok(())
    }

    /// Gaia round: average within each region, then across region servers.
    fn gaia_round(&mut self, assignment: &[usize], regions: usize) -> Result<()> {
        let compression = self.spec.compression;
        let lane = &mut self.lanes[0];
        let mut region_models: Vec<Option<Vec<f32>>> = vec![None; regions];
        for (r, slot) in region_models.iter_mut().enumerate() {
            let members: Vec<&[f32]> = lane
                .clients
                .iter()
                .filter(|c| c.alive && assignment[c.id] == r)
                .map(|c| c.params.as_slice())
                .collect();
            if members.is_empty() {
                continue; // a fully-failed region drops out of the average
            }
            *slot = Some(aggregate_cpu(&members, &vec![1.0; members.len()]));
        }
        // inter-region complete-graph averaging over populated regions
        let refs: Vec<&[f32]> = region_models.iter().filter_map(|m| m.as_deref()).collect();
        if refs.is_empty() {
            return Ok(());
        }
        let p = refs[0].len();
        // the redistributed global model travels through the wire scheme
        let global = compression.roundtrip(&aggregate_cpu(&refs, &vec![1.0; refs.len()]));
        let p_bytes = compression.payload_bytes(p);
        let members_per_region = (lane.clients.len() / regions.max(1)).max(1) as u64;
        for c in lane.clients.iter_mut().filter(|c| c.alive && !c.byzantine) {
            c.params = global.clone();
            c.version += 1;
            c.exchanges += 1;
            // client <-> region server, plus the servers' complete-graph
            // exchange amortized over members
            c.model_bytes_sent += 2 * p_bytes + (regions as u64 - 1) * p_bytes / members_per_region;
        }
        Ok(())
    }

    /// Evaluate every client of lane `task` on its fixed iid test set.
    /// Distinct models are found by fingerprint, the fresh ones evaluated
    /// in parallel, and results memoized — after a broadcast round `n`
    /// identical clients cost one evaluation.
    pub fn evaluate_task(&mut self, task: usize) -> Result<AccuracySample> {
        let (batch, _) = self.info_batch(task);
        let nb = self.lanes[task].eval_y.len();
        let fps: Vec<u64> = self.lanes[task]
            .clients
            .iter()
            .map(|c| fingerprint(&c.params))
            .collect();
        // bound the memo before extending it (long runs, many versions)
        let bound = 8 * self.lanes[task].clients.len().max(8);
        if self.lanes[task].eval_cache.len() > bound {
            let keep: HashSet<u64> = fps.iter().copied().collect();
            self.lanes[task].eval_cache.retain(|k, _| keep.contains(k));
        }
        let mut seen = HashSet::new();
        let fresh: Vec<(u64, usize)> = fps
            .iter()
            .enumerate()
            .filter(|&(_, fp)| !self.lanes[task].eval_cache.contains_key(fp) && seen.insert(*fp))
            .map(|(i, &fp)| (fp, i))
            .collect();
        let this: &Self = &*self;
        let lane = &this.lanes[task];
        let evaluated = fresh
            .par_iter()
            .map(|&(fp, i)| -> Result<(u64, (f64, f64))> {
                let mut correct = 0.0f64;
                let mut loss = 0.0f64;
                for e in 0..nb {
                    let x = if !lane.eval_x.is_empty() {
                        XInput::F32(&lane.eval_x[e])
                    } else {
                        XInput::I32(&lane.eval_xi[e])
                    };
                    let (cr, lo) = this.engine.eval_step(
                        &lane.spec.task,
                        &lane.clients[i].params,
                        &x,
                        &lane.eval_y[e],
                    )?;
                    correct += cr as f64;
                    loss += lo as f64;
                }
                Ok((fp, (correct / (nb * batch) as f64, loss / nb as f64)))
            })
            .collect::<Result<Vec<_>>>()?;
        self.lanes[task].eval_cache.extend(evaluated);
        let lane = &self.lanes[task];
        let mut per_client = Vec::with_capacity(lane.clients.len());
        let (mut acc_sum, mut loss_sum, mut live) = (0.0, 0.0, 0usize);
        let (mut byz_sum, mut byz) = (0.0, 0usize);
        for (i, c) in lane.clients.iter().enumerate() {
            let (acc, lo) = lane.eval_cache[&fps[i]];
            per_client.push(acc);
            if !c.alive {
                continue;
            }
            if c.byzantine {
                // compromised clients report separately; folding a
                // NaN-poisoned model's loss into the honest mean would
                // wreck the whole series
                byz_sum += acc;
                byz += 1;
            } else {
                acc_sum += acc;
                loss_sum += lo;
                live += 1;
            }
        }
        let denom = live.max(1) as f64;
        Ok(AccuracySample {
            at: self.now,
            mean_accuracy: acc_sum / denom,
            mean_loss: loss_sum / denom,
            byz_mean_accuracy: (byz > 0).then(|| byz_sum / byz as f64),
            per_client,
        })
    }

    /// Evaluate the primary lane (single-task callers' view).
    pub fn evaluate(&mut self) -> Result<AccuracySample> {
        self.evaluate_task(0)
    }

    fn record_lane_sample(&mut self, task: usize) -> Result<()> {
        let s = self.evaluate_task(task)?;
        self.lanes[task].samples.push(s);
        Ok(())
    }

    /// Record one accuracy sample per lane at the current clock.
    pub fn record_sample(&mut self) -> Result<()> {
        for t in 0..self.lanes.len() {
            self.record_lane_sample(t)?;
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // Same-instant wake batching: independent wakes at one timestamp run
    // their compute (training + aggregation arithmetic) in parallel
    // ------------------------------------------------------------------

    /// The pure compute half of one batched wake: local training on a
    /// working copy, fingerprint/dedup decisions, MEP aggregation.
    /// Reads shared client state but never writes it — every job in a
    /// batch is independent (no job's client appears in another job's
    /// neighborhood), so the frozen view each job reads is exactly the
    /// state the serial loop would have shown it.
    fn compute_wake(&self, job: &WakeJob) -> Result<WakeOutcome> {
        let lane = &self.lanes[job.task];
        let spec = &lane.spec;
        let base = &lane.clients[job.client].params;
        // local training (drawn batches were pre-drawn at the event's
        // serial position; empty when training is frozen)
        let trained = !self.freeze_training;
        let mut trained_params: Option<Vec<f32>> = None;
        if trained {
            let mut p = base.clone();
            for (xf, xi, y) in &job.drawn {
                let x = if xf.is_empty() {
                    XInput::I32(xi)
                } else {
                    XInput::F32(xf)
                };
                let (new, _loss) = self.engine.train_step(&spec.task, &p, &x, y, spec.lr)?;
                p = new;
            }
            trained_params = Some(p);
        }
        let cur: &[f32] = trained_params.as_deref().unwrap_or(base);
        let compression = self.spec.compression;
        let payload_bytes = compression.payload_bytes(cur.len());
        // MEP aggregation against the (stable) neighbor models
        let mut pulls = Vec::with_capacity(job.nbrs.len());
        let mut aggregated = false;
        let mut rejected = 0u64;
        let mut final_params = trained_params;
        if !job.nbrs.is_empty() {
            let task_key = job.task as u32;
            for &j in &job.nbrs {
                let fp = fingerprint(&lane.clients[j].params);
                let dup = lane.clients[job.client]
                    .fingerprints
                    .is_duplicate(j as u64, task_key, fp);
                pulls.push((j, fp, dup));
            }
            let hood: Vec<(f64, f64)> = std::iter::once(lane.clients[job.client].raw_confidence())
                .chain(job.nbrs.iter().map(|&j| lane.clients[j].raw_confidence()))
                .collect();
            let weights: Vec<f64> = if self.spec.confidence {
                hood.iter().map(|&own| self.conf.combine(own, &hood)).collect()
            } else {
                vec![1.0; hood.len()]
            };
            let cur = final_params.as_deref().unwrap_or(base);
            // neighbor models arrive through the wire scheme; the
            // client's own model never travels
            let wire_models: Option<Vec<Vec<f32>>> =
                (compression != Compression::None).then(|| {
                    job.nbrs
                        .iter()
                        .map(|&j| compression.roundtrip(&lane.clients[j].params))
                        .collect()
                });
            let models: Vec<&[f32]> = match &wire_models {
                Some(ws) => std::iter::once(cur)
                    .chain(ws.iter().map(|v| v.as_slice()))
                    .collect(),
                None => std::iter::once(cur)
                    .chain(job.nbrs.iter().map(|&j| lane.clients[j].params.as_slice()))
                    .collect(),
            };
            // Byzantine guard: reject non-finite rows before the AOT
            // kernel sees them (NaN would poison every survivor). Clean
            // runs keep every row — the Mean path stays bitwise-identical.
            let mut kept: Vec<&[f32]> = Vec::with_capacity(models.len());
            let mut kept_w: Vec<f64> = Vec::with_capacity(weights.len());
            for (&m, &w) in models.iter().zip(&weights) {
                if w.is_finite() && m.iter().all(|v| v.is_finite()) {
                    kept.push(m);
                    kept_w.push(w);
                } else {
                    rejected += 1;
                }
            }
            if !kept.is_empty() {
                let k_max = self.engine.manifest.k_max;
                let new = match self.spec.aggregation {
                    Aggregation::Mean if kept.len() <= k_max => {
                        let (stack, w) = pack_for_artifact(&kept, &kept_w, k_max);
                        self.engine.aggregate(&spec.task, &stack, &w)?
                    }
                    agg => agg.apply(&kept, &kept_w),
                };
                final_params = Some(new);
                aggregated = true;
            }
        }
        Ok(WakeOutcome {
            task: job.task,
            client: job.client,
            params: final_params,
            trained,
            steps: job.drawn.len() as u64,
            aggregated,
            pulls,
            payload_bytes,
            rejected,
        })
    }

    /// The serial apply half: commit one wake's outcome in batch order —
    /// telemetry, fingerprint records, parameters, and the re-wake push
    /// land exactly as the serial loop would emit them.
    fn apply_wake(&mut self, o: WakeOutcome) {
        let lane = &mut self.lanes[o.task];
        let i = o.client;
        if o.trained {
            lane.clients[i].train_steps += o.steps;
            lane.clients[i].version += 1;
        }
        let task_key = o.task as u32;
        for (j, fp, dup) in o.pulls {
            if dup {
                lane.clients[i].dedup_skips += 1;
            } else {
                lane.clients[i].fingerprints.record(j as u64, task_key, fp);
                lane.clients[j].model_bytes_sent += o.payload_bytes;
            }
        }
        if let Some(p) = o.params {
            lane.clients[i].params = p;
        }
        lane.clients[i].rejected_models += o.rejected;
        if o.aggregated {
            lane.clients[i].version += 1;
            lane.clients[i].exchanges += 1;
        }
        let period = lane.clients[i].schedule.period;
        lane.clients[i].next_wake = self.now + period;
        self.queue
            .push(self.now + period, TrainEvent::Wake { task: o.task, client: i });
    }

    /// Drain the current wake batch: compute every job (in parallel when
    /// there is more than one), then apply outcomes serially in arrival
    /// order. Clears the per-lane touched sets.
    fn flush_wakes(
        &mut self,
        batch: &mut Vec<WakeJob>,
        touched: &mut [HashSet<usize>],
    ) -> Result<()> {
        if batch.is_empty() {
            return Ok(());
        }
        let jobs = std::mem::take(batch);
        for t in touched.iter_mut() {
            t.clear();
        }
        let outcomes: Vec<WakeOutcome> = if jobs.len() >= 2 {
            let this: &Self = &*self;
            jobs.par_iter()
                .map(|j| this.compute_wake(j))
                .collect::<Result<Vec<_>>>()?
        } else {
            jobs.iter()
                .map(|j| self.compute_wake(j))
                .collect::<Result<Vec<_>>>()?
        };
        for o in outcomes {
            self.apply_wake(o);
        }
        Ok(())
    }

    /// Run until `until` (µs of simulated time), sampling accuracy every
    /// `sample_every` (each lane records its own series). One event loop
    /// serves every method and every lane: synchronous rounds,
    /// asynchronous gossip, and scheduled churn all pop from the same
    /// heap, and the embedded overlay (if any) advances in lockstep.
    /// Returns the primary lane's final sample.
    ///
    /// Same-instant `Wake` events whose read/write footprints are
    /// disjoint (no client of one appears in the neighborhood ∪ self of
    /// another, per lane) batch together and run their compute phase on
    /// the rayon pool; everything observable — rng draws, fingerprints,
    /// telemetry, queue order — is sequenced exactly as the serial loop
    /// sequences it, so batching never changes a trajectory.
    pub fn run(&mut self, until: Time, sample_every: Time) -> Result<AccuracySample> {
        self.ensure_overlay();
        // baseline at the current clock (skipped on resume if the prior
        // run already sampled this instant)
        for t in 0..self.lanes.len() {
            if self.lanes[t].samples.last().map(|s| s.at) != Some(self.now) {
                self.record_lane_sample(t)?;
            }
        }
        // Seed the wake/round/sample chains on the first run only; the
        // chains re-push themselves unconditionally, so events past
        // `until` stay queued and a later `run` resumes them — calling
        // `run` again continues training rather than double-scheduling.
        if self.now == 0 {
            if self.synchronous() {
                let period = self.lanes[0].clients[0].schedule.period;
                self.queue.push(period, TrainEvent::Round);
            } else {
                for t in 0..self.lanes.len() {
                    for i in 0..self.lanes[t].clients.len() {
                        if self.lanes[t].clients[i].alive {
                            self.queue.push(
                                self.lanes[t].clients[i].next_wake,
                                TrainEvent::Wake { task: t, client: i },
                            );
                        }
                    }
                }
            }
            if sample_every > 0 {
                for t in 0..self.lanes.len() {
                    self.queue.push(sample_every, TrainEvent::Sample { task: t });
                }
            }
        }
        let mut batch: Vec<WakeJob> = Vec::new();
        let mut touched: Vec<HashSet<usize>> = vec![HashSet::new(); self.lanes.len()];
        while let Some(t) = self.queue.peek_time() {
            if t > until {
                break;
            }
            self.now = t;
            // Drain every event at instant `t` in arrival order. Wakes
            // whose footprint is disjoint from the open batch join it;
            // anything else (a conflicting wake, a sample, a round, any
            // churn) flushes first, so each event still observes exactly
            // the state its serial position would have shown it.
            while self.queue.peek_time() == Some(t) {
                let ev = self.queue.pop().unwrap();
                self.sync_overlay();
                match ev.kind {
                    TrainEvent::Wake { task, client: i } => {
                        if !self.lanes[task].clients[i].alive {
                            continue; // failed/left while the wake was queued
                        }
                        if self.lanes[task].clients[i].byzantine {
                            // compromised clients stop training and
                            // aggregating (no re-wake either) but stay
                            // alive, so neighbors keep pulling their
                            // frozen adversarial payload
                            continue;
                        }
                        let nbrs = self.neighbors_of(i);
                        if touched[task].contains(&i)
                            || nbrs.iter().any(|j| touched[task].contains(j))
                        {
                            self.flush_wakes(&mut batch, &mut touched)?;
                        }
                        touched[task].insert(i);
                        touched[task].extend(nbrs.iter().copied());
                        let steps = if self.freeze_training {
                            0
                        } else {
                            self.lanes[task].spec.local_steps
                        };
                        let drawn: Vec<_> =
                            (0..steps).map(|_| self.draw_batch(task, i)).collect();
                        batch.push(WakeJob { task, client: i, nbrs, drawn });
                    }
                    other => {
                        self.flush_wakes(&mut batch, &mut touched)?;
                        self.handle_serial_event(other, sample_every)?;
                    }
                }
            }
            self.flush_wakes(&mut batch, &mut touched)?;
        }
        self.now = until;
        self.sync_overlay();
        // final sample per lane, unless an in-loop Sample already landed
        // on `until`
        for t in 0..self.lanes.len() {
            if self.lanes[t].samples.last().map(|s| s.at) != Some(until) {
                self.record_lane_sample(t)?;
            }
        }
        Ok(self.lanes[0].samples.last().unwrap().clone())
    }

    /// Every non-wake event, handled exactly as the serial loop handled
    /// it (the caller has already flushed the open wake batch, so this
    /// runs against fully committed state).
    fn handle_serial_event(&mut self, ev: TrainEvent, sample_every: Time) -> Result<()> {
        match ev {
            TrainEvent::Wake { .. } => unreachable!("wake events batch in the run loop"),
            TrainEvent::Round => {
                for i in 0..self.lanes[0].clients.len() {
                    if self.lanes[0].clients[i].alive && !self.lanes[0].clients[i].byzantine {
                        self.local_train(0, i)?;
                    }
                }
                match self.spec.neighborhood.clone() {
                    Neighborhood::Star => self.fedavg_round()?,
                    Neighborhood::Regions { assignment, regions } => {
                        self.gaia_round(&assignment, regions)?
                    }
                    _ => {
                        // synchronous decentralized: everyone
                        // aggregates against pre-round snapshots
                        let snapshot: Vec<Vec<f32>> = self.lanes[0]
                            .clients
                            .iter()
                            .map(|c| c.params.clone())
                            .collect();
                        for i in 0..self.lanes[0].clients.len() {
                            if !self.lanes[0].clients[i].alive
                                || self.lanes[0].clients[i].byzantine
                            {
                                continue;
                            }
                            let nbrs = self.neighbors_of(i);
                            self.aggregate(0, i, &nbrs, &snapshot)?;
                        }
                    }
                }
                self.queue.push(
                    self.now + self.lanes[0].clients[0].schedule.period,
                    TrainEvent::Round,
                );
            }
            TrainEvent::Sample { task } => {
                self.record_lane_sample(task)?;
                self.queue
                    .push(self.now + sample_every.max(1), TrainEvent::Sample { task });
            }
            TrainEvent::Join { client, bootstrap } => {
                // The paper's minimal assumption is one live contact.
                // If the scheduled bootstrap died meanwhile,
                // re-bootstrap through any other live member; with no
                // live contact at all the joiner cannot enter the
                // network and stays a dead placeholder.
                let boot = if self.lanes[0].clients[bootstrap].alive {
                    Some(bootstrap)
                } else {
                    self.lanes[0]
                        .clients
                        .iter()
                        .position(|c| c.alive && c.id != client)
                };
                let mut entered = false;
                if let (Some(sim), Some(b)) = (self.overlay.as_mut(), boot) {
                    if sim.contains_node(b as NodeId) {
                        sim.schedule_join(self.now, client as NodeId, b as NodeId);
                        entered = true;
                    }
                }
                if entered {
                    let now = self.now;
                    let sync = self.synchronous();
                    for t in 0..self.lanes.len() {
                        let wake = now + self.lanes[t].clients[client].next_wake.max(1);
                        self.lanes[t].clients[client].alive = true;
                        self.lanes[t].clients[client].next_wake = wake;
                        if !sync {
                            self.queue.push(wake, TrainEvent::Wake { task: t, client });
                        }
                    }
                    self.invalidate_neighbor_caches_for(client);
                }
            }
            TrainEvent::Fail { client } => {
                if client >= self.lanes[0].clients.len() {
                    return Ok(());
                }
                if let Some(sim) = self.overlay.as_mut() {
                    sim.schedule_fail(self.now, client as NodeId);
                }
                self.retire_client(client);
            }
            TrainEvent::Leave { client } => {
                if client >= self.lanes[0].clients.len() {
                    return Ok(());
                }
                if let Some(sim) = self.overlay.as_mut() {
                    sim.schedule_leave(self.now, client as NodeId);
                }
                self.retire_client(client);
            }
            TrainEvent::Attack { client, kind } => {
                if client >= self.lanes[0].clients.len() {
                    return Ok(());
                }
                match kind {
                    AttackKind::Poison(mode) => {
                        for lane in &mut self.lanes {
                            let c = &mut lane.clients[client];
                            match mode {
                                PoisonMode::Nan => {
                                    c.params.iter_mut().for_each(|v| *v = f32::NAN)
                                }
                                PoisonMode::Scale => {
                                    c.params.iter_mut().for_each(|v| *v *= -10.0)
                                }
                                PoisonMode::SignFlip => {
                                    c.params.iter_mut().for_each(|v| *v = -*v)
                                }
                            }
                            c.version += 1;
                            c.byzantine = true;
                        }
                    }
                    AttackKind::StaleMark { lag } => {
                        // the victim keeps training honestly until `lag`
                        // elapses, then replays today's model forever
                        let snap: Vec<Vec<f32>> = self
                            .lanes
                            .iter()
                            .map(|l| l.clients[client].params.clone())
                            .collect();
                        self.stale_snapshots.insert(client, snap);
                        self.queue.push(
                            self.now + lag.max(1),
                            TrainEvent::Attack {
                                client,
                                kind: AttackKind::StaleApply,
                            },
                        );
                    }
                    AttackKind::StaleApply => {
                        // skip if the victim churned out in the meantime
                        if let Some(snap) = self.stale_snapshots.remove(&client) {
                            if self.lanes[0].clients[client].alive {
                                for (lane, p) in self.lanes.iter_mut().zip(snap) {
                                    let c = &mut lane.clients[client];
                                    c.params = p;
                                    c.version += 1;
                                    c.byzantine = true;
                                }
                            }
                        }
                    }
                    AttackKind::Eclipse => {
                        // the eclipsed arc serves the common init — the
                        // "stuck at birth" payload an isolated attacker
                        // region would present to the rest of the ring
                        for lane in &mut self.lanes {
                            let p = lane.init_params.clone();
                            let c = &mut lane.clients[client];
                            c.params = p;
                            c.version += 1;
                            c.byzantine = true;
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Total model payload bytes sent, per client, summed over every lane
    /// (Fig. 20d metric; single-task runs have one lane).
    pub fn model_mb_per_client(&self) -> f64 {
        let total: u64 = self
            .lanes
            .iter()
            .flat_map(|l| l.clients.iter())
            .map(|c| c.model_bytes_sent)
            .sum();
        total as f64 / (1024.0 * 1024.0) / self.lanes[0].clients.len() as f64
    }

    /// Total training compute (train steps) per client across lanes —
    /// Fig. 15's relative-computation-cost metric numerator.
    pub fn train_steps_per_client(&self) -> f64 {
        let total: u64 = self
            .lanes
            .iter()
            .flat_map(|l| l.clients.iter())
            .map(|c| c.train_steps)
            .sum();
        total as f64 / self.lanes[0].clients.len() as f64
    }
}

/// Per-client Markov stream from its shard labels (each nonzero label
/// acts as a Shakespeare "role"), seeded from the owning task's seed so
/// coexisting lstm tasks draw independent streams.
fn char_stream_for(seed: u64, i: usize, w: &[f64]) -> CharStream {
    let roles: Vec<u64> = w
        .iter()
        .enumerate()
        .filter(|(_, &x)| x > 0.0)
        .map(|(l, _)| seed ^ (l as u64 + 1))
        .collect();
    let roles = if roles.is_empty() { vec![seed] } else { roles };
    CharStream::new(&roles, seed ^ (i as u64) << 8)
}
