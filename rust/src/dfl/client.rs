//! Per-client DFL state: local data distribution, capacity tier, exchange
//! schedule, confidence parameters, model version and fingerprint cache.

use crate::data::{expected_histogram, kl_divergence_vs_uniform};
use crate::mep::{Capacity, ExchangeSchedule, FingerprintCache};
use crate::ndmp::messages::Time;
use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct ClientState {
    pub id: usize,
    pub capacity: Capacity,
    pub schedule: ExchangeSchedule,
    /// Unnormalized label weights of the local shard (non-iid spec).
    pub label_weights: Vec<f64>,
    /// Flat model parameters (artifact ABI).
    pub params: Vec<f32>,
    /// Raw data confidence `c_d` (computed once from the shard).
    pub c_d: f64,
    /// Raw communication confidence `c_c = 1/T_u`.
    pub c_c: f64,
    /// Monotone model version (bumped on every local update/aggregate).
    pub version: u64,
    pub fingerprints: FingerprintCache,
    pub rng: Rng,
    /// Next time this client wakes to train+exchange.
    pub next_wake: Time,
    /// Live in the current run. Scheduled joiners start dead (placeholder
    /// until their `TrainEvent::Join` fires); failed/left clients stop
    /// waking and drop out of every neighborhood and the accuracy mean.
    pub alive: bool,
    /// Telemetry: bytes of model payload sent, exchanges skipped by dedup.
    pub model_bytes_sent: u64,
    pub dedup_skips: u64,
    pub exchanges: u64,
    pub train_steps: u64,
    /// Compromised by an adversarial scenario phase. Byzantine clients
    /// stay alive (neighbors still pull their models — that *is* the
    /// attack) but stop training and aggregating, so their payload never
    /// self-heals through honest averages.
    pub byzantine: bool,
    /// Neighbor models this client rejected for non-finite parameters or
    /// weights (the Byzantine guard in front of every aggregation).
    pub rejected_models: u64,
}

impl ClientState {
    pub fn new(
        id: usize,
        capacity: Capacity,
        base_period: Time,
        label_weights: Vec<f64>,
        params: Vec<f32>,
        seed: u64,
    ) -> Self {
        let schedule = ExchangeSchedule::coarse(base_period, capacity);
        let hist = expected_histogram(&label_weights, 10_000);
        let c_d = (-kl_divergence_vs_uniform(&hist)).exp();
        let c_c = 1.0 / schedule.period as f64;
        // stagger wake-ups like real unsynchronized clients
        let mut rng = Rng::new(seed ^ (id as u64).wrapping_mul(0x9E37_79B9));
        let next_wake = (rng.next_f64() * schedule.period as f64 * 0.1) as Time;
        Self {
            id,
            capacity,
            schedule,
            label_weights,
            params,
            c_d,
            c_c,
            version: 0,
            fingerprints: FingerprintCache::new(),
            rng,
            next_wake,
            alive: true,
            model_bytes_sent: 0,
            dedup_skips: 0,
            exchanges: 0,
            train_steps: 0,
            byzantine: false,
            rejected_models: 0,
        }
    }

    /// Raw confidence pair `(c_d, c_c)` used in neighborhood normalization.
    pub fn raw_confidence(&self) -> (f64, f64) {
        (self.c_d, self.c_c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn confidence_reflects_shard_skew() {
        let iid = ClientState::new(0, Capacity::Medium, 1_000, vec![1.0; 10], vec![], 1);
        let mut skewed_w = vec![0.0; 10];
        skewed_w[0] = 1.0;
        let skewed = ClientState::new(1, Capacity::Medium, 1_000, skewed_w, vec![], 1);
        assert!(iid.c_d > skewed.c_d);
        assert!((iid.c_d - 1.0).abs() < 1e-9);
    }

    #[test]
    fn capacity_affects_comm_confidence() {
        let fast = ClientState::new(0, Capacity::High, 9_000, vec![1.0; 4], vec![], 2);
        let slow = ClientState::new(1, Capacity::Low, 9_000, vec![1.0; 4], vec![], 2);
        assert!(fast.c_c > slow.c_c);
        assert!(fast.schedule.period < slow.schedule.period);
    }

    #[test]
    fn wake_is_staggered_within_a_fraction_of_period() {
        let c = ClientState::new(3, Capacity::Medium, 100_000, vec![1.0; 4], vec![], 5);
        assert!(c.next_wake < 10_000);
    }
}
