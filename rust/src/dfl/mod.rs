//! DFL methods and the unified training engine: FedLay (MEP over the
//! FedLay overlay) plus the paper's comparators (FedAvg, Gaia, DFL-DDS,
//! Chord), driven by one discrete-event loop (`sim::Scheduler`) in which
//! client wake-ups, synchronous rounds, accuracy samples and churn are
//! all heap events. `Neighborhood::Dynamic` embeds the NDMP overlay
//! simulator so topology maintenance and training share a single clock.

pub mod client;
pub mod methods;
pub mod trainer;

pub use client::ClientState;
pub use methods::{MethodSpec, Mobility, Neighborhood};
pub use trainer::{AccuracySample, TaskData, TrainEvent, Trainer};
pub mod harness;
