//! DFL methods and the unified training engine: FedLay (MEP over the
//! FedLay overlay) plus the paper's comparators (FedAvg, Gaia, DFL-DDS,
//! Chord), driven by one discrete-event loop (`sim::Scheduler`) in which
//! client wake-ups, synchronous rounds, accuracy samples and churn are
//! all heap events. `Neighborhood::Dynamic` embeds the NDMP overlay
//! simulator so topology maintenance and training share a single clock.
//! The trainer is natively multi-task: N independent model tasks (lanes)
//! share one overlay and one scheduler (`multitask` holds the spec-level
//! harness; `docs/multitask.md` documents the format).

pub mod client;
pub mod methods;
pub mod multitask;
pub mod trainer;

pub use client::ClientState;
pub use methods::{Compression, MethodSpec, Mobility, Neighborhood};
pub use trainer::{AccuracySample, AttackKind, TaskData, TaskLane, TrainEvent, Trainer};

/// Robust aggregation rules (re-exported from `mep::aggregate` so DFL
/// callers configure `MethodSpec::with_aggregation` without reaching
/// into MEP internals).
pub use crate::mep::Aggregation;
pub mod harness;
