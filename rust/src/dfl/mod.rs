//! DFL methods and the training driver: FedLay (MEP over the FedLay
//! overlay) plus the paper's comparators (FedAvg, Gaia, DFL-DDS, Chord)
//! executing the AOT model artifacts through the PJRT runtime.

pub mod client;
pub mod methods;
pub mod trainer;

pub use client::ClientState;
pub use methods::{MethodSpec, Mobility, Neighborhood};
pub use trainer::{AccuracySample, TaskData, Trainer};
pub mod harness;
