//! Classic DFL topologies from paper Table I: ring, 2D grid, complete
//! graph, (dynamic) chain, hypercube, and torus.

use crate::graph::Graph;

/// Ring: degree 2 (He et al. [11]).
pub fn ring(n: usize) -> Graph {
    let mut g = Graph::new(n);
    if n >= 2 {
        for i in 0..n {
            g.add_edge(i, (i + 1) % n);
        }
    }
    g
}

/// Chain (path): the GADMM "dynamic chain" static skeleton.
pub fn chain(n: usize) -> Graph {
    let mut g = Graph::new(n);
    for i in 1..n {
        g.add_edge(i - 1, i);
    }
    g
}

/// Complete graph: degree N-1.
pub fn complete(n: usize) -> Graph {
    let mut g = Graph::new(n);
    for u in 0..n {
        for v in (u + 1)..n {
            g.add_edge(u, v);
        }
    }
    g
}

/// 2D grid, as square as possible (degree <= 4, no wraparound).
pub fn grid2d(n: usize) -> Graph {
    let mut g = Graph::new(n);
    if n == 0 {
        return g;
    }
    let cols = (n as f64).sqrt().ceil() as usize;
    for i in 0..n {
        let (r, c) = (i / cols, i % cols);
        if c + 1 < cols && i + 1 < n {
            g.add_edge(i, i + 1);
        }
        if (r + 1) * cols + c < n {
            g.add_edge(i, (r + 1) * cols + c);
        }
    }
    g
}

/// 2D torus (grid with wraparound, degree 4). Requires n = rows*cols with
/// rows, cols >= 3 for a simple graph; we pick the most square factoring.
pub fn torus(n: usize) -> Graph {
    let mut g = Graph::new(n);
    if n < 9 {
        return ring(n); // degenerate: fall back
    }
    let mut rows = (n as f64).sqrt() as usize;
    while rows > 1 && n % rows != 0 {
        rows -= 1;
    }
    let cols = n / rows;
    if rows < 3 || cols < 3 {
        return ring(n);
    }
    for r in 0..rows {
        for c in 0..cols {
            let i = r * cols + c;
            g.add_edge(i, r * cols + (c + 1) % cols);
            g.add_edge(i, ((r + 1) % rows) * cols + c);
        }
    }
    g
}

/// Hypercube over n = 2^k nodes (degree k = log2 n). Panics otherwise.
pub fn hypercube(n: usize) -> Graph {
    assert!(n.is_power_of_two(), "hypercube needs a power of two, got {n}");
    let mut g = Graph::new(n);
    let k = n.trailing_zeros() as usize;
    for u in 0..n {
        for b in 0..k {
            g.add_edge(u, u ^ (1 << b));
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::traversal::is_connected;
    use crate::metrics::path_metrics;

    #[test]
    fn ring_properties() {
        let g = ring(10);
        assert!(is_connected(&g));
        assert!((0..10).all(|u| g.degree(u) == 2));
        assert_eq!(path_metrics(&g).diameter, 5);
    }

    #[test]
    fn chain_properties() {
        let g = chain(10);
        assert!(is_connected(&g));
        assert_eq!(g.degree(0), 1);
        assert_eq!(g.degree(5), 2);
        assert_eq!(path_metrics(&g).diameter, 9);
    }

    #[test]
    fn complete_properties() {
        let g = complete(8);
        assert_eq!(g.m(), 28);
        assert!((0..8).all(|u| g.degree(u) == 7));
    }

    #[test]
    fn grid_properties() {
        let g = grid2d(16);
        assert!(is_connected(&g));
        assert!(g.max_degree() <= 4);
        assert_eq!(path_metrics(&g).diameter, 6); // 4x4 grid: (3+3)
    }

    #[test]
    fn torus_properties() {
        let g = torus(36);
        assert!(is_connected(&g));
        assert!((0..36).all(|u| g.degree(u) == 4));
        assert_eq!(path_metrics(&g).diameter, 6); // 6x6 torus: 3+3
    }

    #[test]
    fn hypercube_properties() {
        let g = hypercube(32);
        assert!(is_connected(&g));
        assert!((0..32).all(|u| g.degree(u) == 5));
        assert_eq!(path_metrics(&g).diameter, 5);
    }

    #[test]
    #[should_panic]
    fn hypercube_rejects_non_power() {
        hypercube(20);
    }
}
