//! Social-network topology baseline.
//!
//! The paper samples 300 nodes of the Facebook ego-network dataset [22];
//! that dataset is unavailable offline, so we generate a Barabási–Albert
//! preferential-attachment graph (same heavy-tailed degree family, strong
//! local clustering added via triad closure) — see DESIGN.md
//! §Substitutions. The comparator's role in Fig. 3 is "overlay from another
//! application channel with skewed degrees", which BA+triads reproduces.

use crate::graph::gen::barabasi_albert;
use crate::graph::Graph;
use crate::util::Rng;

/// BA graph with an extra triad-closure pass (clustering like a social
/// graph): for each node, with probability `p_triad` connect two of its
/// neighbors.
pub fn social(n: usize, seed: u64) -> Graph {
    let mut rng = Rng::new(seed ^ 0x50C1A1);
    let mut g = barabasi_albert(n, 3, &mut rng);
    let p_triad = 0.3;
    for u in 0..n {
        let nbrs: Vec<usize> = g.neighbors(u).collect();
        if nbrs.len() >= 2 && rng.chance(p_triad) {
            let a = nbrs[rng.index(nbrs.len())];
            let b = nbrs[rng.index(nbrs.len())];
            if a != b {
                g.add_edge(a, b);
            }
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::traversal::is_connected;

    #[test]
    fn social_connected_heavy_tail() {
        let g = social(300, 11);
        assert!(is_connected(&g));
        assert!(g.max_degree() > 3 * g.avg_degree() as usize);
    }

    #[test]
    fn social_deterministic() {
        assert_eq!(social(100, 2).edges(), social(100, 2).edges());
    }
}
