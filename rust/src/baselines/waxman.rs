//! Waxman random geometric network (Waxman [36]): nodes placed in the unit
//! square; edge probability decays with Euclidean distance,
//! `P(u,v) = α · exp(-d(u,v) / (β·D))` with `D = max distance`.
//! Models physical-proximity overlays — the paper uses it to show that
//! geographic locality hurts DFL propagation (Fig. 3).

use crate::graph::Graph;
use crate::util::Rng;

pub struct WaxmanParams {
    pub alpha: f64,
    pub beta: f64,
}

impl Default for WaxmanParams {
    fn default() -> Self {
        // Locality-emphasizing values: sparse, connected at n~300, with the
        // long-path geometric character the paper contrasts against.
        Self { alpha: 0.4, beta: 0.06 }
    }
}

pub fn waxman(n: usize, params: &WaxmanParams, seed: u64) -> Graph {
    let mut rng = Rng::new(seed ^ 0x0A0A_BEEF);
    let pts: Vec<(f64, f64)> = (0..n).map(|_| (rng.next_f64(), rng.next_f64())).collect();
    let dmax = 2f64.sqrt();
    let mut g = Graph::new(n);
    for u in 0..n {
        for v in (u + 1)..n {
            let dx = pts[u].0 - pts[v].0;
            let dy = pts[u].1 - pts[v].1;
            let d = (dx * dx + dy * dy).sqrt();
            let p = params.alpha * (-d / (params.beta * dmax)).exp();
            if rng.chance(p) {
                g.add_edge(u, v);
            }
        }
    }
    // Waxman graphs can leave isolated nodes; attach each to its nearest
    // neighbor so metric computations see one component (the paper's
    // comparator is implicitly connected).
    for u in 0..n {
        if g.degree(u) == 0 {
            let mut best = usize::MAX;
            let mut bd = f64::INFINITY;
            for v in 0..n {
                if v == u {
                    continue;
                }
                let dx = pts[u].0 - pts[v].0;
                let dy = pts[u].1 - pts[v].1;
                let d = dx * dx + dy * dy;
                if d < bd {
                    bd = d;
                    best = v;
                }
            }
            g.add_edge(u, best);
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn waxman_no_isolated_nodes() {
        let g = waxman(200, &WaxmanParams::default(), 3);
        assert!((0..200).all(|u| g.degree(u) >= 1));
    }

    #[test]
    fn waxman_prefers_short_edges() {
        // with beta small, graph should be sparse relative to complete
        let g = waxman(200, &WaxmanParams::default(), 4);
        assert!(g.m() < 200 * 199 / 8, "too dense: {}", g.m());
        assert!(g.m() > 100, "too sparse: {}", g.m());
    }

    #[test]
    fn waxman_deterministic() {
        let a = waxman(100, &WaxmanParams::default(), 9);
        let b = waxman(100, &WaxmanParams::default(), 9);
        assert_eq!(a.edges(), b.edges());
    }
}
