//! Baseline overlay topologies from the paper's Table I and Fig. 3
//! comparators, plus the "Best of 100 random d-regular graphs" generator.

pub mod chord;
pub mod classic;
pub mod delaunay;
pub mod social;
pub mod viceroy;
pub mod waxman;

pub use chord::chord;
pub use classic::{chain, complete, grid2d, hypercube, ring, torus};
pub use delaunay::delaunay_like;
pub use social::social;
pub use viceroy::viceroy;
pub use waxman::{waxman, WaxmanParams};

use crate::graph::gen::random_regular;
use crate::graph::Graph;
use crate::metrics::{self, TopologyMetrics};
use crate::util::Rng;

/// "Best": generate `trials` random d-regular graphs and keep, per metric,
/// the best value observed (paper §II-C(1)). Returns the per-metric optima
/// — note these may come from *different* graphs, exactly like the paper's
/// plotted "Best" curve.
pub struct BestOfRegular {
    pub best_convergence_factor: f64,
    pub best_lambda: f64,
    pub best_diameter: u32,
    pub best_aspl: f64,
}

pub fn best_of_regular(n: usize, d: usize, trials: usize, seed: u64) -> BestOfRegular {
    let mut rng = Rng::new(seed ^ 0xBE57);
    let mut best = BestOfRegular {
        best_convergence_factor: f64::INFINITY,
        best_lambda: f64::INFINITY,
        best_diameter: u32::MAX,
        best_aspl: f64::INFINITY,
    };
    for t in 0..trials {
        let g = random_regular(n, d, &mut rng);
        let m = metrics::evaluate(&g, seed.wrapping_add(t as u64));
        if !m.connected {
            continue;
        }
        best.best_convergence_factor = best.best_convergence_factor.min(m.convergence_factor);
        best.best_lambda = best.best_lambda.min(m.lambda);
        best.best_diameter = best.best_diameter.min(m.diameter);
        best.best_aspl = best.best_aspl.min(m.avg_shortest_path);
    }
    best
}

/// Named topology constructor used by the CLI and the Fig. 3 harness.
pub fn by_name(name: &str, n: usize, seed: u64) -> anyhow::Result<Graph> {
    Ok(match name {
        "ring" => ring(n),
        "chain" => chain(n),
        "complete" => complete(n),
        "grid" => grid2d(n),
        "torus" => torus(n),
        "hypercube" => hypercube(n.next_power_of_two() / 2),
        "chord" => chord(n),
        "viceroy" => viceroy(n, seed),
        "waxman" => waxman(n, &WaxmanParams::default(), seed),
        "delaunay" => delaunay_like(n, 6, seed),
        "social" => social(n, seed),
        "fedlay" => crate::topology::fedlay_graph(n, 3),
        other => anyhow::bail!("unknown topology {other:?}"),
    })
}

/// Evaluate a named topology (CLI `topology` subcommand).
pub fn evaluate_named(name: &str, n: usize, seed: u64) -> anyhow::Result<TopologyMetrics> {
    Ok(metrics::evaluate(&by_name(name, n, seed)?, seed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_of_regular_sane() {
        let b = best_of_regular(60, 6, 5, 3);
        assert!(b.best_lambda > 0.0 && b.best_lambda < 1.0);
        assert!(b.best_convergence_factor >= 1.0);
        assert!(b.best_diameter >= 2 && b.best_diameter < 10);
        assert!(b.best_aspl > 1.0);
    }

    #[test]
    fn by_name_covers_all() {
        for name in [
            "ring", "chain", "complete", "grid", "torus", "hypercube", "chord", "viceroy",
            "waxman", "delaunay", "social", "fedlay",
        ] {
            let g = by_name(name, 64, 1).unwrap();
            assert!(g.n() >= 32, "{name}");
        }
        assert!(by_name("nope", 10, 1).is_err());
    }
}
