//! Distributed-Delaunay-triangulation-style overlay (Lee & Lam [19],
//! Lam & Qian [17]) as a topology baseline.
//!
//! A full Delaunay triangulation implementation is overkill for the
//! metric study: what the paper exercises is its *geometric locality*
//! (constant degree, greedy-routable, neighbors are spatially close). We
//! build the standard planar proxy: connect each node to its k nearest
//! neighbors in the unit square and symmetrize, then add a Gabriel-graph
//! pruning pass to keep the planar, short-edge character. This reproduces
//! DT's qualitative position in Fig. 3 (long paths across the space).

use crate::graph::Graph;
use crate::util::Rng;

pub fn delaunay_like(n: usize, k: usize, seed: u64) -> Graph {
    assert!(n > k);
    let mut rng = Rng::new(seed ^ 0xDE1A);
    let pts: Vec<(f64, f64)> = (0..n).map(|_| (rng.next_f64(), rng.next_f64())).collect();
    let d2 = |u: usize, v: usize| -> f64 {
        let dx = pts[u].0 - pts[v].0;
        let dy = pts[u].1 - pts[v].1;
        dx * dx + dy * dy
    };
    let mut g = Graph::new(n);
    for u in 0..n {
        // k nearest neighbors of u
        let mut others: Vec<usize> = (0..n).filter(|&v| v != u).collect();
        others.sort_by(|&a, &b| d2(u, a).partial_cmp(&d2(u, b)).unwrap());
        for &v in others.iter().take(k) {
            // Gabriel condition: no third point inside the circle with
            // diameter (u,v). Keeps edges locally minimal like a DT.
            let mid = ((pts[u].0 + pts[v].0) / 2.0, (pts[u].1 + pts[v].1) / 2.0);
            let r2 = d2(u, v) / 4.0;
            let blocked = (0..n).any(|w| {
                if w == u || w == v {
                    return false;
                }
                let dx = pts[w].0 - mid.0;
                let dy = pts[w].1 - mid.1;
                dx * dx + dy * dy < r2
            });
            if !blocked {
                g.add_edge(u, v);
            }
        }
        // guarantee minimum connectivity: always keep the single nearest
        if g.degree(u) == 0 {
            g.add_edge(u, others[0]);
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::traversal::num_components;
    use crate::metrics::path_metrics;

    #[test]
    fn dt_like_constant_degree() {
        let g = delaunay_like(300, 6, 5);
        assert!(g.avg_degree() < 8.0);
        assert!((0..300).all(|u| g.degree(u) >= 1));
    }

    #[test]
    fn dt_like_mostly_connected_with_long_paths() {
        let g = delaunay_like(300, 6, 6);
        assert!(num_components(&g) <= 3);
        // geometric locality => diameter grows like sqrt(n), much larger
        // than an expander's log(n)
        let m = path_metrics(&g);
        assert!(m.diameter >= 10, "diameter {}", m.diameter);
    }

    #[test]
    fn dt_deterministic() {
        assert_eq!(
            delaunay_like(100, 5, 1).edges(),
            delaunay_like(100, 5, 1).edges()
        );
    }
}
