//! Viceroy overlay (Malkhi, Naor, Ratajczak [21]): a constant-degree
//! butterfly-network emulation.
//!
//! We follow the classic construction: each node draws a random level
//! `l ∈ {1..log n}` and a random ring position; links are (a) ring
//! successor/predecessor, (b) level-ring neighbors, (c) butterfly "down"
//! links to level l+1 at distance ~1/2^l and ~0, and (d) an "up" link to
//! level l-1. Degree is O(1); routing diameter is O(log n) in expectation
//! — matching the qualitative dot the paper plots in Fig. 3.

use crate::graph::Graph;
use crate::util::Rng;

pub fn viceroy(n: usize, seed: u64) -> Graph {
    assert!(n >= 4);
    let mut rng = Rng::new(seed ^ 0x51CE_B00C);
    let levels = ((n as f64).log2().floor() as usize).max(1);
    // random ring positions in [0,1), unique by construction of f64 draws
    let pos: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| pos[a].partial_cmp(&pos[b]).unwrap());
    let level: Vec<usize> = (0..n).map(|_| 1 + rng.index(levels)).collect();

    let mut g = Graph::new(n);
    // (a) general ring
    for i in 0..n {
        g.add_edge(order[i], order[(i + 1) % n]);
    }
    // helper: node at smallest position >= x (wrapping), by binary search
    let mut sorted_pos: Vec<(f64, usize)> = order.iter().map(|&i| (pos[i], i)).collect();
    sorted_pos.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let successor_at = |x: f64, pred: &dyn Fn(usize) -> bool| -> Option<usize> {
        let start = sorted_pos.partition_point(|p| p.0 < x);
        for k in 0..n {
            let cand = sorted_pos[(start + k) % n].1;
            if pred(cand) {
                return Some(cand);
            }
        }
        None
    };
    for u in 0..n {
        let l = level[u];
        let x = pos[u];
        // (b) level ring: next node on the same level
        if let Some(v) = successor_at(x + 1e-9, &|c| c != u && level[c] == l) {
            g.add_edge(u, v);
        }
        // (c) down links to level l+1: one "close", one at distance 1/2^l
        if l < levels {
            if let Some(v) = successor_at(x, &|c| c != u && level[c] == l + 1) {
                g.add_edge(u, v);
            }
            let far = (x + 1.0 / (1u64 << l) as f64).fract();
            if let Some(v) = successor_at(far, &|c| c != u && level[c] == l + 1) {
                g.add_edge(u, v);
            }
        }
        // (d) up link to level l-1
        if l > 1 {
            if let Some(v) = successor_at(x, &|c| c != u && level[c] == l - 1) {
                g.add_edge(u, v);
            }
        }
    }
    // fix the degenerate case where level filtering left pieces: the
    // general ring already guarantees connectivity.
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::traversal::is_connected;

    #[test]
    fn viceroy_connected_constant_degree() {
        let g = viceroy(300, 42);
        assert!(is_connected(&g));
        // butterfly emulation: constant average degree, way below log n
        assert!(g.avg_degree() < 12.0, "avg {}", g.avg_degree());
    }

    #[test]
    fn viceroy_deterministic_per_seed() {
        assert_eq!(viceroy(100, 7).edges(), viceroy(100, 7).edges());
        assert_ne!(viceroy(100, 7).edges(), viceroy(100, 8).edges());
    }
}
