//! Chord DHT overlay (Stoica et al. [30]) as a topology baseline.
//!
//! Nodes are hashed onto a 2^m identifier ring; each node keeps its
//! successor and m fingers (successor of `id + 2^i`). The undirected
//! overlay graph has degree ~2·log2(n) (fingers + reverse fingers), which
//! is why paper Fig. 3 shows Chord with low diameter but a high
//! convergence factor relative to its degree.

use crate::graph::Graph;
use sha2::{Digest, Sha256};

const M: usize = 32; // identifier bits

fn chord_id(node: u64) -> u64 {
    let mut h = Sha256::new();
    h.update(b"chord");
    h.update(node.to_be_bytes());
    let d = h.finalize();
    let mut b = [0u8; 8];
    b.copy_from_slice(&d[..8]);
    u64::from_be_bytes(b) & ((1u64 << M) - 1)
}

/// Build the Chord overlay over `n` nodes (indices 0..n are hashed to the
/// identifier ring; duplicate ids are perturbed deterministically).
pub fn chord(n: usize) -> Graph {
    assert!(n >= 2);
    // (ring id, node index), sorted along the identifier circle
    let mut pts: Vec<(u64, usize)> = (0..n).map(|i| (chord_id(i as u64), i)).collect();
    pts.sort();
    // perturb exact duplicates (astronomically rare, but keep total order)
    for i in 1..pts.len() {
        if pts[i].0 == pts[i - 1].0 {
            pts[i].0 = pts[i].0.wrapping_add(1) & ((1u64 << M) - 1);
        }
    }
    pts.sort();

    // successor of an identifier: first point with id >= x (wrapping)
    let successor = |x: u64| -> usize {
        match pts.binary_search_by(|p| p.0.cmp(&x)) {
            Ok(i) => pts[i].1,
            Err(i) => pts[i % pts.len()].1,
        }
    };

    let mut g = Graph::new(n);
    for &(id, node) in &pts {
        // successor link
        let succ = successor((id + 1) & ((1u64 << M) - 1));
        if succ != node {
            g.add_edge(node, succ);
        }
        // finger links: successor(id + 2^i)
        for i in 0..M {
            let target = (id.wrapping_add(1u64 << i)) & ((1u64 << M) - 1);
            let f = successor(target);
            if f != node {
                g.add_edge(node, f);
            }
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::traversal::is_connected;
    use crate::metrics::path_metrics;

    #[test]
    fn chord_connected_and_log_degree() {
        let n = 300;
        let g = chord(n);
        assert!(is_connected(&g));
        let avg = g.avg_degree();
        let log2n = (n as f64).log2();
        // paper: node degree ≈ 2 log n
        assert!(avg > log2n && avg < 4.0 * log2n, "avg degree {avg}");
    }

    #[test]
    fn chord_low_diameter() {
        let g = chord(300);
        let m = path_metrics(&g);
        assert!(m.diameter <= 8, "diameter {}", m.diameter);
    }

    #[test]
    fn chord_deterministic() {
        let a = chord(64);
        let b = chord(64);
        assert_eq!(a.edges(), b.edges());
    }
}
