//! Synthetic datasets standing in for MNIST / CIFAR-10 / Shakespeare
//! (DESIGN.md §Substitutions).
//!
//! * `GaussianTask` — class-conditional Gaussians in `D` dims, `C`
//!   classes: each class has a deterministic unit-ish mean vector; samples
//!   are `mean + σ·N(0, I)`. Separable but noisy, so SGD accuracy climbs
//!   smoothly from chance toward ~1 like the paper's image tasks.
//! * `CharStream` (in `stream.rs`) — Markov character stream for the
//!   LSTM task.
//!
//! All generation is seeded and reproducible; train and test draws come
//! from disjoint RNG streams.

use crate::util::Rng;

/// A labeled batch: features flattened row-major `[B, D]`, labels `[B]`.
#[derive(Debug, Clone)]
pub struct Batch {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub batch: usize,
    pub dim: usize,
}

/// Class-conditional Gaussian classification task.
#[derive(Debug, Clone)]
pub struct GaussianTask {
    pub dim: usize,
    pub classes: usize,
    pub sigma: f32,
    /// `classes x dim` mean matrix (deterministic from the task seed).
    means: Vec<f32>,
}

impl GaussianTask {
    pub fn new(dim: usize, classes: usize, sigma: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xDA7A);
        // Random unit-norm means scaled so classes overlap at sigma~1.
        let mut means = vec![0.0f32; classes * dim];
        for c in 0..classes {
            let row = &mut means[c * dim..(c + 1) * dim];
            let mut norm = 0.0f64;
            for v in row.iter_mut() {
                *v = rng.gaussian() as f32;
                norm += (*v as f64) * (*v as f64);
            }
            let scale = (2.5 / norm.sqrt()) as f32;
            for v in row.iter_mut() {
                *v *= scale;
            }
        }
        Self {
            dim,
            classes,
            sigma,
            means,
        }
    }

    /// The standard MNIST-like task (784-d, 10 classes) for the `mlp`
    /// artifact.
    pub fn mnist_like(seed: u64) -> Self {
        Self::new(784, 10, 1.0, seed)
    }

    /// The CIFAR-like task (16x16x3 = 768-d, 10 classes) for the `cnn`
    /// artifact. Class means are *smooth* low-frequency images (a coarse
    /// random grid bilinearly upsampled), so convolution + pooling can
    /// actually extract them — a Gaussian mean with no spatial structure
    /// would defeat a conv net by construction.
    pub fn cifar_like(seed: u64) -> Self {
        Self::new_smooth_image(16, 3, 10, 1.0, seed)
    }

    /// Class-conditional Gaussians whose means are smooth `hw x hw x ch`
    /// images: a `coarse x coarse` random grid per channel, bilinearly
    /// upsampled, then normalized to a fixed energy.
    pub fn new_smooth_image(hw: usize, ch: usize, classes: usize, sigma: f32, seed: u64) -> Self {
        let dim = hw * hw * ch;
        let coarse = 4usize;
        let mut rng = Rng::new(seed ^ 0xC1FA);
        let mut means = vec![0.0f32; classes * dim];
        for c in 0..classes {
            for k in 0..ch {
                // coarse random field
                let grid: Vec<f32> = (0..coarse * coarse)
                    .map(|_| rng.gaussian() as f32)
                    .collect();
                // bilinear upsample onto hw x hw (NHWC layout)
                for y in 0..hw {
                    for x in 0..hw {
                        let fy = y as f32 / (hw - 1) as f32 * (coarse - 1) as f32;
                        let fx = x as f32 / (hw - 1) as f32 * (coarse - 1) as f32;
                        let (y0, x0) = (fy as usize, fx as usize);
                        let (y1, x1) = ((y0 + 1).min(coarse - 1), (x0 + 1).min(coarse - 1));
                        let (dy, dx) = (fy - y0 as f32, fx - x0 as f32);
                        let v = grid[y0 * coarse + x0] * (1.0 - dy) * (1.0 - dx)
                            + grid[y0 * coarse + x1] * (1.0 - dy) * dx
                            + grid[y1 * coarse + x0] * dy * (1.0 - dx)
                            + grid[y1 * coarse + x1] * dy * dx;
                        means[c * dim + (y * hw + x) * ch + k] = v;
                    }
                }
            }
            // normalize class mean energy like the plain constructor
            let row = &mut means[c * dim..(c + 1) * dim];
            let norm: f64 = row.iter().map(|v| (*v as f64) * (*v as f64)).sum();
            let scale = (7.0 / norm.sqrt()) as f32;
            for v in row.iter_mut() {
                *v *= scale;
            }
        }
        Self {
            dim,
            classes,
            sigma,
            means,
        }
    }

    /// Sample one data point of class `label` into `out`.
    pub fn sample_into(&self, label: usize, rng: &mut Rng, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dim);
        let mean = &self.means[label * self.dim..(label + 1) * self.dim];
        for (o, &m) in out.iter_mut().zip(mean) {
            *o = m + self.sigma * rng.gaussian() as f32;
        }
    }

    /// Draw a batch with labels sampled from `label_weights` (unnormalized;
    /// this is how non-iid client shards are expressed).
    pub fn batch(&self, batch: usize, label_weights: &[f64], rng: &mut Rng) -> Batch {
        assert_eq!(label_weights.len(), self.classes);
        let mut x = vec![0.0f32; batch * self.dim];
        let mut y = Vec::with_capacity(batch);
        for b in 0..batch {
            let label = rng.weighted_index(label_weights);
            y.push(label as i32);
            self.sample_into(label, rng, &mut x[b * self.dim..(b + 1) * self.dim]);
        }
        Batch {
            x,
            y,
            batch,
            dim: self.dim,
        }
    }

    /// An iid test batch (uniform labels) from a dedicated stream.
    pub fn test_batch(&self, batch: usize, seed: u64) -> Batch {
        let mut rng = Rng::new(seed ^ 0x7E57);
        let w = vec![1.0; self.classes];
        self.batch(batch, &w, &mut rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let t = GaussianTask::new(16, 4, 1.0, 9);
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        let w = vec![1.0; 4];
        let a = t.batch(8, &w, &mut r1);
        let b = t.batch(8, &w, &mut r2);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn labels_respect_weights() {
        let t = GaussianTask::new(8, 4, 1.0, 9);
        let mut rng = Rng::new(2);
        let w = vec![1.0, 0.0, 0.0, 1.0];
        let b = t.batch(200, &w, &mut rng);
        assert!(b.y.iter().all(|&y| y == 0 || y == 3));
        assert!(b.y.iter().any(|&y| y == 0) && b.y.iter().any(|&y| y == 3));
    }

    #[test]
    fn classes_are_separable() {
        // nearest-mean classification on fresh samples should beat chance
        // by a wide margin (validates the task is learnable)
        let t = GaussianTask::new(32, 5, 1.0, 3);
        let mut rng = Rng::new(4);
        let mut correct = 0;
        let n = 500;
        let mut buf = vec![0.0f32; 32];
        for i in 0..n {
            let label = i % 5;
            t.sample_into(label, &mut rng, &mut buf);
            let mut best = 0;
            let mut bd = f64::INFINITY;
            for c in 0..5 {
                let mean = &t.means[c * 32..(c + 1) * 32];
                let d: f64 = buf
                    .iter()
                    .zip(mean)
                    .map(|(a, m)| ((a - m) as f64).powi(2))
                    .sum();
                if d < bd {
                    bd = d;
                    best = c;
                }
            }
            if best == label {
                correct += 1;
            }
        }
        let acc = correct as f64 / n as f64;
        assert!(acc > 0.8, "nearest-mean accuracy {acc}");
    }

    #[test]
    fn batch_shapes() {
        let t = GaussianTask::mnist_like(1);
        let b = t.test_batch(32, 5);
        assert_eq!(b.x.len(), 32 * 784);
        assert_eq!(b.y.len(), 32);
        assert!(b.y.iter().all(|&y| (0..10).contains(&y)));
    }
}
