//! Kullback–Leibler divergence of a label histogram against the assumed
//! iid (uniform) distribution — feeds the MEP data confidence `c_d`
//! (paper §III-C2, refs [16], [42], [28]).

/// `KL(D_loc || uniform)` from raw label counts. Empty classes contribute
/// zero (the 0·log 0 limit). Returns 0 for an empty histogram.
pub fn kl_divergence_vs_uniform(counts: &[u64]) -> f64 {
    let total: u64 = counts.iter().sum();
    let k = counts.len();
    if total == 0 || k == 0 {
        return 0.0;
    }
    let q = 1.0 / k as f64;
    counts
        .iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / total as f64;
            p * (p / q).ln()
        })
        .sum()
}

/// General discrete KL(P||Q) with the usual conventions; `f64::INFINITY`
/// when P has mass where Q does not.
pub fn kl_divergence(p: &[f64], q: &[f64]) -> f64 {
    assert_eq!(p.len(), q.len());
    let mut s = 0.0;
    for (&pi, &qi) in p.iter().zip(q) {
        if pi == 0.0 {
            continue;
        }
        if qi == 0.0 {
            return f64::INFINITY;
        }
        s += pi * (pi / qi).ln();
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_is_zero() {
        assert!(kl_divergence_vs_uniform(&[5, 5, 5, 5]).abs() < 1e-12);
    }

    #[test]
    fn point_mass_is_log_k() {
        let kl = kl_divergence_vs_uniform(&[100, 0, 0, 0]);
        assert!((kl - 4.0f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn more_shards_less_divergence() {
        // the paper's non-iid knob: fewer shards -> larger KL
        let one = kl_divergence_vs_uniform(&[90, 0, 0, 0, 0, 0, 0, 0, 0, 0]);
        let four = kl_divergence_vs_uniform(&[30, 30, 30, 30, 0, 0, 0, 0, 0, 0]);
        let all = kl_divergence_vs_uniform(&[12; 10]);
        assert!(one > four && four > all);
    }

    #[test]
    fn general_kl_infinite_when_unsupported() {
        assert!(kl_divergence(&[0.5, 0.5], &[1.0, 0.0]).is_infinite());
        assert!(kl_divergence(&[1.0, 0.0], &[0.5, 0.5]).is_finite());
    }

    #[test]
    fn empty_histogram_is_zero() {
        assert_eq!(kl_divergence_vs_uniform(&[]), 0.0);
        assert_eq!(kl_divergence_vs_uniform(&[0, 0]), 0.0);
    }
}
