//! Synthetic data substrate: class-conditional Gaussian tasks, the Markov
//! character stream, non-iid sharding, and KL-divergence utilities.

pub mod kl;
pub mod shard;
pub mod stream;
pub mod synth;

pub use kl::{kl_divergence, kl_divergence_vs_uniform};
pub use shard::{expected_histogram, locality_groups, shard_labels};
pub use stream::{CharStream, VOCAB};
pub use synth::{Batch, GaussianTask};
