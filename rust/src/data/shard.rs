//! Non-iid data sharding (paper §IV-A2).
//!
//! * `shard_labels` — the paper's sharding method: each shard carries one
//!   label; each client holds `shards_per_client` shards, so fewer shards
//!   ⇒ stronger non-iid skew (Fig. 11's 4/8/12-shard sweep).
//! * `locality_groups` — the biased-locality design of Fig. 13/14: clients
//!   are split into 10 groups; group `g` holds labels `g..g+6 (mod 10)`.

use crate::util::Rng;

/// Per-client label weights from the sharding method. Returns a
/// `clients x classes` weight matrix (rows unnormalized; zero weight means
/// the client never sees that label).
pub fn shard_labels(
    clients: usize,
    classes: usize,
    shards_per_client: usize,
    seed: u64,
) -> Vec<Vec<f64>> {
    let mut rng = Rng::new(seed ^ 0x54A2D);
    let mut out = Vec::with_capacity(clients);
    for _ in 0..clients {
        let mut w = vec![0.0f64; classes];
        if shards_per_client >= classes {
            // enough shards to cover all labels: iid-ish but still integer
            // shard counts per label
            let per = shards_per_client / classes;
            let extra = shards_per_client % classes;
            for (c, wc) in w.iter_mut().enumerate() {
                *wc = per as f64 + if c < extra { 1.0 } else { 0.0 };
            }
        } else {
            // pick distinct labels for this client's shards
            let labels = rng.sample_indices(classes, shards_per_client);
            for l in labels {
                w[l] += 1.0;
            }
        }
        out.push(w);
    }
    out
}

/// Fig. 13/14 locality layout: `groups` groups; group `g` holds
/// `labels_per_group` consecutive labels starting at `g` (mod classes).
/// Each group differs from its ring-neighbor group by exactly one label.
pub fn locality_groups(
    clients: usize,
    classes: usize,
    groups: usize,
    labels_per_group: usize,
) -> Vec<Vec<f64>> {
    assert!(groups > 0 && labels_per_group <= classes);
    let mut out = Vec::with_capacity(clients);
    for i in 0..clients {
        let g = i * groups / clients; // even split into groups
        let mut w = vec![0.0f64; classes];
        for k in 0..labels_per_group {
            w[(g + k) % classes] = 1.0;
        }
        out.push(w);
    }
    out
}

/// Label histogram (expected counts) from weights — used for the KL-based
/// confidence, mirroring what a real client computes over its local data.
pub fn expected_histogram(weights: &[f64], samples: u64) -> Vec<u64> {
    let total: f64 = weights.iter().sum();
    if total == 0.0 {
        return vec![0; weights.len()];
    }
    weights
        .iter()
        .map(|w| ((w / total) * samples as f64).round() as u64)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_counts_respected() {
        let w = shard_labels(50, 10, 4, 1);
        assert_eq!(w.len(), 50);
        for row in &w {
            let nz = row.iter().filter(|&&x| x > 0.0).count();
            assert_eq!(nz, 4);
            assert_eq!(row.iter().sum::<f64>(), 4.0);
        }
    }

    #[test]
    fn many_shards_cover_all_labels() {
        let w = shard_labels(10, 10, 12, 2);
        for row in &w {
            assert!(row.iter().all(|&x| x > 0.0));
            assert_eq!(row.iter().sum::<f64>() as usize, 12);
        }
    }

    #[test]
    fn fewer_shards_more_skew() {
        use crate::data::kl::kl_divergence_vs_uniform;
        let avg_kl = |shards: usize| -> f64 {
            let w = shard_labels(40, 10, shards, 3);
            w.iter()
                .map(|row| {
                    let h = expected_histogram(row, 1000);
                    kl_divergence_vs_uniform(&h)
                })
                .sum::<f64>()
                / 40.0
        };
        let k4 = avg_kl(4);
        let k8 = avg_kl(8);
        let k12 = avg_kl(12);
        assert!(k4 > k8 && k8 > k12, "{k4} {k8} {k12}");
    }

    #[test]
    fn locality_matches_paper_layout() {
        // 100 clients, 10 groups, 6 of 10 labels each (paper §IV-C)
        let w = locality_groups(100, 10, 10, 6);
        // group 0 = clients 0..10 -> labels 0..6
        assert_eq!(w[0].iter().filter(|&&x| x > 0.0).count(), 6);
        assert!(w[0][0] > 0.0 && w[0][5] > 0.0 && w[0][6] == 0.0);
        // last group wraps (labels 9,0,1,2,3,4)
        let last = &w[99];
        assert!(last[9] > 0.0 && last[0] > 0.0 && last[4] > 0.0 && last[5] == 0.0);
        // neighboring groups differ by exactly 2 labels (one in, one out)
        let diff: usize = (0..10)
            .filter(|&c| (w[0][c] > 0.0) != (w[10][c] > 0.0))
            .count();
        assert_eq!(diff, 2);
    }

    #[test]
    fn histogram_matches_weights() {
        let h = expected_histogram(&[1.0, 1.0, 2.0], 400);
        assert_eq!(h, vec![100, 100, 200]);
    }
}
