//! Markov character stream — the Shakespeare-dataset stand-in for the
//! LSTM next-character task (DESIGN.md §Substitutions).
//!
//! A first-order Markov chain over a 32-symbol alphabet with a seeded,
//! sparse transition matrix produces sequences with learnable structure
//! (an LSTM beats the unigram baseline). Per-client non-iid-ness follows
//! the paper's "each speaking role is a shard" by giving every client its
//! own chain *mixture* of a few global "roles".

use crate::util::Rng;

pub const VOCAB: usize = 32;

/// One global "role": a sparse Markov transition table.
#[derive(Debug, Clone)]
pub struct Role {
    /// `VOCAB x VOCAB` transition weights.
    trans: Vec<f64>,
}

impl Role {
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x5EA4);
        let mut trans = vec![0.0f64; VOCAB * VOCAB];
        for r in 0..VOCAB {
            // each symbol transitions to ~4 preferred successors
            for _ in 0..4 {
                let c = rng.index(VOCAB);
                trans[r * VOCAB + c] += 1.0 + rng.next_f64() * 3.0;
            }
            // smoothing so every transition is possible
            for c in 0..VOCAB {
                trans[r * VOCAB + c] += 0.05;
            }
        }
        Self { trans }
    }

    fn row(&self, sym: usize) -> &[f64] {
        &self.trans[sym * VOCAB..(sym + 1) * VOCAB]
    }
}

/// A client's character stream: a mixture of roles (usually 1).
#[derive(Debug, Clone)]
pub struct CharStream {
    roles: Vec<Role>,
    state: usize,
    rng: Rng,
}

impl CharStream {
    pub fn new(role_seeds: &[u64], client_seed: u64) -> Self {
        assert!(!role_seeds.is_empty());
        Self {
            roles: role_seeds.iter().map(|&s| Role::new(s)).collect(),
            state: 0,
            rng: Rng::new(client_seed ^ 0xC4A2),
        }
    }

    pub fn next_symbol(&mut self) -> usize {
        let role = if self.roles.len() == 1 {
            &self.roles[0]
        } else {
            &self.roles[self.rng.index(self.roles.len())]
        };
        let next = self.rng.weighted_index(role.row(self.state));
        self.state = next;
        next
    }

    /// An LSTM batch: `x[B, T]` int32 windows and `y[B]` the next symbol.
    pub fn batch(&mut self, batch: usize, seq: usize) -> (Vec<i32>, Vec<i32>) {
        let mut x = Vec::with_capacity(batch * seq);
        let mut y = Vec::with_capacity(batch);
        for _ in 0..batch {
            for _ in 0..seq {
                x.push(self.next_symbol() as i32);
            }
            y.push(self.next_symbol() as i32);
        }
        (x, y)
    }

    /// Symbol histogram over a horizon (for KL confidence).
    pub fn histogram(&mut self, n: usize) -> Vec<u64> {
        let mut h = vec![0u64; VOCAB];
        for _ in 0..n {
            h[self.next_symbol()] += 1;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_is_deterministic() {
        let mut a = CharStream::new(&[1], 7);
        let mut b = CharStream::new(&[1], 7);
        let (xa, ya) = a.batch(4, 16);
        let (xb, yb) = b.batch(4, 16);
        assert_eq!(xa, xb);
        assert_eq!(ya, yb);
    }

    #[test]
    fn symbols_in_vocab() {
        let mut s = CharStream::new(&[2], 3);
        let (x, y) = s.batch(8, 32);
        assert_eq!(x.len(), 8 * 32);
        assert!(x.iter().all(|&c| (0..VOCAB as i32).contains(&c)));
        assert!(y.iter().all(|&c| (0..VOCAB as i32).contains(&c)));
    }

    #[test]
    fn chain_has_structure() {
        // bigram predictability: the most likely successor of each symbol
        // should appear far above chance
        let mut s = CharStream::new(&[4], 5);
        let mut bigrams = vec![0u64; VOCAB * VOCAB];
        let mut prev = s.next_symbol();
        for _ in 0..200_000 {
            let cur = s.next_symbol();
            bigrams[prev * VOCAB + cur] += 1;
            prev = cur;
        }
        // average max-row probability
        let mut acc = 0.0;
        let mut rows = 0;
        for r in 0..VOCAB {
            let row = &bigrams[r * VOCAB..(r + 1) * VOCAB];
            let tot: u64 = row.iter().sum();
            if tot > 100 {
                acc += *row.iter().max().unwrap() as f64 / tot as f64;
                rows += 1;
            }
        }
        let avg_max = acc / rows as f64;
        assert!(avg_max > 0.15, "chain too uniform: {avg_max}");
    }

    #[test]
    fn different_roles_have_different_stats() {
        let mut a = CharStream::new(&[10], 1);
        let mut b = CharStream::new(&[11], 1);
        let ha = a.histogram(50_000);
        let hb = b.histogram(50_000);
        let kl = crate::data::kl::kl_divergence(
            &ha.iter().map(|&c| c as f64 / 50_000.0).collect::<Vec<_>>(),
            &hb.iter().map(|&c| (c.max(1)) as f64 / 50_000.0).collect::<Vec<_>>(),
        );
        assert!(kl > 0.01, "roles indistinguishable, KL={kl}");
    }
}
