//! PJRT runtime: loads `artifacts/*.hlo.txt` (AOT-lowered by
//! `python/compile/aot.py`) and executes them from the Rust hot path.

pub mod artifacts;
pub mod pjrt;

pub use artifacts::{find_artifacts_dir, Manifest, TaskInfo};
pub use pjrt::{Engine, XInput};
