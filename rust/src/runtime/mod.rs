//! Model-execution runtime behind a single `Engine` API.
//!
//! Two interchangeable backends:
//! * `pjrt` (feature `xla`) — loads `artifacts/*.hlo.txt` (AOT-lowered by
//!   `python/compile/aot.py`) and executes them on the PJRT CPU client.
//!   Requires the vendored `xla` crate.
//! * `reference` (default) — a pure-Rust engine with the exact same API
//!   and artifact ABI (flat params, `[K_MAX, P]` aggregation stacks),
//!   backed by softmax-linear models. It needs no artifacts on disk, so
//!   the full DFL pipeline (trainer, benches, integration tests) runs in
//!   a bare container.

pub mod artifacts;
#[cfg(feature = "xla")]
pub mod pjrt;
pub mod reference;

pub use artifacts::{find_artifacts_dir, Manifest, TaskInfo};
#[cfg(feature = "xla")]
pub use pjrt::Engine;
#[cfg(not(feature = "xla"))]
pub use reference::Engine;

/// Model input batch: f32 features or i32 token windows.
pub enum XInput<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}
