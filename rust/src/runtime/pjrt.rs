//! PJRT execution engine: loads the AOT HLO-text artifacts and runs them
//! on the CPU PJRT client from the Rust hot path (no Python at runtime).
//!
//! Wire format notes (see /opt/xla-example/README.md):
//! * artifacts are HLO **text**; `HloModuleProto::from_text_file`
//!   reassigns instruction ids, avoiding the 64-bit-id proto rejection;
//! * every entry computation returns a tuple (`return_tuple=True` at
//!   lowering), so results are unwrapped with `to_tupleN`.

use super::artifacts::{Manifest, TaskInfo};
use super::XInput;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// One task's compiled executables.
pub struct TaskExecutables {
    pub info: TaskInfo,
    init: xla::PjRtLoadedExecutable,
    train: xla::PjRtLoadedExecutable,
    eval: xla::PjRtLoadedExecutable,
    agg: xla::PjRtLoadedExecutable,
}

/// The runtime engine: one PJRT client + compiled executables per task.
pub struct Engine {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    tasks: HashMap<String, TaskExecutables>,
    /// Execution counters for telemetry / benches (atomic so the trainer's
    /// parallel evaluation compiles against either backend).
    pub exec_count: std::sync::atomic::AtomicU64,
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().context("non-utf8 artifact path")?,
    )
    .with_context(|| format!("parsing HLO text {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {}", path.display()))
}

impl Engine {
    /// Load and compile the artifacts of `task_names` (compiling all tasks
    /// costs startup time; benches load only what they use).
    pub fn load(artifacts_dir: &Path, task_names: &[&str]) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut tasks = HashMap::new();
        for &name in task_names {
            let info = manifest.task(name)?.clone();
            let load = |kind: &str| -> Result<xla::PjRtLoadedExecutable> {
                compile(&client, &manifest.hlo_path(name, kind)?)
            };
            tasks.insert(
                name.to_string(),
                TaskExecutables {
                    init: load("init")?,
                    train: load("train")?,
                    eval: load("eval")?,
                    agg: load("agg")?,
                    info,
                },
            );
        }
        Ok(Engine {
            client,
            manifest,
            tasks,
            exec_count: std::sync::atomic::AtomicU64::new(0),
        })
    }

    pub fn task(&self, name: &str) -> Result<&TaskExecutables> {
        self.tasks
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("task {name:?} not loaded"))
    }

    fn bump(&self) {
        self.exec_count
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    /// Initialize a flat parameter vector from a 2-word seed.
    pub fn init(&self, task: &str, seed: [u32; 2]) -> Result<Vec<f32>> {
        let t = self.task(task)?;
        let seed_lit = xla::Literal::vec1(&seed);
        self.bump();
        let result = t.init.execute::<xla::Literal>(&[seed_lit])?[0][0]
            .to_literal_sync()?
            .to_tuple1()?;
        Ok(result.to_vec::<f32>()?)
    }

    /// One local SGD step: returns (new_params, loss).
    pub fn train_step(
        &self,
        task: &str,
        params: &[f32],
        x: &XInput,
        y: &[i32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let t = self.task(task)?;
        let b = t.info.batch as i64;
        let d = t.info.x_len as i64;
        anyhow::ensure!(params.len() == t.info.param_count, "param length mismatch");
        anyhow::ensure!(y.len() == t.info.batch, "label batch mismatch");
        let p_lit = xla::Literal::vec1(params);
        let x_lit = x.to_literal(b, d)?;
        let y_lit = xla::Literal::vec1(y);
        let lr_lit = xla::Literal::scalar(lr);
        self.bump();
        let out = t.train.execute::<xla::Literal>(&[p_lit, x_lit, y_lit, lr_lit])?[0][0]
            .to_literal_sync()?;
        let (new_params, loss) = out.to_tuple2()?;
        Ok((
            new_params.to_vec::<f32>()?,
            loss.to_vec::<f32>()?.first().copied().unwrap_or(f32::NAN),
        ))
    }

    /// Evaluate a batch: returns (correct_count, loss).
    pub fn eval_step(
        &self,
        task: &str,
        params: &[f32],
        x: &XInput,
        y: &[i32],
    ) -> Result<(f32, f32)> {
        let t = self.task(task)?;
        let b = t.info.batch as i64;
        let d = t.info.x_len as i64;
        let p_lit = xla::Literal::vec1(params);
        let x_lit = x.to_literal(b, d)?;
        let y_lit = xla::Literal::vec1(y);
        self.bump();
        let out = t.eval.execute::<xla::Literal>(&[p_lit, x_lit, y_lit])?[0][0]
            .to_literal_sync()?;
        let (correct, loss) = out.to_tuple2()?;
        Ok((
            correct.to_vec::<f32>()?.first().copied().unwrap_or(0.0),
            loss.to_vec::<f32>()?.first().copied().unwrap_or(f32::NAN),
        ))
    }

    /// Confidence-weighted aggregation via the L1 Pallas kernel artifact.
    /// `stack` is `[K_MAX * P]` row-major, `weights` is `[K_MAX]` — use
    /// `mep::pack_for_artifact` to build them.
    pub fn aggregate(&self, task: &str, stack: &[f32], weights: &[f32]) -> Result<Vec<f32>> {
        let t = self.task(task)?;
        let k = self.manifest.k_max as i64;
        let p = t.info.param_count as i64;
        anyhow::ensure!(stack.len() as i64 == k * p, "stack shape mismatch");
        anyhow::ensure!(weights.len() as i64 == k, "weights shape mismatch");
        let s_lit = xla::Literal::vec1(stack).reshape(&[k, p])?;
        let w_lit = xla::Literal::vec1(weights);
        self.bump();
        let out = t.agg.execute::<xla::Literal>(&[s_lit, w_lit])?[0][0]
            .to_literal_sync()?
            .to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

impl XInput<'_> {
    fn to_literal(&self, b: i64, d: i64) -> Result<xla::Literal> {
        let lit = match self {
            XInput::F32(x) => {
                anyhow::ensure!(x.len() as i64 == b * d, "x shape mismatch");
                xla::Literal::vec1(*x).reshape(&[b, d])?
            }
            XInput::I32(x) => {
                anyhow::ensure!(x.len() as i64 == b * d, "x shape mismatch");
                xla::Literal::vec1(*x).reshape(&[b, d])?
            }
        };
        Ok(lit)
    }
}
