//! Pure-Rust reference engine: the default `Engine` backend when the
//! `xla` feature (vendored PJRT) is absent.
//!
//! It mirrors `runtime::pjrt::Engine`'s API and artifact ABI exactly —
//! flat f32 parameter vectors, `[K_MAX, P]` row-major aggregation stacks
//! with zero-weighted padding rows, shape-validated inputs — so the
//! trainer, the TCP prototype, and every bench run unmodified against
//! either backend. Models are softmax-linear classifiers:
//!
//! * f32 tasks (`mlp`, `cnn`): logits = Wᵀ(x/√d) + b over the raw
//!   features (scaled to unit-ish norm so the paper's learning rates are
//!   stable);
//! * the i32 task (`lstm`): logits = Wᵀ·onehot(last token) + b — the
//!   sufficient statistic of the first-order Markov stream, so the model
//!   genuinely learns the next-character task.
//!
//! The manifest is synthesized in memory; no artifacts directory is
//! needed. The engine is `Send + Sync` (unlike the PJRT client), which
//! the trainer exploits to evaluate distinct models in parallel.

use super::artifacts::{Manifest, TaskInfo};
use super::XInput;
use crate::data::VOCAB;
use crate::util::Rng;
use anyhow::Result;
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

/// One task's "executables" (just the static task description here).
pub struct TaskExecutables {
    pub info: TaskInfo,
}

/// The reference engine: a synthesized manifest plus per-task models.
pub struct Engine {
    pub manifest: Manifest,
    tasks: HashMap<String, TaskExecutables>,
    /// Execution counters for telemetry / benches.
    pub exec_count: AtomicU64,
}

/// Aggregation stack height shared with the artifact ABI.
const K_MAX: usize = 16;

fn builtin_tasks() -> Vec<TaskInfo> {
    let linear = |d: usize, c: usize| d * c + c;
    vec![
        TaskInfo {
            name: "mlp".into(),
            param_count: linear(784, 10),
            batch: 32,
            x_len: 784,
            x_dtype: "f32".into(),
            classes: 10,
        },
        TaskInfo {
            name: "cnn".into(),
            param_count: linear(768, 10),
            batch: 32,
            x_len: 768,
            x_dtype: "f32".into(),
            classes: 10,
        },
        TaskInfo {
            name: "lstm".into(),
            param_count: linear(VOCAB, VOCAB),
            batch: 32,
            x_len: 16,
            x_dtype: "i32".into(),
            classes: VOCAB,
        },
    ]
}

/// Densify the model input into `[batch, d]` features. f32 features are
/// scaled by 1/√d (unit-ish row norm); i32 windows become a one-hot of
/// the last token.
fn feature_rows(info: &TaskInfo, x: &XInput) -> Result<(usize, Vec<f32>)> {
    match x {
        XInput::F32(v) => {
            anyhow::ensure!(
                v.len() == info.batch * info.x_len,
                "x shape mismatch: {} != {}x{}",
                v.len(),
                info.batch,
                info.x_len
            );
            let d = info.x_len;
            let scale = 1.0 / (d as f32).sqrt();
            Ok((d, v.iter().map(|&f| f * scale).collect()))
        }
        XInput::I32(v) => {
            anyhow::ensure!(
                v.len() == info.batch * info.x_len,
                "x shape mismatch: {} != {}x{}",
                v.len(),
                info.batch,
                info.x_len
            );
            let d = VOCAB;
            let mut out = vec![0.0f32; info.batch * d];
            for b in 0..info.batch {
                let last = v[(b + 1) * info.x_len - 1];
                anyhow::ensure!(
                    (0..d as i32).contains(&last),
                    "token {last} outside vocab {d}"
                );
                out[b * d + last as usize] = 1.0;
            }
            Ok((d, out))
        }
    }
}

/// logits[b*c + k] for the flat `[W (d x c), bias (c)]` parameter layout.
fn forward(params: &[f32], d: usize, c: usize, feats: &[f32], batch: usize) -> Vec<f32> {
    let (w, bias) = params.split_at(d * c);
    let mut logits = vec![0.0f32; batch * c];
    for b in 0..batch {
        let row = &feats[b * d..(b + 1) * d];
        let out = &mut logits[b * c..(b + 1) * c];
        out.copy_from_slice(bias);
        for (j, &f) in row.iter().enumerate() {
            if f == 0.0 {
                continue;
            }
            let wrow = &w[j * c..(j + 1) * c];
            for (o, &wv) in out.iter_mut().zip(wrow) {
                *o += f * wv;
            }
        }
    }
    logits
}

/// Per-example softmax cross-entropy loss and probabilities.
fn softmax_ce(logits: &[f32], c: usize, y: i32) -> (f64, Vec<f64>) {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let exps: Vec<f64> = logits.iter().map(|&l| ((l as f64) - m).exp()).collect();
    let z: f64 = exps.iter().sum();
    let probs: Vec<f64> = exps.iter().map(|e| e / z).collect();
    let loss = m + z.ln() - logits[y as usize] as f64;
    let _ = c;
    (loss, probs)
}

impl Engine {
    /// Load `task_names` from the built-in registry. The artifacts
    /// directory is ignored: the reference engine is fully synthetic.
    pub fn load(_artifacts_dir: &Path, task_names: &[&str]) -> Result<Engine> {
        let all = builtin_tasks();
        let manifest = Manifest::synthetic(all.clone(), K_MAX);
        let mut tasks = HashMap::new();
        for &name in task_names {
            let info = all
                .iter()
                .find(|t| t.name == name)
                .ok_or_else(|| anyhow::anyhow!("task {name:?} not in reference registry"))?
                .clone();
            tasks.insert(name.to_string(), TaskExecutables { info });
        }
        Ok(Engine {
            manifest,
            tasks,
            exec_count: AtomicU64::new(0),
        })
    }

    pub fn task(&self, name: &str) -> Result<&TaskExecutables> {
        self.tasks
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("task {name:?} not loaded"))
    }

    fn bump(&self) {
        self.exec_count.fetch_add(1, Ordering::Relaxed);
    }

    /// Initialize a flat parameter vector from a 2-word seed.
    pub fn init(&self, task: &str, seed: [u32; 2]) -> Result<Vec<f32>> {
        let info = &self.task(task)?.info;
        self.bump();
        let mut rng = Rng::new(((seed[0] as u64) << 32) | seed[1] as u64 ^ 0x1217);
        Ok((0..info.param_count)
            .map(|_| (rng.next_f32() - 0.5) * 0.02)
            .collect())
    }

    /// One SGD step on the batch: returns (new_params, mean loss).
    pub fn train_step(
        &self,
        task: &str,
        params: &[f32],
        x: &XInput,
        y: &[i32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let info = &self.task(task)?.info;
        anyhow::ensure!(params.len() == info.param_count, "param length mismatch");
        anyhow::ensure!(y.len() == info.batch, "label batch mismatch");
        let (d, feats) = feature_rows(info, x)?;
        let c = info.classes;
        anyhow::ensure!(d * c + c == params.len(), "feature/param shape mismatch");
        self.bump();
        let logits = forward(params, d, c, &feats, info.batch);
        let mut grad = vec![0.0f64; params.len()];
        let mut loss_sum = 0.0f64;
        for b in 0..info.batch {
            let yb = y[b];
            anyhow::ensure!((0..c as i32).contains(&yb), "label {yb} out of range");
            let (loss, mut probs) = softmax_ce(&logits[b * c..(b + 1) * c], c, yb);
            loss_sum += loss;
            probs[yb as usize] -= 1.0;
            let row = &feats[b * d..(b + 1) * d];
            for (j, &f) in row.iter().enumerate() {
                if f == 0.0 {
                    continue;
                }
                let g = &mut grad[j * c..(j + 1) * c];
                for (gv, &p) in g.iter_mut().zip(&probs) {
                    *gv += f as f64 * p;
                }
            }
            let gb = &mut grad[d * c..];
            for (gv, &p) in gb.iter_mut().zip(&probs) {
                *gv += p;
            }
        }
        let new: Vec<f32> = params
            .iter()
            .zip(&grad)
            .map(|(&p, &g)| p - lr * g as f32)
            .collect();
        Ok((new, (loss_sum / info.batch as f64) as f32))
    }

    /// Evaluate a batch: returns (correct_count, mean loss).
    pub fn eval_step(
        &self,
        task: &str,
        params: &[f32],
        x: &XInput,
        y: &[i32],
    ) -> Result<(f32, f32)> {
        let info = &self.task(task)?.info;
        anyhow::ensure!(params.len() == info.param_count, "param length mismatch");
        anyhow::ensure!(y.len() == info.batch, "label batch mismatch");
        let (d, feats) = feature_rows(info, x)?;
        let c = info.classes;
        anyhow::ensure!(d * c + c == params.len(), "feature/param shape mismatch");
        self.bump();
        let logits = forward(params, d, c, &feats, info.batch);
        let mut correct = 0.0f32;
        let mut loss_sum = 0.0f64;
        for b in 0..info.batch {
            let row = &logits[b * c..(b + 1) * c];
            let mut best = 0usize;
            for k in 1..c {
                if row[k] > row[best] {
                    best = k;
                }
            }
            if best as i32 == y[b] {
                correct += 1.0;
            }
            let (loss, _) = softmax_ce(row, c, y[b]);
            loss_sum += loss;
        }
        Ok((correct, (loss_sum / info.batch as f64) as f32))
    }

    /// Confidence-weighted aggregation over a `[K_MAX, P]` stack with
    /// zero-weighted padding rows — bit-for-bit the `aggregate_cpu`
    /// semantics, so the two implementations stay pinned together.
    pub fn aggregate(&self, task: &str, stack: &[f32], weights: &[f32]) -> Result<Vec<f32>> {
        let _ = self.task(task)?;
        let k = self.manifest.k_max;
        anyhow::ensure!(weights.len() == k, "weights shape mismatch");
        anyhow::ensure!(
            !stack.is_empty() && stack.len() % k == 0,
            "stack shape mismatch"
        );
        let p = stack.len() / k;
        self.bump();
        let denom: f64 = weights.iter().map(|&w| w as f64).sum::<f64>().max(1e-12);
        let mut out = vec![0.0f64; p];
        for (i, &w) in weights.iter().enumerate() {
            if w == 0.0 {
                continue;
            }
            let row = &stack[i * p..(i + 1) * p];
            for (o, &x) in out.iter_mut().zip(row) {
                *o += w as f64 * x as f64;
            }
        }
        Ok(out.into_iter().map(|x| (x / denom) as f32).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::GaussianTask;
    use crate::mep::{aggregate_cpu, pack_for_artifact};

    fn engine(tasks: &[&str]) -> Engine {
        Engine::load(Path::new(""), tasks).unwrap()
    }

    #[test]
    fn registry_and_manifest_are_consistent() {
        let eng = engine(&["mlp", "cnn", "lstm"]);
        for name in ["mlp", "cnn", "lstm"] {
            let info = eng.manifest.task(name).unwrap();
            assert_eq!(eng.task(name).unwrap().info, *info);
            let d = if info.x_dtype == "i32" { VOCAB } else { info.x_len };
            assert_eq!(info.param_count, d * info.classes + info.classes);
        }
        assert!(eng.task("nope").is_err());
        assert!(Engine::load(Path::new(""), &["nope"]).is_err());
    }

    #[test]
    fn training_learns_the_gaussian_task() {
        let eng = engine(&["mlp"]);
        let info = eng.manifest.task("mlp").unwrap().clone();
        let task = GaussianTask::mnist_like(3);
        let mut params = eng.init("mlp", [1, 2]).unwrap();
        let mut rng = crate::util::Rng::new(11);
        let w = vec![1.0; 10];
        for _ in 0..150 {
            let b = task.batch(info.batch, &w, &mut rng);
            let (new, loss) = eng
                .train_step("mlp", &params, &XInput::F32(&b.x), &b.y, 0.5)
                .unwrap();
            assert!(loss.is_finite());
            params = new;
        }
        let mut correct = 0.0;
        for s in 0..4u64 {
            let t = task.test_batch(info.batch, 99 + s);
            let (cr, _) = eng
                .eval_step("mlp", &params, &XInput::F32(&t.x), &t.y)
                .unwrap();
            correct += cr as f64;
        }
        let acc = correct / (4 * info.batch) as f64;
        assert!(acc > 0.45, "reference model failed to learn: acc {acc}");
    }

    #[test]
    fn lstm_learns_the_markov_chain() {
        let eng = engine(&["lstm"]);
        let info = eng.manifest.task("lstm").unwrap().clone();
        let mut stream = crate::data::CharStream::new(&[5], 1);
        let mut params = eng.init("lstm", [4, 4]).unwrap();
        let mut first_loss = 0.0f32;
        let mut last_loss = 0.0f32;
        for step in 0..80 {
            let (x, y) = stream.batch(info.batch, info.x_len);
            let (new, loss) = eng
                .train_step("lstm", &params, &XInput::I32(&x), &y, 0.5)
                .unwrap();
            params = new;
            if step == 0 {
                first_loss = loss;
            }
            last_loss = loss;
        }
        assert!(
            last_loss < first_loss - 0.3,
            "markov task not learned: {first_loss} -> {last_loss}"
        );
    }

    #[test]
    fn aggregate_matches_cpu_reference() {
        let eng = engine(&["mlp"]);
        let p = eng.manifest.task("mlp").unwrap().param_count;
        let k_max = eng.manifest.k_max;
        let mut rng = crate::util::Rng::new(5);
        let models: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..p).map(|_| rng.gaussian() as f32).collect())
            .collect();
        let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
        let weights = [0.7, 0.2, 0.4];
        let want = aggregate_cpu(&refs, &weights);
        let (stack, w) = pack_for_artifact(&refs, &weights, k_max);
        let got = eng.aggregate("mlp", &stack, &w).unwrap();
        for (g, wv) in got.iter().zip(&want) {
            assert!((g - wv).abs() < 1e-4 * (1.0 + wv.abs()));
        }
    }

    #[test]
    fn shape_validation() {
        let eng = engine(&["mlp"]);
        let info = eng.manifest.task("mlp").unwrap().clone();
        let y = vec![0i32; info.batch];
        let bad_x = vec![0.0f32; 3];
        let params = vec![0.0f32; info.param_count];
        assert!(eng
            .train_step("mlp", &params, &XInput::F32(&bad_x), &y, 0.1)
            .is_err());
        assert!(eng
            .eval_step("mlp", &vec![0.0; 7], &XInput::F32(&bad_x), &y)
            .is_err());
    }
}
