//! AOT artifact manifest: parses `artifacts/manifest.txt` (written by
//! `python/compile/aot.py`) into a typed registry of tasks and HLO files.

use crate::config::toml::Doc;
use std::path::{Path, PathBuf};

/// Static description of one model task (mirrors `model.TaskSpec`).
#[derive(Debug, Clone, PartialEq)]
pub struct TaskInfo {
    pub name: String,
    pub param_count: usize,
    pub batch: usize,
    /// Per-example feature length (f32 dims or int32 sequence length).
    pub x_len: usize,
    /// "f32" or "i32".
    pub x_dtype: String,
    pub classes: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub k_max: usize,
    pub tasks: Vec<TaskInfo>,
    doc: Doc,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.txt");
        let doc = Doc::parse_file(&path)?;
        let k_max = doc
            .int("k_max")
            .ok_or_else(|| anyhow::anyhow!("manifest missing k_max"))? as usize;
        let names: Vec<String> = doc
            .str("tasks")
            .ok_or_else(|| anyhow::anyhow!("manifest missing tasks"))?
            .split(',')
            .map(|s| s.to_string())
            .collect();
        let mut tasks = Vec::new();
        for name in names {
            let get = |k: &str| -> anyhow::Result<i64> {
                doc.int(&format!("task.{name}.{k}"))
                    .ok_or_else(|| anyhow::anyhow!("manifest missing task.{name}.{k}"))
            };
            tasks.push(TaskInfo {
                param_count: get("param_count")? as usize,
                batch: get("batch")? as usize,
                x_len: get("x_len")? as usize,
                x_dtype: doc
                    .str(&format!("task.{name}.x_dtype"))
                    .unwrap_or("f32")
                    .to_string(),
                classes: get("classes")? as usize,
                name,
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            k_max,
            tasks,
            doc,
        })
    }

    /// Build an in-memory manifest. The reference engine (no artifacts on
    /// disk) synthesizes its registry through this; `hlo_path` lookups on
    /// a synthetic manifest fail, which is correct — there are no files.
    pub fn synthetic(tasks: Vec<TaskInfo>, k_max: usize) -> Manifest {
        Manifest {
            dir: PathBuf::new(),
            k_max,
            tasks,
            doc: Doc::default(),
        }
    }

    pub fn task(&self, name: &str) -> anyhow::Result<&TaskInfo> {
        self.tasks
            .iter()
            .find(|t| t.name == name)
            .ok_or_else(|| anyhow::anyhow!("task {name:?} not in manifest"))
    }

    /// Path of the HLO artifact for `task`/`kind` (kind ∈ init/train/eval/agg).
    pub fn hlo_path(&self, task: &str, kind: &str) -> anyhow::Result<PathBuf> {
        let key = format!("artifact.{task}.{kind}");
        let file = self
            .doc
            .str(&key)
            .ok_or_else(|| anyhow::anyhow!("manifest missing {key}"))?;
        Ok(self.dir.join(file))
    }
}

/// Locate the artifacts directory: explicit arg > $FEDLAY_ARTIFACTS >
/// ./artifacts (walking up from cwd for tests running in target/).
pub fn find_artifacts_dir(explicit: Option<&Path>) -> anyhow::Result<PathBuf> {
    if let Some(p) = explicit {
        anyhow::ensure!(p.join("manifest.txt").exists(), "no manifest in {}", p.display());
        return Ok(p.to_path_buf());
    }
    if let Ok(env) = std::env::var("FEDLAY_ARTIFACTS") {
        let p = PathBuf::from(env);
        anyhow::ensure!(p.join("manifest.txt").exists(), "no manifest in {}", p.display());
        return Ok(p);
    }
    let mut cur = std::env::current_dir()?;
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.txt").exists() {
            return Ok(cand);
        }
        if !cur.pop() {
            break;
        }
    }
    if cfg!(feature = "xla") {
        anyhow::bail!("artifacts/manifest.txt not found — run `make artifacts` first");
    }
    // The reference engine synthesizes its manifest in memory, so a
    // missing artifacts tree is not an error without the `xla` feature.
    Ok(PathBuf::new())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_artifacts() -> Option<PathBuf> {
        // filter the reference-mode placeholder path: this test is about
        // real on-disk artifacts only
        find_artifacts_dir(None)
            .ok()
            .filter(|d| d.join("manifest.txt").exists())
    }

    #[test]
    fn manifest_parses_if_built() {
        let Some(dir) = repo_artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.k_max >= 2);
        assert!(!m.tasks.is_empty());
        let mlp = m.task("mlp").unwrap();
        assert_eq!(mlp.x_len, 784);
        assert_eq!(mlp.classes, 10);
        assert!(mlp.param_count > 100_000);
        for kind in ["init", "train", "eval", "agg"] {
            let p = m.hlo_path("mlp", kind).unwrap();
            assert!(p.exists(), "{} missing", p.display());
        }
        assert!(m.task("nope").is_err());
        assert!(m.hlo_path("mlp", "nope").is_err());
    }
}
