//! Fixed-size bitset used by BFS/connectivity over graphs up to ~10^5 nodes.

#[derive(Debug, Clone)]
pub struct BitSet {
    words: Vec<u64>,
    len: usize,
}

impl BitSet {
    pub fn new(len: usize) -> Self {
        Self {
            words: vec![0; len.div_ceil(64)],
            len,
        }
    }

    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i >> 6] |= 1u64 << (i & 63);
    }

    #[inline]
    pub fn clear(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i >> 6] &= !(1u64 << (i & 63));
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i >> 6] >> (i & 63)) & 1 == 1
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    pub fn clear_all(&mut self) {
        self.words.fill(0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_clear() {
        let mut b = BitSet::new(130);
        assert!(!b.get(0) && !b.get(129));
        b.set(0);
        b.set(64);
        b.set(129);
        assert!(b.get(0) && b.get(64) && b.get(129) && !b.get(1));
        assert_eq!(b.count_ones(), 3);
        b.clear(64);
        assert!(!b.get(64));
        assert_eq!(b.count_ones(), 2);
        b.clear_all();
        assert_eq!(b.count_ones(), 0);
    }
}
