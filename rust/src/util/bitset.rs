//! Fixed-size bitset used by BFS/connectivity over graphs up to ~10^5 nodes.

#[derive(Debug, Clone)]
pub struct BitSet {
    words: Vec<u64>,
    len: usize,
}

impl BitSet {
    pub fn new(len: usize) -> Self {
        Self {
            words: vec![0; len.div_ceil(64)],
            len,
        }
    }

    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i >> 6] |= 1u64 << (i & 63);
    }

    #[inline]
    pub fn clear(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i >> 6] &= !(1u64 << (i & 63));
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i >> 6] >> (i & 63)) & 1 == 1
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    pub fn clear_all(&mut self) {
        self.words.fill(0);
    }

    /// Grow to at least `len` bits (new bits are zero). Shrinking is a
    /// no-op — the arena that uses this never reuses a slot index for a
    /// smaller universe.
    pub fn grow(&mut self, len: usize) {
        if len > self.len {
            self.len = len;
            self.words.resize(len.div_ceil(64), 0);
        }
    }

    /// Indices of set bits, ascending. Skips zero words wholesale, so
    /// sparse sets (e.g. an arena after a mass departure) iterate in
    /// O(words + ones) rather than O(len).
    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &word)| {
            let mut w = word;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let bit = w.trailing_zeros() as usize;
                    w &= w - 1;
                    Some((wi << 6) | bit)
                }
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_clear() {
        let mut b = BitSet::new(130);
        assert!(!b.get(0) && !b.get(129));
        b.set(0);
        b.set(64);
        b.set(129);
        assert!(b.get(0) && b.get(64) && b.get(129) && !b.get(1));
        assert_eq!(b.count_ones(), 3);
        b.clear(64);
        assert!(!b.get(64));
        assert_eq!(b.count_ones(), 2);
        b.clear_all();
        assert_eq!(b.count_ones(), 0);
    }

    #[test]
    fn iter_ones_walks_set_bits_ascending() {
        let mut b = BitSet::new(200);
        for i in [0usize, 1, 63, 64, 127, 130, 199] {
            b.set(i);
        }
        let ones: Vec<usize> = b.iter_ones().collect();
        assert_eq!(ones, vec![0, 1, 63, 64, 127, 130, 199]);
        b.clear_all();
        assert_eq!(b.iter_ones().count(), 0);
    }

    #[test]
    fn grow_preserves_bits_and_zeroes_new_range() {
        let mut b = BitSet::new(10);
        b.set(3);
        b.set(9);
        b.grow(200);
        assert_eq!(b.len(), 200);
        assert!(b.get(3) && b.get(9) && !b.get(10) && !b.get(199));
        b.set(199);
        assert_eq!(b.count_ones(), 3);
        b.grow(50); // shrink request is a no-op
        assert_eq!(b.len(), 200);
    }
}
