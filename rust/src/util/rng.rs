//! Deterministic PRNG substrate.
//!
//! The whole system — topology generation, the discrete-event simulator,
//! churn injection, synthetic data — draws from seeded generators so every
//! experiment is exactly reproducible from its config seed. We implement
//! SplitMix64 (seeding / stream-splitting) and Xoshiro256** (bulk
//! generation) from the reference algorithms; no external crates.

/// SplitMix64: used to expand a single `u64` seed into independent streams.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256**: fast, high-quality 64-bit generator for bulk use.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 per the xoshiro authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next_u64();
        }
        // Avoid the all-zero state (probability 2^-256, but cheap to guard).
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E37_79B9_7F4A_7C15;
        }
        Self { s }
    }

    /// Derive an independent stream (e.g. one per simulated node).
    pub fn split(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Unbiased uniform integer in `[0, n)` (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize index into a slice of length `n`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (polar form avoided: trig is fine).
    pub fn gaussian(&mut self) -> f64 {
        // Draw u1 in (0,1] to avoid ln(0).
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Gaussian with mean/stddev as f32 (synthetic dataset hot path).
    #[inline]
    pub fn gaussian_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.gaussian() as f32
    }

    /// Exponential with rate `lambda` (latency jitter).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.next_f64()).ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Weighted index sample (linear scan; weights need not be normalized).
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted_index with non-positive total");
        let mut t = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// True with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn unit_interval_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.next_f32();
            assert!((0.0..1.0).contains(&y));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            let expect = n / 10;
            assert!((c as i64 - expect as i64).abs() < (expect as i64) / 10);
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gaussian();
            sum += g;
            sq += g * g;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(17);
        for _ in 0..100 {
            let s = r.sample_indices(50, 10);
            let mut t = s.clone();
            t.sort_unstable();
            t.dedup();
            assert_eq!(t.len(), 10);
            assert!(s.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Rng::new(23);
        let w = [0.0, 10.0, 0.0, 1.0];
        let mut counts = [0usize; 4];
        for _ in 0..10_000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert_eq!(counts[2], 0);
        assert!(counts[1] > 8 * counts[3]);
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Rng::new(31);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(37);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }
}
