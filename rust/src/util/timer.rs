//! Wall-clock timing helpers for the bench harness and telemetry.

use std::time::Instant;

/// Measure one closure invocation in seconds.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let start = Instant::now();
    let out = f();
    (out, start.elapsed().as_secs_f64())
}

/// A scope timer that records elapsed seconds into a sink on drop.
pub struct ScopeTimer<'a> {
    start: Instant,
    sink: &'a mut f64,
}

impl<'a> ScopeTimer<'a> {
    pub fn new(sink: &'a mut f64) -> Self {
        Self {
            start: Instant::now(),
            sink,
        }
    }
}

impl Drop for ScopeTimer<'_> {
    fn drop(&mut self) {
        *self.sink += self.start.elapsed().as_secs_f64();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_once_positive() {
        let (v, dt) = time_once(|| (0..1000).sum::<u64>());
        assert_eq!(v, 499_500);
        assert!(dt >= 0.0);
    }

    #[test]
    fn scope_timer_accumulates() {
        let mut acc = 0.0;
        {
            let _t = ScopeTimer::new(&mut acc);
            std::hint::black_box((0..10_000).sum::<u64>());
        }
        assert!(acc > 0.0);
    }
}
