//! Shared substrates: deterministic RNG, statistics, bitsets, timers.

pub mod bitset;
pub mod rng;
pub mod stats;
pub mod timer;

pub use bitset::BitSet;
pub use rng::{Rng, SplitMix64};
pub use stats::{cdf_points, mean, percentile, Summary};
