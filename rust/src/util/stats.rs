//! Small statistics helpers used by metrics, benches and telemetry.

/// Running mean/variance via Welford's algorithm, plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn extend<I: IntoIterator<Item = f64>>(&mut self, xs: I) {
        for x in xs {
            self.add(x);
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile of a slice (nearest-rank on a sorted copy). `q` in `[0, 1]`.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    assert!((0.0..=1.0).contains(&q));
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (q * (v.len() - 1) as f64).round() as usize;
    v[rank.min(v.len() - 1)]
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Empirical CDF points `(value, fraction <= value)` for plotting figures
/// like the paper's per-client accuracy CDFs (Figs. 9d-f, 11c, 19).
pub fn cdf_points(xs: &[f64]) -> Vec<(f64, f64)> {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len() as f64;
    v.iter()
        .enumerate()
        .map(|(i, &x)| (x, (i + 1) as f64 / n))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_matches_closed_form() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let mut s = Summary::new();
        s.extend(xs.iter().copied());
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.variance() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
        let p50 = percentile(&xs, 0.5);
        assert!((p50 - 50.0).abs() <= 1.0);
    }

    #[test]
    fn cdf_monotone() {
        let xs = [3.0, 1.0, 2.0, 2.0];
        let c = cdf_points(&xs);
        assert_eq!(c.len(), 4);
        assert!(c.windows(2).all(|w| w[0].0 <= w[1].0 && w[0].1 <= w[1].1));
        assert_eq!(c.last().unwrap().1, 1.0);
    }
}
