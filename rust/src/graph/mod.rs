//! Graph substrate: an undirected simple graph with adjacency lists,
//! traversals and generators. Every overlay topology in the repo (FedLay
//! and all baselines) lowers to this representation before the metric
//! pipeline (`metrics::`) runs on it.

pub mod gen;
pub mod traversal;

use std::collections::BTreeSet;

/// Undirected simple graph over node ids `0..n`.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    adj: Vec<BTreeSet<u32>>,
}

impl Graph {
    pub fn new(n: usize) -> Self {
        Self {
            adj: vec![BTreeSet::new(); n],
        }
    }

    pub fn n(&self) -> usize {
        self.adj.len()
    }

    /// Number of undirected edges.
    pub fn m(&self) -> usize {
        self.adj.iter().map(|s| s.len()).sum::<usize>() / 2
    }

    /// Add an undirected edge; self-loops and duplicates are ignored.
    /// Returns true if the edge was new.
    pub fn add_edge(&mut self, u: usize, v: usize) -> bool {
        assert!(u < self.n() && v < self.n(), "edge ({u},{v}) out of range");
        if u == v {
            return false;
        }
        let new = self.adj[u].insert(v as u32);
        self.adj[v].insert(u as u32);
        new
    }

    pub fn remove_edge(&mut self, u: usize, v: usize) -> bool {
        let had = self.adj[u].remove(&(v as u32));
        self.adj[v].remove(&(u as u32));
        had
    }

    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.adj[u].contains(&(v as u32))
    }

    pub fn degree(&self, u: usize) -> usize {
        self.adj[u].len()
    }

    pub fn neighbors(&self, u: usize) -> impl Iterator<Item = usize> + '_ {
        self.adj[u].iter().map(|&v| v as usize)
    }

    pub fn max_degree(&self) -> usize {
        self.adj.iter().map(|s| s.len()).max().unwrap_or(0)
    }

    pub fn avg_degree(&self) -> f64 {
        if self.n() == 0 {
            return 0.0;
        }
        2.0 * self.m() as f64 / self.n() as f64
    }

    /// All edges as (u, v) with u < v.
    pub fn edges(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.m());
        for (u, s) in self.adj.iter().enumerate() {
            for &v in s {
                let v = v as usize;
                if u < v {
                    out.push((u, v));
                }
            }
        }
        out
    }

    /// Build from an edge list over `n` nodes.
    pub fn from_edges(n: usize, edges: &[(usize, usize)]) -> Self {
        let mut g = Graph::new(n);
        for &(u, v) in edges {
            g.add_edge(u, v);
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_remove_edges() {
        let mut g = Graph::new(4);
        assert!(g.add_edge(0, 1));
        assert!(!g.add_edge(1, 0)); // duplicate
        assert!(!g.add_edge(2, 2)); // self-loop
        g.add_edge(1, 2);
        assert_eq!(g.m(), 2);
        assert_eq!(g.degree(1), 2);
        assert!(g.has_edge(0, 1) && g.has_edge(1, 0));
        assert!(g.remove_edge(0, 1));
        assert!(!g.remove_edge(0, 1));
        assert_eq!(g.m(), 1);
    }

    #[test]
    fn edges_are_canonical() {
        let g = Graph::from_edges(5, &[(3, 1), (0, 4), (1, 3)]);
        assert_eq!(g.edges(), vec![(0, 4), (1, 3)]);
    }

    #[test]
    fn degree_stats() {
        let g = Graph::from_edges(4, &[(0, 1), (0, 2), (0, 3)]);
        assert_eq!(g.max_degree(), 3);
        assert!((g.avg_degree() - 1.5).abs() < 1e-12);
    }
}
