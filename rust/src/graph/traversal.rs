//! BFS-based traversals: shortest paths, connectivity, components.

use super::Graph;
use crate::util::BitSet;
use std::collections::VecDeque;

/// BFS distances from `src`; unreachable nodes get `u32::MAX`.
pub fn bfs_distances(g: &Graph, src: usize) -> Vec<u32> {
    let mut dist = vec![u32::MAX; g.n()];
    let mut q = VecDeque::new();
    dist[src] = 0;
    q.push_back(src);
    while let Some(u) = q.pop_front() {
        let du = dist[u];
        for v in g.neighbors(u) {
            if dist[v] == u32::MAX {
                dist[v] = du + 1;
                q.push_back(v);
            }
        }
    }
    dist
}

pub fn is_connected(g: &Graph) -> bool {
    if g.n() == 0 {
        return true;
    }
    bfs_distances(g, 0).iter().all(|&d| d != u32::MAX)
}

/// Connected components as a label vector (component id per node).
pub fn components(g: &Graph) -> Vec<usize> {
    let n = g.n();
    let mut label = vec![usize::MAX; n];
    let mut seen = BitSet::new(n);
    let mut next = 0;
    let mut q = VecDeque::new();
    for s in 0..n {
        if seen.get(s) {
            continue;
        }
        seen.set(s);
        label[s] = next;
        q.push_back(s);
        while let Some(u) = q.pop_front() {
            for v in g.neighbors(u) {
                if !seen.get(v) {
                    seen.set(v);
                    label[v] = next;
                    q.push_back(v);
                }
            }
        }
        next += 1;
    }
    label
}

pub fn num_components(g: &Graph) -> usize {
    components(g).iter().copied().max().map_or(0, |m| m + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bfs_on_path() {
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        assert_eq!(bfs_distances(&g, 0), vec![0, 1, 2, 3]);
        assert_eq!(bfs_distances(&g, 2), vec![2, 1, 0, 1]);
    }

    #[test]
    fn connectivity_and_components() {
        let g = Graph::from_edges(5, &[(0, 1), (2, 3)]);
        assert!(!is_connected(&g));
        let c = components(&g);
        assert_eq!(c[0], c[1]);
        assert_eq!(c[2], c[3]);
        assert_ne!(c[0], c[2]);
        assert_ne!(c[4], c[0]);
        assert_eq!(num_components(&g), 3);
        let g2 = Graph::from_edges(3, &[(0, 1), (1, 2)]);
        assert!(is_connected(&g2));
        assert_eq!(num_components(&g2), 1);
    }

    #[test]
    fn unreachable_is_max() {
        let g = Graph::from_edges(3, &[(0, 1)]);
        let d = bfs_distances(&g, 0);
        assert_eq!(d[2], u32::MAX);
    }
}
