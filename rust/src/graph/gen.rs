//! Random-graph generators used by "Best" (random d-regular, paper §II-C)
//! and auxiliary models (Erdős–Rényi, Barabási–Albert for the social-graph
//! stand-in).

use super::Graph;
use crate::util::Rng;

/// Random d-regular graph via the pairing/configuration model with
/// rejection of self-loops and multi-edges (retry until simple).
///
/// This is the centralized "Best of 100" generator from paper §II-C(1):
/// `n * d` must be even and `d < n`.
pub fn random_regular(n: usize, d: usize, rng: &mut Rng) -> Graph {
    assert!(d < n, "degree {d} >= n {n}");
    assert!(n * d % 2 == 0, "n*d must be even");
    'outer: for _attempt in 0..100 {
        // stubs: node i appears d times
        let mut stubs: Vec<u32> = Vec::with_capacity(n * d);
        for i in 0..n {
            for _ in 0..d {
                stubs.push(i as u32);
            }
        }
        rng.shuffle(&mut stubs);
        let mut g = Graph::new(n);
        let mut conflicts: Vec<(usize, usize)> = Vec::new();
        for pair in stubs.chunks(2) {
            let (u, v) = (pair[0] as usize, pair[1] as usize);
            if u == v || g.has_edge(u, v) {
                conflicts.push((u, v)); // defer; repair below by edge swaps
            } else {
                g.add_edge(u, v);
            }
        }
        // Repair each conflicting stub pair (u,v) by breaking a random
        // accepted edge (x,y) and rewiring to (u,x),(v,y) — a standard
        // 2-swap that preserves all degrees and keeps the pairing uniform
        // enough for the near-RRG role (cf. Jellyfish's incremental swap).
        for (u, v) in conflicts {
            let mut done = false;
            for _try in 0..10_000 {
                let edges = g.edges();
                if edges.is_empty() {
                    break;
                }
                let (x, y) = edges[rng.index(edges.len())];
                let (a, b) = if rng.chance(0.5) { (x, y) } else { (y, x) };
                if a == u || a == v || b == u || b == v {
                    continue;
                }
                if !g.has_edge(u, a) && !g.has_edge(v, b) {
                    g.remove_edge(a, b);
                    g.add_edge(u, a);
                    g.add_edge(v, b);
                    done = true;
                    break;
                }
            }
            if !done {
                continue 'outer; // pathological; rebuild from scratch
            }
        }
        return g;
    }
    panic!("random_regular({n},{d}): repair failed after 100 attempts");
}

/// Erdős–Rényi G(n, p).
pub fn erdos_renyi(n: usize, p: f64, rng: &mut Rng) -> Graph {
    let mut g = Graph::new(n);
    for u in 0..n {
        for v in (u + 1)..n {
            if rng.chance(p) {
                g.add_edge(u, v);
            }
        }
    }
    g
}

/// Barabási–Albert preferential attachment with `m` edges per new node.
/// Heavy-tailed degree distribution — our stand-in for the Facebook social
/// graph comparator of paper Fig. 3 (DESIGN.md §Substitutions).
pub fn barabasi_albert(n: usize, m: usize, rng: &mut Rng) -> Graph {
    assert!(m >= 1 && n > m, "need n > m >= 1");
    let mut g = Graph::new(n);
    // seed: complete graph over the first m+1 nodes
    for u in 0..=m {
        for v in (u + 1)..=m {
            g.add_edge(u, v);
        }
    }
    // repeated-endpoint list implements preferential attachment
    let mut endpoints: Vec<u32> = Vec::new();
    for (u, v) in g.edges() {
        endpoints.push(u as u32);
        endpoints.push(v as u32);
    }
    for u in (m + 1)..n {
        let mut targets = Vec::with_capacity(m);
        let mut guard = 0;
        while targets.len() < m {
            let t = endpoints[rng.index(endpoints.len())] as usize;
            if t != u && !targets.contains(&t) {
                targets.push(t);
            }
            guard += 1;
            assert!(guard < 10_000, "BA attachment stuck");
        }
        for &t in &targets {
            g.add_edge(u, t);
            endpoints.push(u as u32);
            endpoints.push(t as u32);
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::traversal::is_connected;

    #[test]
    fn regular_graph_is_regular() {
        let mut rng = Rng::new(1);
        for &(n, d) in &[(20, 4), (50, 6), (101, 8)] {
            let g = random_regular(n, d, &mut rng);
            assert_eq!(g.n(), n);
            for u in 0..n {
                assert_eq!(g.degree(u), d, "node {u} in ({n},{d})");
            }
        }
    }

    #[test]
    fn regular_graph_usually_connected() {
        // d >= 3 random regular graphs are a.a.s. connected.
        let mut rng = Rng::new(2);
        let g = random_regular(100, 4, &mut rng);
        assert!(is_connected(&g));
    }

    #[test]
    #[should_panic]
    fn regular_rejects_odd_product() {
        let mut rng = Rng::new(3);
        random_regular(5, 3, &mut rng);
    }

    #[test]
    fn er_density() {
        let mut rng = Rng::new(4);
        let g = erdos_renyi(100, 0.1, &mut rng);
        let expect = 0.1 * (100.0 * 99.0 / 2.0);
        assert!((g.m() as f64 - expect).abs() < expect * 0.35);
    }

    #[test]
    fn ba_has_heavy_tail() {
        let mut rng = Rng::new(5);
        let g = barabasi_albert(300, 3, &mut rng);
        assert!(is_connected(&g));
        // minimum degree is m, hubs much larger
        assert!((0..300).all(|u| g.degree(u) >= 3));
        assert!(g.max_degree() > 15, "max degree {}", g.max_degree());
    }
}
