//! Config system: a TOML-subset parser plus typed experiment schemas.

pub mod schema;
pub mod toml;

pub use schema::{CapacityConfig, Config, DflConfig, NetConfig, OverlayConfig};
pub use toml::{Doc, ParseError, Value};
