//! Config system: a TOML-subset parser plus typed experiment schemas.

pub mod schema;
pub mod tasks;
pub mod toml;

pub use schema::{CapacityConfig, Config, DflConfig, NetConfig, OverlayConfig};
pub use tasks::{MultiTaskSpec, TaskSpec};
pub use toml::{Doc, ParseError, Value};
