//! Multi-task training specifications: the `TaskSpec` describing one
//! model task (dataset shards, model, MEP period, seeds) and the
//! `MultiTaskSpec` bundle the multi-task engine consumes — N independent
//! tasks trained by one `dfl::Trainer` over a single shared overlay.
//!
//! Serializable to the repo's TOML subset (`fedlay train --tasks
//! <spec.toml>`; format documented in `docs/multitask.md`, runnable
//! examples under `configs/tasks/`). Parsing follows the scenario-spec
//! rules: unknown keys and wrong-typed values fail loudly instead of
//! silently running a different experiment.

use super::schema::DflConfig;
use super::toml::Doc;
use anyhow::{ensure, Result};
use std::collections::BTreeSet;

/// One model task riding the shared overlay: its own dataset shards,
/// model (and therefore parameter dimensionality), MEP exchange period,
/// and seed — everything per-task the trainer needs for one lane.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskSpec {
    /// Unique label of the task (reports, golden lines, CLI tables).
    pub name: String,
    /// Runtime model task in the artifact manifest: "mlp" | "cnn" | "lstm".
    pub task: String,
    /// Label shards per client (non-iid level) for this task's data.
    pub shards_per_client: usize,
    /// Local SGD steps per wake.
    pub local_steps: usize,
    pub lr: f32,
    /// Base MEP communication period for medium-capacity clients (ms of
    /// simulated time); capacity tiers scale it per client.
    pub comm_period_ms: u64,
    /// Task-local seed: initialization, shards, data streams, eval
    /// batches, and wake staggering all derive from it, so a task's
    /// trajectory is a pure function of its own spec (task isolation).
    pub seed: u64,
}

impl TaskSpec {
    /// The single-task spec equivalent to a legacy `DflConfig` run — the
    /// multi-task engine with exactly this one lane reproduces the
    /// single-task trainer bit for bit.
    pub fn from_dfl(cfg: &DflConfig) -> Self {
        Self {
            name: cfg.task.clone(),
            task: cfg.task.clone(),
            shards_per_client: cfg.shards_per_client,
            local_steps: cfg.local_steps,
            lr: cfg.lr,
            comm_period_ms: cfg.comm_period_ms,
            seed: cfg.seed,
        }
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(!self.name.is_empty(), "task name must be non-empty");
        // names ride inside quoted TOML strings (`to_toml`) and golden
        // lines; quotes, backslashes and control characters would break
        // the round trip
        ensure!(
            !self.name.chars().any(|c| c == '"' || c == '\\' || c.is_control()),
            "task name {:?} must not contain quotes, backslashes or control characters",
            self.name
        );
        ensure!(!self.task.is_empty(), "task model must be non-empty");
        ensure!(self.lr > 0.0, "task {}: lr must be positive", self.name);
        ensure!(
            self.comm_period_ms > 0,
            "task {}: comm_period_ms must be positive",
            self.name
        );
        ensure!(
            self.shards_per_client >= 1,
            "task {}: shards_per_client must be >= 1",
            self.name
        );
        Ok(())
    }
}

/// A bundle of independent model tasks for one multi-task run.
#[derive(Debug, Clone, PartialEq)]
pub struct MultiTaskSpec {
    pub tasks: Vec<TaskSpec>,
}

/// Every field a `[task.N]` table may contain.
const TASK_FIELDS: &[&str] = &[
    "name",
    "model",
    "shards_per_client",
    "local_steps",
    "lr",
    "comm_period_ms",
    "seed",
];

impl MultiTaskSpec {
    pub fn load(path: &std::path::Path) -> Result<MultiTaskSpec> {
        let doc = Doc::parse_file(path)?;
        Self::from_doc(&doc)
    }

    pub fn from_toml_str(text: &str) -> Result<MultiTaskSpec> {
        let doc = Doc::parse(text)?;
        Self::from_doc(&doc)
    }

    /// Parse `[task.N]` tables. Absent fields default from
    /// `DflConfig::default()`, except `seed` which defaults to a
    /// per-index derivation (so two default lanes never train clones of
    /// the same model) and `name` which defaults to `<model>-N`.
    ///
    /// A `[task.N]` table must set at least one field: the TOML-subset
    /// parser keeps only `key = value` entries, so a bare section header
    /// is invisible to this layer and cannot be declared as a lane.
    pub fn from_doc(doc: &Doc) -> Result<MultiTaskSpec> {
        let dd = DflConfig::default();
        let mut indices: BTreeSet<u64> = BTreeSet::new();
        for key in doc.keys_with_prefix("") {
            let Some(rest) = key.strip_prefix("task.") else {
                anyhow::bail!(
                    "unknown task-spec key {key:?} (see docs/multitask.md for the format)"
                );
            };
            let Some((idx, field)) = rest.split_once('.') else {
                anyhow::bail!("malformed task-spec key {key:?}");
            };
            // the index must be in canonical form: `[task.01]` would
            // parse as 1 here but its fields would be looked up under
            // `task.1.*` and silently run the lane on defaults
            let canonical = idx.parse::<u64>().is_ok_and(|v| v.to_string() == idx);
            ensure!(
                canonical && TASK_FIELDS.contains(&field),
                "unknown task-spec key {key:?} (see docs/multitask.md for the format)"
            );
            indices.insert(idx.parse::<u64>().unwrap());
        }
        ensure!(
            !indices.is_empty(),
            "task spec declares no [task.N] tables"
        );
        let mut tasks = Vec::new();
        for i in indices {
            let path = |field: &str| format!("task.{i}.{field}");
            let model = str_key(doc, &path("model"))?
                .unwrap_or(&dd.task)
                .to_string();
            let name = str_key(doc, &path("name"))?
                .map(str::to_string)
                .unwrap_or_else(|| format!("{model}-{i}"));
            tasks.push(TaskSpec {
                name,
                task: model,
                shards_per_client: uint_key(doc, &path("shards_per_client"))?
                    .map(|v| v as usize)
                    .unwrap_or(dd.shards_per_client),
                local_steps: uint_key(doc, &path("local_steps"))?
                    .map(|v| v as usize)
                    .unwrap_or(dd.local_steps),
                lr: float_key(doc, &path("lr"))?.unwrap_or(dd.lr as f64) as f32,
                comm_period_ms: uint_key(doc, &path("comm_period_ms"))?
                    .map(|v| v as u64)
                    .unwrap_or(dd.comm_period_ms),
                seed: uint_key(doc, &path("seed"))?
                    .map(|v| v as u64)
                    .unwrap_or(dd.seed ^ (i << 8)),
            });
        }
        let spec = MultiTaskSpec { tasks };
        spec.validate()?;
        Ok(spec)
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(!self.tasks.is_empty(), "at least one task is required");
        let mut names = BTreeSet::new();
        for t in &self.tasks {
            t.validate()?;
            ensure!(
                names.insert(t.name.as_str()),
                "duplicate task name {:?}",
                t.name
            );
        }
        Ok(())
    }

    /// Distinct runtime model tasks, in first-appearance order — what the
    /// engine must load.
    pub fn model_tasks(&self) -> Vec<&str> {
        let mut seen = BTreeSet::new();
        self.tasks
            .iter()
            .map(|t| t.task.as_str())
            .filter(|m| seen.insert(*m))
            .collect()
    }

    /// Serialize to the TOML subset `from_doc` parses (round-trips).
    pub fn to_toml(&self) -> String {
        let mut s = String::new();
        for (i, t) in self.tasks.iter().enumerate() {
            s.push_str(&format!("[task.{}]\n", i + 1));
            s.push_str(&format!("name = \"{}\"\n", t.name));
            s.push_str(&format!("model = \"{}\"\n", t.task));
            s.push_str(&format!("shards_per_client = {}\n", t.shards_per_client));
            s.push_str(&format!("local_steps = {}\n", t.local_steps));
            s.push_str(&format!("lr = {}\n", t.lr));
            s.push_str(&format!("comm_period_ms = {}\n", t.comm_period_ms));
            s.push_str(&format!("seed = {}\n", t.seed));
            if i + 1 < self.tasks.len() {
                s.push('\n');
            }
        }
        s
    }
}

/// String key: absent is fine, present-but-not-a-string is an error (a
/// bare number would otherwise silently fall back to the default model
/// or name — the exact silent-misconfiguration this module rejects).
fn str_key<'d>(doc: &'d Doc, key: &str) -> Result<Option<&'d str>> {
    match doc.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_str()
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("{key} must be a string, got {v}")),
    }
}

/// Non-negative integer key (negatives would wrap through the casts).
fn uint_key(doc: &Doc, key: &str) -> Result<Option<i64>> {
    match doc.get(key) {
        None => Ok(None),
        Some(v) => {
            let i = v
                .as_int()
                .ok_or_else(|| anyhow::anyhow!("{key} must be an integer, got {v}"))?;
            ensure!(i >= 0, "{key} must be non-negative, got {i}");
            Ok(Some(i))
        }
    }
}

fn float_key(doc: &Doc, key: &str) -> Result<Option<f64>> {
    match doc.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_float()
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("{key} must be a number, got {v}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_dfl_mirrors_the_legacy_config() {
        let cfg = DflConfig::default();
        let t = TaskSpec::from_dfl(&cfg);
        assert_eq!(t.task, cfg.task);
        assert_eq!(t.seed, cfg.seed);
        assert_eq!(t.comm_period_ms, cfg.comm_period_ms);
        assert_eq!(t.local_steps, cfg.local_steps);
        t.validate().unwrap();
    }

    #[test]
    fn parses_two_task_spec() {
        let text = "\
[task.1]
name = \"digits-a\"
model = \"mlp\"
comm_period_ms = 200000
seed = 5

[task.2]
name = \"chars\"
model = \"lstm\"
local_steps = 2
lr = 0.3
";
        let spec = MultiTaskSpec::from_toml_str(text).unwrap();
        assert_eq!(spec.tasks.len(), 2);
        assert_eq!(spec.tasks[0].name, "digits-a");
        assert_eq!(spec.tasks[0].comm_period_ms, 200_000);
        assert_eq!(spec.tasks[0].seed, 5);
        assert_eq!(spec.tasks[1].task, "lstm");
        assert_eq!(spec.tasks[1].local_steps, 2);
        assert!((spec.tasks[1].lr - 0.3).abs() < 1e-6);
        assert_eq!(spec.model_tasks(), vec!["mlp", "lstm"]);
    }

    #[test]
    fn default_seeds_differ_per_lane() {
        let text = "[task.1]\nmodel = \"mlp\"\n[task.2]\nmodel = \"mlp\"\n";
        let spec = MultiTaskSpec::from_toml_str(text).unwrap();
        assert_ne!(spec.tasks[0].seed, spec.tasks[1].seed);
        assert_ne!(spec.tasks[0].name, spec.tasks[1].name);
    }

    #[test]
    fn round_trips_through_toml() {
        let spec = MultiTaskSpec {
            tasks: vec![
                TaskSpec {
                    name: "a".into(),
                    task: "mlp".into(),
                    shards_per_client: 8,
                    local_steps: 4,
                    lr: 0.5,
                    comm_period_ms: 300_000,
                    seed: 17,
                },
                TaskSpec {
                    name: "b".into(),
                    task: "lstm".into(),
                    shards_per_client: 4,
                    local_steps: 1,
                    lr: 0.25,
                    comm_period_ms: 120_000,
                    seed: 99,
                },
            ],
        };
        let back = MultiTaskSpec::from_toml_str(&spec.to_toml()).unwrap();
        assert_eq!(spec, back);
    }

    #[test]
    fn rejects_typos_duplicates_and_bad_values() {
        // unknown field: a typo must not silently fall back to a default
        let typo = "[task.1]\nmodel = \"mlp\"\ncomm_periodms = 5\n";
        assert!(MultiTaskSpec::from_toml_str(typo).is_err());
        // keys outside [task.N] are rejected
        let stray = "[scenario]\ninitial = 10\n[task.1]\nmodel = \"mlp\"\n";
        assert!(MultiTaskSpec::from_toml_str(stray).is_err());
        // duplicate names would make per-task reports ambiguous
        let dup = "[task.1]\nname = \"x\"\n[task.2]\nname = \"x\"\n";
        assert!(MultiTaskSpec::from_toml_str(dup).is_err());
        // wrong-typed and negative values fail loudly
        assert!(MultiTaskSpec::from_toml_str("[task.1]\nseed = 1.5\n").is_err());
        assert!(MultiTaskSpec::from_toml_str("[task.1]\nlocal_steps = -1\n").is_err());
        // an empty document is not a runnable spec
        assert!(MultiTaskSpec::from_toml_str("").is_err());
        // names that cannot survive the quoted-TOML round trip are
        // rejected at validation instead of corrupting `to_toml` output
        let mut bad = TaskSpec::from_dfl(&DflConfig::default());
        bad.name = "a\"b".into();
        assert!(bad.validate().is_err());
        bad.name = "a\\b".into();
        assert!(bad.validate().is_err());
        // wrong-typed STRING fields must fail loudly too, not fall back
        // to the default model/name
        assert!(MultiTaskSpec::from_toml_str("[task.1]\nname = 123\n").is_err());
        assert!(MultiTaskSpec::from_toml_str("[task.1]\nmodel = 5\n").is_err());
        // non-canonical indices would make every field of the table
        // unreachable (`task.01.lr` stored, `task.1.lr` looked up)
        assert!(MultiTaskSpec::from_toml_str("[task.01]\nmodel = \"mlp\"\n").is_err());
    }
}
