//! Typed experiment configuration, loaded from the TOML-subset files under
//! `configs/` (or built programmatically by benches/examples) with CLI
//! overrides applied on top (`--set key=value`).

use super::toml::{Doc, Value};

/// FedLay overlay parameters (paper §II-C).
#[derive(Debug, Clone, PartialEq)]
pub struct OverlayConfig {
    /// Number of virtual ring spaces `L`; node degree is at most `2L`.
    pub spaces: usize,
    /// Heartbeat period `T` in milliseconds (maintenance §III-B3).
    pub heartbeat_ms: u64,
    /// A neighbor is declared failed after `failure_multiple * T` silence.
    pub failure_multiple: u32,
    /// Period of the proactive bidirectional `Neighbor_repair` probes.
    pub repair_probe_ms: u64,
}

impl Default for OverlayConfig {
    fn default() -> Self {
        Self {
            spaces: 3,
            heartbeat_ms: 1_000,
            failure_multiple: 3,
            repair_probe_ms: 4_000,
        }
    }
}

/// Simulated network parameters (evaluation types 2-3, §IV-A1).
#[derive(Debug, Clone, PartialEq)]
pub struct NetConfig {
    /// Mean one-way message latency in ms (paper uses 350ms in Fig. 8).
    pub latency_ms: f64,
    /// Latency jitter fraction (exponential tail added to the mean).
    pub jitter: f64,
    /// Mean per-directed-link capacity in Mbit/s; transfer time grows
    /// with payload bytes. `0` = infinite bandwidth (latency-only model,
    /// the pre-link-model behavior).
    pub bandwidth_mbps: f64,
    /// Independent per-frame loss probability in `[0, 1)`; a lost frame
    /// is silently dropped by both backends. `0` = lossless.
    pub loss: f64,
    /// Per-node uplink capacity in Mbit/s shared by all of a node's
    /// concurrent sends (stragglers under fan-out). `0` = uncapped.
    pub node_up_mbps: f64,
    /// Per-node downlink capacity in Mbit/s shared by all of a node's
    /// concurrent receives. `0` = uncapped.
    pub node_down_mbps: f64,
    pub seed: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            latency_ms: 350.0,
            jitter: 0.2,
            bandwidth_mbps: 0.0,
            loss: 0.0,
            node_up_mbps: 0.0,
            node_down_mbps: 0.0,
            seed: 7,
        }
    }
}

impl NetConfig {
    /// Validate the link-model fields (shared by `Config::validate`,
    /// `ScenarioSpec::validate`, and the CLI flag overrides).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.latency_ms.is_finite() && self.latency_ms >= 0.0,
            "net.latency_ms must be a finite value >= 0"
        );
        anyhow::ensure!(
            self.jitter.is_finite() && self.jitter >= 0.0,
            "net.jitter must be a finite value >= 0"
        );
        anyhow::ensure!(
            self.bandwidth_mbps.is_finite() && self.bandwidth_mbps >= 0.0,
            "net.bandwidth_mbps must be a finite value >= 0 (0 = uncapped)"
        );
        anyhow::ensure!(
            self.loss.is_finite() && (0.0..1.0).contains(&self.loss),
            "net.loss must be a probability in [0, 1)"
        );
        anyhow::ensure!(
            self.node_up_mbps.is_finite() && self.node_up_mbps >= 0.0,
            "net.node_up_mbps must be a finite value >= 0 (0 = uncapped)"
        );
        anyhow::ensure!(
            self.node_down_mbps.is_finite() && self.node_down_mbps >= 0.0,
            "net.node_down_mbps must be a finite value >= 0 (0 = uncapped)"
        );
        Ok(())
    }
}

/// Client capacity tiers (paper §IV-A2: 60% medium / 20% high / 20% low;
/// high = 2/3 of medium's times, low = 2x medium's).
#[derive(Debug, Clone, PartialEq)]
pub struct CapacityConfig {
    pub frac_high: f64,
    pub frac_low: f64,
    pub high_scale: f64,
    pub low_scale: f64,
}

impl Default for CapacityConfig {
    fn default() -> Self {
        Self {
            frac_high: 0.2,
            frac_low: 0.2,
            high_scale: 2.0 / 3.0,
            low_scale: 2.0,
        }
    }
}

/// DFL training run parameters (§III-C, §IV-A).
#[derive(Debug, Clone, PartialEq)]
pub struct DflConfig {
    /// Task name: "mlp" | "cnn" | "lstm" (must exist in the manifest).
    pub task: String,
    pub clients: usize,
    /// Label shards per client (non-iid level; paper default 8).
    pub shards_per_client: usize,
    /// Local SGD steps per communication period.
    pub local_steps: usize,
    pub lr: f32,
    /// Base communication period for medium-capacity clients, in sim ms.
    pub comm_period_ms: u64,
    /// MEP confidence weights (paper: 0.5 / 0.5).
    pub alpha_d: f64,
    pub alpha_c: f64,
    /// Asynchronous exchange (paper default) vs synchronous rounds.
    pub asynchronous: bool,
    /// Use confidence-weighted aggregation (vs simple average ablation).
    pub confidence: bool,
    pub capacity: CapacityConfig,
    pub seed: u64,
}

impl Default for DflConfig {
    fn default() -> Self {
        Self {
            task: "mlp".into(),
            clients: 16,
            shards_per_client: 8,
            local_steps: 4,
            lr: 0.5,
            comm_period_ms: 5 * 60 * 1_000,
            alpha_d: 0.5,
            alpha_c: 0.5,
            asynchronous: true,
            confidence: true,
            capacity: CapacityConfig::default(),
            seed: 17,
        }
    }
}

/// Top-level experiment config.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    pub overlay: OverlayConfig,
    pub net: NetConfig,
    pub dfl: DflConfig,
    /// Directory holding the AOT artifacts + manifest.
    pub artifacts_dir: String,
}

fn d_usize(doc: &Doc, key: &str, default: usize) -> usize {
    doc.int(key).map(|i| i as usize).unwrap_or(default)
}

fn d_u64(doc: &Doc, key: &str, default: u64) -> u64 {
    doc.int(key).map(|i| i as u64).unwrap_or(default)
}

fn d_f64(doc: &Doc, key: &str, default: f64) -> f64 {
    doc.float(key).unwrap_or(default)
}

impl Config {
    /// Build a config from a parsed document; absent keys keep defaults.
    pub fn from_doc(doc: &Doc) -> Config {
        let od = OverlayConfig::default();
        let nd = NetConfig::default();
        let dd = DflConfig::default();
        let cd = CapacityConfig::default();
        Config {
            overlay: OverlayConfig {
                spaces: d_usize(doc, "overlay.spaces", od.spaces),
                heartbeat_ms: d_u64(doc, "overlay.heartbeat_ms", od.heartbeat_ms),
                failure_multiple: d_u64(doc, "overlay.failure_multiple", od.failure_multiple as u64)
                    as u32,
                repair_probe_ms: d_u64(doc, "overlay.repair_probe_ms", od.repair_probe_ms),
            },
            net: NetConfig {
                latency_ms: d_f64(doc, "net.latency_ms", nd.latency_ms),
                jitter: d_f64(doc, "net.jitter", nd.jitter),
                bandwidth_mbps: d_f64(doc, "net.bandwidth_mbps", nd.bandwidth_mbps),
                loss: d_f64(doc, "net.loss", nd.loss),
                node_up_mbps: d_f64(doc, "net.node_up_mbps", nd.node_up_mbps),
                node_down_mbps: d_f64(doc, "net.node_down_mbps", nd.node_down_mbps),
                seed: d_u64(doc, "net.seed", nd.seed),
            },
            dfl: DflConfig {
                task: doc.str("dfl.task").unwrap_or(&dd.task).to_string(),
                clients: d_usize(doc, "dfl.clients", dd.clients),
                shards_per_client: d_usize(doc, "dfl.shards_per_client", dd.shards_per_client),
                local_steps: d_usize(doc, "dfl.local_steps", dd.local_steps),
                lr: d_f64(doc, "dfl.lr", dd.lr as f64) as f32,
                comm_period_ms: d_u64(doc, "dfl.comm_period_ms", dd.comm_period_ms),
                alpha_d: d_f64(doc, "dfl.alpha_d", dd.alpha_d),
                alpha_c: d_f64(doc, "dfl.alpha_c", dd.alpha_c),
                asynchronous: doc.bool("dfl.asynchronous").unwrap_or(dd.asynchronous),
                confidence: doc.bool("dfl.confidence").unwrap_or(dd.confidence),
                capacity: CapacityConfig {
                    frac_high: d_f64(doc, "dfl.capacity.frac_high", cd.frac_high),
                    frac_low: d_f64(doc, "dfl.capacity.frac_low", cd.frac_low),
                    high_scale: d_f64(doc, "dfl.capacity.high_scale", cd.high_scale),
                    low_scale: d_f64(doc, "dfl.capacity.low_scale", cd.low_scale),
                },
                seed: d_u64(doc, "dfl.seed", dd.seed),
            },
            artifacts_dir: doc.str("artifacts_dir").unwrap_or("artifacts").to_string(),
        }
    }

    /// Load a file and apply `key=value` override strings on top.
    pub fn load(path: Option<&std::path::Path>, overrides: &[String]) -> anyhow::Result<Config> {
        let mut doc = match path {
            Some(p) => Doc::parse_file(p)?,
            None => Doc::default(),
        };
        for ov in overrides {
            let (k, v) = ov
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("override {ov:?} is not key=value"))?;
            let parsed = Doc::parse(&format!("{} = {}", k.trim(), v.trim()))
                .map_err(|e| anyhow::anyhow!("override {ov:?}: {e}"))?;
            doc.merge_from(parsed);
        }
        let cfg = Config::from_doc(&doc);
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.overlay.spaces >= 1, "overlay.spaces must be >= 1");
        anyhow::ensure!(self.overlay.heartbeat_ms > 0, "heartbeat must be positive");
        self.net.validate()?;
        anyhow::ensure!(self.dfl.clients >= 1, "dfl.clients must be >= 1");
        anyhow::ensure!(self.dfl.lr > 0.0, "dfl.lr must be positive");
        // a zero period would panic deep in MEP (`comm_confidence`) and
        // wedge the wake scheduler; reject it where the user typed it
        anyhow::ensure!(
            self.dfl.comm_period_ms > 0,
            "dfl.comm_period_ms must be positive"
        );
        anyhow::ensure!(
            self.dfl.alpha_d >= 0.0 && self.dfl.alpha_c >= 0.0,
            "confidence weights must be non-negative"
        );
        anyhow::ensure!(
            self.dfl.capacity.frac_high + self.dfl.capacity.frac_low <= 1.0,
            "capacity fractions exceed 1"
        );
        Ok(())
    }
}

/// Helper for benches: set a numeric override on a `Doc`.
pub fn set_num(doc: &mut Doc, key: &str, v: f64) {
    if v.fract() == 0.0 && v.abs() < i64::MAX as f64 {
        doc.set(key, Value::Int(v as i64));
    } else {
        doc.set(key, Value::Float(v));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn from_doc_overrides_defaults() {
        let doc = Doc::parse(
            "overlay.spaces = 5\ndfl.task = \"cnn\"\ndfl.clients = 100\nnet.latency_ms = 350",
        )
        .unwrap();
        let cfg = Config::from_doc(&doc);
        assert_eq!(cfg.overlay.spaces, 5);
        assert_eq!(cfg.dfl.task, "cnn");
        assert_eq!(cfg.dfl.clients, 100);
        assert_eq!(cfg.net.latency_ms, 350.0);
        // untouched defaults survive
        assert_eq!(cfg.overlay.heartbeat_ms, 1_000);
    }

    #[test]
    fn cli_overrides_win() {
        let cfg =
            Config::load(None, &["dfl.clients=64".into(), "overlay.spaces=4".into()]).unwrap();
        assert_eq!(cfg.dfl.clients, 64);
        assert_eq!(cfg.overlay.spaces, 4);
    }

    #[test]
    fn invalid_rejected() {
        assert!(Config::load(None, &["overlay.spaces=0".into()]).is_err());
        assert!(Config::load(None, &["dfl.lr=-1".into()]).is_err());
        // zero exchange period used to reach an assert! inside MEP
        assert!(Config::load(None, &["dfl.comm_period_ms=0".into()]).is_err());
        assert!(Config::load(None, &["garbage".into()]).is_err());
        // negative latency would underflow the delay floor; a non-finite
        // one saturates to u64::MAX µs and corrupts virtual time
        assert!(Config::load(None, &["net.latency_ms=-1".into()]).is_err());
        assert!(Config::load(None, &["net.jitter=-0.5".into()]).is_err());
        // link-model fields: probabilities and capacities bounded
        assert!(Config::load(None, &["net.loss=1.0".into()]).is_err());
        assert!(Config::load(None, &["net.loss=-0.1".into()]).is_err());
        assert!(Config::load(None, &["net.bandwidth_mbps=-5".into()]).is_err());
        assert!(Config::load(None, &["net.node_up_mbps=-1".into()]).is_err());
        assert!(Config::load(None, &["net.node_down_mbps=-1".into()]).is_err());
    }

    #[test]
    fn link_model_fields_parse_and_default_off() {
        let cfg = Config::load(
            None,
            &[
                "net.bandwidth_mbps=20".into(),
                "net.loss=0.05".into(),
                "net.node_up_mbps=10".into(),
                "net.node_down_mbps=40".into(),
            ],
        )
        .unwrap();
        assert_eq!(cfg.net.bandwidth_mbps, 20.0);
        assert_eq!(cfg.net.loss, 0.05);
        assert_eq!(cfg.net.node_up_mbps, 10.0);
        assert_eq!(cfg.net.node_down_mbps, 40.0);
        // defaults leave the link model disabled (latency-only behavior)
        let d = NetConfig::default();
        assert_eq!(d.bandwidth_mbps, 0.0);
        assert_eq!(d.loss, 0.0);
        assert_eq!(d.node_up_mbps, 0.0);
        assert_eq!(d.node_down_mbps, 0.0);
    }
}
