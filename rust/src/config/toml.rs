//! Minimal TOML-subset parser (substrate: serde/toml are unavailable in the
//! vendored dependency set, so the config system parses its own files).
//!
//! Supported syntax — everything the FedLay configs need:
//!   * `# comments` and blank lines
//!   * `[section]` and `[dotted.section]` headers
//!   * `key = value` with string ("..."), integer, float, bool values
//!   * flat arrays of scalars: `[1, 2, 3]`, `["a", "b"]`
//!
//! Keys are flattened to dotted paths (`section.key`), matching the
//! `artifacts/manifest.txt` convention so one parser serves both.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "\"{s}\""),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Array(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// A parsed document: dotted-path -> value, with source ordering discarded.
#[derive(Debug, Clone, Default)]
pub struct Doc {
    entries: BTreeMap<String, Value>,
}

#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc, ParseError> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| ParseError {
                line: lineno + 1,
                msg: msg.to_string(),
            };
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| err("unterminated section"))?;
                let name = name.trim();
                if name.is_empty() {
                    return Err(err("empty section name"));
                }
                section = name.to_string();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| err("expected key = value"))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let value = parse_value(line[eq + 1..].trim()).map_err(|m| err(&m))?;
            let path = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            entries.insert(path, value);
        }
        Ok(Doc { entries })
    }

    pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Doc> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Doc::parse(&text)?)
    }

    pub fn get(&self, path: &str) -> Option<&Value> {
        self.entries.get(path)
    }

    pub fn str(&self, path: &str) -> Option<&str> {
        self.get(path).and_then(Value::as_str)
    }

    pub fn int(&self, path: &str) -> Option<i64> {
        self.get(path).and_then(Value::as_int)
    }

    pub fn float(&self, path: &str) -> Option<f64> {
        self.get(path).and_then(Value::as_float)
    }

    pub fn bool(&self, path: &str) -> Option<bool> {
        self.get(path).and_then(Value::as_bool)
    }

    /// All keys under a dotted prefix (e.g. `task.mlp.`).
    pub fn keys_with_prefix<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a str> {
        self.entries
            .range(prefix.to_string()..)
            .take_while(move |(k, _)| k.starts_with(prefix))
            .map(|(k, _)| k.as_str())
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Merge `other` over `self` (CLI overrides > file values).
    pub fn merge_from(&mut self, other: Doc) {
        self.entries.extend(other.entries);
    }

    pub fn set(&mut self, path: &str, value: Value) {
        self.entries.insert(path.to_string(), value);
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        if inner.contains('"') {
            return Err("embedded quote in string".into());
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let mut items = Vec::new();
        for part in inner.split(',') {
            items.push(parse_value(part.trim())?);
        }
        return Ok(Value::Array(items));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    // Bare strings (manifest.txt style: `key = mlp_train.hlo.txt`).
    if s.chars().all(|c| {
        c.is_ascii_alphanumeric() || matches!(c, '_' | '-' | '.' | '/' | ',' | ':')
    }) {
        return Ok(Value::Str(s.to_string()));
    }
    Err(format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = Doc::parse(
            r#"
            # experiment config
            seed = 42
            [overlay]
            spaces = 3          # L
            degree_cap = 10
            name = "fedlay"
            frac = 0.25
            enabled = true
            "#,
        )
        .unwrap();
        assert_eq!(doc.int("seed"), Some(42));
        assert_eq!(doc.int("overlay.spaces"), Some(3));
        assert_eq!(doc.str("overlay.name"), Some("fedlay"));
        assert_eq!(doc.float("overlay.frac"), Some(0.25));
        assert_eq!(doc.bool("overlay.enabled"), Some(true));
    }

    #[test]
    fn parses_arrays() {
        let doc = Doc::parse("degrees = [4, 6, 8]\nnames = [\"a\", \"b\"]").unwrap();
        let arr = doc.get("degrees").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].as_int(), Some(6));
        let names = doc.get("names").unwrap().as_array().unwrap();
        assert_eq!(names[0].as_str(), Some("a"));
    }

    #[test]
    fn parses_manifest_style_bare_strings() {
        let doc = Doc::parse("artifact.mlp.train = mlp_train.hlo.txt\ntasks = mlp,cnn").unwrap();
        assert_eq!(doc.str("artifact.mlp.train"), Some("mlp_train.hlo.txt"));
        assert_eq!(doc.str("tasks"), Some("mlp,cnn"));
    }

    #[test]
    fn int_promotes_to_float() {
        let doc = Doc::parse("x = 3").unwrap();
        assert_eq!(doc.float("x"), Some(3.0));
    }

    #[test]
    fn reports_line_numbers() {
        let err = Doc::parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn rejects_unterminated() {
        assert!(Doc::parse("x = \"abc").is_err());
        assert!(Doc::parse("[sec").is_err());
        assert!(Doc::parse("x = [1, 2").is_err());
    }

    #[test]
    fn comment_inside_string_kept() {
        let doc = Doc::parse("x = \"a#b\"").unwrap();
        assert_eq!(doc.str("x"), Some("a#b"));
    }

    #[test]
    fn merge_overrides() {
        let mut a = Doc::parse("x = 1\ny = 2").unwrap();
        let b = Doc::parse("y = 3\nz = 4").unwrap();
        a.merge_from(b);
        assert_eq!(a.int("x"), Some(1));
        assert_eq!(a.int("y"), Some(3));
        assert_eq!(a.int("z"), Some(4));
    }

    #[test]
    fn prefix_iteration() {
        let doc = Doc::parse("a.b = 1\na.c = 2\nb.a = 3").unwrap();
        let keys: Vec<_> = doc.keys_with_prefix("a.").collect();
        assert_eq!(keys, vec!["a.b", "a.c"]);
    }
}
