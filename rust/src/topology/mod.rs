//! FedLay topology: virtual coordinates, ring spaces, the centralized
//! overlay constructor (ground truth for NDMP), and the correctness metric.

pub mod coords;
pub mod correctness;
pub mod fedlay;
pub mod incremental;

pub use coords::{
    ccw_arc, circular_distance, closer, cw_arc, Coord, NodeId, RingPoint, VirtualCoords,
};
pub use correctness::{
    correctness, graph_from_snapshot, ideal_neighbor_sets, ideal_sets_for_live, report,
    report_against_ideal, CorrectnessReport, NeighborSnapshot,
};
pub use fedlay::{build_overlay, fedlay_graph, Membership};
pub use incremental::IdealRings;
