//! Incrementally-maintained Definition-1 ideal topology.
//!
//! The batch path (`correctness::ideal_neighbor_sets`) re-sorts every
//! ring on every evaluation — O(L·n log n) per sample, which dominates a
//! 100k-client run the moment correctness is sampled on a cadence. This
//! module maintains the same ideal *persistently*: each space's ring is a
//! `BTreeSet<RingPoint>`, membership changes splice a node in or out in
//! O(L·log n), and the directed required/present tallies of the
//! correctness metric are running counters updated only on the O(L)
//! ring edges a join/fail/leave actually touches.
//!
//! The tracker is deliberately oblivious to *how* neighbor sets are
//! obtained: callers feed it membership events (`add`/`remove`) and
//! presence refreshes (`refresh(id, have)`), and it answers
//! `correctness()` in O(1). A membership `generation` stamp increments on
//! every add/remove so consumers (per-shard samplers) can assert they
//! merged tallies against one consistent membership.
//!
//! Batch equivalence is pinned by `tests/incremental_ideals.rs`: after
//! every event of a random churn schedule, `ideal_snapshot()` must equal
//! `ideal_neighbor_sets` over the same membership, and the running
//! tallies must equal `correctness::correctness` over the same have-sets.

use super::coords::{NodeId, RingPoint, VirtualCoords};
use super::correctness::NeighborSnapshot;
use super::fedlay::Membership;
use std::collections::{BTreeMap, BTreeSet};
use std::ops::Bound::{Excluded, Unbounded};

/// One directed ideal relation `a -> b` ("a requires b as a neighbor").
///
/// `mult` counts in how many spaces the pair is ring-adjacent; the
/// relation exists (and contributes 1 to `required`, matching the batch
/// metric's per-node de-duplicated `want` sets) while `mult > 0`.
/// `present` caches whether the owner's last refreshed have-set contains
/// `b`, so the global `present` tally is a running counter.
#[derive(Debug, Clone, Copy)]
struct DirEdge {
    mult: u32,
    present: bool,
}

/// Persistent Definition-1 ideal rings with running correctness tallies.
#[derive(Debug, Clone)]
pub struct IdealRings {
    spaces: usize,
    /// One ordered ring per space. `RingPoint`'s total order (coord, then
    /// id) matches `Membership::ring`, so splice positions agree with the
    /// batch sort bit-for-bit — including duplicate-coordinate ties.
    rings: Vec<BTreeSet<RingPoint>>,
    coords: BTreeMap<NodeId, VirtualCoords>,
    /// Directed edges keyed `(owner, neighbor)` so one `BTreeMap` range
    /// scan enumerates a node's ideal set.
    edges: BTreeMap<(NodeId, NodeId), DirEdge>,
    /// Bumped on every membership change (add/remove).
    generation: u64,
    required: usize,
    present: usize,
}

impl IdealRings {
    pub fn new(spaces: usize) -> Self {
        Self {
            spaces,
            rings: vec![BTreeSet::new(); spaces],
            coords: BTreeMap::new(),
            edges: BTreeMap::new(),
            generation: 0,
            required: 0,
            present: 0,
        }
    }

    pub fn spaces(&self) -> usize {
        self.spaces
    }

    pub fn len(&self) -> usize {
        self.coords.len()
    }

    pub fn is_empty(&self) -> bool {
        self.coords.is_empty()
    }

    pub fn contains(&self, id: NodeId) -> bool {
        self.coords.contains_key(&id)
    }

    /// Membership generation stamp: increments on every add/remove.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Total directed ideal relations (Σ over nodes of |want|).
    pub fn required(&self) -> usize {
        self.required
    }

    /// Directed relations whose owner's refreshed have-set holds them.
    pub fn present(&self) -> usize {
        self.present
    }

    /// The §IV-A3 correctness ratio from the running tallies — O(1).
    pub fn correctness(&self) -> f64 {
        if self.required == 0 {
            1.0
        } else {
            self.present as f64 / self.required as f64
        }
    }

    /// Admit `id` with hash-derived coordinates (the production path).
    /// Returns every node whose ideal set changed — the caller must
    /// `refresh` each of them (their presence flags may be stale).
    pub fn add(&mut self, id: NodeId) -> Vec<NodeId> {
        let coords = VirtualCoords::from_id(id, self.spaces);
        self.add_with_coords(id, coords)
    }

    /// Admit `id` with explicit coordinates (tests inject collisions).
    pub fn add_with_coords(&mut self, id: NodeId, coords: VirtualCoords) -> Vec<NodeId> {
        assert_eq!(coords.spaces(), self.spaces, "coordinate arity mismatch");
        if self.coords.contains_key(&id) {
            return Vec::new();
        }
        let mut touched = BTreeSet::new();
        touched.insert(id);
        for s in 0..self.spaces {
            let pt = RingPoint::new(coords.get(s), id);
            let n_before = self.rings[s].len();
            match n_before {
                0 => {}
                1 => {
                    // singleton ring: one new wrap pair
                    let other = self.rings[s].iter().next().unwrap().id;
                    self.link(id, other, &mut touched);
                }
                _ => {
                    let (prev, next) = Self::around(&self.rings[s], pt);
                    // on a 2-ring (prev, next) stays adjacent after the
                    // splice (every pair of a 3-ring is adjacent); from 3
                    // nodes up the splice breaks the (prev, next) edge
                    if n_before >= 3 {
                        self.unlink(prev, next, &mut touched);
                    }
                    self.link(prev, id, &mut touched);
                    self.link(id, next, &mut touched);
                }
            }
            self.rings[s].insert(pt);
        }
        self.coords.insert(id, coords);
        self.generation += 1;
        touched.into_iter().collect()
    }

    /// Retire `id`. Returns every node whose ideal set changed (the
    /// departed node is *not* included — it has no tallies left).
    pub fn remove(&mut self, id: NodeId) -> Vec<NodeId> {
        let Some(coords) = self.coords.remove(&id) else {
            return Vec::new();
        };
        let mut touched = BTreeSet::new();
        for s in 0..self.spaces {
            let pt = RingPoint::new(coords.get(s), id);
            let n_before = self.rings[s].len();
            match n_before {
                1 => {}
                2 => {
                    let other = self
                        .rings[s]
                        .iter()
                        .find(|p| p.id != id)
                        .unwrap()
                        .id;
                    self.unlink(id, other, &mut touched);
                }
                _ => {
                    let (prev, next) = Self::around(&self.rings[s], pt);
                    self.unlink(prev, id, &mut touched);
                    self.unlink(id, next, &mut touched);
                    // the survivors of a 3-ring are already adjacent
                    // (all pairs of a 3-ring are); from 4 nodes up the
                    // removal welds a new (prev, next) edge
                    if n_before >= 4 {
                        self.link(prev, next, &mut touched);
                    }
                }
            }
            self.rings[s].remove(&pt);
        }
        touched.remove(&id);
        self.generation += 1;
        touched.into_iter().collect()
    }

    /// Re-evaluate the presence flags of `id`'s ideal relations against
    /// its current have-set. Idempotent; O(|want| · log n).
    pub fn refresh(&mut self, id: NodeId, have: &BTreeSet<NodeId>) {
        let lo = (id, NodeId::MIN);
        let hi = (id, NodeId::MAX);
        let mut delta: i64 = 0;
        for (&(_, nbr), e) in self.edges.range_mut(lo..=hi) {
            let now = have.contains(&nbr);
            if now != e.present {
                delta += if now { 1 } else { -1 };
                e.present = now;
            }
        }
        self.present = (self.present as i64 + delta) as usize;
    }

    /// The Definition-1 ideal set of `id` (empty if unknown).
    pub fn want(&self, id: NodeId) -> BTreeSet<NodeId> {
        self.edges
            .range((id, NodeId::MIN)..=(id, NodeId::MAX))
            .map(|(&(_, nbr), _)| nbr)
            .collect()
    }

    /// Materialize the full ideal topology — the shape the batch
    /// `ideal_neighbor_sets` returns, for oracle comparison and the
    /// debug report path. O(n + edges), no ring sorts.
    pub fn ideal_snapshot(&self) -> NeighborSnapshot {
        let mut out: NeighborSnapshot =
            self.coords.keys().map(|&id| (id, BTreeSet::new())).collect();
        for &(a, b) in self.edges.keys() {
            out.get_mut(&a).unwrap().insert(b);
        }
        out
    }

    /// The tracked membership, rebuilt as the batch type (oracle use).
    pub fn membership(&self) -> Membership {
        let mut m = Membership::new(self.spaces);
        m.nodes = self.coords.clone();
        m
    }

    /// The ring neighbors of `pt`'s splice position, with wrap-around.
    /// Works whether or not `pt` itself is in the set (`Excluded` bounds
    /// skip it); callers guarantee the ring holds >= 2 *other* points or
    /// handle the small-ring cases themselves.
    fn around(ring: &BTreeSet<RingPoint>, pt: RingPoint) -> (NodeId, NodeId) {
        let next = ring
            .range((Excluded(pt), Unbounded))
            .next()
            .or_else(|| ring.iter().find(|&&p| p != pt))
            .unwrap()
            .id;
        let prev = ring
            .range((Unbounded, Excluded(pt)))
            .next_back()
            .or_else(|| ring.iter().rev().find(|&&p| p != pt))
            .unwrap()
            .id;
        (prev, next)
    }

    /// Record that `a` and `b` are ring-adjacent in one more space.
    /// Both directed relations move in lock-step; only a 0 -> 1
    /// transition touches the tallies (de-dup across spaces).
    fn link(&mut self, a: NodeId, b: NodeId, touched: &mut BTreeSet<NodeId>) {
        debug_assert_ne!(a, b, "self-adjacency is impossible on a ring");
        for (x, y) in [(a, b), (b, a)] {
            let e = self
                .edges
                .entry((x, y))
                .or_insert(DirEdge { mult: 0, present: false });
            if e.mult == 0 {
                self.required += 1;
                touched.insert(x);
            }
            e.mult += 1;
        }
    }

    /// Record that `a` and `b` are ring-adjacent in one fewer space.
    fn unlink(&mut self, a: NodeId, b: NodeId, touched: &mut BTreeSet<NodeId>) {
        for (x, y) in [(a, b), (b, a)] {
            let e = self.edges.get_mut(&(x, y)).expect("unlink of absent edge");
            e.mult -= 1;
            if e.mult == 0 {
                if e.present {
                    self.present -= 1;
                }
                self.required -= 1;
                self.edges.remove(&(x, y));
                touched.insert(x);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::correctness::ideal_neighbor_sets;

    fn batch_ideal(t: &IdealRings) -> NeighborSnapshot {
        ideal_neighbor_sets(&t.membership())
    }

    #[test]
    fn empty_and_singleton_rings() {
        let mut t = IdealRings::new(3);
        assert_eq!(t.correctness(), 1.0);
        assert_eq!(t.generation(), 0);
        t.add(7);
        assert_eq!(t.len(), 1);
        assert_eq!(t.required(), 0);
        assert_eq!(t.correctness(), 1.0);
        assert_eq!(t.generation(), 1);
        assert_eq!(t.ideal_snapshot(), batch_ideal(&t));
    }

    #[test]
    fn grows_to_match_batch_ideal() {
        let mut t = IdealRings::new(2);
        for id in 0..20u64 {
            let touched = t.add(id);
            assert!(touched.contains(&id) || t.len() == 1);
            assert_eq!(t.ideal_snapshot(), batch_ideal(&t), "after add {id}");
        }
        // required equals the sum of batch want-set sizes
        let want_all = batch_ideal(&t);
        let sum: usize = want_all.values().map(|s| s.len()).sum();
        assert_eq!(t.required(), sum);
    }

    #[test]
    fn shrinks_to_match_batch_ideal() {
        let mut t = IdealRings::new(2);
        for id in 0..12u64 {
            t.add(id);
        }
        for id in [5u64, 0, 11, 3, 7, 1, 9, 2, 4, 6, 8, 10] {
            t.remove(id);
            assert_eq!(t.ideal_snapshot(), batch_ideal(&t), "after remove {id}");
        }
        assert!(t.is_empty());
        assert_eq!(t.required(), 0);
        assert_eq!(t.present(), 0);
    }

    #[test]
    fn two_and_three_node_ring_transitions() {
        // the n<4 splice cases all have bespoke edge arithmetic — walk
        // through them explicitly in both directions
        let mut t = IdealRings::new(1);
        t.add(1);
        t.add(2); // 1-ring -> 2-ring: one pair
        assert_eq!(t.required(), 2);
        t.add(3); // 2-ring -> 3-ring: keep the old pair, add two
        assert_eq!(t.required(), 6);
        assert_eq!(t.ideal_snapshot(), batch_ideal(&t));
        t.add(4); // 3-ring -> 4-ring: now an unlink happens
        assert_eq!(t.ideal_snapshot(), batch_ideal(&t));
        t.remove(4); // 4 -> 3: weld suppressed (already adjacent)
        assert_eq!(t.required(), 6);
        assert_eq!(t.ideal_snapshot(), batch_ideal(&t));
        t.remove(3); // 3 -> 2: single unlink per side
        assert_eq!(t.required(), 2);
        assert_eq!(t.ideal_snapshot(), batch_ideal(&t));
        t.remove(2); // 2 -> 1
        assert_eq!(t.required(), 0);
        t.remove(1);
        assert!(t.is_empty());
    }

    #[test]
    fn duplicate_coordinates_order_by_id() {
        // inject colliding coordinates: the (coord, id) total order must
        // keep the incremental splice aligned with the batch sort
        let mut t = IdealRings::new(1);
        let c = |v: f64| VirtualCoords { coords: vec![v] };
        t.add_with_coords(10, c(0.5));
        t.add_with_coords(20, c(0.5));
        t.add_with_coords(15, c(0.5));
        t.add_with_coords(1, c(0.2));
        assert_eq!(t.ideal_snapshot(), batch_ideal(&t));
        t.remove(15);
        assert_eq!(t.ideal_snapshot(), batch_ideal(&t));
    }

    #[test]
    fn refresh_drives_running_tallies() {
        let mut t = IdealRings::new(2);
        for id in 0..8u64 {
            t.add(id);
        }
        assert_eq!(t.present(), 0);
        // hand every node its exact ideal set -> correctness 1
        for id in 0..8u64 {
            let want = t.want(id);
            t.refresh(id, &want);
        }
        assert_eq!(t.present(), t.required());
        assert_eq!(t.correctness(), 1.0);
        // degrade one node to an empty have-set
        t.refresh(3, &BTreeSet::new());
        assert!(t.correctness() < 1.0);
        // refresh is idempotent
        let (p, r) = (t.present(), t.required());
        t.refresh(3, &BTreeSet::new());
        assert_eq!((t.present(), t.required()), (p, r));
        // restore
        let want = t.want(3);
        t.refresh(3, &want);
        assert_eq!(t.correctness(), 1.0);
    }

    #[test]
    fn removal_drops_presence_of_dangling_edges() {
        let mut t = IdealRings::new(2);
        for id in 0..6u64 {
            t.add(id);
        }
        for id in 0..6u64 {
            let want = t.want(id);
            t.refresh(id, &want);
        }
        assert_eq!(t.correctness(), 1.0);
        // removing a node must retire its own directed edges (and their
        // presence) without help from the caller
        let touched = t.remove(2);
        assert!(!touched.contains(&2));
        assert!(t.present() <= t.required());
        // survivors' flags are stale until refreshed — that's the
        // caller's contract; refresh the touched set and compare
        for id in touched {
            let want = t.want(id);
            t.refresh(id, &want);
        }
        assert_eq!(t.correctness(), 1.0);
        assert_eq!(t.ideal_snapshot(), batch_ideal(&t));
    }

    #[test]
    fn generation_stamps_every_membership_change() {
        let mut t = IdealRings::new(2);
        t.add(1);
        t.add(2);
        let g = t.generation();
        t.refresh(1, &BTreeSet::new()); // presence does not bump
        assert_eq!(t.generation(), g);
        t.remove(1);
        assert_eq!(t.generation(), g + 1);
        t.remove(99); // no-op remove does not bump
        assert_eq!(t.generation(), g + 1);
    }
}
