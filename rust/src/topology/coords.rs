//! Virtual coordinates and circular distance (paper §II-C, Definition 2).
//!
//! Each node derives `L` coordinates in `[0,1)` by hashing its identity
//! with the space index: `x_i = H(id | i)` — a publicly computable,
//! collision-resistant mapping (we use SHA-256, the paper just requires a
//! public hash). Node identity is a `NodeId` (stand-in for the IP address
//! in simulation; the TCP transport uses real socket addresses mapped to
//! ids). Ties on a ring are broken by smaller id, so ring order is total.

use sha2::{Digest, Sha256};

/// Node identity. In simulations this is a dense index; in the TCP
/// prototype it is derived from the socket address. Ordering mirrors the
/// paper's "smaller IP address wins" tie-break.
pub type NodeId = u64;

/// One coordinate in `[0, 1)`.
pub type Coord = f64;

/// Circular distance between two ring coordinates (Definition 2):
/// `CD(x,y) = min(|x-y|, 1-|x-y|)` — the smaller arc, perimeter 1.
#[inline]
pub fn circular_distance(x: Coord, y: Coord) -> f64 {
    let d = (x - y).abs();
    d.min(1.0 - d)
}

/// Length of the arc from `x` to `y` travelling **counterclockwise**
/// (decreasing coordinate direction, wrapping at 0). Used by the
/// directional `Neighbor_repair` routing (§III-B3).
#[inline]
pub fn ccw_arc(from: Coord, to: Coord) -> f64 {
    let d = from - to;
    if d >= 0.0 {
        d
    } else {
        d + 1.0
    }
}

/// Length of the arc from `x` to `y` travelling **clockwise**
/// (increasing coordinate direction, wrapping at 1).
#[inline]
pub fn cw_arc(from: Coord, to: Coord) -> f64 {
    ccw_arc(to, from)
}

/// The full coordinate vector of one node across all `L` spaces.
#[derive(Debug, Clone, PartialEq)]
pub struct VirtualCoords {
    pub coords: Vec<Coord>,
}

impl VirtualCoords {
    /// Derive coordinates from a node id: `x_i = H(id | i) / 2^64`.
    pub fn from_id(id: NodeId, spaces: usize) -> Self {
        let coords = (0..spaces)
            .map(|i| {
                let mut h = Sha256::new();
                h.update(id.to_be_bytes());
                h.update(b"|");
                h.update((i as u64).to_be_bytes());
                let digest = h.finalize();
                let mut b = [0u8; 8];
                b.copy_from_slice(&digest[..8]);
                // map the top 53 bits into [0,1) exactly like Rng::next_f64
                (u64::from_be_bytes(b) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
            })
            .collect();
        Self { coords }
    }

    pub fn spaces(&self) -> usize {
        self.coords.len()
    }

    pub fn get(&self, space: usize) -> Coord {
        self.coords[space]
    }
}

/// `(coordinate, id)` with the paper's total order on a ring: by
/// coordinate, ties broken by smaller id.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RingPoint {
    pub coord: Coord,
    pub id: NodeId,
}

impl RingPoint {
    pub fn new(coord: Coord, id: NodeId) -> Self {
        Self { coord, id }
    }
}

impl Eq for RingPoint {}

impl PartialOrd for RingPoint {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for RingPoint {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.coord
            .partial_cmp(&other.coord)
            .unwrap()
            .then(self.id.cmp(&other.id))
    }
}

/// Is `candidate` strictly closer to `target` than `incumbent`, under the
/// paper's tie-break (equal distance -> smaller id wins)?
#[inline]
pub fn closer(
    target: Coord,
    candidate: (Coord, NodeId),
    incumbent: (Coord, NodeId),
) -> bool {
    let dc = circular_distance(candidate.0, target);
    let di = circular_distance(incumbent.0, target);
    dc < di || (dc == di && candidate.1 < incumbent.1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn circular_distance_basics() {
        assert_eq!(circular_distance(0.0, 0.0), 0.0);
        assert!((circular_distance(0.1, 0.9) - 0.2).abs() < 1e-12);
        assert!((circular_distance(0.9, 0.1) - 0.2).abs() < 1e-12);
        assert!((circular_distance(0.25, 0.75) - 0.5).abs() < 1e-12);
        assert!((circular_distance(0.2, 0.4) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn circular_distance_symmetric_and_bounded() {
        let mut rng = crate::util::Rng::new(1);
        for _ in 0..1_000 {
            let (x, y) = (rng.next_f64(), rng.next_f64());
            let d = circular_distance(x, y);
            assert!((0.0..=0.5).contains(&d));
            assert_eq!(d, circular_distance(y, x));
        }
    }

    #[test]
    fn arcs_complement() {
        let mut rng = crate::util::Rng::new(2);
        for _ in 0..1_000 {
            let (x, y) = (rng.next_f64(), rng.next_f64());
            if x == y {
                continue;
            }
            let s = ccw_arc(x, y) + cw_arc(x, y);
            assert!((s - 1.0).abs() < 1e-12, "arcs must cover the ring");
            let d = circular_distance(x, y);
            assert!((d - ccw_arc(x, y).min(cw_arc(x, y))).abs() < 1e-12);
        }
    }

    #[test]
    fn ccw_arc_direction() {
        // from 0.3 travelling ccw (decreasing) to 0.1 is 0.2
        assert!((ccw_arc(0.3, 0.1) - 0.2).abs() < 1e-12);
        // from 0.1 travelling ccw to 0.3 wraps: 0.8
        assert!((ccw_arc(0.1, 0.3) - 0.8).abs() < 1e-12);
    }

    #[test]
    fn coords_deterministic_and_spread() {
        let a = VirtualCoords::from_id(42, 5);
        let b = VirtualCoords::from_id(42, 5);
        assert_eq!(a, b);
        assert_eq!(a.spaces(), 5);
        for &c in &a.coords {
            assert!((0.0..1.0).contains(&c));
        }
        // different spaces give (practically) different coordinates
        let mut sorted = a.coords.clone();
        sorted.sort_by(|x, y| x.partial_cmp(y).unwrap());
        sorted.dedup();
        assert_eq!(sorted.len(), 5);
        // different ids differ
        let c = VirtualCoords::from_id(43, 5);
        assert_ne!(a.coords[0], c.coords[0]);
    }

    #[test]
    fn coords_approximately_uniform() {
        // mean of many hashed coordinates should be ~0.5
        let n = 2_000;
        let mean: f64 = (0..n)
            .map(|id| VirtualCoords::from_id(id, 1).get(0))
            .sum::<f64>()
            / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn ring_point_order_breaks_ties_by_id() {
        let a = RingPoint::new(0.5, 1);
        let b = RingPoint::new(0.5, 2);
        assert!(a < b);
        let c = RingPoint::new(0.4, 9);
        assert!(c < a);
    }

    #[test]
    fn closer_tie_break() {
        // equidistant: smaller id wins
        assert!(closer(0.5, (0.4, 1), (0.6, 2)));
        assert!(!closer(0.5, (0.4, 3), (0.6, 2)));
        assert!(closer(0.5, (0.45, 9), (0.6, 1)));
    }
}
