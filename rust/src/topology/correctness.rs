//! Topology-correctness metric (paper §IV-A3): the fraction of required
//! (Definition 1) neighbor relations that the live nodes actually hold.
//! Correctness 1.0 ⇔ the network is a correct FedLay.

use super::coords::NodeId;
use super::fedlay::Membership;
use std::collections::{BTreeMap, BTreeSet};

/// A snapshot of every live node's neighbor set, as reported by the nodes
/// themselves (NDMP state or simulator state).
pub type NeighborSnapshot = BTreeMap<NodeId, BTreeSet<NodeId>>;

/// All nodes' Definition-1 neighbor sets of a membership, with one ring
/// sort per space — O(L·n log n) total. `Membership::correct_neighbors`
/// rebuilds the rings per *node* (O(n log n) each), which is fine for
/// spot checks but quadratic over a snapshot; every whole-network
/// consumer (correctness metric, scenario quiescence, conformance
/// ideals) goes through this batch path so 10k-node scenarios stay
/// tractable.
pub fn ideal_neighbor_sets(m: &Membership) -> NeighborSnapshot {
    let mut out: NeighborSnapshot = m.nodes.keys().map(|&id| (id, BTreeSet::new())).collect();
    for s in 0..m.spaces {
        let ring = m.ring(s);
        let n = ring.len();
        if n < 2 {
            continue;
        }
        for i in 0..n {
            let a = ring[i].id;
            let b = ring[(i + 1) % n].id;
            if a != b {
                out.get_mut(&a).unwrap().insert(b);
                out.get_mut(&b).unwrap().insert(a);
            }
        }
    }
    out
}

/// The Definition-1 ideal neighbor sets of the membership implied by a
/// snapshot's live ids — the one place the metric and the debug report
/// build their ground truth, so the two can never drift.
pub fn ideal_sets_for_live(snapshot: &NeighborSnapshot, spaces: usize) -> NeighborSnapshot {
    let mut ideal = Membership::new(spaces);
    for &id in snapshot.keys() {
        ideal.add(id);
    }
    ideal_neighbor_sets(&ideal)
}

/// Fraction of correct neighbor entries over required entries, following
/// the paper: "the number of correct neighbors of all nodes over the total
/// number of neighbors" of the ideal topology built from the live ids.
pub fn correctness(snapshot: &NeighborSnapshot, spaces: usize) -> f64 {
    let want_all = ideal_sets_for_live(snapshot, spaces);
    let mut required = 0usize;
    let mut present = 0usize;
    for (id, have) in snapshot {
        let want = &want_all[id];
        required += want.len();
        present += want.iter().filter(|w| have.contains(w)).count();
    }
    if required == 0 {
        1.0
    } else {
        present as f64 / required as f64
    }
}

/// Lower a neighbor snapshot to an undirected `Graph` plus the sorted
/// live-id order its indices follow. Edges are the union of the nodes'
/// reported neighbor sets, restricted to live nodes — the *live* learning
/// topology, as opposed to the idealized `fedlay::build_overlay`.
pub fn graph_from_snapshot(snapshot: &NeighborSnapshot) -> (crate::graph::Graph, Vec<NodeId>) {
    let ids: Vec<NodeId> = snapshot.keys().copied().collect();
    let index: BTreeMap<NodeId, usize> =
        ids.iter().enumerate().map(|(i, &id)| (id, i)).collect();
    let mut g = crate::graph::Graph::new(ids.len());
    for (&id, nbrs) in snapshot {
        for n in nbrs {
            if let (Some(&u), Some(&v)) = (index.get(&id), index.get(n)) {
                g.add_edge(u, v);
            }
        }
    }
    (g, ids)
}

/// Detailed correctness report for debugging / experiment logging.
#[derive(Debug, Clone)]
pub struct CorrectnessReport {
    pub correctness: f64,
    /// Nodes whose neighbor set is exactly correct.
    pub correct_nodes: usize,
    pub total_nodes: usize,
    /// (node, missing-neighbor) pairs.
    pub missing: Vec<(NodeId, NodeId)>,
    /// (node, extra-neighbor) pairs (in set but not Definition-1 required).
    pub extra: Vec<(NodeId, NodeId)>,
}

pub fn report(snapshot: &NeighborSnapshot, spaces: usize) -> CorrectnessReport {
    report_against_ideal(snapshot, &ideal_sets_for_live(snapshot, spaces))
}

/// The report against an already-built ideal — lets callers holding an
/// incrementally-maintained ideal (`topology::IdealRings::ideal_snapshot`)
/// skip the O(L·n log n) rebuild entirely.
pub fn report_against_ideal(
    snapshot: &NeighborSnapshot,
    want_all: &NeighborSnapshot,
) -> CorrectnessReport {
    let mut required = 0usize;
    let mut present = 0usize;
    let mut correct_nodes = 0usize;
    let mut missing = Vec::new();
    let mut extra = Vec::new();
    for (&id, have) in snapshot {
        let want = &want_all[&id];
        required += want.len();
        let mut ok = true;
        for &w in want {
            if have.contains(&w) {
                present += 1;
            } else {
                missing.push((id, w));
                ok = false;
            }
        }
        for &h in have {
            if !want.contains(&h) {
                extra.push((id, h));
                ok = false;
            }
        }
        if ok {
            correct_nodes += 1;
        }
    }
    CorrectnessReport {
        correctness: if required == 0 {
            1.0
        } else {
            present as f64 / required as f64
        },
        correct_nodes,
        total_nodes: snapshot.len(),
        missing,
        extra,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::fedlay::Membership;

    fn perfect_snapshot(n: usize, spaces: usize) -> NeighborSnapshot {
        let m = Membership::dense(n, spaces);
        m.nodes
            .keys()
            .map(|&id| (id, m.correct_neighbors(id)))
            .collect()
    }

    #[test]
    fn perfect_network_scores_one() {
        let snap = perfect_snapshot(50, 3);
        assert_eq!(correctness(&snap, 3), 1.0);
        let r = report(&snap, 3);
        assert_eq!(r.correct_nodes, 50);
        assert!(r.missing.is_empty() && r.extra.is_empty());
    }

    #[test]
    fn broken_link_lowers_score() {
        let mut snap = perfect_snapshot(50, 3);
        // drop one neighbor entry from one node
        let (&id, _) = snap.iter().next().unwrap();
        let victim = *snap[&id].iter().next().unwrap();
        snap.get_mut(&id).unwrap().remove(&victim);
        let c = correctness(&snap, 3);
        assert!(c < 1.0 && c > 0.9);
        let r = report(&snap, 3);
        assert_eq!(r.missing, vec![(id, victim)]);
    }

    #[test]
    fn extra_neighbor_flagged_but_not_penalized_in_ratio() {
        let mut snap = perfect_snapshot(30, 2);
        // add a bogus far-away neighbor
        let (&id, _) = snap.iter().next().unwrap();
        let stranger = snap.keys().copied().last().unwrap();
        let is_required = {
            let m = Membership::dense(30, 2);
            m.correct_neighbors(id).contains(&stranger)
        };
        if !is_required {
            snap.get_mut(&id).unwrap().insert(stranger);
            assert_eq!(correctness(&snap, 2), 1.0);
            let r = report(&snap, 2);
            assert_eq!(r.extra, vec![(id, stranger)]);
            assert!(r.correct_nodes < 30);
        }
    }

    #[test]
    fn correctness_recomputed_over_survivors() {
        // after removing nodes, the ideal topology is over the survivors
        let m = Membership::dense(20, 2);
        let mut snap: NeighborSnapshot = m
            .nodes
            .keys()
            .filter(|&&id| id >= 5)
            .map(|&id| (id, m.correct_neighbors(id)))
            .collect();
        // survivors still point at dead nodes -> correctness < 1
        let before = correctness(&snap, 2);
        assert!(before < 1.0);
        // fix the snapshot to the survivor-ideal -> correctness = 1
        let survivors: Vec<NodeId> = snap.keys().copied().collect();
        let mut ideal = Membership::new(2);
        for id in &survivors {
            ideal.add(*id);
        }
        for id in survivors {
            snap.insert(id, ideal.correct_neighbors(id));
        }
        assert_eq!(correctness(&snap, 2), 1.0);
    }
}
