//! The FedLay overlay topology (paper §II-C): `L` virtual ring spaces,
//! each node adjacent to its two ring neighbors per space.
//!
//! This module is the *centralized* constructor — used for topology-metric
//! studies (Fig. 3) and as the ground truth the decentralized NDMP
//! protocols (`crate::ndmp`) are checked against (Definition 1).

use super::coords::{NodeId, RingPoint, VirtualCoords};
use crate::graph::Graph;
use std::collections::{BTreeMap, BTreeSet};

/// A FedLay network membership: ids with their coordinate vectors.
#[derive(Debug, Clone, Default)]
pub struct Membership {
    /// id -> coordinates; BTreeMap for deterministic iteration.
    pub nodes: BTreeMap<NodeId, VirtualCoords>,
    pub spaces: usize,
}

impl Membership {
    pub fn new(spaces: usize) -> Self {
        Self {
            nodes: BTreeMap::new(),
            spaces,
        }
    }

    /// Membership of ids `0..n` with hash-derived coordinates.
    pub fn dense(n: usize, spaces: usize) -> Self {
        let mut m = Self::new(spaces);
        for id in 0..n as NodeId {
            m.add(id);
        }
        m
    }

    pub fn add(&mut self, id: NodeId) -> &VirtualCoords {
        self.nodes
            .entry(id)
            .or_insert_with(|| VirtualCoords::from_id(id, self.spaces))
    }

    pub fn remove(&mut self, id: NodeId) {
        self.nodes.remove(&id);
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// The ring of space `i`, sorted by (coordinate, id).
    pub fn ring(&self, space: usize) -> Vec<RingPoint> {
        let mut pts: Vec<RingPoint> = self
            .nodes
            .iter()
            .map(|(&id, c)| RingPoint::new(c.get(space), id))
            .collect();
        pts.sort();
        pts
    }

    /// The two ring-adjacent node ids of `id` in space `i`.
    /// With fewer than 3 nodes the "two" adjacents may coincide or be none.
    pub fn adjacents(&self, id: NodeId, space: usize) -> Vec<NodeId> {
        let ring = self.ring(space);
        let n = ring.len();
        if n <= 1 {
            return vec![];
        }
        let pos = ring
            .iter()
            .position(|p| p.id == id)
            .expect("id not in membership");
        if n == 2 {
            return vec![ring[(pos + 1) % 2].id];
        }
        let prev = ring[(pos + n - 1) % n].id;
        let next = ring[(pos + 1) % n].id;
        if prev == next {
            vec![prev]
        } else {
            vec![prev, next]
        }
    }

    /// Correct neighbor set of `id` (Definition 1): ring-adjacent nodes in
    /// every space, de-duplicated.
    pub fn correct_neighbors(&self, id: NodeId) -> BTreeSet<NodeId> {
        let mut out = BTreeSet::new();
        for s in 0..self.spaces {
            for a in self.adjacents(id, s) {
                out.insert(a);
            }
        }
        out
    }
}

/// Build the full FedLay overlay graph of a membership (all spaces).
/// Node indices in the returned `Graph` follow the sorted id order.
pub fn build_overlay(m: &Membership) -> (Graph, Vec<NodeId>) {
    let ids: Vec<NodeId> = m.nodes.keys().copied().collect();
    let index: BTreeMap<NodeId, usize> = ids.iter().enumerate().map(|(i, &id)| (id, i)).collect();
    let mut g = Graph::new(ids.len());
    for s in 0..m.spaces {
        let ring = m.ring(s);
        let n = ring.len();
        if n < 2 {
            continue;
        }
        for i in 0..n {
            let j = (i + 1) % n;
            if n == 2 && i == 1 {
                break; // avoid double edge on a 2-ring
            }
            g.add_edge(index[&ring[i].id], index[&ring[j].id]);
        }
    }
    (g, ids)
}

/// Convenience: the FedLay overlay over ids `0..n` with `L` spaces.
pub fn fedlay_graph(n: usize, spaces: usize) -> Graph {
    build_overlay(&Membership::dense(n, spaces)).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::traversal::is_connected;

    #[test]
    fn degree_bounded_by_2l() {
        for &(n, l) in &[(30usize, 2usize), (100, 3), (200, 5)] {
            let g = fedlay_graph(n, l);
            assert!(g.max_degree() <= 2 * l, "n={n} L={l}");
            // with random coords nearly every node hits the bound
            assert!(g.avg_degree() > (2 * l) as f64 * 0.8);
        }
    }

    #[test]
    fn overlay_connected() {
        for &l in &[2usize, 3, 4] {
            assert!(is_connected(&fedlay_graph(150, l)), "L={l}");
        }
    }

    #[test]
    fn adjacents_are_mutual() {
        let m = Membership::dense(40, 3);
        for s in 0..3 {
            for (&id, _) in &m.nodes {
                for a in m.adjacents(id, s) {
                    assert!(
                        m.adjacents(a, s).contains(&id),
                        "adjacency must be symmetric (space {s}, {id}<->{a})"
                    );
                }
            }
        }
    }

    #[test]
    fn ring_is_sorted_and_complete() {
        let m = Membership::dense(25, 2);
        let ring = m.ring(0);
        assert_eq!(ring.len(), 25);
        assert!(ring.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn correct_neighbors_match_overlay_edges() {
        let m = Membership::dense(60, 3);
        let (g, ids) = build_overlay(&m);
        for (i, &id) in ids.iter().enumerate() {
            let want = m.correct_neighbors(id);
            let got: BTreeSet<NodeId> = g.neighbors(i).map(|j| ids[j]).collect();
            assert_eq!(got, want, "node {id}");
        }
    }

    #[test]
    fn two_node_network() {
        let mut m = Membership::new(3);
        m.add(1);
        m.add(2);
        let (g, _) = build_overlay(&m);
        assert_eq!(g.m(), 1);
        assert_eq!(m.adjacents(1, 0), vec![2]);
    }

    #[test]
    fn paper_example_three_neighbors_possible() {
        // Some nodes can have < 2L neighbors when the same pair is
        // adjacent in multiple spaces (paper's node B/D example).
        let g = fedlay_graph(12, 2);
        let degs: Vec<usize> = (0..12).map(|u| g.degree(u)).collect();
        assert!(degs.iter().all(|&d| d >= 2 && d <= 4));
    }

    #[test]
    fn membership_add_remove_roundtrip() {
        let mut m = Membership::dense(10, 2);
        m.remove(4);
        assert_eq!(m.len(), 9);
        assert!(m.ring(0).iter().all(|p| p.id != 4));
        m.add(4);
        assert_eq!(m.len(), 10);
    }
}
