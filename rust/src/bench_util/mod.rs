//! Bench harness substrate (criterion is not in the vendored dependency
//! set): warmup + timed repetitions with mean/stddev/percentiles, plus
//! aligned table printing for the per-figure experiment harnesses.

use crate::util::{percentile, Summary};
use std::time::Instant;

pub mod suite;

pub use suite::{engine_suite, micro_suite};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub stddev_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
}

impl BenchResult {
    pub fn throughput_per_s(&self) -> f64 {
        if self.mean_s > 0.0 {
            1.0 / self.mean_s
        } else {
            f64::INFINITY
        }
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    let mut summary = Summary::new();
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        let dt = t0.elapsed().as_secs_f64();
        times.push(dt);
        summary.add(dt);
    }
    BenchResult {
        name: name.to_string(),
        iters: iters.max(1),
        mean_s: summary.mean(),
        stddev_s: summary.stddev(),
        p50_s: percentile(&times, 0.5),
        p95_s: percentile(&times, 0.95),
        p99_s: percentile(&times, 0.99),
    }
}

/// Render bench results as an aligned table.
pub fn render_results(results: &[BenchResult]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<44} {:>8} {:>12} {:>12} {:>12} {:>12} {:>14}\n",
        "benchmark", "iters", "mean", "p50", "p95", "p99", "throughput/s"
    ));
    for r in results {
        out.push_str(&format!(
            "{:<44} {:>8} {:>12} {:>12} {:>12} {:>12} {:>14.1}\n",
            r.name,
            r.iters,
            fmt_time(r.mean_s),
            fmt_time(r.p50_s),
            fmt_time(r.p95_s),
            fmt_time(r.p99_s),
            r.throughput_per_s()
        ));
    }
    out
}

/// Persist results as `BENCH_<suite>.json` under `dir` (the repo root,
/// for the CI perf artifact). Hand-rolled serialization — serde is not
/// in the vendored dependency set; the schema is documented in
/// docs/perf.md.
pub fn write_bench_json(
    dir: &std::path::Path,
    suite: &str,
    results: &[BenchResult],
) -> std::io::Result<std::path::PathBuf> {
    let path = dir.join(format!("BENCH_{suite}.json"));
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"suite\": {},\n", json_str(suite)));
    s.push_str(&format!("  \"git_rev\": {},\n", json_str(&git_rev())));
    s.push_str(&format!("  \"timestamp_unix_s\": {},\n", unix_time_s()));
    s.push_str("  \"results\": [\n");
    for (i, r) in results.iter().enumerate() {
        let sep = if i + 1 == results.len() { "" } else { "," };
        s.push_str(&format!(
            "    {{\"name\": {}, \"iters\": {}, \"mean_s\": {}, \"p50_s\": {}, \
             \"p95_s\": {}, \"p99_s\": {}, \"throughput_per_s\": {}}}{sep}\n",
            json_str(&r.name),
            r.iters,
            json_num(r.mean_s),
            json_num(r.p50_s),
            json_num(r.p95_s),
            json_num(r.p99_s),
            json_num(r.throughput_per_s()),
        ));
    }
    s.push_str("  ]\n}\n");
    std::fs::write(&path, s)?;
    Ok(path)
}

/// Parse a `BENCH_*.json` written by `write_bench_json` back into
/// `(name, mean_s)` pairs. Tolerant of field order within a result
/// object but expects our own writer's one-object-per-entry shape — this
/// is a baseline reader for `fedlay bench --compare`, not a general JSON
/// parser (serde is not in the vendored set).
pub fn read_bench_json(path: &std::path::Path) -> anyhow::Result<Vec<(String, f64)>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read baseline {}: {e}", path.display()))?;
    let mut out = Vec::new();
    let mut rest = text.as_str();
    // skip the header's "suite" string; entries live under "results"
    let Some(results_at) = rest.find("\"results\"") else {
        anyhow::bail!("{}: no \"results\" array", path.display());
    };
    rest = &rest[results_at..];
    while let Some(at) = rest.find("\"name\":") {
        rest = &rest[at + "\"name\":".len()..];
        let (name, after) = parse_json_string(rest)
            .ok_or_else(|| anyhow::anyhow!("{}: malformed name string", path.display()))?;
        rest = after;
        let mean_at = rest.find("\"mean_s\":").ok_or_else(|| {
            anyhow::anyhow!("{}: entry {name:?} has no mean_s", path.display())
        })?;
        rest = &rest[mean_at + "\"mean_s\":".len()..];
        let end = rest
            .find(|c: char| c == ',' || c == '}')
            .ok_or_else(|| anyhow::anyhow!("{}: unterminated mean_s", path.display()))?;
        let mean: f64 = rest[..end].trim().parse().map_err(|_| {
            anyhow::anyhow!("{}: bad mean_s for {name:?}: {:?}", path.display(), &rest[..end])
        })?;
        rest = &rest[end..];
        out.push((name, mean));
    }
    anyhow::ensure!(!out.is_empty(), "{}: no bench entries", path.display());
    Ok(out)
}

/// Read one JSON string starting at (whitespace before) an opening
/// quote; returns the unescaped value and the remainder after the
/// closing quote.
fn parse_json_string(s: &str) -> Option<(String, &str)> {
    let s = s.trim_start();
    let mut chars = s.char_indices();
    match chars.next() {
        Some((_, '"')) => {}
        _ => return None,
    }
    let mut out = String::new();
    while let Some((i, c)) = chars.next() {
        match c {
            '"' => return Some((out, &s[i + 1..])),
            '\\' => match chars.next()?.1 {
                '"' => out.push('"'),
                '\\' => out.push('\\'),
                'u' => {
                    // our writer only emits \uXXXX for control chars
                    let mut code = 0u32;
                    for _ in 0..4 {
                        code = code * 16 + chars.next()?.1.to_digit(16)?;
                    }
                    out.push(char::from_u32(code)?);
                }
                other => out.push(other),
            },
            c => out.push(c),
        }
    }
    None
}

/// Should a regression in this entry fail CI? The event-queue and
/// correctness entries are the scale-critical hot paths (the sharded
/// engine's heartbeat loop and the incremental Definition-1 tallies);
/// everything else is informational in the delta table.
pub fn gated_entry(name: &str) -> bool {
    name.contains("event_queue") || name.contains("correctness")
}

/// Compare current results against a baseline: a per-entry delta table
/// plus the list of gated entries whose mean regressed above
/// `fail_ratio` (current/baseline). Entries present on only one side
/// are shown but never gate — a renamed or new bench must not brick CI.
pub fn compare_results(
    baseline: &[(String, f64)],
    current: &[BenchResult],
    fail_ratio: f64,
) -> (Table, Vec<String>) {
    let base: std::collections::BTreeMap<&str, f64> =
        baseline.iter().map(|(n, m)| (n.as_str(), *m)).collect();
    let mut t = Table::new(&["benchmark", "baseline", "current", "ratio", "gate"]);
    let mut regressions = Vec::new();
    let mut seen = std::collections::BTreeSet::new();
    for r in current {
        seen.insert(r.name.as_str());
        let gate = gated_entry(&r.name);
        match base.get(r.name.as_str()) {
            Some(&prev) if prev > 0.0 => {
                let ratio = r.mean_s / prev;
                let verdict = if gate && ratio > fail_ratio {
                    regressions.push(format!(
                        "{}: {} -> {} ({:.2}x > {:.2}x allowed)",
                        r.name,
                        fmt_time(prev),
                        fmt_time(r.mean_s),
                        ratio,
                        fail_ratio
                    ));
                    "FAIL"
                } else if gate {
                    "ok"
                } else {
                    "-"
                };
                t.row(&[
                    r.name.clone(),
                    fmt_time(prev),
                    fmt_time(r.mean_s),
                    format!("{ratio:.2}x"),
                    verdict.to_string(),
                ]);
            }
            _ => {
                t.row(&[
                    r.name.clone(),
                    "(new)".to_string(),
                    fmt_time(r.mean_s),
                    "-".to_string(),
                    "-".to_string(),
                ]);
            }
        }
    }
    for (name, prev) in baseline {
        if !seen.contains(name.as_str()) {
            t.row(&[
                name.clone(),
                fmt_time(*prev),
                "(absent)".to_string(),
                "-".to_string(),
                "-".to_string(),
            ]);
        }
    }
    (t, regressions)
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Finite JSON number (JSON has no Infinity/NaN literal).
fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:e}")
    } else {
        "0".to_string()
    }
}

fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .unwrap_or_else(|| "unknown".to_string())
}

fn unix_time_s() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

/// Simple aligned table printer for experiment harnesses.
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        let rule = widths.iter().sum::<usize>() + 2 * (widths.len().saturating_sub(1));
        out.push_str(&"-".repeat(rule));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Scale knob: benches run scaled-down by default on the 1-CPU sandbox;
/// `FEDLAY_BENCH_SCALE=paper` switches to paper-scale parameters.
pub fn paper_scale() -> bool {
    std::env::var("FEDLAY_BENCH_SCALE").map(|v| v == "paper").unwrap_or(false)
}

/// Pick `small` normally, `paper` under FEDLAY_BENCH_SCALE=paper.
pub fn scaled<T>(small: T, paper: T) -> T {
    if paper_scale() {
        paper
    } else {
        small
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 1, 5, || (0..10_000).sum::<u64>());
        assert!(r.mean_s > 0.0);
        assert!(r.p95_s >= r.p50_s);
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("long-name"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    fn bench_json_escapes_and_balances() {
        let r = bench("json/check \"quoted\"", 0, 3, || 1 + 1);
        let path = write_bench_json(&std::env::temp_dir(), "unit_test", &[r]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"suite\": \"unit_test\""));
        assert!(text.contains("\\\"quoted\\\""));
        assert!(text.contains("\"git_rev\""));
        assert!(text.contains("\"p99_s\""));
        assert!(text.contains("\"throughput_per_s\""));
        assert_eq!(text.matches('{').count(), text.matches('}').count());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn bench_json_roundtrips_for_compare() {
        let r1 = bench("sim/event_queue unit x10", 0, 3, || (0..100).sum::<u64>());
        let r2 = bench("other/\"entry\"", 0, 3, || 2 + 2);
        let path =
            write_bench_json(&std::env::temp_dir(), "unit_cmp", &[r1.clone(), r2.clone()])
                .unwrap();
        let back = read_bench_json(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].0, r1.name);
        assert_eq!(back[1].0, r2.name, "escaped names must round-trip");
        // {:e} prints a round-trippable f64, so means survive exactly
        assert_eq!(back[0].1, r1.mean_s);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn compare_gates_only_hot_path_entries() {
        assert!(gated_entry("sim/event_queue push+pop x1000"));
        assert!(gated_entry("topology/correctness_incremental_vs_batch 1k"));
        assert!(!gated_entry("mep/merge 1k params"));
        let r1 = bench("sim/event_queue unit x10", 0, 2, || (0..100).sum::<u64>());
        let r2 = bench("mep/other", 0, 2, || 2 + 2);
        let base = vec![(r1.name.clone(), r1.mean_s), (r2.name.clone(), r2.mean_s)];
        // identical runs never regress
        let (t, regs) = compare_results(&base, &[r1.clone(), r2.clone()], 1.5);
        assert!(regs.is_empty(), "{regs:?}");
        assert!(t.render().contains("1.00x"));
        // a blown-up gated entry fails; the ungated one never does
        let mut slow1 = r1.clone();
        slow1.mean_s *= 10.0;
        let mut slow2 = r2.clone();
        slow2.mean_s *= 10.0;
        let (_, regs) = compare_results(&base, &[slow1, slow2], 1.5);
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert!(regs[0].contains("event_queue"));
        // one-sided entries render but never gate
        let fresh = bench("sim/event_queue brand-new", 0, 2, || 1 + 1);
        let (t, regs) = compare_results(&base, &[fresh], 1.5);
        assert!(regs.is_empty());
        let rendered = t.render();
        assert!(rendered.contains("(new)"));
        assert!(rendered.contains("(absent)"));
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(5e-9).ends_with("ns"));
        assert!(fmt_time(5e-6).ends_with("µs"));
        assert!(fmt_time(5e-3).ends_with("ms"));
        assert!(fmt_time(5.0).ends_with('s'));
    }
}
