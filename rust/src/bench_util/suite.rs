//! The shared perf micro-suite behind `fedlay bench` and
//! `cargo bench --bench perf_micro`: the hot paths of all three layers,
//! persisted as `BENCH_<suite>.json` by the callers (schema and usage in
//! docs/perf.md).
//!
//!  * greedy routing next-hop decision (per-hop cost of NDMP)
//!  * virtual-coordinate hashing
//!  * event-queue throughput: push/pop, the cancel-heavy tombstone
//!    path, and a million-event heap
//!  * the sharded engine end to end — the same fleet on K=1 and K=4,
//!    which exercises the boundary-mailbox drain and the merge barrier
//!  * model fingerprinting (MEP de-dup) and CPU aggregation
//!  * artifact execution latency (`engine_suite`, needs a runtime)

use super::{bench, BenchResult};
use crate::config::{NetConfig, OverlayConfig};
use crate::data::GaussianTask;
use crate::mep::{aggregate_cpu, fingerprint, pack_for_artifact};
use crate::ndmp::messages::{Dir, SEC};
use crate::ndmp::routing::{coord_of, directional_next_hop, greedy_next_hop};
use crate::runtime::{Engine, XInput};
use crate::sim::{EventKind, EventQueue, Simulator};
use crate::topology::fedlay::Membership;
use crate::topology::NodeId;
use crate::util::Rng;
use anyhow::Result;

/// One full engine run for the simulator benches: `n` nodes over `k`
/// coordinate-arc shards, advanced to `horizon`.
fn sharded_run(n: usize, k: usize, horizon: u64) -> usize {
    let mut sim = Simulator::new(OverlayConfig::default(), NetConfig::default());
    if k > 1 {
        sim.set_shards(k);
    }
    let ids: Vec<NodeId> = (0..n as NodeId).collect();
    sim.bootstrap_correct(&ids);
    sim.run_until(horizon);
    sim.live_count()
}

/// The engine-free micro benches. `quick` trims iteration counts and the
/// large-heap size for the CI smoke run.
pub fn micro_suite(quick: bool) -> Vec<BenchResult> {
    let it = |full: usize| if quick { (full / 10).max(2) } else { full };
    let mut results = Vec::new();

    // --- L3: routing hot path ---
    let m = Membership::dense(500, 3);
    let nbrs: Vec<Vec<u64>> = m
        .nodes
        .keys()
        .map(|&id| m.correct_neighbors(id).into_iter().collect())
        .collect();
    let ids: Vec<u64> = m.nodes.keys().copied().collect();
    let mut rng = Rng::new(1);
    results.push(bench("ndmp/greedy_next_hop (500 nodes, L=3)", 100, it(20_000), || {
        let i = rng.index(ids.len());
        let target = rng.next_f64();
        greedy_next_hop(ids[i], target, 1, nbrs[i].iter().copied())
    }));
    results.push(bench("ndmp/directional_next_hop", 100, it(20_000), || {
        let i = rng.index(ids.len());
        let target = rng.next_f64();
        directional_next_hop(ids[i], target, 1, Dir::Ccw, nbrs[i].iter().copied())
    }));
    results.push(bench("topology/coord_of (sha256)", 100, it(20_000), || {
        coord_of(rng.next_u64(), 2)
    }));

    // --- L3: discrete-event backbone ---
    results.push(bench("sim/event_queue push+pop x1000", 10, it(500), || {
        let mut q = EventQueue::new();
        for i in 0..1000u64 {
            q.push(i * 7 % 997, EventKind::Snapshot { tag: i });
        }
        while q.pop().is_some() {}
    }));
    // the tombstone path: half of a 4096-event heap cancelled before the
    // drain, so every other pop reaps a cancelled entry
    results.push(bench("sim/event_queue cancel-heavy x4096", 5, it(200), || {
        let mut q = EventQueue::new();
        let ids: Vec<_> = (0..4096u64)
            .map(|i| q.push(i * 13 % 4099, EventKind::Snapshot { tag: i }))
            .collect();
        for id in ids.iter().step_by(2) {
            q.cancel(*id);
        }
        while q.pop().is_some() {}
    }));
    let heap_n: u64 = if quick { 100_000 } else { 1_000_000 };
    let iters = if quick { 3 } else { 5 };
    let name = format!("sim/event_queue large-heap push+pop x{heap_n}");
    results.push(bench(&name, 1, iters, || {
        let mut q = EventQueue::new();
        for i in 0..heap_n {
            let at = i.wrapping_mul(2_654_435_761) % 1_000_003;
            q.push(at, EventKind::Snapshot { tag: i });
        }
        while q.pop().is_some() {}
    }));

    // --- the sharded engine end to end: one fleet, K=1 vs K=4 ---
    let (n, horizon) = if quick {
        (128usize, 5 * SEC)
    } else {
        (512usize, 10 * SEC)
    };
    let secs = horizon / SEC;
    let iters = if quick { 2 } else { 5 };
    let name = format!("sim/run_until serial ({n} nodes, {secs}s)");
    results.push(bench(&name, 1, iters, || sharded_run(n, 1, horizon)));
    let name = format!("sim/run_until K=4 mailbox drain ({n} nodes, {secs}s)");
    results.push(bench(&name, 1, iters, || sharded_run(n, 4, horizon)));

    // --- incremental Definition-1 tallies vs the batch rebuild ---
    // the per-sample cost the tentpole removes: one O(1) read of the
    // maintained tallies against one full snapshot + ring re-sort. Built
    // on a converged fleet so both paths see the same membership.
    let corr_n = if quick { 512usize } else { 2_048 };
    let mut sim = Simulator::new(OverlayConfig::default(), NetConfig::default());
    sim.bootstrap_correct(&(0..corr_n as NodeId).collect::<Vec<_>>());
    let name = format!("topology/correctness_incremental ({corr_n} nodes)");
    results.push(bench(&name, 10, it(5_000), || sim.correctness()));
    let name = format!("topology/correctness_batch ({corr_n} nodes)");
    results.push(bench(&name, 2, it(50), || sim.correctness_batch()));
    // churn-heavy maintenance: the per-event splice + refresh cost that
    // replaces nothing (the batch path pays at sample time instead)
    let name = format!("topology/correctness_incremental_vs_batch churn x64 ({corr_n} nodes)");
    let mut next_id = corr_n as NodeId;
    results.push(bench(&name, 1, it(40), || {
        for i in 0..32u64 {
            sim.schedule_fail(sim.now + 1, (next_id + i) % corr_n as NodeId);
            sim.schedule_join(sim.now + 2, next_id + i, i % corr_n as NodeId);
        }
        next_id += 32;
        sim.run_until(sim.now + 3);
        sim.correctness()
    }));

    // --- MEP: fingerprint + CPU aggregation ---
    let dim: usize = if quick { 10_177 } else { 101_770 };
    let model: Vec<f32> = (0..dim).map(|i| i as f32 * 0.001).collect();
    let name = format!("mep/fingerprint ({dim} params)");
    results.push(bench(&name, 3, it(200), || fingerprint(&model)));
    let stack_models: Vec<Vec<f32>> = (0..7)
        .map(|k| model.iter().map(|v| v * (k as f32 + 1.0)).collect())
        .collect();
    let refs: Vec<&[f32]> = stack_models.iter().map(|m| m.as_slice()).collect();
    let weights = vec![1.0; 7];
    let name = format!("mep/aggregate_cpu (7 x {dim})");
    results.push(bench(&name, 3, it(100), || aggregate_cpu(&refs, &weights)));

    results
}

/// The artifact-execution benches (runtime layer). Split from
/// `micro_suite` so callers without artifacts can still run the rest.
pub fn engine_suite(engine: &Engine, quick: bool) -> Result<Vec<BenchResult>> {
    let it = |full: usize| if quick { (full / 10).max(2) } else { full };
    let mut results = Vec::new();
    let info = engine.manifest.task("mlp")?.clone();
    let k_max = engine.manifest.k_max;
    let params = engine.init("mlp", [1, 2])?;
    let scaled: Vec<Vec<f32>> = (0..7)
        .map(|k| params.iter().map(|v| v * (k as f32 + 1.0)).collect())
        .collect();
    let refs: Vec<&[f32]> = scaled.iter().map(|m| m.as_slice()).collect();
    let weights = vec![1.0; 7];
    let (stack, w) = pack_for_artifact(&refs, &weights, k_max);
    results.push(bench("runtime/agg artifact (Pallas weighted_agg)", 3, it(50), || {
        engine.aggregate("mlp", &stack, &w).unwrap()
    }));
    let task = GaussianTask::mnist_like(3);
    let b = task.test_batch(info.batch, 9);
    results.push(bench("runtime/train_step mlp (B=32)", 3, it(50), || {
        engine
            .train_step("mlp", &params, &XInput::F32(&b.x), &b.y, 0.1)
            .unwrap()
    }));
    results.push(bench("runtime/eval_step mlp (B=32)", 3, it(50), || {
        engine
            .eval_step("mlp", &params, &XInput::F32(&b.x), &b.y)
            .unwrap()
    }));
    let cnn_params = engine.init("cnn", [1, 2])?;
    let cnn_info = engine.manifest.task("cnn")?.clone();
    let cnn_task = GaussianTask::cifar_like(3);
    let cb = cnn_task.test_batch(cnn_info.batch, 9);
    results.push(bench("runtime/train_step cnn (B=32)", 3, it(50), || {
        engine
            .train_step("cnn", &cnn_params, &XInput::F32(&cb.x), &cb.y, 0.1)
            .unwrap()
    }));
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_suite_runs_and_names_are_unique() {
        let results = micro_suite(true);
        assert!(results.len() >= 8, "suite shrank to {}", results.len());
        let names: std::collections::HashSet<&str> =
            results.iter().map(|r| r.name.as_str()).collect();
        assert_eq!(names.len(), results.len(), "duplicate bench names");
        for r in &results {
            assert!(r.mean_s >= 0.0 && r.p99_s >= r.p50_s, "bad stats for {}", r.name);
        }
    }
}
