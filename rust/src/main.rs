//! FedLay launcher: the L3 binary entrypoint.

use fedlay::baselines;
use fedlay::bench_util::Table;
use fedlay::cli::{parse_args, Args, USAGE};
use fedlay::config::OverlayConfig;
use fedlay::dfl::{MethodSpec, Trainer};
use fedlay::ndmp::messages::MS;
use fedlay::net::{spawn, ClientNodeConfig, SchedTransport};
use fedlay::runtime::{find_artifacts_dir, Engine};
use fedlay::sim::{churn, Simulator};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match parse_args(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result = match args.command.as_str() {
        "topology" => cmd_topology(&args),
        "churn" => cmd_churn(&args),
        "train" => cmd_train(&args),
        "node" => cmd_node(&args),
        "help" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// `fedlay topology`: §II-B metrics for one named overlay.
fn cmd_topology(args: &Args) -> anyhow::Result<()> {
    let name = args.str("name", "fedlay");
    let n = args.usize("nodes", 300)?;
    let seed = args.u64("seed", 1)?;
    let m = baselines::evaluate_named(&name, n, seed)?;
    let mut t = Table::new(&[
        "topology", "nodes", "lambda", "conv.factor", "diameter", "aspl", "avg.deg",
    ]);
    t.row(&[
        name,
        n.to_string(),
        format!("{:.4}", m.lambda),
        format!("{:.1}", m.convergence_factor),
        m.diameter.to_string(),
        format!("{:.2}", m.avg_shortest_path),
        format!("{:.1}", m.avg_degree),
    ]);
    print!("{}", t.render());
    if !m.connected {
        println!("warning: topology is disconnected");
    }
    Ok(())
}

/// `fedlay churn`: Fig. 8-style resilience run with a correctness timeline.
fn cmd_churn(args: &Args) -> anyhow::Result<()> {
    let cfg = args.config()?;
    let initial = args.usize("initial", 100)?;
    let joins = args.usize("joins", 25)?;
    let fails = args.usize("fails", 0)?;
    let until = args.u64("until-ms", 120_000)? * MS;
    let mut sim = Simulator::new(cfg.overlay.clone(), cfg.net.clone());
    if joins > 0 {
        churn::mass_join(&mut sim, initial, joins, 10 * MS, cfg.net.seed);
    } else {
        churn::mass_fail(&mut sim, initial, fails, 10 * MS, cfg.net.seed);
    }
    churn::sample_correctness(&mut sim, until, until / 40);
    sim.run_until(until);
    let mut t = Table::new(&["t (s)", "correctness", "live nodes"]);
    for s in &sim.samples {
        t.row(&[
            format!("{:.1}", s.at as f64 / 1e6),
            format!("{:.4}", s.correctness),
            s.live_nodes.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!(
        "control messages/node: {:.1}   delivered: {}",
        sim.control_messages_per_node(),
        sim.delivered
    );
    Ok(())
}

/// `fedlay train`: one DFL method over the AOT runtime.
fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let cfg = args.config()?;
    let method = args.str("method", "fedlay");
    let minutes = args.u64("minutes", 30)?;
    let sample_minutes = args.u64("sample-minutes", 5)?;
    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &[&cfg.dfl.task])?;
    let n = cfg.dfl.clients;
    let spec = match method.as_str() {
        "fedlay" => MethodSpec::fedlay(n, cfg.overlay.spaces),
        "fedlay-dyn" => MethodSpec::fedlay_dynamic(cfg.overlay.clone(), cfg.net.clone()),
        "fedlay-sync" => MethodSpec::fedlay_sync(n, cfg.overlay.spaces),
        "fedlay-avg" => MethodSpec::fedlay_simple_avg(n, cfg.overlay.spaces),
        "fedavg" => MethodSpec::fedavg(),
        "gaia" => MethodSpec::gaia(n, 4),
        "dfl-dds" => MethodSpec::dfl_dds(cfg.dfl.seed),
        "chord" => MethodSpec::chord(n),
        "complete" => MethodSpec::complete(n),
        other => anyhow::bail!("unknown method {other:?}"),
    };
    let classes = engine.manifest.task(&cfg.dfl.task)?.classes;
    let weights =
        fedlay::data::shard_labels(n, classes, cfg.dfl.shards_per_client, cfg.dfl.seed);
    let mut trainer = Trainer::new(&engine, spec, cfg.dfl.clone(), weights)?;
    // message backend for the embedded overlay (fedlay-dyn only):
    // deterministic in-memory network, or real localhost TCP sockets
    let transport = args.str("transport", "sim");
    match transport.as_str() {
        "sim" => {}
        "tcp" => trainer.set_transport(Box::new(SchedTransport::new()))?,
        other => anyhow::bail!("unknown transport {other:?} (expected sim|tcp)"),
    }
    let until = minutes * 60 * 1_000_000;
    let every = (sample_minutes * 60 * 1_000_000).max(1);
    // mid-run churn (fedlay-dyn only: joins go through the NDMP protocol)
    let joins = args.usize("joins", 0)?;
    let fails = args.usize("fails", 0)?.min(n.saturating_sub(1));
    let churn_at = args.u64("churn-at-min", minutes / 2)? * 60 * 1_000_000;
    if fails > 0 {
        // fail the lowest ids so join bootstraps can avoid them
        for f in 0..fails {
            trainer.schedule_fail(churn_at, f);
        }
    }
    if joins > 0 {
        let w = fedlay::data::shard_labels(
            n + joins,
            classes,
            cfg.dfl.shards_per_client,
            cfg.dfl.seed ^ 1,
        );
        for j in 0..joins {
            // bootstrap through survivors only (ids >= fails)
            let boot = fails + j % (n - fails);
            trainer.schedule_join(churn_at, w[n + j].clone(), boot)?;
        }
    }
    trainer.run(until, every)?;
    let mut t = Table::new(&["t (min)", "mean acc", "mean loss"]);
    for s in &trainer.samples {
        t.row(&[
            format!("{:.1}", s.at as f64 / 60e6),
            format!("{:.4}", s.mean_accuracy),
            format!("{:.4}", s.mean_loss),
        ]);
    }
    print!("{}", t.render());
    let backend = trainer
        .overlay
        .as_ref()
        .map(|s| s.backend())
        .unwrap_or("none");
    println!(
        "method={}  clients={}  overlay transport={}  model MB/client: {:.2}  \
         train steps/client: {:.1}",
        method,
        n,
        backend,
        trainer.model_mb_per_client(),
        trainer.train_steps_per_client()
    );
    Ok(())
}

/// `fedlay node`: one real TCP client (prototype building block).
fn cmd_node(args: &Args) -> anyhow::Result<()> {
    let cfg = args.config()?;
    let id = args.u64("id", 0)?;
    let base_port = args.u64("base-port", 7400)? as u16;
    let bootstrap = args.flags.get("bootstrap").map(|v| v.parse::<u64>()).transpose()?;
    let run_ms = args.u64("run-ms", 30_000)?;
    let dir = find_artifacts_dir(None)?;
    let classes = 10;
    let weights = fedlay::data::shard_labels(
        (id + 1) as usize,
        classes,
        cfg.dfl.shards_per_client,
        cfg.dfl.seed,
    )
    .pop()
    .unwrap();
    let node_cfg = ClientNodeConfig {
        id,
        base_port,
        bootstrap,
        overlay: OverlayConfig {
            heartbeat_ms: 500,
            repair_probe_ms: 2_000,
            ..cfg.overlay.clone()
        },
        artifacts_dir: dir,
        task: cfg.dfl.task.clone(),
        label_weights: weights,
        lr: cfg.dfl.lr,
        local_steps: cfg.dfl.local_steps,
        period_ms: 2_000,
        seed: cfg.dfl.seed,
        book: None,
    };
    println!("node {id} listening on port {}", base_port + id as u16);
    let handle = spawn(node_cfg)?;
    std::thread::sleep(std::time::Duration::from_millis(run_ms));
    let report = handle.stop_and_join()?;
    println!(
        "node {} done: acc={:.3} loss={:.3} neighbors={} joined={} ctrl={} data={} dedup={}",
        report.id,
        report.accuracy,
        report.loss,
        report.neighbor_count,
        report.joined,
        report.control_sent,
        report.data_sent,
        report.dedup_skips
    );
    Ok(())
}
