//! FedLay launcher: the L3 binary entrypoint.

use fedlay::baselines;
use fedlay::bench_util;
use fedlay::check::{self, mutations, ExploreLimits, ModelConfig};
use fedlay::bench_util::{engine_suite, micro_suite, render_results, write_bench_json, Table};
use fedlay::cli::{parse_args, Args, USAGE};
use fedlay::config::{DflConfig, MultiTaskSpec, NetConfig, OverlayConfig};
use fedlay::dfl::{multitask, Aggregation, Compression, MethodSpec, Trainer};
use fedlay::ndmp::messages::MS;
use fedlay::net::{spawn, ClientNodeConfig, SchedTransport};
use fedlay::runtime::{find_artifacts_dir, Engine};
use fedlay::sim::{churn, ChurnOp, ScenarioReport, ScenarioSpec, Simulator, Transport};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match parse_args(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result = match args.command.as_str() {
        "topology" => args.no_positionals().and_then(|()| cmd_topology(&args)),
        "churn" => args.no_positionals().and_then(|()| cmd_churn(&args)),
        "scenario" => cmd_scenario(&args),
        "train" => args.no_positionals().and_then(|()| cmd_train(&args)),
        "node" => args.no_positionals().and_then(|()| cmd_node(&args)),
        "bench" => args.no_positionals().and_then(|()| cmd_bench(&args)),
        "check" => args.no_positionals().and_then(|()| cmd_check(&args)),
        "help" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// `fedlay topology`: §II-B metrics for one named overlay.
fn cmd_topology(args: &Args) -> anyhow::Result<()> {
    let name = args.str("name", "fedlay");
    let n = args.usize("nodes", 300)?;
    let seed = args.u64("seed", 1)?;
    let m = baselines::evaluate_named(&name, n, seed)?;
    let mut t = Table::new(&[
        "topology", "nodes", "lambda", "conv.factor", "diameter", "aspl", "avg.deg",
    ]);
    t.row(&[
        name,
        n.to_string(),
        format!("{:.4}", m.lambda),
        format!("{:.1}", m.convergence_factor),
        m.diameter.to_string(),
        format!("{:.2}", m.avg_shortest_path),
        format!("{:.1}", m.avg_degree),
    ]);
    print!("{}", t.render());
    if !m.connected {
        println!("warning: topology is disconnected");
    }
    Ok(())
}

/// `fedlay churn`: Fig. 8-style resilience run with a correctness timeline.
fn cmd_churn(args: &Args) -> anyhow::Result<()> {
    let cfg = args.config()?;
    let initial = args.usize("initial", 100)?;
    let joins = args.usize("joins", 25)?;
    let fails = args.usize("fails", 0)?;
    let until = args.u64("until-ms", 120_000)? * MS;
    let mut sim = Simulator::new(cfg.overlay.clone(), cfg.net.clone());
    if joins > 0 {
        churn::mass_join(&mut sim, initial, joins, 10 * MS, cfg.net.seed);
    } else {
        churn::mass_fail(&mut sim, initial, fails, 10 * MS, cfg.net.seed);
    }
    // 40 samples across the horizon; the sampler clamps the cadence to
    // >= 1 µs so sub-40-tick horizons (until / 40 == 0) stay finite
    churn::sample_correctness(&mut sim, until, until / 40);
    sim.run_until(until);
    let mut t = Table::new(&["t (s)", "correctness", "live nodes"]);
    for s in &sim.samples {
        t.row(&[
            format!("{:.1}", s.at as f64 / 1e6),
            format!("{:.4}", s.correctness),
            s.live_nodes.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!(
        "control messages/node: {:.1}   delivered: {}",
        sim.control_messages_per_node(),
        sim.delivered
    );
    Ok(())
}

/// `fedlay scenario`: run or inspect a declarative churn scenario
/// (`sim::scenario::ScenarioSpec`, TOML format in docs/scenarios.md).
fn cmd_scenario(args: &Args) -> anyhow::Result<()> {
    let action = args
        .positionals
        .first()
        .map(String::as_str)
        .ok_or_else(|| anyhow::anyhow!("usage: fedlay scenario <run|show> <spec.toml>"))?;
    let spec_path = args
        .positionals
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("scenario {action} needs a <spec.toml> path"))?;
    anyhow::ensure!(
        args.positionals.len() == 2,
        "unexpected positional argument {:?}",
        args.positionals[2]
    );
    // boolean flags greedily consume a following non-flag token; catch
    // `--trainer stray` style misparses instead of silently dropping the
    // flag and running a different mode
    for flag in ["trainer", "freeze"] {
        if let Some(v) = args.flags.get(flag) {
            anyhow::ensure!(
                v == "true",
                "--{flag} is a boolean flag; unexpected value {v:?} \
                 (put positionals before flags)"
            );
        }
    }
    // --tasks only makes sense for a training run; silently dropping it
    // would run a bare overlay simulation instead of the multi-task
    // experiment the user asked for
    anyhow::ensure!(
        args.bool("trainer") || args.flags.get("tasks").is_none(),
        "--tasks needs --trainer (a multi-task spec drives a training run)"
    );
    let mut spec = ScenarioSpec::load(std::path::Path::new(spec_path))?;
    apply_net_flags(args, &mut spec.net)?;
    match action {
        "show" => {
            print!("{}", spec.to_toml());
            let events = spec.compile();
            let mut t = Table::new(&["t (s)", "op", "node", "bootstrap"]);
            for e in &events {
                let (op, node, boot) = match e.op {
                    ChurnOp::Join { node, bootstrap } => ("join", node, bootstrap.to_string()),
                    ChurnOp::Fail { node } => ("fail", node, "-".into()),
                    ChurnOp::Leave { node } => ("leave", node, "-".into()),
                };
                t.row(&[
                    format!("{:.1}", e.at as f64 / 1e6),
                    op.to_string(),
                    node.to_string(),
                    boot,
                ]);
            }
            print!("{}", t.render());
            println!("{} events compiled", events.len());
            Ok(())
        }
        "run" => {
            if args.bool("trainer") {
                run_scenario_trainer(args, &spec)
            } else {
                let transport = scenario_transport(args, &spec.net)?;
                let (_, report) = spec.run_sim(transport)?;
                print!("{}", report.render());
                Ok(())
            }
        }
        other => anyhow::bail!("unknown scenario action {other:?} (expected run|show)"),
    }
}

/// Apply the link-model overrides (`--latency-ms`, `--jitter`,
/// `--bandwidth-mbps`, `--loss`, `--node-up-mbps`, `--node-down-mbps`).
/// Both transport backends honor the resulting `NetConfig` — the
/// in-memory network schedules deliveries with it, the TCP backend
/// stamps the same per-link delays into its wire frames and treats a
/// loss-lottery hit as a deliberate non-send (docs/transports.md).
fn apply_net_flags(args: &Args, net: &mut NetConfig) -> anyhow::Result<()> {
    net.latency_ms = args.f64("latency-ms", net.latency_ms)?;
    net.jitter = args.f64("jitter", net.jitter)?;
    net.bandwidth_mbps = args.f64("bandwidth-mbps", net.bandwidth_mbps)?;
    net.loss = args.f64("loss", net.loss)?;
    net.node_up_mbps = args.f64("node-up-mbps", net.node_up_mbps)?;
    net.node_down_mbps = args.f64("node-down-mbps", net.node_down_mbps)?;
    net.validate()
}

/// Parse the `--compression none|q8|topk:<keep>` wire-scheme flag.
fn compression_flag(args: &Args) -> anyhow::Result<Compression> {
    Compression::parse(&args.str("compression", "none"))
}

/// Parse the `--aggregation mean|trimmed:<beta>|median|krum:<f>` rule.
fn aggregation_flag(args: &Args) -> anyhow::Result<Aggregation> {
    Aggregation::parse(&args.str("aggregation", "mean"))
}

fn scenario_transport(args: &Args, net: &NetConfig) -> anyhow::Result<Option<Box<dyn Transport>>> {
    match args.str("transport", "sim").as_str() {
        "sim" => Ok(None),
        "tcp" => Ok(Some(Box::new(SchedTransport::new(net)))),
        other => anyhow::bail!("unknown transport {other:?} (expected sim|tcp)"),
    }
}

/// `scenario run --trainer`: drive a full fedlay-dyn training run whose
/// churn schedule comes from the scenario spec. With `--tasks
/// <spec.toml>` the run is multi-task: every task in the spec trains
/// over the one overlay the scenario churns.
fn run_scenario_trainer(args: &Args, spec: &ScenarioSpec) -> anyhow::Result<()> {
    if let Some(tasks_path) = args.flags.get("tasks") {
        let tasks = MultiTaskSpec::load(std::path::Path::new(tasks_path))?;
        let dir = find_artifacts_dir(None)?;
        let engine = Engine::load(&dir, &tasks.model_tasks())?;
        let base = DflConfig {
            clients: spec.initial,
            seed: spec.seed,
            ..DflConfig::default()
        };
        let method =
            MethodSpec::fedlay_multi(spec.overlay.clone(), spec.net.clone(), tasks.tasks.len())
                .with_compression(compression_flag(args)?)
                .with_aggregation(aggregation_flag(args)?);
        let report = multitask::run_scenario(
            &engine,
            spec,
            &tasks,
            method,
            base,
            args.bool("freeze"),
            scenario_transport(args, &spec.net)?,
        )?;
        print!("{}", report.render());
        return Ok(());
    }
    let task = args.str("task", "mlp");
    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &[&task])?;
    let classes = engine.manifest.task(&task)?.classes;
    let joins = spec
        .compile()
        .iter()
        .filter(|e| matches!(e.op, ChurnOp::Join { .. }))
        .count();
    let cfg = DflConfig {
        task: task.clone(),
        clients: spec.initial,
        seed: spec.seed,
        ..DflConfig::default()
    };
    let weights = fedlay::data::shard_labels(
        spec.initial + joins,
        classes,
        cfg.shards_per_client,
        cfg.seed,
    );
    let mut trainer = Trainer::new(
        &engine,
        MethodSpec::fedlay_dynamic(spec.overlay.clone(), spec.net.clone())
            .with_compression(compression_flag(args)?)
            .with_aggregation(aggregation_flag(args)?),
        cfg,
        weights[..spec.initial].to_vec(),
    )?;
    if let Some(t) = scenario_transport(args, &spec.net)? {
        trainer.set_transport(t)?;
    }
    trainer.freeze_training = args.bool("freeze");
    let report = spec.run_trainer(&mut trainer, |id| weights[id].clone())?;
    print!("{}", report.render());
    Ok(())
}

/// `fedlay train`: one DFL method over the AOT runtime. With `--tasks
/// <spec.toml>`, N independent model tasks train over one shared live
/// overlay (the multi-task engine).
fn cmd_train(args: &Args) -> anyhow::Result<()> {
    if let Some(tasks_path) = args.flags.get("tasks").cloned() {
        return cmd_train_multi(args, &tasks_path);
    }
    let mut cfg = args.config()?;
    apply_net_flags(args, &mut cfg.net)?;
    let method = args.str("method", "fedlay");
    let minutes = args.u64("minutes", 30)?;
    let sample_minutes = args.u64("sample-minutes", 5)?;
    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &[&cfg.dfl.task])?;
    let n = cfg.dfl.clients;
    let spec = match method.as_str() {
        "fedlay" => MethodSpec::fedlay(n, cfg.overlay.spaces),
        "fedlay-dyn" => MethodSpec::fedlay_dynamic(cfg.overlay.clone(), cfg.net.clone()),
        "fedlay-sync" => MethodSpec::fedlay_sync(n, cfg.overlay.spaces),
        "fedlay-avg" => MethodSpec::fedlay_simple_avg(n, cfg.overlay.spaces),
        "fedavg" => MethodSpec::fedavg(),
        "gaia" => MethodSpec::gaia(n, 4),
        "dfl-dds" => MethodSpec::dfl_dds(cfg.dfl.seed),
        "chord" => MethodSpec::chord(n),
        "complete" => MethodSpec::complete(n),
        other => anyhow::bail!("unknown method {other:?}"),
    };
    let spec = spec
        .with_compression(compression_flag(args)?)
        .with_aggregation(aggregation_flag(args)?);
    let classes = engine.manifest.task(&cfg.dfl.task)?.classes;
    let weights =
        fedlay::data::shard_labels(n, classes, cfg.dfl.shards_per_client, cfg.dfl.seed);
    let mut trainer = Trainer::new(&engine, spec, cfg.dfl.clone(), weights)?;
    // message backend for the embedded overlay (fedlay-dyn only):
    // deterministic in-memory network, or real localhost TCP sockets
    let transport = args.str("transport", "sim");
    match transport.as_str() {
        "sim" => {}
        "tcp" => trainer.set_transport(Box::new(SchedTransport::new(&cfg.net)))?,
        other => anyhow::bail!("unknown transport {other:?} (expected sim|tcp)"),
    }
    let until = minutes * 60 * 1_000_000;
    let every = (sample_minutes * 60 * 1_000_000).max(1);
    // mid-run churn (fedlay-dyn only: joins go through the NDMP protocol)
    let joins = args.usize("joins", 0)?;
    let fails = args.usize("fails", 0)?.min(n.saturating_sub(1));
    let churn_at = args.u64("churn-at-min", minutes / 2)? * 60 * 1_000_000;
    if fails > 0 {
        // fail the lowest ids so join bootstraps can avoid them
        for f in 0..fails {
            trainer.schedule_fail(churn_at, f);
        }
    }
    if joins > 0 {
        let w = fedlay::data::shard_labels(
            n + joins,
            classes,
            cfg.dfl.shards_per_client,
            cfg.dfl.seed ^ 1,
        );
        for j in 0..joins {
            // bootstrap through survivors only (ids >= fails)
            let boot = fails + j % (n - fails);
            trainer.schedule_join(churn_at, w[n + j].clone(), boot)?;
        }
    }
    trainer.run(until, every)?;
    let mut t = Table::new(&["t (min)", "mean acc", "mean loss"]);
    for s in trainer.samples() {
        t.row(&[
            format!("{:.1}", s.at as f64 / 60e6),
            format!("{:.4}", s.mean_accuracy),
            format!("{:.4}", s.mean_loss),
        ]);
    }
    print!("{}", t.render());
    let backend = trainer
        .overlay
        .as_ref()
        .map(|s| s.backend())
        .unwrap_or("none");
    println!(
        "method={}  clients={}  overlay transport={}  model MB/client: {:.2}  \
         train steps/client: {:.1}",
        method,
        n,
        backend,
        trainer.model_mb_per_client(),
        trainer.train_steps_per_client()
    );
    Ok(())
}

/// `fedlay train --tasks <spec.toml>`: the multi-task engine — every
/// task in the spec trains concurrently over one shared live NDMP
/// overlay, and the run reports one accuracy column per task.
fn cmd_train_multi(args: &Args, tasks_path: &str) -> anyhow::Result<()> {
    let mut cfg = args.config()?;
    apply_net_flags(args, &mut cfg.net)?;
    let spec = MultiTaskSpec::load(std::path::Path::new(tasks_path))?;
    let method = args.str("method", "fedlay-multi");
    anyhow::ensure!(
        method == "fedlay-multi" || method == "fedlay-dyn",
        "--tasks runs on the live overlay (expected method fedlay-multi|fedlay-dyn, got {method:?})"
    );
    let minutes = args.u64("minutes", 30)?;
    let sample_minutes = args.u64("sample-minutes", 5)?;
    let dir = find_artifacts_dir(None)?;
    let engine = Engine::load(&dir, &spec.model_tasks())?;
    let n = cfg.dfl.clients;
    let joins = args.usize("joins", 0)?;
    let fails = args.usize("fails", 0)?.min(n.saturating_sub(1));
    let churn_at = args.u64("churn-at-min", minutes / 2)? * 60 * 1_000_000;
    let mspec = MethodSpec::fedlay_multi(cfg.overlay.clone(), cfg.net.clone(), spec.tasks.len())
        .with_compression(compression_flag(args)?)
        .with_aggregation(aggregation_flag(args)?);
    let (mut trainer, tables) =
        multitask::build_trainer(&engine, mspec, cfg.dfl.clone(), &spec, n + joins)?;
    match args.str("transport", "sim").as_str() {
        "sim" => {}
        "tcp" => trainer.set_transport(Box::new(SchedTransport::new(&cfg.net)))?,
        other => anyhow::bail!("unknown transport {other:?} (expected sim|tcp)"),
    }
    // mid-run churn: fail the lowest ids so join bootstraps can avoid them
    for f in 0..fails {
        trainer.schedule_fail(churn_at, f);
    }
    for j in 0..joins {
        let boot = fails + j % (n - fails);
        let per_lane: Vec<Vec<f64>> = tables.iter().map(|t| t[n + j].clone()).collect();
        trainer.schedule_join_tasks(churn_at, per_lane, boot)?;
    }
    let until = minutes * 60 * 1_000_000;
    let every = (sample_minutes * 60 * 1_000_000).max(1);
    trainer.run(until, every)?;
    let series: Vec<(String, Vec<(u64, f64)>)> = trainer
        .lanes
        .iter()
        .map(|l| {
            (
                l.spec.name.clone(),
                l.samples.iter().map(|s| (s.at, s.mean_accuracy)).collect(),
            )
        })
        .collect();
    print!("{}", ScenarioReport::task_accuracy_table(&series).render());
    let backend = trainer
        .overlay
        .as_ref()
        .map(|s| s.backend())
        .unwrap_or("none");
    println!(
        "method={}  tasks={}  clients={}  overlay transport={}  model MB/client: {:.2}  \
         train steps/client: {:.1}",
        trainer.spec.name,
        trainer.lanes.len(),
        n,
        backend,
        trainer.model_mb_per_client(),
        trainer.train_steps_per_client()
    );
    Ok(())
}

/// `fedlay bench`: the perf micro-suite (`bench_util::suite`), printed
/// as a table and persisted to `BENCH_micro.json` for the CI perf
/// artifact (docs/perf.md). Runtime benches are skipped when no
/// artifact directory is found so the suite works on a bare checkout.
fn cmd_bench(args: &Args) -> anyhow::Result<()> {
    let quick = args.bool("quick");
    let out = std::path::PathBuf::from(args.str("out", "."));
    let mut results = micro_suite(quick);
    match find_artifacts_dir(None).and_then(|dir| Engine::load(&dir, &["mlp", "cnn"])) {
        Ok(engine) => results.extend(engine_suite(&engine, quick)?),
        Err(e) => eprintln!("skipping runtime benches (no artifacts): {e}"),
    }
    print!("{}", render_results(&results));
    let path = write_bench_json(&out, "micro", &results)?;
    println!("wrote {}", path.display());
    // --compare <prev.json>: per-entry delta table against a previous
    // run (the committed seed baseline in CI); regressions above
    // --fail-ratio on the gated hot-path entries (event queue,
    // correctness) fail the command so the trajectory can gate merges.
    if let Some(prev) = args.flags.get("compare") {
        let fail_ratio = args.f64("fail-ratio", 2.0)?;
        anyhow::ensure!(fail_ratio > 0.0, "--fail-ratio must be positive");
        let baseline = bench_util::read_bench_json(std::path::Path::new(prev))?;
        let (table, regressions) = bench_util::compare_results(&baseline, &results, fail_ratio);
        println!("\ndelta vs {prev} (gate: mean > {fail_ratio:.2}x baseline)");
        print!("{}", table.render());
        anyhow::ensure!(
            regressions.is_empty(),
            "bench regression on gated entries:\n  {}",
            regressions.join("\n  ")
        );
    }
    Ok(())
}

/// `fedlay check`: exhaustive model checking of the NDMP protocols
/// (`check::explore`, design in docs/model-checking.md). With
/// `--mutation` the scenario sizing defaults to that mutation's
/// guaranteed-detection configuration, and `--expect-violation` inverts
/// the exit semantics: *not* catching the injected bug is the failure.
fn cmd_check(args: &Args) -> anyhow::Result<()> {
    let mutation_name = args.str("mutation", "none");
    let mutation = mutations::parse(&mutation_name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown mutation {mutation_name:?} (expected none|no-probes|adopt-farther|\
             flip-repair-sides|adopt-untracked)"
        )
    })?;
    let base = mutations::detection_config(mutation);
    let cfg = ModelConfig {
        n: args.usize("n", base.n)?,
        spaces: args.usize("spaces", base.spaces)?,
        joins: args.usize("joins", base.joins)?,
        fails: args.usize("fails", base.fails)?,
        leaves: args.usize("leaves", base.leaves)?,
        mutation,
    };
    let defaults = ExploreLimits::default();
    let limits = ExploreLimits {
        max_depth: args.u64("max-depth", defaults.max_depth as u64)? as u32,
        max_states: args.usize("max-states", defaults.max_states)?,
    };
    println!(
        "model checking NDMP: n={} spaces={} joins={} fails={} leaves={} mutation={}",
        cfg.n,
        cfg.spaces,
        cfg.joins,
        cfg.fails,
        cfg.leaves,
        mutations::name(mutation)
    );
    if mutation != fedlay::ndmp::Mutation::None {
        println!("injected fault: {}", mutations::describe(mutation));
    }
    let report = check::explore(&cfg, &limits)?;
    println!("{report}");
    for (i, cx) in report.counterexamples.iter().enumerate() {
        println!(
            "\ncounterexample {} of {} ({}, depth {}) — replayable schedule:",
            i + 1,
            report.counterexamples.len(),
            cx.kind,
            cx.depth
        );
        for v in &cx.violations {
            println!("# violated {v}");
        }
        if cx.schedule.is_empty() {
            println!("# (initial state)");
        }
        print!("{}", check::format_schedule(&cx.schedule));
    }
    if args.bool("expect-violation") {
        anyhow::ensure!(
            !report.ok(),
            "mutation {:?} was NOT caught — the checker has lost detection power",
            mutations::name(mutation)
        );
        let first = &report.counterexamples[0];
        let expected = mutations::expected_kind(mutation);
        anyhow::ensure!(
            first.kind == expected,
            "mutation {:?} caught as {} but {} was expected",
            mutations::name(mutation),
            first.kind,
            expected
        );
        println!("\ninjected violation detected as {expected}, as required");
    } else {
        anyhow::ensure!(
            report.ok(),
            "{} safety, {} liveness, {} deadlock violations found",
            report.safety_violation_count,
            report.liveness_violation_count,
            report.deadlock_count
        );
        println!("\nno violations");
    }
    Ok(())
}

/// `fedlay node`: one real TCP client (prototype building block).
fn cmd_node(args: &Args) -> anyhow::Result<()> {
    let cfg = args.config()?;
    let id = args.u64("id", 0)?;
    let base_port = args.u64("base-port", 7400)? as u16;
    let bootstrap = args.flags.get("bootstrap").map(|v| v.parse::<u64>()).transpose()?;
    let run_ms = args.u64("run-ms", 30_000)?;
    let dir = find_artifacts_dir(None)?;
    let classes = 10;
    let weights = fedlay::data::shard_labels(
        (id + 1) as usize,
        classes,
        cfg.dfl.shards_per_client,
        cfg.dfl.seed,
    )
    .pop()
    .unwrap();
    let node_cfg = ClientNodeConfig {
        id,
        base_port,
        bootstrap,
        overlay: OverlayConfig {
            heartbeat_ms: 500,
            repair_probe_ms: 2_000,
            ..cfg.overlay.clone()
        },
        artifacts_dir: dir,
        task: cfg.dfl.task.clone(),
        task_id: 0,
        label_weights: weights,
        lr: cfg.dfl.lr,
        local_steps: cfg.dfl.local_steps,
        period_ms: 2_000,
        compression: compression_flag(args)?,
        aggregation: aggregation_flag(args)?,
        seed: cfg.dfl.seed,
        book: None,
    };
    println!("node {id} listening on port {}", base_port + id as u16);
    let handle = spawn(node_cfg)?;
    std::thread::sleep(std::time::Duration::from_millis(run_ms));
    let report = handle.stop_and_join()?;
    println!(
        "node {} done: acc={:.3} loss={:.3} neighbors={} joined={} ctrl={} data={} dedup={}",
        report.id,
        report.accuracy,
        report.loss,
        report.neighbor_count,
        report.joined,
        report.control_sent,
        report.data_sent,
        report.dedup_skips
    );
    Ok(())
}
