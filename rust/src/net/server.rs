//! Inbound TCP listener: accepts peer connections and pumps decoded
//! frames into an mpsc channel consumed by the node's protocol loop.

use super::wire::{self, Frame};
use anyhow::Result;
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

pub struct Listener {
    pub addr: SocketAddr,
    /// Decoded inbound frames, timing stamps included (see `net::wire`).
    pub rx: Receiver<Frame>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl Listener {
    /// Bind and start accepting. Each connection gets a reader thread that
    /// decodes frames until EOF/error.
    pub fn start(addr: SocketAddr) -> Result<Listener> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let (tx, rx) = channel::<Frame>();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let accept_thread = std::thread::spawn(move || {
            accept_loop(listener, tx, stop2);
        });
        Ok(Listener {
            addr: local,
            rx,
            stop,
            accept_thread: Some(accept_thread),
        })
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Listener {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, tx: Sender<Frame>, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let tx = tx.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut stream = stream;
                    let _ = stream.set_nodelay(true);
                    // Blocking reads: a mid-frame timeout would desync the
                    // framing (model payloads span many segments), so the
                    // reader blocks until a full frame, EOF, or a decode
                    // error. Peers closing their connections at shutdown
                    // unblocks the thread.
                    loop {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        match wire::read_frame(&mut stream) {
                            Ok(frame) => {
                                if tx.send(frame).is_err() {
                                    break;
                                }
                            }
                            Err(_) => break, // EOF or corrupt frame
                        }
                    }
                });
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                // Short nap: first-contact latency gates how fast the
                // scheduler-driven transport can settle a virtual instant.
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            Err(_) => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ndmp::messages::Msg;
    use crate::net::peer::PeerPool;

    #[test]
    fn frames_flow_end_to_end() {
        // bind on an OS-assigned port
        let mut l = Listener::start(SocketAddr::from(([127, 0, 0, 1], 0))).unwrap();
        let port = l.addr.port();
        // a PeerPool whose addr_of(base_port, id) hits our listener: use
        // base_port = port - id with id = 0
        let pool = PeerPool::new(port, 9);
        pool.send(0, &Msg::Heartbeat);
        let stamp = wire::Stamp {
            seq: 4,
            sent_at: 12_000,
            delay: 350,
        };
        pool.send_stamped(
            0,
            stamp,
            &Msg::ModelOffer {
                task: 0,
                fingerprint: 123,
                confidence: 0.5,
                version: 7,
            },
        );
        let f1 = l.rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        let f2 = l.rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        assert_eq!(f1.sender, 9);
        assert_eq!(f1.msg, Msg::Heartbeat);
        assert_eq!(f1.stamp, wire::Stamp::default());
        assert_eq!(f2.sender, 9);
        assert_eq!(f2.stamp, stamp);
        assert!(matches!(f2.msg, Msg::ModelOffer { fingerprint: 123, .. }));
        l.shutdown();
    }
}
