//! Wire codec for NDMP/MEP messages over TCP.
//!
//! Frame format (all integers big-endian):
//!
//! ```text
//! [0xFD magic u8][sender u64][seq u64][sent_at u64][delay u64][type u8][len u32][payload ...]
//! ```
//!
//! `sent_at` is the *virtual* send time in microseconds on the sender's
//! scheduler clock, and `delay` the virtual one-way link delay sampled
//! at send time (`sim::network::LinkDelay`): the receiver releases the
//! frame into its event loop at `sent_at + delay`, which is what lets
//! the scheduler-driven TCP backend reproduce the in-memory backend's
//! arrival timestamps exactly (see `docs/transports.md`). `seq` is the
//! sender-side global send sequence, the canonical tie-breaker when two
//! frames fall due at the same virtual instant. Wall-clock nodes
//! (`net::client_node`) have no virtual clock and stamp zeros.
//!
//! The payload layout per message type mirrors `Msg`'s fields in order.
//! Coordinates never travel (they are hash-derived from node ids).

use crate::ndmp::messages::{Dir, Msg, Side, Time};
use crate::topology::NodeId;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

pub const MAGIC: u8 = 0xFD;

/// Total bytes before the payload: magic + sender + seq + sent_at +
/// delay + type + length.
pub const HEAD_LEN: usize = 1 + 8 + 8 + 8 + 8 + 1 + 4;

/// Virtual timing stamps carried by every frame (zeros from wall-clock
/// senders, which have no virtual clock).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Stamp {
    /// Sender-side global send sequence: orders frames that fall due at
    /// the same virtual instant exactly like the in-memory backend's
    /// event-queue insertion order.
    pub seq: u64,
    /// Virtual send time (µs) on the sender's scheduler clock.
    pub sent_at: Time,
    /// Virtual one-way delay (µs) sampled at send time.
    pub delay: Time,
}

impl Stamp {
    /// The frame's virtual due time (saturating: wall-clock zero stamps
    /// stay 0).
    pub fn due(&self) -> Time {
        self.sent_at.saturating_add(self.delay)
    }
}

/// One decoded frame: the sender, its virtual timing stamps, and the
/// message.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub sender: NodeId,
    pub stamp: Stamp,
    pub msg: Msg,
}

const T_DISCOVERY: u8 = 1;
const T_DISCOVERY_RESULT: u8 = 2;
const T_ADJ_UPDATE: u8 = 3;
const T_LEAVE: u8 = 4;
const T_HEARTBEAT: u8 = 5;
const T_REPAIR: u8 = 6;
const T_REPAIR_STOP: u8 = 7;
const T_MODEL_OFFER: u8 = 8;
const T_MODEL_REQUEST: u8 = 9;
const T_MODEL_PAYLOAD: u8 = 10;
const T_MODEL_PAYLOAD_Q8: u8 = 11;
const T_MODEL_PAYLOAD_TOPK: u8 = 12;

/// The frame head's length field is a `u32`, so this is the largest
/// payload the format can carry. Payloads past it must fail loudly at
/// encode time: a bare `as u32` cast would silently truncate the length
/// and desynchronize every frame behind it on the stream.
pub const MAX_PAYLOAD_LEN: usize = u32::MAX as usize;

fn payload_len_u32(len: usize) -> Result<u32> {
    if len > MAX_PAYLOAD_LEN {
        bail!("payload of {len} bytes exceeds the u32 frame length field (max {MAX_PAYLOAD_LEN})");
    }
    Ok(len as u32)
}

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new() -> Self {
        Self { buf: Vec::new() }
    }
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn i8(&mut self, v: i8) {
        self.buf.push(v as u8);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_be_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_be_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_be_bytes());
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated payload");
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn i8(&mut self) -> Result<i8> {
        Ok(self.take(1)?[0] as i8)
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_be_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_be_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_be_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
    /// Bytes left — bounds `Vec::with_capacity` on decode so a forged
    /// element count cannot force a huge up-front allocation.
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

fn side_byte(s: Side) -> u8 {
    match s {
        Side::Prev => 0,
        Side::Next => 1,
    }
}

fn byte_side(b: u8) -> Result<Side> {
    match b {
        0 => Ok(Side::Prev),
        1 => Ok(Side::Next),
        _ => bail!("bad side byte {b}"),
    }
}

fn dir_byte(d: Dir) -> u8 {
    match d {
        Dir::Ccw => 0,
        Dir::Cw => 1,
    }
}

fn byte_dir(b: u8) -> Result<Dir> {
    match b {
        0 => Ok(Dir::Ccw),
        1 => Ok(Dir::Cw),
        _ => bail!("bad dir byte {b}"),
    }
}

/// Serialize one message into a framed byte vector, stamped with its
/// send sequence, virtual send time, and sampled link delay
/// (`Stamp::default()` for wall-clock senders).
///
/// Errors when the payload cannot be framed: longer than
/// [`MAX_PAYLOAD_LEN`], or a `ModelPayloadTopK` whose index and value
/// vectors disagree in length (the wire format carries one count for
/// both).
pub fn encode(sender: NodeId, stamp: Stamp, msg: &Msg) -> Result<Vec<u8>> {
    let mut w = Writer::new();
    let ty = match msg {
        Msg::NeighborDiscovery { joiner, space } => {
            w.u64(*joiner);
            w.u32(*space);
            T_DISCOVERY
        }
        Msg::DiscoveryResult { space, prev, next } => {
            w.u32(*space);
            w.u64(*prev);
            w.u64(*next);
            T_DISCOVERY_RESULT
        }
        Msg::AdjacentUpdate { space, side, node } => {
            w.u32(*space);
            w.u8(side_byte(*side));
            w.u64(*node);
            T_ADJ_UPDATE
        }
        Msg::Leave { space, side, other } => {
            w.u32(*space);
            w.u8(side_byte(*side));
            w.u64(*other);
            T_LEAVE
        }
        Msg::Heartbeat => T_HEARTBEAT,
        Msg::NeighborRepair {
            origin,
            target,
            space,
            dir,
        } => {
            w.u64(*origin);
            w.u64(*target);
            w.u32(*space);
            w.u8(dir_byte(*dir));
            T_REPAIR
        }
        Msg::RepairStop { space, dir } => {
            w.u32(*space);
            w.u8(dir_byte(*dir));
            T_REPAIR_STOP
        }
        Msg::ModelOffer {
            task,
            fingerprint,
            confidence,
            version,
        } => {
            w.u32(*task);
            w.u64(*fingerprint);
            w.f32(*confidence);
            w.u64(*version);
            T_MODEL_OFFER
        }
        Msg::ModelRequest { task, version } => {
            w.u32(*task);
            w.u64(*version);
            T_MODEL_REQUEST
        }
        Msg::ModelPayload {
            task,
            version,
            confidence,
            params,
        } => {
            w.u32(*task);
            w.u64(*version);
            w.f32(*confidence);
            w.u32(params.len() as u32);
            for p in params {
                w.f32(*p);
            }
            T_MODEL_PAYLOAD
        }
        Msg::ModelPayloadQ8 {
            task,
            version,
            confidence,
            scale,
            levels,
        } => {
            w.u32(*task);
            w.u64(*version);
            w.f32(*confidence);
            w.f32(*scale);
            w.u32(payload_len_u32(levels.len())?);
            for l in levels {
                w.i8(*l);
            }
            T_MODEL_PAYLOAD_Q8
        }
        Msg::ModelPayloadTopK {
            task,
            version,
            confidence,
            dim,
            indices,
            values,
        } => {
            if indices.len() != values.len() {
                bail!(
                    "top-k payload with {} indices but {} values",
                    indices.len(),
                    values.len()
                );
            }
            w.u32(*task);
            w.u64(*version);
            w.f32(*confidence);
            w.u32(*dim);
            w.u32(payload_len_u32(indices.len())?);
            for i in indices {
                w.u32(*i);
            }
            for v in values {
                w.f32(*v);
            }
            T_MODEL_PAYLOAD_TOPK
        }
    };
    let payload = w.buf;
    let len = payload_len_u32(payload.len())?;
    let mut frame = Vec::with_capacity(HEAD_LEN + payload.len());
    frame.push(MAGIC);
    frame.extend_from_slice(&sender.to_be_bytes());
    frame.extend_from_slice(&stamp.seq.to_be_bytes());
    frame.extend_from_slice(&stamp.sent_at.to_be_bytes());
    frame.extend_from_slice(&stamp.delay.to_be_bytes());
    frame.push(ty);
    frame.extend_from_slice(&len.to_be_bytes());
    frame.extend_from_slice(&payload);
    Ok(frame)
}

/// Decode one payload given its type byte.
fn decode_payload(ty: u8, payload: &[u8]) -> Result<Msg> {
    let mut r = Reader::new(payload);
    let msg = match ty {
        T_DISCOVERY => Msg::NeighborDiscovery {
            joiner: r.u64()?,
            space: r.u32()?,
        },
        T_DISCOVERY_RESULT => Msg::DiscoveryResult {
            space: r.u32()?,
            prev: r.u64()?,
            next: r.u64()?,
        },
        T_ADJ_UPDATE => Msg::AdjacentUpdate {
            space: r.u32()?,
            side: byte_side(r.u8()?)?,
            node: r.u64()?,
        },
        T_LEAVE => Msg::Leave {
            space: r.u32()?,
            side: byte_side(r.u8()?)?,
            other: r.u64()?,
        },
        T_HEARTBEAT => Msg::Heartbeat,
        T_REPAIR => Msg::NeighborRepair {
            origin: r.u64()?,
            target: r.u64()?,
            space: r.u32()?,
            dir: byte_dir(r.u8()?)?,
        },
        T_REPAIR_STOP => Msg::RepairStop {
            space: r.u32()?,
            dir: byte_dir(r.u8()?)?,
        },
        T_MODEL_OFFER => Msg::ModelOffer {
            task: r.u32()?,
            fingerprint: r.u64()?,
            confidence: r.f32()?,
            version: r.u64()?,
        },
        T_MODEL_REQUEST => Msg::ModelRequest {
            task: r.u32()?,
            version: r.u64()?,
        },
        T_MODEL_PAYLOAD => {
            let task = r.u32()?;
            let version = r.u64()?;
            let confidence = r.f32()?;
            let n = r.u32()? as usize;
            let mut params = Vec::with_capacity(n.min(r.remaining() / 4));
            for _ in 0..n {
                params.push(r.f32()?);
            }
            Msg::ModelPayload {
                task,
                version,
                confidence,
                params,
            }
        }
        T_MODEL_PAYLOAD_Q8 => {
            let task = r.u32()?;
            let version = r.u64()?;
            let confidence = r.f32()?;
            let scale = r.f32()?;
            let n = r.u32()? as usize;
            let mut levels = Vec::with_capacity(n.min(r.remaining()));
            for _ in 0..n {
                levels.push(r.i8()?);
            }
            Msg::ModelPayloadQ8 {
                task,
                version,
                confidence,
                scale,
                levels,
            }
        }
        T_MODEL_PAYLOAD_TOPK => {
            let task = r.u32()?;
            let version = r.u64()?;
            let confidence = r.f32()?;
            let dim = r.u32()?;
            let n = r.u32()? as usize;
            let mut indices = Vec::with_capacity(n.min(r.remaining() / 8));
            for _ in 0..n {
                indices.push(r.u32()?);
            }
            let mut values = Vec::with_capacity(n.min(r.remaining() / 4));
            for _ in 0..n {
                values.push(r.f32()?);
            }
            Msg::ModelPayloadTopK {
                task,
                version,
                confidence,
                dim,
                indices,
                values,
            }
        }
        _ => bail!("unknown message type {ty}"),
    };
    if !r.done() {
        bail!("trailing bytes in payload of type {ty}");
    }
    Ok(msg)
}

/// Read one frame from a stream.
pub fn read_frame(stream: &mut impl Read) -> Result<Frame> {
    let mut head = [0u8; HEAD_LEN];
    stream.read_exact(&mut head).context("reading frame head")?;
    if head[0] != MAGIC {
        bail!("bad magic byte {:#x}", head[0]);
    }
    let sender = u64::from_be_bytes(head[1..9].try_into().unwrap());
    let stamp = Stamp {
        seq: u64::from_be_bytes(head[9..17].try_into().unwrap()),
        sent_at: u64::from_be_bytes(head[17..25].try_into().unwrap()),
        delay: u64::from_be_bytes(head[25..33].try_into().unwrap()),
    };
    let ty = head[33];
    let len = u32::from_be_bytes(head[34..38].try_into().unwrap()) as usize;
    if len > 512 * 1024 * 1024 {
        bail!("frame too large: {len}");
    }
    let mut payload = vec![0u8; len];
    stream.read_exact(&mut payload).context("reading payload")?;
    Ok(Frame {
        sender,
        stamp,
        msg: decode_payload(ty, &payload)?,
    })
}

/// Write one frame to a stream.
pub fn write_frame(
    stream: &mut impl Write,
    sender: NodeId,
    stamp: Stamp,
    msg: &Msg,
) -> Result<()> {
    let frame = encode(sender, stamp, msg).context("encoding frame")?;
    stream.write_all(&frame).context("writing frame")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Msg) {
        roundtrip_from(42, msg);
    }

    fn roundtrip_from(sender: NodeId, msg: Msg) {
        let stamp = Stamp {
            seq: 3,
            sent_at: 7_000,
            delay: 350,
        };
        let frame = encode(sender, stamp, &msg).unwrap();
        let mut cursor = std::io::Cursor::new(frame);
        let got = read_frame(&mut cursor).unwrap();
        assert_eq!(got.sender, sender);
        assert_eq!(got.stamp, stamp);
        assert_eq!(got.msg, msg);
    }

    /// One instance of every `Msg` variant, with edge-leaning field
    /// values (max ids, zero ids, empty and extreme parameter vectors).
    fn all_variants() -> Vec<Msg> {
        vec![
            Msg::NeighborDiscovery { joiner: 7, space: 2 },
            Msg::NeighborDiscovery {
                joiner: u64::MAX,
                space: u32::MAX,
            },
            Msg::DiscoveryResult {
                space: 1,
                prev: 3,
                next: 9,
            },
            Msg::AdjacentUpdate {
                space: 0,
                side: Side::Next,
                node: 5,
            },
            Msg::AdjacentUpdate {
                space: 1,
                side: Side::Prev,
                node: 0,
            },
            Msg::Leave {
                space: 3,
                side: Side::Prev,
                other: 11,
            },
            Msg::Heartbeat,
            Msg::NeighborRepair {
                origin: 1,
                target: 2,
                space: 4,
                dir: Dir::Cw,
            },
            Msg::NeighborRepair {
                origin: u64::MAX,
                target: 0,
                space: 0,
                dir: Dir::Ccw,
            },
            Msg::RepairStop {
                space: 2,
                dir: Dir::Ccw,
            },
            Msg::RepairStop {
                space: 2,
                dir: Dir::Cw,
            },
            Msg::ModelOffer {
                task: 0,
                fingerprint: 0xDEAD_BEEF,
                confidence: 0.75,
                version: 9,
            },
            Msg::ModelOffer {
                task: u32::MAX,
                fingerprint: u64::MAX,
                confidence: 0.0,
                version: 0,
            },
            Msg::ModelRequest { task: 0, version: 4 },
            Msg::ModelRequest {
                task: u32::MAX,
                version: u64::MAX,
            },
            Msg::ModelPayload {
                task: 1,
                version: 8,
                confidence: 0.5,
                params: vec![1.0, -2.5, 3.25],
            },
            Msg::ModelPayload {
                task: 0,
                version: 0,
                confidence: 0.0,
                params: Vec::new(),
            },
            Msg::ModelPayload {
                task: 7,
                version: 1,
                confidence: 1.0,
                params: vec![f32::MAX, f32::MIN, f32::INFINITY, f32::NEG_INFINITY, 0.0],
            },
            Msg::ModelPayloadQ8 {
                task: 2,
                version: 5,
                confidence: 0.25,
                scale: 0.01,
                levels: vec![0, 1, -1, i8::MAX, i8::MIN],
            },
            Msg::ModelPayloadQ8 {
                task: 0,
                version: 0,
                confidence: 0.0,
                scale: 0.0,
                levels: Vec::new(),
            },
            Msg::ModelPayloadTopK {
                task: 3,
                version: 6,
                confidence: 0.75,
                dim: 10,
                indices: vec![0, 4, 9],
                values: vec![1.5, -2.0, 0.125],
            },
            Msg::ModelPayloadTopK {
                task: u32::MAX,
                version: u64::MAX,
                confidence: 1.0,
                dim: 0,
                indices: Vec::new(),
                values: Vec::new(),
            },
        ]
    }

    #[test]
    fn roundtrip_all_variants() {
        for msg in all_variants() {
            roundtrip(msg);
        }
    }

    #[test]
    fn roundtrip_sender_extremes() {
        roundtrip_from(0, Msg::Heartbeat);
        roundtrip_from(u64::MAX, Msg::ModelRequest { task: 0, version: 1 });
    }

    /// The virtual timing stamps survive the wire bit-exactly — the TCP
    /// backend's arrival timestamps are computed from them, so a lossy
    /// stamp would silently desynchronize the two transports.
    #[test]
    fn timing_stamps_roundtrip() {
        for (seq, sent_at, delay) in [
            (0u64, 0u64, 0u64),
            (1, 1, 1),
            (u64::MAX, u64::MAX, u64::MAX),
            (42, 90_000_000, 350_123),
        ] {
            let stamp = Stamp { seq, sent_at, delay };
            let frame = encode(9, stamp, &Msg::Heartbeat).unwrap();
            let got = read_frame(&mut std::io::Cursor::new(frame)).unwrap();
            assert_eq!(got.stamp, stamp);
        }
        // frames differing only in one stamp field must not encode
        // identically
        let base = Stamp {
            seq: 2,
            sent_at: 5,
            delay: 10,
        };
        let a = encode(1, base, &Msg::Heartbeat).unwrap();
        let b = encode(1, Stamp { delay: 11, ..base }, &Msg::Heartbeat).unwrap();
        let c = encode(1, Stamp { sent_at: 6, ..base }, &Msg::Heartbeat).unwrap();
        let d = encode(1, Stamp { seq: 3, ..base }, &Msg::Heartbeat).unwrap();
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, d);
        // due() is the stamped sum, saturating at the top
        assert_eq!(base.due(), 15);
        assert_eq!(
            Stamp {
                seq: 0,
                sent_at: u64::MAX,
                delay: 2
            }
            .due(),
            u64::MAX
        );
    }

    /// The task id survives the wire bit-exactly on every MEP message —
    /// the multi-task engine relies on frames never migrating between
    /// tasks.
    #[test]
    fn task_tags_roundtrip_distinctly() {
        for task in [0u32, 1, 2, 41, u32::MAX] {
            roundtrip(Msg::ModelOffer {
                task,
                fingerprint: 5,
                confidence: 0.5,
                version: 2,
            });
            roundtrip(Msg::ModelRequest { task, version: 2 });
            roundtrip(Msg::ModelPayload {
                task,
                version: 2,
                confidence: 0.5,
                params: vec![1.0, 2.0],
            });
        }
        // two frames differing only in task must not encode identically
        let a = encode(1, Stamp::default(), &Msg::ModelRequest { task: 0, version: 9 }).unwrap();
        let b = encode(1, Stamp::default(), &Msg::ModelRequest { task: 1, version: 9 }).unwrap();
        assert_ne!(a, b);
    }

    /// Every strict prefix of every variant's frame must fail to decode
    /// — no truncation may be silently accepted as a shorter message.
    #[test]
    fn truncation_at_every_byte_errors() {
        for msg in all_variants() {
            let frame = encode(3, Stamp { seq: 1, sent_at: 1_000, delay: 50 }, &msg).unwrap();
            for cut in 0..frame.len() {
                let mut cursor = std::io::Cursor::new(&frame[..cut]);
                assert!(
                    read_frame(&mut cursor).is_err(),
                    "cut at {cut}/{} decoded for {msg:?}",
                    frame.len()
                );
            }
        }
    }

    /// A frame whose length field covers more bytes than its payload
    /// layout uses must be rejected (trailing garbage, not ignored).
    #[test]
    fn rejects_trailing_payload_bytes() {
        for msg in [Msg::Heartbeat, Msg::ModelRequest { task: 0, version: 2 }] {
            let mut frame = encode(1, Stamp::default(), &msg).unwrap();
            let len = u32::from_be_bytes(frame[34..38].try_into().unwrap()) + 1;
            frame[34..38].copy_from_slice(&len.to_be_bytes());
            frame.push(0);
            let mut cursor = std::io::Cursor::new(frame);
            assert!(read_frame(&mut cursor).is_err(), "trailing byte accepted");
        }
    }

    #[test]
    fn rejects_bad_side_and_dir_bytes() {
        // AdjacentUpdate payload: space u32, side u8, node u64 — the side
        // byte sits at offset HEAD_LEN + 4.
        let mut frame = encode(
            1,
            Stamp::default(),
            &Msg::AdjacentUpdate {
                space: 0,
                side: Side::Next,
                node: 5,
            },
        )
        .unwrap();
        frame[HEAD_LEN + 4] = 7;
        assert!(read_frame(&mut std::io::Cursor::new(frame)).is_err());
        // RepairStop payload: space u32, dir u8 — dir byte at HEAD_LEN + 4.
        let mut frame = encode(
            1,
            Stamp::default(),
            &Msg::RepairStop {
                space: 2,
                dir: Dir::Cw,
            },
        )
        .unwrap();
        frame[HEAD_LEN + 4] = 9;
        assert!(read_frame(&mut std::io::Cursor::new(frame)).is_err());
    }

    #[test]
    fn rejects_oversized_length_field() {
        let mut frame = encode(1, Stamp::default(), &Msg::Heartbeat).unwrap();
        frame[34..38].copy_from_slice(&u32::MAX.to_be_bytes());
        assert!(read_frame(&mut std::io::Cursor::new(frame)).is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let mut frame = encode(1, Stamp::default(), &Msg::Heartbeat).unwrap();
        frame[0] = 0x00;
        let mut cursor = std::io::Cursor::new(frame);
        assert!(read_frame(&mut cursor).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let frame = encode(1, Stamp::default(), &Msg::ModelRequest { task: 0, version: 2 }).unwrap();
        let mut cursor = std::io::Cursor::new(&frame[..frame.len() - 2]);
        assert!(read_frame(&mut cursor).is_err());
    }

    #[test]
    fn rejects_unknown_type() {
        let mut frame = encode(1, Stamp::default(), &Msg::Heartbeat).unwrap();
        frame[33] = 99;
        let mut cursor = std::io::Cursor::new(frame);
        assert!(read_frame(&mut cursor).is_err());
    }

    /// `payload.len() as u32` used to truncate silently past 4 GiB; the
    /// checked helper must accept exactly `u32::MAX` and reject one byte
    /// more — testable without allocating 4 GiB.
    #[test]
    fn payload_length_guard_is_exact_at_u32_boundary() {
        assert_eq!(payload_len_u32(0).unwrap(), 0);
        assert_eq!(payload_len_u32(MAX_PAYLOAD_LEN).unwrap(), u32::MAX);
        assert!(payload_len_u32(MAX_PAYLOAD_LEN + 1).is_err());
        assert!(payload_len_u32(usize::MAX).is_err());
    }

    /// A top-k payload with mismatched index/value lengths cannot be
    /// expressed on the wire (one count covers both) — encoding it must
    /// fail loudly instead of producing a frame that decodes differently.
    #[test]
    fn mismatched_topk_lengths_fail_to_encode() {
        let msg = Msg::ModelPayloadTopK {
            task: 0,
            version: 1,
            confidence: 0.5,
            dim: 10,
            indices: vec![1, 2, 3],
            values: vec![0.5],
        };
        assert!(encode(1, Stamp::default(), &msg).is_err());
    }

    #[test]
    fn wire_size_estimate_close() {
        for msg in [
            Msg::Heartbeat,
            Msg::NeighborDiscovery { joiner: 1, space: 0 },
            Msg::ModelPayload {
                task: 0,
                version: 1,
                confidence: 1.0,
                params: vec![0.0; 100],
            },
            Msg::ModelPayloadQ8 {
                task: 0,
                version: 1,
                confidence: 1.0,
                scale: 0.5,
                levels: vec![1; 100],
            },
            Msg::ModelPayloadTopK {
                task: 0,
                version: 1,
                confidence: 1.0,
                dim: 100,
                indices: (0..10).collect(),
                values: vec![0.5; 10],
            },
        ] {
            let actual = encode(1, Stamp::default(), &msg).unwrap().len();
            // estimate excludes the sender id and the three stamp fields
            let estimate = msg.wire_size() + 9 + 24;
            assert!(
                (actual as i64 - estimate as i64).abs() <= 8,
                "{msg:?}: actual {actual} vs estimate {estimate}"
            );
        }
    }
}
