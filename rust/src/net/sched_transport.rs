//! The real-socket `Transport` backend: maps unified-scheduler events
//! onto the TCP peer/wire layer, so the *same* deterministic event loop
//! that drives the in-memory simulation drives localhost sockets.
//!
//! Every node the `Simulator` opens gets an endpoint — a `Listener`
//! bound to an OS-assigned port (no port-collision flakiness) plus a
//! `PeerPool` of outbound connections — registered in a shared
//! `AddrBook`. `send` writes a `net::wire` frame to the destination's
//! live address; `poll` drains whatever the loopback delivered, waiting
//! (bounded) for in-flight traffic to quiesce so a multi-hop protocol
//! exchange completes within one virtual instant.
//!
//! Timing model: virtual time is the scheduler's; the wire contributes
//! effectively zero *virtual* latency (messages arrive at the instant of
//! the next pump). The overlay protocols converge to the same
//! Definition-1 topology regardless of latency, which is what the
//! conformance suite (`tests/transport_conformance.rs`) checks against
//! the in-memory backend.
//!
//! Failure semantics match the simulator's crash-fail rule: `close`
//! tears the endpoint down, in-flight messages to it vanish, and later
//! sends fail silently (counted by the pool, detected by NDMP
//! heartbeats).

use super::peer::{AddrBook, PeerPool};
use super::server::Listener;
use crate::ndmp::messages::{Msg, Time};
use crate::sim::{Arrival, Transport};
use crate::topology::NodeId;
use anyhow::Result;
use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

struct Endpoint {
    listener: Listener,
    pool: PeerPool,
}

struct Inner {
    book: Arc<AddrBook>,
    endpoints: BTreeMap<NodeId, Endpoint>,
    /// Frames written to sockets since the last settled poll; nonzero
    /// makes the next `poll` wait for loopback delivery to quiesce.
    in_flight: usize,
    /// A poll returns once this long passes with no new arrival.
    settle: Duration,
    /// Hard cap on how long one poll may wait in total.
    budget: Duration,
}

impl Inner {
    /// Non-blocking drain of every endpoint's inbound channel (in id
    /// order). Returns how many frames were collected.
    fn drain_into(&mut self, out: &mut Vec<Arrival>) -> usize {
        let mut got = 0;
        for (&node, ep) in self.endpoints.iter() {
            while let Ok((from, msg)) = ep.listener.rx.try_recv() {
                out.push(Arrival {
                    from,
                    to: node,
                    msg,
                });
                got += 1;
            }
        }
        got
    }
}

/// Scheduler-driven TCP transport: one in-process endpoint per live
/// node, real frames on localhost sockets. See the module docs.
///
/// The inner mutex exists for the `Sync` bound of `Transport` (inbound
/// channels are single-consumer); all calls come from the owning
/// simulator's thread.
pub struct SchedTransport {
    inner: Mutex<Inner>,
}

impl SchedTransport {
    pub fn new() -> Self {
        Self::with_pacing(Duration::from_millis(5), Duration::from_millis(1_000))
    }

    /// Tune the quiescence pacing: `settle` is how long the loopback must
    /// stay silent before a poll returns, `budget` the per-poll cap.
    pub fn with_pacing(settle: Duration, budget: Duration) -> Self {
        Self {
            inner: Mutex::new(Inner {
                book: Arc::new(AddrBook::new()),
                endpoints: BTreeMap::new(),
                in_flight: 0,
                settle,
                budget,
            }),
        }
    }

    /// The shared address registry (exposed for tests/diagnostics).
    pub fn book(&self) -> Arc<AddrBook> {
        self.inner.lock().unwrap().book.clone()
    }

    /// Number of open endpoints.
    pub fn endpoint_count(&self) -> usize {
        self.inner.lock().unwrap().endpoints.len()
    }
}

impl Default for SchedTransport {
    fn default() -> Self {
        Self::new()
    }
}

impl Transport for SchedTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn open(&mut self, node: NodeId) -> Result<()> {
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        if inner.endpoints.contains_key(&node) {
            return Ok(());
        }
        let listener = Listener::start(SocketAddr::from(([127, 0, 0, 1], 0)))?;
        inner.book.register(node, listener.addr);
        let pool = PeerPool::with_book(node, inner.book.clone());
        inner.endpoints.insert(node, Endpoint { listener, pool });
        Ok(())
    }

    fn close(&mut self, node: NodeId) {
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        inner.book.unregister(node);
        if let Some(mut ep) = inner.endpoints.remove(&node) {
            ep.listener.shutdown();
            ep.pool.disconnect_all();
        }
    }

    fn send(&mut self, _now: Time, from: NodeId, to: NodeId, msg: &Msg) -> Option<Time> {
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        if let Some(ep) = inner.endpoints.get(&from) {
            // only frames actually written count as in-flight: dropped
            // sends (dead/unregistered peers) must not make later polls
            // wait for arrivals that will never come
            if ep.pool.send(to, msg) {
                inner.in_flight += 1;
            }
        }
        None
    }

    fn poll(&mut self) -> Vec<Arrival> {
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        let mut out = Vec::new();
        inner.drain_into(&mut out);
        if inner.in_flight == 0 && out.is_empty() {
            return out;
        }
        // Frames are (or just were) on the wire: wait until the loopback
        // quiesces, so whatever this virtual instant triggered is fully
        // collected. A first contact pays connect + accept latency, so
        // an empty drain waits a longer window than the steady-state
        // settle; sends to dead peers never arrive and cost one window.
        let first_window = inner.settle.max(Duration::from_millis(50));
        let start = Instant::now();
        let mut last_arrival = Instant::now();
        while start.elapsed() < inner.budget {
            let window = if out.is_empty() {
                first_window
            } else {
                inner.settle
            };
            if last_arrival.elapsed() >= window {
                break;
            }
            std::thread::sleep(Duration::from_micros(200));
            if inner.drain_into(&mut out) > 0 {
                last_arrival = Instant::now();
            }
        }
        inner.in_flight = 0;
        out
    }

    fn idle(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_cross_between_endpoints() {
        let mut t =
            SchedTransport::with_pacing(Duration::from_millis(5), Duration::from_millis(2_000));
        t.open(1).unwrap();
        t.open(2).unwrap();
        assert_eq!(t.endpoint_count(), 2);
        assert_eq!(t.send(0, 1, 2, &Msg::Heartbeat), None);
        let arrivals = t.poll();
        assert_eq!(arrivals.len(), 1);
        assert_eq!(arrivals[0].from, 1);
        assert_eq!(arrivals[0].to, 2);
        assert_eq!(arrivals[0].msg, Msg::Heartbeat);
        // quiet transport: an immediate second poll is empty and cheap
        assert!(t.poll().is_empty());
        t.close(2);
        // sends to a closed endpoint vanish (crash-fail semantics)
        t.send(0, 1, 2, &Msg::Heartbeat);
        assert!(t.poll().is_empty());
        t.close(1);
        assert_eq!(t.endpoint_count(), 0);
    }

    #[test]
    fn broadcast_reaches_every_live_endpoint() {
        let mut t =
            SchedTransport::with_pacing(Duration::from_millis(5), Duration::from_millis(2_000));
        for id in 1..=3u64 {
            t.open(id).unwrap();
        }
        // wire backend: nothing is queue-scheduled, frames go out-of-band
        let scheduled = t.broadcast(0, 1, &[2, 3], &Msg::Heartbeat);
        assert!(scheduled.is_empty());
        let mut arrivals = t.poll();
        arrivals.sort_by_key(|a| a.to);
        let tos: Vec<_> = arrivals.iter().map(|a| (a.from, a.to)).collect();
        assert_eq!(tos, vec![(1, 2), (1, 3)]);
        for id in 1..=3u64 {
            t.close(id);
        }
    }
}
