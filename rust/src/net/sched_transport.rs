//! The real-socket `Transport` backend: maps unified-scheduler events
//! onto the TCP peer/wire layer, so the *same* deterministic event loop
//! that drives the in-memory simulation drives localhost sockets.
//!
//! Every node the `Simulator` opens gets an endpoint — a `Listener`
//! bound to an OS-assigned port (no port-collision flakiness) plus a
//! `PeerPool` of outbound connections — registered in a shared
//! `AddrBook`. `send` samples the virtual delivery time from the same
//! seeded per-link component the in-memory backend uses
//! (`sim::network::LinkModel`: propagation delay, payload-proportional
//! bandwidth, loss lottery, per-node capacity queues), stamps the full
//! virtual delay with the send time and a global send sequence into the
//! `net::wire` frame, and writes the frame to the destination's live
//! address. A loss-lottery hit is a **deliberate non-send**: the frame
//! is never written and the in-flight counter never incremented (so the
//! poll backstop cannot stall waiting for it) — exactly the frames the
//! in-memory backend never schedules.
//!
//! Timing model: virtual time is the scheduler's, and the wire carries
//! **virtual latency**. Frames physically arrive early — while the
//! sending instant is still being settled — and are parked in a
//! time-ordered staging buffer keyed by their stamped due time
//! `sent_at + delay` (ties by send sequence). `poll` waits (bounded)
//! until every frame written since the last poll has landed, then
//! releases the staged arrivals so the caller can schedule each as a
//! `Deliver` event at exactly its stamped virtual time. The old
//! real-time quiescence window survives only as a **liveness backstop**:
//! it times out the wait when a frame was lost to a peer dying
//! mid-flight. A seeded schedule therefore replays over sockets with
//! the identical arrival timestamps it has in simulation — not just the
//! same converged topology (`tests/transport_conformance.rs`,
//! `docs/transports.md`).
//!
//! Failure semantics match the simulator's crash-fail rule: `close`
//! tears the endpoint down, in-flight messages to it vanish, and later
//! sends fail silently (counted by the pool, detected by NDMP
//! heartbeats).

use super::peer::{AddrBook, PeerPool};
use super::server::Listener;
use super::wire::Stamp;
use crate::config::NetConfig;
use crate::ndmp::messages::{Msg, Time};
use crate::sim::{Arrival, LinkModel, Transport};
use crate::topology::NodeId;
use anyhow::Result;
use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

struct Endpoint {
    listener: Listener,
    pool: PeerPool,
}

struct Inner {
    book: Arc<AddrBook>,
    endpoints: BTreeMap<NodeId, Endpoint>,
    /// The shared per-link virtual model (same seeding as
    /// `SimTransport`, so the k-th frame on a link samples the same
    /// delay, bandwidth, and loss outcome on both backends).
    model: LinkModel,
    /// Global send sequence stamped into every written frame — the
    /// tie-breaker that orders equal-due-time arrivals exactly like the
    /// in-memory backend's event-queue insertion order.
    send_seq: u64,
    /// Frames written to sockets but not yet drained, per destination;
    /// `close` forgets a dead node's count so lost frames don't stall
    /// every later poll.
    in_flight: BTreeMap<NodeId, usize>,
    /// Time-ordered staging buffer: frames that physically arrived
    /// early, keyed by (virtual due time, send sequence).
    staged: BTreeMap<(Time, u64), Arrival>,
    /// Liveness backstop: a poll stops waiting for outstanding frames
    /// once this long passes with no new arrival (only frames lost to a
    /// dying peer ever pay it).
    settle: Duration,
    /// Hard cap on how long one poll may wait in total.
    budget: Duration,
    /// Frames the backstop gave up waiting for (telemetry: nonzero means
    /// either real loss to a dying peer, or a too-tight `settle`).
    gave_up: u64,
    /// Frames that drained *after* a backstop gave them up — the
    /// conformance-threatening case: their `Deliver` is scheduled late
    /// (clamped to the caller's clock), so timestamp pins can diverge.
    late: u64,
    /// Send errors accumulated from pools of endpoints that have since
    /// closed, so `dropped_sends` keeps counting them.
    closed_send_errors: u64,
}

impl Inner {
    /// Non-blocking drain of every endpoint's inbound channel into the
    /// staging buffer (in id order). Returns how many frames landed.
    fn drain(&mut self) -> usize {
        let mut got = 0;
        for (&node, ep) in self.endpoints.iter() {
            while let Ok(frame) = ep.listener.rx.try_recv() {
                let stamp = frame.stamp;
                self.staged.insert(
                    (stamp.due(), stamp.seq),
                    Arrival {
                        from: frame.sender,
                        to: node,
                        at: stamp.due(),
                        msg: frame.msg,
                    },
                );
                match self.in_flight.get_mut(&node) {
                    Some(n) if *n > 0 => *n -= 1,
                    // not owed: a frame the backstop already gave up on
                    // landed after all — its delivery may now be late in
                    // virtual time, the one way timestamp conformance
                    // can break, so say it loudly
                    _ => {
                        self.late += 1;
                        eprintln!(
                            "[SchedTransport] frame {} -> {node} drained after the settle \
                             backstop gave it up; its delivery may be late in virtual time \
                             (consider a larger `settle` in with_pacing)",
                            frame.sender
                        );
                    }
                }
                got += 1;
            }
        }
        got
    }

    /// Frames written but not yet drained (to still-open endpoints).
    fn outstanding(&self) -> usize {
        self.in_flight.values().sum()
    }
}

/// Scheduler-driven TCP transport: one in-process endpoint per live
/// node, real frames on localhost sockets, virtual latency stamped into
/// every frame. See the module docs.
///
/// The inner mutex exists for the `Sync` bound of `Transport` (inbound
/// channels are single-consumer); all calls come from the owning
/// simulator's thread.
pub struct SchedTransport {
    inner: Mutex<Inner>,
}

impl SchedTransport {
    /// A transport whose virtual link delays come from `net` (the same
    /// `NetConfig` the in-memory backend would use), with the default
    /// pacing: `settle` = 200 ms, `budget` = 2 s.
    pub fn new(net: &NetConfig) -> Self {
        Self::with_pacing(net, Duration::from_millis(200), Duration::from_millis(2_000))
    }

    /// Tune the liveness backstop of [`Transport::poll`]:
    ///
    /// * `settle` — wall-clock duration (default **200 ms**): a poll
    ///   that is still owed frames gives them up as lost once this long
    ///   passes with no new arrival. Only frames genuinely lost (a peer
    ///   dying mid-flight) ever pay this window; in the common case a
    ///   poll returns as soon as every written frame has landed.
    /// * `budget` — wall-clock duration (default **2 s**): the hard cap
    ///   on one poll's total wait, whatever the arrival pattern.
    pub fn with_pacing(net: &NetConfig, settle: Duration, budget: Duration) -> Self {
        Self {
            inner: Mutex::new(Inner {
                book: Arc::new(AddrBook::new()),
                endpoints: BTreeMap::new(),
                model: LinkModel::new(net),
                send_seq: 0,
                in_flight: BTreeMap::new(),
                staged: BTreeMap::new(),
                settle,
                budget,
                gave_up: 0,
                late: 0,
                closed_send_errors: 0,
            }),
        }
    }

    /// Pacing-anomaly telemetry: `(gave_up, late)` — frames the settle
    /// backstop stopped waiting for, and frames that drained *after*
    /// being given up (late virtual delivery, the one condition that can
    /// break timestamp conformance). Both are 0 on a healthy run.
    pub fn pacing_anomalies(&self) -> (u64, u64) {
        let inner = self.inner.lock().unwrap();
        (inner.gave_up, inner.late)
    }

    /// Frames that failed to *write* against a resolved, live address
    /// (connect refused, write error) across every pool this transport
    /// ever opened. Unreachable-peer drops — the routine crash-fail case
    /// — are excluded; on a clean run the conformance suite asserts this
    /// stays zero.
    pub fn dropped_sends(&self) -> u64 {
        let inner = self.inner.lock().unwrap();
        inner.closed_send_errors
            + inner
                .endpoints
                .values()
                .map(|ep| {
                    ep.pool
                        .send_errors
                        .load(std::sync::atomic::Ordering::Relaxed)
                })
                .sum::<u64>()
    }

    /// The shared address registry (exposed for tests/diagnostics).
    pub fn book(&self) -> Arc<AddrBook> {
        self.inner.lock().unwrap().book.clone()
    }

    /// Number of open endpoints.
    pub fn endpoint_count(&self) -> usize {
        self.inner.lock().unwrap().endpoints.len()
    }
}

impl Transport for SchedTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn open(&mut self, node: NodeId) -> Result<()> {
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        inner.model.reopen(node);
        if inner.endpoints.contains_key(&node) {
            return Ok(());
        }
        let listener = Listener::start(SocketAddr::from(([127, 0, 0, 1], 0)))?;
        inner.book.register(node, listener.addr);
        let pool = PeerPool::with_book(node, inner.book.clone());
        inner.endpoints.insert(node, Endpoint { listener, pool });
        Ok(())
    }

    fn close(&mut self, node: NodeId) {
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        inner.book.unregister(node);
        // frames still in flight toward the dead node will never arrive:
        // forget their count so later polls don't wait out the backstop
        inner.in_flight.remove(&node);
        if let Some(mut ep) = inner.endpoints.remove(&node) {
            ep.listener.shutdown();
            ep.pool.disconnect_all();
            // keep the dead pool's anomaly count in the telemetry total
            inner.closed_send_errors += ep
                .pool
                .send_errors
                .load(std::sync::atomic::Ordering::Relaxed);
        }
        // survivors' cached connections to the dead node would accept
        // writes into the kernel buffer; drop them so later sends fail
        // fast instead of counting unarrivable frames
        for ep in inner.endpoints.values() {
            ep.pool.forget(node);
        }
        // prune the dead node's link-model streams (both backends do,
        // keeping link state identical) so churn doesn't grow them
        // forever
        inner.model.forget(node);
    }

    fn send(&mut self, now: Time, from: NodeId, to: NodeId, msg: &Msg) -> Option<Time> {
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        // sample unconditionally — the in-memory backend samples for
        // dropped sends too, and skipping here would shift the link's
        // delay or loss sequence between backends
        let sampled = inner.model.sample(now, from, to, msg.wire_size() as u64);
        let seq = inner.send_seq;
        inner.send_seq += 1;
        let Some(at) = sampled else {
            // loss lottery: a deliberate non-send. The frame is never
            // written and `in_flight` never incremented, so the poll
            // backstop has nothing to stall on — the same frame the
            // in-memory backend never schedules.
            return None;
        };
        let stamp = Stamp {
            seq,
            sent_at: now,
            delay: at.saturating_sub(now),
        };
        if let Some(ep) = inner.endpoints.get(&from) {
            // only frames actually written count as in-flight: dropped
            // sends (dead/unregistered peers) must not make later polls
            // wait for arrivals that will never come
            if ep.pool.send_stamped(to, stamp, msg) {
                *inner.in_flight.entry(to).or_insert(0) += 1;
            }
        }
        None
    }

    fn lost_frames(&self) -> u64 {
        self.inner.lock().unwrap().model.lost()
    }

    fn dropped_sends(&self) -> u64 {
        SchedTransport::dropped_sends(self)
    }

    fn poll(&mut self) -> Vec<Arrival> {
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        inner.drain();
        if inner.outstanding() > 0 {
            // Frames are on the wire: wait until each one lands. The
            // settle window only fires when a frame was lost (peer died
            // mid-flight); the budget caps the poll whatever happens.
            let start = Instant::now();
            let mut last_progress = Instant::now();
            while inner.outstanding() > 0 && start.elapsed() < inner.budget {
                if last_progress.elapsed() >= inner.settle {
                    break; // lost frames: give them up
                }
                std::thread::sleep(Duration::from_micros(200));
                if inner.drain() > 0 {
                    last_progress = Instant::now();
                }
            }
            let abandoned = inner.outstanding() as u64;
            if abandoned > 0 {
                // real loss (peer died mid-flight) or a too-tight settle
                // window — either way, leave a trace for flake forensics
                inner.gave_up += abandoned;
                eprintln!(
                    "[SchedTransport] poll gave up on {abandoned} in-flight frame(s) \
                     after {:?}; lost to a dead peer, or `settle` too tight",
                    start.elapsed()
                );
            }
            inner.in_flight.clear();
        }
        let staged = std::mem::take(&mut inner.staged);
        staged.into_values().collect()
    }

    fn idle(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimTransport;

    fn net(latency_ms: f64, jitter: f64) -> NetConfig {
        NetConfig {
            latency_ms,
            jitter,
            seed: 99,
            ..NetConfig::default()
        }
    }

    #[test]
    fn frames_cross_with_stamped_virtual_latency() {
        let mut t = SchedTransport::new(&net(5.0, 0.0));
        t.open(1).unwrap();
        t.open(2).unwrap();
        assert_eq!(t.endpoint_count(), 2);
        assert_eq!(t.send(100, 1, 2, &Msg::Heartbeat), None);
        let arrivals = t.poll();
        assert_eq!(arrivals.len(), 1);
        assert_eq!(arrivals[0].from, 1);
        assert_eq!(arrivals[0].to, 2);
        // virtual due time = send time + the sampled 5 ms link delay
        assert_eq!(arrivals[0].at, 100 + 5_000);
        assert_eq!(arrivals[0].msg, Msg::Heartbeat);
        // quiet transport: an immediate second poll is empty and cheap
        assert!(t.poll().is_empty());
        t.close(2);
        // sends to a closed endpoint vanish (crash-fail semantics)
        t.send(0, 1, 2, &Msg::Heartbeat);
        assert!(t.poll().is_empty());
        t.close(1);
        assert_eq!(t.endpoint_count(), 0);
    }

    /// Both backends sample the same per-link delay sequence from the
    /// same `NetConfig` — the arrival time the TCP backend stamps equals
    /// the delivery time the in-memory backend schedules.
    #[test]
    fn stamped_arrival_times_match_sim_backend() {
        let cfg = net(20.0, 0.4);
        let mut sim = SimTransport::new(&cfg);
        let mut tcp = SchedTransport::new(&cfg);
        for id in 1..=3u64 {
            tcp.open(id).unwrap();
        }
        let sends: &[(Time, NodeId, NodeId)] =
            &[(10, 1, 2), (10, 1, 3), (500, 2, 1), (500, 1, 2), (900, 3, 2)];
        let sim_times: Vec<Time> = sends
            .iter()
            .map(|&(now, f, to)| sim.send(now, f, to, &Msg::Heartbeat).unwrap())
            .collect();
        for &(now, f, to) in sends {
            assert_eq!(tcp.send(now, f, to, &Msg::Heartbeat), None);
        }
        let arrivals = tcp.poll();
        assert_eq!(arrivals.len(), sends.len());
        // order-free comparison: the multisets of due times must match
        let mut got: Vec<Time> = arrivals.iter().map(|a| a.at).collect();
        let mut want = sim_times;
        got.sort_unstable();
        want.sort_unstable();
        assert_eq!(got, want, "tcp stamps diverge from sim schedule");
        for id in 1..=3u64 {
            tcp.close(id);
        }
    }

    #[test]
    fn poll_releases_in_time_order() {
        // zero jitter, distinct send times: due times are fully ordered
        let mut t = SchedTransport::new(&net(2.0, 0.0));
        for id in 1..=3u64 {
            t.open(id).unwrap();
        }
        t.send(300, 1, 2, &Msg::Heartbeat);
        t.send(100, 2, 3, &Msg::Heartbeat);
        t.send(200, 3, 1, &Msg::Heartbeat);
        let arrivals = t.poll();
        let ats: Vec<Time> = arrivals.iter().map(|a| a.at).collect();
        assert_eq!(ats, vec![2_100, 2_200, 2_300]);
        for id in 1..=3u64 {
            t.close(id);
        }
    }

    /// Under loss, both backends drop the *same* frames: the TCP backend
    /// treats a loss-lottery hit as a deliberate non-send (nothing
    /// written, nothing in flight, poll returns immediately), and its
    /// delivered arrival times still match the in-memory schedule.
    #[test]
    fn lossy_sends_are_non_sends_and_match_sim() {
        let cfg = NetConfig {
            latency_ms: 10.0,
            jitter: 0.3,
            bandwidth_mbps: 8.0,
            loss: 0.4,
            node_up_mbps: 16.0,
            node_down_mbps: 16.0,
            seed: 7,
        };
        let mut sim = SimTransport::new(&cfg);
        let mut tcp = SchedTransport::new(&cfg);
        for id in 1..=3u64 {
            sim.open(id).unwrap();
            tcp.open(id).unwrap();
        }
        let sends: Vec<(Time, NodeId, NodeId)> = (0..40)
            .map(|i| (i * 50, 1 + i % 3, 1 + (i + 1) % 3))
            .collect();
        let sim_times: Vec<Option<Time>> = sends
            .iter()
            .map(|&(now, f, to)| sim.send(now, f, to, &Msg::Heartbeat))
            .collect();
        for &(now, f, to) in &sends {
            assert_eq!(tcp.send(now, f, to, &Msg::Heartbeat), None);
        }
        let delivered: Vec<Time> = sim_times.iter().filter_map(|t| *t).collect();
        assert!(!delivered.is_empty(), "seed lost every frame");
        assert!(
            delivered.len() < sends.len(),
            "seed lost no frame — loss path untested"
        );
        // identical loss lottery on both backends
        assert_eq!(tcp.lost_frames(), sim.lost_frames());
        assert_eq!(
            tcp.lost_frames(),
            (sends.len() - delivered.len()) as u64
        );
        // the surviving frames arrive with the in-memory delivery times
        let arrivals = tcp.poll();
        let mut got: Vec<Time> = arrivals.iter().map(|a| a.at).collect();
        let mut want = delivered;
        got.sort_unstable();
        want.sort_unstable();
        assert_eq!(got, want, "tcp stamps diverge from sim under loss");
        assert_eq!(tcp.dropped_sends(), 0, "clean run must not drop writes");
        for id in 1..=3u64 {
            tcp.close(id);
        }
    }

    #[test]
    fn broadcast_reaches_every_live_endpoint() {
        let mut t = SchedTransport::new(&net(5.0, 0.0));
        for id in 1..=3u64 {
            t.open(id).unwrap();
        }
        // wire backend: nothing is queue-scheduled, frames go out-of-band
        let scheduled = t.broadcast(0, 1, &[2, 3], &Msg::Heartbeat);
        assert!(scheduled.is_empty());
        let mut arrivals = t.poll();
        arrivals.sort_by_key(|a| a.to);
        let tos: Vec<_> = arrivals.iter().map(|a| (a.from, a.to)).collect();
        assert_eq!(tos, vec![(1, 2), (1, 3)]);
        for id in 1..=3u64 {
            t.close(id);
        }
    }
}
