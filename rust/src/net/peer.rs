//! Outbound connection management: a cache of TCP streams to peers,
//! reconnecting on demand. In the localhost prototype a node's address is
//! derived from its id (`127.0.0.1:base_port + id`), mirroring the paper's
//! use of the IP address as the node identity.

use super::wire;
use crate::ndmp::messages::Msg;
use crate::topology::NodeId;
use anyhow::Result;
use std::collections::HashMap;
use std::net::{SocketAddr, TcpStream};
use std::sync::Mutex;
use std::time::Duration;

/// id -> socket address mapping for the localhost prototype.
pub fn addr_of(base_port: u16, id: NodeId) -> SocketAddr {
    SocketAddr::from(([127, 0, 0, 1], base_port + id as u16))
}

pub struct PeerPool {
    pub base_port: u16,
    pub self_id: NodeId,
    conns: Mutex<HashMap<NodeId, TcpStream>>,
    /// send failures (dead peers are detected by NDMP heartbeats, not here)
    pub send_errors: std::sync::atomic::AtomicU64,
}

impl PeerPool {
    pub fn new(base_port: u16, self_id: NodeId) -> Self {
        Self {
            base_port,
            self_id,
            conns: Mutex::new(HashMap::new()),
            send_errors: std::sync::atomic::AtomicU64::new(0),
        }
    }

    fn connect(&self, to: NodeId) -> Result<TcpStream> {
        let addr = addr_of(self.base_port, to);
        let s = TcpStream::connect_timeout(&addr, Duration::from_millis(1_000))?;
        s.set_nodelay(true)?;
        // Bounded writes: two peers simultaneously pushing large model
        // payloads into full kernel buffers must not deadlock; a timed-out
        // send is dropped and the connection rebuilt on the next message.
        s.set_write_timeout(Some(Duration::from_millis(2_000)))?;
        Ok(s)
    }

    /// Send a message, reconnecting once on a stale cached connection.
    /// Failures are counted but not fatal (crash-fail peers are expected).
    pub fn send(&self, to: NodeId, msg: &Msg) {
        let mut conns = self.conns.lock().unwrap();
        // try the cached stream first
        if let Some(stream) = conns.get_mut(&to) {
            if wire::write_frame(stream, self.self_id, msg).is_ok() {
                return;
            }
            conns.remove(&to);
        }
        match self.connect(to) {
            Ok(mut stream) => {
                if wire::write_frame(&mut stream, self.self_id, msg).is_ok() {
                    conns.insert(to, stream);
                } else {
                    self.send_errors
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
            }
            Err(e) => {
                if std::env::var("FEDLAY_NET_DEBUG").is_ok() {
                    eprintln!("[pool {}] connect to {to} failed: {e}", self.self_id);
                }
                self.send_errors
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            }
        }
    }

    pub fn disconnect_all(&self) {
        self.conns.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addr_mapping() {
        let a = addr_of(9000, 5);
        assert_eq!(a.port(), 9005);
        assert!(a.ip().is_loopback());
    }

    #[test]
    fn send_to_dead_peer_counts_error() {
        let pool = PeerPool::new(1, 0); // port 1+id: nothing listens there
        pool.send(7, &Msg::Heartbeat);
        assert_eq!(
            pool.send_errors.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
    }
}
