//! Outbound connection management: a cache of TCP streams to peers,
//! reconnecting on demand. Destinations resolve either through the
//! derived `127.0.0.1:base_port + id` convention (multi-process
//! prototype; the paper uses the IP address as the node identity) or
//! through a shared `AddrBook` of OS-assigned ports (in-process fleets
//! binding port 0, which kills port-collision flakiness in tests).

use super::wire;
use crate::ndmp::messages::Msg;
use crate::topology::NodeId;
use anyhow::Result;
use std::collections::HashMap;
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

/// id -> socket address mapping for the localhost prototype.
pub fn addr_of(base_port: u16, id: NodeId) -> SocketAddr {
    SocketAddr::from(([127, 0, 0, 1], base_port + id as u16))
}

/// Shared registry of live listener addresses for in-process fleets:
/// each node binds an OS-assigned port (port 0) and registers the actual
/// address here; `PeerPool::with_book` resolves destinations through it.
/// A missing entry means the peer is dead or not yet open — the send is
/// dropped and counted, like any crash-fail peer.
#[derive(Debug, Default)]
pub struct AddrBook {
    map: RwLock<HashMap<NodeId, SocketAddr>>,
}

impl AddrBook {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register(&self, id: NodeId, addr: SocketAddr) {
        self.map.write().unwrap().insert(id, addr);
    }

    pub fn unregister(&self, id: NodeId) {
        self.map.write().unwrap().remove(&id);
    }

    pub fn lookup(&self, id: NodeId) -> Option<SocketAddr> {
        self.map.read().unwrap().get(&id).copied()
    }

    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.read().unwrap().is_empty()
    }
}

pub struct PeerPool {
    pub base_port: u16,
    pub self_id: NodeId,
    /// Address registry for port-0 fleets; `None` = derived addressing.
    book: Option<Arc<AddrBook>>,
    conns: Mutex<HashMap<NodeId, TcpStream>>,
    /// Sends dropped because the destination had no registered address —
    /// the *routine* crash-fail case under churn (dead peers are detected
    /// by NDMP heartbeats, not here).
    pub dropped_unreachable: std::sync::atomic::AtomicU64,
    /// Sends that failed against a *resolved* address (connect refused,
    /// write error). Unlike `dropped_unreachable` this is an anomaly: on
    /// a clean run the conformance suite asserts it stays zero.
    pub send_errors: std::sync::atomic::AtomicU64,
}

impl PeerPool {
    pub fn new(base_port: u16, self_id: NodeId) -> Self {
        Self {
            base_port,
            self_id,
            book: None,
            conns: Mutex::new(HashMap::new()),
            dropped_unreachable: std::sync::atomic::AtomicU64::new(0),
            send_errors: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// A pool resolving destinations through a shared `AddrBook` instead
    /// of the `base_port + id` convention.
    pub fn with_book(self_id: NodeId, book: Arc<AddrBook>) -> Self {
        Self {
            base_port: 0,
            self_id,
            book: Some(book),
            conns: Mutex::new(HashMap::new()),
            dropped_unreachable: std::sync::atomic::AtomicU64::new(0),
            send_errors: std::sync::atomic::AtomicU64::new(0),
        }
    }

    fn resolve(&self, to: NodeId) -> Option<SocketAddr> {
        match &self.book {
            Some(book) => book.lookup(to),
            None => Some(addr_of(self.base_port, to)),
        }
    }

    fn connect(&self, addr: SocketAddr) -> Result<TcpStream> {
        let s = TcpStream::connect_timeout(&addr, Duration::from_millis(1_000))?;
        s.set_nodelay(true)?;
        // Bounded writes: two peers simultaneously pushing large model
        // payloads into full kernel buffers must not deadlock; a timed-out
        // send is dropped and the connection rebuilt on the next message.
        s.set_write_timeout(Some(Duration::from_millis(2_000)))?;
        Ok(s)
    }

    /// Send a message with zeroed timing stamps (wall-clock senders —
    /// `net::client_node` — have no virtual clock). See [`Self::send_stamped`].
    pub fn send(&self, to: NodeId, msg: &Msg) -> bool {
        self.send_stamped(to, wire::Stamp::default(), msg)
    }

    /// Send a message carrying its virtual timing stamp (send sequence,
    /// send time, sampled link delay — see `net::wire::Stamp`),
    /// reconnecting once on a stale cached connection.
    /// Failures are counted but not fatal (crash-fail peers are expected):
    /// an unresolvable destination bumps `dropped_unreachable`, a failed
    /// connect or write against a live address bumps `send_errors`.
    /// Returns whether a frame was actually written to a socket, so
    /// callers tracking in-flight traffic don't wait for frames that
    /// were dropped on a dead or unregistered peer.
    pub fn send_stamped(&self, to: NodeId, stamp: wire::Stamp, msg: &Msg) -> bool {
        let mut conns = self.conns.lock().unwrap();
        // try the cached stream first
        if let Some(stream) = conns.get_mut(&to) {
            if wire::write_frame(stream, self.self_id, stamp, msg).is_ok() {
                return true;
            }
            conns.remove(&to);
        }
        let Some(addr) = self.resolve(to) else {
            // no registered address: the peer is dead or not yet open —
            // the expected crash-fail drop, tallied apart from real
            // connect/write failures
            self.dropped_unreachable
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            return false;
        };
        match self.connect(addr) {
            Ok(mut stream) => {
                if wire::write_frame(&mut stream, self.self_id, stamp, msg).is_ok() {
                    conns.insert(to, stream);
                    true
                } else {
                    self.send_errors
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    false
                }
            }
            Err(e) => {
                if std::env::var("FEDLAY_NET_DEBUG").is_ok() {
                    eprintln!("[pool {}] connect to {to} failed: {e}", self.self_id);
                }
                self.send_errors
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                false
            }
        }
    }

    pub fn disconnect_all(&self) {
        self.conns.lock().unwrap().clear();
    }

    /// Drop the cached connection to one peer (its endpoint closed): a
    /// write into the stale socket could still "succeed" into the kernel
    /// buffer, and callers tracking in-flight frames would wait out
    /// their loss backstop for a frame that can never arrive.
    pub fn forget(&self, to: NodeId) {
        self.conns.lock().unwrap().remove(&to);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addr_mapping() {
        let a = addr_of(9000, 5);
        assert_eq!(a.port(), 9005);
        assert!(a.ip().is_loopback());
    }

    #[test]
    fn send_to_dead_peer_counts_error() {
        let pool = PeerPool::new(1, 0); // port 1+id: nothing listens there
        pool.send(7, &Msg::Heartbeat);
        // derived addressing always resolves, so a refused connect is a
        // real send error, not an unreachable drop
        assert_eq!(
            pool.send_errors.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
        assert_eq!(
            pool.dropped_unreachable
                .load(std::sync::atomic::Ordering::Relaxed),
            0
        );
    }

    #[test]
    fn book_resolution_and_unregistered_send() {
        let book = Arc::new(AddrBook::new());
        assert!(book.is_empty());
        let addr = SocketAddr::from(([127, 0, 0, 1], 12345));
        book.register(4, addr);
        assert_eq!(book.len(), 1);
        let pool = PeerPool::with_book(1, book.clone());
        assert_eq!(pool.resolve(4), Some(addr));
        // unregistered destination: the routine crash-fail drop — counted
        // apart from real send errors, never panics
        assert_eq!(pool.resolve(9), None);
        pool.send(9, &Msg::Heartbeat);
        assert_eq!(
            pool.dropped_unreachable
                .load(std::sync::atomic::Ordering::Relaxed),
            1
        );
        assert_eq!(
            pool.send_errors.load(std::sync::atomic::Ordering::Relaxed),
            0
        );
        book.unregister(4);
        assert_eq!(pool.resolve(4), None);
    }
}
