//! A full FedLay client over real TCP: the NDMP protocol engine plus the
//! MEP offer/request/payload exchange and local training through the
//! runtime engine — the paper's §IV-A1 "real experiment" node, 16 of
//! which form the prototype (examples/prototype_16.rs).
//!
//! Each node runs in its own OS thread and owns a private `Engine` (the
//! PJRT client is not `Send`); all inter-node communication is real TCP
//! via `net::wire` frames. The node is an **event-pumped reactor** on the
//! same deterministic `sim::Scheduler` the simulator uses: NDMP tick and
//! MEP round timers are heap events, and inbound frames are pumped off
//! the listener channel between timer deadlines — no fixed-interval
//! sleep/poll loop. Wall-clock time maps one-to-one onto the timer axis,
//! exactly like a deployment.
//!
//! Every node publishes a `NodeStatus` (joined flag, neighbor sets, MEP
//! counters) so orchestrators and tests can poll protocol state with a
//! bounded deadline instead of sleeping for a fixed guess.

use super::peer::{addr_of, AddrBook, PeerPool};
use super::server::Listener;
use crate::config::OverlayConfig;
use crate::data::GaussianTask;
use crate::dfl::Compression;
use crate::mep::{
    densify_topk, dequantize_q8, fingerprint, pack_for_artifact, quantize_q8, sparsify_topk,
    Aggregation, ConfidenceParams, FingerprintCache,
};
use crate::ndmp::messages::{Msg, Time, MS};
use crate::ndmp::node::NodeState;
use crate::runtime::{Engine, XInput};
use crate::sim::Scheduler;
use crate::topology::NodeId;
use crate::util::Rng;
use anyhow::Result;
use std::collections::{BTreeSet, HashMap};
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct ClientNodeConfig {
    pub id: NodeId,
    pub base_port: u16,
    /// `None` = bootstrap node (first in the network).
    pub bootstrap: Option<NodeId>,
    /// Shared address registry: when set, the node binds an OS-assigned
    /// port (port 0) and registers it here instead of deriving
    /// `base_port + id` — no port-collision flakiness for in-process
    /// fleets. `base_port` is ignored in that case.
    pub book: Option<Arc<AddrBook>>,
    pub overlay: OverlayConfig,
    pub artifacts_dir: std::path::PathBuf,
    pub task: String,
    /// Wire-level task tag for the MEP frames this node sends, and the
    /// only tag it aggregates: several independent model tasks can share
    /// one overlay, and a node ignores offers/payloads of tasks it does
    /// not train (single-task fleets use 0).
    pub task_id: u32,
    pub label_weights: Vec<f64>,
    pub lr: f32,
    pub local_steps: usize,
    /// MEP communication period (wall-clock ms; scaled-down prototype).
    pub period_ms: u64,
    /// Wire scheme for outbound model payload replies. Inbound frames of
    /// any scheme are always accepted — nodes with different settings
    /// interoperate, each only deciding what *it* puts on the wire.
    pub compression: Compression,
    /// Aggregation rule for the MEP round (`Mean` = the historical
    /// confidence-weighted average; the robust rules tolerate Byzantine
    /// neighbors). Independent of the non-finite payload guard, which is
    /// always on.
    pub aggregation: Aggregation,
    pub seed: u64,
}

/// Final report returned when a node shuts down.
#[derive(Debug, Clone)]
pub struct ClientReport {
    pub id: NodeId,
    pub accuracy: f64,
    pub loss: f64,
    pub neighbor_count: usize,
    pub control_sent: u64,
    pub data_sent: u64,
    pub model_bytes_sent: u64,
    pub dedup_skips: u64,
    /// Inbound models dropped for non-finite parameters or confidence
    /// (the Byzantine guard at the frame boundary).
    pub rejected_models: u64,
    pub joined: bool,
}

/// Live protocol state a running node publishes for bounded polling
/// (tests and orchestrators watch this instead of sleeping).
#[derive(Debug, Default)]
pub struct NodeStatus {
    joined: AtomicBool,
    data_sent: AtomicU64,
    exchanges: AtomicU64,
    neighbors: Mutex<BTreeSet<NodeId>>,
    ring: Mutex<BTreeSet<NodeId>>,
}

impl NodeStatus {
    /// Has the node completed its NDMP join?
    pub fn joined(&self) -> bool {
        self.joined.load(Ordering::Relaxed)
    }

    /// MEP messages sent so far (offers + requests + payload replies).
    pub fn data_sent(&self) -> u64 {
        self.data_sent.load(Ordering::Relaxed)
    }

    /// Completed MEP exchange rounds.
    pub fn exchanges(&self) -> u64 {
        self.exchanges.load(Ordering::Relaxed)
    }

    /// Current full neighbor set (`N_u`, incl. routed-traffic peers).
    pub fn neighbors(&self) -> BTreeSet<NodeId> {
        self.neighbors.lock().unwrap().clone()
    }

    /// Current ring-adjacency set (Definition-1 views only).
    pub fn ring_neighbors(&self) -> BTreeSet<NodeId> {
        self.ring.lock().unwrap().clone()
    }
}

struct NeighborModel {
    confidence: f32,
    params: Vec<f32>,
}

pub struct ClientHandle {
    pub id: NodeId,
    /// Live protocol state, updated by the reactor after every event.
    pub status: Arc<NodeStatus>,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<Result<ClientReport>>>,
}

impl ClientHandle {
    pub fn stop_and_join(mut self) -> Result<ClientReport> {
        self.stop.store(true, Ordering::SeqCst);
        self.thread
            .take()
            .expect("already joined")
            .join()
            .map_err(|_| anyhow::anyhow!("client thread panicked"))?
    }
}

/// Spawn a client node thread. It binds its listener synchronously (so
/// callers can order bootstrap before joiners) and then runs until
/// `stop_and_join`.
pub fn spawn(cfg: ClientNodeConfig) -> Result<ClientHandle> {
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let status = Arc::new(NodeStatus::default());
    let status2 = status.clone();
    // Bind before returning so the caller knows the address is live.
    let listener = match &cfg.book {
        Some(book) => {
            let l = Listener::start(SocketAddr::from(([127, 0, 0, 1], 0)))?;
            book.register(cfg.id, l.addr);
            l
        }
        None => Listener::start(addr_of(cfg.base_port, cfg.id))?,
    };
    let id = cfg.id;
    // The runtime engine loads in the node thread (PJRT is not Send);
    // block until it is ready so callers measure *protocol* time, not
    // compile time, and a bootstrap node is live before joiners start.
    let (ready_tx, ready_rx) = std::sync::mpsc::channel::<()>();
    let book = cfg.book.clone();
    let thread = std::thread::Builder::new()
        .name(format!("fedlay-node-{id}"))
        .spawn(move || {
            let report = run_node(cfg, listener, stop2, ready_tx, status2);
            // unregister on every exit path (incl. runtime errors), so
            // peers stop resolving a dead node's stale address
            if let Some(b) = book {
                b.unregister(id);
            }
            report
        })?;
    let _ = ready_rx.recv_timeout(std::time::Duration::from_secs(120));
    Ok(ClientHandle {
        id,
        status,
        stop,
        thread: Some(thread),
    })
}

/// Reactor timer kinds: the NDMP tick granularity (heartbeats, failure
/// detection, repair probes) and the MEP train/offer/aggregate period.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum NodeEvent {
    NdmpTick,
    MepRound,
}

/// The per-node reactor state: protocol engines, model, MEP bookkeeping,
/// and the published status. Driven by `run_node`'s event loop.
struct Reactor<'e> {
    cfg: &'e ClientNodeConfig,
    engine: &'e Engine,
    batch: usize,
    k_max: usize,
    pool: PeerPool,
    ndmp: NodeState,
    task: GaussianTask,
    rng: Rng,
    params: Vec<f32>,
    version: u64,
    my_conf: f32,
    c_d: f64,
    c_c: f64,
    conf: ConfidenceParams,
    /// Latest model received per neighbor, for this node's own task only
    /// (foreign-task payloads are dropped at the frame boundary).
    neighbor_models: HashMap<NodeId, NeighborModel>,
    /// Fingerprints already offered, keyed `(neighbor, task)`.
    offered: FingerprintCache,
    /// Neighbor set at the last tick, to detect peer expiry: departed
    /// peers' dedup entries and cached models are dropped so a repaired
    /// overlay never keeps aggregating a dead neighbor's stale model.
    known_neighbors: BTreeSet<NodeId>,
    model_bytes_sent: u64,
    dedup_skips: u64,
    mep_sent: u64,
    /// Inbound models rejected by the non-finite guard (never cached, so
    /// NaN can never reach this node's aggregation or its own params).
    rejected_models: u64,
    /// `FEDLAY_NET_DEBUG` resolved once at construction: env lookups take
    /// a process-global lock, far too hot for the per-frame path.
    debug: bool,
    status: Arc<NodeStatus>,
    start: Instant,
}

impl Reactor<'_> {
    fn now_us(&self) -> Time {
        self.start.elapsed().as_micros() as Time
    }

    /// Mirror protocol state into the shared `NodeStatus`.
    fn publish(&self) {
        self.status.joined.store(self.ndmp.joined, Ordering::Relaxed);
        self.status.data_sent.store(self.mep_sent, Ordering::Relaxed);
        *self.status.neighbors.lock().unwrap() = self.ndmp.neighbor_ids();
        *self.status.ring.lock().unwrap() = self.ndmp.ring_neighbor_ids();
    }

    /// Cache one inbound neighbor model — unless anything about it is
    /// non-finite, in which case it is counted and dropped at the frame
    /// boundary. This is the TCP path's Byzantine guard: a poisoned (or
    /// bit-flipped) payload must never be stored, because a single NaN
    /// row fed to the aggregation kernel would poison this node's own
    /// parameters on the next round.
    fn accept_model(&mut self, from: NodeId, confidence: f32, params: Vec<f32>) {
        if !confidence.is_finite() || params.iter().any(|v| !v.is_finite()) {
            self.rejected_models += 1;
            return;
        }
        self.neighbor_models
            .insert(from, NeighborModel { confidence, params });
    }

    /// One inbound frame: MEP messages are handled here, everything else
    /// goes to the NDMP engine and its replies onto the wire.
    fn handle_frame(&mut self, from: NodeId, msg: Msg) {
        if self.debug {
            eprintln!("[node {}] recv from {} : {:?}", self.cfg.id, from, &msg);
        }
        match &msg {
            Msg::ModelOffer {
                task,
                fingerprint: fp,
                confidence: _,
                version: v,
            } => {
                if *task != self.cfg.task_id {
                    return; // another task's exchange rides the same overlay
                }
                let known = self
                    .neighbor_models
                    .get(&from)
                    .map(|m| fingerprint(&m.params) == *fp)
                    .unwrap_or(false);
                if known {
                    self.dedup_skips += 1;
                } else {
                    self.mep_sent += 1;
                    self.pool.send(
                        from,
                        &Msg::ModelRequest {
                            task: *task,
                            version: *v,
                        },
                    );
                }
            }
            Msg::ModelRequest { task, .. } => {
                if *task != self.cfg.task_id {
                    return; // never answer with another task's parameters
                }
                self.mep_sent += 1;
                let reply = self.payload_reply(*task);
                self.pool.send(from, &reply);
                self.model_bytes_sent +=
                    self.cfg.compression.payload_bytes(self.params.len()) as u64;
            }
            Msg::ModelPayload {
                task,
                version: _,
                confidence,
                params: p,
            } => {
                if *task != self.cfg.task_id {
                    return; // foreign-task payloads must never be aggregated
                }
                self.accept_model(from, *confidence, p.clone());
            }
            Msg::ModelPayloadQ8 {
                task,
                version: _,
                confidence,
                scale,
                levels,
            } => {
                if *task != self.cfg.task_id {
                    return;
                }
                let params = dequantize_q8(*scale, levels);
                self.accept_model(from, *confidence, params);
            }
            Msg::ModelPayloadTopK {
                task,
                version: _,
                confidence,
                dim,
                indices,
                values,
            } => {
                if *task != self.cfg.task_id {
                    return;
                }
                let params = densify_topk(*dim as usize, indices, values);
                self.accept_model(from, *confidence, params);
            }
            _ => {
                let now = self.now_us();
                let outs = self.ndmp.handle(from, msg.clone(), now);
                for o in outs {
                    self.pool.send(o.to, &o.msg);
                }
            }
        }
    }

    /// Encode this node's current model as a payload frame under the
    /// configured wire scheme (`Compression::None` stays the dense
    /// `ModelPayload` the fleet always spoke).
    fn payload_reply(&self, task: u32) -> Msg {
        match self.cfg.compression {
            Compression::None => Msg::ModelPayload {
                task,
                version: self.version,
                confidence: self.my_conf,
                params: self.params.clone(),
            },
            Compression::Q8 => {
                let (scale, levels) = quantize_q8(&self.params);
                Msg::ModelPayloadQ8 {
                    task,
                    version: self.version,
                    confidence: self.my_conf,
                    scale,
                    levels,
                }
            }
            Compression::TopK { .. } => {
                let keep = self.cfg.compression.kept(self.params.len());
                let (indices, values) = sparsify_topk(&self.params, keep);
                Msg::ModelPayloadTopK {
                    task,
                    version: self.version,
                    confidence: self.my_conf,
                    dim: self.params.len() as u32,
                    indices,
                    values,
                }
            }
        }
    }

    /// NDMP timer granularity: heartbeats, failure detection, probes.
    /// After the tick, expire MEP peer state for neighbors the protocol
    /// dropped: their cached model leaves the aggregation set and their
    /// dedup entry is forgotten for *this* task only (`forget_task`), so
    /// on a multi-task node one task's expiry never evicts another
    /// task's entries.
    fn ndmp_tick(&mut self) {
        let now = self.now_us();
        let outs = self.ndmp.tick(now);
        for o in outs {
            self.pool.send(o.to, &o.msg);
        }
        let current = self.ndmp.neighbor_ids();
        for departed in self.known_neighbors.difference(&current) {
            self.neighbor_models.remove(departed);
            self.offered.forget_task(*departed, self.cfg.task_id);
        }
        self.known_neighbors = current;
    }

    /// One MEP period: local training, fingerprint-first offers to all
    /// overlay neighbors (§III-C3), and confidence-weighted aggregation
    /// of whatever neighbor models arrived (§III-C2).
    fn mep_round(&mut self) -> Result<()> {
        for _ in 0..self.cfg.local_steps {
            let batch = self
                .task
                .batch(self.batch, &self.cfg.label_weights, &mut self.rng);
            let (new, _) = self.engine.train_step(
                &self.cfg.task,
                &self.params,
                &XInput::F32(&batch.x),
                &batch.y,
                self.cfg.lr,
            )?;
            self.params = new;
        }
        self.version += 1;
        let fp = fingerprint(&self.params);
        let task = self.cfg.task_id;
        for n in self.ndmp.neighbor_ids() {
            if self.offered.is_duplicate(n, task, fp) {
                self.dedup_skips += 1;
                continue;
            }
            self.offered.record(n, task, fp);
            self.mep_sent += 1;
            self.pool.send(
                n,
                &Msg::ModelOffer {
                    task,
                    fingerprint: fp,
                    confidence: self.my_conf,
                    version: self.version,
                },
            );
        }
        if !self.neighbor_models.is_empty() {
            let hood: Vec<(f64, f64)> = std::iter::once((self.c_d, self.c_c))
                .chain(
                    self.neighbor_models
                        .values()
                        .map(|m| (m.confidence as f64, self.c_c)),
                )
                .collect();
            let weights: Vec<f64> = hood
                .iter()
                .map(|&own| self.conf.combine(own, &hood))
                .collect();
            let models: Vec<&[f32]> = std::iter::once(self.params.as_slice())
                .chain(self.neighbor_models.values().map(|m| m.params.as_slice()))
                .collect();
            // cached neighbor models are guarded on arrival, so every
            // row here is finite; dispatch on the configured rule, with
            // Mean keeping the historical AOT-kernel hot path
            let new = match self.cfg.aggregation {
                Aggregation::Mean if models.len() <= self.k_max => {
                    let (stack, w) = pack_for_artifact(&models, &weights, self.k_max);
                    self.engine.aggregate(&self.cfg.task, &stack, &w)?
                }
                agg => agg.apply(&models, &weights),
            };
            self.params = new;
            self.version += 1;
        }
        self.status.exchanges.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }
}

fn run_node(
    cfg: ClientNodeConfig,
    mut listener: Listener,
    stop: Arc<AtomicBool>,
    ready_tx: std::sync::mpsc::Sender<()>,
    status: Arc<NodeStatus>,
) -> Result<ClientReport> {
    let engine = Engine::load(&cfg.artifacts_dir, &[&cfg.task])?;
    let _ = ready_tx.send(());
    let info = engine.manifest.task(&cfg.task)?.clone();
    let pool = match &cfg.book {
        Some(book) => PeerPool::with_book(cfg.id, book.clone()),
        None => PeerPool::new(cfg.base_port, cfg.id),
    };
    let start = Instant::now();

    // --- NDMP state ---
    let mut ndmp = NodeState::new(cfg.id, cfg.overlay.clone(), 0);
    match cfg.bootstrap {
        None => ndmp.bootstrap_first(),
        Some(b) => {
            let now = start.elapsed().as_micros() as Time;
            for o in ndmp.start_join(b, now) {
                pool.send(o.to, &o.msg);
            }
        }
    }

    // --- MEP / training state ---
    let task = GaussianTask::mnist_like(cfg.seed);
    let rng = Rng::new(cfg.seed ^ cfg.id);
    // shared initialization across the fleet (see dfl::trainer)
    let params = engine.init(&cfg.task, [cfg.seed as u32, 0])?;
    let hist = crate::data::expected_histogram(&cfg.label_weights, 10_000);
    let c_d = (-crate::data::kl_divergence_vs_uniform(&hist)).exp();
    let c_c = 1.0 / cfg.period_ms as f64;
    let my_conf = (0.5 * c_d + 0.5 * c_c * cfg.period_ms as f64) as f32; // normalized-ish

    let mut r = Reactor {
        cfg: &cfg,
        engine: &engine,
        batch: info.batch,
        k_max: engine.manifest.k_max,
        pool,
        ndmp,
        task,
        rng,
        params,
        version: 0,
        my_conf,
        c_d,
        c_c,
        conf: ConfidenceParams::default(),
        neighbor_models: HashMap::new(),
        offered: FingerprintCache::new(),
        known_neighbors: BTreeSet::new(),
        model_bytes_sent: 0,
        dedup_skips: 0,
        mep_sent: 0,
        rejected_models: 0,
        debug: std::env::var("FEDLAY_NET_DEBUG").is_ok(),
        status,
        start,
    };
    r.publish();

    // --- the event-pumped reactor ---
    // Timers live on the same deterministic scheduler as the simulator;
    // the tick granularity matches sim::Simulator (half the heartbeat).
    let tick_period: Time = (cfg.overlay.heartbeat_ms * 1_000 / 2).max(1_000);
    let period_us: Time = cfg.period_ms * 1_000;
    let mut timers: Scheduler<NodeEvent> = Scheduler::new();
    timers.push(tick_period, NodeEvent::NdmpTick);
    // stagger first exchanges so the fleet doesn't offer in lockstep
    timers.push(period_us / 2 + (cfg.id % 7) * 50 * MS, NodeEvent::MepRound);

    'reactor: loop {
        let next_at = timers.peek_time().expect("timer chains never drain");
        // pump inbound frames until the next timer is due
        loop {
            if stop.load(Ordering::SeqCst) {
                break 'reactor;
            }
            // Always drain the backlog first: even when the timer heap
            // has fallen behind wall clock (slow training rounds), every
            // timer firing is preceded by a full drain, so a busy chain
            // can never starve inbound protocol traffic. Wall-clock
            // nodes ignore the frames' virtual timing stamps — wall time
            // is the timer axis here.
            let mut drained = false;
            while let Ok(frame) = listener.rx.try_recv() {
                r.handle_frame(frame.sender, frame.msg);
                drained = true;
            }
            if drained {
                r.publish();
            }
            let now = r.now_us();
            if now >= next_at {
                break;
            }
            // cap the wait so a stop request is noticed promptly
            let wait = Duration::from_micros((next_at - now).min(5 * MS));
            match listener.rx.recv_timeout(wait) {
                Ok(frame) => {
                    r.handle_frame(frame.sender, frame.msg);
                    r.publish();
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break 'reactor,
            }
        }
        let ev = timers.pop().expect("peeked above");
        match ev.kind {
            NodeEvent::NdmpTick => {
                r.ndmp_tick();
                timers.push(ev.at + tick_period, NodeEvent::NdmpTick);
            }
            NodeEvent::MepRound => {
                r.mep_round()?;
                timers.push(ev.at + period_us, NodeEvent::MepRound);
            }
        }
        r.publish();
    }

    // final evaluation on the shared iid test set
    let mut correct = 0.0;
    let mut loss = 0.0;
    let evals = 2;
    for e in 0..evals {
        let b = r.task.test_batch(r.batch, cfg.seed ^ (0xE0 + e));
        let (c, l) = engine.eval_step(&cfg.task, &r.params, &XInput::F32(&b.x), &b.y)?;
        correct += c as f64;
        loss += l as f64;
    }
    listener.shutdown();
    r.pool.disconnect_all();
    Ok(ClientReport {
        id: cfg.id,
        accuracy: correct / (evals as usize * r.batch) as f64,
        loss: loss / evals as f64,
        neighbor_count: r.ndmp.neighbor_ids().len(),
        control_sent: r.ndmp.counters.control_sent
            + r.ndmp.counters.repair_sent
            + r.ndmp.counters.heartbeats_sent,
        data_sent: r.mep_sent,
        model_bytes_sent: r.model_bytes_sent,
        dedup_skips: r.dedup_skips,
        rejected_models: r.rejected_models,
        joined: r.ndmp.joined,
    })
}
