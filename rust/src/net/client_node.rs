//! A full FedLay client over real TCP: the NDMP protocol engine plus the
//! MEP offer/request/payload exchange and local training through the PJRT
//! runtime — the paper's §IV-A1 "real experiment" node, 16 of which form
//! the prototype (examples/prototype_16.rs).
//!
//! Each node runs in its own OS thread and owns a private `Engine` (the
//! PJRT client is not `Send`); all inter-node communication is real TCP
//! via `net::wire` frames. Wall-clock time drives NDMP timers and MEP
//! periods, exactly like a deployment.

use super::peer::{addr_of, PeerPool};
use super::server::Listener;
use crate::config::OverlayConfig;
use crate::data::GaussianTask;
use crate::mep::{fingerprint, pack_for_artifact, ConfidenceParams};
use crate::ndmp::messages::{Msg, Time};
use crate::ndmp::node::NodeState;
use crate::runtime::{Engine, XInput};
use crate::topology::NodeId;
use crate::util::Rng;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct ClientNodeConfig {
    pub id: NodeId,
    pub base_port: u16,
    /// `None` = bootstrap node (first in the network).
    pub bootstrap: Option<NodeId>,
    pub overlay: OverlayConfig,
    pub artifacts_dir: std::path::PathBuf,
    pub task: String,
    pub label_weights: Vec<f64>,
    pub lr: f32,
    pub local_steps: usize,
    /// MEP communication period (wall-clock ms; scaled-down prototype).
    pub period_ms: u64,
    pub seed: u64,
}

/// Final report returned when a node shuts down.
#[derive(Debug, Clone)]
pub struct ClientReport {
    pub id: NodeId,
    pub accuracy: f64,
    pub loss: f64,
    pub neighbor_count: usize,
    pub control_sent: u64,
    pub data_sent: u64,
    pub model_bytes_sent: u64,
    pub dedup_skips: u64,
    pub joined: bool,
}

struct NeighborModel {
    version: u64,
    confidence: f32,
    params: Vec<f32>,
}

pub struct ClientHandle {
    pub id: NodeId,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<Result<ClientReport>>>,
}

impl ClientHandle {
    pub fn stop_and_join(mut self) -> Result<ClientReport> {
        self.stop.store(true, Ordering::SeqCst);
        self.thread
            .take()
            .expect("already joined")
            .join()
            .map_err(|_| anyhow::anyhow!("client thread panicked"))?
    }
}

/// Spawn a client node thread. It binds its listener synchronously (so
/// callers can order bootstrap before joiners) and then runs until
/// `stop_and_join`.
pub fn spawn(cfg: ClientNodeConfig) -> Result<ClientHandle> {
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    // Bind before returning so the caller knows the port is live.
    let listener = Listener::start(addr_of(cfg.base_port, cfg.id))?;
    let id = cfg.id;
    // The PJRT engine compiles in the node thread (it is not Send); block
    // until it is ready so callers measure *protocol* time, not XLA
    // compile time, and a bootstrap node is live before joiners start.
    let (ready_tx, ready_rx) = std::sync::mpsc::channel::<()>();
    let thread = std::thread::Builder::new()
        .name(format!("fedlay-node-{id}"))
        .spawn(move || run_node(cfg, listener, stop2, ready_tx))?;
    let _ = ready_rx.recv_timeout(std::time::Duration::from_secs(120));
    Ok(ClientHandle {
        id,
        stop,
        thread: Some(thread),
    })
}

fn run_node(
    cfg: ClientNodeConfig,
    mut listener: Listener,
    stop: Arc<AtomicBool>,
    ready_tx: std::sync::mpsc::Sender<()>,
) -> Result<ClientReport> {
    let engine = Engine::load(&cfg.artifacts_dir, &[&cfg.task])?;
    let _ = ready_tx.send(());
    let info = engine.manifest.task(&cfg.task)?.clone();
    let k_max = engine.manifest.k_max;
    let pool = PeerPool::new(cfg.base_port, cfg.id);
    let start = Instant::now();
    let now_us = || start.elapsed().as_micros() as Time;

    // --- NDMP state ---
    let mut ndmp = NodeState::new(cfg.id, cfg.overlay.clone(), 0);
    match cfg.bootstrap {
        None => ndmp.bootstrap_first(),
        Some(b) => {
            for o in ndmp.start_join(b, now_us()) {
                pool.send(o.to, &o.msg);
            }
        }
    }

    // --- MEP / training state ---
    let task = GaussianTask::mnist_like(cfg.seed);
    let mut rng = Rng::new(cfg.seed ^ cfg.id);
    // shared initialization across the fleet (see dfl::trainer)
    let mut params = engine.init(&cfg.task, [cfg.seed as u32, 0])?;
    let mut version: u64 = 0;
    let hist = crate::data::expected_histogram(&cfg.label_weights, 10_000);
    let c_d = (-crate::data::kl_divergence_vs_uniform(&hist)).exp();
    let c_c = 1.0 / cfg.period_ms as f64;
    let my_conf = (0.5 * c_d + 0.5 * c_c * cfg.period_ms as f64) as f32; // normalized-ish
    let conf_params = ConfidenceParams::default();
    let mut neighbor_models: HashMap<NodeId, NeighborModel> = HashMap::new();
    let mut offered_fp: HashMap<NodeId, u64> = HashMap::new();
    let mut model_bytes_sent = 0u64;
    let mut dedup_skips = 0u64;
    let mut mep_sent = 0u64;
    let mut next_exchange = Duration::from_millis(cfg.period_ms / 2 + (cfg.id % 7) * 50);

    while !stop.load(Ordering::SeqCst) {
        // 1. drain inbound frames
        while let Ok((from, msg)) = listener.rx.try_recv() {
            if std::env::var("FEDLAY_NET_DEBUG").is_ok() {
                eprintln!("[node {}] recv from {} : {:?}", cfg.id, from, &msg);
            }
            match &msg {
                Msg::ModelOffer {
                    fingerprint: fp,
                    confidence: _,
                    version: v,
                } => {
                    let known = neighbor_models
                        .get(&from)
                        .map(|m| fingerprint(&m.params) == *fp)
                        .unwrap_or(false);
                    if known {
                        dedup_skips += 1;
                    } else {
                        mep_sent += 1;
                        pool.send(from, &Msg::ModelRequest { version: *v });
                    }
                }
                Msg::ModelRequest { .. } => {
                    mep_sent += 1;
                    pool.send(
                        from,
                        &Msg::ModelPayload {
                            version,
                            confidence: my_conf,
                            params: params.clone(),
                        },
                    );
                    model_bytes_sent += (params.len() * 4) as u64;
                }
                Msg::ModelPayload {
                    version: v,
                    confidence,
                    params: p,
                } => {
                    neighbor_models.insert(
                        from,
                        NeighborModel {
                            version: *v,
                            confidence: *confidence,
                            params: p.clone(),
                        },
                    );
                }
                _ => {
                    for o in ndmp.handle(from, msg.clone(), now_us()) {
                        pool.send(o.to, &o.msg);
                    }
                }
            }
        }
        // 2. NDMP timers
        for o in ndmp.tick(now_us()) {
            pool.send(o.to, &o.msg);
        }
        // 3. MEP period: train, offer, aggregate
        if start.elapsed() >= next_exchange {
            next_exchange += Duration::from_millis(cfg.period_ms);
            // local training
            for _ in 0..cfg.local_steps {
                let batch = task.batch(info.batch, &cfg.label_weights, &mut rng);
                let (new, _) = engine.train_step(
                    &cfg.task,
                    &params,
                    &XInput::F32(&batch.x),
                    &batch.y,
                    cfg.lr,
                )?;
                params = new;
            }
            version += 1;
            // offer to all overlay neighbors (fingerprint-first, §III-C3)
            let fp = fingerprint(&params);
            for n in ndmp.neighbor_ids() {
                if offered_fp.get(&n) == Some(&fp) {
                    dedup_skips += 1;
                    continue;
                }
                offered_fp.insert(n, fp);
                mep_sent += 1;
                pool.send(
                    n,
                    &Msg::ModelOffer {
                        fingerprint: fp,
                        confidence: my_conf,
                        version,
                    },
                );
            }
            // aggregate own + received neighbor models (MEP §III-C2)
            if !neighbor_models.is_empty() {
                let hood: Vec<(f64, f64)> = std::iter::once((c_d, c_c))
                    .chain(
                        neighbor_models
                            .values()
                            .map(|m| (m.confidence as f64, c_c)),
                    )
                    .collect();
                let weights: Vec<f64> = hood
                    .iter()
                    .map(|&own| conf_params.combine(own, &hood))
                    .collect();
                let models: Vec<&[f32]> = std::iter::once(params.as_slice())
                    .chain(neighbor_models.values().map(|m| m.params.as_slice()))
                    .collect();
                let new = if models.len() <= k_max {
                    let (stack, w) = pack_for_artifact(&models, &weights, k_max);
                    engine.aggregate(&cfg.task, &stack, &w)?
                } else {
                    crate::mep::aggregate_cpu(&models, &weights)
                };
                params = new;
                version += 1;
            }
        }
        std::thread::sleep(Duration::from_millis(5));
    }

    // final evaluation on the shared iid test set
    let mut correct = 0.0;
    let mut loss = 0.0;
    let evals = 2;
    for e in 0..evals {
        let b = task.test_batch(info.batch, cfg.seed ^ (0xE0 + e));
        let (c, l) = engine.eval_step(&cfg.task, &params, &XInput::F32(&b.x), &b.y)?;
        correct += c as f64;
        loss += l as f64;
    }
    listener.shutdown();
    pool.disconnect_all();
    let _ = neighbor_models
        .values()
        .map(|m| m.version)
        .max();
    Ok(ClientReport {
        id: cfg.id,
        accuracy: correct / (evals as usize * info.batch) as f64,
        loss: loss / evals as f64,
        neighbor_count: ndmp.neighbor_ids().len(),
        control_sent: ndmp.counters.control_sent
            + ndmp.counters.repair_sent
            + ndmp.counters.heartbeats_sent,
        data_sent: mep_sent,
        model_bytes_sent,
        dedup_skips,
        joined: ndmp.joined,
    })
}
