//! Real TCP transport (the paper's prototype path, §IV-A1 type 1): wire
//! codec, connection pool, listener, and the full TCP client node driving
//! the same NDMP/MEP protocol engines as the simulator.

pub mod client_node;
pub mod peer;
pub mod server;
pub mod wire;

pub use client_node::{spawn, ClientHandle, ClientNodeConfig, ClientReport};
pub use peer::{addr_of, PeerPool};
pub use server::Listener;
