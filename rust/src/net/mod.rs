//! Real TCP transport (the paper's prototype path, §IV-A1 type 1): wire
//! codec, connection pool + address book, listener, the scheduler-driven
//! socket backend (`sched_transport`, a `sim::Transport` implementation),
//! and the full TCP client node driving the same NDMP/MEP protocol
//! engines as the simulator.

pub mod client_node;
pub mod peer;
pub mod sched_transport;
pub mod server;
pub mod wire;

pub use client_node::{spawn, ClientHandle, ClientNodeConfig, ClientReport, NodeStatus};
pub use peer::{addr_of, AddrBook, PeerPool};
pub use sched_transport::SchedTransport;
pub use server::Listener;
pub use wire::{Frame, Stamp};
