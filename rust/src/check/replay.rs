//! Counterexample schedules as replayable text.
//!
//! The explorer's counterexamples print one [`Action`] per line (via
//! the `Display` impls in [`crate::check::model`]); this module parses
//! that text back and replays it two ways:
//!
//! * [`replay_abstract`] — through the abstract [`Model`], reproducing
//!   the exact violating state, and
//! * [`replay_concrete`] — through the real [`crate::sim::Simulator`]:
//!   the schedule's *churn* actions are scheduled as concrete events
//!   (tick/deliver steps belong to the concrete engine's own timers and
//!   transport) and the network is given ample quiet time, then judged
//!   with the shared [`crate::sim::invariants`] battery. A liveness
//!   counterexample must leave the concrete network unconverged under
//!   the same mutation, and converge cleanly without it — that is the
//!   refinement link between the swept model and the shipped engine.
//!
//! Format, one action per line (`#` comments and blank lines ignored):
//!
//! ```text
//! join 4 via 0
//! fail 2
//! leave 1
//! tick 3
//! deliver 1 2 update 0 prev 4
//! ```

use crate::check::model::{Action, Envelope, Model, ModelConfig};
use crate::config::NetConfig;
use crate::ndmp::{Dir, Msg, Side, SEC};
use crate::sim::invariants::{self, Violation};
use crate::sim::{quiesce, Simulator};
use crate::topology::NodeId;
use anyhow::{bail, Context, Result};
use std::collections::BTreeSet;

/// Render a schedule in the parseable text format.
pub fn format_schedule(schedule: &[Action]) -> String {
    let mut s = String::new();
    for a in schedule {
        s.push_str(&a.to_string());
        s.push('\n');
    }
    s
}

/// Parse a schedule produced by [`format_schedule`] (or hand-written).
pub fn parse_schedule(text: &str) -> Result<Vec<Action>> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        out.push(parse_action(line).with_context(|| format!("line {}: {line:?}", lineno + 1))?);
    }
    Ok(out)
}

fn parse_id(tok: &str) -> Result<NodeId> {
    tok.parse::<NodeId>()
        .with_context(|| format!("bad node id {tok:?}"))
}

fn parse_space(tok: &str) -> Result<u32> {
    tok.parse::<u32>()
        .with_context(|| format!("bad space {tok:?}"))
}

fn parse_side(tok: &str) -> Result<Side> {
    match tok {
        "prev" => Ok(Side::Prev),
        "next" => Ok(Side::Next),
        _ => bail!("bad side {tok:?} (want prev|next)"),
    }
}

fn parse_dir(tok: &str) -> Result<Dir> {
    match tok {
        "ccw" => Ok(Dir::Ccw),
        "cw" => Ok(Dir::Cw),
        _ => bail!("bad direction {tok:?} (want cw|ccw)"),
    }
}

/// Parse one schedule line.
pub fn parse_action(line: &str) -> Result<Action> {
    let toks: Vec<&str> = line.split_whitespace().collect();
    match toks.as_slice() {
        ["join", node, "via", bootstrap] => Ok(Action::Join {
            node: parse_id(node)?,
            bootstrap: parse_id(bootstrap)?,
        }),
        ["fail", node] => Ok(Action::Fail {
            node: parse_id(node)?,
        }),
        ["leave", node] => Ok(Action::Leave {
            node: parse_id(node)?,
        }),
        ["tick", node] => Ok(Action::Tick {
            node: parse_id(node)?,
        }),
        ["deliver", from, to, rest @ ..] => {
            let msg = match rest {
                ["discovery", joiner, space] => Msg::NeighborDiscovery {
                    joiner: parse_id(joiner)?,
                    space: parse_space(space)?,
                },
                ["result", space, prev, next] => Msg::DiscoveryResult {
                    space: parse_space(space)?,
                    prev: parse_id(prev)?,
                    next: parse_id(next)?,
                },
                ["update", space, side, node] => Msg::AdjacentUpdate {
                    space: parse_space(space)?,
                    side: parse_side(side)?,
                    node: parse_id(node)?,
                },
                ["leavemsg", space, side, other] => Msg::Leave {
                    space: parse_space(space)?,
                    side: parse_side(side)?,
                    other: parse_id(other)?,
                },
                ["heartbeat"] => Msg::Heartbeat,
                ["repair", origin, target, space, dir] => Msg::NeighborRepair {
                    origin: parse_id(origin)?,
                    target: parse_id(target)?,
                    space: parse_space(space)?,
                    dir: parse_dir(dir)?,
                },
                ["stop", space, dir] => Msg::RepairStop {
                    space: parse_space(space)?,
                    dir: parse_dir(dir)?,
                },
                _ => bail!("bad message tokens {rest:?}"),
            };
            Ok(Action::Deliver(Envelope {
                from: parse_id(from)?,
                to: parse_id(to)?,
                msg,
            }))
        }
        _ => bail!("unrecognized action"),
    }
}

/// Replay a schedule through the abstract model, returning the state it
/// lands in. Panics (via [`Model::apply`]) if the schedule does not fit
/// `cfg` — a stale fixture.
pub fn replay_abstract(cfg: &ModelConfig, schedule: &[Action]) -> Model {
    let mut m = Model::init(cfg.clone());
    for a in schedule {
        m.apply(a);
    }
    m
}

/// Verdict of a concrete replay.
#[derive(Debug, Clone)]
pub struct ConcreteReplay {
    /// `quiesce` found a stable correct overlay before the deadline.
    pub converged: bool,
    /// Final Definition-1 correctness.
    pub correctness: f64,
    /// Shared invariant battery on the final state: membership
    /// arithmetic plus the converged-ring checks.
    pub violations: Vec<Violation>,
}

/// Replay the *churn* of a schedule against the real simulator under
/// the same mutation the abstract sweep used (see module docs). Churn
/// events are spaced 2 s apart so each lands on a settled network —
/// the abstract counterexamples injected here are states the protocol
/// cannot recover from no matter the interleaving, so adversarial
/// timing is not needed to reproduce them.
pub fn replay_concrete(cfg: &ModelConfig, schedule: &[Action]) -> ConcreteReplay {
    let overlay = crate::config::OverlayConfig {
        spaces: cfg.spaces,
        heartbeat_ms: 500,
        failure_multiple: 3,
        repair_probe_ms: 2_000,
    };
    let mut sim = Simulator::new(overlay, NetConfig::default());
    sim.set_mutation(cfg.mutation);
    let initial = cfg.initial_ids();
    sim.bootstrap_correct(&initial);

    let mut expected: BTreeSet<NodeId> = initial.into_iter().collect();
    let mut t = 0;
    for a in schedule {
        if !a.is_churn() {
            continue;
        }
        t += 2 * SEC;
        match a {
            Action::Join { node, bootstrap } => {
                sim.schedule_join(t, *node, *bootstrap);
                expected.insert(*node);
            }
            Action::Fail { node } => {
                sim.schedule_fail(t, *node);
                expected.remove(node);
            }
            Action::Leave { node } => {
                sim.schedule_leave(t, *node);
                expected.remove(node);
            }
            _ => unreachable!(),
        }
    }

    let converged = quiesce(&mut sim, t + 240 * SEC, 2 * SEC).is_some();
    let live: BTreeSet<NodeId> = sim.node_ids().into_iter().collect();
    let mut violations = invariants::membership_violations(&live, &expected);
    violations.extend(invariants::converged_ring_violations(
        &sim.ring_snapshot(),
        cfg.spaces,
    ));
    ConcreteReplay {
        converged,
        correctness: sim.correctness(),
        violations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ndmp::node::Mutation;

    #[test]
    fn schedule_text_round_trips() {
        let text = "\
# a comment
join 4 via 0

fail 2
leave 1
tick 3
deliver 1 2 update 0 prev 4
deliver 0 3 repair 0 0 1 ccw
deliver 2 0 stop 1 cw
deliver 3 1 discovery 4 0
deliver 0 4 result 1 2 3
deliver 1 0 leavemsg 0 next 2
deliver 0 1 heartbeat
";
        let schedule = parse_schedule(text).unwrap();
        assert_eq!(schedule.len(), 12);
        let rendered = format_schedule(&schedule);
        assert_eq!(parse_schedule(&rendered).unwrap(), schedule);
    }

    #[test]
    fn bad_lines_are_rejected_with_context() {
        for bad in ["join 4", "deliver 1 2 bogus", "fail x", "tick"] {
            assert!(parse_schedule(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn abstract_replay_reaches_the_scheduled_state() {
        let cfg = ModelConfig {
            n: 3,
            spaces: 1,
            joins: 0,
            fails: 1,
            leaves: 0,
            mutation: Mutation::None,
        };
        let m = replay_abstract(&cfg, &parse_schedule("fail 2").unwrap());
        assert_eq!(m.nodes.len(), 2);
        assert_eq!(m.fails_left, 0);
        assert!(!m.converged(), "survivors still track the dead node");
    }
}
