//! Abstract NDMP model: the real protocol engines under abstracted time.
//!
//! A [`Model`] is one state of the whole network — the live fleet's
//! [`NodeState`] machines, the multiset of in-flight control messages,
//! the ids still waiting to join, and the remaining churn budgets. The
//! message handlers are **the shipped `ndmp::node` code**, not a
//! re-implementation: what the explorer sweeps is the protocol the
//! simulator and the TCP prototype run.
//!
//! Time is abstracted away, which is what makes the interleaving space
//! finite:
//!
//! * every handler runs at `now = 0`, so `last_seen` stamps and the
//!   heartbeat/probe timers are never consulted;
//! * heartbeats never enter the in-flight multiset (they carry no
//!   protocol state — their only job, failure detection, is replaced by
//!   a global-liveness oracle);
//! * [`Action::Tick`] condenses the periodic driver into "purge peers
//!   the oracle says are dead, then self-probe if the views are off the
//!   ideal", and is *enabled* only while the node has such work and has
//!   no repair traffic outstanding — otherwise re-probing could grow the
//!   multiset without bound.
//!
//! Because no transition reads a timestamp or a counter, two states with
//! equal [`Model::canonical_key`] encodings (which skip those fields)
//! have identical futures — the dedup-soundness argument spelled out in
//! `docs/model-checking.md`.

use crate::config::OverlayConfig;
use crate::ndmp::node::{Mutation, NodeState, PeerInfo, SpaceView};
use crate::ndmp::{Dir, Msg, Outgoing, Side};
use crate::topology::{Membership, NeighborSnapshot, NodeId};
use std::cmp::Ordering;
use std::collections::{BTreeMap, BTreeSet};
use std::fmt;

/// Exploration scenario: universe size, ring spaces, churn budgets, and
/// the injected [`Mutation`] (`None` for the clean protocol).
#[derive(Debug, Clone)]
pub struct ModelConfig {
    /// Universe size: node ids `0..n`. The last `joins` ids start
    /// *pending* (they enter mid-exploration through the join protocol);
    /// the first `n - joins` are live in the bootstrapped initial rings.
    pub n: usize,
    /// Virtual ring spaces `L` (degree bound `2L`).
    pub spaces: usize,
    /// How many universe ids start pending.
    pub joins: usize,
    /// Crash-failure budget.
    pub fails: usize,
    /// Graceful-leave budget.
    pub leaves: usize,
    /// Fault injection installed on every node (`Mutation::None` sweeps
    /// the unmodified protocol).
    pub mutation: Mutation,
}

impl Default for ModelConfig {
    fn default() -> Self {
        Self {
            n: 4,
            spaces: 2,
            joins: 1,
            fails: 1,
            leaves: 1,
            mutation: Mutation::None,
        }
    }
}

impl ModelConfig {
    /// The overlay parameters the abstract fleet runs under. Timer
    /// periods are irrelevant (time is abstracted) but kept at the
    /// defaults so a concrete replay can reuse the same struct.
    pub fn overlay(&self) -> OverlayConfig {
        OverlayConfig {
            spaces: self.spaces,
            ..OverlayConfig::default()
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.n >= 2, "need a universe of at least 2 ids");
        anyhow::ensure!(
            self.n <= 32,
            "universe of {} ids is beyond exhaustive reach (max 32)",
            self.n
        );
        anyhow::ensure!(self.spaces >= 1 && self.spaces <= 4, "spaces must be 1..=4");
        anyhow::ensure!(
            self.joins < self.n,
            "at least one id must be live initially (joins < n)"
        );
        Ok(())
    }

    /// The ids live in the bootstrapped initial state.
    pub fn initial_ids(&self) -> Vec<NodeId> {
        (0..(self.n - self.joins) as NodeId).collect()
    }
}

/// One in-flight protocol message. Delivery removes one instance of
/// exactly this `(from, to, msg)` value from the multiset — mirroring
/// the simulator, a message addressed to a dead node vanishes.
#[derive(Debug, Clone, PartialEq)]
pub struct Envelope {
    pub from: NodeId,
    pub to: NodeId,
    pub msg: Msg,
}

// Control messages carry no floats, so value equality is total here.
impl Eq for Envelope {}

impl Ord for Envelope {
    fn cmp(&self, other: &Self) -> Ordering {
        (self.from, self.to, msg_rank(&self.msg)).cmp(&(
            other.from,
            other.to,
            msg_rank(&other.msg),
        ))
    }
}

impl PartialOrd for Envelope {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

fn side_rank(side: Side) -> u64 {
    match side {
        Side::Prev => 0,
        Side::Next => 1,
    }
}

fn dir_rank(dir: Dir) -> u64 {
    match dir {
        Dir::Ccw => 0,
        Dir::Cw => 1,
    }
}

/// Total order key over the control subset of [`Msg`] (injective per
/// variant), used for the canonical multiset order and the byte
/// encoding. MEP payload variants never enter the abstract model.
fn msg_rank(msg: &Msg) -> (u8, u64, u64, u64) {
    match msg {
        Msg::NeighborDiscovery { joiner, space } => (0, *joiner, *space as u64, 0),
        Msg::DiscoveryResult { space, prev, next } => (1, *space as u64, *prev, *next),
        Msg::AdjacentUpdate { space, side, node } => (2, *space as u64, side_rank(*side), *node),
        Msg::Leave { space, side, other } => (3, *space as u64, side_rank(*side), *other),
        Msg::Heartbeat => (4, 0, 0, 0),
        Msg::NeighborRepair {
            origin,
            target,
            space,
            dir,
        } => (5, *origin, *target, *space as u64 * 2 + dir_rank(*dir)),
        Msg::RepairStop { space, dir } => (6, *space as u64, dir_rank(*dir), 0),
        _ => (7, 0, 0, 0),
    }
}

fn side_token(side: Side) -> &'static str {
    match side {
        Side::Prev => "prev",
        Side::Next => "next",
    }
}

fn dir_token(dir: Dir) -> &'static str {
    match dir {
        Dir::Ccw => "ccw",
        Dir::Cw => "cw",
    }
}

/// The schedule-text token of a control message (parsed back by
/// [`crate::check::replay::parse_schedule`]).
pub fn msg_token(msg: &Msg) -> String {
    match msg {
        Msg::NeighborDiscovery { joiner, space } => format!("discovery {joiner} {space}"),
        Msg::DiscoveryResult { space, prev, next } => format!("result {space} {prev} {next}"),
        Msg::AdjacentUpdate { space, side, node } => {
            format!("update {space} {} {node}", side_token(*side))
        }
        Msg::Leave { space, side, other } => {
            format!("leavemsg {space} {} {other}", side_token(*side))
        }
        Msg::Heartbeat => "heartbeat".to_string(),
        Msg::NeighborRepair {
            origin,
            target,
            space,
            dir,
        } => format!("repair {origin} {target} {space} {}", dir_token(*dir)),
        Msg::RepairStop { space, dir } => format!("stop {space} {}", dir_token(*dir)),
        _ => "mep".to_string(),
    }
}

/// One step of a schedule: the enumerable transition alphabet.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Action {
    /// A pending id starts the join protocol through a live bootstrap.
    Join { node: NodeId, bootstrap: NodeId },
    /// A live node crash-fails (silent; in-flight messages to it vanish
    /// on delivery).
    Fail { node: NodeId },
    /// A live node departs gracefully (its `Leave` notices go in flight,
    /// then it is gone).
    Leave { node: NodeId },
    /// The maintenance oracle fires at one node: purge globally-dead
    /// peers (emitting directional repair probes) and self-probe if the
    /// views are off the ideal adjacency.
    Tick { node: NodeId },
    /// Deliver one in-flight message.
    Deliver(Envelope),
}

impl Action {
    /// Churn actions are excluded from the liveness subgraph ("every
    /// schedule with no *further* churn reaches correctness 1.0").
    pub fn is_churn(&self) -> bool {
        matches!(
            self,
            Action::Join { .. } | Action::Fail { .. } | Action::Leave { .. }
        )
    }
}

impl fmt::Display for Action {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Action::Join { node, bootstrap } => write!(f, "join {node} via {bootstrap}"),
            Action::Fail { node } => write!(f, "fail {node}"),
            Action::Leave { node } => write!(f, "leave {node}"),
            Action::Tick { node } => write!(f, "tick {node}"),
            Action::Deliver(e) => write!(f, "deliver {} {} {}", e.from, e.to, msg_token(&e.msg)),
        }
    }
}

/// Per-side ideal adjacency (the exact `SpaceView` per space) for every
/// id of a live set: what a fully converged node's views must equal.
/// Computed the same way `Simulator::bootstrap_correct` seeds a correct
/// network — one `Membership` ring sort per space.
pub fn ideal_views(ids: &[NodeId], spaces: usize) -> BTreeMap<NodeId, Vec<SpaceView>> {
    let mut m = Membership::new(spaces);
    for &id in ids {
        m.add(id);
    }
    let mut tabs: Vec<BTreeMap<NodeId, (NodeId, NodeId)>> = Vec::with_capacity(spaces);
    for s in 0..spaces {
        let ring = m.ring(s);
        let n = ring.len();
        let mut tab = BTreeMap::new();
        if n >= 2 {
            for pos in 0..n {
                tab.insert(
                    ring[pos].id,
                    (ring[(pos + n - 1) % n].id, ring[(pos + 1) % n].id),
                );
            }
        }
        tabs.push(tab);
    }
    ids.iter()
        .map(|&id| {
            let views = (0..spaces)
                .map(|s| match tabs[s].get(&id) {
                    Some(&(prev, next)) => SpaceView {
                        prev: Some(prev),
                        next: Some(next),
                    },
                    None => SpaceView::default(),
                })
                .collect();
            (id, views)
        })
        .collect()
}

/// One abstract network state. See the module docs for the time
/// abstraction and the finiteness argument.
#[derive(Debug, Clone)]
pub struct Model {
    pub cfg: ModelConfig,
    /// Live protocol engines, keyed by id.
    pub nodes: BTreeMap<NodeId, NodeState>,
    /// Universe ids that have not joined yet.
    pub pending: BTreeSet<NodeId>,
    pub fails_left: usize,
    pub leaves_left: usize,
    /// In-flight control messages, kept sorted (canonical multiset).
    pub inflight: Vec<Envelope>,
}

impl Model {
    /// The initial state: the first `n - joins` ids bootstrapped into
    /// ideal rings (mirroring `Simulator::bootstrap_correct` — ideal
    /// per-side views, peer tables seeded from the views), the rest
    /// pending, nothing in flight.
    pub fn init(cfg: ModelConfig) -> Self {
        let overlay = cfg.overlay();
        let initial = cfg.initial_ids();
        let pending: BTreeSet<NodeId> =
            ((cfg.n - cfg.joins) as NodeId..cfg.n as NodeId).collect();
        let ideal = ideal_views(&initial, cfg.spaces);
        let mut nodes = BTreeMap::new();
        for &id in &initial {
            let mut st = NodeState::new(id, overlay.clone(), 0);
            st.mutation = cfg.mutation;
            st.bootstrap_first();
            st.views = ideal[&id].clone();
            for v in ideal[&id].clone() {
                for peer in [v.prev, v.next].into_iter().flatten() {
                    st.peers.entry(peer).or_insert(PeerInfo { last_seen: 0 });
                }
            }
            nodes.insert(id, st);
        }
        Model {
            fails_left: cfg.fails,
            leaves_left: cfg.leaves,
            cfg,
            nodes,
            pending,
            inflight: Vec::new(),
        }
    }

    pub fn live_ids(&self) -> Vec<NodeId> {
        self.nodes.keys().copied().collect()
    }

    /// Ring-adjacency snapshot of the live fleet, for the shared
    /// [`crate::sim::invariants`] predicates.
    pub fn ring_snapshot(&self) -> NeighborSnapshot {
        self.nodes
            .iter()
            .map(|(&id, st)| (id, st.ring_neighbor_ids()))
            .collect()
    }

    /// Does `u` have maintenance work: a peer the global-liveness oracle
    /// knows is dead, or views off the ideal per-side adjacency?
    fn tick_work(&self, u: NodeId, ideal: &BTreeMap<NodeId, Vec<SpaceView>>) -> bool {
        let st = &self.nodes[&u];
        let has_dead_peer = st.peers.keys().any(|p| !self.nodes.contains_key(p));
        has_dead_peer || st.views != ideal[&u]
    }

    /// Finiteness gate: `u` still has repair traffic outstanding — a
    /// probe it originated, or a `RepairStop` addressed to it. Ticking
    /// again before that drains would accumulate probes without bound.
    fn repair_outstanding(&self, u: NodeId) -> bool {
        self.inflight.iter().any(|e| match &e.msg {
            Msg::NeighborRepair { origin, .. } => *origin == u,
            Msg::RepairStop { .. } => e.to == u,
            _ => false,
        })
    }

    /// Every enabled action, in a deterministic canonical order: churn
    /// (joins, fails, leaves), then ticks, then one `Deliver` per
    /// *distinct* in-flight envelope.
    pub fn enabled_actions(&self) -> Vec<Action> {
        let mut out = Vec::new();
        if !self.nodes.is_empty() {
            for &j in &self.pending {
                for &b in self.nodes.keys() {
                    out.push(Action::Join { node: j, bootstrap: b });
                }
            }
        }
        // keep at least one node alive so the network never vanishes
        if self.nodes.len() >= 2 {
            if self.fails_left > 0 {
                for &u in self.nodes.keys() {
                    out.push(Action::Fail { node: u });
                }
            }
            if self.leaves_left > 0 {
                for &u in self.nodes.keys() {
                    out.push(Action::Leave { node: u });
                }
            }
        }
        let ideal = ideal_views(&self.live_ids(), self.cfg.spaces);
        for &u in self.nodes.keys() {
            if self.tick_work(u, &ideal) && !self.repair_outstanding(u) {
                out.push(Action::Tick { node: u });
            }
        }
        let mut prev: Option<&Envelope> = None;
        for e in &self.inflight {
            if prev != Some(e) {
                out.push(Action::Deliver(e.clone()));
            }
            prev = Some(e);
        }
        out
    }

    /// Apply one action. Panics if the action is not applicable in this
    /// state (a schedule replayed against the wrong state).
    pub fn apply(&mut self, a: &Action) {
        match a {
            Action::Join { node, bootstrap } => {
                assert!(self.pending.remove(node), "join of non-pending id {node}");
                assert!(
                    self.nodes.contains_key(bootstrap),
                    "join via dead bootstrap {bootstrap}"
                );
                let mut st = NodeState::new(*node, self.cfg.overlay(), 0);
                st.mutation = self.cfg.mutation;
                let outs = st.start_join(*bootstrap, 0);
                self.nodes.insert(*node, st);
                self.enqueue(*node, outs);
            }
            Action::Fail { node } => {
                self.nodes.remove(node).expect("fail of dead node");
                self.fails_left -= 1;
            }
            Action::Leave { node } => {
                let mut st = self.nodes.remove(node).expect("leave of dead node");
                let outs = st.start_leave();
                self.leaves_left -= 1;
                self.enqueue(*node, outs);
            }
            Action::Tick { node } => {
                let u = *node;
                let dead: Vec<NodeId> = self.nodes[&u]
                    .peers
                    .keys()
                    .filter(|p| !self.nodes.contains_key(*p))
                    .copied()
                    .collect();
                let mut outs = Vec::new();
                {
                    let st = self.nodes.get_mut(&u).expect("tick of dead node");
                    for d in &dead {
                        outs.extend(st.declare_failed(*d, 0));
                    }
                }
                // self-probe only if the purge left the views off the
                // ideal (a survivor of a 2-ring has nothing to repair)
                let ideal = ideal_views(&self.live_ids(), self.cfg.spaces);
                let st = self.nodes.get_mut(&u).expect("tick of dead node");
                if st.views != ideal[&u] {
                    outs.extend(st.emit_self_probes());
                }
                self.enqueue(u, outs);
            }
            Action::Deliver(env) => {
                let idx = self
                    .inflight
                    .iter()
                    .position(|e| e == env)
                    .expect("deliver of a message not in flight");
                self.inflight.remove(idx);
                // dead target: the message vanishes (crash-fail rule,
                // identical to the simulator's Deliver arm)
                if let Some(st) = self.nodes.get_mut(&env.to) {
                    let outs = st.handle(env.from, env.msg.clone(), 0);
                    self.enqueue(env.to, outs);
                }
            }
        }
    }

    fn enqueue(&mut self, from: NodeId, outs: Vec<Outgoing>) {
        for o in outs {
            // self-sends are dropped exactly like `Simulator::dispatch`;
            // heartbeats carry no protocol state and liveness is the
            // oracle's job, so they never enter the multiset
            if o.to == from || matches!(o.msg, Msg::Heartbeat) {
                continue;
            }
            self.inflight.push(Envelope {
                from,
                to: o.to,
                msg: o.msg,
            });
        }
        self.inflight.sort_unstable();
    }

    /// A state is *converged* when nothing is in flight, every peer
    /// table references live nodes only, and every node's per-side views
    /// equal the ideal adjacency — which makes Definition-1 correctness
    /// exactly 1.0 by construction (and implies ring symmetry and
    /// ghost-freedom; the explorer cross-checks that with the shared
    /// `sim::invariants` predicates).
    pub fn converged(&self) -> bool {
        if !self.inflight.is_empty() {
            return false;
        }
        let ideal = ideal_views(&self.live_ids(), self.cfg.spaces);
        self.nodes.iter().all(|(id, st)| {
            st.peers.keys().all(|p| self.nodes.contains_key(p)) && st.views == ideal[id]
        })
    }

    // ------------------------------------------------------------------
    // Canonical encoding
    // ------------------------------------------------------------------

    /// Canonical byte encoding of the behavior-relevant state: live ids
    /// with joined flags, per-space views, peer keysets, the pending
    /// set, churn budgets, and the sorted in-flight multiset. Timers,
    /// counters, and `last_seen` stamps are deliberately excluded — with
    /// time pinned to 0 no transition reads them, so equal encodings
    /// imply identical futures.
    pub fn canonical_key(&self) -> Vec<u8> {
        let id8 = |id: NodeId| -> u8 {
            debug_assert!(id < 255);
            id as u8
        };
        let slot8 = |slot: Option<NodeId>| -> u8 { slot.map(|w| w as u8 + 1).unwrap_or(0) };
        let mut k = Vec::with_capacity(64);
        k.push(self.nodes.len() as u8);
        for (&id, st) in &self.nodes {
            k.push(id8(id));
            k.push(st.joined as u8);
            for v in &st.views {
                k.push(slot8(v.prev));
                k.push(slot8(v.next));
            }
            k.push(st.peers.len() as u8);
            k.extend(st.peers.keys().map(|&p| id8(p)));
        }
        k.push(self.pending.len() as u8);
        k.extend(self.pending.iter().map(|&p| id8(p)));
        k.push(self.fails_left as u8);
        k.push(self.leaves_left as u8);
        k.extend((self.inflight.len() as u16).to_le_bytes());
        for e in &self.inflight {
            k.push(id8(e.from));
            k.push(id8(e.to));
            let (tag, a, b, c) = msg_rank(&e.msg);
            k.push(tag);
            k.push(a as u8);
            k.push(b as u8);
            k.push(c as u8);
        }
        k
    }

    /// Rebuild the full state from a canonical key (the explorer stores
    /// only keys — a `Model` per state would be memory-prohibitive).
    /// Exact inverse of [`Model::canonical_key`], pinned by a round-trip
    /// test.
    pub fn decode(cfg: &ModelConfig, key: &[u8]) -> Model {
        let overlay = cfg.overlay();
        let mut i = 0usize;
        let mut next = |i: &mut usize| -> u8 {
            let b = key[*i];
            *i += 1;
            b
        };
        let slot = |b: u8| -> Option<NodeId> {
            if b == 0 {
                None
            } else {
                Some(b as NodeId - 1)
            }
        };
        let n_live = next(&mut i) as usize;
        let mut nodes = BTreeMap::new();
        for _ in 0..n_live {
            let id = next(&mut i) as NodeId;
            let joined = next(&mut i) != 0;
            let mut st = NodeState::new(id, overlay.clone(), 0);
            st.mutation = cfg.mutation;
            st.joined = joined;
            for s in 0..cfg.spaces {
                let prev = slot(next(&mut i));
                let nextn = slot(next(&mut i));
                st.views[s] = SpaceView { prev, next: nextn };
            }
            let n_peers = next(&mut i) as usize;
            for _ in 0..n_peers {
                let p = next(&mut i) as NodeId;
                st.peers.insert(p, PeerInfo { last_seen: 0 });
            }
            nodes.insert(id, st);
        }
        let n_pending = next(&mut i) as usize;
        let mut pending = BTreeSet::new();
        for _ in 0..n_pending {
            pending.insert(next(&mut i) as NodeId);
        }
        let fails_left = next(&mut i) as usize;
        let leaves_left = next(&mut i) as usize;
        let n_msgs = u16::from_le_bytes([next(&mut i), next(&mut i)]) as usize;
        let mut inflight = Vec::with_capacity(n_msgs);
        for _ in 0..n_msgs {
            let from = next(&mut i) as NodeId;
            let to = next(&mut i) as NodeId;
            let tag = next(&mut i);
            let a = next(&mut i);
            let b = next(&mut i);
            let c = next(&mut i);
            inflight.push(Envelope {
                from,
                to,
                msg: decode_msg(tag, a, b, c),
            });
        }
        debug_assert_eq!(i, key.len(), "canonical key not fully consumed");
        Model {
            cfg: cfg.clone(),
            nodes,
            pending,
            fails_left,
            leaves_left,
            inflight,
        }
    }
}

fn decode_side(b: u8) -> Side {
    if b == 0 {
        Side::Prev
    } else {
        Side::Next
    }
}

fn decode_dir(b: u8) -> Dir {
    if b == 0 {
        Dir::Ccw
    } else {
        Dir::Cw
    }
}

fn decode_msg(tag: u8, a: u8, b: u8, c: u8) -> Msg {
    match tag {
        0 => Msg::NeighborDiscovery {
            joiner: a as NodeId,
            space: b as u32,
        },
        1 => Msg::DiscoveryResult {
            space: a as u32,
            prev: b as NodeId,
            next: c as NodeId,
        },
        2 => Msg::AdjacentUpdate {
            space: a as u32,
            side: decode_side(b),
            node: c as NodeId,
        },
        3 => Msg::Leave {
            space: a as u32,
            side: decode_side(b),
            other: c as NodeId,
        },
        4 => Msg::Heartbeat,
        5 => Msg::NeighborRepair {
            origin: a as NodeId,
            target: b as NodeId,
            space: (c / 2) as u32,
            dir: decode_dir(c % 2),
        },
        6 => Msg::RepairStop {
            space: a as u32,
            dir: decode_dir(b),
        },
        other => unreachable!("MEP tag {other} can never be in the abstract multiset"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_state_is_converged_and_stable() {
        for n in 2..=5 {
            for spaces in 1..=2 {
                let cfg = ModelConfig {
                    n,
                    spaces,
                    joins: 1,
                    fails: 0,
                    leaves: 0,
                    mutation: Mutation::None,
                };
                let m = Model::init(cfg);
                assert!(m.converged(), "n={n} L={spaces}: bootstrap not converged");
                // no ticks enabled: the only enabled actions are joins
                assert!(
                    m.enabled_actions().iter().all(Action::is_churn),
                    "n={n} L={spaces}: non-churn action enabled at the ideal state"
                );
            }
        }
    }

    #[test]
    fn canonical_key_round_trips_through_decode() {
        let cfg = ModelConfig::default();
        let mut m = Model::init(cfg.clone());
        // walk a few transitions to cover joins, deliveries, and churn
        for _ in 0..12 {
            let key = m.canonical_key();
            let back = Model::decode(&cfg, &key);
            assert_eq!(back.canonical_key(), key);
            assert_eq!(back.enabled_actions(), m.enabled_actions());
            let acts = m.enabled_actions();
            match acts.into_iter().next() {
                Some(a) => m.apply(&a),
                None => break,
            }
        }
    }

    #[test]
    fn join_then_drain_converges() {
        // deliver everything, tick anyone with work, repeat: the 2+1
        // network must reach the ideal 3-ring
        let cfg = ModelConfig {
            n: 3,
            spaces: 2,
            joins: 1,
            fails: 0,
            leaves: 0,
            mutation: Mutation::None,
        };
        let mut m = Model::init(cfg);
        m.apply(&Action::Join {
            node: 2,
            bootstrap: 0,
        });
        for _ in 0..500 {
            if m.converged() {
                break;
            }
            let a = m
                .enabled_actions()
                .into_iter()
                .find(|a| !a.is_churn())
                .expect("not converged but no non-churn action enabled");
            m.apply(&a);
        }
        assert!(m.converged(), "drain schedule did not converge");
        assert_eq!(m.nodes.len(), 3);
    }

    #[test]
    fn action_display_is_stable() {
        let e = Envelope {
            from: 1,
            to: 2,
            msg: Msg::NeighborRepair {
                origin: 1,
                target: 3,
                space: 1,
                dir: Dir::Ccw,
            },
        };
        assert_eq!(
            Action::Deliver(e).to_string(),
            "deliver 1 2 repair 1 3 1 ccw"
        );
        assert_eq!(
            Action::Join {
                node: 4,
                bootstrap: 0
            }
            .to_string(),
            "join 4 via 0"
        );
    }
}
