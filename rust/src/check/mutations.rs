//! Mutation harness: known-critical ring-repair lines, flipped behind
//! the test-only [`Mutation`] hook in `ndmp::node`, each paired with a
//! small scenario where the explorer is *guaranteed* to catch it.
//!
//! This is the checker checking itself: if a future refactor weakens
//! the explorer (or the tick gate accidentally masks real behavior),
//! the mutation battery in `tests/check_model.rs` fails because an
//! injected, known-real bug stops being detected.
//!
//! | mutation | broken line | caught as |
//! |---|---|---|
//! | `no-probes` | `fail_neighbor` / `tick` emit no self-probes | liveness: a failed adjacent's slot never heals |
//! | `adopt-farther` | `maybe_adopt` prefers the arc-*farther* candidate | liveness: the true adjacent is rejected forever |
//! | `flip-repair-sides` | repair terminal adopts on the wrong side (and `RepairStop` ditto) | liveness: correct adoptions monotone-rejected |
//! | `adopt-untracked` | adoption skips `track_peer` | safety: `view-not-tracked` on first update-before-discovery interleaving |

use crate::check::explore::ViolationKind;
use crate::check::model::ModelConfig;
use crate::ndmp::node::Mutation;

/// Every injectable mutation, in battery order.
pub const ALL: [Mutation; 4] = [
    Mutation::NoRepairProbes,
    Mutation::AdoptFarther,
    Mutation::RepairSidesFlipped,
    Mutation::AdoptUntracked,
];

/// Stable CLI / fixture name of a mutation.
pub fn name(m: Mutation) -> &'static str {
    match m {
        Mutation::None => "none",
        Mutation::NoRepairProbes => "no-probes",
        Mutation::AdoptFarther => "adopt-farther",
        Mutation::RepairSidesFlipped => "flip-repair-sides",
        Mutation::AdoptUntracked => "adopt-untracked",
    }
}

/// Inverse of [`name`].
pub fn parse(s: &str) -> Option<Mutation> {
    match s {
        "none" => Some(Mutation::None),
        "no-probes" => Some(Mutation::NoRepairProbes),
        "adopt-farther" => Some(Mutation::AdoptFarther),
        "flip-repair-sides" => Some(Mutation::RepairSidesFlipped),
        "adopt-untracked" => Some(Mutation::AdoptUntracked),
        _ => None,
    }
}

/// One-line description for `fedlay check --mutation` output.
pub fn describe(m: Mutation) -> &'static str {
    match m {
        Mutation::None => "unmodified protocol",
        Mutation::NoRepairProbes => "failure handling and tick emit no repair self-probes",
        Mutation::AdoptFarther => "repair adoption prefers the arc-farther candidate",
        Mutation::RepairSidesFlipped => "repair terminal and RepairStop adopt on the wrong side",
        Mutation::AdoptUntracked => "repair adoption skips peer tracking",
    }
}

/// The smallest scenario on which the explorer provably detects `m`
/// (argued case-by-case in `docs/model-checking.md`). Detection configs
/// deliberately use `spaces = 1`: the per-side convergence predicate
/// already distinguishes flipped sides, and one space keeps the
/// guaranteed-detection sweep in the low thousands of states.
pub fn detection_config(m: Mutation) -> ModelConfig {
    let (n, joins, fails) = match m {
        // a crash with no probes leaves per-side `None` slots that
        // nothing can ever heal
        Mutation::NoRepairProbes => (4, 0, 1),
        // the displaced node can never adopt the closer joiner
        Mutation::AdoptFarther => (3, 1, 0),
        // needs 3+ survivors: in a 2-ring both sides point at the same
        // node, which masks a side flip
        Mutation::RepairSidesFlipped => (4, 0, 1),
        // the joiner is adopted into views without being tracked on the
        // deliver-update-before-discovery interleaving
        Mutation::AdoptUntracked => (3, 1, 0),
        Mutation::None => return ModelConfig::default(),
    };
    ModelConfig {
        n,
        spaces: 1,
        joins,
        fails,
        leaves: 0,
        mutation: m,
    }
}

/// The property class the first counterexample must have when `m` is
/// explored under its [`detection_config`].
pub fn expected_kind(m: Mutation) -> ViolationKind {
    match m {
        Mutation::AdoptUntracked => ViolationKind::Safety,
        _ => ViolationKind::Liveness,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip() {
        for m in ALL.into_iter().chain([Mutation::None]) {
            assert_eq!(parse(name(m)), Some(m));
        }
        assert_eq!(parse("bogus"), None);
    }

    #[test]
    fn detection_configs_validate() {
        for m in ALL {
            let cfg = detection_config(m);
            cfg.validate().unwrap();
            assert_eq!(cfg.mutation, m);
        }
    }
}
