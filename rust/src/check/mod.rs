//! Exhaustive model checking for the NDMP join / fail / leave and ring
//! repair protocols (see `docs/model-checking.md`).
//!
//! The pieces:
//!
//! * [`model`] — the abstract network state: the *real*
//!   [`crate::ndmp::NodeState`] engines under abstracted time, an
//!   in-flight message multiset, and an enumerable [`model::Action`]
//!   alphabet (deliver any pending message, tick any node, join / fail
//!   / leave any id), deduped by a canonical byte encoding.
//! * [`explore`] — BFS over the full interleaving space for small `n`,
//!   checking safety on every state and churn-free convergence
//!   (liveness) after the sweep, with minimal counterexample schedules
//!   recovered through parent pointers.
//! * [`props`] — the tiered safety predicates, built on the same
//!   [`crate::sim::invariants`] the sampled scenario suites assert.
//! * [`mutations`] — known-critical ring-repair lines flipped behind
//!   the [`crate::ndmp::Mutation`] hook, each with a scenario where the
//!   explorer provably catches it: the battery that proves the checker
//!   can actually find bugs.
//! * [`replay`] — counterexamples as parseable text schedules, replayed
//!   through the abstract model and through the concrete
//!   [`crate::sim::Simulator`] (the refinement link).
//!
//! Driven by `fedlay check` (CLI) and the `check_model` /
//! `check_refinement` integration suites.

pub mod explore;
pub mod model;
pub mod mutations;
pub mod props;
pub mod replay;

pub use explore::{explore, Counterexample, ExploreLimits, ExploreReport, ViolationKind};
pub use model::{Action, Envelope, Model, ModelConfig};
pub use replay::{
    format_schedule, parse_schedule, replay_abstract, replay_concrete, ConcreteReplay,
};
