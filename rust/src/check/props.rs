//! Safety properties checked on every explored state.
//!
//! Three tiers, by how much quiescence they assume:
//!
//! * [`step_violations`] must hold on **every** reachable state, even
//!   mid-repair: degree ≤ 2L, no self-referencing view slot, every view
//!   slot tracked in the peer table, no view slot pointing at an id that
//!   has never joined, and the incremental [`IdealRings`] tally never
//!   counting more correct links than Definition 1 requires.
//! * [`settled_violations`] additionally apply once nothing is in
//!   flight and no node still tracks a dead peer: ghost ring entries
//!   are a bug the maintenance protocol should already have purged.
//! * [`converged_violations`] apply to converged states only and defer
//!   to the shared [`crate::sim::invariants`] battery (degree, ghosts,
//!   symmetry, ring ≡ ideal) — the same predicates the sampled scenario
//!   suites assert, so the two batteries cannot drift apart.

use crate::check::model::Model;
use crate::sim::invariants::{self, Violation};
use crate::topology::IdealRings;

fn violation(invariant: &'static str, detail: String) -> Violation {
    Violation { invariant, detail }
}

/// Invariants of every reachable state (see module docs).
pub fn step_violations(m: &Model) -> Vec<Violation> {
    let mut out = Vec::new();
    let rings = m.ring_snapshot();
    out.extend(invariants::degree_violations(&rings, m.cfg.spaces));
    for (&id, st) in &m.nodes {
        for (s, v) in st.views.iter().enumerate() {
            for slot in [v.prev, v.next].into_iter().flatten() {
                if slot == id {
                    out.push(violation(
                        "self-view",
                        format!("node {id} space {s} points at itself"),
                    ));
                }
                if !st.peers.contains_key(&slot) {
                    out.push(violation(
                        "view-not-tracked",
                        format!("node {id} space {s} references untracked {slot}"),
                    ));
                }
                if m.pending.contains(&slot) {
                    out.push(violation(
                        "view-of-unjoined",
                        format!("node {id} space {s} references never-joined {slot}"),
                    ));
                }
            }
        }
    }
    out.extend(tally_violations(m));
    out
}

/// Ghost-freedom once the network is *settled*: no messages in flight
/// and every peer table references live nodes only. Any remaining ring
/// entry pointing at a dead node can never be repaired.
pub fn settled_violations(m: &Model) -> Vec<Violation> {
    if !m.inflight.is_empty() {
        return Vec::new();
    }
    let tracking_dead = m
        .nodes
        .values()
        .any(|st| st.peers.keys().any(|p| !m.nodes.contains_key(p)));
    if tracking_dead {
        return Vec::new();
    }
    invariants::ghost_violations(&m.ring_snapshot())
}

/// The full shared converged-ring battery, applied to states the model
/// itself claims are converged — a cross-check that [`Model::converged`]
/// (per-side view equality) really implies Definition-1 set equality.
pub fn converged_violations(m: &Model) -> Vec<Violation> {
    invariants::converged_ring_violations(&m.ring_snapshot(), m.cfg.spaces)
}

/// Feed the state's live membership and ring views through the
/// incremental [`IdealRings`] tally and require `present ≤ required`:
/// the O(1) correctness maintenance may never report more correct links
/// than Definition 1 defines.
pub fn tally_violations(m: &Model) -> Vec<Violation> {
    let mut tally = IdealRings::new(m.cfg.spaces);
    for &id in m.nodes.keys() {
        tally.add(id);
    }
    for (&id, st) in &m.nodes {
        tally.refresh(id, &st.ring_neighbor_ids());
    }
    if tally.present() > tally.required() {
        vec![violation(
            "tally-overcount",
            format!(
                "IdealRings tally counts {} correct links but only {} are required",
                tally.present(),
                tally.required()
            ),
        )]
    } else {
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::model::{Action, Model, ModelConfig};

    #[test]
    fn bootstrap_state_is_clean_at_all_tiers() {
        let m = Model::init(ModelConfig::default());
        assert!(step_violations(&m).is_empty());
        assert!(settled_violations(&m).is_empty());
        assert!(converged_violations(&m).is_empty());
    }

    #[test]
    fn mid_join_states_stay_step_clean() {
        let mut m = Model::init(ModelConfig {
            n: 4,
            spaces: 2,
            joins: 1,
            fails: 0,
            leaves: 0,
            ..ModelConfig::default()
        });
        m.apply(&Action::Join {
            node: 3,
            bootstrap: 0,
        });
        for _ in 0..300 {
            assert!(
                step_violations(&m).is_empty(),
                "step violation mid-join: {:?}",
                step_violations(&m)
            );
            let Some(a) = m.enabled_actions().into_iter().find(|a| !a.is_churn()) else {
                break;
            };
            m.apply(&a);
        }
        assert!(m.converged());
        assert!(converged_violations(&m).is_empty());
    }

    #[test]
    fn ghost_in_settled_state_is_flagged() {
        // force a settled state with a ghost by surgically removing a
        // node without letting anyone purge it
        let mut m = Model::init(ModelConfig {
            n: 3,
            spaces: 1,
            joins: 0,
            fails: 1,
            leaves: 0,
            ..ModelConfig::default()
        });
        m.apply(&Action::Fail { node: 2 });
        // survivors still track node 2 => not settled yet
        assert!(settled_violations(&m).is_empty());
        for st in m.nodes.values_mut() {
            st.peers.remove(&2);
        }
        // now settled, and views still reference 2: ghost
        assert!(!settled_violations(&m).is_empty());
    }
}
