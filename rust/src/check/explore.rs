//! Exhaustive BFS over the NDMP interleaving space.
//!
//! Starting from the bootstrapped ideal rings, the explorer enumerates
//! every enabled [`Action`] of every reachable state, dedups states by
//! their canonical encoding, and checks:
//!
//! * **safety** on every state (the tiered [`crate::check::props`]
//!   predicates),
//! * **deadlock**: a non-converged state with no enabled action at all
//!   (structurally impossible for the clean protocol — kept as a
//!   defensive verdict), and
//! * **liveness** after the sweep: from every reachable state, some
//!   churn-free schedule must reach a converged state. Computed as
//!   backward reachability from the converged states over the
//!   non-churn transition edges; any unreached state yields a minimal
//!   counterexample via the BFS parent pointers.
//!
//! Depth- or state-capped sweeps are *truncated*: safety still holds on
//! everything visited, but the liveness verdict is skipped (an
//! unconverged frontier state is not a counterexample).

use crate::check::model::{Action, Model, ModelConfig};
use crate::check::props;
use crate::sim::invariants::Violation;
use std::collections::{HashMap, VecDeque};
use std::fmt;

/// How many counterexamples and converged-schedule samples to retain.
const CX_CAP: usize = 8;
const SAMPLE_CAP: usize = 8;

/// Sweep bounds. `max_depth == 0` means unbounded.
#[derive(Debug, Clone)]
pub struct ExploreLimits {
    /// Maximum schedule length explored (0 = exhaust the space).
    pub max_depth: u32,
    /// Hard cap on distinct states (memory guard).
    pub max_states: usize,
}

impl Default for ExploreLimits {
    fn default() -> Self {
        Self {
            max_depth: 0,
            max_states: 2_000_000,
        }
    }
}

/// What class of property a counterexample violates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ViolationKind {
    Safety,
    Liveness,
    Deadlock,
}

impl fmt::Display for ViolationKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ViolationKind::Safety => write!(f, "safety"),
            ViolationKind::Liveness => write!(f, "liveness"),
            ViolationKind::Deadlock => write!(f, "deadlock"),
        }
    }
}

/// A minimal-depth schedule from the initial state to a violating
/// state, replayable through [`crate::check::replay`].
#[derive(Debug, Clone)]
pub struct Counterexample {
    pub kind: ViolationKind,
    /// Actions from the initial state to the violating state.
    pub schedule: Vec<Action>,
    /// The violated predicates (safety only; empty for liveness and
    /// deadlock, where the defect is the *absence* of a path onward).
    pub violations: Vec<Violation>,
    pub depth: u32,
}

/// Everything a sweep learned.
#[derive(Debug, Clone)]
pub struct ExploreReport {
    pub cfg: ModelConfig,
    /// Distinct canonical states discovered.
    pub states: usize,
    /// Transitions taken (edges, counting re-derivations of known states).
    pub transitions: u64,
    /// Transitions that landed on an already-known state.
    pub dedup_hits: u64,
    pub max_depth_seen: u32,
    pub converged_states: usize,
    /// A depth or state cap cut the sweep short.
    pub truncated: bool,
    /// The liveness sweep ran (requires an untruncated sweep).
    pub liveness_checked: bool,
    pub safety_violation_count: u64,
    pub liveness_violation_count: u64,
    pub deadlock_count: u64,
    /// Up to [`CX_CAP`] minimal counterexamples, safety (BFS order,
    /// shallowest first) before liveness.
    pub counterexamples: Vec<Counterexample>,
    /// Sample schedules for refinement replay: paths to the first few
    /// converged states plus the deepest state reached.
    pub schedules: Vec<Vec<Action>>,
}

impl ExploreReport {
    /// No violation of any kind found.
    pub fn ok(&self) -> bool {
        self.safety_violation_count == 0
            && self.liveness_violation_count == 0
            && self.deadlock_count == 0
    }

    /// Fraction of transitions that hit an already-known state.
    pub fn dedup_ratio(&self) -> f64 {
        self.dedup_hits as f64 / (self.transitions.max(1)) as f64
    }
}

impl fmt::Display for ExploreReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "explored {} states, {} transitions (dedup ratio {:.3}), max depth {}",
            self.states,
            self.transitions,
            self.dedup_ratio(),
            self.max_depth_seen
        )?;
        writeln!(
            f,
            "converged states: {}{}",
            self.converged_states,
            if self.truncated {
                " (sweep truncated: liveness not judged)"
            } else {
                ""
            }
        )?;
        write!(
            f,
            "violations: {} safety, {} liveness{}, {} deadlock",
            self.safety_violation_count,
            self.liveness_violation_count,
            if self.liveness_checked { "" } else { " (skipped)" },
            self.deadlock_count
        )
    }
}

/// Path from the root to `id` via the BFS parent pointers.
fn schedule_to(parent: &[Option<(u32, Action)>], id: u32) -> Vec<Action> {
    let mut path = Vec::new();
    let mut cur = id;
    while let Some((p, a)) = &parent[cur as usize] {
        path.push(a.clone());
        cur = *p;
    }
    path.reverse();
    path
}

/// Exhaustively sweep the interleaving space of `cfg` under `limits`.
pub fn explore(cfg: &ModelConfig, limits: &ExploreLimits) -> anyhow::Result<ExploreReport> {
    cfg.validate()?;
    let max_states = limits.max_states.min(u32::MAX as usize - 1);

    let root = Model::init(cfg.clone());
    let root_key = root.canonical_key();

    // Per-state bookkeeping, indexed by discovery order. Only canonical
    // keys are retained (a full `Model` per state would be
    // memory-prohibitive); the frontier carries the key so expansion can
    // decode without a second map lookup.
    let mut index: HashMap<Vec<u8>, u32> = HashMap::new();
    let mut parent: Vec<Option<(u32, Action)>> = Vec::new();
    let mut depth: Vec<u32> = Vec::new();
    let mut preds: Vec<Vec<u32>> = Vec::new(); // non-churn edges, reversed
    let mut converged: Vec<bool> = Vec::new();
    let mut deadlocked: Vec<bool> = Vec::new();
    let mut frontier: VecDeque<(u32, Vec<u8>)> = VecDeque::new();

    index.insert(root_key.clone(), 0);
    parent.push(None);
    depth.push(0);
    preds.push(Vec::new());
    converged.push(false);
    deadlocked.push(false);
    frontier.push_back((0, root_key));

    let mut states = 1usize;
    let mut transitions = 0u64;
    let mut dedup_hits = 0u64;
    let mut max_depth_seen = 0u32;
    let mut truncated = false;
    let mut converged_count = 0usize;
    let mut safety_count = 0u64;
    let mut deadlock_count = 0u64;
    let mut counterexamples: Vec<Counterexample> = Vec::new();
    let mut converged_samples: Vec<u32> = Vec::new();
    let mut deepest: u32 = 0;

    while let Some((cur, key)) = frontier.pop_front() {
        let m = Model::decode(cfg, &key);
        let cur_depth = depth[cur as usize];
        if cur_depth > depth[deepest as usize] {
            deepest = cur;
        }

        let mut viols = props::step_violations(&m);
        viols.extend(props::settled_violations(&m));
        let is_conv = m.converged();
        if is_conv {
            converged[cur as usize] = true;
            converged_count += 1;
            if converged_samples.len() < SAMPLE_CAP {
                converged_samples.push(cur);
            }
            viols.extend(props::converged_violations(&m));
        }
        if !viols.is_empty() {
            safety_count += 1;
            if counterexamples.len() < CX_CAP {
                counterexamples.push(Counterexample {
                    kind: ViolationKind::Safety,
                    schedule: schedule_to(&parent, cur),
                    violations: viols,
                    depth: cur_depth,
                });
            }
        }

        let actions = m.enabled_actions();
        if actions.is_empty() && !is_conv {
            deadlocked[cur as usize] = true;
            deadlock_count += 1;
            if counterexamples.len() < CX_CAP {
                counterexamples.push(Counterexample {
                    kind: ViolationKind::Deadlock,
                    schedule: schedule_to(&parent, cur),
                    violations: Vec::new(),
                    depth: cur_depth,
                });
            }
        }
        if limits.max_depth > 0 && cur_depth >= limits.max_depth {
            if !actions.is_empty() {
                truncated = true;
            }
            continue;
        }

        for a in actions {
            let mut succ = m.clone();
            succ.apply(&a);
            let skey = succ.canonical_key();
            transitions += 1;
            let sid = if let Some(&sid) = index.get(&skey) {
                dedup_hits += 1;
                sid
            } else {
                if states >= max_states {
                    truncated = true;
                    continue;
                }
                let sid = states as u32;
                states += 1;
                index.insert(skey.clone(), sid);
                parent.push(Some((cur, a.clone())));
                depth.push(cur_depth + 1);
                preds.push(Vec::new());
                converged.push(false);
                deadlocked.push(false);
                max_depth_seen = max_depth_seen.max(cur_depth + 1);
                frontier.push_back((sid, skey));
                sid
            };
            if !a.is_churn() {
                preds[sid as usize].push(cur);
            }
        }
    }

    // Liveness: backward reachability from converged states over the
    // non-churn edges. Only meaningful on an exhausted space.
    let liveness_checked = !truncated;
    let mut liveness_count = 0u64;
    if liveness_checked {
        let mut good = converged.clone();
        let mut queue: VecDeque<u32> = (0..states as u32)
            .filter(|&s| good[s as usize])
            .collect();
        while let Some(g) = queue.pop_front() {
            for &p in &preds[g as usize] {
                if !good[p as usize] {
                    good[p as usize] = true;
                    queue.push_back(p);
                }
            }
        }
        // BFS ids are in nondecreasing depth order, so the first
        // unmarked id is a minimal-depth counterexample.
        for s in 0..states as u32 {
            if good[s as usize] || deadlocked[s as usize] {
                continue;
            }
            liveness_count += 1;
            if counterexamples.len() < CX_CAP {
                counterexamples.push(Counterexample {
                    kind: ViolationKind::Liveness,
                    schedule: schedule_to(&parent, s),
                    violations: Vec::new(),
                    depth: depth[s as usize],
                });
            }
        }
    }

    let mut schedules: Vec<Vec<Action>> = converged_samples
        .iter()
        .map(|&s| schedule_to(&parent, s))
        .collect();
    let deepest_path = schedule_to(&parent, deepest);
    if !schedules.contains(&deepest_path) {
        schedules.push(deepest_path);
    }

    Ok(ExploreReport {
        cfg: cfg.clone(),
        states,
        transitions,
        dedup_hits,
        max_depth_seen,
        converged_states: converged_count,
        truncated,
        liveness_checked,
        safety_violation_count: safety_count,
        liveness_violation_count: liveness_count,
        deadlock_count,
        counterexamples,
        schedules,
    })
}

/// Can `start` reach a converged state using non-churn actions only?
/// Bounded forward search used by the counterexample-replay harness to
/// demonstrate that a pinned schedule really strands the network.
pub fn churn_free_converges(start: &Model, max_states: usize) -> bool {
    let mut seen: HashMap<Vec<u8>, ()> = HashMap::new();
    let mut frontier: VecDeque<Vec<u8>> = VecDeque::new();
    let key = start.canonical_key();
    seen.insert(key.clone(), ());
    frontier.push_back(key);
    while let Some(key) = frontier.pop_front() {
        let m = Model::decode(&start.cfg, &key);
        if m.converged() {
            return true;
        }
        for a in m.enabled_actions() {
            if a.is_churn() {
                continue;
            }
            let mut succ = m.clone();
            succ.apply(&a);
            let skey = succ.canonical_key();
            if seen.len() >= max_states {
                return false;
            }
            if !seen.contains_key(&skey) {
                seen.insert(skey.clone(), ());
                frontier.push_back(skey);
            }
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ndmp::node::Mutation;

    #[test]
    fn tiny_clean_sweep_is_exhaustive_and_clean() {
        let cfg = ModelConfig {
            n: 3,
            spaces: 1,
            joins: 1,
            fails: 0,
            leaves: 0,
            mutation: Mutation::None,
        };
        let report = explore(&cfg, &ExploreLimits::default()).unwrap();
        assert!(report.ok(), "violations: {:?}", report.counterexamples);
        assert!(!report.truncated);
        assert!(report.liveness_checked);
        assert!(report.converged_states >= 2, "root + post-join ideal");
        assert!(report.dedup_hits > 0, "interleaving space must reconverge");
        assert!(!report.schedules.is_empty());
    }

    #[test]
    fn depth_cap_truncates_and_skips_liveness() {
        let cfg = ModelConfig {
            n: 3,
            spaces: 1,
            joins: 1,
            fails: 0,
            leaves: 0,
            mutation: Mutation::None,
        };
        let report = explore(
            &cfg,
            &ExploreLimits {
                max_depth: 1,
                ..ExploreLimits::default()
            },
        )
        .unwrap();
        assert!(report.truncated);
        assert!(!report.liveness_checked);
        assert_eq!(report.liveness_violation_count, 0);
        assert!(report.ok(), "a truncated sweep must not invent violations");
    }

    #[test]
    fn churn_free_convergence_from_mid_join() {
        let cfg = ModelConfig {
            n: 3,
            spaces: 1,
            joins: 1,
            fails: 0,
            leaves: 0,
            mutation: Mutation::None,
        };
        let mut m = Model::init(cfg);
        m.apply(&Action::Join {
            node: 2,
            bootstrap: 0,
        });
        assert!(churn_free_converges(&m, 100_000));
    }
}
