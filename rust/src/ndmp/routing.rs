//! Greedy routing primitives (paper §III-B1 and §III-B3).
//!
//! * `greedy_next_hop` — circular-distance greedy step for
//!   `Neighbor_discovery` (Lemma 1 / Theorem 1: at the node with the
//!   minimal circular distance to the target, no neighbor is closer, so
//!   routing stops exactly at the correct terminal).
//! * `directional_next_hop` — the counterclockwise/clockwise arc-length
//!   greedy step for `Neighbor_repair` (Theorem 2: the arc length strictly
//!   decreases per hop, so the probe stops at the surviving adjacent).

use super::messages::Dir;
use crate::topology::coords::{ccw_arc, circular_distance, cw_arc, Coord, NodeId};

/// Coordinate of `id` in `space` — everyone can compute it by hashing
/// (paper §II-C: `x_i = H(IP | i)`), so coordinates never travel in
/// messages.
///
/// Perf note (§Perf iteration 1): hashes exactly one `(id, space)` pair;
/// an earlier version built the whole `VirtualCoords` vector (hashing
/// spaces `0..=space`) on every routing decision, ~2.4× slower per hop.
#[inline]
pub fn coord_of(id: NodeId, space: u32) -> Coord {
    use sha2::{Digest, Sha256};
    let mut h = Sha256::new();
    h.update(id.to_be_bytes());
    h.update(b"|");
    h.update((space as u64).to_be_bytes());
    let digest = h.finalize();
    let mut b = [0u8; 8];
    b.copy_from_slice(&digest[..8]);
    (u64::from_be_bytes(b) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// One greedy step toward `target` coordinate in `space`.
///
/// `neighbors` yields candidate next hops. Returns `Some(w)` if some
/// neighbor is strictly closer (by circular distance, ties to smaller id)
/// than the current node `me`; `None` means `me` is the terminal.
pub fn greedy_next_hop(
    me: NodeId,
    target: Coord,
    space: u32,
    neighbors: impl Iterator<Item = NodeId>,
) -> Option<NodeId> {
    let my_d = circular_distance(coord_of(me, space), target);
    let mut best: Option<(f64, NodeId)> = None;
    for w in neighbors {
        let d = circular_distance(coord_of(w, space), target);
        let better = match best {
            None => true,
            Some((bd, bid)) => d < bd || (d == bd && w < bid),
        };
        if better {
            best = Some((d, w));
        }
    }
    match best {
        Some((d, w)) if d < my_d || (d == my_d && w < me) => Some(w),
        _ => None,
    }
}

/// Remaining arc length from `x` to `target` travelling in `dir`.
#[inline]
pub fn dir_arc(dir: Dir, x: Coord, target: Coord) -> f64 {
    match dir {
        Dir::Ccw => ccw_arc(x, target),
        Dir::Cw => cw_arc(x, target),
    }
}

/// One directional greedy step for repair probes: forward to the neighbor
/// with the smallest remaining `dir`-arc to `target`, if strictly smaller
/// than ours. `None` = the probe stops here.
pub fn directional_next_hop(
    me: NodeId,
    target: Coord,
    space: u32,
    dir: Dir,
    neighbors: impl Iterator<Item = NodeId>,
) -> Option<NodeId> {
    let my_a = dir_arc(dir, coord_of(me, space), target);
    let mut best: Option<(f64, NodeId)> = None;
    for w in neighbors {
        let a = dir_arc(dir, coord_of(w, space), target);
        let better = match best {
            None => true,
            Some((ba, bid)) => a < ba || (a == ba && w < bid),
        };
        if better {
            best = Some((a, w));
        }
    }
    match best {
        Some((a, w)) if a < my_a => Some(w),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::fedlay::Membership;

    /// Fully route a discovery greedily over a correct membership and
    /// assert it terminates at the globally closest node (Theorem 1).
    #[test]
    fn greedy_routing_reaches_closest_node() {
        let spaces = 3;
        let m = Membership::dense(80, spaces);
        for joiner in [1000u64, 2000, 3000, 4321] {
            for space in 0..spaces as u32 {
                let target = coord_of(joiner, space);
                // start from an arbitrary node
                let mut cur: NodeId = *m.nodes.keys().next().unwrap();
                let mut hops = 0;
                loop {
                    let nbrs = m.correct_neighbors(cur);
                    match greedy_next_hop(cur, target, space, nbrs.into_iter()) {
                        Some(w) => {
                            cur = w;
                            hops += 1;
                            assert!(hops < 100, "routing loop");
                        }
                        None => break,
                    }
                }
                // terminal must be the global minimum circular distance
                let best = m
                    .nodes
                    .keys()
                    .copied()
                    .min_by(|&a, &b| {
                        circular_distance(coord_of(a, space), target)
                            .partial_cmp(&circular_distance(coord_of(b, space), target))
                            .unwrap()
                            .then(a.cmp(&b))
                    })
                    .unwrap();
                assert_eq!(cur, best, "joiner {joiner} space {space}");
            }
        }
    }

    /// Directional routing from one adjacent of a "failed" node must stop
    /// at the other adjacent (Theorem 2).
    #[test]
    fn directional_routing_finds_other_adjacent() {
        let spaces = 2;
        let m = Membership::dense(60, spaces);
        for space in 0..spaces as u32 {
            let ring = m.ring(space as usize);
            let n = ring.len();
            for i in (0..n).step_by(7) {
                let failed = ring[i].id;
                let prev = ring[(i + n - 1) % n].id; // ccw adjacent
                let next = ring[(i + 1) % n].id; // cw adjacent
                // prev detects failure of its NEXT-side adjacent -> probe Ccw
                let target = coord_of(failed, space);
                let mut cur = prev;
                let mut hops = 0;
                loop {
                    let nbrs: Vec<NodeId> = m
                        .correct_neighbors(cur)
                        .into_iter()
                        .filter(|&x| x != failed)
                        .collect();
                    match directional_next_hop(cur, target, space, Dir::Ccw, nbrs.into_iter()) {
                        Some(w) => {
                            cur = w;
                            hops += 1;
                            assert!(hops < 200, "repair loop");
                        }
                        None => break,
                    }
                }
                assert_eq!(cur, next, "space {space} failed {failed}");
            }
        }
    }

    #[test]
    fn greedy_hop_count_is_logarithmic_ish() {
        // with L=3 spaces the shortcuts should keep hops well below n
        let spaces = 3;
        let m = Membership::dense(200, spaces);
        let mut total = 0usize;
        let mut count = 0usize;
        for joiner in 5_000..5_020u64 {
            let target = coord_of(joiner, 0);
            let mut cur: NodeId = 7;
            loop {
                let nbrs = m.correct_neighbors(cur);
                match greedy_next_hop(cur, target, 0, nbrs.into_iter()) {
                    Some(w) => {
                        cur = w;
                        total += 1;
                    }
                    None => break,
                }
            }
            count += 1;
        }
        let avg = total as f64 / count as f64;
        assert!(avg < 25.0, "avg hops {avg} too high for n=200");
    }

    #[test]
    fn coord_of_matches_virtual_coords() {
        for id in [0u64, 5, 99] {
            for s in 0..4u32 {
                let via_fn = coord_of(id, s);
                let via_struct = crate::topology::VirtualCoords::from_id(id, 8).get(s as usize);
                assert_eq!(via_fn, via_struct);
            }
        }
    }
}
