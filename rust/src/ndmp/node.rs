//! NDMP node state machine (paper §III-B).
//!
//! `NodeState` is a pure protocol engine: it consumes `(from, Msg, now)`
//! and timer ticks, and emits `Outgoing` messages. It performs no I/O and
//! never touches a transport — the unified scheduler drives it over any
//! `sim::Transport` backend (in-memory `SimTransport`, socket-backed
//! `net::SchedTransport`) and the wall-clock TCP reactor
//! (`net::client_node`) drives the *same* engine, which is the point of
//! the paper's "prototype + simulation use one protocol suite"
//! methodology.

use super::messages::{Dir, Msg, Outgoing, Side, Time};
use super::routing::{coord_of, directional_next_hop, dir_arc, greedy_next_hop};
use crate::config::OverlayConfig;
use crate::topology::NodeId;
use std::collections::{BTreeMap, BTreeSet};

/// Ring adjacency in one virtual space as known by this node.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SpaceView {
    /// Counterclockwise adjacent (smaller-coordinate direction).
    pub prev: Option<NodeId>,
    /// Clockwise adjacent (larger-coordinate direction).
    pub next: Option<NodeId>,
}

#[derive(Debug, Clone, Copy)]
pub struct PeerInfo {
    pub last_seen: Time,
}

/// Message/telemetry counters (feeds Fig. 8c and the comm-cost figures).
#[derive(Debug, Clone, Copy, Default)]
pub struct NodeCounters {
    /// Join/leave traffic: NeighborDiscovery, DiscoveryResult,
    /// AdjacentUpdate, Leave — the Fig. 8c "construction messages".
    pub control_sent: u64,
    pub control_bytes: u64,
    pub data_sent: u64,
    pub data_bytes: u64,
    /// Heartbeats counted separately: Fig. 8c reports *construction*
    /// messages, which exclude steady-state liveness traffic.
    pub heartbeats_sent: u64,
    /// Repair probes + stops (maintenance, also excluded from Fig. 8c).
    pub repair_sent: u64,
}

impl NodeCounters {
    /// Fold another counter set into this one (used by the simulator to
    /// collapse departed nodes' counters into one running tally instead
    /// of keeping per-node history forever).
    pub fn absorb(&mut self, other: &NodeCounters) {
        self.control_sent += other.control_sent;
        self.control_bytes += other.control_bytes;
        self.data_sent += other.data_sent;
        self.data_bytes += other.data_bytes;
        self.heartbeats_sent += other.heartbeats_sent;
        self.repair_sent += other.repair_sent;
    }
}

/// Fault injection for the model checker's mutation harness
/// (`check::mutations`): each variant flips one known-critical line of
/// the ring-repair logic so the exhaustive explorer can prove it *finds*
/// the resulting violation. `Mutation::None` — the default everywhere —
/// leaves every code path bitwise unchanged; production paths never set
/// anything else.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Mutation {
    /// Unmodified protocol.
    #[default]
    None,
    /// Failure handling purges the dead neighbor but emits no directional
    /// repair probes, and the proactive self-probes are suppressed — the
    /// ring loses both of its repair mechanisms.
    NoRepairProbes,
    /// The monotone adoption guard is inverted: `maybe_adopt` keeps the
    /// *farther* candidate whenever an incumbent exists.
    AdoptFarther,
    /// The probe-direction → ring-side mapping is flipped at *both*
    /// repair adoption sites (the Theorem-2 terminal and the `RepairStop`
    /// reply). A single flipped site is masked by the redundant
    /// dual-channel repair; flipping both defeats it.
    RepairSidesFlipped,
    /// `maybe_adopt` installs the candidate in the ring view without
    /// recording it in the peer table, so a view can reference a node the
    /// failure detector will never observe.
    AdoptUntracked,
}

#[derive(Debug, Clone)]
pub struct NodeState {
    pub id: NodeId,
    pub cfg: OverlayConfig,
    pub views: Vec<SpaceView>,
    pub peers: BTreeMap<NodeId, PeerInfo>,
    pub joined: bool,
    pub counters: NodeCounters,
    /// Fault injection for the model-checking mutation harness; `None`
    /// on every production path.
    pub mutation: Mutation,
    next_heartbeat: Time,
    next_probe: Time,
}

impl NodeState {
    pub fn new(id: NodeId, cfg: OverlayConfig, now: Time) -> Self {
        let spaces = cfg.spaces;
        // Stagger periodic timers by id so a simulated fleet doesn't tick
        // in lockstep (mirrors real deployments' unsynchronized clocks).
        let stagger = (id.wrapping_mul(0x9E37_79B9)) % (cfg.heartbeat_ms * 1_000);
        Self {
            id,
            views: vec![SpaceView::default(); spaces],
            peers: BTreeMap::new(),
            joined: false,
            counters: NodeCounters::default(),
            mutation: Mutation::None,
            next_heartbeat: now + stagger,
            next_probe: now + stagger + cfg.repair_probe_ms * 500,
            cfg,
        }
    }

    /// The node's current neighbor set (union of all space views plus any
    /// peers learned through repair), i.e. `N_u` of Definition 1.
    pub fn neighbor_ids(&self) -> BTreeSet<NodeId> {
        let mut s: BTreeSet<NodeId> = self.peers.keys().copied().collect();
        for v in &self.views {
            if let Some(p) = v.prev {
                s.insert(p);
            }
            if let Some(n) = v.next {
                s.insert(n);
            }
        }
        s.remove(&self.id);
        s
    }

    /// Ring-adjacent neighbors only (union of the space views): the
    /// FedLay learning topology of Definition 1, degree ≤ 2L. Unlike
    /// `neighbor_ids` this excludes incidental peers learned from routed
    /// traffic, so MEP layers (e.g. `dfl::Neighborhood::Dynamic`) see the
    /// paper's bounded-degree exchange graph.
    pub fn ring_neighbor_ids(&self) -> BTreeSet<NodeId> {
        let mut s = BTreeSet::new();
        for v in &self.views {
            if let Some(p) = v.prev {
                s.insert(p);
            }
            if let Some(n) = v.next {
                s.insert(n);
            }
        }
        s.remove(&self.id);
        s
    }

    /// Order-sensitive fingerprint of the ring views: cheap change
    /// detection for neighbor caches. The fleet runner compares it
    /// around every message/tick and emits a view-change notification
    /// when it moves, so consumers (e.g. the trainer's per-client
    /// neighbor cache) never have to re-read `ring_neighbor_ids` on a
    /// quiet node.
    pub fn view_stamp(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for v in &self.views {
            for slot in [v.prev, v.next] {
                // +1 distinguishes Some(0) from None
                let x = slot.map(|id| id.wrapping_add(1)).unwrap_or(0);
                h = (h ^ x).wrapping_mul(0x100_0000_01b3);
            }
        }
        h
    }

    /// Fingerprint of the full `neighbor_ids` identity set: the ring
    /// views *plus* the peer-table keyset (routed-traffic acquaintances
    /// enter and leave the have-set too). The fleet runner compares it
    /// around every message/tick to decide when the incremental
    /// correctness tracker must re-read this node's have-set — the
    /// presence-tally analogue of `view_stamp`. Order-sensitive over a
    /// sorted iteration, so equal sets always hash equal.
    pub fn nbr_stamp(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for &id in self.peers.keys() {
            h = (h ^ id.wrapping_add(1)).wrapping_mul(0x100_0000_01b3);
        }
        for v in &self.views {
            for slot in [v.prev, v.next] {
                let x = slot.map(|id| id.wrapping_add(1)).unwrap_or(0);
                h = (h ^ x).wrapping_mul(0x100_0000_01b3);
            }
        }
        h
    }

    /// Neighbors used for routing = peers we believe are alive.
    fn routing_neighbors(&self) -> impl Iterator<Item = NodeId> + '_ {
        self.peers.keys().copied().filter(move |&p| p != self.id)
    }

    fn track_peer(&mut self, id: NodeId, now: Time) {
        if id == self.id {
            return;
        }
        self.peers
            .entry(id)
            .and_modify(|p| p.last_seen = now)
            .or_insert(PeerInfo { last_seen: now });
    }

    fn count(&mut self, msg: &Msg) {
        if matches!(msg, Msg::Heartbeat) {
            self.counters.heartbeats_sent += 1;
            self.counters.control_bytes += msg.wire_size() as u64;
        } else if matches!(msg, Msg::NeighborRepair { .. } | Msg::RepairStop { .. }) {
            self.counters.repair_sent += 1;
            self.counters.control_bytes += msg.wire_size() as u64;
        } else if msg.is_control() {
            self.counters.control_sent += 1;
            self.counters.control_bytes += msg.wire_size() as u64;
        } else {
            self.counters.data_sent += 1;
            self.counters.data_bytes += msg.wire_size() as u64;
        }
    }

    fn send(&mut self, out: &mut Vec<Outgoing>, to: NodeId, msg: Msg) {
        debug_assert_ne!(to, self.id, "node sending to itself: {msg:?}");
        self.count(&msg);
        out.push(Outgoing::new(to, msg));
    }

    // ------------------------------------------------------------------
    // Join protocol (§III-B1)
    // ------------------------------------------------------------------

    /// Start joining an existing network through `bootstrap` (the paper's
    /// minimal assumption: a joiner knows one live node). Returns the
    /// initial `Neighbor_discovery` messages, one per virtual space.
    pub fn start_join(&mut self, bootstrap: NodeId, now: Time) -> Vec<Outgoing> {
        self.track_peer(bootstrap, now);
        let mut out = Vec::new();
        for space in 0..self.cfg.spaces as u32 {
            self.send(
                &mut out,
                bootstrap,
                Msg::NeighborDiscovery {
                    joiner: self.id,
                    space,
                },
            );
        }
        out
    }

    /// Bootstrap a brand-new network (first node): immediately "joined".
    pub fn bootstrap_first(&mut self) {
        self.joined = true;
    }

    fn handle_discovery(&mut self, joiner: NodeId, space: u32, now: Time) -> Vec<Outgoing> {
        let mut out = Vec::new();
        if joiner == self.id {
            return out; // own probe echoed back; ignore
        }
        let target = coord_of(joiner, space);
        let nbrs: Vec<NodeId> = self.routing_neighbors().filter(|&w| w != joiner).collect();
        if let Some(w) = greedy_next_hop(self.id, target, space, nbrs.into_iter()) {
            self.send(&mut out, w, Msg::NeighborDiscovery { joiner, space });
            return out;
        }
        // Terminal (Theorem 1): we are the closest node to the joiner's
        // coordinate. Insert the joiner between us and the proper adjacent.
        let s = space as usize;
        let view = self.views[s];
        self.track_peer(joiner, now);
        match (view.prev, view.next) {
            (None, None) => {
                // singleton network: the 2-ring is joiner <-> me
                self.views[s].prev = Some(joiner);
                self.views[s].next = Some(joiner);
                self.send(
                    &mut out,
                    joiner,
                    Msg::DiscoveryResult {
                        space,
                        prev: self.id,
                        next: self.id,
                    },
                );
            }
            _ => {
                let my_x = coord_of(self.id, space);
                let next = view.next.unwrap_or(self.id);
                let next_x = coord_of(next, space);
                // Is the joiner on our clockwise arc (me -> next)?
                let on_next_side = dir_arc(Dir::Cw, my_x, target) <= dir_arc(Dir::Cw, my_x, next_x);
                if on_next_side {
                    self.views[s].next = Some(joiner);
                    if next != self.id {
                        self.send(
                            &mut out,
                            next,
                            Msg::AdjacentUpdate {
                                space,
                                side: Side::Prev,
                                node: joiner,
                            },
                        );
                    }
                    self.send(
                        &mut out,
                        joiner,
                        Msg::DiscoveryResult {
                            space,
                            prev: self.id,
                            next,
                        },
                    );
                } else {
                    let prev = view.prev.unwrap_or(self.id);
                    self.views[s].prev = Some(joiner);
                    if prev != self.id {
                        self.send(
                            &mut out,
                            prev,
                            Msg::AdjacentUpdate {
                                space,
                                side: Side::Next,
                                node: joiner,
                            },
                        );
                    }
                    self.send(
                        &mut out,
                        joiner,
                        Msg::DiscoveryResult {
                            space,
                            prev,
                            next: self.id,
                        },
                    );
                }
            }
        }
        out
    }

    // ------------------------------------------------------------------
    // Leave protocol (§III-B2)
    // ------------------------------------------------------------------

    /// Graceful departure: tell both adjacents in every space to link with
    /// each other. After emitting these, the node can be shut down.
    pub fn start_leave(&mut self) -> Vec<Outgoing> {
        let mut out = Vec::new();
        for space in 0..self.cfg.spaces as u32 {
            let v = self.views[space as usize];
            if let (Some(p), Some(n)) = (v.prev, v.next) {
                if p != self.id {
                    // prev's NEXT side becomes our next
                    self.send(
                        &mut out,
                        p,
                        Msg::Leave {
                            space,
                            side: Side::Next,
                            other: n,
                        },
                    );
                }
                if n != self.id && n != p {
                    self.send(
                        &mut out,
                        n,
                        Msg::Leave {
                            space,
                            side: Side::Prev,
                            other: p,
                        },
                    );
                }
            }
        }
        out
    }

    fn handle_leave(&mut self, from: NodeId, space: u32, side: Side, other: NodeId, now: Time) {
        let s = space as usize;
        // `from` is departing: replace it on the named side with `other`.
        match side {
            Side::Next => {
                if self.views[s].next == Some(from) {
                    self.views[s].next = if other == self.id { None } else { Some(other) };
                }
            }
            Side::Prev => {
                if self.views[s].prev == Some(from) {
                    self.views[s].prev = if other == self.id { None } else { Some(other) };
                }
            }
        }
        if other != self.id {
            self.track_peer(other, now);
        }
        self.forget_if_unreferenced(from);
    }

    /// Drop a peer from the table when no space view references it.
    fn forget_if_unreferenced(&mut self, id: NodeId) {
        let referenced = self
            .views
            .iter()
            .any(|v| v.prev == Some(id) || v.next == Some(id));
        if !referenced {
            self.peers.remove(&id);
        }
    }

    // ------------------------------------------------------------------
    // Maintenance protocol (§III-B3)
    // ------------------------------------------------------------------

    /// Monotone adjacency update: adopt `cand` as the `side` adjacent in
    /// `space` only if it is strictly closer (by directional arc) than the
    /// incumbent. Keeps stale repair probes from un-fixing the ring.
    fn maybe_adopt(&mut self, space: u32, side: Side, cand: NodeId, now: Time) {
        if cand == self.id {
            return;
        }
        let s = space as usize;
        let my_x = coord_of(self.id, space);
        let cand_x = coord_of(cand, space);
        let (dir, incumbent) = match side {
            Side::Next => (Dir::Cw, self.views[s].next),
            Side::Prev => (Dir::Ccw, self.views[s].prev),
        };
        let adopt = match incumbent {
            None => true,
            Some(inc) if inc == cand => false,
            Some(inc) => {
                let cand_arc = dir_arc(dir, my_x, cand_x);
                let inc_arc = dir_arc(dir, my_x, coord_of(inc, space));
                let closer = cand_arc < inc_arc || (cand_arc == inc_arc && cand < inc);
                if self.mutation == Mutation::AdoptFarther {
                    !closer
                } else {
                    closer
                }
            }
        };
        if adopt {
            let old = match side {
                Side::Next => self.views[s].next.replace(cand),
                Side::Prev => self.views[s].prev.replace(cand),
            };
            if self.mutation != Mutation::AdoptUntracked {
                self.track_peer(cand, now);
            }
            if let Some(o) = old {
                self.forget_if_unreferenced(o);
            }
        } else {
            self.track_peer(cand, now);
        }
    }

    fn handle_repair(
        &mut self,
        origin: NodeId,
        target: NodeId,
        space: u32,
        dir: Dir,
        now: Time,
    ) -> Vec<Outgoing> {
        let mut out = Vec::new();
        let t = coord_of(target, space);
        let nbrs: Vec<NodeId> = self
            .routing_neighbors()
            .filter(|&w| w != target && w != origin)
            .collect();
        match directional_next_hop(self.id, t, space, dir, nbrs.into_iter()) {
            Some(w) => {
                self.send(
                    &mut out,
                    w,
                    Msg::NeighborRepair {
                        origin,
                        target,
                        space,
                        dir,
                    },
                );
            }
            None => {
                // Theorem 2: we are the surviving adjacent on the far side
                // of `target` from `origin`. The probe travelled `dir`, so
                // the origin sits on our `dir` side.
                if origin != self.id {
                    let mut my_side = match dir {
                        Dir::Ccw => Side::Prev, // probe moved ccw; origin is ccw of us
                        Dir::Cw => Side::Next,
                    };
                    if self.mutation == Mutation::RepairSidesFlipped {
                        my_side = match my_side {
                            Side::Prev => Side::Next,
                            Side::Next => Side::Prev,
                        };
                    }
                    self.maybe_adopt(space, my_side, origin, now);
                    self.send(&mut out, origin, Msg::RepairStop { space, dir });
                }
            }
        }
        out
    }

    /// Declare `dead` failed: purge from views/peers and emit directional
    /// repair probes for every space where it was an adjacent.
    fn fail_neighbor(&mut self, dead: NodeId, _now: Time) -> Vec<Outgoing> {
        let mut out = Vec::new();
        self.peers.remove(&dead);
        for space in 0..self.cfg.spaces as u32 {
            let s = space as usize;
            let was_next = self.views[s].next == Some(dead);
            let was_prev = self.views[s].prev == Some(dead);
            if was_next {
                self.views[s].next = None;
            }
            if was_prev {
                self.views[s].prev = None;
            }
            if self.mutation == Mutation::NoRepairProbes {
                continue;
            }
            if was_next {
                // dead was clockwise of us: probe counterclockwise (paper
                // Fig. 7: A's clockwise adjacent G fails -> ccw routing).
                let probe = Msg::NeighborRepair {
                    origin: self.id,
                    target: dead,
                    space,
                    dir: Dir::Ccw,
                };
                let first = self.first_repair_hop(dead, space, Dir::Ccw);
                if let Some(w) = first {
                    self.send(&mut out, w, probe);
                }
            }
            if was_prev {
                let probe = Msg::NeighborRepair {
                    origin: self.id,
                    target: dead,
                    space,
                    dir: Dir::Cw,
                };
                let first = self.first_repair_hop(dead, space, Dir::Cw);
                if let Some(w) = first {
                    self.send(&mut out, w, probe);
                }
            }
        }
        out
    }

    /// First hop of a repair probe we originate (we route from ourselves).
    fn first_repair_hop(&self, target: NodeId, space: u32, dir: Dir) -> Option<NodeId> {
        let t = coord_of(target, space);
        let nbrs: Vec<NodeId> = self
            .routing_neighbors()
            .filter(|&w| w != target)
            .collect();
        directional_next_hop(self.id, t, space, dir, nbrs.into_iter())
    }

    /// First hop of a proactive *self*-probe. Our own arc to our own
    /// coordinate is 0, so the normal stop rule would never let the probe
    /// leave — instead we hand it to the neighbor with the smallest
    /// remaining `dir`-arc and let directional routing take over.
    fn first_self_probe_hop(&self, space: u32, dir: Dir) -> Option<NodeId> {
        let t = coord_of(self.id, space);
        self.routing_neighbors()
            .map(|w| {
                let a = dir_arc(dir, coord_of(w, space), t);
                (a, w)
            })
            .min_by(|(a1, w1), (a2, w2)| a1.partial_cmp(a2).unwrap().then(w1.cmp(w2)))
            .map(|(_, w)| w)
    }

    /// Periodic driver: heartbeats, failure detection, and the proactive
    /// bidirectional self-probes that handle concurrent churn (§III-B3,
    /// "Neighbor repair for concurrent joins and failures").
    pub fn tick(&mut self, now: Time) -> Vec<Outgoing> {
        let mut out = Vec::new();
        let hb_period = self.cfg.heartbeat_ms * 1_000;
        if now >= self.next_heartbeat {
            self.next_heartbeat = now + hb_period;
            for id in self.neighbor_ids() {
                self.send(&mut out, id, Msg::Heartbeat);
            }
            // failure detection: silence for failure_multiple * T
            let deadline = (self.cfg.failure_multiple as u64) * hb_period;
            let dead: Vec<NodeId> = self
                .peers
                .iter()
                .filter(|(_, p)| now.saturating_sub(p.last_seen) > deadline)
                .map(|(&id, _)| id)
                .collect();
            for d in dead {
                out.extend(self.fail_neighbor(d, now));
            }
        }
        if now >= self.next_probe {
            self.next_probe = now + self.cfg.repair_probe_ms * 1_000;
            out.extend(self.emit_self_probes());
        }
        out
    }

    /// Proactive bidirectional self-probes for every space (§III-B3,
    /// "Neighbor repair for concurrent joins and failures"): hand a
    /// directional probe targeting our own coordinate to the neighbor
    /// with the smallest remaining arc and let routing take over. `tick`
    /// fires this on the `repair_probe_ms` cadence; the model checker
    /// (`check`), which abstracts timers away, calls it directly.
    pub fn emit_self_probes(&mut self) -> Vec<Outgoing> {
        let mut out = Vec::new();
        if self.mutation == Mutation::NoRepairProbes {
            return out;
        }
        for space in 0..self.cfg.spaces as u32 {
            for dir in [Dir::Ccw, Dir::Cw] {
                if let Some(w) = self.first_self_probe_hop(space, dir) {
                    self.send(
                        &mut out,
                        w,
                        Msg::NeighborRepair {
                            origin: self.id,
                            target: self.id,
                            space,
                            dir,
                        },
                    );
                }
            }
        }
        out
    }

    /// Public entry to the failure-handling path: purge `dead` from views
    /// and peers and emit directional repair probes for every space where
    /// it was an adjacent. The simulator reaches this through `tick`'s
    /// silence detector; the model checker, which abstracts time away,
    /// declares failures through a global-liveness oracle instead.
    pub fn declare_failed(&mut self, dead: NodeId, now: Time) -> Vec<Outgoing> {
        self.fail_neighbor(dead, now)
    }

    // ------------------------------------------------------------------
    // Dispatch
    // ------------------------------------------------------------------

    /// Handle one inbound NDMP message. MEP messages are routed by the
    /// caller to `mep::ExchangeState` instead.
    pub fn handle(&mut self, from: NodeId, msg: Msg, now: Time) -> Vec<Outgoing> {
        self.track_peer(from, now);
        match msg {
            Msg::NeighborDiscovery { joiner, space } => self.handle_discovery(joiner, space, now),
            Msg::DiscoveryResult { space, prev, next } => {
                let s = space as usize;
                self.maybe_adopt(space, Side::Prev, prev, now);
                self.maybe_adopt(space, Side::Next, next, now);
                // On first join the view was empty, so adopt always fires;
                // record completion once every space has an adjacency.
                if self.views.iter().all(|v| v.prev.is_some() || v.next.is_some()) {
                    self.joined = true;
                }
                let _ = s;
                Vec::new()
            }
            Msg::AdjacentUpdate { space, side, node } => {
                self.maybe_adopt(space, side, node, now);
                Vec::new()
            }
            Msg::Leave {
                space,
                side,
                other,
            } => {
                self.handle_leave(from, space, side, other, now);
                Vec::new()
            }
            Msg::Heartbeat => Vec::new(),
            Msg::NeighborRepair {
                origin,
                target,
                space,
                dir,
            } => self.handle_repair(origin, target, space, dir, now),
            Msg::RepairStop { space, dir } => {
                // Our probe travelled `dir` and stopped at the node with
                // the smallest remaining `dir`-arc to the target — which
                // lies just *beyond* the target on the opposite side. A
                // Ccw probe (fired when our NEXT died, paper Fig. 7) stops
                // at the node clockwise of the target: our new NEXT.
                let mut side = match dir {
                    Dir::Ccw => Side::Next,
                    Dir::Cw => Side::Prev,
                };
                if self.mutation == Mutation::RepairSidesFlipped {
                    side = match side {
                        Side::Prev => Side::Next,
                        Side::Next => Side::Prev,
                    };
                }
                self.maybe_adopt(space, side, from, now);
                Vec::new()
            }
            Msg::ModelOffer { .. }
            | Msg::ModelRequest { .. }
            | Msg::ModelPayload { .. }
            | Msg::ModelPayloadQ8 { .. }
            | Msg::ModelPayloadTopK { .. } => {
                Vec::new() // MEP handled by the exchange layer
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(spaces: usize) -> OverlayConfig {
        OverlayConfig {
            spaces,
            ..OverlayConfig::default()
        }
    }

    #[test]
    fn singleton_accepts_joiner() {
        let mut a = NodeState::new(1, cfg(2), 0);
        a.bootstrap_first();
        let mut b = NodeState::new(2, cfg(2), 0);
        let join_msgs = b.start_join(1, 0);
        assert_eq!(join_msgs.len(), 2); // one discovery per space
        let mut replies = Vec::new();
        for m in join_msgs {
            assert_eq!(m.to, 1);
            replies.extend(a.handle(2, m.msg, 1));
        }
        // a adopted b in both spaces
        assert_eq!(a.views[0].prev, Some(2));
        assert_eq!(a.views[0].next, Some(2));
        for r in replies {
            assert_eq!(r.to, 2);
            b.handle(1, r.msg, 2);
        }
        assert!(b.joined);
        assert_eq!(b.views[0].prev, Some(1));
        assert_eq!(b.views[0].next, Some(1));
        assert_eq!(b.neighbor_ids().len(), 1);
        assert_eq!(b.ring_neighbor_ids().len(), 1);
        // ring neighbors never include routed-traffic acquaintances
        b.handle(42, Msg::Heartbeat, 3);
        assert!(b.neighbor_ids().contains(&42));
        assert!(!b.ring_neighbor_ids().contains(&42));
    }

    #[test]
    fn repair_stop_adopts_origin_side() {
        let mut n = NodeState::new(5, cfg(1), 0);
        n.bootstrap_first();
        // a RepairStop from node 9 after our Ccw probe: a Ccw probe fires
        // when our NEXT died, and stops at our new NEXT.
        n.handle(9, Msg::RepairStop { space: 0, dir: Dir::Ccw }, 1);
        assert_eq!(n.views[0].next, Some(9));
        assert_eq!(n.views[0].prev, None);
    }

    #[test]
    fn leave_rewires_sides() {
        let mut n = NodeState::new(5, cfg(1), 0);
        n.views[0].prev = Some(3);
        n.views[0].next = Some(7);
        n.track_peer(3, 0);
        n.track_peer(7, 0);
        // 7 leaves; we are 7's prev, so it tells us our NEXT becomes 9
        n.handle(
            7,
            Msg::Leave {
                space: 0,
                side: Side::Next,
                other: 9,
            },
            1,
        );
        assert_eq!(n.views[0].next, Some(9));
        assert!(!n.neighbor_ids().contains(&7));
    }

    #[test]
    fn nbr_stamp_tracks_peers_and_views() {
        let mut n = NodeState::new(5, cfg(2), 0);
        n.bootstrap_first();
        let s0 = n.nbr_stamp();
        // a routed-traffic acquaintance changes the have-set (and the
        // stamp) without touching the ring views
        n.handle(42, Msg::Heartbeat, 1);
        assert_eq!(n.view_stamp(), n.view_stamp());
        let s1 = n.nbr_stamp();
        assert_ne!(s0, s1);
        // a repeated heartbeat from a known peer changes nothing
        n.handle(42, Msg::Heartbeat, 2);
        assert_eq!(n.nbr_stamp(), s1);
        // view rewires move the stamp too
        n.views[0].next = Some(9);
        assert_ne!(n.nbr_stamp(), s1);
    }

    #[test]
    fn counters_track_messages() {
        let mut b = NodeState::new(2, cfg(3), 0);
        b.start_join(1, 0);
        assert_eq!(b.counters.control_sent, 3);
        assert!(b.counters.control_bytes > 0);
        assert_eq!(b.counters.data_sent, 0);
    }

    #[test]
    fn tick_emits_heartbeats_and_detects_failure() {
        let mut n = NodeState::new(1, cfg(1), 0);
        n.bootstrap_first();
        n.views[0].prev = Some(2);
        n.views[0].next = Some(2);
        n.track_peer(2, 0);
        // first tick: heartbeat to 2
        let out = n.tick(n.next_heartbeat);
        assert!(out.iter().any(|o| o.to == 2 && o.msg == Msg::Heartbeat));
        // long silence -> failure detection; with no other peers there is
        // no repair hop, but 2 must be purged
        let much_later = 1_000 * SEC_LIKE;
        let _ = n.tick(much_later);
        assert!(n.peers.is_empty());
        assert_eq!(n.views[0].prev, None);
    }

    const SEC_LIKE: Time = 1_000_000;
}
