//! NDMP / MEP wire messages (paper §III).
//!
//! One enum covers both protocol sets so a single transport carries them:
//! the discrete-event simulator passes `Msg` values directly; the TCP
//! prototype serializes them with `net::codec`.

use crate::topology::NodeId;

/// Simulation / protocol time in microseconds.
pub type Time = u64;

pub const MS: Time = 1_000;
pub const SEC: Time = 1_000_000;

/// Ring travel direction for directional repair routing (§III-B3).
/// `Cw` = clockwise = increasing coordinate; `Ccw` = decreasing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    Cw,
    Ccw,
}

impl Dir {
    pub fn flip(self) -> Dir {
        match self {
            Dir::Cw => Dir::Ccw,
            Dir::Ccw => Dir::Cw,
        }
    }
}

/// Which ring side of a node an update applies to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Side {
    Prev, // counterclockwise adjacent
    Next, // clockwise adjacent
}

#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    // ---- NDMP control protocol (§III-B) ----
    /// Greedy-routed toward the joiner's coordinate in `space`; the node
    /// closest to that coordinate answers (join protocol, §III-B1).
    /// Coordinates are derived from `joiner` by hashing, so they never
    /// ride in the message.
    NeighborDiscovery { joiner: NodeId, space: u32 },
    /// Terminal node's answer to the joiner: its ring-adjacent pair.
    DiscoveryResult { space: u32, prev: NodeId, next: NodeId },
    /// Terminal node tells the displaced old adjacent about the joiner.
    AdjacentUpdate { space: u32, side: Side, node: NodeId },
    /// Planned leave (§III-B2): "link with `other` on `side`".
    Leave { space: u32, side: Side, other: NodeId },
    /// Periodic liveness (§III-B3).
    Heartbeat,
    /// Directionally greedy-routed repair probe toward `target`'s
    /// coordinate in `space`; stops at the surviving adjacent (§III-B3).
    NeighborRepair {
        origin: NodeId,
        target: NodeId,
        space: u32,
        dir: Dir,
    },
    /// Stop node's answer to the repair origin: "I am your `dir`-side
    /// adjacent in `space`".
    RepairStop { space: u32, dir: Dir },

    // ---- MEP application protocol (§III-C) ----
    /// Fingerprint-first offer (model de-duplication, §III-C3). `task`
    /// names which of the coexisting model tasks the offer is about, so
    /// several tasks can share one overlay without their dedup state or
    /// payloads crossing (single-task nodes use task 0).
    ModelOffer {
        task: u32,
        fingerprint: u64,
        confidence: f32,
        version: u64,
    },
    /// "Your fingerprint is new to me — send the parameters."
    ModelRequest { task: u32, version: u64 },
    /// Flat model parameters + sender confidence for one task.
    ModelPayload {
        task: u32,
        version: u64,
        confidence: f32,
        params: Vec<f32>,
    },
    /// Quantized model payload: per-tensor symmetric i8 quantization
    /// (`param ≈ scale * level`), ~4× fewer bytes on the wire than
    /// `ModelPayload` for the same parameter count.
    ModelPayloadQ8 {
        task: u32,
        version: u64,
        confidence: f32,
        /// Dequantization scale (`max |param| / 127`).
        scale: f32,
        levels: Vec<i8>,
    },
    /// Top-k sparsified model payload: only the `k` largest-magnitude
    /// parameters ride the wire (`dim` total, the rest are zero on
    /// receive).
    ModelPayloadTopK {
        task: u32,
        version: u64,
        confidence: f32,
        /// Dense dimension of the full parameter vector.
        dim: u32,
        indices: Vec<u32>,
        values: Vec<f32>,
    },
}

impl Msg {
    /// Is this an NDMP control message (counted in Fig. 8c)?
    pub fn is_control(&self) -> bool {
        !matches!(
            self,
            Msg::ModelOffer { .. }
                | Msg::ModelRequest { .. }
                | Msg::ModelPayload { .. }
                | Msg::ModelPayloadQ8 { .. }
                | Msg::ModelPayloadTopK { .. }
        )
    }

    /// Approximate wire size in bytes (for communication-cost metrics;
    /// matches what `net::codec` actually produces within a few bytes).
    pub fn wire_size(&self) -> usize {
        match self {
            Msg::NeighborDiscovery { .. } => 21,
            Msg::DiscoveryResult { .. } => 25,
            Msg::AdjacentUpdate { .. } => 18,
            Msg::Leave { .. } => 18,
            Msg::Heartbeat => 5,
            Msg::NeighborRepair { .. } => 26,
            Msg::RepairStop { .. } => 10,
            Msg::ModelOffer { .. } => 29,
            Msg::ModelRequest { .. } => 17,
            Msg::ModelPayload { params, .. } => 21 + 4 * params.len(),
            Msg::ModelPayloadQ8 { levels, .. } => 25 + levels.len(),
            Msg::ModelPayloadTopK { indices, values, .. } => {
                25 + 4 * indices.len() + 4 * values.len()
            }
        }
    }
}

/// An outbound message from a protocol handler.
#[derive(Debug, Clone, PartialEq)]
pub struct Outgoing {
    pub to: NodeId,
    pub msg: Msg,
}

impl Outgoing {
    pub fn new(to: NodeId, msg: Msg) -> Self {
        Self { to, msg }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn control_classification() {
        assert!(Msg::Heartbeat.is_control());
        assert!(Msg::NeighborDiscovery { joiner: 1, space: 0 }.is_control());
        assert!(!Msg::ModelRequest { task: 0, version: 1 }.is_control());
        assert!(!Msg::ModelPayload {
            task: 0,
            version: 0,
            confidence: 1.0,
            params: vec![]
        }
        .is_control());
        assert!(!Msg::ModelPayloadQ8 {
            task: 0,
            version: 0,
            confidence: 1.0,
            scale: 1.0,
            levels: vec![]
        }
        .is_control());
        assert!(!Msg::ModelPayloadTopK {
            task: 0,
            version: 0,
            confidence: 1.0,
            dim: 0,
            indices: vec![],
            values: vec![]
        }
        .is_control());
    }

    #[test]
    fn payload_size_scales_with_params() {
        let small = Msg::ModelPayload {
            task: 0,
            version: 0,
            confidence: 1.0,
            params: vec![0.0; 10],
        };
        let big = Msg::ModelPayload {
            task: 1,
            version: 0,
            confidence: 1.0,
            params: vec![0.0; 1000],
        };
        assert_eq!(big.wire_size() - small.wire_size(), 4 * 990);
    }

    #[test]
    fn compressed_payloads_are_smaller_on_the_wire() {
        let dense = Msg::ModelPayload {
            task: 0,
            version: 1,
            confidence: 1.0,
            params: vec![0.5; 1000],
        };
        let q8 = Msg::ModelPayloadQ8 {
            task: 0,
            version: 1,
            confidence: 1.0,
            scale: 0.5 / 127.0,
            levels: vec![127; 1000],
        };
        let topk = Msg::ModelPayloadTopK {
            task: 0,
            version: 1,
            confidence: 1.0,
            dim: 1000,
            indices: (0..100).collect(),
            values: vec![0.5; 100],
        };
        // q8: ~1 byte/param vs 4; topk at k = dim/10: ~8 bytes * k
        assert!(q8.wire_size() * 3 < dense.wire_size());
        assert!(topk.wire_size() * 4 < dense.wire_size());
    }

    #[test]
    fn dir_flip() {
        assert_eq!(Dir::Cw.flip(), Dir::Ccw);
        assert_eq!(Dir::Ccw.flip(), Dir::Cw);
    }
}
