//! Neighbor Discovery and Maintenance Protocols (paper §III-B): the fully
//! decentralized join / leave / maintenance suite with greedy routing over
//! virtual ring coordinates.

pub mod messages;
pub mod node;
pub mod routing;

pub use messages::{Dir, Msg, Outgoing, Side, Time, MS, SEC};
pub use node::{Mutation, NodeCounters, NodeState, PeerInfo, SpaceView};
