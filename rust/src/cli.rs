//! Command-line launcher (substrate: clap is not in the vendored set).
//!
//! Subcommands:
//!   topology  — evaluate a named overlay on the §II-B metrics
//!   churn     — mass join/fail resilience simulation (Fig. 8)
//!   scenario  — run/inspect a declarative churn scenario (TOML spec)
//!   train     — run a DFL method over the AOT runtime (Figs. 9-19)
//!   node      — run one real TCP FedLay client (prototype mode)
//!   bench     — run the perf micro-suite, emit BENCH_<suite>.json
//!   check     — exhaustively model-check NDMP for a small universe
//!
//! Global flags: `--config <file>` and repeatable `--set key=value`.

use crate::config::Config;
use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    /// Non-flag tokens after the subcommand (e.g. `scenario run <spec>`).
    /// Commands that take none reject leftovers via `no_positionals`.
    pub positionals: Vec<String>,
    pub flags: BTreeMap<String, String>,
    pub sets: Vec<String>,
}

pub fn parse_args(argv: &[String]) -> anyhow::Result<Args> {
    let mut args = Args::default();
    let mut it = argv.iter().peekable();
    match it.next() {
        Some(cmd) if !cmd.starts_with("--") => args.command = cmd.clone(),
        Some(flag) => anyhow::bail!("expected a subcommand before {flag:?}"),
        None => {
            anyhow::bail!("usage: fedlay <topology|churn|scenario|train|node|bench|check> [flags]")
        }
    }
    while let Some(a) = it.next() {
        let Some(name) = a.strip_prefix("--") else {
            args.positionals.push(a.clone());
            continue;
        };
        if name == "set" {
            let v = it
                .next()
                .ok_or_else(|| anyhow::anyhow!("--set needs key=value"))?;
            args.sets.push(v.clone());
            continue;
        }
        // flags may be --k v or --k=v; bare --k is boolean true
        if let Some((k, v)) = name.split_once('=') {
            args.flags.insert(k.to_string(), v.to_string());
        } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
            args.flags.insert(name.to_string(), it.next().unwrap().clone());
        } else {
            args.flags.insert(name.to_string(), "true".to_string());
        }
    }
    Ok(args)
}

impl Args {
    /// Reject stray positional tokens (commands that take none).
    pub fn no_positionals(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.positionals.is_empty(),
            "unexpected positional argument {:?}",
            self.positionals[0]
        );
        Ok(())
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn u64(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        self.flags.get(key).map(|v| v == "true").unwrap_or(false)
    }

    pub fn config(&self) -> anyhow::Result<Config> {
        let path = self.flags.get("config").map(std::path::PathBuf::from);
        Config::load(path.as_deref(), &self.sets)
    }
}

pub const USAGE: &str = "\
fedlay — practical overlay networks for decentralized federated learning

USAGE:
  fedlay topology --name <fedlay|chord|viceroy|waxman|delaunay|social|ring|...>
                  [--nodes N] [--seed S]
  fedlay churn    [--initial N] [--joins J] [--fails F] [--until-ms T]
                  [--set overlay.spaces=L] [--set net.latency_ms=350]
  fedlay scenario run <spec.toml>  [--transport sim|tcp] [--trainer]
                                   [--freeze] [--task mlp]
                                   [--tasks <tasks.toml>]
                                   [--latency-ms L] [--jitter J]
                                   [--bandwidth-mbps B] [--loss P]
                                   [--node-up-mbps U] [--node-down-mbps D]
                                   [--compression none|q8|topk:<keep>]
                                   [--aggregation mean|trimmed:<beta>|median|krum:<f>]
  fedlay scenario show <spec.toml>
                  (declarative churn scenarios — TOML format in
                   docs/scenarios.md, examples under configs/scenarios/;
                   `run` drives a bare overlay simulation, or with
                   --trainer a full fedlay-dyn training run whose join
                   wave enters through the NDMP protocol; --trainer
                   --tasks runs every task of a multi-task spec over the
                   one churned overlay; adversarial phases (poison /
                   stale_replay / eclipse) compromise a deterministic
                   attacker set, and --aggregation picks the robust rule
                   honest clients defend with; `show` prints the
                   compiled event schedule without running it)
  fedlay train    [--method fedlay|fedlay-dyn|fedavg|gaia|dfl-dds|chord]
                  [--set dfl.task=mlp] [--set dfl.clients=16]
                  [--minutes M] [--sample-minutes S]
                  [--joins J] [--fails F] [--churn-at-min T]
                  [--transport sim|tcp]
                  [--latency-ms L] [--jitter J]
                  [--bandwidth-mbps B] [--loss P]
                  [--node-up-mbps U] [--node-down-mbps D]
                  [--compression none|q8|topk:<keep>]
                  [--aggregation mean|trimmed:<beta>|median|krum:<f>]
                  [--tasks <tasks.toml>]
                  (fedlay-dyn runs on the live NDMP overlay; --joins adds
                   J clients mid-run through the protocol join; --transport
                   tcp carries that overlay's messages over real localhost
                   sockets instead of the in-memory simulated network —
                   with the same seeded virtual link model on either
                   backend: latency + jitter, per-link bandwidth, frame
                   loss and per-node capacity, overridable via the net
                   flags above (docs/transports.md); --compression sends
                   model payloads quantized (q8) or top-k sparsified
                   instead of dense f32; --aggregation replaces the
                   confidence-weighted mean with a Byzantine-robust rule
                   (trimmed mean, coordinate median, or Krum selection);
                   --tasks runs the multi-task
                   engine — N model tasks from a TOML spec,
                   docs/multitask.md, over one shared overlay, one
                   accuracy column per task)
  fedlay node     --id I --base-port P [--bootstrap B] [--run-ms T]
                  [--compression none|q8|topk:<keep>]
                  [--aggregation mean|trimmed:<beta>|median|krum:<f>]
                  (one real TCP client; spawn several for a live network;
                   non-finite inbound payloads are always rejected at the
                   frame boundary, whatever the aggregation rule)
  fedlay bench    [--quick] [--out <dir>]
                  [--compare <prev.json>] [--fail-ratio R]
                  (perf micro-suite over routing, event queue, sharded
                   engine, correctness tallies, MEP, and — when
                   artifacts are present — the AOT runtime; prints a
                   table and writes BENCH_micro.json to --out, default
                   the working directory; --quick is the scaled-down CI
                   smoke run; --compare prints a per-entry delta table
                   against a previous BENCH_*.json and exits non-zero
                   when a gated hot-path entry (event queue,
                   correctness) regressed above --fail-ratio, default
                   2.0; schema in docs/perf.md)

  fedlay check    [--n N] [--spaces L] [--joins J] [--fails F] [--leaves V]
                  [--max-depth D] [--max-states S]
                  [--mutation none|no-probes|adopt-farther|
                              flip-repair-sides|adopt-untracked]
                  [--expect-violation]
                  (exhaustive model checking of the NDMP join/fail/leave
                   and ring-repair protocols: BFS over every message /
                   tick / churn interleaving of an N-id universe, safety
                   invariants on every state, churn-free convergence as
                   liveness, counterexamples printed as replayable
                   schedules — docs/model-checking.md; --mutation
                   injects a known repair bug and, with
                   --expect-violation, requires the checker to catch it;
                   the scenario sizing then defaults to that mutation's
                   guaranteed-detection configuration; --max-depth /
                   --max-states truncate the sweep, which skips the
                   liveness verdict)

GLOBAL FLAGS:
  --config <file>     TOML-subset config file
  --set key=value     override any config key (repeatable)
";

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = parse_args(&sv(&["train", "--method", "fedlay", "--minutes=30", "--verbose"]))
            .unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.str("method", ""), "fedlay");
        assert_eq!(a.usize("minutes", 0).unwrap(), 30);
        assert!(a.bool("verbose"));
        assert!(!a.bool("quiet"));
    }

    #[test]
    fn collects_set_overrides() {
        let a = parse_args(&sv(&["churn", "--set", "overlay.spaces=4", "--set", "net.seed=9"]))
            .unwrap();
        assert_eq!(a.sets, vec!["overlay.spaces=4", "net.seed=9"]);
        let cfg = a.config().unwrap();
        assert_eq!(cfg.overlay.spaces, 4);
        assert_eq!(cfg.net.seed, 9);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(parse_args(&sv(&[])).is_err());
        assert!(parse_args(&sv(&["--flag-first"])).is_err());
        let a = parse_args(&sv(&["train", "--minutes", "abc"])).unwrap();
        assert!(a.usize("minutes", 1).is_err());
    }

    #[test]
    fn parses_float_flags() {
        let a = parse_args(&sv(&["train", "--latency-ms", "350.5", "--jitter=0.2"])).unwrap();
        assert_eq!(a.f64("latency-ms", 0.0).unwrap(), 350.5);
        assert_eq!(a.f64("jitter", 0.0).unwrap(), 0.2);
        assert_eq!(a.f64("absent", 1.5).unwrap(), 1.5);
        let b = parse_args(&sv(&["train", "--latency-ms", "fast"])).unwrap();
        assert!(b.f64("latency-ms", 0.0).is_err());
    }

    #[test]
    fn collects_positionals() {
        let a = parse_args(&sv(&["scenario", "run", "spec.toml", "--transport", "tcp"]))
            .unwrap();
        assert_eq!(a.command, "scenario");
        assert_eq!(a.positionals, vec!["run".to_string(), "spec.toml".to_string()]);
        assert_eq!(a.str("transport", "sim"), "tcp");
        assert!(a.no_positionals().is_err());
        // commands that take no positionals reject strays via the helper
        let b = parse_args(&sv(&["train", "stray"])).unwrap();
        assert!(b.no_positionals().is_err());
        let c = parse_args(&sv(&["train", "--minutes", "5"])).unwrap();
        assert!(c.no_positionals().is_ok());
    }
}
