//! # FedLay — practical overlay networks for decentralized federated learning
//!
//! A reproduction of *"Towards Practical Overlay Networks for Decentralized
//! Federated Learning"* (Hua et al., 2024) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the FedLay coordinator: the overlay topology
//!   built from random virtual coordinates (`topology`), the decentralized
//!   Neighbor Discovery and Maintenance Protocols (`ndmp`), the Model
//!   Exchange Protocol (`mep`), a real TCP transport (`net`), all baseline
//!   topologies and DFL methods from the paper's evaluation (`baselines`,
//!   `dfl`), and the topology-metric pipeline (`metrics`).
//! * **L2 (python/compile/model.py)** — the JAX model zoo (MLP/CNN/LSTM),
//!   AOT-lowered once to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the MEP
//!   aggregation and fused SGD update, embedded in the L2 artifacts.
//!
//! Overlay maintenance and training share one **unified discrete-event
//! engine**: `sim::sched` is a deterministic scheduler generic over the
//! event-kind type, instantiated by the NDMP fleet simulator
//! (`sim::Simulator`, message deliveries / timers / churn) and by the DFL
//! trainer (`dfl::Trainer`, client wake-ups / rounds / samples / churn).
//! Under `dfl::Neighborhood::Dynamic` the trainer embeds a `Simulator`
//! advanced in lockstep with training time, so mid-training joins and
//! failures rewire the learning topology through the actual protocols —
//! the paper's NDMP + MEP co-execution (Figs. 18/19).
//!
//! ## Sim vs. TCP backends
//!
//! Message passage is a pluggable [`sim::Transport`] with two
//! implementations — the in-memory [`sim::SimTransport`] and the
//! real-socket [`net::SchedTransport`] — both driven by the same
//! scheduler, protocol engines, churn schedules, and seeded per-link
//! virtual latency ([`sim::LinkDelay`]), so a schedule replays over
//! real sockets with the *identical arrival timestamps* it has in
//! simulation. The architecture — the `Transport` contract, the
//! quiescence pump's role as liveness backstop, virtual-latency
//! injection, and a worked sim ≡ tcp conformance example — is
//! documented in `docs/transports.md`; the executable contract is
//! `tests/transport_conformance.rs`. Select the backend with
//! `Simulator::with_transport` / `Trainer::set_transport` /
//! `fedlay train --method fedlay-dyn --transport tcp|sim`.
//!
//! ## Churn scenarios
//!
//! Resilience experiments are *declarative*: a [`sim::ScenarioSpec`]
//! (serializable TOML, see `docs/scenarios.md`) describes phases of mass
//! joins/failures/leaves, flash crowds, Poisson churn, and
//! partition-style bursts plus a sampling cadence, compiles to one
//! deterministic event schedule, and drives either a bare `Simulator`
//! or a full `Trainer` through the same path (`sim::ChurnSink`). Runs
//! emit a structured [`sim::ScenarioReport`] (correctness/ring-quality/
//! accuracy time series, neighbor-cache telemetry) consumed by the
//! Fig. 8 and Fig. 18/19 benches, the golden-trajectory and property
//! test suites, and `fedlay scenario run`. Under `Neighborhood::Dynamic`
//! the trainer reads aggregation neighborhoods through a per-client
//! cache invalidated by the simulator's view-change notifications,
//! which carries scenario runs to 10k clients
//! (`tests/scenario_scale.rs`).
//!
//! ## Byzantine resilience & robust aggregation
//!
//! Scenarios also carry an *adversarial* phase family (`poison` with
//! NaN/scale/sign-flip modes, `stale_replay`, ring-arc `eclipse` —
//! `docs/scenarios.md`): attackers are chosen in the same deterministic
//! compile replay as churn victims, stay alive serving their corrupted
//! payload, and stop training — on both backends identically. Defenses
//! live in [`mep::Aggregation`]: next to the historical
//! confidence-weighted `Mean` (bitwise-unchanged for clean runs) sit
//! coordinate-wise `TrimmedMean`/`Median` and `Krum` selection, wired
//! through `dfl::MethodSpec::with_aggregation`, the TCP node's config,
//! and `--aggregation` on the CLI. Independent of the rule, a
//! non-finite guard in front of every aggregation ([`mep::aggregate_cpu_guarded`],
//! the trainer's wake/round paths, and the TCP node's frame boundary)
//! counts and drops NaN/Inf rows so a single poisoned model can never
//! silently zero a neighborhood average — the rejected-model count and
//! an honest-vs-byzantine accuracy-gap series surface in
//! [`sim::ScenarioReport`]. Pinned by `tests/adversarial_aggregation.rs`.
//!
//! ## Multi-task engine
//!
//! One [`dfl::Trainer`] drives N independent model tasks — each a
//! [`dfl::TaskLane`] with its own dataset shards, model dimensions, MEP
//! period, seeds, and eval stream — over a *single* shared overlay and
//! scheduler (the paper's "machine learning tasks on distributed
//! devices", plural, on one near-random regular overlay). Wake/sample
//! events are task-tagged, fingerprint de-dup is keyed by
//! `(neighbor, task)` ([`mep::FingerprintCache`]), MEP wire frames carry
//! a task field on both transports, and churn flips every lane's
//! membership at once. Task isolation is a hard invariant — a lane's
//! trajectory is a pure function of its own [`config::TaskSpec`] plus
//! the shared churn schedule, reproduced bit-for-bit when other lanes
//! are removed (`tests/multitask_properties.rs`). Specs are TOML
//! (`config::MultiTaskSpec`, format in `docs/multitask.md`), the CLI is
//! `fedlay train --tasks <spec.toml>`, and scenarios drive multi-task
//! runs via `ScenarioSpec::run_trainer_tasks` /
//! `dfl::multitask::run_scenario`.
//!
//! ## Sharded event engine & perf harness
//!
//! The event engine shards by contiguous arcs of the `[0,1)` coordinate
//! circle ([`sim::Simulator::set_shards`]): each shard owns a scheduler
//! heap and arena-packed node state ([`sim::NodeArena`]), boundary
//! events cross through a deterministic mailbox, and per-instant merge
//! barriers replay global effects in producer-seq order — so a K-shard
//! run is *bitwise-identical* to the serial run while shard compute
//! fans out on rayon (as do independent same-instant trainer wakes).
//! Memory under sustained churn is O(live set): arena slots recycle and
//! departed nodes fold into scalar tallies
//! ([`sim::Simulator::footprint`]). This carries the pinned scale runs
//! to 100k clients (`tests/scenario_scale.rs`); the determinism battery
//! is `tests/shard_conformance.rs`. Hot paths are tracked by the
//! [`bench_util`] harness — `fedlay bench` emits `BENCH_*.json`
//! archived per CI run. Architecture and the determinism argument live
//! in `docs/perf.md`.
//!
//! ## Exhaustive model checking
//!
//! The NDMP join / fail / leave and ring-repair protocols are swept
//! *exhaustively* for small networks by the [`check`] subsystem: an
//! abstract model that runs the real [`ndmp::NodeState`] engines under
//! abstracted time, a BFS explorer over every message/tick/churn
//! interleaving (canonical-form dedup), tiered safety invariants shared
//! with the scenario suites ([`sim::invariants`]), and churn-free
//! convergence as the liveness property. A mutation harness
//! ([`check::mutations`]) flips known-critical repair lines behind the
//! test-only [`ndmp::Mutation`] hook and demands the explorer catch
//! each one with a minimal counterexample, printed as a text schedule
//! that replays through both the abstract model and the concrete
//! [`sim::Simulator`] ([`check::replay`]). Run it with `fedlay check`;
//! the design and the dedup-soundness argument live in
//! `docs/model-checking.md`.
//!
//! The `runtime` module executes models behind a single `Engine` API:
//! the PJRT CPU client running the AOT artifacts (feature `xla`), or a
//! pure-Rust reference backend with the identical ABI that needs no
//! artifacts. Python never runs on the request path.

pub mod baselines;
pub mod bench_util;
pub mod check;
pub mod config;
pub mod data;
pub mod dfl;
pub mod graph;
pub mod mep;
pub mod metrics;
pub mod ndmp;
pub mod net;
pub mod runtime;
pub mod sim;
pub mod telemetry;
pub mod topology;
pub mod util;
pub mod cli;
