//! # FedLay — practical overlay networks for decentralized federated learning
//!
//! A reproduction of *"Towards Practical Overlay Networks for Decentralized
//! Federated Learning"* (Hua et al., 2024) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the FedLay coordinator: the overlay topology
//!   built from random virtual coordinates (`topology`), the decentralized
//!   Neighbor Discovery and Maintenance Protocols (`ndmp`), the Model
//!   Exchange Protocol (`mep`), a deterministic discrete-event simulator
//!   (`sim`), a real TCP transport (`net`), all baseline topologies and
//!   DFL methods from the paper's evaluation (`baselines`, `dfl`), and the
//!   topology-metric pipeline (`metrics`).
//! * **L2 (python/compile/model.py)** — the JAX model zoo (MLP/CNN/LSTM),
//!   AOT-lowered once to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the MEP
//!   aggregation and fused SGD update, embedded in the L2 artifacts.
//!
//! The `runtime` module loads the AOT artifacts via the PJRT CPU client;
//! Python never runs on the request path.

pub mod baselines;
pub mod bench_util;
pub mod config;
pub mod data;
pub mod dfl;
pub mod graph;
pub mod mep;
pub mod metrics;
pub mod ndmp;
pub mod net;
pub mod runtime;
pub mod sim;
pub mod telemetry;
pub mod topology;
pub mod util;
pub mod cli;
