//! Churn scenario builders for the paper's resilience experiments:
//! mass joins (Fig. 8a), mass failures (Fig. 8b), and mixed churn.

use super::runner::Simulator;
use crate::ndmp::messages::{Time, MS};
use crate::topology::NodeId;
use crate::util::Rng;

/// Paper Fig. 8a: `joiners` new clients join an `initial`-node network at
/// the same instant (`at`), each through a random existing node.
pub fn mass_join(sim: &mut Simulator, initial: usize, joiners: usize, at: Time, seed: u64) {
    let ids: Vec<NodeId> = (0..initial as NodeId).collect();
    sim.bootstrap_correct(&ids);
    let mut rng = Rng::new(seed ^ 0x101B);
    for j in 0..joiners as NodeId {
        let bootstrap = ids[rng.index(ids.len())];
        sim.schedule_join(at, initial as NodeId + j, bootstrap);
    }
}

/// Paper Fig. 8b: `failures` random clients crash-fail simultaneously.
pub fn mass_fail(sim: &mut Simulator, initial: usize, failures: usize, at: Time, seed: u64) {
    let ids: Vec<NodeId> = (0..initial as NodeId).collect();
    sim.bootstrap_correct(&ids);
    let mut rng = Rng::new(seed ^ 0xFA11);
    let victims = rng.sample_indices(initial, failures);
    for v in victims {
        sim.schedule_fail(at, ids[v]);
    }
}

/// Mixed churn: Poisson-ish joins and failures over a window (failure
/// injection testing beyond the paper's extremes).
pub fn mixed_churn(
    sim: &mut Simulator,
    initial: usize,
    events: usize,
    window: Time,
    seed: u64,
) {
    let ids: Vec<NodeId> = (0..initial as NodeId).collect();
    sim.bootstrap_correct(&ids);
    let mut rng = Rng::new(seed ^ 0xC4A0);
    let mut next_id = initial as NodeId;
    let mut live: Vec<NodeId> = ids.clone();
    for _ in 0..events {
        let at = (rng.next_f64() * window as f64) as Time + 10 * MS;
        if rng.chance(0.5) {
            let bootstrap = live[rng.index(live.len())];
            sim.schedule_join(at, next_id, bootstrap);
            live.push(next_id);
            next_id += 1;
        } else if live.len() > initial / 2 {
            let idx = rng.index(live.len());
            sim.schedule_fail(at, live.swap_remove(idx));
        }
    }
}

/// Record correctness samples every `every` from 0 to `until`.
pub fn sample_correctness(sim: &mut Simulator, until: Time, every: Time) {
    let mut t = 0;
    while t <= until {
        sim.schedule_snapshot(t);
        t += every;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{NetConfig, OverlayConfig};

    fn mk_sim() -> Simulator {
        Simulator::new(
            OverlayConfig {
                spaces: 2,
                heartbeat_ms: 500,
                failure_multiple: 3,
                repair_probe_ms: 2_000,
            },
            NetConfig {
                latency_ms: 50.0,
                jitter: 0.1,
                seed: 3,
            },
        )
    }

    #[test]
    fn mass_join_converges_small() {
        let mut sim = mk_sim();
        mass_join(&mut sim, 30, 10, 10 * MS, 1);
        let t = sim.run_until_correct(1.0, 240_000 * MS, 2_000 * MS);
        assert!(t.is_some(), "mass join stuck at {}", sim.correctness());
        assert_eq!(sim.nodes.len(), 40);
    }

    #[test]
    fn mass_fail_recovers_small() {
        let mut sim = mk_sim();
        mass_fail(&mut sim, 40, 10, 10 * MS, 2);
        let t = sim.run_until_correct(1.0, 240_000 * MS, 2_000 * MS);
        assert!(t.is_some(), "mass fail stuck at {}", sim.correctness());
        assert_eq!(sim.nodes.len(), 30);
    }

    #[test]
    fn correctness_drops_then_recovers() {
        let mut sim = mk_sim();
        mass_fail(&mut sim, 40, 10, 10 * MS, 4);
        // sample finely: detection takes ~3 heartbeats (1.5s), repair a few
        // latencies more, so the dip is only visible sub-second.
        sample_correctness(&mut sim, 120_000 * MS, 200 * MS);
        sim.run_until(120_000 * MS);
        let dip = sim
            .samples
            .iter()
            .filter(|s| s.at > 10 * MS)
            .map(|s| s.correctness)
            .fold(1.0f64, f64::min);
        let last = sim.samples.last().unwrap();
        assert!(dip < 1.0, "no drop observed");
        assert!(last.correctness > dip);
    }
}
