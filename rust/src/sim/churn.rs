//! Churn scenario builders for the paper's resilience experiments:
//! mass joins (Fig. 8a), mass failures (Fig. 8b), and mixed churn.

use super::runner::Simulator;
use crate::ndmp::messages::{Time, MS};
use crate::topology::NodeId;
use crate::util::Rng;

/// Paper Fig. 8a: `joiners` new clients join an `initial`-node network at
/// the same instant (`at`), each through a random existing node.
pub fn mass_join(sim: &mut Simulator, initial: usize, joiners: usize, at: Time, seed: u64) {
    let ids: Vec<NodeId> = (0..initial as NodeId).collect();
    sim.bootstrap_correct(&ids);
    let mut rng = Rng::new(seed ^ 0x101B);
    for j in 0..joiners as NodeId {
        let bootstrap = ids[rng.index(ids.len())];
        sim.schedule_join(at, initial as NodeId + j, bootstrap);
    }
}

/// Paper Fig. 8b: `failures` random clients crash-fail simultaneously.
pub fn mass_fail(sim: &mut Simulator, initial: usize, failures: usize, at: Time, seed: u64) {
    let ids: Vec<NodeId> = (0..initial as NodeId).collect();
    sim.bootstrap_correct(&ids);
    let mut rng = Rng::new(seed ^ 0xFA11);
    let victims = rng.sample_indices(initial, failures);
    for v in victims {
        sim.schedule_fail(at, ids[v]);
    }
}

/// Mixed Poisson churn: joins and failures as one merged Poisson process
/// over a window — exponential inter-arrivals at rate `events / window`,
/// each arrival a join or a failure with probability 1/2 (failure
/// injection testing beyond the paper's extremes). `events` sets the
/// *expected* count; the realized count varies with the seed, and the
/// process is truncated at the window's end.
///
/// For richer processes (independent join/fail/leave rates, flash
/// crowds, partition bursts) use `sim::scenario::ScenarioSpec`.
pub fn mixed_churn(
    sim: &mut Simulator,
    initial: usize,
    events: usize,
    window: Time,
    seed: u64,
) {
    let ids: Vec<NodeId> = (0..initial as NodeId).collect();
    sim.bootstrap_correct(&ids);
    let mut rng = Rng::new(seed ^ 0xC4A0);
    let mut next_id = initial as NodeId;
    let mut live: Vec<NodeId> = ids.clone();
    if events == 0 || window == 0 {
        return;
    }
    let rate_per_us = events as f64 / window as f64;
    let mut at = 10 * MS;
    loop {
        let dt = rng.exponential(rate_per_us);
        if !dt.is_finite() || dt >= (Time::MAX / 4) as f64 {
            break;
        }
        at += dt.max(1.0) as Time;
        if at >= 10 * MS + window {
            break;
        }
        if rng.chance(0.5) {
            let bootstrap = live[rng.index(live.len())];
            sim.schedule_join(at, next_id, bootstrap);
            live.push(next_id);
            next_id += 1;
        } else if live.len() > initial / 2 {
            let idx = rng.index(live.len());
            sim.schedule_fail(at, live.swap_remove(idx));
        }
    }
}

/// The pre-Poisson behavior of `mixed_churn`: event times drawn
/// *uniformly* over the window (kept for experiments that want a flat
/// arrival profile rather than exponential inter-arrivals).
pub fn uniform_churn(
    sim: &mut Simulator,
    initial: usize,
    events: usize,
    window: Time,
    seed: u64,
) {
    let ids: Vec<NodeId> = (0..initial as NodeId).collect();
    sim.bootstrap_correct(&ids);
    let mut rng = Rng::new(seed ^ 0xC4A0);
    let mut next_id = initial as NodeId;
    let mut live: Vec<NodeId> = ids.clone();
    for _ in 0..events {
        let at = (rng.next_f64() * window as f64) as Time + 10 * MS;
        if rng.chance(0.5) {
            let bootstrap = live[rng.index(live.len())];
            sim.schedule_join(at, next_id, bootstrap);
            live.push(next_id);
            next_id += 1;
        } else if live.len() > initial / 2 {
            let idx = rng.index(live.len());
            sim.schedule_fail(at, live.swap_remove(idx));
        }
    }
}

/// Record correctness samples every `every` from 0 to `until`.
///
/// `every == 0` (easy to produce from integer cadence math like
/// `until / 40` on a tiny horizon) is clamped to 1 — sampling every
/// microsecond over a horizon that small is harmless, whereas the
/// unguarded `t += 0` spun forever scheduling snapshots at t = 0.
pub fn sample_correctness(sim: &mut Simulator, until: Time, every: Time) {
    let every = every.max(1);
    let mut t = 0;
    while t <= until {
        sim.schedule_snapshot(t);
        t += every;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{NetConfig, OverlayConfig};
    use crate::sim::event::EventKind;

    fn mk_sim() -> Simulator {
        Simulator::new(
            OverlayConfig {
                spaces: 2,
                heartbeat_ms: 500,
                failure_multiple: 3,
                repair_probe_ms: 2_000,
            },
            NetConfig {
                latency_ms: 50.0,
                jitter: 0.1,
                seed: 3,
                ..NetConfig::default()
            },
        )
    }

    #[test]
    fn mass_join_converges_small() {
        let mut sim = mk_sim();
        mass_join(&mut sim, 30, 10, 10 * MS, 1);
        let t = sim.run_until_correct(1.0, 240_000 * MS, 2_000 * MS);
        assert!(t.is_some(), "mass join stuck at {}", sim.correctness());
        assert_eq!(sim.live_count(), 40);
    }

    #[test]
    fn mass_fail_recovers_small() {
        let mut sim = mk_sim();
        mass_fail(&mut sim, 40, 10, 10 * MS, 2);
        let t = sim.run_until_correct(1.0, 240_000 * MS, 2_000 * MS);
        assert!(t.is_some(), "mass fail stuck at {}", sim.correctness());
        assert_eq!(sim.live_count(), 30);
    }

    /// Drain the scheduled churn (join/fail/leave) times off the queue.
    fn churn_times(sim: &mut Simulator) -> Vec<Time> {
        let mut ts = Vec::new();
        while let Some(e) = sim.pop_event() {
            if matches!(
                e.kind,
                EventKind::Join { .. } | EventKind::Fail { .. } | EventKind::Leave { .. }
            ) {
                ts.push(e.at);
            }
        }
        ts
    }

    #[test]
    fn mixed_churn_has_exponential_interarrivals() {
        let events = 30usize;
        let window = 30_000 * MS;
        let mut counts = Vec::new();
        let mut spacings: Vec<f64> = Vec::new();
        for seed in 0..12u64 {
            let mut sim = mk_sim();
            mixed_churn(&mut sim, 40, events, window, seed);
            let ts = churn_times(&mut sim);
            assert!(
                ts.windows(2).all(|w| w[0] <= w[1]),
                "arrivals out of order (seed {seed})"
            );
            assert!(ts.iter().all(|&t| t < 10 * MS + window));
            spacings.extend(ts.windows(2).map(|w| (w[1] - w[0]) as f64));
            counts.push(ts.len());
        }
        // a Poisson process has a *random* event count — the old uniform
        // sampler always scheduled at most exactly `events`
        assert!(
            counts.iter().any(|&c| c != counts[0]),
            "event counts identical across seeds: {counts:?}"
        );
        // pooled mean inter-arrival ~= 1/rate = window/events
        let want = window as f64 / events as f64;
        let mean = spacings.iter().sum::<f64>() / spacings.len() as f64;
        assert!(
            mean > 0.6 * want && mean < 1.67 * want,
            "mean spacing {mean:.0}us vs expected {want:.0}us"
        );
    }

    #[test]
    fn mixed_and_uniform_churn_are_deterministic_and_distinct() {
        let collect = |f: &dyn Fn(&mut Simulator)| {
            let mut sim = mk_sim();
            f(&mut sim);
            churn_times(&mut sim)
        };
        let poisson = collect(&|s| mixed_churn(s, 30, 12, 20_000 * MS, 9));
        let poisson2 = collect(&|s| mixed_churn(s, 30, 12, 20_000 * MS, 9));
        let uniform = collect(&|s| uniform_churn(s, 30, 12, 20_000 * MS, 9));
        assert_eq!(poisson, poisson2, "mixed_churn not deterministic");
        assert_ne!(poisson, uniform, "uniform_churn should keep the old draw");
        assert_eq!(uniform.len(), 12, "uniform schedules exactly `events`");
    }

    /// Regression: the CLI passes `until / 40` as the cadence, which is
    /// 0 for any horizon under 40 ticks — the unguarded loop never
    /// terminated. A tiny horizon must now schedule (and run) finitely.
    #[test]
    fn tiny_horizon_sampling_terminates() {
        let mut sim = mk_sim();
        sim.bootstrap_correct(&(0..10).collect::<Vec<_>>());
        let until = 25; // µs — way under any sane cadence divisor
        sample_correctness(&mut sim, until, until / 40);
        sim.run_until(until);
        // clamped to every-1µs: exactly until+1 samples, all at c = 1
        assert_eq!(sim.samples.len(), until as usize + 1);
        assert!(sim.samples.iter().all(|s| s.correctness == 1.0));
    }

    #[test]
    fn correctness_drops_then_recovers() {
        let mut sim = mk_sim();
        mass_fail(&mut sim, 40, 10, 10 * MS, 4);
        // sample finely: detection takes ~3 heartbeats (1.5s), repair a few
        // latencies more, so the dip is only visible sub-second.
        sample_correctness(&mut sim, 120_000 * MS, 200 * MS);
        sim.run_until(120_000 * MS);
        let dip = sim
            .samples
            .iter()
            .filter(|s| s.at > 10 * MS)
            .map(|s| s.correctness)
            .fold(1.0f64, f64::min);
        let last = sim.samples.last().unwrap();
        assert!(dip < 1.0, "no drop observed");
        assert!(last.correctness > dip);
    }
}
