//! Slot arena for live-node protocol state.
//!
//! Replaces the simulator's old `BTreeMap<NodeId, NodeState>`: node
//! state lives packed in a `Vec` of slots (departed slots go on a free
//! list and are reused), a hash index maps ids to slots, and a
//! [`BitSet`] tracks slot aliveness so iteration skips dead regions a
//! whole word at a time. Memory is bounded by the *peak live set*, not
//! by join history — sustained churn recycles slots instead of growing
//! the map.
//!
//! Iteration over the bitset is in slot order, which is admission
//! order, not id order — callers that need deterministic id-ordered
//! output (snapshots, golden lines) go through [`NodeArena::ids_sorted`].

use crate::ndmp::node::NodeState;
use crate::topology::NodeId;
use crate::util::BitSet;
use std::collections::HashMap;

#[derive(Debug)]
pub struct NodeArena {
    slots: Vec<Option<NodeState>>,
    free: Vec<u32>,
    index: HashMap<NodeId, u32>,
    alive: BitSet,
}

impl Default for NodeArena {
    fn default() -> Self {
        Self::new()
    }
}

impl NodeArena {
    pub fn new() -> Self {
        Self {
            slots: Vec::new(),
            free: Vec::new(),
            index: HashMap::new(),
            alive: BitSet::new(0),
        }
    }

    /// Admit a node (keyed by `st.id`). Panics if the id is already
    /// present — the simulator's Join arm checks membership first.
    pub fn insert(&mut self, st: NodeState) {
        let id = st.id;
        assert!(
            !self.index.contains_key(&id),
            "node {id} inserted twice into arena"
        );
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                self.slots.push(None);
                self.alive.grow(self.slots.len());
                (self.slots.len() - 1) as u32
            }
        };
        self.slots[slot as usize] = Some(st);
        self.alive.set(slot as usize);
        self.index.insert(id, slot);
    }

    /// Remove a node, returning its state; the slot is recycled.
    pub fn remove(&mut self, id: NodeId) -> Option<NodeState> {
        let slot = self.index.remove(&id)?;
        self.alive.clear(slot as usize);
        self.free.push(slot);
        Some(self.slots[slot as usize].take().expect("indexed slot empty"))
    }

    pub fn get(&self, id: NodeId) -> Option<&NodeState> {
        let slot = *self.index.get(&id)?;
        self.slots[slot as usize].as_ref()
    }

    pub fn get_mut(&mut self, id: NodeId) -> Option<&mut NodeState> {
        let slot = *self.index.get(&id)?;
        self.slots[slot as usize].as_mut()
    }

    pub fn contains(&self, id: NodeId) -> bool {
        self.index.contains_key(&id)
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Live node ids in ascending order (the deterministic view order).
    pub fn ids_sorted(&self) -> Vec<NodeId> {
        let mut ids: Vec<NodeId> = self.index.keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    /// Live nodes in slot (admission) order — for order-insensitive
    /// reductions like counter sums.
    pub fn iter_unordered(&self) -> impl Iterator<Item = &NodeState> + '_ {
        self.alive
            .iter_ones()
            .map(|s| self.slots[s].as_ref().expect("alive slot empty"))
    }

    /// Slots currently allocated (live + recyclable). The footprint
    /// regression test pins this to the peak live set under churn.
    pub fn slot_capacity(&self) -> usize {
        self.slots.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OverlayConfig;

    fn node(id: NodeId) -> NodeState {
        let cfg = OverlayConfig {
            spaces: 2,
            heartbeat_ms: 500,
            failure_multiple: 3,
            repair_probe_ms: 2_000,
        };
        NodeState::new(id, cfg, 0)
    }

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut a = NodeArena::new();
        for id in [5u64, 1, 9] {
            a.insert(node(id));
        }
        assert_eq!(a.len(), 3);
        assert!(a.contains(5) && !a.contains(2));
        assert_eq!(a.get(1).unwrap().id, 1);
        a.get_mut(9).unwrap().joined = true;
        assert!(a.get(9).unwrap().joined);
        assert_eq!(a.ids_sorted(), vec![1, 5, 9]);
        let gone = a.remove(5).unwrap();
        assert_eq!(gone.id, 5);
        assert!(a.remove(5).is_none());
        assert_eq!(a.ids_sorted(), vec![1, 9]);
    }

    #[test]
    fn slots_recycle_under_churn() {
        let mut a = NodeArena::new();
        for id in 0..100u64 {
            a.insert(node(id));
        }
        let peak = a.slot_capacity();
        // sustained churn: one departure per admission
        for round in 0..1_000u64 {
            a.remove(round % 100).unwrap();
            a.insert(node(100 + round));
            a.remove(100 + round).unwrap();
            a.insert(node(round % 100));
        }
        assert_eq!(a.len(), 100);
        assert!(
            a.slot_capacity() <= peak + 1,
            "arena grew with history: {} slots",
            a.slot_capacity()
        );
        let sum: u64 = a.iter_unordered().map(|n| n.id).sum();
        assert_eq!(sum, (0..100u64).sum());
    }
}
