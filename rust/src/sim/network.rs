//! Virtual link latency: one-way delay = `latency_ms` plus an
//! exponential jitter tail, sampled from a deterministic per-link
//! stream ([`LinkDelay`]). Both message backends consume the same
//! component — `SimTransport` turns each sample into a queue-scheduled
//! delivery time, `net::SchedTransport` stamps it into the wire frame —
//! which is what makes arrival *timestamps* (not just converged
//! topologies) conformant across backends (see `docs/transports.md`).

use super::transport::{Arrival, Transport};
use crate::config::NetConfig;
use crate::ndmp::messages::{Msg, Time};
use crate::topology::NodeId;
use crate::util::Rng;
use std::collections::HashMap;

/// One delay distribution: base latency plus an exponential tail with
/// mean `jitter * base`. Every sample is at least 1 µs so virtual
/// arrivals are strictly after their sends.
#[derive(Debug)]
pub struct LatencyModel {
    base_us: f64,
    jitter: f64,
    rng: Rng,
}

impl LatencyModel {
    /// One stream seeded from the config alone (the pre-`LinkDelay`
    /// behavior; kept for direct distribution use and tests).
    pub fn new(cfg: &NetConfig) -> Self {
        Self::with_seed(cfg, cfg.seed ^ 0x1a7e_0c11)
    }

    /// One stream with an explicit seed — `LinkDelay` derives one per
    /// directed link so the delay sequence of a link depends only on the
    /// config seed and the link's endpoints, never on global send order.
    pub fn with_seed(cfg: &NetConfig, seed: u64) -> Self {
        Self {
            base_us: cfg.latency_ms * 1_000.0,
            jitter: cfg.jitter,
            rng: Rng::new(seed),
        }
    }

    /// Sample a one-way delay in microseconds (>= 1).
    pub fn sample(&mut self) -> Time {
        let jitter = if self.jitter > 0.0 {
            self.rng.exponential(1.0 / (self.jitter * self.base_us.max(1.0)))
        } else {
            0.0
        };
        (self.base_us + jitter).max(1.0) as Time
    }
}

/// Deterministic per-link delay: the shared component both transport
/// backends sample. Each directed link `(from, to)` owns an independent
/// [`LatencyModel`] stream seeded from `(config seed, from, to)`, so
///
/// * the k-th message on a link gets the same delay on every backend
///   (per-link send order is identical when both replay one schedule),
/// * links never perturb each other's sequences, and
/// * a link's sequence is reproducible from the config seed alone.
#[derive(Debug)]
pub struct LinkDelay {
    cfg: NetConfig,
    links: HashMap<(NodeId, NodeId), LatencyModel>,
    /// Nodes with a live endpoint (`open`ed, not yet `forget`ed): only
    /// links between two open nodes cache a stream; everything else is
    /// sampled ephemerally. Tracking the *open* set — instead of the
    /// old ever-growing closed set — bounds this map by the live mesh
    /// under unbounded churn: post-close traffic (e.g. a dead node's
    /// neighbors heartbeating it until failure detection) can't regrow
    /// it, and departed ids leave no tombstone behind.
    open: std::collections::HashSet<NodeId>,
}

impl LinkDelay {
    pub fn new(cfg: &NetConfig) -> Self {
        Self {
            cfg: cfg.clone(),
            links: HashMap::new(),
            open: std::collections::HashSet::new(),
        }
    }

    /// Seed for the directed link `from -> to`: SplitMix64-style mixing
    /// keeps nearby id pairs statistically independent.
    fn link_seed(seed: u64, from: NodeId, to: NodeId) -> u64 {
        let mut z = seed ^ 0x9E37_79B9_7F4A_7C15;
        for part in [from, to] {
            z = (z ^ part).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        }
        z ^ (z >> 31)
    }

    /// Sample the next delay (µs, >= 1) on the directed link `from -> to`.
    ///
    /// Links touching a non-open node draw from a fresh seed-initialized
    /// stream each call instead of a cached one: such sends are dropped
    /// or delivered-to-dead on every backend, so the values are
    /// unobservable — both backends compute the same ones — and caching
    /// them would regrow the map with dead links.
    pub fn sample(&mut self, from: NodeId, to: NodeId) -> Time {
        let cfg = &self.cfg;
        if !self.open.contains(&from) || !self.open.contains(&to) {
            return LatencyModel::with_seed(cfg, Self::link_seed(cfg.seed, from, to)).sample();
        }
        self.links
            .entry((from, to))
            .or_insert_with(|| {
                LatencyModel::with_seed(cfg, Self::link_seed(cfg.seed, from, to))
            })
            .sample()
    }

    /// `node`'s endpoint closed: drop every link stream touching it and
    /// sample its links ephemerally from now on. Both backends call this
    /// from `Transport::close`, so link state stays identical across
    /// them.
    pub fn forget(&mut self, node: NodeId) {
        self.links.retain(|&(from, to), _| from != node && to != node);
        self.open.remove(&node);
    }

    /// `node`'s endpoint (re)opened: cached streaming for its links to
    /// other open nodes. A reused id restarts its links from their seeds
    /// — on both backends, since both pruned at close.
    pub fn reopen(&mut self, node: NodeId) {
        self.open.insert(node);
    }

    /// Cached link streams held (footprint telemetry).
    pub fn link_count(&self) -> usize {
        self.links.len()
    }

    /// Open endpoints tracked (footprint telemetry; bounded by the live
    /// set, unlike the pre-inversion closed-set which grew per departure).
    pub fn open_count(&self) -> usize {
        self.open.len()
    }
}

/// The in-memory message backend: every send is scheduled back onto the
/// caller's event queue after a per-link [`LinkDelay`] sample. Fully
/// deterministic per seed — the reference behavior the TCP backend is
/// conformance-tested against.
#[derive(Debug)]
pub struct SimTransport {
    delay: LinkDelay,
}

impl SimTransport {
    pub fn new(cfg: &NetConfig) -> Self {
        Self {
            delay: LinkDelay::new(cfg),
        }
    }
}

impl Transport for SimTransport {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn open(&mut self, node: NodeId) -> anyhow::Result<()> {
        self.delay.reopen(node);
        Ok(())
    }

    fn close(&mut self, node: NodeId) {
        self.delay.forget(node);
    }

    fn send(&mut self, now: Time, from: NodeId, to: NodeId, _msg: &Msg) -> Option<Time> {
        // saturating, to match the wire path's `Stamp::due()` on absurd
        // configured latencies
        Some(now.saturating_add(self.delay.sample(from, to)))
    }

    fn poll(&mut self) -> Vec<Arrival> {
        Vec::new()
    }

    fn idle(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_near_base_plus_jitter() {
        let cfg = NetConfig {
            latency_ms: 350.0,
            jitter: 0.2,
            seed: 1,
        };
        let mut m = LatencyModel::new(&cfg);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| m.sample() as f64).sum::<f64>() / n as f64;
        let want = 350_000.0 * 1.2; // base + exp(mean = jitter*base)
        assert!((mean - want).abs() < want * 0.05, "mean {mean} want {want}");
    }

    #[test]
    fn zero_jitter_is_constant() {
        let cfg = NetConfig {
            latency_ms: 10.0,
            jitter: 0.0,
            seed: 2,
        };
        let mut m = LatencyModel::new(&cfg);
        assert!((0..100).all(|_| m.sample() == 10_000));
    }

    #[test]
    fn link_delay_is_deterministic_per_seed() {
        let cfg = NetConfig {
            latency_ms: 40.0,
            jitter: 0.3,
            seed: 11,
        };
        let draw = |cfg: &NetConfig| {
            let mut d = LinkDelay::new(cfg);
            for n in 0..5 {
                d.reopen(n);
            }
            (0..200).map(|i| d.sample(i % 5, (i + 1) % 5)).collect::<Vec<Time>>()
        };
        assert_eq!(draw(&cfg), draw(&cfg), "same seed must replay identically");
        let other = NetConfig {
            seed: 12,
            ..cfg.clone()
        };
        assert_ne!(draw(&cfg), draw(&other), "different seeds must differ");
    }

    #[test]
    fn link_delay_respects_distribution_bounds() {
        let cfg = NetConfig {
            latency_ms: 25.0,
            jitter: 0.2,
            seed: 3,
        };
        let mut d = LinkDelay::new(&cfg);
        d.reopen(1);
        d.reopen(2);
        let n = 30_000;
        let samples: Vec<Time> = (0..n).map(|_| d.sample(1, 2)).collect();
        // hard floor: base latency (jitter only ever adds)
        assert!(samples.iter().all(|&s| s >= 25_000));
        // mean tracks base * (1 + jitter)
        let mean = samples.iter().map(|&s| s as f64).sum::<f64>() / n as f64;
        let want = 25_000.0 * 1.2;
        assert!((mean - want).abs() < want * 0.05, "mean {mean} want {want}");
        // zero-latency configs still produce strictly positive delays
        let zero = NetConfig {
            latency_ms: 0.0,
            jitter: 0.0,
            seed: 3,
        };
        let mut z = LinkDelay::new(&zero);
        z.reopen(1);
        z.reopen(2);
        assert!((0..100).all(|_| z.sample(1, 2) == 1));
    }

    #[test]
    fn links_are_independent_streams() {
        let cfg = NetConfig {
            latency_ms: 50.0,
            jitter: 0.5,
            seed: 7,
        };
        let opened = |cfg: &NetConfig| {
            let mut d = LinkDelay::new(cfg);
            for n in 1..=4 {
                d.reopen(n);
            }
            d
        };
        // interleaving draws on link B must not shift link A's sequence
        let mut solo = opened(&cfg);
        let a_solo: Vec<Time> = (0..50).map(|_| solo.sample(1, 2)).collect();
        let mut mixed = opened(&cfg);
        let a_mixed: Vec<Time> = (0..50)
            .map(|_| {
                mixed.sample(3, 4);
                mixed.sample(2, 1); // reverse direction is its own link too
                mixed.sample(1, 2)
            })
            .collect();
        assert_eq!(a_solo, a_mixed, "foreign links perturbed link (1,2)");
        // distinct links draw distinct sequences
        let mut d = opened(&cfg);
        let a: Vec<Time> = (0..50).map(|_| d.sample(1, 2)).collect();
        let b: Vec<Time> = (0..50).map(|_| d.sample(2, 1)).collect();
        assert_ne!(a, b, "directed links must not share a stream");
    }

    #[test]
    fn forget_prunes_links_and_samples_dead_ones_ephemerally() {
        let cfg = NetConfig {
            latency_ms: 50.0,
            jitter: 0.5,
            seed: 9,
        };
        let mut d = LinkDelay::new(&cfg);
        for n in 1..=3 {
            d.reopen(n);
        }
        let first = d.sample(1, 2);
        let second = d.sample(1, 2);
        assert_ne!(first, second, "jittered stream should advance");
        d.sample(2, 3); // untouched by the forget below
        let third_continuation = {
            let mut probe = LinkDelay::new(&cfg);
            probe.reopen(2);
            probe.reopen(3);
            probe.sample(2, 3);
            probe.sample(2, 3)
        };
        d.forget(1);
        // links touching the closed node sample ephemerally (fresh from
        // the seed every call, nothing cached); (2,3) streams on
        assert_eq!(d.sample(1, 2), first);
        assert_eq!(d.sample(1, 2), first);
        assert_eq!(d.sample(2, 3), third_continuation);
        // a reopened (reused) id resumes cached streaming from its seed
        d.reopen(1);
        assert_eq!(d.sample(1, 2), first);
        assert_eq!(d.sample(1, 2), second);
    }

    #[test]
    fn churned_ids_leave_no_tombstones() {
        let cfg = NetConfig {
            latency_ms: 10.0,
            jitter: 0.1,
            seed: 6,
        };
        let mut d = LinkDelay::new(&cfg);
        d.reopen(0);
        for id in 1..5_000u64 {
            d.reopen(id);
            d.sample(0, id);
            d.sample(id, 0);
            d.forget(id);
        }
        // every link touching a departed id is pruned and no per-id
        // tombstone survives: state is bounded by the live set (node 0)
        assert_eq!(d.open_count(), 1);
        assert_eq!(d.link_count(), 0);
    }

    #[test]
    fn sim_transport_schedules_and_never_polls() {
        let cfg = NetConfig {
            latency_ms: 5.0,
            jitter: 0.0,
            seed: 3,
        };
        let mut t = SimTransport::new(&cfg);
        assert!(t.idle());
        assert!(t.open(1).is_ok());
        let at = t.send(100, 1, 2, &Msg::Heartbeat);
        assert_eq!(at, Some(100 + 5_000));
        assert!(t.poll().is_empty());
        t.close(1);
    }

    #[test]
    fn sim_transport_broadcast_schedules_every_destination() {
        let cfg = NetConfig {
            latency_ms: 2.0,
            jitter: 0.0,
            seed: 4,
        };
        let mut t = SimTransport::new(&cfg);
        let scheduled = t.broadcast(50, 1, &[2, 3, 4], &Msg::Heartbeat);
        assert_eq!(
            scheduled,
            vec![(2, 50 + 2_000), (3, 50 + 2_000), (4, 50 + 2_000)]
        );
    }
}
