//! The virtual link model. [`LinkDelay`] owns propagation latency:
//! one-way delay = `latency_ms` plus an exponential jitter tail, sampled
//! from a deterministic per-link stream. [`LinkModel`] layers the rest
//! of a realistic link on top: seeded per-directed-link bandwidth
//! (transfer time ∝ payload bytes), an independent per-link loss
//! lottery, and per-node up/down capacity queues (concurrent sends
//! share a node's uplink, so large payloads create stragglers). Both
//! message backends consume the same component — `SimTransport` turns
//! each sample into a queue-scheduled delivery time (or silently drops
//! a lost frame), `net::SchedTransport` stamps the full delay into the
//! wire frame (or deliberately skips the write) — which is what makes
//! arrival *timestamps* and *drop counts* (not just converged
//! topologies) conformant across backends (see `docs/transports.md`).

use super::transport::{Arrival, Transport};
use crate::config::NetConfig;
use crate::ndmp::messages::{Msg, Time};
use crate::topology::NodeId;
use crate::util::Rng;
use std::collections::HashMap;

/// One delay distribution: base latency plus an exponential tail with
/// mean `jitter * base`. Every sample is at least 1 µs so virtual
/// arrivals are strictly after their sends.
#[derive(Debug)]
pub struct LatencyModel {
    base_us: f64,
    jitter: f64,
    rng: Rng,
}

impl LatencyModel {
    /// One stream seeded from the config alone (the pre-`LinkDelay`
    /// behavior; kept for direct distribution use and tests).
    pub fn new(cfg: &NetConfig) -> Self {
        Self::with_seed(cfg, cfg.seed ^ 0x1a7e_0c11)
    }

    /// One stream with an explicit seed — `LinkDelay` derives one per
    /// directed link so the delay sequence of a link depends only on the
    /// config seed and the link's endpoints, never on global send order.
    pub fn with_seed(cfg: &NetConfig, seed: u64) -> Self {
        Self {
            base_us: cfg.latency_ms * 1_000.0,
            jitter: cfg.jitter,
            rng: Rng::new(seed),
        }
    }

    /// Sample a one-way delay in microseconds (>= 1).
    pub fn sample(&mut self) -> Time {
        let jitter = if self.jitter > 0.0 {
            self.rng.exponential(1.0 / (self.jitter * self.base_us.max(1.0)))
        } else {
            0.0
        };
        (self.base_us + jitter).max(1.0) as Time
    }
}

/// Deterministic per-link delay: the shared component both transport
/// backends sample. Each directed link `(from, to)` owns an independent
/// [`LatencyModel`] stream seeded from `(config seed, from, to)`, so
///
/// * the k-th message on a link gets the same delay on every backend
///   (per-link send order is identical when both replay one schedule),
/// * links never perturb each other's sequences, and
/// * a link's sequence is reproducible from the config seed alone.
#[derive(Debug)]
pub struct LinkDelay {
    cfg: NetConfig,
    links: HashMap<(NodeId, NodeId), LatencyModel>,
    /// Nodes with a live endpoint (`open`ed, not yet `forget`ed): only
    /// links between two open nodes cache a stream; everything else is
    /// sampled ephemerally. Tracking the *open* set — instead of the
    /// old ever-growing closed set — bounds this map by the live mesh
    /// under unbounded churn: post-close traffic (e.g. a dead node's
    /// neighbors heartbeating it until failure detection) can't regrow
    /// it, and departed ids leave no tombstone behind.
    open: std::collections::HashSet<NodeId>,
}

impl LinkDelay {
    pub fn new(cfg: &NetConfig) -> Self {
        Self {
            cfg: cfg.clone(),
            links: HashMap::new(),
            open: std::collections::HashSet::new(),
        }
    }

    /// Seed for the directed link `from -> to`: SplitMix64-style mixing
    /// keeps nearby id pairs statistically independent. `LinkModel`
    /// derives its loss and bandwidth streams from the same mixer under
    /// distinct salts, so they never correlate with the delay streams.
    pub(crate) fn link_seed(seed: u64, from: NodeId, to: NodeId) -> u64 {
        let mut z = seed ^ 0x9E37_79B9_7F4A_7C15;
        for part in [from, to] {
            z = (z ^ part).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        }
        z ^ (z >> 31)
    }

    /// Sample the next delay (µs, >= 1) on the directed link `from -> to`.
    ///
    /// Links touching a non-open node draw from a fresh seed-initialized
    /// stream each call instead of a cached one: such sends are dropped
    /// or delivered-to-dead on every backend, so the values are
    /// unobservable — both backends compute the same ones — and caching
    /// them would regrow the map with dead links.
    pub fn sample(&mut self, from: NodeId, to: NodeId) -> Time {
        let cfg = &self.cfg;
        if !self.open.contains(&from) || !self.open.contains(&to) {
            return LatencyModel::with_seed(cfg, Self::link_seed(cfg.seed, from, to)).sample();
        }
        self.links
            .entry((from, to))
            .or_insert_with(|| {
                LatencyModel::with_seed(cfg, Self::link_seed(cfg.seed, from, to))
            })
            .sample()
    }

    /// `node`'s endpoint closed: drop every link stream touching it and
    /// sample its links ephemerally from now on. Both backends call this
    /// from `Transport::close`, so link state stays identical across
    /// them.
    pub fn forget(&mut self, node: NodeId) {
        self.links.retain(|&(from, to), _| from != node && to != node);
        self.open.remove(&node);
    }

    /// `node`'s endpoint (re)opened: cached streaming for its links to
    /// other open nodes. A reused id restarts its links from their seeds
    /// — on both backends, since both pruned at close.
    pub fn reopen(&mut self, node: NodeId) {
        self.open.insert(node);
    }

    /// Cached link streams held (footprint telemetry).
    pub fn link_count(&self) -> usize {
        self.links.len()
    }

    /// Open endpoints tracked (footprint telemetry; bounded by the live
    /// set, unlike the pre-inversion closed-set which grew per departure).
    pub fn open_count(&self) -> usize {
        self.open.len()
    }
}

/// Salt separating the per-link *loss* streams from the delay streams.
const LOSS_SALT: u64 = 0x4C05_5A17_9E3B_D201;

/// Transfer time in µs of `bytes` over a `mbps` pipe: 1 Mbit/s carries
/// exactly 1 bit per µs, so `time = bits / mbps`. Ceiled and floored at
/// 1 µs so serialization always advances virtual time deterministically.
/// Callers guarantee `mbps > 0`.
fn transfer_us(bytes: u64, mbps: f64) -> Time {
    ((bytes as f64 * 8.0) / mbps).ceil().max(1.0) as Time
}

/// The full per-link model both transport backends sample: propagation
/// (the wrapped [`LinkDelay`] — its streams, seeds, and open-set
/// semantics are untouched, so latency-only configs reproduce the
/// pre-`LinkModel` sequences bitwise), plus
///
/// * **per-link bandwidth** — each directed link gets a capacity drawn
///   deterministically in `[0.5, 1.5) × bandwidth_mbps` from a salted
///   hash of `(seed, from, to)` (stateless: no stream to keep aligned),
///   adding `bytes / capacity` of serialization time;
/// * **per-link loss** — an independent seeded lottery stream per
///   directed link (salted, so it never correlates with the delay
///   stream); a hit means the frame is dropped before scheduling.
///   When `loss == 0` no stream is consumed at all, so lossless configs
///   carry zero extra state on either backend;
/// * **per-node capacity queues** — a busy-until horizon per sender
///   uplink and receiver downlink (`node_up_mbps` / `node_down_mbps`):
///   concurrent sends from one node queue behind each other, which is
///   exactly how large model payloads create stragglers.
///
/// `sample` returns `None` for a lost frame — after consuming the same
/// stream draws a delivered frame would have consumed, so outcomes
/// never shift a link's sequence between backends. Delivery time
/// composes as `uplink queue+ser → link ser → propagation → downlink
/// queue+ser`, every stage saturating.
#[derive(Debug)]
pub struct LinkModel {
    cfg: NetConfig,
    delay: LinkDelay,
    /// Per-directed-link loss lottery streams (only for links between
    /// two open nodes, mirroring `LinkDelay`'s ephemeral rule).
    loss: HashMap<(NodeId, NodeId), Rng>,
    /// Open endpoints (the loss/busy mirror of `LinkDelay::open`).
    open: std::collections::HashSet<NodeId>,
    /// Busy-until horizon of each node's uplink / downlink.
    up_busy: HashMap<NodeId, Time>,
    down_busy: HashMap<NodeId, Time>,
    /// Frames the loss lottery dropped (telemetry; conformance asserts
    /// this matches across backends).
    lost: u64,
}

impl LinkModel {
    pub fn new(cfg: &NetConfig) -> Self {
        Self {
            cfg: cfg.clone(),
            delay: LinkDelay::new(cfg),
            loss: HashMap::new(),
            open: std::collections::HashSet::new(),
            up_busy: HashMap::new(),
            down_busy: HashMap::new(),
            lost: 0,
        }
    }

    /// The directed link's capacity in Mbit/s: the configured mean
    /// scaled by a seeded factor in `[0.5, 1.5)`. Pure function of
    /// `(seed, from, to)` — no state, nothing to prune or replay.
    pub fn link_mbps(&self, from: NodeId, to: NodeId) -> f64 {
        let h = LinkDelay::link_seed(self.cfg.seed ^ BW_SALT, from, to);
        let frac = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        self.cfg.bandwidth_mbps * (0.5 + frac)
    }

    /// Draw the loss lottery for one send on `from -> to`. Links
    /// touching a non-open node draw ephemerally (fresh stream each
    /// call) exactly like `LinkDelay::sample` — identical on both
    /// backends, and departed links leave no state behind.
    fn draw_loss(&mut self, from: NodeId, to: NodeId) -> bool {
        let p = self.cfg.loss;
        let seed = LinkDelay::link_seed(self.cfg.seed ^ LOSS_SALT, from, to);
        if !self.open.contains(&from) || !self.open.contains(&to) {
            return Rng::new(seed).next_f64() < p;
        }
        self.loss
            .entry((from, to))
            .or_insert_with(|| Rng::new(seed))
            .next_f64()
            < p
    }

    /// Sample one send of `bytes` on `from -> to` at virtual time `now`:
    /// `Some(deliver_at)` or `None` if the loss lottery dropped it.
    ///
    /// The propagation and loss streams advance *first, unconditionally,
    /// in this order* — every send consumes the same stream positions on
    /// every backend whatever the outcome. Capacity horizons advance
    /// only for delivered frames (a lost frame never transmits), and in
    /// send order, which both backends share: sends happen serially as
    /// events dispatch in global time order.
    pub fn sample(&mut self, now: Time, from: NodeId, to: NodeId, bytes: u64) -> Option<Time> {
        let prop = self.delay.sample(from, to);
        if self.cfg.loss > 0.0 && self.draw_loss(from, to) {
            self.lost += 1;
            return None;
        }
        let mut t = now;
        if self.cfg.node_up_mbps > 0.0 {
            let ser = transfer_us(bytes, self.cfg.node_up_mbps);
            let start = t.max(self.up_busy.get(&from).copied().unwrap_or(0));
            let end = start.saturating_add(ser);
            self.up_busy.insert(from, end);
            t = end;
        }
        if self.cfg.bandwidth_mbps > 0.0 {
            t = t.saturating_add(transfer_us(bytes, self.link_mbps(from, to)));
        }
        t = t.saturating_add(prop);
        if self.cfg.node_down_mbps > 0.0 {
            let ser = transfer_us(bytes, self.cfg.node_down_mbps);
            let start = t.max(self.down_busy.get(&to).copied().unwrap_or(0));
            let end = start.saturating_add(ser);
            self.down_busy.insert(to, end);
            t = end;
        }
        Some(t)
    }

    /// `node`'s endpoint closed: prune its delay and loss streams and
    /// its capacity horizons. Both backends call this from
    /// `Transport::close`, so link state stays identical across them.
    pub fn forget(&mut self, node: NodeId) {
        self.delay.forget(node);
        self.loss.retain(|&(from, to), _| from != node && to != node);
        self.open.remove(&node);
        self.up_busy.remove(&node);
        self.down_busy.remove(&node);
    }

    /// `node`'s endpoint (re)opened: cached streaming for its links. A
    /// reused id restarts its streams and horizons from scratch — on
    /// both backends, since both pruned at close.
    pub fn reopen(&mut self, node: NodeId) {
        self.delay.reopen(node);
        self.open.insert(node);
    }

    /// Frames dropped by the loss lottery so far.
    pub fn lost(&self) -> u64 {
        self.lost
    }

    /// Cached loss streams held (footprint telemetry, bounded by the
    /// live mesh like `LinkDelay::link_count`).
    pub fn loss_stream_count(&self) -> usize {
        self.loss.len()
    }
}

/// Salt separating the per-link *bandwidth* factors from everything else.
const BW_SALT: u64 = 0xBA2D_31D7_0F0E_55ED;

/// The in-memory message backend: every send is scheduled back onto the
/// caller's event queue after a per-link [`LinkModel`] sample — or
/// silently dropped when the loss lottery hits (the caller's
/// `if let Some(at)` dispatch path never schedules a `Deliver`). Fully
/// deterministic per seed — the reference behavior the TCP backend is
/// conformance-tested against.
#[derive(Debug)]
pub struct SimTransport {
    model: LinkModel,
}

impl SimTransport {
    pub fn new(cfg: &NetConfig) -> Self {
        Self {
            model: LinkModel::new(cfg),
        }
    }
}

impl Transport for SimTransport {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn open(&mut self, node: NodeId) -> anyhow::Result<()> {
        self.model.reopen(node);
        Ok(())
    }

    fn close(&mut self, node: NodeId) {
        self.model.forget(node);
    }

    fn send(&mut self, now: Time, from: NodeId, to: NodeId, msg: &Msg) -> Option<Time> {
        // `LinkModel::sample` saturates internally, matching the wire
        // path's `Stamp::due()` on absurd configured latencies; `None`
        // (a loss-lottery hit) drops the frame before scheduling.
        self.model.sample(now, from, to, msg.wire_size() as u64)
    }

    fn poll(&mut self) -> Vec<Arrival> {
        Vec::new()
    }

    fn idle(&self) -> bool {
        true
    }

    fn lost_frames(&self) -> u64 {
        self.model.lost()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A latency-only config (link-model fields at their disabled
    /// defaults), as every pre-`LinkModel` test used.
    fn net(latency_ms: f64, jitter: f64, seed: u64) -> NetConfig {
        NetConfig {
            latency_ms,
            jitter,
            seed,
            ..NetConfig::default()
        }
    }

    #[test]
    fn mean_near_base_plus_jitter() {
        let cfg = net(350.0, 0.2, 1);
        let mut m = LatencyModel::new(&cfg);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| m.sample() as f64).sum::<f64>() / n as f64;
        let want = 350_000.0 * 1.2; // base + exp(mean = jitter*base)
        assert!((mean - want).abs() < want * 0.05, "mean {mean} want {want}");
    }

    #[test]
    fn zero_jitter_is_constant() {
        let cfg = net(10.0, 0.0, 2);
        let mut m = LatencyModel::new(&cfg);
        assert!((0..100).all(|_| m.sample() == 10_000));
    }

    #[test]
    fn link_delay_is_deterministic_per_seed() {
        let cfg = net(40.0, 0.3, 11);
        let draw = |cfg: &NetConfig| {
            let mut d = LinkDelay::new(cfg);
            for n in 0..5 {
                d.reopen(n);
            }
            (0..200).map(|i| d.sample(i % 5, (i + 1) % 5)).collect::<Vec<Time>>()
        };
        assert_eq!(draw(&cfg), draw(&cfg), "same seed must replay identically");
        let other = NetConfig {
            seed: 12,
            ..cfg.clone()
        };
        assert_ne!(draw(&cfg), draw(&other), "different seeds must differ");
    }

    #[test]
    fn link_delay_respects_distribution_bounds() {
        let cfg = net(25.0, 0.2, 3);
        let mut d = LinkDelay::new(&cfg);
        d.reopen(1);
        d.reopen(2);
        let n = 30_000;
        let samples: Vec<Time> = (0..n).map(|_| d.sample(1, 2)).collect();
        // hard floor: base latency (jitter only ever adds)
        assert!(samples.iter().all(|&s| s >= 25_000));
        // mean tracks base * (1 + jitter)
        let mean = samples.iter().map(|&s| s as f64).sum::<f64>() / n as f64;
        let want = 25_000.0 * 1.2;
        assert!((mean - want).abs() < want * 0.05, "mean {mean} want {want}");
        // zero-latency configs still produce strictly positive delays
        let zero = net(0.0, 0.0, 3);
        let mut z = LinkDelay::new(&zero);
        z.reopen(1);
        z.reopen(2);
        assert!((0..100).all(|_| z.sample(1, 2) == 1));
    }

    #[test]
    fn links_are_independent_streams() {
        let cfg = net(50.0, 0.5, 7);
        let opened = |cfg: &NetConfig| {
            let mut d = LinkDelay::new(cfg);
            for n in 1..=4 {
                d.reopen(n);
            }
            d
        };
        // interleaving draws on link B must not shift link A's sequence
        let mut solo = opened(&cfg);
        let a_solo: Vec<Time> = (0..50).map(|_| solo.sample(1, 2)).collect();
        let mut mixed = opened(&cfg);
        let a_mixed: Vec<Time> = (0..50)
            .map(|_| {
                mixed.sample(3, 4);
                mixed.sample(2, 1); // reverse direction is its own link too
                mixed.sample(1, 2)
            })
            .collect();
        assert_eq!(a_solo, a_mixed, "foreign links perturbed link (1,2)");
        // distinct links draw distinct sequences
        let mut d = opened(&cfg);
        let a: Vec<Time> = (0..50).map(|_| d.sample(1, 2)).collect();
        let b: Vec<Time> = (0..50).map(|_| d.sample(2, 1)).collect();
        assert_ne!(a, b, "directed links must not share a stream");
    }

    #[test]
    fn forget_prunes_links_and_samples_dead_ones_ephemerally() {
        let cfg = net(50.0, 0.5, 9);
        let mut d = LinkDelay::new(&cfg);
        for n in 1..=3 {
            d.reopen(n);
        }
        let first = d.sample(1, 2);
        let second = d.sample(1, 2);
        assert_ne!(first, second, "jittered stream should advance");
        d.sample(2, 3); // untouched by the forget below
        let third_continuation = {
            let mut probe = LinkDelay::new(&cfg);
            probe.reopen(2);
            probe.reopen(3);
            probe.sample(2, 3);
            probe.sample(2, 3)
        };
        d.forget(1);
        // links touching the closed node sample ephemerally (fresh from
        // the seed every call, nothing cached); (2,3) streams on
        assert_eq!(d.sample(1, 2), first);
        assert_eq!(d.sample(1, 2), first);
        assert_eq!(d.sample(2, 3), third_continuation);
        // a reopened (reused) id resumes cached streaming from its seed
        d.reopen(1);
        assert_eq!(d.sample(1, 2), first);
        assert_eq!(d.sample(1, 2), second);
    }

    #[test]
    fn churned_ids_leave_no_tombstones() {
        let cfg = net(10.0, 0.1, 6);
        let mut d = LinkDelay::new(&cfg);
        d.reopen(0);
        for id in 1..5_000u64 {
            d.reopen(id);
            d.sample(0, id);
            d.sample(id, 0);
            d.forget(id);
        }
        // every link touching a departed id is pruned and no per-id
        // tombstone survives: state is bounded by the live set (node 0)
        assert_eq!(d.open_count(), 1);
        assert_eq!(d.link_count(), 0);
    }

    #[test]
    fn sim_transport_schedules_and_never_polls() {
        let cfg = net(5.0, 0.0, 3);
        let mut t = SimTransport::new(&cfg);
        assert!(t.idle());
        assert!(t.open(1).is_ok());
        let at = t.send(100, 1, 2, &Msg::Heartbeat);
        assert_eq!(at, Some(100 + 5_000));
        assert!(t.poll().is_empty());
        t.close(1);
    }

    #[test]
    fn sim_transport_broadcast_schedules_every_destination() {
        let cfg = net(2.0, 0.0, 4);
        let mut t = SimTransport::new(&cfg);
        let scheduled = t.broadcast(50, 1, &[2, 3, 4], &Msg::Heartbeat);
        assert_eq!(
            scheduled,
            vec![(2, 50 + 2_000), (3, 50 + 2_000), (4, 50 + 2_000)]
        );
    }

    // ------------------------------------------------------------------
    // LinkModel: the battery mirrors LinkDelay's (seeded determinism,
    // link independence, pruning) plus loss/bandwidth/capacity behavior
    // ------------------------------------------------------------------

    /// A full link-model config: bandwidth, loss, and node caps all on.
    fn rich_net(seed: u64) -> NetConfig {
        NetConfig {
            latency_ms: 20.0,
            jitter: 0.3,
            bandwidth_mbps: 8.0,
            loss: 0.2,
            node_up_mbps: 16.0,
            node_down_mbps: 16.0,
            seed,
        }
    }

    fn opened_model(cfg: &NetConfig, ids: std::ops::RangeInclusive<u64>) -> LinkModel {
        let mut m = LinkModel::new(cfg);
        for n in ids {
            m.reopen(n);
        }
        m
    }

    #[test]
    fn link_model_defaults_reduce_to_latency_only() {
        // with the link-model fields at their disabled defaults, the
        // model is exactly `now + LinkDelay::sample` and never loses
        let cfg = net(40.0, 0.3, 11);
        let mut d = LinkDelay::new(&cfg);
        let mut m = LinkModel::new(&cfg);
        for n in 1..=3 {
            d.reopen(n);
            m.reopen(n);
        }
        for i in 0..200u64 {
            let now = i * 1_000;
            let want = now + d.sample(1 + i % 2, 2 + i % 2);
            assert_eq!(m.sample(now, 1 + i % 2, 2 + i % 2, 10_000), Some(want));
        }
        assert_eq!(m.lost(), 0);
        assert_eq!(m.loss_stream_count(), 0, "lossless configs keep no loss state");
    }

    #[test]
    fn link_model_is_deterministic_per_seed() {
        let draw = |cfg: &NetConfig| {
            let mut m = opened_model(cfg, 0..=4);
            (0..300u64)
                .map(|i| m.sample(i * 500, i % 5, (i + 1) % 5, 2_000 + i * 7))
                .collect::<Vec<Option<Time>>>()
        };
        let cfg = rich_net(11);
        assert_eq!(draw(&cfg), draw(&cfg), "same seed must replay identically");
        let a = draw(&cfg);
        assert!(a.iter().any(|s| s.is_none()), "loss 0.2 must drop some frames");
        assert!(a.iter().any(|s| s.is_some()), "loss 0.2 must deliver some frames");
        let other = NetConfig { seed: 12, ..cfg };
        assert_ne!(a, draw(&other), "different seeds must differ");
    }

    #[test]
    fn link_model_per_link_outcomes_are_independent() {
        // per-link features only (no shared node horizons): foreign
        // links must not perturb link (1,2)'s outcome sequence
        let cfg = NetConfig {
            latency_ms: 30.0,
            jitter: 0.4,
            bandwidth_mbps: 10.0,
            loss: 0.25,
            node_up_mbps: 0.0,
            node_down_mbps: 0.0,
            seed: 21,
        };
        let mut solo = opened_model(&cfg, 1..=4);
        let a_solo: Vec<Option<Time>> =
            (0..150u64).map(|i| solo.sample(i * 100, 1, 2, 5_000)).collect();
        let mut mixed = opened_model(&cfg, 1..=4);
        let a_mixed: Vec<Option<Time>> = (0..150u64)
            .map(|i| {
                mixed.sample(i * 100, 3, 4, 9_000);
                mixed.sample(i * 100, 2, 1, 1_000); // reverse = its own link
                mixed.sample(i * 100, 1, 2, 5_000)
            })
            .collect();
        assert_eq!(a_solo, a_mixed, "foreign links perturbed link (1,2)");
    }

    #[test]
    fn link_model_loss_stream_is_independent_of_delay_stream() {
        // two configs differing only in `loss`: every delivered frame
        // must keep the identical delivery time — the loss lottery draws
        // from its own salted stream, never from the delay stream
        let lossless = net(25.0, 0.5, 17);
        let lossy = NetConfig { loss: 0.3, ..lossless.clone() };
        let mut a = opened_model(&lossless, 1..=2);
        let mut b = opened_model(&lossy, 1..=2);
        let mut delivered = 0;
        for i in 0..400u64 {
            let now = i * 1_000;
            let clean = a.sample(now, 1, 2, 3_000).unwrap();
            match b.sample(now, 1, 2, 3_000) {
                Some(t) => {
                    assert_eq!(t, clean, "loss draw shifted the delay stream at send {i}");
                    delivered += 1;
                }
                None => {}
            }
        }
        assert!(b.lost() > 0, "loss 0.3 should drop some of 400 sends");
        assert_eq!(delivered + b.lost(), 400);
    }

    #[test]
    fn link_model_bandwidth_scales_with_bytes() {
        // zero latency/jitter isolates serialization: delivery is
        // now + bytes/link_mbps (+ the 1 µs propagation floor)
        let cfg = NetConfig {
            latency_ms: 0.0,
            jitter: 0.0,
            bandwidth_mbps: 8.0,
            loss: 0.0,
            node_up_mbps: 0.0,
            node_down_mbps: 0.0,
            seed: 5,
        };
        let mut m = opened_model(&cfg, 1..=3);
        let mbps = m.link_mbps(1, 2);
        assert!((4.0..12.0).contains(&mbps), "factor outside [0.5,1.5): {mbps}");
        let small = m.sample(0, 1, 2, 1_000).unwrap();
        let big = m.sample(0, 1, 2, 100_000).unwrap();
        assert_eq!(small, transfer_us(1_000, mbps) + 1);
        assert_eq!(big, transfer_us(100_000, mbps) + 1);
        assert!(big > 50 * small / 2, "transfer time must scale with bytes");
        // directed links draw their own seeded capacities
        assert_ne!(m.link_mbps(1, 2), m.link_mbps(2, 1));
        assert_ne!(m.link_mbps(1, 2), m.link_mbps(1, 3));
    }

    #[test]
    fn link_model_uplink_queue_creates_stragglers() {
        // one sender, two same-instant sends: the second queues behind
        // the first on the shared uplink
        let cfg = NetConfig {
            latency_ms: 0.0,
            jitter: 0.0,
            bandwidth_mbps: 0.0,
            loss: 0.0,
            node_up_mbps: 8.0,
            node_down_mbps: 0.0,
            seed: 6,
        };
        let mut m = opened_model(&cfg, 1..=3);
        let ser = transfer_us(40_000, 8.0); // 40 kB at 8 Mbit/s = 40 ms
        let first = m.sample(1_000, 1, 2, 40_000).unwrap();
        let second = m.sample(1_000, 1, 3, 40_000).unwrap();
        assert_eq!(first, 1_000 + ser + 1);
        assert_eq!(second, 1_000 + 2 * ser + 1, "second send must queue");
        // once the uplink drains, a later send pays only its own time
        let later = m.sample(first + 2 * ser, 1, 2, 40_000).unwrap();
        assert_eq!(later, first + 2 * ser + ser + 1);
    }

    #[test]
    fn link_model_downlink_queue_serializes_receives() {
        let cfg = NetConfig {
            latency_ms: 0.0,
            jitter: 0.0,
            bandwidth_mbps: 0.0,
            loss: 0.0,
            node_up_mbps: 0.0,
            node_down_mbps: 8.0,
            seed: 6,
        };
        let mut m = opened_model(&cfg, 1..=3);
        let ser = transfer_us(8_000, 8.0);
        let a = m.sample(500, 1, 3, 8_000).unwrap();
        let b = m.sample(500, 2, 3, 8_000).unwrap();
        assert_eq!(a, 500 + 1 + ser);
        assert_eq!(b, a + ser, "receiver downlink must serialize arrivals");
    }

    #[test]
    fn link_model_forget_prunes_loss_streams_and_horizons() {
        let cfg = rich_net(9);
        let mut m = opened_model(&cfg, 1..=3);
        let first = m.sample(0, 1, 2, 4_000);
        for i in 1..40u64 {
            m.sample(i * 1_000, 1, 2, 4_000);
            m.sample(i * 1_000, 2, 3, 4_000);
        }
        assert!(m.loss_stream_count() >= 2);
        m.forget(1);
        m.forget(2);
        m.forget(3);
        assert_eq!(m.loss_stream_count(), 0, "forget must prune loss streams");
        // a reopened (reused) id restarts every stream from its seed
        m.reopen(1);
        m.reopen(2);
        assert_eq!(m.sample(0, 1, 2, 4_000), first);
    }

    #[test]
    fn sim_transport_drops_lost_frames_and_counts_them() {
        let cfg = NetConfig {
            loss: 0.5,
            latency_ms: 1.0,
            jitter: 0.0,
            seed: 8,
            ..NetConfig::default()
        };
        let mut t = SimTransport::new(&cfg);
        t.open(1).unwrap();
        t.open(2).unwrap();
        let mut dropped = 0u64;
        for i in 0..200u64 {
            if t.send(i * 10, 1, 2, &Msg::Heartbeat).is_none() {
                dropped += 1;
            }
        }
        assert!(dropped > 0 && dropped < 200, "loss 0.5 should drop ~half");
        assert_eq!(t.lost_frames(), dropped);
    }
}
