//! Simulated network latency model: one-way delay = `latency_ms` plus an
//! exponential jitter tail. Deterministic per seed. `SimTransport` wraps
//! the model as the in-memory `Transport` backend of the unified engine.

use super::transport::{Arrival, Transport};
use crate::config::NetConfig;
use crate::ndmp::messages::{Msg, Time};
use crate::topology::NodeId;
use crate::util::Rng;

#[derive(Debug)]
pub struct LatencyModel {
    base_us: f64,
    jitter: f64,
    rng: Rng,
}

impl LatencyModel {
    pub fn new(cfg: &NetConfig) -> Self {
        Self {
            base_us: cfg.latency_ms * 1_000.0,
            jitter: cfg.jitter,
            rng: Rng::new(cfg.seed ^ 0x1a7e_0c11),
        }
    }

    /// Sample a one-way delay in microseconds (>= 1).
    pub fn sample(&mut self) -> Time {
        let jitter = if self.jitter > 0.0 {
            self.rng.exponential(1.0 / (self.jitter * self.base_us.max(1.0)))
        } else {
            0.0
        };
        (self.base_us + jitter).max(1.0) as Time
    }
}

/// The in-memory message backend: every send is scheduled back onto the
/// caller's event queue after a latency-model delay. Fully deterministic
/// per seed — the reference behavior the TCP backend is conformance-tested
/// against.
#[derive(Debug)]
pub struct SimTransport {
    latency: LatencyModel,
}

impl SimTransport {
    pub fn new(cfg: &NetConfig) -> Self {
        Self {
            latency: LatencyModel::new(cfg),
        }
    }
}

impl Transport for SimTransport {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn open(&mut self, _node: NodeId) -> anyhow::Result<()> {
        Ok(())
    }

    fn close(&mut self, _node: NodeId) {}

    fn send(&mut self, now: Time, _from: NodeId, _to: NodeId, _msg: &Msg) -> Option<Time> {
        Some(now + self.latency.sample())
    }

    fn poll(&mut self) -> Vec<Arrival> {
        Vec::new()
    }

    fn idle(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_near_base_plus_jitter() {
        let cfg = NetConfig {
            latency_ms: 350.0,
            jitter: 0.2,
            seed: 1,
        };
        let mut m = LatencyModel::new(&cfg);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| m.sample() as f64).sum::<f64>() / n as f64;
        let want = 350_000.0 * 1.2; // base + exp(mean = jitter*base)
        assert!((mean - want).abs() < want * 0.05, "mean {mean} want {want}");
    }

    #[test]
    fn zero_jitter_is_constant() {
        let cfg = NetConfig {
            latency_ms: 10.0,
            jitter: 0.0,
            seed: 2,
        };
        let mut m = LatencyModel::new(&cfg);
        assert!((0..100).all(|_| m.sample() == 10_000));
    }

    #[test]
    fn sim_transport_schedules_and_never_polls() {
        let cfg = NetConfig {
            latency_ms: 5.0,
            jitter: 0.0,
            seed: 3,
        };
        let mut t = SimTransport::new(&cfg);
        assert!(t.idle());
        assert!(t.open(1).is_ok());
        let at = t.send(100, 1, 2, &Msg::Heartbeat);
        assert_eq!(at, Some(100 + 5_000));
        assert!(t.poll().is_empty());
        t.close(1);
    }

    #[test]
    fn sim_transport_broadcast_schedules_every_destination() {
        let cfg = NetConfig {
            latency_ms: 2.0,
            jitter: 0.0,
            seed: 4,
        };
        let mut t = SimTransport::new(&cfg);
        let scheduled = t.broadcast(50, 1, &[2, 3, 4], &Msg::Heartbeat);
        assert_eq!(
            scheduled,
            vec![(2, 50 + 2_000), (3, 50 + 2_000), (4, 50 + 2_000)]
        );
    }
}
