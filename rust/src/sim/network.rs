//! Simulated network latency model: one-way delay = `latency_ms` plus an
//! exponential jitter tail. Deterministic per seed.

use crate::config::NetConfig;
use crate::ndmp::messages::Time;
use crate::util::Rng;

#[derive(Debug)]
pub struct LatencyModel {
    base_us: f64,
    jitter: f64,
    rng: Rng,
}

impl LatencyModel {
    pub fn new(cfg: &NetConfig) -> Self {
        Self {
            base_us: cfg.latency_ms * 1_000.0,
            jitter: cfg.jitter,
            rng: Rng::new(cfg.seed ^ 0x1a7e_0c11),
        }
    }

    /// Sample a one-way delay in microseconds (>= 1).
    pub fn sample(&mut self) -> Time {
        let jitter = if self.jitter > 0.0 {
            self.rng.exponential(1.0 / (self.jitter * self.base_us.max(1.0)))
        } else {
            0.0
        };
        (self.base_us + jitter).max(1.0) as Time
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_near_base_plus_jitter() {
        let cfg = NetConfig {
            latency_ms: 350.0,
            jitter: 0.2,
            seed: 1,
        };
        let mut m = LatencyModel::new(&cfg);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| m.sample() as f64).sum::<f64>() / n as f64;
        let want = 350_000.0 * 1.2; // base + exp(mean = jitter*base)
        assert!((mean - want).abs() < want * 0.05, "mean {mean} want {want}");
    }

    #[test]
    fn zero_jitter_is_constant() {
        let cfg = NetConfig {
            latency_ms: 10.0,
            jitter: 0.0,
            seed: 2,
        };
        let mut m = LatencyModel::new(&cfg);
        assert!((0..100).all(|_| m.sample() == 10_000));
    }
}
