//! The `Transport` abstraction: how protocol messages travel between
//! nodes, decoupled from *when* protocol logic runs.
//!
//! The unified engine separates two concerns that the original prototype
//! fused together:
//!
//! * **Timers** belong to the deterministic scheduler (`sim::sched`).
//!   Heartbeats, repair probes, joins, failures, and snapshots are heap
//!   events popped in virtual-time order — identically on every backend.
//! * **Message passage** belongs to a `Transport`. The simulated backend
//!   (`sim::network::SimTransport`) samples the per-link model
//!   (`sim::network::LinkModel`: propagation delay, payload-proportional
//!   bandwidth, loss lottery, per-node capacity queues) and hands the
//!   message straight back to the scheduler; the socket backend
//!   (`net::SchedTransport`) samples the *same* per-link model, stamps
//!   the full delay into a real TCP frame, and surfaces the arrival —
//!   tagged with its virtual due time — on the next `poll`. A
//!   loss-lottery hit is a silent drop on the in-memory path and a
//!   deliberate non-send on the socket path — the same frames vanish on
//!   both.
//!
//! A backend therefore answers `send` in one of two ways:
//!
//! * `Some(deliver_at)` — "schedule the delivery yourself": the caller
//!   (`sim::Simulator`) pushes a `Deliver` event at that virtual time.
//!   This is the deterministic, in-memory path.
//! * `None` — "the message is on the wire": the frame travels physically
//!   and the caller must `poll` for [`Arrival`]s between scheduler
//!   events, scheduling each at its stamped [`Arrival::at`].
//!
//! Either way the delivery executes as a `Deliver` event at
//! `send_time + sampled_delay` on the scheduler clock, so both backends
//! drive the *same* `ndmp::NodeState` protocol engines through the same
//! event sequence — a seeded churn schedule replays over real sockets
//! with the identical arrival timestamps it has in simulation. That is
//! the conformance contract checked by `tests/transport_conformance.rs`
//! and documented in `docs/transports.md`.

use crate::ndmp::messages::{Msg, Time};
use crate::topology::NodeId;
use anyhow::Result;

/// A message that arrived out-of-band (socket backends): `from` sent
/// `msg` to `to`, due for delivery at virtual time `at` (its stamped
/// send time plus the sampled link delay). `at` is usually in the
/// caller's future — frames arrive physically while the virtual instant
/// that sent them is still being settled — and the caller schedules the
/// delivery on its own event queue.
#[derive(Debug, Clone, PartialEq)]
pub struct Arrival {
    pub from: NodeId,
    pub to: NodeId,
    /// Virtual delivery time: wire-stamped send time + sampled delay.
    pub at: Time,
    pub msg: Msg,
}

/// A message-passage backend for the unified scheduler.
///
/// `Send + Sync` because the owning `Simulator` is embedded in
/// `dfl::Trainer`, whose parallel evaluation shares `&Trainer` across
/// rayon workers.
pub trait Transport: Send + Sync {
    /// Backend name for logs and reports (`"sim"`, `"tcp"`).
    fn name(&self) -> &'static str;

    /// A node entered the network: allocate its endpoint (bind a socket,
    /// register an address, ...). No-op on the in-memory backend.
    fn open(&mut self, node: NodeId) -> Result<()>;

    /// A node failed or left: tear its endpoint down. Messages already
    /// addressed to it vanish (crash-fail model) on every backend.
    fn close(&mut self, node: NodeId);

    /// Carry `msg` from `from` to `to` at virtual time `now`.
    ///
    /// Returns `Some(deliver_at)` when the caller should schedule the
    /// delivery on its own event queue (in-memory backend), or `None`
    /// when the transport moves the bytes itself and the caller should
    /// `poll` for the arrival (socket backend) — **or** when the link
    /// model's loss lottery dropped the frame (either backend: the
    /// in-memory path simply never schedules it, the socket path never
    /// writes it). Sends to unknown or dead endpoints are dropped, never
    /// an error. In every drop case the backend still samples the link
    /// model's streams first, so drops cannot shift a link's delay or
    /// loss sequence between backends.
    fn send(&mut self, now: Time, from: NodeId, to: NodeId, msg: &Msg) -> Option<Time>;

    /// Frames the link model's loss lottery dropped so far. `0` on
    /// backends without a loss model. The conformance suite asserts the
    /// two backends agree on this count for a seeded lossy run.
    fn lost_frames(&self) -> u64 {
        0
    }

    /// Sends that failed in the transport itself (connect refused, write
    /// error against a resolved live address) — *not* loss-lottery drops
    /// and not unreachable-peer drops, which are routine under churn.
    /// `0` on the in-memory backend; the conformance suite asserts a
    /// clean socket run stays at `0`.
    fn dropped_sends(&self) -> u64 {
        0
    }

    /// Fan `msg` out to several destinations; returns the scheduled
    /// `(to, deliver_at)` pairs for queue-scheduled deliveries.
    ///
    /// The default delegates to [`Transport::send`] per destination, so
    /// it cannot diverge from unicast semantics unless a backend
    /// overrides it. The simulator's dispatch path fans out per
    /// destination itself (outgoing batches mix message types); this is
    /// the convenience entry point for orchestrators and backends with
    /// a native fan-out primitive.
    fn broadcast(
        &mut self,
        now: Time,
        from: NodeId,
        to: &[NodeId],
        msg: &Msg,
    ) -> Vec<(NodeId, Time)> {
        to.iter()
            .filter_map(|&t| self.send(now, from, t, msg).map(|at| (t, at)))
            .collect()
    }

    /// Collect messages that arrived out-of-band since the last poll,
    /// in virtual-time order (ties by send order). The in-memory backend
    /// always returns an empty vector. Socket backends wait (bounded)
    /// until every frame written since the last poll has physically
    /// arrived — the quiescence window is only a liveness backstop for
    /// frames lost to a dying peer — and each returned [`Arrival`]
    /// carries the virtual due time the caller must schedule it at.
    fn poll(&mut self) -> Vec<Arrival>;

    /// `true` when `poll` can never return anything (pure queue-scheduled
    /// backend) — lets the caller skip polling on the hot path.
    fn idle(&self) -> bool;
}
