//! Declarative churn scenarios: a serializable description of a
//! resilience experiment (phases of mass joins/failures/leaves, flash
//! crowds, Poisson churn, partition-style adversarial bursts, plus a
//! sampling cadence) that compiles to one deterministic event schedule
//! and drives either a bare overlay [`Simulator`] or a full
//! `dfl::Trainer` through the same code path (`ChurnSink`).
//!
//! The compiled schedule is a pure function of the spec and its seed:
//! node ids, bootstraps, and victims are resolved at compile time against
//! a virtual live-set replay, so the identical schedule can be replayed
//! on the in-memory transport, on real TCP sockets, or inside a training
//! run — the substrate for the golden-trajectory and model-based
//! property suites (`tests/scenario_golden.rs`,
//! `tests/scenario_properties.rs`) and the `fedlay scenario` CLI.
//!
//! The TOML-subset format is documented in `docs/scenarios.md`; runnable
//! examples live under `configs/scenarios/`.

use super::runner::{CorrectnessSample, Simulator};
use super::transport::Transport;
use crate::config::{Doc, NetConfig, OverlayConfig};
use crate::dfl::Trainer;
use crate::ndmp::messages::{Time, MS, SEC};
use crate::topology::{correctness, Membership, NeighborSnapshot, NodeId};
use crate::util::Rng;
use anyhow::{bail, ensure, Result};
use std::collections::{BTreeMap, BTreeSet};

/// One churn phase: what happens, starting when.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Phase {
    pub at: Time,
    pub kind: PhaseKind,
}

/// The scenario vocabulary. Mass events fire at the phase instant (the
/// paper's "same time" extremes, Figs. 8a/8b); the stochastic kinds
/// expand into seeded event streams at compile time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PhaseKind {
    /// `count` new clients join at the phase instant, each through a
    /// random live bootstrap (Fig. 8a).
    MassJoin { count: usize },
    /// `count` random live clients crash-fail at the phase instant
    /// (Fig. 8b).
    MassFail { count: usize },
    /// `count` random live clients leave gracefully at the phase instant.
    MassLeave { count: usize },
    /// A flash crowd: `count` clients join at the phase instant and each
    /// departs gracefully `dwell` later.
    FlashCrowd { count: usize, dwell: Time },
    /// Merged Poisson processes with exponential inter-arrivals over
    /// `window`: rates are events per simulated minute.
    PoissonChurn {
        join_per_min: f64,
        fail_per_min: f64,
        leave_per_min: f64,
        window: Time,
    },
    /// Adversarial burst: a contiguous arc of the space-0 ring —
    /// `fraction` of the live nodes — crash-fails at once. Coordinated
    /// failures of ring-adjacent nodes are the worst case for repair
    /// (random failures rarely hit both adjacents of anyone).
    Partition { fraction: f64 },
    /// Byzantine model poisoning: `frac` of the live clients turn
    /// adversarial at the phase instant and serve `mode`-poisoned
    /// models from then on (they stay protocol-live, so the overlay
    /// never notices them).
    Poison { mode: PoisonMode, frac: f64 },
    /// Stale-model replay: `frac` of the live clients snapshot their
    /// model at the phase instant and, from `lag` later, serve that
    /// (by then `lag`-old) snapshot forever instead of fresh updates.
    StaleReplay { frac: f64, lag: Time },
    /// Eclipse misdirection: a contiguous arc of the space-0 ring —
    /// `arc` of the live nodes — keeps answering the protocol but
    /// serves only the initial model, starving the clients whose
    /// neighborhoods the arc dominates.
    Eclipse { arc: f64 },
}

/// How a poisoned client corrupts the model it serves.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PoisonMode {
    /// Every parameter becomes NaN — caught by the non-finite guard in
    /// `mep::aggregate`, so it tests the telemetry path.
    Nan,
    /// Parameters scaled by −10: finite, so only robust aggregation
    /// rules (trimmed mean / median / Krum) reject it.
    Scale,
    /// Parameters negated (sign-flip attack).
    SignFlip,
}

impl PoisonMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "nan" => Ok(Self::Nan),
            "scale" => Ok(Self::Scale),
            "signflip" => Ok(Self::SignFlip),
            other => bail!("unknown poison mode {other:?} (nan | scale | signflip)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Nan => "nan",
            Self::Scale => "scale",
            Self::SignFlip => "signflip",
        }
    }
}

/// A resolved churn operation in the compiled schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ChurnOp {
    Join { node: NodeId, bootstrap: NodeId },
    Fail { node: NodeId },
    Leave { node: NodeId },
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChurnEvent {
    pub at: Time,
    pub op: ChurnOp,
}

/// Tally of the compiled schedule (drives the membership arithmetic
/// checks: final live count = initial + joins - fails - leaves).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ChurnCounts {
    pub joins: usize,
    pub fails: usize,
    pub leaves: usize,
}

impl ChurnCounts {
    pub fn of(events: &[ChurnEvent]) -> Self {
        let mut c = ChurnCounts::default();
        for e in events {
            match e.op {
                ChurnOp::Join { .. } => c.joins += 1,
                ChurnOp::Fail { .. } => c.fails += 1,
                ChurnOp::Leave { .. } => c.leaves += 1,
            }
        }
        c
    }
}

/// A resolved Byzantine attack in the compiled schedule. Attacker
/// selection happens at compile time against the same virtual live-set
/// replay (and rng stream) as churn victims, so the identical attacker
/// set fires on every backend — sim ≡ tcp conformance holds for
/// adversarial scenarios for the same reason it does for churn.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AttackOp {
    /// `node` starts serving `mode`-poisoned models.
    Poison { node: NodeId, mode: PoisonMode },
    /// `node` snapshots its model now and serves the frozen snapshot
    /// from `lag` later.
    StaleReplay { node: NodeId, lag: Time },
    /// `node` serves only the initial model from now on.
    Eclipse { node: NodeId },
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AttackEvent {
    pub at: Time,
    pub op: AttackOp,
}

/// Tally of the compiled attack schedule.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AttackCounts {
    pub poisoned: usize,
    pub stale: usize,
    pub eclipsed: usize,
}

impl AttackCounts {
    pub fn of(events: &[AttackEvent]) -> Self {
        let mut c = AttackCounts::default();
        for e in events {
            match e.op {
                AttackOp::Poison { .. } => c.poisoned += 1,
                AttackOp::StaleReplay { .. } => c.stale += 1,
                AttackOp::Eclipse { .. } => c.eclipsed += 1,
            }
        }
        c
    }

    pub fn total(&self) -> usize {
        self.poisoned + self.stale + self.eclipsed
    }
}

/// Anything that can receive a compiled churn schedule: the bare overlay
/// simulator and the DFL trainer implement this, which is what lets one
/// scenario description drive both.
pub trait ChurnSink {
    fn join(&mut self, at: Time, node: NodeId, bootstrap: NodeId) -> Result<()>;
    fn fail(&mut self, at: Time, node: NodeId) -> Result<()>;
    fn leave(&mut self, at: Time, node: NodeId) -> Result<()>;
    /// Byzantine attack event. Defaults to a no-op: attackers stay
    /// protocol-live, so the bare overlay simulator is unaffected (NDMP
    /// carries no model traffic); the trainer sink overrides this to
    /// flip the victim's Byzantine state at `at`.
    fn attack(&mut self, _at: Time, _op: AttackOp) -> Result<()> {
        Ok(())
    }
}

impl ChurnSink for Simulator {
    fn join(&mut self, at: Time, node: NodeId, bootstrap: NodeId) -> Result<()> {
        self.schedule_join(at, node, bootstrap);
        Ok(())
    }

    fn fail(&mut self, at: Time, node: NodeId) -> Result<()> {
        self.schedule_fail(at, node);
        Ok(())
    }

    fn leave(&mut self, at: Time, node: NodeId) -> Result<()> {
        self.schedule_leave(at, node);
        Ok(())
    }
}

/// Adapter scheduling a scenario onto a `dfl::Trainer`: mid-run joiners
/// need one weight vector *per lane*, so the sink carries a
/// `(lane, node id) -> weights` function alongside the trainer
/// (single-task trainers have one lane; `run_trainer` adapts the
/// single-task closure form). One churn schedule enters every lane's
/// membership at once — per-task membership arithmetic is shared by
/// construction.
pub struct MultiTrainerSink<'a, 'e, F> {
    pub trainer: &'a mut Trainer<'e>,
    pub weights_for: F,
}

impl<F: FnMut(usize, usize) -> Vec<f64>> ChurnSink for MultiTrainerSink<'_, '_, F> {
    fn join(&mut self, at: Time, node: NodeId, bootstrap: NodeId) -> Result<()> {
        let per_lane: Vec<Vec<f64>> = (0..self.trainer.lanes.len())
            .map(|lane| (self.weights_for)(lane, node as usize))
            .collect();
        let id = self.trainer.schedule_join_tasks(at, per_lane, bootstrap as usize)?;
        ensure!(
            id == node as usize,
            "scenario join id mismatch: trainer assigned {id}, schedule expects {node}"
        );
        Ok(())
    }

    fn fail(&mut self, at: Time, node: NodeId) -> Result<()> {
        self.trainer.schedule_fail(at, node as usize);
        Ok(())
    }

    fn leave(&mut self, at: Time, node: NodeId) -> Result<()> {
        self.trainer.schedule_leave(at, node as usize);
        Ok(())
    }

    fn attack(&mut self, at: Time, op: AttackOp) -> Result<()> {
        self.trainer.schedule_attack(at, op)
    }
}

/// A declarative churn scenario. Serializable to the repo's TOML subset
/// (`to_toml` / `load`); `compile` resolves it to a deterministic event
/// schedule; `run_sim` / `run_trainer` execute it end to end.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    /// Size of the instantly-correct network the scenario starts from.
    pub initial: usize,
    /// Master seed: schedule compilation and (by default) the simulated
    /// network both derive from it.
    pub seed: u64,
    /// End of the scheduled run (sampling stops here).
    pub horizon: Time,
    /// Correctness/accuracy sampling cadence (0 = endpoints only).
    pub sample_every: Time,
    /// Extra budget after the horizon to quiesce to the ideal rings
    /// (0 = stop at the horizon).
    pub settle: Time,
    /// Floor on the live population: stochastic fails/leaves are skipped
    /// when they would shrink the network below it.
    pub min_live: usize,
    /// Coordinate-arc shard count for the discrete-event engine
    /// ([`Simulator::set_shards`]); 1 = the serial engine. Every value
    /// produces the bitwise-identical run, so this is purely a
    /// wall-clock knob for large scenarios (in-memory transport only).
    pub shards: usize,
    pub overlay: OverlayConfig,
    pub net: NetConfig,
    pub phases: Vec<Phase>,
}

/// Compile-time work item: times are fixed, targets resolve against the
/// virtual live set when the item is reached in time order.
enum Intent {
    Join { dwell: Option<Time> },
    Fail,
    Leave,
    /// Scheduled graceful departure of a specific flash-crowd node.
    Depart(NodeId),
    Partition { fraction: f64 },
    Poison { mode: PoisonMode, frac: f64 },
    StaleReplay { frac: f64, lag: Time },
    Eclipse { arc: f64 },
}

impl ScenarioSpec {
    fn base(name: &str, initial: usize, seed: u64) -> Self {
        Self {
            name: name.to_string(),
            initial,
            seed,
            horizon: 90 * SEC,
            sample_every: 3 * SEC,
            settle: 0,
            min_live: (initial / 2).max(2),
            shards: 1,
            overlay: OverlayConfig::default(),
            net: NetConfig {
                seed,
                ..NetConfig::default()
            },
            phases: Vec::new(),
        }
    }

    /// Paper Fig. 8a: a join wave hits an `initial`-node network at one
    /// instant.
    pub fn fig8a_join_wave(initial: usize, joiners: usize, seed: u64) -> Self {
        let mut s = Self::base("fig8a-join-wave", initial, seed);
        s.phases.push(Phase {
            at: 10 * MS,
            kind: PhaseKind::MassJoin { count: joiners },
        });
        s
    }

    /// Paper Fig. 8b: simultaneous crash failures.
    pub fn fig8b_mass_fail(initial: usize, failures: usize, seed: u64) -> Self {
        let mut s = Self::base("fig8b-mass-fail", initial, seed);
        s.phases.push(Phase {
            at: 10 * MS,
            kind: PhaseKind::MassFail { count: failures },
        });
        s
    }

    /// Mixed Poisson churn: joins/fails/leaves as merged Poisson
    /// processes (50/30/20 rate split) over `window`, then a quiet tail.
    pub fn poisson_mix(initial: usize, events_per_min: f64, window: Time, seed: u64) -> Self {
        let mut s = Self::base("poisson-mix", initial, seed);
        s.horizon = window + 60 * SEC;
        s.phases.push(Phase {
            at: SEC,
            kind: PhaseKind::PoissonChurn {
                join_per_min: events_per_min * 0.5,
                fail_per_min: events_per_min * 0.3,
                leave_per_min: events_per_min * 0.2,
                window,
            },
        });
        s
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.initial >= 1, "scenario.initial must be >= 1");
        ensure!(self.horizon > 0, "scenario.horizon_ms must be positive");
        ensure!(self.overlay.spaces >= 1, "overlay.spaces must be >= 1");
        ensure!(self.min_live >= 1, "scenario.min_live must be >= 1");
        ensure!(self.shards >= 1, "scenario.shards must be >= 1");
        // latency, jitter, bandwidth, loss, node capacities
        self.net.validate()?;
        for (i, ph) in self.phases.iter().enumerate() {
            match ph.kind {
                PhaseKind::Partition { fraction } => {
                    ensure!(
                        fraction > 0.0 && fraction < 1.0,
                        "phase {}: partition fraction must be in (0, 1)",
                        i + 1
                    );
                }
                PhaseKind::PoissonChurn {
                    join_per_min,
                    fail_per_min,
                    leave_per_min,
                    window,
                } => {
                    ensure!(
                        join_per_min >= 0.0 && fail_per_min >= 0.0 && leave_per_min >= 0.0,
                        "phase {}: rates must be >= 0",
                        i + 1
                    );
                    ensure!(window > 0, "phase {}: window_ms must be positive", i + 1);
                }
                PhaseKind::Poison { frac, .. } => {
                    ensure!(
                        frac > 0.0 && frac <= 1.0,
                        "phase {}: poison frac must be in (0, 1]",
                        i + 1
                    );
                }
                PhaseKind::StaleReplay { frac, lag } => {
                    ensure!(
                        frac > 0.0 && frac <= 1.0,
                        "phase {}: stale_replay frac must be in (0, 1]",
                        i + 1
                    );
                    ensure!(lag > 0, "phase {}: lag_ms must be positive", i + 1);
                }
                PhaseKind::Eclipse { arc } => {
                    ensure!(
                        arc > 0.0 && arc < 1.0,
                        "phase {}: eclipse arc must be in (0, 1)",
                        i + 1
                    );
                }
                _ => {}
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // Compilation: spec -> deterministic event schedule
    // ------------------------------------------------------------------

    /// Resolve the scenario to a concrete schedule. Deterministic in the
    /// spec (including its seed): ids are assigned and bootstraps/victims
    /// sampled against a virtual replay of the live membership, walked in
    /// time order, so a join's bootstrap is always live when the event
    /// fires — on any backend, and on the trainer (whose sequential id
    /// assignment matches the schedule's emission order by construction).
    pub fn compile(&self) -> Vec<ChurnEvent> {
        self.compile_all().0
    }

    /// The Byzantine half of the compiled schedule (empty for purely
    /// churn scenarios).
    pub fn compile_attacks(&self) -> Vec<AttackEvent> {
        self.compile_all().1
    }

    /// Compile churn and attacks together: attacker selection consumes
    /// the same replay rng stream as churn victims, interleaved in time
    /// order, so adding an adversarial phase reshuffles nothing before
    /// it and a spec without one compiles to the bitwise-identical
    /// churn schedule as ever.
    pub fn compile_all(&self) -> (Vec<ChurnEvent>, Vec<AttackEvent>) {
        let mut work: BTreeMap<(Time, u64), Intent> = BTreeMap::new();
        let mut seq = 0u64;
        for (pi, phase) in self.phases.iter().enumerate() {
            let at = phase.at;
            match phase.kind {
                PhaseKind::MassJoin { count } => {
                    for _ in 0..count {
                        work.insert((at, seq), Intent::Join { dwell: None });
                        seq += 1;
                    }
                }
                PhaseKind::MassFail { count } => {
                    for _ in 0..count {
                        work.insert((at, seq), Intent::Fail);
                        seq += 1;
                    }
                }
                PhaseKind::MassLeave { count } => {
                    for _ in 0..count {
                        work.insert((at, seq), Intent::Leave);
                        seq += 1;
                    }
                }
                PhaseKind::FlashCrowd { count, dwell } => {
                    for _ in 0..count {
                        work.insert((at, seq), Intent::Join { dwell: Some(dwell) });
                        seq += 1;
                    }
                }
                PhaseKind::PoissonChurn {
                    join_per_min,
                    fail_per_min,
                    leave_per_min,
                    window,
                } => {
                    let total = join_per_min + fail_per_min + leave_per_min;
                    if total <= 0.0 {
                        continue;
                    }
                    // One stream per phase so reordering phases in the
                    // spec does not silently reshuffle every arrival.
                    let mut trng = Rng::new(self.seed ^ 0xA271 ^ ((pi as u64 + 1) << 32));
                    let per_us = total / 60e6;
                    let mut t = at;
                    loop {
                        let dt = trng.exponential(per_us);
                        if !dt.is_finite() || dt >= (Time::MAX / 4) as f64 {
                            break;
                        }
                        t += dt.max(1.0) as Time;
                        if t >= at + window {
                            break;
                        }
                        let u = trng.next_f64() * total;
                        let intent = if u < join_per_min {
                            Intent::Join { dwell: None }
                        } else if u < join_per_min + fail_per_min {
                            Intent::Fail
                        } else {
                            Intent::Leave
                        };
                        work.insert((t, seq), intent);
                        seq += 1;
                    }
                }
                PhaseKind::Partition { fraction } => {
                    work.insert((at, seq), Intent::Partition { fraction });
                    seq += 1;
                }
                PhaseKind::Poison { mode, frac } => {
                    work.insert((at, seq), Intent::Poison { mode, frac });
                    seq += 1;
                }
                PhaseKind::StaleReplay { frac, lag } => {
                    work.insert((at, seq), Intent::StaleReplay { frac, lag });
                    seq += 1;
                }
                PhaseKind::Eclipse { arc } => {
                    work.insert((at, seq), Intent::Eclipse { arc });
                    seq += 1;
                }
            }
        }

        // Time-ordered replay against the virtual live set.
        let mut rng = Rng::new(self.seed ^ 0x5CE1);
        let mut live: Vec<NodeId> = (0..self.initial as NodeId).collect();
        let mut next_id = self.initial as NodeId;
        let min_live = self.min_live.max(1);
        let mut out = Vec::new();
        let mut attacks = Vec::new();
        // nodes already turned Byzantine: never re-selected by a later
        // adversarial phase (they keep their first behavior)
        let mut attackers: BTreeSet<NodeId> = BTreeSet::new();
        while let Some(((at, _), intent)) = work.pop_first() {
            match intent {
                Intent::Join { dwell } => {
                    if live.is_empty() {
                        continue;
                    }
                    let bootstrap = live[rng.index(live.len())];
                    let node = next_id;
                    next_id += 1;
                    out.push(ChurnEvent {
                        at,
                        op: ChurnOp::Join { node, bootstrap },
                    });
                    live.push(node);
                    if let Some(d) = dwell {
                        work.insert((at + d.max(1), seq), Intent::Depart(node));
                        seq += 1;
                    }
                }
                Intent::Fail => {
                    if live.len() <= min_live {
                        continue;
                    }
                    let node = live.swap_remove(rng.index(live.len()));
                    out.push(ChurnEvent {
                        at,
                        op: ChurnOp::Fail { node },
                    });
                }
                Intent::Leave => {
                    if live.len() <= min_live {
                        continue;
                    }
                    let node = live.swap_remove(rng.index(live.len()));
                    out.push(ChurnEvent {
                        at,
                        op: ChurnOp::Leave { node },
                    });
                }
                Intent::Depart(node) => {
                    if live.len() <= min_live {
                        continue;
                    }
                    if let Some(pos) = live.iter().position(|&x| x == node) {
                        live.swap_remove(pos);
                        out.push(ChurnEvent {
                            at,
                            op: ChurnOp::Leave { node },
                        });
                    }
                }
                Intent::Partition { fraction } => {
                    let want = (fraction * live.len() as f64).round() as usize;
                    let count = want.min(live.len().saturating_sub(min_live));
                    if count == 0 {
                        continue;
                    }
                    let mut m = Membership::new(self.overlay.spaces);
                    for &id in &live {
                        m.add(id);
                    }
                    let ring = m.ring(0);
                    let start = rng.index(ring.len());
                    let victims: Vec<NodeId> = (0..count)
                        .map(|k| ring[(start + k) % ring.len()].id)
                        .collect();
                    for node in victims {
                        if let Some(pos) = live.iter().position(|&x| x == node) {
                            live.swap_remove(pos);
                            out.push(ChurnEvent {
                                at,
                                op: ChurnOp::Fail { node },
                            });
                        }
                    }
                }
                Intent::Poison { mode, frac } => {
                    let want = (frac * live.len() as f64).round() as usize;
                    let mut pool: Vec<NodeId> = live
                        .iter()
                        .copied()
                        .filter(|id| !attackers.contains(id))
                        .collect();
                    for _ in 0..want.min(pool.len()) {
                        let node = pool.swap_remove(rng.index(pool.len()));
                        attackers.insert(node);
                        attacks.push(AttackEvent {
                            at,
                            op: AttackOp::Poison { node, mode },
                        });
                    }
                }
                Intent::StaleReplay { frac, lag } => {
                    let want = (frac * live.len() as f64).round() as usize;
                    let mut pool: Vec<NodeId> = live
                        .iter()
                        .copied()
                        .filter(|id| !attackers.contains(id))
                        .collect();
                    for _ in 0..want.min(pool.len()) {
                        let node = pool.swap_remove(rng.index(pool.len()));
                        attackers.insert(node);
                        attacks.push(AttackEvent {
                            at,
                            op: AttackOp::StaleReplay { node, lag },
                        });
                    }
                }
                Intent::Eclipse { arc } => {
                    let want = (arc * live.len() as f64).round() as usize;
                    if want == 0 || live.is_empty() {
                        continue;
                    }
                    // contiguous arc of the space-0 ring, like Partition —
                    // but the arc stays protocol-live
                    let mut m = Membership::new(self.overlay.spaces);
                    for &id in &live {
                        m.add(id);
                    }
                    let ring = m.ring(0);
                    let start = rng.index(ring.len());
                    let mut added = 0usize;
                    let mut k = 0usize;
                    while added < want && k < ring.len() {
                        let node = ring[(start + k) % ring.len()].id;
                        k += 1;
                        if attackers.insert(node) {
                            attacks.push(AttackEvent {
                                at,
                                op: AttackOp::Eclipse { node },
                            });
                            added += 1;
                        }
                    }
                }
            }
        }
        (out, attacks)
    }

    /// Schedule the compiled events onto any sink (simulator or trainer)
    /// — the single code path shared by benches, tests, and the CLI.
    pub fn schedule(&self, sink: &mut dyn ChurnSink) -> Result<ChurnCounts> {
        let (events, attacks) = self.compile_all();
        let counts = ChurnCounts::of(&events);
        schedule_events(&events, sink)?;
        schedule_attacks(&attacks, sink)?;
        Ok(counts)
    }

    // ------------------------------------------------------------------
    // Execution
    // ------------------------------------------------------------------

    /// The end of the scheduled run: the horizon, extended past the last
    /// compiled churn event so the whole schedule always executes (a
    /// Poisson tail or flash-crowd departure may spill past the sampled
    /// horizon) and the membership arithmetic holds unconditionally.
    fn run_end(&self, events: &[ChurnEvent], attacks: &[AttackEvent]) -> Time {
        let last = events.last().map(|e| e.at).unwrap_or(0);
        let last_attack = attacks.last().map(|e| e.at).unwrap_or(0);
        self.horizon.max(last.max(last_attack).saturating_add(1))
    }

    /// Run the scenario on a bare overlay simulator. `transport` selects
    /// the message backend (`None` = deterministic in-memory network from
    /// the spec's `net` section).
    pub fn run_sim(
        &self,
        transport: Option<Box<dyn Transport>>,
    ) -> Result<(Simulator, ScenarioReport)> {
        self.validate()?;
        let mut sim = match transport {
            Some(t) => {
                ensure!(
                    self.shards == 1 || t.idle(),
                    "scenario.shards > 1 needs a queue-scheduled transport (got {})",
                    t.name()
                );
                Simulator::with_transport(self.overlay.clone(), t)
            }
            None => Simulator::new(self.overlay.clone(), self.net.clone()),
        };
        if self.shards > 1 {
            sim.set_shards(self.shards);
        }
        let ids: Vec<NodeId> = (0..self.initial as NodeId).collect();
        sim.bootstrap_correct(&ids);
        let (events, attacks) = self.compile_all();
        let counts = ChurnCounts::of(&events);
        schedule_events(&events, &mut sim)?;
        schedule_attacks(&attacks, &mut sim)?;
        if self.sample_every > 0 {
            let mut t = 0;
            while t <= self.horizon {
                sim.schedule_snapshot(t);
                t += self.sample_every;
            }
        } else {
            // endpoints only
            sim.schedule_snapshot(0);
            sim.schedule_snapshot(self.horizon);
        }
        sim.run_until(self.run_end(&events, &attacks));
        let settled_at = if self.settle > 0 {
            let deadline = sim.now + self.settle;
            quiesce(&mut sim, deadline, SEC)
        } else {
            None
        };
        let mut report = ScenarioReport::from_sim(self, &sim, counts, settled_at);
        report.attacks = AttackCounts::of(&attacks);
        Ok((sim, report))
    }

    /// Run the scenario through a full single-task training run: churn is
    /// scheduled on the trainer (joins enter through the NDMP protocol of
    /// the embedded overlay), the overlay records the correctness series,
    /// and the report carries the accuracy series plus neighbor-cache
    /// stats. `weights_for(id)` supplies the label weights of mid-run
    /// joiners.
    pub fn run_trainer<F>(
        &self,
        trainer: &mut Trainer<'_>,
        mut weights_for: F,
    ) -> Result<ScenarioReport>
    where
        F: FnMut(usize) -> Vec<f64>,
    {
        ensure!(
            trainer.lanes.len() == 1,
            "multi-task trainers need run_trainer_tasks (per-lane joiner weights)"
        );
        self.run_trainer_tasks(trainer, move |_lane, node| weights_for(node))
    }

    /// Run the scenario through a multi-task training run: one churn
    /// schedule drives every lane's membership over the shared overlay,
    /// and the report carries per-task accuracy series alongside the
    /// shared correctness series. `weights_for(lane, id)` supplies a
    /// mid-run joiner's label weights for each lane.
    pub fn run_trainer_tasks<F>(
        &self,
        trainer: &mut Trainer<'_>,
        weights_for: F,
    ) -> Result<ScenarioReport>
    where
        F: FnMut(usize, usize) -> Vec<f64>,
    {
        self.validate()?;
        ensure!(
            trainer.clients().len() == self.initial,
            "trainer has {} clients, scenario starts from {}",
            trainer.clients().len(),
            self.initial
        );
        let (events, attacks) = self.compile_all();
        let counts = ChurnCounts::of(&events);
        {
            let mut sink = MultiTrainerSink {
                trainer: &mut *trainer,
                weights_for,
            };
            schedule_events(&events, &mut sink)?;
            schedule_attacks(&attacks, &mut sink)?;
        }
        // applies when the trainer builds its own in-memory overlay;
        // adopted overlays and custom transports keep their own engine
        trainer.set_overlay_shards(self.shards);
        trainer.schedule_overlay_snapshots(self.horizon, self.sample_every)?;
        trainer.run(self.run_end(&events, &attacks), self.sample_every)?;
        let (cache_hits, cache_misses) = trainer.neighbor_cache_stats();
        let settled_at = if self.settle > 0 {
            let sim = trainer
                .overlay
                .as_mut()
                .expect("dynamic overlay state after run");
            let deadline = sim.now + self.settle;
            quiesce(sim, deadline, SEC)
        } else {
            None
        };
        let sim = trainer
            .overlay
            .as_ref()
            .expect("dynamic overlay state after run");
        let mut report = ScenarioReport::from_sim(self, sim, counts, settled_at);
        report.accuracy = trainer
            .samples()
            .iter()
            .map(|s| (s.at, s.mean_accuracy))
            .collect();
        report.task_accuracy = trainer
            .lanes
            .iter()
            .map(|l| {
                (
                    l.spec.name.clone(),
                    l.samples.iter().map(|s| (s.at, s.mean_accuracy)).collect(),
                )
            })
            .collect();
        report.cache_hits = cache_hits;
        report.cache_misses = cache_misses;
        report.model_mb_per_client = trainer.model_mb_per_client();
        report.attacks = AttackCounts::of(&attacks);
        report.rejected_models = trainer.rejected_models_total();
        // honest-vs-Byzantine gap of the primary lane, where both
        // cohorts had a live member at the sample instant
        report.accuracy_gap = trainer
            .samples()
            .iter()
            .filter_map(|s| s.byz_mean_accuracy.map(|b| (s.at, s.mean_accuracy - b)))
            .collect();
        Ok(report)
    }

    // ------------------------------------------------------------------
    // Serialization (TOML subset, see docs/scenarios.md)
    // ------------------------------------------------------------------

    pub fn load(path: &std::path::Path) -> Result<ScenarioSpec> {
        let doc = Doc::parse_file(path)?;
        Self::from_doc(&doc)
    }

    pub fn from_toml_str(text: &str) -> Result<ScenarioSpec> {
        let doc = Doc::parse(text)?;
        Self::from_doc(&doc)
    }

    pub fn from_doc(doc: &Doc) -> Result<ScenarioSpec> {
        check_known_keys(doc)?;
        let od = OverlayConfig::default();
        let nd = NetConfig::default();
        let name = doc.str("scenario.name").unwrap_or("unnamed").to_string();
        let initial = int_key(doc, "scenario.initial")?.unwrap_or(100) as usize;
        let seed = int_key(doc, "scenario.seed")?.unwrap_or(1) as u64;
        let horizon = ms_key(doc, "scenario.horizon_ms")?.unwrap_or(120 * SEC);
        let sample_every =
            ms_key(doc, "scenario.sample_every_ms")?.unwrap_or((horizon / 40).max(MS));
        let settle = ms_key(doc, "scenario.settle_ms")?.unwrap_or(0);
        let min_live = int_key(doc, "scenario.min_live")?
            .map(|v| v as usize)
            .unwrap_or_else(|| (initial / 2).max(2));
        let shards = int_key(doc, "scenario.shards")?
            .map(|v| v as usize)
            .unwrap_or(1);
        let overlay = OverlayConfig {
            spaces: int_key(doc, "overlay.spaces")?
                .map(|v| v as usize)
                .unwrap_or(od.spaces),
            heartbeat_ms: int_key(doc, "overlay.heartbeat_ms")?
                .map(|v| v as u64)
                .unwrap_or(od.heartbeat_ms),
            failure_multiple: int_key(doc, "overlay.failure_multiple")?
                .map(|v| v as u32)
                .unwrap_or(od.failure_multiple),
            repair_probe_ms: int_key(doc, "overlay.repair_probe_ms")?
                .map(|v| v as u64)
                .unwrap_or(od.repair_probe_ms),
        };
        let net = NetConfig {
            latency_ms: float_key(doc, "net.latency_ms")?.unwrap_or(nd.latency_ms),
            jitter: float_key(doc, "net.jitter")?.unwrap_or(nd.jitter),
            bandwidth_mbps: float_key(doc, "net.bandwidth_mbps")?.unwrap_or(nd.bandwidth_mbps),
            loss: float_key(doc, "net.loss")?.unwrap_or(nd.loss),
            node_up_mbps: float_key(doc, "net.node_up_mbps")?.unwrap_or(nd.node_up_mbps),
            node_down_mbps: float_key(doc, "net.node_down_mbps")?.unwrap_or(nd.node_down_mbps),
            seed: int_key(doc, "net.seed")?.map(|v| v as u64).unwrap_or(seed),
        };
        let mut indices: BTreeSet<u64> = BTreeSet::new();
        for key in doc.keys_with_prefix("phase.") {
            let rest = &key["phase.".len()..];
            if let Some((idx, _)) = rest.split_once('.') {
                if let Ok(i) = idx.parse::<u64>() {
                    indices.insert(i);
                }
            }
        }
        let mut phases = Vec::new();
        for i in indices {
            let path = |field: &str| format!("phase.{i}.{field}");
            let kind_name = doc
                .str(&path("kind"))
                .ok_or_else(|| anyhow::anyhow!("phase.{i} is missing `kind`"))?;
            // only accept the fields this kind actually consumes — a
            // known field on the wrong kind (e.g. `fraction` on a
            // mass_fail) would otherwise be silently ignored
            let allowed: &[&str] = match kind_name {
                "mass_join" | "mass_fail" | "mass_leave" => &["kind", "at_ms", "count"],
                "flash_crowd" => &["kind", "at_ms", "count", "dwell_ms"],
                "poisson_churn" => &[
                    "kind",
                    "at_ms",
                    "join_per_min",
                    "fail_per_min",
                    "leave_per_min",
                    "window_ms",
                ],
                "partition" => &["kind", "at_ms", "fraction"],
                "poison" => &["kind", "at_ms", "mode", "frac"],
                "stale_replay" => &["kind", "at_ms", "frac", "lag_ms"],
                "eclipse" => &["kind", "at_ms", "arc"],
                other => bail!("phase.{i}: unknown kind {other:?}"),
            };
            let prefix = format!("phase.{i}.");
            for key in doc.keys_with_prefix(&prefix) {
                let field = &key[prefix.len()..];
                ensure!(
                    allowed.contains(&field),
                    "phase.{i} ({kind_name}): field {field:?} does not apply to this kind"
                );
            }
            let at = ms_key(doc, &path("at_ms"))?
                .ok_or_else(|| anyhow::anyhow!("phase.{i} is missing `at_ms`"))?;
            let need_count = || {
                int_key(doc, &path("count"))?
                    .map(|v| v as usize)
                    .ok_or_else(|| anyhow::anyhow!("phase.{i} is missing `count`"))
            };
            let kind = match kind_name {
                "mass_join" => PhaseKind::MassJoin {
                    count: need_count()?,
                },
                "mass_fail" => PhaseKind::MassFail {
                    count: need_count()?,
                },
                "mass_leave" => PhaseKind::MassLeave {
                    count: need_count()?,
                },
                "flash_crowd" => PhaseKind::FlashCrowd {
                    count: need_count()?,
                    dwell: ms_key(doc, &path("dwell_ms"))?.unwrap_or(20 * SEC),
                },
                "poisson_churn" => PhaseKind::PoissonChurn {
                    join_per_min: float_key(doc, &path("join_per_min"))?.unwrap_or(0.0),
                    fail_per_min: float_key(doc, &path("fail_per_min"))?.unwrap_or(0.0),
                    leave_per_min: float_key(doc, &path("leave_per_min"))?.unwrap_or(0.0),
                    window: ms_key(doc, &path("window_ms"))?.unwrap_or(60 * SEC),
                },
                "partition" => PhaseKind::Partition {
                    fraction: float_key(doc, &path("fraction"))?.unwrap_or(0.25),
                },
                "poison" => PhaseKind::Poison {
                    mode: PoisonMode::parse(doc.str(&path("mode")).unwrap_or("nan"))?,
                    frac: float_key(doc, &path("frac"))?.unwrap_or(0.1),
                },
                "stale_replay" => PhaseKind::StaleReplay {
                    frac: float_key(doc, &path("frac"))?.unwrap_or(0.1),
                    lag: ms_key(doc, &path("lag_ms"))?.unwrap_or(30 * SEC),
                },
                "eclipse" => PhaseKind::Eclipse {
                    arc: float_key(doc, &path("arc"))?.unwrap_or(0.1),
                },
                other => bail!("phase.{i}: unknown kind {other:?}"),
            };
            phases.push(Phase { at, kind });
        }
        let spec = ScenarioSpec {
            name,
            initial,
            seed,
            horizon,
            sample_every,
            settle,
            min_live,
            shards,
            overlay,
            net,
            phases,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Serialize to the TOML subset `from_doc` parses (round-trips for
    /// millisecond-aligned times).
    pub fn to_toml(&self) -> String {
        let mut s = String::new();
        s.push_str("[scenario]\n");
        s.push_str(&format!("name = \"{}\"\n", self.name));
        s.push_str(&format!("initial = {}\n", self.initial));
        s.push_str(&format!("seed = {}\n", self.seed));
        s.push_str(&format!("horizon_ms = {}\n", self.horizon / MS));
        s.push_str(&format!("sample_every_ms = {}\n", self.sample_every / MS));
        s.push_str(&format!("settle_ms = {}\n", self.settle / MS));
        s.push_str(&format!("min_live = {}\n", self.min_live));
        s.push_str(&format!("shards = {}\n", self.shards));
        s.push_str("\n[overlay]\n");
        s.push_str(&format!("spaces = {}\n", self.overlay.spaces));
        s.push_str(&format!("heartbeat_ms = {}\n", self.overlay.heartbeat_ms));
        s.push_str(&format!(
            "failure_multiple = {}\n",
            self.overlay.failure_multiple
        ));
        s.push_str(&format!(
            "repair_probe_ms = {}\n",
            self.overlay.repair_probe_ms
        ));
        s.push_str("\n[net]\n");
        s.push_str(&format!("latency_ms = {}\n", self.net.latency_ms));
        s.push_str(&format!("jitter = {}\n", self.net.jitter));
        s.push_str(&format!("bandwidth_mbps = {}\n", self.net.bandwidth_mbps));
        s.push_str(&format!("loss = {}\n", self.net.loss));
        s.push_str(&format!("node_up_mbps = {}\n", self.net.node_up_mbps));
        s.push_str(&format!("node_down_mbps = {}\n", self.net.node_down_mbps));
        s.push_str(&format!("seed = {}\n", self.net.seed));
        for (i, ph) in self.phases.iter().enumerate() {
            s.push_str(&format!("\n[phase.{}]\n", i + 1));
            s.push_str(&format!("at_ms = {}\n", ph.at / MS));
            match ph.kind {
                PhaseKind::MassJoin { count } => {
                    s.push_str("kind = \"mass_join\"\n");
                    s.push_str(&format!("count = {count}\n"));
                }
                PhaseKind::MassFail { count } => {
                    s.push_str("kind = \"mass_fail\"\n");
                    s.push_str(&format!("count = {count}\n"));
                }
                PhaseKind::MassLeave { count } => {
                    s.push_str("kind = \"mass_leave\"\n");
                    s.push_str(&format!("count = {count}\n"));
                }
                PhaseKind::FlashCrowd { count, dwell } => {
                    s.push_str("kind = \"flash_crowd\"\n");
                    s.push_str(&format!("count = {count}\n"));
                    s.push_str(&format!("dwell_ms = {}\n", dwell / MS));
                }
                PhaseKind::PoissonChurn {
                    join_per_min,
                    fail_per_min,
                    leave_per_min,
                    window,
                } => {
                    s.push_str("kind = \"poisson_churn\"\n");
                    s.push_str(&format!("join_per_min = {join_per_min}\n"));
                    s.push_str(&format!("fail_per_min = {fail_per_min}\n"));
                    s.push_str(&format!("leave_per_min = {leave_per_min}\n"));
                    s.push_str(&format!("window_ms = {}\n", window / MS));
                }
                PhaseKind::Partition { fraction } => {
                    s.push_str("kind = \"partition\"\n");
                    s.push_str(&format!("fraction = {fraction}\n"));
                }
                PhaseKind::Poison { mode, frac } => {
                    s.push_str("kind = \"poison\"\n");
                    s.push_str(&format!("mode = \"{}\"\n", mode.name()));
                    s.push_str(&format!("frac = {frac}\n"));
                }
                PhaseKind::StaleReplay { frac, lag } => {
                    s.push_str("kind = \"stale_replay\"\n");
                    s.push_str(&format!("frac = {frac}\n"));
                    s.push_str(&format!("lag_ms = {}\n", lag / MS));
                }
                PhaseKind::Eclipse { arc } => {
                    s.push_str("kind = \"eclipse\"\n");
                    s.push_str(&format!("arc = {arc}\n"));
                }
            }
        }
        s
    }
}

/// Every key a scenario document may contain (typos fail loudly instead
/// of silently running a different experiment).
const SCALAR_KEYS: &[&str] = &[
    "scenario.name",
    "scenario.initial",
    "scenario.seed",
    "scenario.horizon_ms",
    "scenario.sample_every_ms",
    "scenario.settle_ms",
    "scenario.min_live",
    "scenario.shards",
    "overlay.spaces",
    "overlay.heartbeat_ms",
    "overlay.failure_multiple",
    "overlay.repair_probe_ms",
    "net.latency_ms",
    "net.jitter",
    "net.bandwidth_mbps",
    "net.loss",
    "net.node_up_mbps",
    "net.node_down_mbps",
    "net.seed",
];

const PHASE_FIELDS: &[&str] = &[
    "kind",
    "at_ms",
    "count",
    "dwell_ms",
    "window_ms",
    "join_per_min",
    "fail_per_min",
    "leave_per_min",
    "fraction",
    "mode",
    "frac",
    "lag_ms",
    "arc",
];

fn check_known_keys(doc: &Doc) -> Result<()> {
    for key in doc.keys_with_prefix("") {
        let known = SCALAR_KEYS.contains(&key)
            || key
                .strip_prefix("phase.")
                .and_then(|rest| rest.split_once('.'))
                .is_some_and(|(idx, field)| {
                    idx.parse::<u64>().is_ok() && PHASE_FIELDS.contains(&field)
                });
        ensure!(
            known,
            "unknown scenario key {key:?} (see docs/scenarios.md for the format)"
        );
    }
    Ok(())
}

/// A millisecond time key: absent is fine, present-but-not-integer is an
/// error (a float or string would otherwise silently become a default).
fn ms_key(doc: &Doc, key: &str) -> Result<Option<Time>> {
    match int_key(doc, key)? {
        None => Ok(None),
        Some(v) => Ok(Some(v as Time * MS)),
    }
}

/// Non-negative integer key: every integer a scenario carries (counts,
/// sizes, seeds, milliseconds) is unsigned — a negative would wrap
/// through the `as usize`/`as u64` casts into a multi-exabyte loop.
fn int_key(doc: &Doc, key: &str) -> Result<Option<i64>> {
    match doc.get(key) {
        None => Ok(None),
        Some(v) => {
            let i = v
                .as_int()
                .ok_or_else(|| anyhow::anyhow!("{key} must be an integer, got {v}"))?;
            ensure!(i >= 0, "{key} must be non-negative, got {i}");
            Ok(Some(i))
        }
    }
}

fn float_key(doc: &Doc, key: &str) -> Result<Option<f64>> {
    match doc.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_float()
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("{key} must be a number, got {v}")),
    }
}

fn schedule_events(events: &[ChurnEvent], sink: &mut dyn ChurnSink) -> Result<()> {
    for ev in events {
        match ev.op {
            ChurnOp::Join { node, bootstrap } => sink.join(ev.at, node, bootstrap)?,
            ChurnOp::Fail { node } => sink.fail(ev.at, node)?,
            ChurnOp::Leave { node } => sink.leave(ev.at, node)?,
        }
    }
    Ok(())
}

fn schedule_attacks(attacks: &[AttackEvent], sink: &mut dyn ChurnSink) -> Result<()> {
    for ev in attacks {
        sink.attack(ev.at, ev.op)?;
    }
    Ok(())
}

// ----------------------------------------------------------------------
// Quiescence + ring quality
// ----------------------------------------------------------------------

/// Ideal Definition-1 neighbor sets of a membership: the ground truth a
/// converged overlay's ring views must equal exactly. Batch-computed
/// (one ring sort per space) so 10k-node quiescence checks stay cheap.
pub fn ideal_ring_snapshot(ids: &[NodeId], spaces: usize) -> NeighborSnapshot {
    let mut m = Membership::new(spaces);
    for &id in ids {
        m.add(id);
    }
    crate::topology::ideal_neighbor_sets(&m)
}

/// Whether the simulator's ring views equal the ideal overlay of its
/// live membership (stronger than correctness 1.0: no stale entries).
pub fn ring_matches_ideal(sim: &Simulator) -> bool {
    let live: Vec<NodeId> = sim.node_ids();
    sim.ring_snapshot() == ideal_ring_snapshot(&live, sim.cfg.spaces)
}

/// Advance `sim` until its ring views equal the ideal overlay, checking
/// every `check_every`; returns the convergence time, or `None` if
/// `deadline` passes first.
pub fn quiesce(sim: &mut Simulator, deadline: Time, check_every: Time) -> Option<Time> {
    loop {
        if ring_matches_ideal(sim) {
            return Some(sim.now);
        }
        if sim.now >= deadline {
            return None;
        }
        let next = (sim.now + check_every.max(1)).min(deadline);
        sim.run_until(next);
    }
}

/// Structural health of the Definition-1 ring views.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RingQuality {
    /// Definition-1 correctness of the ring views alone.
    pub correctness: f64,
    /// Directed ring entries whose reverse entry is missing.
    pub asymmetric_links: usize,
    /// Ring entries pointing at nodes that are not live ("ghosts").
    pub ghost_entries: usize,
    /// Largest ring-neighbor set (bound: 2L).
    pub max_degree: usize,
}

pub fn ring_quality(sim: &Simulator) -> RingQuality {
    let snap = sim.ring_snapshot();
    let mut asymmetric_links = 0;
    let mut ghost_entries = 0;
    let mut max_degree = 0;
    for (id, nbrs) in &snap {
        max_degree = max_degree.max(nbrs.len());
        for n in nbrs {
            match snap.get(n) {
                None => ghost_entries += 1,
                Some(back) => {
                    if !back.contains(id) {
                        asymmetric_links += 1;
                    }
                }
            }
        }
    }
    RingQuality {
        correctness: correctness(&snap, sim.cfg.spaces),
        asymmetric_links,
        ghost_entries,
        max_degree,
    }
}

// ----------------------------------------------------------------------
// Report
// ----------------------------------------------------------------------

/// Structured outcome of a scenario run, consumed by the benches, the
/// golden/property tests, and the CLI.
#[derive(Debug, Clone)]
pub struct ScenarioReport {
    pub scenario: String,
    pub backend: String,
    pub initial: usize,
    pub counts: ChurnCounts,
    /// Correctness time series over the scheduled horizon.
    pub correctness: Vec<CorrectnessSample>,
    pub final_correctness: f64,
    pub live_nodes: usize,
    /// When the rings matched the ideal overlay (settle phase), if asked.
    pub settled_at: Option<Time>,
    pub ring: RingQuality,
    pub control_messages_per_node: f64,
    pub delivered: u64,
    /// `(t, mean accuracy)` of the primary lane — empty for overlay-only
    /// runs.
    pub accuracy: Vec<(Time, f64)>,
    /// Per-task accuracy series `(task name, [(t, mean accuracy)])` —
    /// one entry per lane for trainer runs (single-task runs have one),
    /// empty for overlay-only runs.
    pub task_accuracy: Vec<(String, Vec<(Time, f64)>)>,
    /// Trainer neighbor-cache telemetry (zero for overlay-only runs).
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Frames the link model's loss lottery dropped (0 on lossless
    /// configs — the historical behavior).
    pub lost_frames: u64,
    /// Model-payload megabytes sent per client across lanes (0 for
    /// overlay-only runs) — the bytes axis of accuracy-vs-bytes studies,
    /// charged at the wire scheme's compressed size.
    pub model_mb_per_client: f64,
    /// `(t, honest mean − Byzantine mean)` accuracy-gap series of the
    /// primary lane — empty unless the scenario scheduled attacks on a
    /// trainer run (a healthy defense keeps honest accuracy climbing
    /// while attackers stay at chance, so the gap *grows*; a poisoned
    /// mean drags both down).
    pub accuracy_gap: Vec<(Time, f64)>,
    /// Neighbor models rejected as non-finite across every honest
    /// client and lane (the counted telemetry of the NaN guard).
    pub rejected_models: u64,
    /// Compiled attack tally (all zero for purely-churn scenarios).
    pub attacks: AttackCounts,
}

impl ScenarioReport {
    pub fn from_sim(
        spec: &ScenarioSpec,
        sim: &Simulator,
        counts: ChurnCounts,
        settled_at: Option<Time>,
    ) -> Self {
        Self {
            scenario: spec.name.clone(),
            backend: sim.backend().to_string(),
            initial: spec.initial,
            counts,
            correctness: sim.samples.clone(),
            final_correctness: sim.correctness(),
            live_nodes: sim.live_count(),
            settled_at,
            ring: ring_quality(sim),
            control_messages_per_node: sim.control_messages_per_node(),
            delivered: sim.delivered,
            accuracy: Vec::new(),
            task_accuracy: Vec::new(),
            cache_hits: 0,
            cache_misses: 0,
            lost_frames: sim.lost_frames(),
            model_mb_per_client: 0.0,
            accuracy_gap: Vec::new(),
            rejected_models: 0,
            attacks: AttackCounts::default(),
        }
    }

    /// Per-task accuracy series as one aligned table: a "t (min)" column
    /// plus one column per task, rows padded with "-" where a lane has
    /// fewer samples. The one construction shared by `render` and the
    /// CLI's `train --tasks` output.
    pub fn task_accuracy_table(tasks: &[(String, Vec<(Time, f64)>)]) -> crate::bench_util::Table {
        let mut headers: Vec<String> = vec!["t (min)".into()];
        headers.extend(tasks.iter().map(|(n, _)| n.clone()));
        let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut t = crate::bench_util::Table::new(&hdr_refs);
        let rows = tasks.iter().map(|(_, s)| s.len()).max().unwrap_or(0);
        for r in 0..rows {
            let at = tasks
                .iter()
                .filter_map(|(_, s)| s.get(r))
                .map(|(at, _)| *at)
                .next()
                .unwrap_or(0);
            let mut cells = vec![format!("{:.1}", at as f64 / 60e6)];
            for (_, s) in tasks {
                cells.push(
                    s.get(r)
                        .map(|(_, acc)| format!("{acc:.4}"))
                        .unwrap_or_else(|| "-".into()),
                );
            }
            t.row(&cells);
        }
        t
    }

    /// The correctness timeline as an aligned table — the one
    /// construction shared by `render`, the figure benches, and the CLI.
    pub fn correctness_table(&self) -> crate::bench_util::Table {
        let mut t = crate::bench_util::Table::new(&["t (s)", "correctness", "live nodes"]);
        for s in &self.correctness {
            t.row(&[
                format!("{:.1}", s.at as f64 / 1e6),
                format!("{:.4}", s.correctness),
                s.live_nodes.to_string(),
            ]);
        }
        t
    }

    /// Human-readable rendering (timeline + summary) for the CLI/benches.
    pub fn render(&self) -> String {
        use crate::bench_util::Table;
        let mut out = String::new();
        out.push_str(&self.correctness_table().render());
        if self.task_accuracy.len() > 1 {
            // multi-task run: one accuracy column per task, rows aligned
            // by sample index (every lane shares the sampling cadence)
            out.push_str(&Self::task_accuracy_table(&self.task_accuracy).render());
        } else if !self.accuracy.is_empty() {
            let mut a = Table::new(&["t (min)", "mean accuracy"]);
            for (at, acc) in &self.accuracy {
                a.row(&[format!("{:.1}", *at as f64 / 60e6), format!("{acc:.4}")]);
            }
            out.push_str(&a.render());
        }
        out.push_str(&format!(
            "scenario={} backend={} initial={} joins={} fails={} leaves={}\n",
            self.scenario,
            self.backend,
            self.initial,
            self.counts.joins,
            self.counts.fails,
            self.counts.leaves
        ));
        out.push_str(&format!(
            "final correctness={:.4} live={} ring[asym={} ghost={} max_deg={}] \
             ctrl msgs/node={:.1} delivered={}\n",
            self.final_correctness,
            self.live_nodes,
            self.ring.asymmetric_links,
            self.ring.ghost_entries,
            self.ring.max_degree,
            self.control_messages_per_node,
            self.delivered
        ));
        if let Some(at) = self.settled_at {
            out.push_str(&format!(
                "settled to ideal rings at t={:.1}s\n",
                at as f64 / 1e6
            ));
        }
        if self.cache_hits + self.cache_misses > 0 {
            out.push_str(&format!(
                "neighbor cache: {} hits / {} misses\n",
                self.cache_hits, self.cache_misses
            ));
        }
        // link-model telemetry, shown only when the feature is on so
        // zero-default runs render exactly as before
        if self.lost_frames > 0 {
            out.push_str(&format!("lost frames (link loss): {}\n", self.lost_frames));
        }
        if self.model_mb_per_client > 0.0 {
            out.push_str(&format!(
                "model payload MB/client: {:.2}\n",
                self.model_mb_per_client
            ));
        }
        // adversarial telemetry, shown only when the scenario scheduled
        // attacks so clean runs render exactly as before
        if !self.accuracy_gap.is_empty() {
            let mut g = Table::new(&["t (min)", "honest-byz acc gap"]);
            for (at, gap) in &self.accuracy_gap {
                g.row(&[format!("{:.1}", *at as f64 / 60e6), format!("{gap:.4}")]);
            }
            out.push_str(&g.render());
        }
        if self.attacks.total() > 0 {
            out.push_str(&format!(
                "attacks: poisoned={} stale={} eclipsed={} rejected models={}\n",
                self.attacks.poisoned, self.attacks.stale, self.attacks.eclipsed,
                self.rejected_models
            ));
        }
        out
    }

    /// Stable, diff-friendly trajectory format for the golden tests:
    /// header, one line per correctness sample, final summary.
    pub fn golden_lines(&self) -> String {
        let mut out = format!(
            "scenario={} initial={} joins={} fails={} leaves={}\n",
            self.scenario, self.initial, self.counts.joins, self.counts.fails, self.counts.leaves
        );
        for s in &self.correctness {
            out.push_str(&format!(
                "t_ms={} c={:.4} live={}\n",
                s.at / MS,
                s.correctness,
                s.live_nodes
            ));
        }
        // trainer runs pin every lane's accuracy series alongside the
        // shared correctness series (absent for overlay-only runs, so
        // existing sim-only goldens are unchanged)
        for (name, series) in &self.task_accuracy {
            for (at, acc) in series {
                out.push_str(&format!("task={name} t_ms={} acc={acc:.4}\n", at / MS));
            }
        }
        // adversarial runs additionally pin the honest-vs-Byzantine gap
        // and the attack/rejection tallies (absent for clean scenarios,
        // so every existing golden is byte-stable)
        for (at, gap) in &self.accuracy_gap {
            out.push_str(&format!("gap t_ms={} gap={gap:.4}\n", at / MS));
        }
        if self.attacks.total() > 0 {
            out.push_str(&format!(
                "attacks poisoned={} stale={} eclipsed={} rejected={}\n",
                self.attacks.poisoned, self.attacks.stale, self.attacks.eclipsed,
                self.rejected_models
            ));
        }
        out.push_str(&format!(
            "final c={:.4} live={}\n",
            self.final_correctness, self.live_nodes
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_overlay() -> OverlayConfig {
        OverlayConfig {
            spaces: 2,
            heartbeat_ms: 500,
            failure_multiple: 3,
            repair_probe_ms: 2_000,
        }
    }

    fn fast_net(seed: u64) -> NetConfig {
        NetConfig {
            latency_ms: 50.0,
            jitter: 0.1,
            seed,
            ..NetConfig::default()
        }
    }

    #[test]
    fn compile_is_deterministic() {
        let spec = ScenarioSpec::poisson_mix(30, 12.0, 30 * SEC, 7);
        assert_eq!(spec.compile(), spec.compile());
        let other = ScenarioSpec::poisson_mix(30, 12.0, 30 * SEC, 8);
        assert_ne!(spec.compile(), other.compile());
    }

    #[test]
    fn compile_membership_arithmetic_holds() {
        let mut spec = ScenarioSpec::poisson_mix(24, 20.0, 40 * SEC, 3);
        spec.phases.push(Phase {
            at: 5 * SEC,
            kind: PhaseKind::MassJoin { count: 6 },
        });
        let events = spec.compile();
        let counts = ChurnCounts::of(&events);
        // every join id is fresh and sequential from `initial`
        let join_ids: Vec<NodeId> = events
            .iter()
            .filter_map(|e| match e.op {
                ChurnOp::Join { node, .. } => Some(node),
                _ => None,
            })
            .collect();
        let want: Vec<NodeId> = (24..24 + counts.joins as NodeId).collect();
        assert_eq!(join_ids, want);
        // victims are never duplicated and never below the floor
        let removed: Vec<NodeId> = events
            .iter()
            .filter_map(|e| match e.op {
                ChurnOp::Fail { node } | ChurnOp::Leave { node } => Some(node),
                _ => None,
            })
            .collect();
        let mut dedup = removed.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), removed.len(), "victim removed twice");
        let final_live = 24 + counts.joins - counts.fails - counts.leaves;
        assert!(final_live >= spec.min_live);
    }

    #[test]
    fn compile_events_are_time_ordered() {
        let mut spec = ScenarioSpec::poisson_mix(20, 15.0, 30 * SEC, 11);
        spec.phases.push(Phase {
            at: 2 * SEC,
            kind: PhaseKind::FlashCrowd {
                count: 4,
                dwell: 10 * SEC,
            },
        });
        let events = spec.compile();
        assert!(events.windows(2).all(|w| w[0].at <= w[1].at));
    }

    #[test]
    fn flash_crowd_pairs_joins_with_leaves() {
        let mut spec = ScenarioSpec::base("flash", 20, 5);
        spec.phases.push(Phase {
            at: SEC,
            kind: PhaseKind::FlashCrowd {
                count: 5,
                dwell: 8 * SEC,
            },
        });
        let events = spec.compile();
        let counts = ChurnCounts::of(&events);
        assert_eq!(counts.joins, 5);
        assert_eq!(counts.leaves, 5);
        for e in &events {
            if let ChurnOp::Leave { .. } = e.op {
                assert_eq!(e.at, SEC + 8 * SEC);
            }
        }
    }

    #[test]
    fn partition_fails_contiguous_ring_arc() {
        let mut spec = ScenarioSpec::base("part", 40, 9);
        spec.phases.push(Phase {
            at: SEC,
            kind: PhaseKind::Partition { fraction: 0.25 },
        });
        let events = spec.compile();
        let counts = ChurnCounts::of(&events);
        assert_eq!(counts.fails, 10);
        // victims form a contiguous run of the space-0 ring order
        let victims: BTreeSet<NodeId> = events
            .iter()
            .filter_map(|e| match e.op {
                ChurnOp::Fail { node } => Some(node),
                _ => None,
            })
            .collect();
        let mut m = Membership::new(spec.overlay.spaces);
        for id in 0..40u64 {
            m.add(id);
        }
        let ring = m.ring(0);
        let positions: Vec<usize> = ring
            .iter()
            .enumerate()
            .filter(|(_, p)| victims.contains(&p.id))
            .map(|(i, _)| i)
            .collect();
        // contiguity mod ring length: exactly one gap > 1 when walking
        // the sorted positions cyclically (or zero if the run wraps).
        let n = ring.len();
        let interior = positions.windows(2).filter(|w| w[1] - w[0] > 1).count();
        let wrap = usize::from((positions[0] + n) - positions[positions.len() - 1] > 1);
        assert!(interior + wrap <= 1, "positions not contiguous: {positions:?}");
    }

    #[test]
    fn adversarial_phases_compile_deterministically() {
        let mut spec = ScenarioSpec::poisson_mix(30, 10.0, 20 * SEC, 7);
        spec.phases.push(Phase {
            at: 5 * SEC,
            kind: PhaseKind::Poison {
                mode: PoisonMode::Nan,
                frac: 0.2,
            },
        });
        spec.phases.push(Phase {
            at: 8 * SEC,
            kind: PhaseKind::StaleReplay {
                frac: 0.1,
                lag: 10 * SEC,
            },
        });
        spec.phases.push(Phase {
            at: 12 * SEC,
            kind: PhaseKind::Eclipse { arc: 0.15 },
        });
        let (e1, a1) = spec.compile_all();
        let (e2, a2) = spec.compile_all();
        assert_eq!(e1, e2);
        assert_eq!(a1, a2);
        let counts = AttackCounts::of(&a1);
        assert!(counts.poisoned > 0 && counts.stale > 0 && counts.eclipsed > 0);
        assert_eq!(counts.total(), a1.len());
        // no node is ever selected by two adversarial phases
        let mut nodes: Vec<NodeId> = a1
            .iter()
            .map(|e| match e.op {
                AttackOp::Poison { node, .. }
                | AttackOp::StaleReplay { node, .. }
                | AttackOp::Eclipse { node } => node,
            })
            .collect();
        let before = nodes.len();
        nodes.sort_unstable();
        nodes.dedup();
        assert_eq!(nodes.len(), before, "attacker selected twice");
    }

    #[test]
    fn attack_phase_leaves_earlier_churn_schedule_untouched() {
        // the replay is time-ordered, so an adversarial phase after the
        // churn window consumes rng draws only after every churn victim
        // was already resolved — the churn half is bitwise-unchanged
        let base = ScenarioSpec::poisson_mix(30, 10.0, 20 * SEC, 7);
        let churn_only = base.compile();
        let mut with_attack = base.clone();
        with_attack.phases.push(Phase {
            at: 50 * SEC,
            kind: PhaseKind::Poison {
                mode: PoisonMode::Scale,
                frac: 0.2,
            },
        });
        let (churn, attacks) = with_attack.compile_all();
        assert_eq!(churn_only, churn);
        assert!(!attacks.is_empty());
    }

    #[test]
    fn adversarial_toml_round_trip_and_field_check() {
        let mut spec = ScenarioSpec::base("adv", 20, 3);
        spec.phases.push(Phase {
            at: 2 * SEC,
            kind: PhaseKind::Poison {
                mode: PoisonMode::SignFlip,
                frac: 0.25,
            },
        });
        spec.phases.push(Phase {
            at: 4 * SEC,
            kind: PhaseKind::StaleReplay {
                frac: 0.1,
                lag: 6 * SEC,
            },
        });
        spec.phases.push(Phase {
            at: 6 * SEC,
            kind: PhaseKind::Eclipse { arc: 0.2 },
        });
        let back = ScenarioSpec::from_toml_str(&spec.to_toml()).expect("round trip");
        assert_eq!(spec, back);
        // a known field on the wrong adversarial kind fails loudly
        let wrong =
            "[scenario]\ninitial = 10\n[phase.1]\nkind = \"poison\"\nat_ms = 5\nfraction = 0.2\n";
        assert!(ScenarioSpec::from_toml_str(wrong).is_err());
        let bad_mode =
            "[scenario]\ninitial = 10\n[phase.1]\nkind = \"poison\"\nat_ms = 5\nmode = \"zero\"\n";
        assert!(ScenarioSpec::from_toml_str(bad_mode).is_err());
        let bad_frac =
            "[scenario]\ninitial = 10\n[phase.1]\nkind = \"poison\"\nat_ms = 5\nfrac = 1.5\n";
        assert!(ScenarioSpec::from_toml_str(bad_frac).is_err());
        let bad_arc =
            "[scenario]\ninitial = 10\n[phase.1]\nkind = \"eclipse\"\nat_ms = 5\narc = 1.0\n";
        assert!(ScenarioSpec::from_toml_str(bad_arc).is_err());
    }

    #[test]
    fn toml_round_trip() {
        let mut spec = ScenarioSpec::fig8a_join_wave(50, 12, 42);
        spec.phases.push(Phase {
            at: 20 * SEC,
            kind: PhaseKind::PoissonChurn {
                join_per_min: 3.0,
                fail_per_min: 1.5,
                leave_per_min: 0.5,
                window: 30 * SEC,
            },
        });
        spec.phases.push(Phase {
            at: 70 * SEC,
            kind: PhaseKind::Partition { fraction: 0.2 },
        });
        spec.settle = 60 * SEC;
        // non-default link-model fields must survive the round trip too
        spec.net.bandwidth_mbps = 12.5;
        spec.net.loss = 0.05;
        spec.net.node_up_mbps = 20.0;
        spec.net.node_down_mbps = 16.0;
        let text = spec.to_toml();
        let back = ScenarioSpec::from_toml_str(&text).expect("round trip parse");
        assert_eq!(spec, back);
    }

    #[test]
    fn from_doc_rejects_invalid_link_model_fields() {
        let bad_loss = "[scenario]\ninitial = 10\n[net]\nloss = 1.5\n";
        assert!(ScenarioSpec::from_toml_str(bad_loss).is_err());
        let bad_bw = "[scenario]\ninitial = 10\n[net]\nbandwidth_mbps = -4.0\n";
        assert!(ScenarioSpec::from_toml_str(bad_bw).is_err());
        // a valid lossy spec parses and carries the fields
        let ok = "[scenario]\ninitial = 10\n[net]\nbandwidth_mbps = 8.0\nloss = 0.02\n\
                  node_up_mbps = 16.0\nnode_down_mbps = 16.0\n";
        let spec = ScenarioSpec::from_toml_str(ok).expect("valid lossy spec");
        assert_eq!(spec.net.bandwidth_mbps, 8.0);
        assert_eq!(spec.net.loss, 0.02);
        assert_eq!(spec.net.node_up_mbps, 16.0);
        assert_eq!(spec.net.node_down_mbps, 16.0);
    }

    #[test]
    fn from_doc_rejects_unknown_kind() {
        let text = "[scenario]\ninitial = 10\n[phase.1]\nkind = \"melt\"\nat_ms = 5\n";
        assert!(ScenarioSpec::from_toml_str(text).is_err());
    }

    #[test]
    fn from_doc_rejects_typos_and_wrong_types() {
        // typoed key: silently running a different experiment is worse
        // than an error
        let typo = "[scenario]\ninitial = 10\nhorizonms = 5000\n";
        assert!(ScenarioSpec::from_toml_str(typo).is_err());
        let typo2 =
            "[scenario]\ninitial = 10\n[phase.1]\nkind = \"flash_crowd\"\nat_ms = 5\ncount = 2\ndwel_ms = 100\n";
        assert!(ScenarioSpec::from_toml_str(typo2).is_err());
        // wrong type: a float horizon must not fall back to the default
        let float_time = "[scenario]\ninitial = 10\nhorizon_ms = 5000.5\n";
        assert!(ScenarioSpec::from_toml_str(float_time).is_err());
        // negative integers would wrap through the usize/u64 casts
        let negative = "[scenario]\ninitial = -5\n";
        assert!(ScenarioSpec::from_toml_str(negative).is_err());
        let neg_count =
            "[scenario]\ninitial = 10\n[phase.1]\nkind = \"mass_join\"\nat_ms = 5\ncount = -1\n";
        assert!(ScenarioSpec::from_toml_str(neg_count).is_err());
        // a known field on the wrong kind is a spec bug, not a default
        let wrong_kind =
            "[scenario]\ninitial = 10\n[phase.1]\nkind = \"mass_fail\"\nat_ms = 5\ncount = 2\nfraction = 0.9\n";
        assert!(ScenarioSpec::from_toml_str(wrong_kind).is_err());
        // the minimal valid spec still parses
        let ok = "[scenario]\ninitial = 10\n";
        assert!(ScenarioSpec::from_toml_str(ok).is_ok());
    }

    #[test]
    fn join_wave_scenario_converges_small() {
        let mut spec = ScenarioSpec::fig8a_join_wave(30, 10, 1);
        spec.overlay = small_overlay();
        spec.net = fast_net(3);
        spec.horizon = 30 * SEC;
        spec.sample_every = 2 * SEC;
        spec.settle = 240 * SEC;
        let (sim, report) = spec.run_sim(None).expect("run");
        assert_eq!(sim.live_count(), 40);
        assert!(
            report.settled_at.is_some(),
            "join wave stuck at {}",
            report.final_correctness
        );
        assert_eq!(report.counts.joins, 10);
        assert_eq!(report.ring.ghost_entries, 0);
        assert_eq!(report.ring.asymmetric_links, 0);
        assert!((report.final_correctness - 1.0).abs() < 1e-12);
        assert!(!report.correctness.is_empty());
    }

    #[test]
    fn golden_lines_are_stable() {
        let mut spec = ScenarioSpec::fig8b_mass_fail(24, 5, 2);
        spec.overlay = small_overlay();
        spec.net = fast_net(2);
        spec.horizon = 20 * SEC;
        spec.sample_every = 5 * SEC;
        let (_, a) = spec.run_sim(None).expect("run a");
        let (_, b) = spec.run_sim(None).expect("run b");
        assert_eq!(a.golden_lines(), b.golden_lines());
        assert!(a.golden_lines().starts_with("scenario=fig8b-mass-fail"));
    }
}
