//! Shared NDMP ring-invariant predicates.
//!
//! One definition of "the overlay is correct", consumed by both
//! confidence suites so the sampled and exhaustive batteries can never
//! drift apart:
//!
//! * the seeded property sweeps (`tests/scenario_properties.rs`) assert
//!   these after quiescing a random churn scenario, and
//! * the exhaustive model checker ([`crate::check`]) asserts them on
//!   every converged state of the swept interleaving space, and its
//!   counterexample-replay harness re-checks them on the concrete
//!   [`crate::sim::Simulator`].
//!
//! Every predicate operates on plain [`NeighborSnapshot`] data so it is
//! equally applicable to a live simulator (`Simulator::ring_snapshot`)
//! and to the checker's abstract states.

use crate::topology::{ideal_neighbor_sets, Membership, NeighborSnapshot, NodeId};
use std::collections::BTreeSet;
use std::fmt;

/// One violated invariant: which predicate failed plus a human-readable
/// description of the offending node(s).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub invariant: &'static str,
    pub detail: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.invariant, self.detail)
    }
}

fn violation(invariant: &'static str, detail: String) -> Violation {
    Violation { invariant, detail }
}

/// Definition-1 degree bound: every ring view set has at most `2L`
/// members (two adjacents per virtual space).
pub fn degree_violations(rings: &NeighborSnapshot, spaces: usize) -> Vec<Violation> {
    let cap = 2 * spaces;
    rings
        .iter()
        .filter(|(_, nbrs)| nbrs.len() > cap)
        .map(|(id, nbrs)| {
            violation(
                "degree",
                format!("node {id} has ring degree {} > 2L = {cap}", nbrs.len()),
            )
        })
        .collect()
}

/// No ghost neighbors: every ring entry points at a live node (a key of
/// the snapshot).
pub fn ghost_violations(rings: &NeighborSnapshot) -> Vec<Violation> {
    let mut out = Vec::new();
    for (id, nbrs) in rings {
        for g in nbrs.iter().filter(|n| !rings.contains_key(n)) {
            out.push(violation(
                "no-ghosts",
                format!("node {id} references departed node {g}"),
            ));
        }
    }
    out
}

/// Ring symmetry: `u ∈ ring(v)` ⇔ `v ∈ ring(u)` for live endpoints
/// (entries pointing at dead nodes are [`ghost_violations`]' findings,
/// not double-reported here).
pub fn symmetry_violations(rings: &NeighborSnapshot) -> Vec<Violation> {
    let mut out = Vec::new();
    for (u, nbrs) in rings {
        for v in nbrs {
            if let Some(back) = rings.get(v) {
                if !back.contains(u) {
                    out.push(violation(
                        "symmetry",
                        format!("ring link {u} -> {v} has no reverse entry"),
                    ));
                }
            }
        }
    }
    out
}

/// Ring ≡ ideal: the snapshot equals the Definition-1 ideal neighbor
/// sets of exactly its live membership (stronger than correctness 1.0 —
/// stale extra entries fail too).
pub fn ideal_violations(rings: &NeighborSnapshot, spaces: usize) -> Vec<Violation> {
    let mut m = Membership::new(spaces);
    for &id in rings.keys() {
        m.add(id);
    }
    let ideal = ideal_neighbor_sets(&m);
    let mut out = Vec::new();
    for (id, nbrs) in rings {
        let want = ideal.get(id).cloned().unwrap_or_default();
        if *nbrs != want {
            out.push(violation(
                "ring-vs-ideal",
                format!("node {id} ring views {nbrs:?} != ideal {want:?}"),
            ));
        }
    }
    out
}

/// Membership arithmetic: the live set equals the expected set
/// (initial + joins − fails − leaves). Reports *lost* nodes (expected
/// but missing) and *zombies* (live but not expected).
pub fn membership_violations(
    live: &BTreeSet<NodeId>,
    expected: &BTreeSet<NodeId>,
) -> Vec<Violation> {
    if live == expected {
        return Vec::new();
    }
    let lost: Vec<_> = expected.difference(live).collect();
    let zombies: Vec<_> = live.difference(expected).collect();
    vec![violation(
        "membership",
        format!("lost {lost:?}, zombies {zombies:?}"),
    )]
}

/// Every ring invariant a *converged* overlay must satisfy at once:
/// degree ≤ 2L, no ghosts, symmetric links, and ring ≡ ideal.
pub fn converged_ring_violations(rings: &NeighborSnapshot, spaces: usize) -> Vec<Violation> {
    let mut out = degree_violations(rings, spaces);
    out.extend(ghost_violations(rings));
    out.extend(symmetry_violations(rings));
    out.extend(ideal_violations(rings, spaces));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(edges: &[(NodeId, &[NodeId])]) -> NeighborSnapshot {
        edges
            .iter()
            .map(|(id, nbrs)| (*id, nbrs.iter().copied().collect()))
            .collect()
    }

    #[test]
    fn clean_two_ring_passes_everything() {
        let rings = snap(&[(1, &[2]), (2, &[1])]);
        assert!(converged_ring_violations(&rings, 1).is_empty());
    }

    #[test]
    fn ghost_and_asymmetry_are_reported_separately() {
        // 1 -> 9 is a ghost (9 not live); 2 -> 1 lacks a reverse entry
        let rings = snap(&[(1, &[9]), (2, &[1])]);
        assert_eq!(ghost_violations(&rings).len(), 1);
        assert_eq!(symmetry_violations(&rings).len(), 1);
    }

    #[test]
    fn degree_bound_uses_2l() {
        let rings = snap(&[(1, &[2, 3, 4]), (2, &[1]), (3, &[1]), (4, &[1])]);
        assert_eq!(degree_violations(&rings, 1).len(), 1);
        assert!(degree_violations(&rings, 2).is_empty());
    }

    #[test]
    fn ideal_comparison_catches_stale_extras() {
        // the true 3-ring for ids {1,2,3} is all-pairs at L=1; drop one
        // link and add nothing: ideal check must flag both endpoints
        let mut m = Membership::new(1);
        for id in [1, 2, 3] {
            m.add(id);
        }
        let mut rings: NeighborSnapshot = ideal_neighbor_sets(&m);
        let removed = rings.get_mut(&1).unwrap().pop_last().unwrap();
        rings.get_mut(&removed).unwrap().remove(&1);
        assert_eq!(ideal_violations(&rings, 1).len(), 2);
    }

    #[test]
    fn membership_reports_lost_and_zombies() {
        let live: BTreeSet<NodeId> = [1, 2, 9].into_iter().collect();
        let expected: BTreeSet<NodeId> = [1, 2, 3].into_iter().collect();
        let v = membership_violations(&live, &expected);
        assert_eq!(v.len(), 1);
        assert!(v[0].detail.contains('3') && v[0].detail.contains('9'));
        assert!(membership_violations(&expected, &expected).is_empty());
    }
}
