//! Overlay-simulator event kinds, instantiating the generic deterministic
//! scheduler (`sim::sched`). Ties at equal timestamps break on a monotone
//! sequence number so runs are exactly reproducible regardless of
//! insertion pattern.

use super::sched::{Scheduled, Scheduler};
use crate::ndmp::messages::Msg;
use crate::topology::NodeId;

#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// Deliver `msg` (sent by `from`) to node `to`.
    Deliver { from: NodeId, to: NodeId, msg: Msg },
    /// Node periodic timer (heartbeats / probes).
    Tick { node: NodeId },
    /// Inject a join: `node` starts joining via `bootstrap`.
    Join { node: NodeId, bootstrap: NodeId },
    /// Crash-fail a node (silent disappearance).
    Fail { node: NodeId },
    /// Graceful leave.
    Leave { node: NodeId },
    /// Snapshot hook for experiment harnesses (records correctness etc.).
    Snapshot { tag: u64 },
}

/// A scheduled overlay event.
pub type Event = Scheduled<EventKind>;

/// Deterministic overlay event queue.
pub type EventQueue = Scheduler<EventKind>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ndmp::messages::Time;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30, EventKind::Snapshot { tag: 3 });
        q.push(10, EventKind::Snapshot { tag: 1 });
        q.push(20, EventKind::Snapshot { tag: 2 });
        let order: Vec<Time> = std::iter::from_fn(|| q.pop().map(|e| e.at)).collect();
        assert_eq!(order, vec![10, 20, 30]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push(5, EventKind::Snapshot { tag: 1 });
        q.push(5, EventKind::Snapshot { tag: 2 });
        q.push(5, EventKind::Snapshot { tag: 3 });
        let tags: Vec<u64> = std::iter::from_fn(|| {
            q.pop().map(|e| match e.kind {
                EventKind::Snapshot { tag } => tag,
                _ => unreachable!(),
            })
        })
        .collect();
        assert_eq!(tags, vec![1, 2, 3]);
    }
}
