//! Discrete-event queue: a deterministic priority queue of timestamped
//! events. Ties break on a monotone sequence number so runs are exactly
//! reproducible regardless of insertion pattern.

use crate::ndmp::messages::{Msg, Time};
use crate::topology::NodeId;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// Deliver `msg` (sent by `from`) to node `to`.
    Deliver { from: NodeId, to: NodeId, msg: Msg },
    /// Node periodic timer (heartbeats / probes).
    Tick { node: NodeId },
    /// Inject a join: `node` starts joining via `bootstrap`.
    Join { node: NodeId, bootstrap: NodeId },
    /// Crash-fail a node (silent disappearance).
    Fail { node: NodeId },
    /// Graceful leave.
    Leave { node: NodeId },
    /// Snapshot hook for experiment harnesses (records correctness etc.).
    Snapshot { tag: u64 },
}

#[derive(Debug, Clone)]
pub struct Event {
    pub at: Time,
    pub seq: u64,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic event queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, at: Time, kind: EventKind) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Event { at, seq, kind });
    }

    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }

    pub fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|e| e.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30, EventKind::Snapshot { tag: 3 });
        q.push(10, EventKind::Snapshot { tag: 1 });
        q.push(20, EventKind::Snapshot { tag: 2 });
        let order: Vec<Time> = std::iter::from_fn(|| q.pop().map(|e| e.at)).collect();
        assert_eq!(order, vec![10, 20, 30]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push(5, EventKind::Snapshot { tag: 1 });
        q.push(5, EventKind::Snapshot { tag: 2 });
        q.push(5, EventKind::Snapshot { tag: 3 });
        let tags: Vec<u64> = std::iter::from_fn(|| {
            q.pop().map(|e| match e.kind {
                EventKind::Snapshot { tag } => tag,
                _ => unreachable!(),
            })
        })
        .collect();
        assert_eq!(tags, vec![1, 2, 3]);
    }
}
