//! Deterministic discrete-event substrate: the generic scheduler
//! (`sched`), overlay event kinds (`event`), the `Transport` abstraction
//! with its in-memory backend (`transport`, `network`), churn injection,
//! the declarative scenario engine (`scenario`), and the NDMP fleet
//! runner.
//!
//! The scheduler is shared with the DFL trainer (`crate::dfl::Trainer`
//! instantiates it with `TrainEvent`), which is what lets training and
//! overlay maintenance run on one time axis: the trainer advances its
//! embedded `Simulator` in lockstep with training time, so mid-training
//! churn rewires the learning topology through the actual NDMP protocol.

pub mod arena;
pub mod churn;
pub mod event;
pub mod invariants;
pub mod network;
pub mod runner;
pub mod sched;
pub mod scenario;
pub mod transport;

pub use arena::NodeArena;
pub use event::{Event, EventKind, EventQueue};
pub use network::{LatencyModel, LinkDelay, LinkModel, SimTransport};
pub use runner::{grow_network, CorrectnessSample, FootprintStats, Simulator};
pub use scenario::{
    quiesce, ring_quality, AttackCounts, AttackEvent, AttackOp, ChurnCounts, ChurnEvent, ChurnOp,
    ChurnSink, MultiTrainerSink, Phase, PhaseKind, PoisonMode, RingQuality, ScenarioReport,
    ScenarioSpec,
};
pub use sched::{EventId, Scheduled, Scheduler};
pub use transport::{Arrival, Transport};
