//! Deterministic discrete-event simulation substrate: event queue,
//! latency model, churn injection, and the NDMP fleet runner.

pub mod churn;
pub mod event;
pub mod network;
pub mod runner;

pub use event::{Event, EventKind, EventQueue};
pub use network::LatencyModel;
pub use runner::{grow_network, CorrectnessSample, Simulator};
